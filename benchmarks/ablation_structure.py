"""Ablation (beyond the paper's figures): clustering + ordering choices.

The paper replaces Block-Vecchia's K-means clustering with Random Anchor
Clustering (RAC) "while maintaining comparable approximation accuracy"
(§5.1.2) and randomly reorders blocks (Alg. 1 step 7). This ablation
quantifies both claims at smoke scale:

  clustering x ordering -> KL divergence + preprocessing wall time.

Expected: RAC ~ K-means in KL (within noise) at a fraction of the
preprocessing cost; coordinate/maxmin orderings give a mild KL
improvement over random (Guinness 2018), at extra preprocessing cost.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SBVConfig, kl_divergence, preprocess
from repro.data.gp_sim import paper_synthetic

from .common import parser, save, table


def main(argv=None):
    ap = parser("ablation_structure")
    args = ap.parse_args(argv)
    n = 1500 if args.scale == "smoke" else 50_000
    bs, m = 10, 30
    x, y, params = paper_synthetic(args.seed, n)
    beta = np.asarray(params.beta)

    rows = []
    for clustering in ("rac", "kmeans"):
        for ordering in ("random", "coord", "maxmin"):
            cfg = SBVConfig(n_blocks=max(1, n // bs), m=m, seed=args.seed,
                            clustering=clustering, ordering=ordering)
            t0 = time.time()
            packed, _ = preprocess(x, y, beta, cfg)
            t_pre = time.time() - t0
            kl = kl_divergence(params, x, packed)
            rows.append({"clustering": clustering, "ordering": ordering,
                         "KL": kl, "KL/n": kl / n, "preproc_s": t_pre})
    table(rows, ["clustering", "ordering", "KL", "KL/n", "preproc_s"],
          "Ablation: block structure choices (SBV)")
    save("ablation_structure", {"rows": rows, "n": n})

    kls = {(r["clustering"], r["ordering"]): r["KL"] for r in rows}
    ts = {(r["clustering"], r["ordering"]): r["preproc_s"] for r in rows}
    # paper claim: RAC comparable to K-means, cheaper preprocessing
    assert kls[("rac", "random")] < 1.3 * kls[("kmeans", "random")], kls
    assert ts[("rac", "random")] < ts[("kmeans", "random")], ts
    print("[ablation] RAC ~ K-means accuracy at lower preprocessing cost: OK")
    return rows


if __name__ == "__main__":
    main()
