"""Run every paper benchmark at smoke scale: ``python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §6). Each benchmark runs in
its OWN subprocess: several need a specific virtual-device count set
before jax initializes (fig9's 8-worker mesh), and isolation keeps one
module's jax state and CPU load from skewing another's measurements.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

MODULES = [
    "fig4_kl_mspe",
    "fig5_satdrag",
    "fig6_relevance",
    "fig7_metarvm",
    "fig8_single_node",
    "fig9_scaling",
    "fig10_energy",
    "table2_complexity",
    "ablation_structure",
    "serving_throughput",
]


def main() -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    failures = []
    for name in MODULES:
        print(f"\n{'='*72}\n  benchmarks.{name}\n{'='*72}", flush=True)
        t0 = time.time()
        r = subprocess.run([sys.executable, "-m", f"benchmarks.{name}"],
                           cwd=root, env=env)
        status = "OK" if r.returncode == 0 else "FAILED"
        if r.returncode != 0:
            failures.append(name)
        print(f"[run] {name}: {status} ({time.time()-t0:.1f}s)", flush=True)
    print(f"\n[run] {len(MODULES) - len(failures)}/{len(MODULES)} benchmarks OK")
    if failures:
        print("[run] FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
