"""Padding occupancy + wall-time: uniform-padded vs bucketed execution.

The uniform layout pads every block to the global ``bs_max`` and every
conditioning set to ``m``; a skewed k-means block-size distribution (the
realistic case the Block Vecchia line of work measures) makes most of
that padded work dead FLOPs. This benchmark builds a deliberately skewed
synthetic (lognormal cluster sizes), runs the likelihood and the chunked
prediction path both ways on the SAME packed data, and reports

  occupancy = Sigma true FLOPs / Sigma padded FLOPs   (1.0 = no waste)

plus steady-state wall time. Gates (ISSUE 3 acceptance): with >= 4
buckets occupancy strictly improves, and wall time does not regress more
than 5%. The CI buckets gate runs this at --scale smoke.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import parser, save, table


def skewed_synthetic(seed: int, n_clusters: int, mean_size: float, d: int = 3):
    """Clustered inputs with lognormal cluster sizes -> skewed k-means blocks."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(size=(n_clusters, d))
    sizes = rng.lognormal(np.log(mean_size), 0.9, size=n_clusters).astype(int) + 5
    x = np.concatenate(
        [c + 0.04 * rng.normal(size=(s, d)) for c, s in zip(centers, sizes)]
    )
    y = rng.normal(size=x.shape[0])
    return x, y


def best_time(fn, reps: int) -> float:
    fn()  # warm the jit cache
    best = np.inf
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def main(argv=None):
    ap = parser("padding_occupancy")
    ap.add_argument("--buckets", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    from repro.core import KernelParams, SBVConfig, preprocess
    from repro.core.buckets import bucket_blocks, bucket_prediction
    from repro.core.fit import neg_loglik_fn
    from repro.core.predict import build_train_index
    from repro.serving import PipelineConfig, predict_synchronous

    if args.scale == "smoke":
        # Sized so device compute (not dispatch overhead) dominates on a
        # 2-core CI host — small enough to finish in ~a minute.
        n_clusters, mean_size, n_blocks, m, n_test, chunk = 16, 60, 48, 64, 4000, 2048
    else:
        n_clusters, mean_size, n_blocks, m, n_test, chunk = 200, 120, 2000, 120, 100_000, 8192

    x, y = skewed_synthetic(args.seed, n_clusters, mean_size)
    d = x.shape[1]
    params = KernelParams.create(sigma2=1.0, beta=[0.3, 0.5, 1.5][:d], nugget=1e-3, d=d)
    cfg = SBVConfig(n_blocks=n_blocks, m=m, clustering="kmeans", seed=args.seed)
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
    bs_true = packed.blk_mask.sum(axis=1)
    print(f"[padding_occupancy] n={x.shape[0]} bc={packed.n_blocks} "
          f"bs true: min={bs_true.min()} med={int(np.median(bs_true))} "
          f"max={bs_true.max()} (padded to {packed.bs_max})")

    uniform = bucket_blocks(packed, n_buckets=1)   # one bucket == uniform layout
    bucketed = bucket_blocks(packed, n_buckets=args.buckets)
    rows = []

    # -- likelihood ---------------------------------------------------
    loss_u = jax.jit(neg_loglik_fn(uniform, 3.5, "ref"))
    loss_b = jax.jit(neg_loglik_fn(bucketed, 3.5, "ref"))
    ll_u, ll_b = float(loss_u(params)), float(loss_b(params))
    assert abs(ll_u - ll_b) <= 1e-10 * max(abs(ll_u), 1.0), (ll_u, ll_b)
    t_u = best_time(lambda: loss_u(params).block_until_ready(), args.reps)
    t_b = best_time(lambda: loss_b(params).block_until_ready(), args.reps)
    rows.append({"path": "loglik/uniform", "occupancy": uniform.occupancy(),
                 "buckets": 1, "time_s": t_u})
    rows.append({"path": "loglik/bucketed", "occupancy": bucketed.occupancy(),
                 "buckets": bucketed.n_buckets, "time_s": t_b})

    # -- chunked prediction -------------------------------------------
    index = build_train_index(x, y, np.asarray(params.beta), m, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    xt = np.concatenate([
        rng.uniform(size=(n_test // 2, d)),
        x[rng.integers(0, x.shape[0], n_test - n_test // 2)]
        + 0.01 * rng.normal(size=(n_test - n_test // 2, d)),
    ])
    cfg_u = PipelineConfig(bs_pred=16, m_pred=m, chunk_size=chunk)
    cfg_b = PipelineConfig(bs_pred=16, m_pred=m, chunk_size=chunk,
                           n_buckets=args.buckets)
    mean_u, _ = predict_synchronous(params, index, xt, cfg_u, seed=args.seed)
    mean_b, _ = predict_synchronous(params, index, xt, cfg_b, seed=args.seed)
    assert np.abs(mean_u - mean_b).max() <= 1e-10

    # Device-side timing on pre-packed chunks: host packing is identical
    # either way (and hidden by the double-buffered pipeline in serving);
    # padding waste lives in the jitted predict programs.
    from repro.core.buckets import prediction_work
    from repro.core.predict import iter_query_chunks, packed_predict

    chunks = [pk for _, pk in iter_query_chunks(index, xt, 16, m,
                                                chunk_size=chunk,
                                                seed=args.seed)]
    pieces_u = [[pk] for pk in chunks]
    pieces_b = [bucket_prediction(pk, args.buckets).buckets for pk in chunks]

    def run_pieces(pieces_list):
        outs = [packed_predict(params, piece)
                for pieces in pieces_list for piece in pieces]
        for mu, _ in outs:
            mu.block_until_ready()

    tp_u = best_time(lambda: run_pieces(pieces_u), args.reps)
    tp_b = best_time(lambda: run_pieces(pieces_b), args.reps)

    tf = pf_u = pf_b = 0.0
    for u, b in zip(pieces_u, pieces_b):
        t1, p1 = prediction_work(u)
        _, pb = prediction_work(b)
        tf += t1
        pf_u += p1
        pf_b += pb
    occ_pu, occ_pb = tf / pf_u, tf / pf_b
    rows.append({"path": "predict/uniform", "occupancy": occ_pu,
                 "buckets": 1, "time_s": tp_u})
    rows.append({"path": "predict/bucketed", "occupancy": occ_pb,
                 "buckets": args.buckets, "time_s": tp_b})

    table(rows, ["path", "buckets", "occupancy", "time_s"],
          title=f"padding occupancy (K={args.buckets}, skewed synthetic)")

    # -- gates --------------------------------------------------------
    assert bucketed.occupancy() > uniform.occupancy(), \
        "bucketing must strictly improve likelihood occupancy on skew"
    assert occ_pb >= occ_pu, "bucketing must not hurt prediction occupancy"
    assert t_b <= 1.05 * t_u, \
        f"bucketed loglik wall-time regressed >5%: {t_b:.4f}s vs {t_u:.4f}s"
    assert tp_b <= 1.05 * tp_u, \
        f"bucketed predict wall-time regressed >5%: {tp_b:.4f}s vs {tp_u:.4f}s"
    print(f"[padding_occupancy] loglik occupancy {uniform.occupancy():.3f} -> "
          f"{bucketed.occupancy():.3f}; speedup {t_u / t_b:.2f}x | predict "
          f"{occ_pu:.3f} -> {occ_pb:.3f}; speedup {tp_u / tp_b:.2f}x")

    from benchmarks.common import calibrate

    save("padding_occupancy", {
        "scale": args.scale, "calib_s": calibrate(),
        "n": int(x.shape[0]), "bc": int(packed.n_blocks),
        "n_buckets": int(bucketed.n_buckets), "rows": rows,
        "loglik_occupancy_uniform": uniform.occupancy(),
        "loglik_occupancy_bucketed": bucketed.occupancy(),
        "predict_occupancy_uniform": occ_pu,
        "predict_occupancy_bucketed": occ_pb,
        "loglik_speedup": t_u / t_b,
        "predict_speedup": tp_u / tp_b,
    })
    return rows


if __name__ == "__main__":
    main()
