"""Paper Fig. 9: weak and strong scaling of distributed SBV.

Virtual CPU devices share one physical socket, so wall-clock "PE" is not
measurable here. The paper's scaling claim rests on three verifiable
properties, each checked directly:

1. LOAD BALANCE (measured): the scaling+partitioning pipeline (Alg. 2)
   distributes blocks/points near-uniformly across workers — the paper
   attributes its PE fluctuations exactly to this balance.
2. O(1) COMMUNICATION (HLO audit): the lowered hot path contains exactly
   one scalar all-reduce per likelihood evaluation (the MPI_Allreduce of
   Alg. 1 step 5) — no data-dependent collectives.
3. DERIVED PE (roofline): per-iteration time = max(compute, memory) on
   each worker's shard + a log2(P) scalar-allreduce latency; weak/strong
   curves for 1..64 workers mirror Fig. 9's near-linear scaling.
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import math

import numpy as np

from repro.analysis.hlo_analysis import DEFAULT_HW
from repro.core import SBVConfig, preprocess
from repro.core.kernels_math import KernelParams
from repro.data.gp_sim import paper_synthetic

from .common import parser, save, table

ALLREDUCE_HOP_S = 2e-6  # scalar-allreduce per-hop latency


def load_balance(n, bs, m, workers, seed):
    x, y, params = paper_synthetic(seed, n)
    cfg = SBVConfig(n_blocks=max(workers, n // bs), m=m,
                    n_workers=workers, seed=seed)
    packed, blocks = preprocess(x, y, np.asarray(params.beta), cfg)
    counts = np.bincount(packed.owners, minlength=workers)
    pts = np.array([packed.blk_mask[packed.owners == w].sum() for w in range(workers)])
    return counts, pts


def hot_path_collectives(n, bs, m, workers, seed):
    from repro.analysis.hlo_cost import CostModel
    from repro.core.distributed import distributed_neg_loglik_fn
    from repro.launch.mesh import make_worker_mesh

    x, y, params = paper_synthetic(seed, n)
    cfg = SBVConfig(n_blocks=max(workers, n // bs), m=m,
                    n_workers=workers, seed=seed)
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
    mesh = make_worker_mesh(workers)
    loss = distributed_neg_loglik_fn(packed, 3.5, mesh, "workers")
    p = KernelParams.create(sigma2=1.0, beta=np.asarray(params.beta),
                            nugget=1e-4, d=x.shape[1])
    compiled = loss.lower(p).compile()
    cm = CostModel(compiled.as_text(), n_devices=workers)
    return cm.collective_bytes()


def derived_pe(n_per_worker, bs, m, workers):
    """Roofline per-iteration seconds for one worker's shard + allreduce."""
    bc = n_per_worker // bs
    flops = bc * (m ** 3 / 3 + bs ** 3 / 3 + m * m * bs + m * bs * bs)
    byts = bc * (m * m + m * bs + bs * bs) * 8 * 3
    t = max(flops / DEFAULT_HW.peak_flops, byts / DEFAULT_HW.hbm_bw)
    return t + math.ceil(math.log2(max(workers, 2))) * ALLREDUCE_HOP_S


def main(argv=None):
    ap = parser("fig9")
    args = ap.parse_args(argv)
    if args.scale == "smoke":
        n, bs, m = 8_000, 20, 24
    else:
        n, bs, m = 2_000_000, 100, 200
    workers = 8

    counts, pts = load_balance(n, bs, m, workers, args.seed)
    imb = float(pts.max() / max(pts.mean(), 1) - 1.0)
    print(f"[fig9] blocks/worker: {counts.tolist()}  points/worker: {pts.tolist()}")
    print(f"[fig9] load imbalance (max/mean - 1): {imb:.3f}")

    coll = hot_path_collectives(n, bs, m, workers, args.seed)
    n_coll = sum(coll["counts"].values())
    print(f"[fig9] hot-path collectives: {coll['counts']} "
          f"(total wire bytes/iter/worker: {coll['total']:.0f})")

    # Derived curves are analytic — always the paper's production sizes
    # (Fig. 9: 2M points/GPU weak, 128M total strong, bs=100, m=200).
    n_w, bs_w, m_w = 2_000_000, 100, 200
    weak = []
    for w in (1, 2, 4, 8, 16, 32, 64):
        t = derived_pe(n_w, bs_w, m_w, w)
        pe = weak[0]["s/iter"] / t if weak else 1.0
        weak.append({"workers": w, "n_total": n_w * w, "s/iter": t, "PE": pe})
    table(weak, ["workers", "n_total", "s/iter", "PE"],
          "Fig. 9 weak scaling (derived, 2M pts/worker)")

    strong = []
    n_tot = 128_000_000
    for w in (1, 2, 4, 8, 16, 32, 64):
        t = derived_pe(n_tot // w, bs_w, m_w, w)
        pe = strong[0]["s/iter"] / (t * w) if strong else 1.0
        strong.append({"workers": w, "n_total": n_tot, "s/iter": t, "PE": pe})
    table(strong, ["workers", "n_total", "s/iter", "PE"],
          "Fig. 9 strong scaling (derived, 128M pts)")

    save("fig9_scaling", {
        "load_balance": {"blocks": counts.tolist(), "points": pts.tolist()},
        "collectives": {k: v for k, v in coll.items()},
        "weak": weak, "strong": strong,
    })

    assert imb < 0.25, f"partitioning load imbalance too high: {imb}"
    assert coll["counts"]["all-reduce"] >= 1 and coll["total"] <= 64 * workers, (
        "hot path must reduce O(1) scalars only", coll)
    assert weak[-1]["PE"] > 0.95 and strong[-1]["PE"] > 0.95
    print("[fig9] balance + O(1)-comm + near-linear derived PE: OK")
    return weak, strong


if __name__ == "__main__":
    main()
