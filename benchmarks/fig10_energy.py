"""Paper Fig. 10: energy per 500-iteration MLE, SBV vs exact GP.

No power meters on CPU, so energy is DERIVED from the roofline step time
(dry-run terms where available, analytic complexity otherwise) times chip
power draw. The paper's comparison is reproduced structurally:

* SBV, 500 iterations over n points: roofline time/iter x 500 x chip W.
* Exact GP, ONE Cholesky iteration at n=122,880 (the [10]-reference point):
  n^3/3 FLOPs at peak x chip W — the paper reports >140 kJ per iteration
  on A100; SBV's FULL 500-iteration MLE on 16x larger data uses a
  fraction of that.
"""
from __future__ import annotations

from repro.analysis.hlo_analysis import DEFAULT_HW

from .common import parser, save, table

CHIP_W = 250.0          # representative accelerator draw under load (W)
EXACT_N = 122_880       # reference exact-GP size from [10]


def sbv_iter_seconds(n, bs, m):
    bc = n // bs
    flops = bc * (m ** 3 / 3 + bs ** 3 / 3 + m * m * bs + m * bs * bs)
    byts = bc * (m * m + m * bs + bs * bs) * 8 * 3
    return max(flops / DEFAULT_HW.peak_flops, byts / DEFAULT_HW.hbm_bw)


def main(argv=None):
    ap = parser("fig10")
    ap.parse_args(argv)

    rows = []
    for n, label in ((2_000_000, "2M (A100-class run)"),
                     (5_000_000, "5M (GH200-class run)")):
        for m in (100, 200, 400):
            t = sbv_iter_seconds(n, 100, m)
            rows.append({
                "workload": f"SBV {label}", "m_est": m,
                "s/iter": t, "iters": 500,
                "energy_kJ": 500 * t * CHIP_W / 1e3,
            })

    # exact GP single iteration (dense FP64 Cholesky), the [10] reference.
    # Roofline-ideal lower bound on the target chip (fp32-class peak; the
    # chip has no fp64 pipe — exact GP pays conversion/emulation on top):
    t_exact = (EXACT_N ** 3 / 3) / (DEFAULT_HW.peak_flops / 4)
    mem_exact = EXACT_N ** 2 * 8 * 3 / DEFAULT_HW.hbm_bw
    t_exact = max(t_exact, mem_exact)
    rows.append({"workload": f"exact GP n={EXACT_N} (roofline ideal)",
                 "m_est": None, "s/iter": t_exact, "iters": 1,
                 "energy_kJ": t_exact * CHIP_W / 1e3})
    # the paper's MEASURED reference: >140 kJ per MLE iteration (A100, [10])
    rows.append({"workload": f"exact GP n={EXACT_N} (paper-measured A100)",
                 "m_est": None, "s/iter": None, "iters": 1,
                 "energy_kJ": 140.0})

    table(rows, ["workload", "m_est", "s/iter", "iters", "energy_kJ"],
          "Fig. 10: derived energy (roofline x chip power)")
    save("fig10_energy", {"rows": rows, "chip_w": CHIP_W})

    sbv_full = max(r["energy_kJ"] for r in rows if r["iters"] == 500)
    ratio = sbv_full / 140.0
    print(f"[fig10] full 500-iter SBV MLE (largest m) vs ONE paper-measured "
          f"exact-GP iteration: {ratio:.2f}x — paper reports 0.12-0.40x; "
          "an entire SBV fit costs a fraction of one exact iteration")
    assert ratio < 0.5, ratio
    return rows


if __name__ == "__main__":
    main()
