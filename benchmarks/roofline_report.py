"""Render EXPERIMENTS.md-ready tables from dryrun_results.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--json FILE] [--mesh pod]
"""
from __future__ import annotations

import argparse
import json


def fmt_row(v: dict) -> str:
    mfu = v["roofline_fraction"] * 100
    return (
        f"| {v['arch']:<18s} | {v['shape']:<11s} | {v['t_compute']*1e3:9.2f} "
        f"| {v['t_memory']*1e3:9.2f} | {v['t_collective']*1e3:9.2f} "
        f"| {v['bottleneck']:<10s} | {v['useful_ratio']*100:5.1f}% | {mfu:5.2f}% "
        f"| {v['peak_memory']/2**30:6.2f} |"
    )


HEADER = (
    "| arch               | shape       | comp (ms) | mem (ms)  | coll (ms) "
    "| bottleneck | useful | MFU*  | peak GiB |\n"
    "|--------------------|-------------|-----------|-----------|-----------"
    "|------------|--------|-------|----------|"
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    args = ap.parse_args(argv)
    with open(args.json) as f:
        results = json.load(f)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    for mesh in meshes:
        rows = [v for v in results.values()
                if v.get("mesh") == mesh and "error" not in v]
        rows.sort(key=lambda v: (v["arch"], v["shape"]))
        print(f"\n### Mesh: {mesh} ({rows[0]['n_devices'] if rows else '?'} chips)\n")
        print(HEADER)
        for v in rows:
            print(fmt_row(v))
    skipped = [v for v in results.values() if "skipped" in v]
    if skipped:
        print("\nSkipped cells (documented in DESIGN.md §4):")
        for v in skipped:
            print(f"* {v['arch']} x {v['shape']}: {v['skipped']}")


if __name__ == "__main__":
    main()
