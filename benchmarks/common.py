"""Shared helpers for the per-figure benchmarks.

Every benchmark accepts ``--scale {smoke,paper}``: smoke sizes finish on
CPU in seconds-to-minutes (used by benchmarks.run and CI); paper sizes
match the publication settings (hours on real hardware).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def parser(name: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(name)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas", "both"],
                    help="kernel backend for benchmarks with a device hot path; "
                         "'both' runs each and cross-checks agreement")
    return ap


def backends(args) -> list[str]:
    """Expand the --backend flag into the list of backends to run."""
    return ["ref", "pallas"] if args.backend == "both" else [args.backend]


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[{name}] results -> {path}")


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0


def table(rows: list[dict], cols: list[str], title: str = ""):
    if title:
        print(f"\n== {title} ==")
    widths = {c: max(len(c), max((len(_fmt(r.get(c))) for r in rows), default=0))
              for c in cols}
    print("  ".join(c.rjust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).rjust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0 or (1e-3 < abs(v) < 1e5):
            return f"{v:.4g}"
        return f"{v:.3e}"
    return str(v)
