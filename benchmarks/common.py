"""Shared helpers for the per-figure benchmarks.

Every benchmark accepts ``--scale {smoke,paper}``: smoke sizes finish on
CPU in seconds-to-minutes (used by benchmarks.run and CI); paper sizes
match the publication settings (hours on real hardware).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

# REPRO_RESULTS_DIR reroutes benchmark output (CI writes fresh runs to a
# scratch dir and compares them against the committed baselines here with
# benchmarks/check_regression.py — see docs/streaming.md).
RESULTS_DIR = os.environ.get(
    "REPRO_RESULTS_DIR", os.path.join(os.path.dirname(__file__), "results")
)


def parser(name: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(name)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas", "both"],
                    help="kernel backend for benchmarks with a device hot path; "
                         "'both' runs each and cross-checks agreement")
    return ap


def backends(args) -> list[str]:
    """Expand the --backend flag into the list of backends to run."""
    return ["ref", "pallas"] if args.backend == "both" else [args.backend]


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[{name}] results -> {path}")


def calibrate(reps: int = 7) -> float:
    """Seconds for a fixed dense float64 workload (GEMM + Cholesky).

    Saved as ``calib_s`` alongside benchmark wall times so the regression
    gate (benchmarks/check_regression.py) can compare NORMALIZED times —
    ``time_s / calib_s`` — across hosts of different speeds. A 10%
    tolerance on normalized time is meaningful even when the committed
    baseline was recorded on different hardware.

    Median of N probes, not min: on shared CI hosts the min is an
    optimistic outlier (one quiet scheduling slot) that made calib_s
    swing by tens of percent run to run and whipsawed every normalized
    time through the denominator; the median is stable against both the
    cold-cache first probes and the lucky fastest one."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((512, 512))
    spd = a @ a.T + 512.0 * np.eye(512)
    np.linalg.cholesky(spd)  # warm BLAS/LAPACK
    times = []
    for _ in range(max(3, reps)):
        t0 = time.perf_counter()
        b = a @ a.T
        np.linalg.cholesky(b + 512.0 * np.eye(512))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0


def table(rows: list[dict], cols: list[str], title: str = ""):
    if title:
        print(f"\n== {title} ==")
    widths = {c: max(len(c), max((len(_fmt(r.get(c))) for r in rows), default=0))
              for c in cols}
    print("  ".join(c.rjust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).rjust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0 or (1e-3 < abs(v) < 1e5):
            return f"{v:.4g}"
        return f"{v:.3e}"
    return str(v)
