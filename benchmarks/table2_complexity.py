"""Paper Table 2: measured complexity scaling of SV vs SBV.

Fits power laws to MEASURED per-iteration FLOPs (from the compiled HLO of
the batched likelihood via the trip-count-aware cost model) as m grows
with bs = m/4 (the paper's recommended ratio):

    SV  compute O(n m^3)   memory O(n m^2)
    SBV compute O(n m^2)   memory O(n m)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import CostModel
from repro.core.kernels_math import KernelParams
from repro.core.vecchia import batched_block_loglik

from .common import parser, save, table


def measure(n, bs, m, d=10):
    bc = max(1, n // bs)
    f = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    args = (
        KernelParams.create(sigma2=1.0, beta=np.full(d, 0.5), nugget=1e-4, d=d),
        jax.ShapeDtypeStruct((bc, bs, d), f), jax.ShapeDtypeStruct((bc, bs), f),
        jax.ShapeDtypeStruct((bc, bs), jnp.bool_),
        jax.ShapeDtypeStruct((bc, m, d), f), jax.ShapeDtypeStruct((bc, m), f),
        jax.ShapeDtypeStruct((bc, m), jnp.bool_),
    )
    fn = lambda p, bx, by, bm, nx, ny, nm: batched_block_loglik(
        p, bx, by, bm, nx, ny, nm, nu=3.5)
    compiled = jax.jit(fn).lower(*args).compile()
    cm = CostModel(compiled.as_text())
    return cm.flops(), cm.bytes_accessed()


def fit_power(ms, vals):
    """exponent p in vals ~ C * m^p."""
    lm, lv = np.log(ms), np.log(vals)
    return float(np.polyfit(lm, lv, 1)[0])


def main(argv=None):
    ap = parser("table2")
    args = ap.parse_args(argv)
    n = 20_000 if args.scale == "smoke" else 500_000
    ms = (16, 32, 64) if args.scale == "smoke" else (100, 200, 400)

    rows = []
    series = {"SV": {"flops": [], "bytes": []}, "SBV": {"flops": [], "bytes": []}}
    for m in ms:
        for name, bs in (("SV", 1), ("SBV", max(1, m // 4))):
            fl, by = measure(n, bs, m)
            series[name]["flops"].append(fl)
            series[name]["bytes"].append(by)
            rows.append({"method": name, "m": m, "bs": bs,
                         "GFLOP/iter": fl / 1e9, "GB/iter": by / 1e9})
    table(rows, ["method", "m", "bs", "GFLOP/iter", "GB/iter"],
          "Table 2: measured cost scaling (fixed n)")

    exps = {}
    for name in ("SV", "SBV"):
        exps[name] = {
            "flops_exp": fit_power(ms, series[name]["flops"]),
            "bytes_exp": fit_power(ms, series[name]["bytes"]),
        }
        print(f"[table2] {name}: FLOPs ~ m^{exps[name]['flops_exp']:.2f}, "
              f"bytes ~ m^{exps[name]['bytes_exp']:.2f}")
    save("table2_complexity", {"rows": rows, "exponents": exps, "n": n})

    assert exps["SV"]["flops_exp"] > exps["SBV"]["flops_exp"] + 0.5, (
        "SV compute should scale ~one power of m worse than SBV", exps)
    assert exps["SV"]["bytes_exp"] > exps["SBV"]["bytes_exp"] + 0.5, (
        "SV memory should scale ~one power of m worse than SBV", exps)
    print("[table2] complexity separation (Table 2): OK")
    return exps


if __name__ == "__main__":
    main()
