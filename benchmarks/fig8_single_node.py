"""Paper Fig. 8: single-node SBV vs SV runtime and throughput.

Two views:
* MEASURED: wall-clock per likelihood iteration on this CPU for SBV
  (bs=100-geometry) vs SV (bs=1) across n and m — the paper's subfigures
  (a)/(c) shape: SBV consistently faster, gap grows with m.
* DERIVED (GPU-model): per-iteration FLOPs from the analytic complexity
  (Table 2) / the compiled HLO, converted to GFLOP/s on the target chip —
  subfigures (b)/(d) shape: SBV sustains much higher throughput because
  batched (m x m) Cholesky work per point is m^2 smaller.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import SBVConfig, preprocess
from repro.core.fit import neg_loglik_fn
from repro.core.kernels_math import KernelParams
from repro.data.gp_sim import paper_synthetic

from .common import parser, save, table


def iter_time(x, y, beta, bs, m, seed, reps=3, n_buckets=None):
    n = x.shape[0]
    cfg = SBVConfig(n_blocks=max(1, n // bs), m=m, seed=seed)
    packed, _ = preprocess(x, y, beta, cfg)
    if n_buckets:
        from repro.core.buckets import bucket_blocks

        packed = bucket_blocks(packed, n_buckets=n_buckets)
    loss = jax.jit(neg_loglik_fn(packed, 3.5, "ref"))
    params = KernelParams.create(sigma2=1.0, beta=beta, nugget=1e-4, d=x.shape[1])
    loss(params).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(reps):
        loss(params).block_until_ready()
    dt = (time.time() - t0) / reps
    # analytic per-iteration flops (complexity analysis, §5.2)
    bc = packed.n_blocks
    flops = bc * (m ** 3 / 3 + bs ** 3 / 3 + m * m * bs + m * bs * bs)
    return dt, flops


HBM_GBPS = 819e9     # target-chip HBM bandwidth (paper's device)
F32_TFLOPS = 197e12  # target-chip dense f32 MXU throughput


def precision_sweep(args):
    """Mixed-precision ladder sweep (docs/precision.md) — the CI
    'tuning' gate's benchmark half.

    Measures the nll at every ladder rung against the f64 reference and
    reports, per rung, the CPU wall time plus the DERIVED (GPU-model)
    iteration time under the fig8 roofline with the rung's storage width
    (assembly traffic halves per rung; the MXU rate doubles at bf16).
    The bf16-vs-f32 speedup claim lives in the model numbers — CPU
    interpret mode emulates MXU numerics but not MXU throughput, so
    measured CPU times are reported for the record, not gated.

    Also exercises the two enforcement stories end to end:
    * enforced ladder — ``assign_precision`` with a hard 1e-6 budget
      demotes rungs until the deployed per-bucket mix meets f32-class
      parity (the ISSUE acceptance bound);
    * autotuner — the measured candidate grid's winner must be within
      5% of the best hand configuration in the same grid, and its
      persisted TuningRecord must reload to identical choices.
    """
    import tempfile

    from repro.core.buckets import (
        _TIER_BUDGETS, PrecisionPolicy, apply_precision, assign_precision,
        bucket_blocks, cast_packed, storage_dtype,
    )
    from repro.core.vecchia import packed_loglik
    from repro.tuning import TuningRecord, autotune_loglik

    from .common import calibrate

    if args.scale == "smoke":
        n, m, bs = 8_000, 40, 25
        n_tune = 3_000
    else:
        n, m, bs = 500_000, 200, 100
        n_tune = 20_000
    x, y, params = paper_synthetic(args.seed, n)
    # The rung sweep evaluates at a WELL-CONDITIONED kernel point
    # (isotropic unit length-scale, healthy nugget): that is where the
    # probe keeps the narrow rungs, so their published budgets are
    # actually exercised. The generator's own params (two length-scales
    # at 0.05 -> near-singular correlation) are kept as the protective
    # case below: there the probe must demote everything to f64.
    beta = np.ones(x.shape[1])
    cfg = SBVConfig(n_blocks=max(1, n // bs), m=m, seed=args.seed)
    packed, _ = preprocess(x, y, beta, cfg)
    par = KernelParams.create(sigma2=1.0, beta=1.0, nugget=1e-2,
                              d=x.shape[1])

    bc = packed.n_blocks
    flops = bc * (m ** 3 / 3 + bs ** 3 / 3 + m * m * bs + m * bs * bs)

    rows, ll64 = [], None
    for tier in ("f64", "f32", "bf16"):
        cast = cast_packed(packed, tier)
        loss = jax.jit(neg_loglik_fn(cast, 3.5, "ref"))
        loss(par).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            loss(par).block_until_ready()
        dt = (time.perf_counter() - t0) / 3
        ll = float(packed_loglik(par, cast, backend="ref"))
        if ll64 is None:
            ll64 = ll
        parity = abs(ll - ll64) / max(1.0, abs(ll64))
        sb = np.dtype(storage_dtype(tier)).itemsize
        byts = bc * ((m * m + m * bs + bs * bs) * sb * 3)
        t_mem = byts / HBM_GBPS
        t_cmp = flops / (F32_TFLOPS * (2.0 if tier == "bf16" else 1.0))
        rows.append({
            "tier": tier, "s/iter(cpu)": dt, "nll_parity": parity,
            "model_s/iter": max(t_mem, t_cmp),
            "budget": _TIER_BUDGETS[tier],
        })

    by_tier = {r["tier"]: r for r in rows}
    model_speedup = (by_tier["f32"]["model_s/iter"]
                     / by_tier["bf16"]["model_s/iter"])
    cpu_speedup_f32 = (by_tier["f64"]["s/iter(cpu)"]
                       / by_tier["f32"]["s/iter(cpu)"])

    # Enforced ladder: hard f32-class budget -> whatever deploys is
    # within 1e-6 of the f64 nll by construction (demotion).
    bucketed = bucket_blocks(packed, n_buckets=4)
    tiers = assign_precision(
        par, bucketed, PrecisionPolicy("bf16", error_budget=1e-6))
    ll_lad = float(packed_loglik(par, apply_precision(bucketed, tiers)))
    ladder_parity = abs(ll_lad - ll64) / max(1.0, abs(ll64))

    # Protective demotion: at the generator's near-singular params the
    # narrow rungs are worthless (f32 can even go NaN) and the probe
    # must refuse them bucket by bucket.
    beta_hard = np.asarray(params.beta)
    packed_hard, _ = preprocess(x, y, beta_hard,
                                SBVConfig(n_blocks=max(1, n // bs), m=m,
                                          seed=args.seed))
    par_hard = KernelParams.create(sigma2=1.0, beta=beta_hard, nugget=1e-4,
                                   d=x.shape[1])
    tiers_hard = assign_precision(
        par_hard, bucket_blocks(packed_hard, n_buckets=4),
        PrecisionPolicy("bf16"))

    # Autotuner: winner within 5% of the grid's best hand config, and
    # the persisted record reloads to the same choices.
    rec = autotune_loglik(
        x[:n_tune], y[:n_tune],
        SBVConfig(n_blocks=max(1, n_tune // bs), m=m, seed=args.seed),
        params=par, bucket_grid=(0, 2, 4), repeats=2)
    best_hand = min(c["time_s"] for c in rec.candidates)
    chosen = next(c for c in rec.candidates
                  if c["n_buckets"] == rec.n_buckets
                  and c["precision"] == rec.precision)
    autotune_ratio = chosen["time_s"] / best_hand
    with tempfile.TemporaryDirectory() as td:
        rec.save(td)
        reload_mismatch = int(TuningRecord.load(td).to_dict() != rec.to_dict())

    table(rows, ["tier", "s/iter(cpu)", "nll_parity", "budget",
                 "model_s/iter"],
          f"Fig. 8 precision ladder (n={n}, m={m}, bs={bs})")
    print(f"[fig8] enforced ladder (budget 1e-6): tiers={tiers} "
          f"parity={ladder_parity:.3g}")
    print(f"[fig8] protective demotion at near-singular params: "
          f"tiers={tiers_hard}")
    print(f"[fig8] model bf16-vs-f32 speedup {model_speedup:.2f}x; "
          f"measured cpu f64->f32 {cpu_speedup_f32:.2f}x")
    print(f"[fig8] autotune winner K={rec.n_buckets} tier={rec.precision} "
          f"ratio-to-best {autotune_ratio:.3f} "
          f"reload {'MISMATCH' if reload_mismatch else 'ok'}")

    save("fig8_precision", {
        "calib_s": calibrate(), "n": n, "m": m, "bs": bs, "rows": rows,
        "ladder_tiers": tiers, "ladder_parity": ladder_parity,
        "hard_tiers": tiers_hard,
        "hard_demotions": sum(t == "f64" for t in tiers_hard) / len(tiers_hard),
        "model_speedup_bf16_vs_f32": model_speedup,
        "cpu_speedup_f64_to_f32": cpu_speedup_f32,
        "autotune_ratio": autotune_ratio,
        "autotune_choice": {"n_buckets": rec.n_buckets,
                            "precision": rec.precision,
                            "bucket_tiers": rec.bucket_tiers},
        "reload_mismatch": reload_mismatch,
    })

    # ISSUE acceptance gates (mirrored in check_regression SPECS):
    assert ladder_parity <= 1e-6, ladder_parity
    assert by_tier["bf16"]["nll_parity"] <= _TIER_BUDGETS["bf16"], rows
    assert model_speedup >= 1.3, model_speedup
    assert autotune_ratio <= 1.05, autotune_ratio
    assert reload_mismatch == 0
    # the probe must refuse narrow tiers where they cannot hold budget
    assert all(t == "f64" for t in tiers_hard), tiers_hard
    print("[fig8] precision sweep gates: OK")
    return rows


def main(argv=None):
    ap = parser("fig8")
    ap.add_argument("--precision", default="none",
                    choices=["none", "sweep"],
                    help="'sweep' runs the mixed-precision ladder sweep "
                         "(docs/precision.md) instead of the SV-vs-SBV "
                         "scan: per-rung nll parity vs f64, roofline-model "
                         "iteration times, the budget-enforced ladder, and "
                         "the autotuner-vs-hand-grid check")
    ap.add_argument("--bucketed", action="store_true",
                    help="run the likelihood on the bucketed layout (4 "
                         "geometric ceiling levels per dimension; realized "
                         "buckets = occupied (bs, m) cells, up to 4^2 — see "
                         "docs/packing.md) so the perf trajectory captures "
                         "uniform-vs-bucketed on the same seed")
    args = ap.parse_args(argv)
    if args.precision == "sweep":
        return precision_sweep(args)
    if args.scale == "smoke":
        ns, ms, bs_sbv = (2_000, 8_000), (20, 40, 80), 25
    else:
        ns, ms, bs_sbv = (500_000, 2_000_000), (100, 200, 400), 100

    rows = []
    for n in ns:
        x, y, params = paper_synthetic(args.seed, n)
        beta = np.asarray(params.beta)
        for m in ms:
            for name, bs in (("SV", 1), ("SBV", bs_sbv)):
                dt, flops = iter_time(x, y, beta, bs, m, args.seed,
                                      n_buckets=4 if args.bucketed else None)
                rows.append({
                    "method": name, "n": n, "m": m, "bs": bs,
                    "s/iter(cpu)": dt,
                    "GFLOP/iter": flops / 1e9,
                    "model-GFLOP/s@819GBps": None,  # filled below
                })
    # derived throughput on the target chip: the batched pipeline is
    # memory-bound (Fig. roofline); bytes/iter ~ 3 covariance builds
    for r in rows:
        m, bs = r["m"], r["bs"]
        bc = r["n"] // bs
        byts = bc * ((m * m + m * bs + bs * bs) * 8 * 3)
        t_mem = byts / 819e9
        t_cmp = r["GFLOP/iter"] * 1e9 / 197e12
        r["model-GFLOP/s@819GBps"] = r["GFLOP/iter"] / max(t_mem, t_cmp)

    table(rows, ["method", "n", "m", "bs", "s/iter(cpu)", "GFLOP/iter",
                 "model-GFLOP/s@819GBps"],
          "Fig. 8: single-node SBV vs SV"
          + (" (bucketed layout)" if args.bucketed else ""))
    save("fig8_single_node", {"bucketed": args.bucketed, "rows": rows})

    # the algorithmic gap grows with m (paper Fig. 8); at the smallest m
    # the iteration is dispatch-dominated on CPU and timing-noisy, so the
    # assertion covers m >= the midpoint of the sweep.
    for n in ns:
        for m in ms[1:]:
            sv = next(r for r in rows if r["method"] == "SV" and r["n"] == n and r["m"] == m)
            sbv = next(r for r in rows if r["method"] == "SBV" and r["n"] == n and r["m"] == m)
            assert sbv["s/iter(cpu)"] < sv["s/iter(cpu)"], (
                f"SBV should beat SV at n={n} m={m}")
    print("[fig8] SBV faster than SV at every (n, m >= mid): OK")
    return rows


if __name__ == "__main__":
    main()
