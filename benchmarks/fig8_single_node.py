"""Paper Fig. 8: single-node SBV vs SV runtime and throughput.

Two views:
* MEASURED: wall-clock per likelihood iteration on this CPU for SBV
  (bs=100-geometry) vs SV (bs=1) across n and m — the paper's subfigures
  (a)/(c) shape: SBV consistently faster, gap grows with m.
* DERIVED (GPU-model): per-iteration FLOPs from the analytic complexity
  (Table 2) / the compiled HLO, converted to GFLOP/s on the target chip —
  subfigures (b)/(d) shape: SBV sustains much higher throughput because
  batched (m x m) Cholesky work per point is m^2 smaller.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import SBVConfig, preprocess
from repro.core.fit import neg_loglik_fn
from repro.core.kernels_math import KernelParams
from repro.data.gp_sim import paper_synthetic

from .common import parser, save, table


def iter_time(x, y, beta, bs, m, seed, reps=3, n_buckets=None):
    n = x.shape[0]
    cfg = SBVConfig(n_blocks=max(1, n // bs), m=m, seed=seed)
    packed, _ = preprocess(x, y, beta, cfg)
    if n_buckets:
        from repro.core.buckets import bucket_blocks

        packed = bucket_blocks(packed, n_buckets=n_buckets)
    loss = jax.jit(neg_loglik_fn(packed, 3.5, "ref"))
    params = KernelParams.create(sigma2=1.0, beta=beta, nugget=1e-4, d=x.shape[1])
    loss(params).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(reps):
        loss(params).block_until_ready()
    dt = (time.time() - t0) / reps
    # analytic per-iteration flops (complexity analysis, §5.2)
    bc = packed.n_blocks
    flops = bc * (m ** 3 / 3 + bs ** 3 / 3 + m * m * bs + m * bs * bs)
    return dt, flops


def main(argv=None):
    ap = parser("fig8")
    ap.add_argument("--bucketed", action="store_true",
                    help="run the likelihood on the bucketed layout (4 "
                         "geometric ceiling levels per dimension; realized "
                         "buckets = occupied (bs, m) cells, up to 4^2 — see "
                         "docs/packing.md) so the perf trajectory captures "
                         "uniform-vs-bucketed on the same seed")
    args = ap.parse_args(argv)
    if args.scale == "smoke":
        ns, ms, bs_sbv = (2_000, 8_000), (20, 40, 80), 25
    else:
        ns, ms, bs_sbv = (500_000, 2_000_000), (100, 200, 400), 100

    rows = []
    for n in ns:
        x, y, params = paper_synthetic(args.seed, n)
        beta = np.asarray(params.beta)
        for m in ms:
            for name, bs in (("SV", 1), ("SBV", bs_sbv)):
                dt, flops = iter_time(x, y, beta, bs, m, args.seed,
                                      n_buckets=4 if args.bucketed else None)
                rows.append({
                    "method": name, "n": n, "m": m, "bs": bs,
                    "s/iter(cpu)": dt,
                    "GFLOP/iter": flops / 1e9,
                    "model-GFLOP/s@819GBps": None,  # filled below
                })
    # derived throughput on the target chip: the batched pipeline is
    # memory-bound (Fig. roofline); bytes/iter ~ 3 covariance builds
    for r in rows:
        m, bs = r["m"], r["bs"]
        bc = r["n"] // bs
        byts = bc * ((m * m + m * bs + bs * bs) * 8 * 3)
        t_mem = byts / 819e9
        t_cmp = r["GFLOP/iter"] * 1e9 / 197e12
        r["model-GFLOP/s@819GBps"] = r["GFLOP/iter"] / max(t_mem, t_cmp)

    table(rows, ["method", "n", "m", "bs", "s/iter(cpu)", "GFLOP/iter",
                 "model-GFLOP/s@819GBps"],
          "Fig. 8: single-node SBV vs SV"
          + (" (bucketed layout)" if args.bucketed else ""))
    save("fig8_single_node", {"bucketed": args.bucketed, "rows": rows})

    # the algorithmic gap grows with m (paper Fig. 8); at the smallest m
    # the iteration is dispatch-dominated on CPU and timing-noisy, so the
    # assertion covers m >= the midpoint of the sweep.
    for n in ns:
        for m in ms[1:]:
            sv = next(r for r in rows if r["method"] == "SV" and r["n"] == n and r["m"] == m)
            sbv = next(r for r in rows if r["method"] == "SBV" and r["n"] == n and r["m"] == m)
            assert sbv["s/iter(cpu)"] < sv["s/iter(cpu)"], (
                f"SBV should beat SV at n={n} m={m}")
    print("[fig8] SBV faster than SV at every (n, m >= mid): OK")
    return rows


if __name__ == "__main__":
    main()
