"""Paper Fig. 4: CV vs BV vs SV vs SBV on the synthetic anisotropic GP.

(a) KL divergence to the exact GP (Eq. 4), (b) MSPE, (c) block-size sweep.
True kernel parameters are supplied directly (as in the paper) so the
numbers isolate APPROXIMATION error. CV/SV are bs=1; BV/CV use isotropic
beta=1 structure. Expected ordering (paper): SBV < SV < BV < CV on both
metrics.
"""
from __future__ import annotations

import numpy as np

from repro.core import SBVConfig, kl_divergence, preprocess
from repro.core.predict import mspe, predict_sbv
from repro.data.gp_sim import paper_synthetic

from .common import Timer, backends, parser, save, table


def variant_cfg(variant: str, n: int, bs: int, m: int, seed: int):
    """CV/SV: one point per block. BV/CV: isotropic preprocessing beta."""
    blocks = n if variant in ("cv", "sv") else max(1, n // bs)
    return SBVConfig(n_blocks=blocks, m=m, seed=seed)


def run_variant(variant, x, y, params, bs, m, seed, bs_pred=5, m_pred=None,
                backend_list=("ref",)):
    d = x.shape[1]
    iso = np.ones(d)
    beta_pre = np.asarray(params.beta) if variant in ("sv", "sbv") else iso
    cfg = variant_cfg(variant, x.shape[0], bs, m, seed)
    packed, _ = preprocess(x, y, beta_pre, cfg)
    kl = kl_divergence(params, x, packed)

    n_test = max(200, x.shape[0] // 10)
    rng = np.random.default_rng(seed + 7)
    from repro.data.gp_sim import sample_gp_exact

    xt = rng.uniform(size=(n_test, d))
    xa = np.vstack([x, xt])
    ya = sample_gp_exact(seed + 8, xa, params) if xa.shape[0] <= 3200 else None
    err, t_pred = None, {}
    if ya is not None:
        ytr, yte = ya[: x.shape[0]], ya[x.shape[0]:]
        # true kernel for ALL variants; only the NN-search scaling differs
        preds = {}
        for backend in backend_list:
            run = lambda: predict_sbv(
                params, x, ytr, xt, bs_pred=bs_pred,
                m_pred=m_pred or 2 * m, backend=backend,
                beta_struct=None if variant in ("sv", "sbv") else iso)
            run()  # warm-up: keep one-time jit compilation out of the timing
            with Timer() as tm:
                preds[backend] = run()
            t_pred[backend] = tm.dt
        if len(preds) == 2:  # both backends: cross-check the fused kernel
            np.testing.assert_allclose(
                preds["pallas"].mean, preds["ref"].mean, rtol=1e-5, atol=1e-8)
            np.testing.assert_allclose(
                preds["pallas"].var, preds["ref"].var, rtol=1e-5, atol=1e-8)
        err = mspe(preds[backend_list[0]].mean, yte)
    else:
        print(f"[fig4] n={x.shape[0]} too large for the exact-GP sample: "
              f"MSPE + backend cross-check skipped for {variant!r}")
    return kl, err, t_pred


def main(argv=None):
    ap = parser("fig4")
    args = ap.parse_args(argv)
    n = 1500 if args.scale == "smoke" else 20_000
    bs, m = 10, 30
    x, y, params = paper_synthetic(args.seed, n)

    backend_list = backends(args)
    rows = []
    for variant in ("cv", "bv", "sv", "sbv"):
        kl, err, t_pred = run_variant(variant, x, y, params, bs, m, args.seed,
                                      backend_list=backend_list)
        row = {"variant": variant.upper(), "KL": kl, "MSPE": err, "KL/n": kl / n}
        for backend, dt in t_pred.items():
            row[f"t_{backend}"] = dt
        rows.append(row)
    cols = ["variant", "KL", "KL/n", "MSPE"] + [f"t_{b}" for b in backend_list]
    table(rows, cols, "Fig. 4a/4b: approximation quality")

    # (c) block-size sweep, SBV only
    sweep = []
    for bs_i in (5, 12, 25, 50):
        cfg = SBVConfig(n_blocks=max(1, n // bs_i), m=m, seed=args.seed)
        packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
        sweep.append({"bs_est": bs_i, "KL": kl_divergence(params, x, packed)})
    table(sweep, ["bs_est", "KL"], "Fig. 4c: block-size sweep (SBV)")

    save("fig4_kl_mspe", {"main": rows, "bs_sweep": sweep, "n": n})
    # the paper's ordering: SBV best, CV worst
    kls = {r["variant"]: r["KL"] for r in rows}
    assert kls["SBV"] <= kls["BV"] * 1.05, (kls, "scaling should not hurt BV")
    assert kls["SV"] <= kls["CV"] * 1.05, (kls, "scaling should not hurt CV")
    return rows


if __name__ == "__main__":
    main()
