"""Benchmark-regression gate: fresh smoke runs vs committed baselines.

CI re-runs the smoke benchmarks into a scratch directory
(``REPRO_RESULTS_DIR``) and this script compares them against the JSONs
committed under ``benchmarks/results/``:

    PYTHONPATH=src python -m benchmarks.check_regression \
        --fresh /tmp/bench-fresh padding_occupancy serving_throughput

Per-metric tolerances (see ``SPECS``):

* ``time``   — wall-clock, compared after normalizing by the host
  calibration score (``calib_s``, a fixed GEMM+Cholesky probe saved by
  each benchmark) so a slower CI host doesn't read as a regression.
  FAILS when the normalized time regresses more than the tolerance
  (default 10%); WARNS on an improvement beyond the tolerance so the
  committed baseline gets refreshed.
* ``floor``  — higher-is-better quality metric (occupancy, speedup).
  FAILS when it drops more than the tolerance; WARNS on improvement.
* ``ceiling``— lower-is-better absolute metric (peak RSS). FAILS when it
  grows more than the tolerance.
* ``bound``  — hard absolute bound (parity errors). FAILS when exceeded,
  baseline-independent.

Exit code 1 on any failure. ``--write-baseline`` copies the fresh
results over the committed baselines instead (the refresh workflow when
a warned improvement is real). Documented in docs/streaming.md.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import sys
from dataclasses import dataclass

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "results")


@dataclass
class Metric:
    path: str            # dotted path; rows[key=value] selects a list entry
    kind: str            # 'time' | 'floor' | 'ceiling' | 'bound'
    tol: float = 0.10    # relative tolerance (kind != 'bound')
    bound: float = 0.0   # absolute bound (kind == 'bound')
    warn_only: bool = False
    gated_by: str | None = None  # top-level flag; falsy in fresh -> SKIP


SPECS: dict[str, list[Metric]] = {
    "padding_occupancy": [
        Metric("rows[path=loglik/bucketed].time_s", "time", tol=0.10),
        Metric("rows[path=predict/bucketed].time_s", "time", tol=0.10),
        Metric("loglik_occupancy_bucketed", "floor", tol=0.02),
        Metric("predict_occupancy_bucketed", "floor", tol=0.02),
        # Speedups are time ratios of the same run — machine-independent
        # but noisy on small smoke sizes, so they warn rather than fail.
        Metric("loglik_speedup", "floor", tol=0.15, warn_only=True),
        Metric("predict_speedup", "floor", tol=0.15, warn_only=True),
    ],
    "serving_throughput": [
        Metric("rows[path=sync].time_s", "time", tol=0.10),
        Metric("rows[path=double].time_s", "time", tol=0.10),
        Metric("speedup_double_vs_sync", "floor", tol=0.15, warn_only=True),
        Metric("parity_double_vs_sync", "bound", bound=0.0),
        Metric("parity_vs_predict_sbv", "bound", bound=1e-5),
        # Soak phase (drain vs continuous scheduler): ratio and parity
        # gates only — both sides of each ratio come from the SAME run,
        # so they hold on any host, while absolute soak times ride the
        # calib_s noise and are deliberately ungated. The benchmark
        # itself asserts the hard acceptance thresholds (< 1.0, >= 0.9,
        # <= 1e-12); the gates below catch erosion of the committed
        # margin and the parity contract.
        Metric("soak.interactive_p99_ratio", "bound", bound=1.0),
        Metric("soak.parity_max", "bound", bound=1e-12),
        Metric("soak.bulk_points_ratio", "floor", tol=0.10),
        Metric("soak.continuous.interactive_p99_s", "time", tol=0.30,
               warn_only=True),
        # Router phase (multi-replica shape-affinity routing): ratio and
        # parity gates only, like the soak — both sides of each ratio
        # come from the same run. recompile_ratio is per-replica compile
        # keys touched under affinity vs random routing (the benchmark
        # asserts <= 0.5; the bound re-checks it) and parity is the
        # routing-never-changes-a-result contract. The 3-vs-1-replica
        # throughput floor only means something where thread replicas
        # can actually run in parallel, so it is gated on the fresh
        # run's core count (the benchmark itself asserts the hard 1.5x
        # there).
        Metric("router.recompile_ratio", "bound", bound=0.5),
        Metric("router.parity_max", "bound", bound=1e-12),
        Metric("router.affinity_hit_rate", "floor", tol=0.01),
        Metric("router.qps_ratio_3v1", "floor", tol=0.15,
               gated_by="router_multi_core"),
    ],
    "fig_streaming_scale": [
        Metric("t_fit_s", "time", tol=0.10),
        Metric("t_predict_s", "time", tol=0.10),
        Metric("parity_fit", "bound", bound=1e-10),
        Metric("parity_predict", "bound", bound=1e-10),
        # Inner-loop memory tiers (docs/streaming.md): the device-resident
        # speedup over the disk-spool loop is a same-run time ratio —
        # machine-independent — and the benchmark itself asserts the 1.5x
        # acceptance floor, so this gate catches gradual erosion of the
        # committed margin. Absolute per-step wall time rides the noisy
        # calibration normalization (calib_s swings run-to-run on shared
        # hosts), so like the other microbenchmark times it only warns.
        Metric("tier_speedup", "floor", tol=0.15),
        Metric("tier_parity", "bound", bound=0.0),
        Metric("tier_step_s_cached", "time", tol=0.25, warn_only=True),
        # The benchmark degrades to a warning where /proc is unreadable
        # (rss_measured=false, peak null) — mirror that here as SKIP
        # instead of misreporting a present-but-null metric as missing.
        Metric("peak_rss_delta_mb", "ceiling", tol=0.20,
               gated_by="rss_measured"),
    ],
    # Mixed-precision ladder sweep (the CI 'tuning' gate): ratio and
    # parity gates only — the rung wall times are CPU interpret-mode
    # artifacts (the speedup claim lives in the roofline model numbers,
    # which are deterministic), so nothing here rides calib_s noise.
    "fig8_precision": [
        # Budget-enforced ladder must hold the ISSUE acceptance bound.
        Metric("ladder_parity", "bound", bound=1e-6),
        # Each raw rung within its published tier budget at the sweep's
        # well-conditioned evaluation point (docs/precision.md).
        Metric("rows[tier=bf16].nll_parity", "bound", bound=5e-3),
        Metric("rows[tier=f32].nll_parity", "bound", bound=1e-6),
        # Roofline-model bf16-vs-f32 speedup: deterministic (derived from
        # storage widths), committed baseline 2.0x; acceptance floor 1.3x
        # is asserted inside the benchmark itself.
        Metric("model_speedup_bf16_vs_f32", "floor", tol=0.05),
        # Autotuner winner within 5% of the best hand config in the same
        # measured grid (1.0 == it IS the best).
        Metric("autotune_ratio", "ceiling", tol=0.05),
        # Persisted TuningRecord reloads to identical choices, and the
        # probe demotes every bucket to f64 at the near-singular params
        # (hard_demotions = demoted fraction; 1.0 means all refused).
        Metric("reload_mismatch", "bound", bound=0.0),
        Metric("hard_demotions", "floor", tol=0.0),
    ],
    # Multi-output emulation (the CI 'multioutput' gate): the cost claim
    # is a same-run ratio — batched P-output fit+predict over P
    # independent single-output fits — so it holds on any host, and the
    # parity metrics are pure math on shared structure. The benchmark
    # itself asserts the hard acceptance thresholds (< 0.5, <= 1e-8);
    # the bound gates re-check them from the saved payload and the
    # ceiling catches gradual erosion of the committed margin (warn
    # only: at smoke sizes the batched side is seconds, so the ratio is
    # noisy). Absolute wall times are deliberately ungated.
    "fig7_multioutput": [
        Metric("cost_ratio_multi_vs_independent", "bound", bound=0.5),
        Metric("cost_ratio_multi_vs_independent", "ceiling", tol=0.50,
               warn_only=True),
        Metric("ll_parity_rel", "bound", bound=1e-8),
        Metric("predict_parity_rel", "bound", bound=1e-8),
        Metric("rows[path=multi].time_s", "time", tol=0.30, warn_only=True),
    ],
    # Multi-process streaming fit (the CI 'distributed' gate): every
    # metric here is a parity bound or a same-run ratio — nothing
    # absolute-time, so the gate is meaningful on any shared CI host.
    "fig_streaming_mh": [
        # Every rank must land on the single-process nll; the benchmark
        # asserts 1e-8, the gate re-checks it from the saved payload.
        Metric("mh_nll_parity", "bound", bound=1e-8),
        # Ranks run a lockstep allreduce — they must agree EXACTLY.
        Metric("mh_nll_spread", "bound", bound=0.0),
        # Per-rank peak RSS over 2x its partitioned working-set model
        # (same-run ratio; the benchmark asserts <= 1.0, the ceiling
        # catches gradual erosion of the committed headroom). Skipped
        # where /proc is unreadable, like the single-host RSS gate.
        Metric("mh_rss_ratio", "ceiling", tol=0.20,
               gated_by="mh_rss_measured"),
        # Spawn + construction-exchange overhead vs the serial fit —
        # a same-run time ratio, but jit re-compilation per rank makes
        # it noisy at smoke sizes: warn only.
        Metric("mh_slowdown_vs_serial", "ceiling", tol=0.30,
               warn_only=True),
    ],
}

_ROW_RE = re.compile(r"^(\w+)\[(\w+)=(.+)\]$")


def lookup(payload: dict, path: str):
    """Resolve 'a.b' / 'rows[path=loglik/bucketed].time_s' style paths."""
    cur = payload
    for part in path.split("."):
        m = _ROW_RE.match(part)
        if m:
            name, key, want = m.groups()
            rows = cur.get(name, [])
            cur = next((r for r in rows if str(r.get(key)) == want), None)
        elif isinstance(cur, dict):
            cur = cur.get(part)
        else:
            cur = None
        if cur is None:
            return None
    return cur


def check_benchmark(name: str, fresh: dict, base: dict) -> list[tuple]:
    """Return (metric, status, detail) rows; status in OK/WARN/FAIL/SKIP."""
    out = []
    # Normalize wall times by each payload's own calibration score.
    calib_f = fresh.get("calib_s")
    calib_b = base.get("calib_s")
    normalize = bool(calib_f and calib_b)
    for spec in SPECS[name]:
        v_f = lookup(fresh, spec.path)
        if spec.gated_by and not fresh.get(spec.gated_by):
            out.append((spec, "SKIP",
                        f"{spec.gated_by} is false in the fresh run"))
            continue
        if spec.kind == "bound":
            if v_f is None:
                out.append((spec, "FAIL", "metric missing from fresh run"))
            elif float(v_f) <= spec.bound:
                out.append((spec, "OK", f"{v_f:.3g} <= {spec.bound:.3g}"))
            else:
                out.append((spec, "FAIL", f"{v_f:.3g} > bound {spec.bound:.3g}"))
            continue
        v_b = lookup(base, spec.path)
        if v_f is None:
            out.append((spec, "FAIL", "metric missing from fresh run"))
            continue
        if v_b is None:
            out.append((spec, "SKIP", "no baseline yet (new metric)"))
            continue
        v_f, v_b = float(v_f), float(v_b)
        if spec.kind == "time":
            if normalize:
                v_f, v_b = v_f / calib_f, v_b / calib_b
            worse = v_f > v_b * (1.0 + spec.tol)
            better = v_f < v_b * (1.0 - spec.tol)
            unit = "x-calib" if normalize else "s"
        elif spec.kind == "floor":
            worse = v_f < v_b * (1.0 - spec.tol)
            better = v_f > v_b * (1.0 + spec.tol)
            unit = ""
        elif spec.kind == "ceiling":
            worse = v_f > v_b * (1.0 + spec.tol)
            better = v_f < v_b * (1.0 - spec.tol)
            unit = ""
        else:
            raise ValueError(spec.kind)
        detail = f"base {v_b:.4g} -> fresh {v_f:.4g} {unit}".rstrip()
        if worse:
            out.append((spec, "WARN" if spec.warn_only else "FAIL", detail))
        elif better:
            out.append((spec, "WARN",
                        detail + "  (improved: refresh the baseline)"))
        else:
            out.append((spec, "OK", detail))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser("check_regression")
    ap.add_argument("names", nargs="+", choices=sorted(SPECS),
                    help="benchmarks to check")
    ap.add_argument("--fresh", required=True,
                    help="directory holding the fresh <name>.json results")
    ap.add_argument("--baseline", default=BASELINE_DIR,
                    help="committed baseline directory")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy fresh results over the baselines instead "
                         "of comparing (refresh workflow)")
    args = ap.parse_args(argv)

    failed = False
    for name in args.names:
        fresh_path = os.path.join(args.fresh, f"{name}.json")
        base_path = os.path.join(args.baseline, f"{name}.json")
        if args.write_baseline:
            shutil.copyfile(fresh_path, base_path)
            print(f"[check_regression] {name}: baseline refreshed from "
                  f"{fresh_path}")
            continue
        if not os.path.exists(fresh_path):
            print(f"[check_regression] {name}: FAIL — fresh result "
                  f"{fresh_path} missing (benchmark did not run?)")
            failed = True
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        if not os.path.exists(base_path):
            print(f"[check_regression] {name}: no committed baseline — "
                  f"commit {base_path} to arm this gate")
            continue
        with open(base_path) as f:
            base = json.load(f)
        print(f"\n== {name} ==")
        for spec, status, detail in check_benchmark(name, fresh, base):
            print(f"  [{status:4s}] {spec.kind:7s} {spec.path}: {detail}")
            failed |= status == "FAIL"
    if failed:
        print("\n[check_regression] REGRESSION — see FAIL lines above. If "
              "intentional, refresh baselines with --write-baseline.")
        return 1
    print("\n[check_regression] all gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
