"""Paper Fig. 6: estimated input relevance (1/beta_i) on satellite drag.

The drag surrogate is built so dims {pitch, acc1, acc2} dominate; a correct
fit recovers large 1/beta there and ~0 for the inert extra dim.
"""
from __future__ import annotations

import numpy as np

from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.data.gp_sim import satellite_drag_like

from .common import parser, save, table

DIMS = ["vel", "t_srf", "t_atm", "yaw", "pitch", "acc1", "acc2", "extra"]


def main(argv=None):
    ap = parser("fig6")
    args = ap.parse_args(argv)
    n = 4_000 if args.scale == "smoke" else 200_000
    x, y = satellite_drag_like(args.seed, n)
    y = y - y.mean()

    rows = []
    for name, bs, m in (("SV", 1, 20), ("SBV", 10, 40)):
        cfg = SBVConfig(n_blocks=max(1, n // bs), m=m, seed=args.seed)
        res = fit_sbv(x, y, cfg, inner_steps=40, outer_rounds=2)
        rel = 1.0 / np.asarray(res.params.beta)
        rows.append({"model": name, **{d: float(r) for d, r in zip(DIMS, rel)}})

    table(rows, ["model"] + DIMS, "Fig. 6: input relevance 1/beta")
    save("fig6_relevance", {"rows": rows})

    for r in rows:
        strong = np.array([r["pitch"], r["acc1"], r["acc2"]])
        weak = np.array([r["extra"]])
        assert strong.min() > 2.0 * weak.max(), (
            f"{r['model']}: dominant dims should out-rank the inert dim: {r}")
    print("[fig6] dominant-dimension recovery: OK")
    return rows


if __name__ == "__main__":
    main()
