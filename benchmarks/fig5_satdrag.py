"""Paper Fig. 5 / Table 3: RMSPE on the satellite-drag benchmark.

Configurations follow Table 3: SV (bs=1, m_est=50, m_pred=140) vs SBV1-6
(bs_est=100, bs_pred=5, m_est in {200,400}, m_pred in {200,400,600}).
Smoke scale shrinks n and m proportionally but keeps the config GEOMETRY
(ratios of m_est/m_pred/bs) so the ordering is meaningful.
"""
from __future__ import annotations


from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.core.predict import predict_sbv, rmspe
from repro.data.gp_sim import satellite_drag_like

from .common import parser, save, table

# Table 3 geometry; smoke divides sizes by 10 (n by 40)
TABLE3 = {
    "SV":   dict(bs_est=1,   bs_pred=1, m_est=50,  m_pred=140),
    "SBV1": dict(bs_est=100, bs_pred=5, m_est=200, m_pred=200),
    "SBV2": dict(bs_est=100, bs_pred=5, m_est=200, m_pred=400),
    "SBV3": dict(bs_est=100, bs_pred=5, m_est=200, m_pred=600),
    "SBV4": dict(bs_est=100, bs_pred=5, m_est=400, m_pred=200),
    "SBV5": dict(bs_est=100, bs_pred=5, m_est=400, m_pred=400),
    "SBV6": dict(bs_est=100, bs_pred=5, m_est=400, m_pred=600),
}


def main(argv=None):
    ap = parser("fig5")
    args = ap.parse_args(argv)
    if args.scale == "smoke":
        n, shrink, inner, outer = 4_000, 10, 25, 2
    else:
        n, shrink, inner, outer = 2_000_000, 1, 60, 3

    x, y = satellite_drag_like(args.seed, n)
    n_test = n // 10
    x_tr, y_tr = x[:-n_test], y[:-n_test]
    x_te, y_te = x[-n_test:], y[-n_test:]
    mu = y_tr.mean()

    rows = []
    for name, c in TABLE3.items():
        bs_est = max(1, c["bs_est"] // shrink) if c["bs_est"] > 1 else 1
        m_est = max(10, c["m_est"] // shrink)
        m_pred = max(20, c["m_pred"] // shrink)
        # SV on a data subset (paper: SV fits only 50K of 2M)
        sub = len(y_tr) // 4 if name == "SV" else len(y_tr)
        cfg = SBVConfig(n_blocks=max(1, sub // bs_est), m=m_est, seed=args.seed)
        res = fit_sbv(x_tr[:sub], y_tr[:sub] - mu, cfg,
                      inner_steps=inner, outer_rounds=outer)
        pred = predict_sbv(res.params, x_tr[:sub], y_tr[:sub] - mu, x_te,
                           bs_pred=c["bs_pred"], m_pred=m_pred)
        err = rmspe(pred.mean + mu, y_te)
        rows.append({"model": name, "bs_est": bs_est, "m_est": m_est,
                     "m_pred": m_pred, "n_fit": sub, "RMSPE%": err})
        table(rows[-1:], ["model", "bs_est", "m_est", "m_pred", "n_fit", "RMSPE%"])

    table(rows, ["model", "bs_est", "m_est", "m_pred", "n_fit", "RMSPE%"],
          "Fig. 5: satellite-drag RMSPE")
    save("fig5_satdrag", {"rows": rows, "n": n})
    return rows


if __name__ == "__main__":
    main()
