"""Paper Fig. 7: MetaRVM epidemic-emulator accuracy vs neighbor count.

RMSPE decreases with m_est/m_pred; estimated relevance of dh and dr is ~0
(they do not influence accumulated hospitalizations in the simulator).

``--outputs P`` switches to the multi-output mode (docs/multioutput.md):
the MetaRVM trajectory snapshotted at P days is emulated once through the
shared-structure batched fit and compared against P independent
single-output fits — same structure work done once vs P times, one
Cholesky per block reused for all P quadratic forms. The saved
``fig7_multioutput`` payload gates the cost RATIO (batched / sum of
independent) and the per-output likelihood/prediction parity — never
absolute wall times (benchmarks/check_regression.py).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.core.predict import predict_sbv, rmspe
from repro.data.gp_sim import METARVM_BOUNDS, metarvm_dataset

from .common import calibrate, parser, save, table

PARAMS = list(METARVM_BOUNDS)


def multioutput_mode(args) -> dict:
    """Batched P-output emulation vs P independent single-output fits."""
    import jax.numpy as jnp

    from repro.core.multioutput import multi_loglik
    from repro.core.pipeline import preprocess
    from repro.core.vecchia import packed_loglik
    from repro.data.gp_sim import metarvm_field_dataset

    p = args.outputs
    if args.scale == "smoke":
        n, bs, m = 1_200, 10, 20
        inner_steps, outer_rounds = 4, 1
        bs_pred, m_pred = 8, 40
    else:
        n, bs, m = 200_000, 100, 100
        inner_steps, outer_rounds = 30, 2
        bs_pred, m_pred = 25, 200

    x, y = metarvm_field_dataset(args.seed, n, p=p)
    n_test = n // 10
    x_tr, y_tr = x[:-n_test], y[:-n_test]
    x_te = x[-n_test:]
    mu = y_tr.mean(axis=0)
    y_tr_c = y_tr - mu
    cfg = SBVConfig(n_blocks=max(1, len(y_tr) // bs), m=m, seed=args.seed)
    fit_kw = dict(inner_steps=inner_steps, outer_rounds=outer_rounds)

    # Warm the jit caches on a throwaway round so both sides time
    # steady-state math, not compilation (the batched and per-output
    # programs compile different shapes; warm both).
    fit_sbv(x_tr, y_tr_c, cfg, inner_steps=1, outer_rounds=1)
    fit_sbv(x_tr, y_tr_c[:, 0], cfg, inner_steps=1, outer_rounds=1)

    t0 = time.time()
    res_multi = fit_sbv(x_tr, y_tr_c, cfg, **fit_kw)
    pred_multi = predict_sbv(res_multi.params, x_tr, y_tr_c, x_te,
                             bs_pred=bs_pred, m_pred=m_pred, n_sims=2,
                             seed=args.seed)
    t_multi = time.time() - t0

    t0 = time.time()
    preds_ind = []
    for j in range(p):
        res_j = fit_sbv(x_tr, y_tr_c[:, j], cfg, **fit_kw)
        preds_ind.append(predict_sbv(res_j.params, x_tr, y_tr_c[:, j], x_te,
                                     bs_pred=bs_pred, m_pred=m_pred, n_sims=2,
                                     seed=args.seed))
    t_indep = time.time() - t0
    ratio = t_multi / t_indep

    # Parity at the FITTED multi params (shared structure): the batched
    # per-output likelihood vector must match p single-output passes, and
    # the batched prediction must match p per-output predictions — both
    # on the same structure, so the diffs are pure-math, host-independent.
    params = res_multi.params
    ll_single = jnp.stack([
        packed_loglik(params.output_params(j),
                      preprocess(x_tr, y_tr_c[:, j], params.beta, cfg)[0])
        for j in range(p)
    ])
    packed_m, _ = preprocess(x_tr, y_tr_c, params.beta, cfg)
    ll_multi = multi_loglik(params, packed_m)
    ll_parity = float(jnp.max(jnp.abs(ll_multi - ll_single)
                              / jnp.maximum(jnp.abs(ll_single), 1.0)))

    pred_parity = 0.0
    for j in range(p):
        pred_j = predict_sbv(params.output_params(j), x_tr, y_tr_c[:, j],
                             x_te, bs_pred=bs_pred, m_pred=m_pred, n_sims=2,
                             seed=args.seed)
        scale_mu = max(float(np.max(np.abs(pred_j.mean))), 1.0)
        pred_parity = max(
            pred_parity,
            float(np.max(np.abs(pred_multi.mean[:, j] - pred_j.mean)))
            / scale_mu,
            float(np.max(np.abs(pred_multi.var[:, j] - pred_j.var)))
            / max(float(np.max(np.abs(pred_j.var))), 1.0),
        )

    rows = [
        {"path": "multi", "time_s": t_multi, "outputs": p},
        {"path": "independent", "time_s": t_indep, "outputs": p},
    ]
    table(rows + [{"path": "ratio", "time_s": ratio}],
          ["path", "time_s", "outputs"],
          f"Fig. 7 multi-output: batched vs {p} independent fits")
    print(f"[fig7] ll parity (rel) {ll_parity:.3g}, "
          f"predict parity (rel) {pred_parity:.3g}")

    payload = {
        "outputs": p, "n": n, "rows": rows,
        "cost_ratio_multi_vs_independent": ratio,
        "ll_parity_rel": ll_parity,
        "predict_parity_rel": pred_parity,
        "calib_s": calibrate(),
    }
    save("fig7_multioutput", payload)

    # Acceptance: sublinear-in-p cost — the batched fit+predict must beat
    # HALF the cost of p independent fits; parity must hold to 1e-8.
    assert ratio < 0.5, (
        f"batched {p}-output cost {t_multi:.2f}s is not < 0.5x the "
        f"{p} independent fits' {t_indep:.2f}s (ratio {ratio:.3f})")
    assert ll_parity <= 1e-8, ll_parity
    assert pred_parity <= 1e-8, pred_parity
    print("[fig7] multi-output cost + parity acceptance: OK")
    return payload


def main(argv=None):
    ap = parser("fig7")
    ap.add_argument("--outputs", type=int, default=0, metavar="P",
                    help="run the multi-output mode: emulate the MetaRVM "
                         "trajectory at P snapshot days via the shared-"
                         "structure batched fit and gate its cost ratio "
                         "against P independent fits (docs/multioutput.md)")
    args = ap.parse_args(argv)
    if args.outputs > 1:
        return multioutput_mode(args)
    if args.scale == "smoke":
        n, m_list, bs = 4_000, (10, 20, 40), 10
    else:
        n, m_list, bs = 50_000_000, (100, 200, 400), 100

    x, y = metarvm_dataset(args.seed, n)
    n_test = n // 10
    x_tr, y_tr = x[:-n_test], y[:-n_test]
    x_te, y_te = x[-n_test:], y[-n_test:]
    mu = y_tr.mean()

    rows, rel_rows = [], []
    for m in m_list:
        cfg = SBVConfig(n_blocks=max(1, len(y_tr) // bs), m=m, seed=args.seed)
        res = fit_sbv(x_tr, y_tr - mu, cfg, inner_steps=30, outer_rounds=2)
        pred = predict_sbv(res.params, x_tr, y_tr - mu, x_te,
                           bs_pred=max(bs // 4, 2), m_pred=2 * m)
        err = rmspe(pred.mean + mu, y_te)
        rel = 1.0 / np.asarray(res.params.beta)
        rows.append({"m_est": m, "m_pred": 2 * m, "RMSPE%": err})
        rel_rows.append({"m_est": m, **{p: float(r) for p, r in zip(PARAMS, rel)}})

    table(rows, ["m_est", "m_pred", "RMSPE%"], "Fig. 7a: RMSPE vs m")
    table(rel_rows, ["m_est"] + PARAMS, "Fig. 7b: relevance 1/beta")
    save("fig7_metarvm", {"rmspe": rows, "relevance": rel_rows, "n": n})

    r = rel_rows[-1]
    influential = max(r["ts"], r["tv"], r["ds"], r["de"])
    assert r["dh"] < 0.5 * influential and r["dr"] < 0.5 * influential, (
        "dh/dr should be least relevant (they don't drive cumulative "
        f"hospitalizations): {r}")
    print("[fig7] dh/dr low-relevance check: OK")
    return rows


if __name__ == "__main__":
    main()
