"""Paper Fig. 7: MetaRVM epidemic-emulator accuracy vs neighbor count.

RMSPE decreases with m_est/m_pred; estimated relevance of dh and dr is ~0
(they do not influence accumulated hospitalizations in the simulator).
"""
from __future__ import annotations

import numpy as np

from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.core.predict import predict_sbv, rmspe
from repro.data.gp_sim import METARVM_BOUNDS, metarvm_dataset

from .common import parser, save, table

PARAMS = list(METARVM_BOUNDS)


def main(argv=None):
    ap = parser("fig7")
    args = ap.parse_args(argv)
    if args.scale == "smoke":
        n, m_list, bs = 4_000, (10, 20, 40), 10
    else:
        n, m_list, bs = 50_000_000, (100, 200, 400), 100

    x, y = metarvm_dataset(args.seed, n)
    n_test = n // 10
    x_tr, y_tr = x[:-n_test], y[:-n_test]
    x_te, y_te = x[-n_test:], y[-n_test:]
    mu = y_tr.mean()

    rows, rel_rows = [], []
    for m in m_list:
        cfg = SBVConfig(n_blocks=max(1, len(y_tr) // bs), m=m, seed=args.seed)
        res = fit_sbv(x_tr, y_tr - mu, cfg, inner_steps=30, outer_rounds=2)
        pred = predict_sbv(res.params, x_tr, y_tr - mu, x_te,
                           bs_pred=max(bs // 4, 2), m_pred=2 * m)
        err = rmspe(pred.mean + mu, y_te)
        rel = 1.0 / np.asarray(res.params.beta)
        rows.append({"m_est": m, "m_pred": 2 * m, "RMSPE%": err})
        rel_rows.append({"m_est": m, **{p: float(r) for p, r in zip(PARAMS, rel)}})

    table(rows, ["m_est", "m_pred", "RMSPE%"], "Fig. 7a: RMSPE vs m")
    table(rel_rows, ["m_est"] + PARAMS, "Fig. 7b: relevance 1/beta")
    save("fig7_metarvm", {"rmspe": rows, "relevance": rel_rows, "n": n})

    r = rel_rows[-1]
    influential = max(r["ts"], r["tv"], r["ds"], r["de"])
    assert r["dh"] < 0.5 * influential and r["dr"] < 0.5 * influential, (
        "dh/dr should be least relevant (they don't drive cumulative "
        f"hospitalizations): {r}")
    print("[fig7] dh/dr low-relevance check: OK")
    return rows


if __name__ == "__main__":
    main()
