"""Persistent-serving throughput: sync chunk loop vs double-buffered
pipeline vs the micro-batching GPServer (ISSUE 2 acceptance benchmark).

Three measurements over the same workload, same train index, warmed jit
cache:

  sync    — strictly serial pack -> compute -> scatter per chunk
            (the pre-server ``serve gp`` behavior);
  double  — double-buffered chunk pipeline (host packs chunk k+1 while
            the device computes chunk k);
  server  — full GPServer request path: the test set split into
            concurrent requests, coalesced by the micro-batcher, each
            batch through the double-buffered pipeline.

Parity gates: double ≡ sync bitwise, and both ≡ ``predict_sbv`` with the
same chunking protocol to <= 1e-5. The server path's outputs are
sanity-gated (finite means, positive variances); its exact micro-batched
≡ one-shot equivalence is pinned deterministically in
tests/test_serving.py (here, post-warmup batches use fresh per-batch
seeds and timing-dependent request grouping, so bitwise comparison
against a single reference call is not defined).

Note on CPU numbers: XLA CPU compute already saturates the host cores,
so overlap buys ~1.1x here; on a real TPU/GPU the host packing cost
vanishes from steady-state entirely (that is the point of the design).

Soak phase (ISSUE 7): the SAME mixed-SLO arrival stream — bulk sweeps
up front, then Poisson interactive arrivals (20 ms mean) with a
back-to-back burst in the middle — replayed against drain mode and the
continuous-batching scheduler. Reported per mode: per-class client-side
p50/p99 latency, bulk goodput, queue-depth peak, preemptions. Gates
(ratios and parity only — absolute times ride calib_s noise):

  * interactive p99 (continuous) strictly below drain — preemption at
    chunk boundaries must beat waiting out whole bulk batches;
  * bulk goodput within 10% of drain — goodput is total bulk points
    over the wall time to drain the whole mixed stream, identical
    compute in both modes, so the ratio isolates scheduler overhead;
  * sampled continuous-mode requests match their own per-request
    ``predict_sbv`` to <= 1e-12 (the scheduler reorders chunks, never
    changes what any chunk computes).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import parser, save, table


def main():
    ap = parser("serving_throughput")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--bucketed", action="store_true",
                    help="serve size-bucketed micro-batches (4 geometric "
                         "ceiling levels per dimension per chunk; realized "
                         "buckets = occupied (bs, m) cells — docs/packing.md) "
                         "so the perf trajectory captures uniform-vs-bucketed "
                         "on the same seed")
    args = ap.parse_args()

    from repro.core.predict import predict_sbv
    from repro.data.gp_sim import paper_synthetic
    from repro.serving import (
        BatchingPolicy, GPServer, GPServerConfig, PipelineConfig,
        SchedulerPolicy, predict_pipelined, predict_synchronous,
    )

    if args.scale == "smoke":
        n_train, n_test, chunk, bs, m, n_req = 8000, 16000, 2048, 16, 96, 16
    else:
        n_train, n_test, chunk, bs, m, n_req = 100_000, 500_000, 8192, 25, 120, 128

    backend = args.backend if args.backend != "both" else "ref"
    x, y, params = paper_synthetic(args.seed, n_train)
    rng = np.random.default_rng(args.seed + 1)
    x_test = rng.uniform(size=(n_test, x.shape[1]))

    pipe_cfg = PipelineConfig(bs_pred=bs, m_pred=m, chunk_size=chunk,
                              backend=backend,
                              n_buckets=4 if args.bucketed else None)
    cfg = GPServerConfig(
        pipeline=pipe_cfg,
        policy=BatchingPolicy(max_points=chunk, max_wait_s=0.005),
        seed=args.seed,
    )
    t0 = time.time()
    server = GPServer(params, x, y, cfg)
    t_index = time.time() - t0

    rows = []
    results = {}
    with server:
        server.warmup()
        # Warm every chunk shape of THIS workload so reps measure steady state.
        predict_synchronous(params, server.index, x_test, pipe_cfg,
                            seed=args.seed)

        for name, runner in (("sync", predict_synchronous),
                             ("double", predict_pipelined)):
            best = np.inf
            for _ in range(args.reps):
                t0 = time.time()
                mean, var = runner(params, server.index, x_test, pipe_cfg,
                                   seed=args.seed)
                best = min(best, time.time() - t0)
            results[name] = (mean, var)
            rows.append({"path": name, "time_s": best,
                         "qps": n_test / best})

        best = np.inf
        for _ in range(args.reps):
            bounds = np.linspace(0, n_test, n_req + 1).astype(int)
            t0 = time.time()
            futs = [server.submit(x_test[a:b])
                    for a, b in zip(bounds[:-1], bounds[1:])]
            outs = [f.result() for f in futs]
            best = min(best, time.time() - t0)
        results["server"] = (np.concatenate([r.mean for r in outs]),
                             np.concatenate([r.var for r in outs]))
        rows.append({"path": "server", "time_s": best, "qps": n_test / best})

    # Parity: double vs sync must be bitwise; vs predict_sbv <= 1e-5.
    d_sync = max(abs(results["double"][0] - results["sync"][0]).max(),
                 abs(results["double"][1] - results["sync"][1]).max())
    ref = predict_sbv(params, x, y, x_test, bs_pred=bs, m_pred=m,
                      seed=args.seed, n_sims=2, chunk_size=chunk,
                      backend="ref")
    d_ref = max(abs(results["double"][0] - ref.mean).max(),
                abs(results["double"][1] - ref.var).max())
    assert d_sync == 0.0, f"double vs sync diverged: {d_sync}"
    assert d_ref <= 1e-5, f"pipeline vs predict_sbv diverged: {d_ref}"
    srv_mean, srv_var = results["server"]
    assert srv_mean.shape == (n_test,) and np.all(np.isfinite(srv_mean))
    assert np.all(srv_var > 0), "server path produced non-positive variance"

    qps = {r["path"]: r["qps"] for r in rows}
    speedup = qps["double"] / qps["sync"]
    stats = server.stats.summary()
    table(rows, ["path", "time_s", "qps"],
          title=f"serving throughput (n_test={n_test}, chunk={chunk}, "
                f"m={m}, backend={backend})")
    print(f"\ndouble-buffered speedup over sync: {speedup:.2f}x")
    print(f"parity: double vs sync = {d_sync:.1e}; vs predict_sbv = {d_ref:.1e}")
    print(f"server: latency p50={stats['latency_p50_s']*1e3:.0f}ms "
          f"p95={stats['latency_p95_s']*1e3:.0f}ms "
          f"occupancy={stats['mean_batch_points']:.0f} pts/batch "
          f"compiled-shapes={stats['n_compiled_shapes']} "
          f"padding-occupancy={stats['padding_occupancy']:.3f}")

    # ---- soak: mixed-SLO arrival stream, drain vs continuous ----------
    # Interactive requests are exactly one chunk so the padded compute is
    # identical in both modes and the ratios below isolate SCHEDULING.
    if args.scale == "smoke":
        soak_chunk, n_bulk, bulk_pts, n_inter, burst = 512, 3, 4096, 24, 8
    else:
        soak_chunk, n_bulk, bulk_pts, n_inter, burst = 2048, 4, 16384, 64, 16
    inter_pts = soak_chunk
    soak_pipe = PipelineConfig(bs_pred=bs, m_pred=m, chunk_size=soak_chunk,
                               backend=backend,
                               n_buckets=4 if args.bucketed else None)
    arr_rng = np.random.default_rng(args.seed + 2)
    bulk_x = [arr_rng.uniform(size=(bulk_pts, x.shape[1]))
              for _ in range(n_bulk)]
    inter_x = [arr_rng.uniform(size=(inter_pts, x.shape[1]))
               for _ in range(n_inter)]
    gaps = arr_rng.exponential(0.020, size=n_inter)
    half = (n_inter - burst) // 2
    gaps[half:half + burst] = 0.0            # mid-stream burst

    def run_soak(sched_policy):
        cfg_s = GPServerConfig(
            pipeline=soak_pipe,
            policy=BatchingPolicy(max_points=soak_chunk, max_wait_s=0.002),
            seed=args.seed, scheduler=sched_policy,
        )
        srv = GPServer(params, x, y, cfg_s, index=server.index)
        futs = []
        with srv:
            srv.warmup()
            t_start = time.time()

            def sub(xq, slo):
                t0 = time.time()
                stamp = {}
                f = srv.submit(xq, slo=slo)
                f.add_done_callback(
                    lambda _f, s=stamp: s.setdefault("t", time.time()))
                futs.append((slo, t0, f, stamp, xq))

            for xb in bulk_x:                # bulk sweeps land up front
                sub(xb, "bulk")
            for g, xi in zip(gaps, inter_x):
                if g > 0:
                    time.sleep(g)
                sub(xi, "interactive")
            srv.flush()
            for _, _, f, _, _ in futs:
                f.result(timeout=1200)
        t_total = max(s["t"] for _, _, _, s, _ in futs) - t_start
        lat = {"interactive": [], "bulk": []}
        for slo, t0, _, s, _ in futs:
            lat[slo].append(s["t"] - t0)
        st = srv.stats.summary()
        return {
            "t_total_s": t_total,
            "bulk_points_per_s": n_bulk * bulk_pts / t_total,
            "interactive_p50_s": float(np.percentile(lat["interactive"], 50)),
            "interactive_p99_s": float(np.percentile(lat["interactive"], 99)),
            "bulk_p50_s": float(np.percentile(lat["bulk"], 50)),
            "bulk_p99_s": float(np.percentile(lat["bulk"], 99)),
            "queue_depth_peak": st["queue_depth_peak"],
            "n_preempted": st["n_preempted"],
        }, futs

    soak_drain, _ = run_soak(None)
    soak_cont, cont_futs = run_soak(SchedulerPolicy())

    # Parity sample: continuous-mode requests against their OWN
    # per-request predict_sbv (drain coalesces with per-batch seeds, so
    # the per-request contract only exists in scheduler mode).
    parity_max = 0.0
    sample = [cont_futs[0], cont_futs[n_bulk], cont_futs[-1]]
    for slo, _, f, _, xq in sample:
        res = f.result(timeout=0)
        ref_s = predict_sbv(params, x, y, xq, bs_pred=bs, m_pred=m,
                            seed=args.seed, n_sims=2, chunk_size=soak_chunk,
                            backend=backend)
        parity_max = max(parity_max,
                         float(abs(res.mean - ref_s.mean).max()),
                         float(abs(res.var - ref_s.var).max()))

    p99_ratio = soak_cont["interactive_p99_s"] / soak_drain["interactive_p99_s"]
    bulk_ratio = soak_cont["bulk_points_per_s"] / soak_drain["bulk_points_per_s"]
    assert p99_ratio < 1.0, (
        f"continuous interactive p99 must beat drain: ratio {p99_ratio:.3f}")
    assert bulk_ratio >= 0.9, (
        f"continuous bulk goodput fell >10% below drain: {bulk_ratio:.3f}")
    assert parity_max <= 1e-12, (
        f"continuous-mode per-request parity broken: {parity_max:.3e}")

    soak_rows = [dict(mode=mode, **vals) for mode, vals in
                 (("drain", soak_drain), ("continuous", soak_cont))]
    table(soak_rows,
          ["mode", "interactive_p50_s", "interactive_p99_s", "bulk_p99_s",
           "bulk_points_per_s", "queue_depth_peak", "n_preempted"],
          title=f"soak: {n_bulk}x{bulk_pts} bulk + {n_inter}x{inter_pts} "
                f"interactive (Poisson 20ms + burst {burst}), chunk={soak_chunk}")
    print(f"\nsoak: interactive p99 continuous/drain = {p99_ratio:.3f} "
          f"(must be < 1), bulk goodput ratio = {bulk_ratio:.3f} "
          f"(must be >= 0.9), parity(sampled) = {parity_max:.1e}")

    # ---- router: multi-replica shape-affinity routing -----------------
    # A stream of 9 distinct request size classes (128..1152 points),
    # each class replayed per_class times — REPEAT traffic, the workload
    # affinity routing exists for. (A request's compile key includes the
    # realized max k-means cluster size, which is data-dependent, so the
    # key is deterministic per payload, not per point count: replaying
    # the class payload is what makes its key re-usable at all.) Three
    # configurations over the SAME shuffled stream and one shared train
    # index: 1 replica, 3 replicas with rendezvous shape affinity, 3
    # replicas with seeded-random spray. Affinity must (a) never change
    # a result (per-request parity vs lone predict_sbv <= 1e-12),
    # (b) touch at most half the per-replica compile keys random routing
    # touches, and (c) on a >= 3-core host, carry >= 1.5x the
    # single-replica throughput (thread replicas on a 1-core host cannot
    # speed anything up, so there the gate is a sanity floor; the ratio
    # is recorded either way).
    import os

    from repro.serving import ReplicaRouter

    r_bs, r_m = 16, m
    r_chunk = 2048 if args.scale == "smoke" else 4096
    per_class = 6 if args.scale == "smoke" else 8
    sizes = [(k + 1) * 128 for k in range(9)]
    req_rng = np.random.default_rng(args.seed + 3)
    class_payloads = [req_rng.uniform(size=(s, x.shape[1])) for s in sizes]
    stream = [xq for xq in class_payloads for _ in range(per_class)]
    stream = [stream[i] for i in req_rng.permutation(len(stream))]
    total_pts = sum(s.shape[0] for s in stream)

    router_cfg = GPServerConfig(
        pipeline=PipelineConfig(bs_pred=r_bs, m_pred=r_m,
                                chunk_size=r_chunk, backend=backend),
        policy=BatchingPolicy(max_points=r_chunk, max_wait_s=0.002),
        scheduler=SchedulerPolicy(), seed=args.seed,
    )

    def run_router(n_replicas, routing):
        reps = [GPServer(params, x, y, router_cfg, index=server.index)
                for _ in range(n_replicas)]
        router = ReplicaRouter(reps, routing=routing, seed=args.seed)
        with router:
            t0 = time.time()
            futs = [router.submit(xq) for xq in stream]
            outs = [f.result(timeout=1200) for f in futs]
            dt = time.time() - t0
        shapes = [len(r.stats.compiled_shape_keys()) for r in reps]
        return dt, outs, shapes, router.stats.summary()

    run_router(1, "affinity")  # compile all 9 keys off the clock
    t_r1, outs_r1, shapes_r1, _ = run_router(1, "affinity")
    t_aff, outs_aff, shapes_aff, rsum_aff = run_router(3, "affinity")
    t_rand, outs_rand, shapes_rand, _ = run_router(3, "random")

    qps_router = {"1": total_pts / t_r1, "3_affinity": total_pts / t_aff,
                  "3_random": total_pts / t_rand}
    qps_ratio_3v1 = qps_router["3_affinity"] / qps_router["1"]
    # Per-replica compile keys touched: affinity pins each size class to
    # one replica (mean = 9/3 = 3); random spray cold-starts most
    # classes on most replicas.
    recompile_ratio = float(np.mean(shapes_aff) / np.mean(shapes_rand))

    parity_router = 0.0
    for idx in (0, len(stream) // 2, len(stream) - 1):
        ref_r = predict_sbv(params, x, y, stream[idx], bs_pred=r_bs,
                            m_pred=r_m, seed=args.seed, n_sims=2,
                            chunk_size=r_chunk, backend=backend)
        for outs in (outs_r1, outs_aff, outs_rand):
            parity_router = max(
                parity_router,
                float(abs(outs[idx].mean - np.asarray(ref_r.mean)).max()),
                float(abs(outs[idx].var - np.asarray(ref_r.var)).max()))

    cores = len(os.sched_getaffinity(0))
    router_rows = [
        {"config": "1", "time_s": t_r1, "qps": qps_router["1"],
         "shapes": sum(shapes_r1)},
        {"config": "3_affinity", "time_s": t_aff,
         "qps": qps_router["3_affinity"], "shapes": sum(shapes_aff)},
        {"config": "3_random", "time_s": t_rand,
         "qps": qps_router["3_random"], "shapes": sum(shapes_rand)},
    ]
    table(router_rows, ["config", "time_s", "qps", "shapes"],
          title=f"router: {len(stream)} requests, 9 size classes "
                f"(128..1152 pts), chunk={r_chunk}, {cores} cores")
    print(f"\nrouter: qps 3-replica-affinity / 1-replica = "
          f"{qps_ratio_3v1:.2f}x ({cores} cores), per-replica compile "
          f"keys affinity/random = {recompile_ratio:.2f} (must be <= 0.5), "
          f"affinity-hit={rsum_aff['affinity_hit_rate']:.2f}, "
          f"parity(sampled) = {parity_router:.1e}")
    assert recompile_ratio <= 0.5, (
        f"affinity stopped concentrating compile keys: {recompile_ratio:.3f}")
    assert rsum_aff["affinity_hit_rate"] >= 0.99, rsum_aff
    assert parity_router <= 1e-12, (
        f"routing changed a result: {parity_router:.3e}")
    if cores >= 3:
        assert qps_ratio_3v1 >= 1.5, (
            f"3 replicas on {cores} cores must beat 1.5x one replica: "
            f"{qps_ratio_3v1:.2f}x")
    else:
        assert qps_ratio_3v1 >= 0.5, (
            f"router overhead ate >2x on a {cores}-core host: "
            f"{qps_ratio_3v1:.2f}x")

    from benchmarks.common import calibrate

    save("serving_throughput", {
        "scale": args.scale, "calib_s": calibrate(),
        "backend": backend, "bucketed": args.bucketed,
        "n_train": n_train, "n_test": n_test, "chunk": chunk,
        "bs_pred": bs, "m_pred": m, "n_requests": n_req,
        "t_index_s": t_index, "router_multi_core": cores >= 3,
        "rows": rows, "speedup_double_vs_sync": speedup,
        "parity_double_vs_sync": float(d_sync),
        "parity_vs_predict_sbv": float(d_ref),
        "server_stats": stats,
        "soak": {
            "chunk": soak_chunk, "n_bulk": n_bulk, "bulk_pts": bulk_pts,
            "n_interactive": n_inter, "interactive_pts": inter_pts,
            "burst": burst,
            "drain": soak_drain, "continuous": soak_cont,
            "interactive_p99_ratio": p99_ratio,
            "bulk_points_ratio": bulk_ratio,
            "parity_max": parity_max,
        },
        "router": {
            "chunk": r_chunk, "bs_pred": r_bs, "m_pred": r_m,
            "n_requests": len(stream), "total_points": total_pts,
            "cores": cores, "multi_core": cores >= 3,
            "rows": router_rows,
            "qps_ratio_3v1": qps_ratio_3v1,
            "shapes_affinity": shapes_aff, "shapes_random": shapes_rand,
            "recompile_ratio": recompile_ratio,
            "affinity_hit_rate": rsum_aff["affinity_hit_rate"],
            "parity_max": parity_router,
        },
    })


if __name__ == "__main__":
    main()
