"""Persistent-serving throughput: sync chunk loop vs double-buffered
pipeline vs the micro-batching GPServer (ISSUE 2 acceptance benchmark).

Three measurements over the same workload, same train index, warmed jit
cache:

  sync    — strictly serial pack -> compute -> scatter per chunk
            (the pre-server ``serve gp`` behavior);
  double  — double-buffered chunk pipeline (host packs chunk k+1 while
            the device computes chunk k);
  server  — full GPServer request path: the test set split into
            concurrent requests, coalesced by the micro-batcher, each
            batch through the double-buffered pipeline.

Parity gates: double ≡ sync bitwise, and both ≡ ``predict_sbv`` with the
same chunking protocol to <= 1e-5. The server path's outputs are
sanity-gated (finite means, positive variances); its exact micro-batched
≡ one-shot equivalence is pinned deterministically in
tests/test_serving.py (here, post-warmup batches use fresh per-batch
seeds and timing-dependent request grouping, so bitwise comparison
against a single reference call is not defined).

Note on CPU numbers: XLA CPU compute already saturates the host cores,
so overlap buys ~1.1x here; on a real TPU/GPU the host packing cost
vanishes from steady-state entirely (that is the point of the design).

Soak phase (ISSUE 7): the SAME mixed-SLO arrival stream — bulk sweeps
up front, then Poisson interactive arrivals (20 ms mean) with a
back-to-back burst in the middle — replayed against drain mode and the
continuous-batching scheduler. Reported per mode: per-class client-side
p50/p99 latency, bulk goodput, queue-depth peak, preemptions. Gates
(ratios and parity only — absolute times ride calib_s noise):

  * interactive p99 (continuous) strictly below drain — preemption at
    chunk boundaries must beat waiting out whole bulk batches;
  * bulk goodput within 10% of drain — goodput is total bulk points
    over the wall time to drain the whole mixed stream, identical
    compute in both modes, so the ratio isolates scheduler overhead;
  * sampled continuous-mode requests match their own per-request
    ``predict_sbv`` to <= 1e-12 (the scheduler reorders chunks, never
    changes what any chunk computes).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import parser, save, table


def main():
    ap = parser("serving_throughput")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--bucketed", action="store_true",
                    help="serve size-bucketed micro-batches (4 geometric "
                         "ceiling levels per dimension per chunk; realized "
                         "buckets = occupied (bs, m) cells — docs/packing.md) "
                         "so the perf trajectory captures uniform-vs-bucketed "
                         "on the same seed")
    args = ap.parse_args()

    from repro.core.predict import predict_sbv
    from repro.data.gp_sim import paper_synthetic
    from repro.serving import (
        BatchingPolicy, GPServer, GPServerConfig, PipelineConfig,
        SchedulerPolicy, predict_pipelined, predict_synchronous,
    )

    if args.scale == "smoke":
        n_train, n_test, chunk, bs, m, n_req = 8000, 16000, 2048, 16, 96, 16
    else:
        n_train, n_test, chunk, bs, m, n_req = 100_000, 500_000, 8192, 25, 120, 128

    backend = args.backend if args.backend != "both" else "ref"
    x, y, params = paper_synthetic(args.seed, n_train)
    rng = np.random.default_rng(args.seed + 1)
    x_test = rng.uniform(size=(n_test, x.shape[1]))

    pipe_cfg = PipelineConfig(bs_pred=bs, m_pred=m, chunk_size=chunk,
                              backend=backend,
                              n_buckets=4 if args.bucketed else None)
    cfg = GPServerConfig(
        pipeline=pipe_cfg,
        policy=BatchingPolicy(max_points=chunk, max_wait_s=0.005),
        seed=args.seed,
    )
    t0 = time.time()
    server = GPServer(params, x, y, cfg)
    t_index = time.time() - t0

    rows = []
    results = {}
    with server:
        server.warmup()
        # Warm every chunk shape of THIS workload so reps measure steady state.
        predict_synchronous(params, server.index, x_test, pipe_cfg,
                            seed=args.seed)

        for name, runner in (("sync", predict_synchronous),
                             ("double", predict_pipelined)):
            best = np.inf
            for _ in range(args.reps):
                t0 = time.time()
                mean, var = runner(params, server.index, x_test, pipe_cfg,
                                   seed=args.seed)
                best = min(best, time.time() - t0)
            results[name] = (mean, var)
            rows.append({"path": name, "time_s": best,
                         "qps": n_test / best})

        best = np.inf
        for _ in range(args.reps):
            bounds = np.linspace(0, n_test, n_req + 1).astype(int)
            t0 = time.time()
            futs = [server.submit(x_test[a:b])
                    for a, b in zip(bounds[:-1], bounds[1:])]
            outs = [f.result() for f in futs]
            best = min(best, time.time() - t0)
        results["server"] = (np.concatenate([r.mean for r in outs]),
                             np.concatenate([r.var for r in outs]))
        rows.append({"path": "server", "time_s": best, "qps": n_test / best})

    # Parity: double vs sync must be bitwise; vs predict_sbv <= 1e-5.
    d_sync = max(abs(results["double"][0] - results["sync"][0]).max(),
                 abs(results["double"][1] - results["sync"][1]).max())
    ref = predict_sbv(params, x, y, x_test, bs_pred=bs, m_pred=m,
                      seed=args.seed, n_sims=2, chunk_size=chunk,
                      backend="ref")
    d_ref = max(abs(results["double"][0] - ref.mean).max(),
                abs(results["double"][1] - ref.var).max())
    assert d_sync == 0.0, f"double vs sync diverged: {d_sync}"
    assert d_ref <= 1e-5, f"pipeline vs predict_sbv diverged: {d_ref}"
    srv_mean, srv_var = results["server"]
    assert srv_mean.shape == (n_test,) and np.all(np.isfinite(srv_mean))
    assert np.all(srv_var > 0), "server path produced non-positive variance"

    qps = {r["path"]: r["qps"] for r in rows}
    speedup = qps["double"] / qps["sync"]
    stats = server.stats.summary()
    table(rows, ["path", "time_s", "qps"],
          title=f"serving throughput (n_test={n_test}, chunk={chunk}, "
                f"m={m}, backend={backend})")
    print(f"\ndouble-buffered speedup over sync: {speedup:.2f}x")
    print(f"parity: double vs sync = {d_sync:.1e}; vs predict_sbv = {d_ref:.1e}")
    print(f"server: latency p50={stats['latency_p50_s']*1e3:.0f}ms "
          f"p95={stats['latency_p95_s']*1e3:.0f}ms "
          f"occupancy={stats['mean_batch_points']:.0f} pts/batch "
          f"compiled-shapes={stats['n_compiled_shapes']} "
          f"padding-occupancy={stats['padding_occupancy']:.3f}")

    # ---- soak: mixed-SLO arrival stream, drain vs continuous ----------
    # Interactive requests are exactly one chunk so the padded compute is
    # identical in both modes and the ratios below isolate SCHEDULING.
    if args.scale == "smoke":
        soak_chunk, n_bulk, bulk_pts, n_inter, burst = 512, 3, 4096, 24, 8
    else:
        soak_chunk, n_bulk, bulk_pts, n_inter, burst = 2048, 4, 16384, 64, 16
    inter_pts = soak_chunk
    soak_pipe = PipelineConfig(bs_pred=bs, m_pred=m, chunk_size=soak_chunk,
                               backend=backend,
                               n_buckets=4 if args.bucketed else None)
    arr_rng = np.random.default_rng(args.seed + 2)
    bulk_x = [arr_rng.uniform(size=(bulk_pts, x.shape[1]))
              for _ in range(n_bulk)]
    inter_x = [arr_rng.uniform(size=(inter_pts, x.shape[1]))
               for _ in range(n_inter)]
    gaps = arr_rng.exponential(0.020, size=n_inter)
    half = (n_inter - burst) // 2
    gaps[half:half + burst] = 0.0            # mid-stream burst

    def run_soak(sched_policy):
        cfg_s = GPServerConfig(
            pipeline=soak_pipe,
            policy=BatchingPolicy(max_points=soak_chunk, max_wait_s=0.002),
            seed=args.seed, scheduler=sched_policy,
        )
        srv = GPServer(params, x, y, cfg_s, index=server.index)
        futs = []
        with srv:
            srv.warmup()
            t_start = time.time()

            def sub(xq, slo):
                t0 = time.time()
                stamp = {}
                f = srv.submit(xq, slo=slo)
                f.add_done_callback(
                    lambda _f, s=stamp: s.setdefault("t", time.time()))
                futs.append((slo, t0, f, stamp, xq))

            for xb in bulk_x:                # bulk sweeps land up front
                sub(xb, "bulk")
            for g, xi in zip(gaps, inter_x):
                if g > 0:
                    time.sleep(g)
                sub(xi, "interactive")
            srv.flush()
            for _, _, f, _, _ in futs:
                f.result(timeout=1200)
        t_total = max(s["t"] for _, _, _, s, _ in futs) - t_start
        lat = {"interactive": [], "bulk": []}
        for slo, t0, _, s, _ in futs:
            lat[slo].append(s["t"] - t0)
        st = srv.stats.summary()
        return {
            "t_total_s": t_total,
            "bulk_points_per_s": n_bulk * bulk_pts / t_total,
            "interactive_p50_s": float(np.percentile(lat["interactive"], 50)),
            "interactive_p99_s": float(np.percentile(lat["interactive"], 99)),
            "bulk_p50_s": float(np.percentile(lat["bulk"], 50)),
            "bulk_p99_s": float(np.percentile(lat["bulk"], 99)),
            "queue_depth_peak": st["queue_depth_peak"],
            "n_preempted": st["n_preempted"],
        }, futs

    soak_drain, _ = run_soak(None)
    soak_cont, cont_futs = run_soak(SchedulerPolicy())

    # Parity sample: continuous-mode requests against their OWN
    # per-request predict_sbv (drain coalesces with per-batch seeds, so
    # the per-request contract only exists in scheduler mode).
    parity_max = 0.0
    sample = [cont_futs[0], cont_futs[n_bulk], cont_futs[-1]]
    for slo, _, f, _, xq in sample:
        res = f.result(timeout=0)
        ref_s = predict_sbv(params, x, y, xq, bs_pred=bs, m_pred=m,
                            seed=args.seed, n_sims=2, chunk_size=soak_chunk,
                            backend=backend)
        parity_max = max(parity_max,
                         float(abs(res.mean - ref_s.mean).max()),
                         float(abs(res.var - ref_s.var).max()))

    p99_ratio = soak_cont["interactive_p99_s"] / soak_drain["interactive_p99_s"]
    bulk_ratio = soak_cont["bulk_points_per_s"] / soak_drain["bulk_points_per_s"]
    assert p99_ratio < 1.0, (
        f"continuous interactive p99 must beat drain: ratio {p99_ratio:.3f}")
    assert bulk_ratio >= 0.9, (
        f"continuous bulk goodput fell >10% below drain: {bulk_ratio:.3f}")
    assert parity_max <= 1e-12, (
        f"continuous-mode per-request parity broken: {parity_max:.3e}")

    soak_rows = [dict(mode=mode, **vals) for mode, vals in
                 (("drain", soak_drain), ("continuous", soak_cont))]
    table(soak_rows,
          ["mode", "interactive_p50_s", "interactive_p99_s", "bulk_p99_s",
           "bulk_points_per_s", "queue_depth_peak", "n_preempted"],
          title=f"soak: {n_bulk}x{bulk_pts} bulk + {n_inter}x{inter_pts} "
                f"interactive (Poisson 20ms + burst {burst}), chunk={soak_chunk}")
    print(f"\nsoak: interactive p99 continuous/drain = {p99_ratio:.3f} "
          f"(must be < 1), bulk goodput ratio = {bulk_ratio:.3f} "
          f"(must be >= 0.9), parity(sampled) = {parity_max:.1e}")

    from benchmarks.common import calibrate

    save("serving_throughput", {
        "scale": args.scale, "calib_s": calibrate(),
        "backend": backend, "bucketed": args.bucketed,
        "n_train": n_train, "n_test": n_test, "chunk": chunk,
        "bs_pred": bs, "m_pred": m, "n_requests": n_req,
        "t_index_s": t_index, "rows": rows, "speedup_double_vs_sync": speedup,
        "parity_double_vs_sync": float(d_sync),
        "parity_vs_predict_sbv": float(d_ref),
        "server_stats": stats,
        "soak": {
            "chunk": soak_chunk, "n_bulk": n_bulk, "bulk_pts": bulk_pts,
            "n_interactive": n_inter, "interactive_pts": inter_pts,
            "burst": burst,
            "drain": soak_drain, "continuous": soak_cont,
            "interactive_p99_ratio": p99_ratio,
            "bulk_points_ratio": bulk_ratio,
            "parity_max": parity_max,
        },
    })


if __name__ == "__main__":
    main()
