"""Persistent-serving throughput: sync chunk loop vs double-buffered
pipeline vs the micro-batching GPServer (ISSUE 2 acceptance benchmark).

Three measurements over the same workload, same train index, warmed jit
cache:

  sync    — strictly serial pack -> compute -> scatter per chunk
            (the pre-server ``serve gp`` behavior);
  double  — double-buffered chunk pipeline (host packs chunk k+1 while
            the device computes chunk k);
  server  — full GPServer request path: the test set split into
            concurrent requests, coalesced by the micro-batcher, each
            batch through the double-buffered pipeline.

Parity gates: double ≡ sync bitwise, and both ≡ ``predict_sbv`` with the
same chunking protocol to <= 1e-5. The server path's outputs are
sanity-gated (finite means, positive variances); its exact micro-batched
≡ one-shot equivalence is pinned deterministically in
tests/test_serving.py (here, post-warmup batches use fresh per-batch
seeds and timing-dependent request grouping, so bitwise comparison
against a single reference call is not defined).

Note on CPU numbers: XLA CPU compute already saturates the host cores,
so overlap buys ~1.1x here; on a real TPU/GPU the host packing cost
vanishes from steady-state entirely (that is the point of the design).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import parser, save, table


def main():
    ap = parser("serving_throughput")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--bucketed", action="store_true",
                    help="serve size-bucketed micro-batches (4 geometric "
                         "ceiling levels per dimension per chunk; realized "
                         "buckets = occupied (bs, m) cells — docs/packing.md) "
                         "so the perf trajectory captures uniform-vs-bucketed "
                         "on the same seed")
    args = ap.parse_args()

    from repro.core.predict import predict_sbv
    from repro.data.gp_sim import paper_synthetic
    from repro.serving import (
        BatchingPolicy, GPServer, GPServerConfig, PipelineConfig,
        predict_pipelined, predict_synchronous,
    )

    if args.scale == "smoke":
        n_train, n_test, chunk, bs, m, n_req = 8000, 16000, 2048, 16, 96, 16
    else:
        n_train, n_test, chunk, bs, m, n_req = 100_000, 500_000, 8192, 25, 120, 128

    backend = args.backend if args.backend != "both" else "ref"
    x, y, params = paper_synthetic(args.seed, n_train)
    rng = np.random.default_rng(args.seed + 1)
    x_test = rng.uniform(size=(n_test, x.shape[1]))

    pipe_cfg = PipelineConfig(bs_pred=bs, m_pred=m, chunk_size=chunk,
                              backend=backend,
                              n_buckets=4 if args.bucketed else None)
    cfg = GPServerConfig(
        pipeline=pipe_cfg,
        policy=BatchingPolicy(max_points=chunk, max_wait_s=0.005),
        seed=args.seed,
    )
    t0 = time.time()
    server = GPServer(params, x, y, cfg)
    t_index = time.time() - t0

    rows = []
    results = {}
    with server:
        server.warmup()
        # Warm every chunk shape of THIS workload so reps measure steady state.
        predict_synchronous(params, server.index, x_test, pipe_cfg,
                            seed=args.seed)

        for name, runner in (("sync", predict_synchronous),
                             ("double", predict_pipelined)):
            best = np.inf
            for _ in range(args.reps):
                t0 = time.time()
                mean, var = runner(params, server.index, x_test, pipe_cfg,
                                   seed=args.seed)
                best = min(best, time.time() - t0)
            results[name] = (mean, var)
            rows.append({"path": name, "time_s": best,
                         "qps": n_test / best})

        best = np.inf
        for _ in range(args.reps):
            bounds = np.linspace(0, n_test, n_req + 1).astype(int)
            t0 = time.time()
            futs = [server.submit(x_test[a:b])
                    for a, b in zip(bounds[:-1], bounds[1:])]
            outs = [f.result() for f in futs]
            best = min(best, time.time() - t0)
        results["server"] = (np.concatenate([r.mean for r in outs]),
                             np.concatenate([r.var for r in outs]))
        rows.append({"path": "server", "time_s": best, "qps": n_test / best})

    # Parity: double vs sync must be bitwise; vs predict_sbv <= 1e-5.
    d_sync = max(abs(results["double"][0] - results["sync"][0]).max(),
                 abs(results["double"][1] - results["sync"][1]).max())
    ref = predict_sbv(params, x, y, x_test, bs_pred=bs, m_pred=m,
                      seed=args.seed, n_sims=2, chunk_size=chunk,
                      backend="ref")
    d_ref = max(abs(results["double"][0] - ref.mean).max(),
                abs(results["double"][1] - ref.var).max())
    assert d_sync == 0.0, f"double vs sync diverged: {d_sync}"
    assert d_ref <= 1e-5, f"pipeline vs predict_sbv diverged: {d_ref}"
    srv_mean, srv_var = results["server"]
    assert srv_mean.shape == (n_test,) and np.all(np.isfinite(srv_mean))
    assert np.all(srv_var > 0), "server path produced non-positive variance"

    qps = {r["path"]: r["qps"] for r in rows}
    speedup = qps["double"] / qps["sync"]
    stats = server.stats.summary()
    table(rows, ["path", "time_s", "qps"],
          title=f"serving throughput (n_test={n_test}, chunk={chunk}, "
                f"m={m}, backend={backend})")
    print(f"\ndouble-buffered speedup over sync: {speedup:.2f}x")
    print(f"parity: double vs sync = {d_sync:.1e}; vs predict_sbv = {d_ref:.1e}")
    print(f"server: latency p50={stats['latency_p50_s']*1e3:.0f}ms "
          f"p95={stats['latency_p95_s']*1e3:.0f}ms "
          f"occupancy={stats['mean_batch_points']:.0f} pts/batch "
          f"compiled-shapes={stats['n_compiled_shapes']} "
          f"padding-occupancy={stats['padding_occupancy']:.3f}")

    from benchmarks.common import calibrate

    save("serving_throughput", {
        "scale": args.scale, "calib_s": calibrate(),
        "backend": backend, "bucketed": args.bucketed,
        "n_train": n_train, "n_test": n_test, "chunk": chunk,
        "bs_pred": bs, "m_pred": m, "n_requests": n_req,
        "t_index_s": t_index, "rows": rows, "speedup_double_vs_sync": speedup,
        "parity_double_vs_sync": float(d_sync),
        "parity_vs_predict_sbv": float(d_ref),
        "server_stats": stats,
    })


if __name__ == "__main__":
    main()
