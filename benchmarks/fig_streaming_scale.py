"""Out-of-core SBV at paper scale: 1M-point fit under a hard RSS ceiling.

The paper's scale claims (50M-point emulation, 2.56B points across 512
GPUs) rest on every stage streaming through bounded memory. This
benchmark is the single-host version of that claim, and the CI gate that
keeps it true:

1. SYNTHESIZE  — an anisotropic RFF-GP dataset is generated chunk-by-
   chunk straight into an ``ArrayStore`` (never materialized in RAM).
2. PARITY      — at 200k points, the store-backed ``fit_sbv`` +
   ``predict_sbv`` must match the in-core (RAM-resident arrays, same
   streaming code path) results to 1e-10. The IO layer must be invisible.
3. TIERS       — the inner-loop memory-tier microbenchmark: one packed
   round driven through many inner steps with the spool pinned to the
   device-resident tier vs. pinned to the disk tier (the PR-4 loop:
   re-read + blocking H2D per piece per step), plus the prefetched-H2D
   middle tier. Reports steps/s and H2D bytes/step per mode and ASSERTS
   the device-resident loop is >= 1.5x the disk loop with bitwise
   parity — the speedup the regression gate then keeps.
4. SCALE       — the full ``--scale smoke`` 1M-point store-backed fit +
   predict runs with the process peak-RSS DELTA asserted below
   ``2 x working_set``, where the working set is computed from the run's
   own streaming state (chunk windows + packed chunk on host and device +
   device-resident spool tier + index arrays + NNS gather cache). The
   same model shows the in-core footprint the streaming path avoids; the
   budget must sit strictly below it, otherwise the assertion would be
   vacuous.

Peak RSS is measured by a 5ms /proc/self/status poll scoped to the
fit+predict region (baseline captured at region start), so data
synthesis and the parity phase don't mask or inflate the fit's peak.

Wall times are saved raw and normalized by ``common.calibrate()`` so the
regression gate (benchmarks/check_regression.py) can compare runs across
hosts. See docs/streaming.md.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import calibrate, parser, save, table

MB = 1024 * 1024


# Peak-RSS accounting lives in repro.memwatch so the multi-host launch
# path (every spawned rank) measures with the identical sampler.
from repro.memwatch import PeakRssSampler  # noqa: E402


# -- chunked synthetic generator ------------------------------------------


def write_rff_store(path: str, n: int, d: int, seed: int,
                    gen_rows: int = 16384, n_features: int = 512):
    """Anisotropic RFF-GP draw written chunk-by-chunk into a store.

    Same spectral construction as ``data.gp_sim.sample_gp_rff`` (Matern
    nu=3.5 via the t-distributed frequency trick), but the feature
    projection is applied per generation chunk, so RAM stays at
    ``gen_rows x n_features`` no matter how large ``n`` is. The first
    ``d//2`` dimensions are relevant (small beta), the rest nuisance.
    """
    rng = np.random.default_rng(seed)
    nu, sigma2, nugget = 3.5, 1.0, 1e-3
    beta = np.where(np.arange(d) < d // 2, 0.2, 2.0)
    z = rng.standard_normal((n_features, d))
    g = rng.gamma(shape=nu, scale=1.0 / nu, size=(n_features, 1))
    omega = z / np.sqrt(g) / beta[None, :]
    phase = rng.uniform(0.0, 2.0 * np.pi, size=n_features)
    w = rng.standard_normal(n_features)

    from repro.data.store import ArrayStore

    with ArrayStore.create(path, d) as writer:
        done = 0
        while done < n:
            k = min(n - done, gen_rows)
            x = rng.uniform(size=(k, d))
            y = np.sqrt(2.0 * sigma2 / n_features) * (
                np.cos(x @ omega.T + phase[None, :]) @ w
            )
            y = y + np.sqrt(nugget) * rng.standard_normal(k)
            writer.append(x, y)
            done += k
    return ArrayStore(path), beta


# -- phases ----------------------------------------------------------------


def parity_phase(workdir: str, n: int, seed: int, knobs: dict) -> dict:
    """Store-backed vs in-core (same rows, same streaming protocol)."""
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.core.predict import predict_sbv

    store, _ = write_rff_store(os.path.join(workdir, f"parity{n}"), n,
                               knobs["d"], seed)
    x, y = store.read_all()
    cfg = SBVConfig(n_blocks=max(1, n // knobs["rows_per_block"]),
                    m=knobs["m"], alpha=knobs["alpha"], seed=seed)
    fit_kw = dict(inner_steps=knobs["parity_steps"], outer_rounds=1,
                  stream_chunk=knobs["stream_chunk"])
    r_store = fit_sbv(store, None, cfg, **fit_kw)
    r_incore = fit_sbv(x, y, cfg, **fit_kw)
    d_fit = max(
        abs(np.asarray(getattr(r_store.params, f)) -
            np.asarray(getattr(r_incore.params, f))).max()
        for f in ("log_sigma2", "log_beta", "log_nugget")
    )

    rng = np.random.default_rng(seed + 7)
    x_test = rng.uniform(size=(4000, knobs["d"]))
    pred_kw = dict(bs_pred=knobs["bs_pred"], m_pred=knobs["m_pred"],
                   alpha=knobs["alpha"], n_sims=2, chunk_size=2048,
                   stream_chunk=knobs["stream_chunk"], seed=seed)
    p_store = predict_sbv(r_store.params, store, None, x_test, **pred_kw)
    p_incore = predict_sbv(r_incore.params, x, y, x_test, **pred_kw)
    d_pred = max(abs(p_store.mean - p_incore.mean).max(),
                 abs(p_store.var - p_incore.var).max())
    print(f"[fig_streaming_scale] parity@{n}: fit max|delta|={d_fit:.3e} "
          f"predict max|delta|={d_pred:.3e}")
    assert d_fit <= 1e-10, f"store vs in-core fit diverged: {d_fit}"
    assert d_pred <= 1e-10, f"store vs in-core predict diverged: {d_pred}"
    return {"parity_n": n, "parity_fit": float(d_fit),
            "parity_predict": float(d_pred)}


def tier_phase(workdir: str, seed: int, knobs: dict) -> dict:
    """Inner-loop memory tiers: device-cached vs prefetched vs disk-spool.

    Shapes are chosen so the per-step cost is tier-dominated (many small
    pieces: per-piece ``.npz`` decode + blocking H2D is the disk loop's
    overhead) — this measures the residency win itself, not the Cholesky
    throughput the scale phase already tracks. The jit cache is warmed
    with a 1-step fit first so no timed mode pays compilation."""
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig

    n, d = knobs["tier_n"], knobs["tier_d"]
    store, _ = write_rff_store(os.path.join(workdir, f"tier{n}"), n, d, seed)
    cfg = SBVConfig(n_blocks=max(1, n // knobs["tier_rows_per_block"]),
                    m=knobs["tier_m"], alpha=knobs["alpha"], seed=seed)
    kw = dict(outer_rounds=1, stream_chunk=knobs["tier_chunk"])
    steps = knobs["tier_steps"]

    fit_sbv(store, None, cfg, inner_steps=1, device_cache=0, prefetch=0, **kw)
    r_disk = fit_sbv(store, None, cfg, inner_steps=steps, device_cache=0,
                     prefetch=0, **kw)              # the PR-4 inner loop
    r_pre = fit_sbv(store, None, cfg, inner_steps=steps, device_cache=0,
                    prefetch=2, **kw)               # H2D pipeline, cold HBM
    r_dev = fit_sbv(store, None, cfg, inner_steps=steps, **kw)  # auto budget

    st = r_dev.stream_stats
    assert st["device_cached_pieces"] == st["n_pieces"] > 1, (
        "device budget did not hold the round — the tier compare would "
        f"be vacuous ({st['device_cached_pieces']}/{st['n_pieces']} cached)"
    )
    parity = max(
        abs(np.asarray(getattr(r_dev.params, f)) -
            np.asarray(getattr(r_disk.params, f))).max()
        for f in ("log_sigma2", "log_beta", "log_nugget")
    )
    assert parity == 0.0, f"memory tiers changed the fit: {parity}"

    def steps_per_s(r):
        return r.stream_stats["inner_steps_total"] / r.stream_stats["inner_time_s"]

    sps_disk, sps_pre, sps_dev = map(steps_per_s, (r_disk, r_pre, r_dev))
    speedup = sps_dev / sps_disk
    out = {
        "tier_n_pieces": st["n_pieces"],
        "tier_steps_per_s_disk": sps_disk,
        "tier_steps_per_s_prefetch": sps_pre,
        "tier_steps_per_s_cached": sps_dev,
        "tier_step_s_cached": 1.0 / sps_dev,
        "tier_speedup": speedup,
        "tier_parity": float(parity),
        "tier_h2d_mb_per_step_disk":
            r_disk.stream_stats["h2d_bytes_per_step"] / MB,
        "tier_h2d_mb_per_step_cached": st["h2d_bytes_per_step"] / MB,
        "tier_device_cached_mb": st["device_cached_bytes"] / MB,
    }
    print(f"[fig_streaming_scale] tiers@{n}: {st['n_pieces']} pieces, "
          f"steps/s disk={sps_disk:.2f} prefetch={sps_pre:.2f} "
          f"cached={sps_dev:.2f} -> speedup {speedup:.2f}x "
          f"(H2D {out['tier_h2d_mb_per_step_disk']:.1f} -> "
          f"{out['tier_h2d_mb_per_step_cached']:.1f} MB/step)")
    assert speedup >= 1.5, (
        f"device-resident inner loop only {speedup:.2f}x over the "
        "disk-spool loop (acceptance floor is 1.5x)"
    )
    return out


def scale_phase(workdir: str, n: int, seed: int, knobs: dict) -> dict:
    """The RSS-bounded big run: store-backed fit + predict, measured."""
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.core.predict import predict_sbv

    d = knobs["d"]
    store, _ = write_rff_store(os.path.join(workdir, f"scale{n}"), n, d, seed)
    cfg = SBVConfig(n_blocks=max(1, n // knobs["rows_per_block"]),
                    m=knobs["m"], alpha=knobs["alpha"], seed=seed)
    rng = np.random.default_rng(seed + 7)
    x_test = rng.uniform(size=(knobs["n_test"], d))

    sampler = PeakRssSampler().start()
    t0 = time.time()
    # Bucketed chunk dispatch (docs/packing.md): k-means skew makes the
    # global bs_max ceiling waste most padded FLOPs at this scale.
    res = fit_sbv(store, None, cfg, inner_steps=knobs["scale_steps"],
                  outer_rounds=1, stream_chunk=knobs["stream_chunk"],
                  n_buckets=4, verbose=True)
    t_fit = time.time() - t0

    t0 = time.time()
    pred = predict_sbv(res.params, store, None, x_test,
                       bs_pred=knobs["bs_pred"], m_pred=knobs["m_pred"],
                       alpha=knobs["alpha"], n_sims=2, chunk_size=8192,
                       stream_chunk=knobs["stream_chunk"], seed=seed)
    t_pred = time.time() - t0
    assert np.all(np.isfinite(pred.mean)) and np.all(pred.var > 0)
    peak = sampler.stop()

    # Working-set model from the run's OWN streaming state — shared with
    # tests/test_streaming.py via data.streaming.working_set_model (see
    # its docstring for the term-by-term breakdown).
    from repro.data.streaming import working_set_model

    st = res.stream_stats
    ws = working_set_model(st, n, d, knobs["m"], knobs["stream_chunk"])
    working_set = ws["total"]
    budget = 2 * working_set
    incore_bytes = ws["incore_total"]

    out = {
        "n": n, "d": d, "t_fit_s": t_fit, "t_predict_s": t_pred,
        "n_chunks": st["n_chunks"], "bc": st["bc"], "bs_max": st["bs_max"],
        "working_set_mb": working_set / MB, "rss_budget_mb": budget / MB,
        "incore_estimate_mb": incore_bytes / MB,
        "peak_rss_delta_mb": None if peak is None else peak / MB,
        "rss_measured": peak is not None,
    }
    print(f"[fig_streaming_scale] scale@{n}: fit {t_fit:.1f}s "
          f"predict {t_pred:.1f}s over {st['n_chunks']} chunks; "
          f"budget {budget / MB:.0f}MB vs in-core {incore_bytes / MB:.0f}MB")
    assert budget < incore_bytes, (
        f"RSS budget {budget / MB:.0f}MB is not below the in-core footprint "
        f"{incore_bytes / MB:.0f}MB — the ceiling would prove nothing"
    )
    if out["rss_measured"]:
        print(f"[fig_streaming_scale] peak RSS delta {peak / MB:.0f}MB "
              f"(ceiling {budget / MB:.0f}MB)")
        assert peak <= budget, (
            f"peak RSS {peak / MB:.0f}MB exceeded 2x working set "
            f"{budget / MB:.0f}MB — streaming is leaking the dataset into RAM"
        )
    else:
        print("[fig_streaming_scale] WARNING: VmHWM reset unavailable; "
              "RSS ceiling not asserted on this platform")
    return out


def multihost_phase(workdir: str, seed: int, knobs: dict) -> dict:
    """Multi-process parity + per-host memory: 2 spawned ranks vs serial.

    Runs the identical store/config through (a) the single-process
    streaming fit in this process and (b) ``fit_gp --distributed-hosts``
    rank processes connected over ``jax.distributed`` (gloo CPU
    collectives — the laptop stand-in for the paper's multi-GPU ranks).
    Asserts the Alg. 2 contract: every rank reaches the same nll
    (<= 1e-8), and every rank's peak RSS stays under 2x ITS OWN
    partitioned working-set model — the per-host memory bound that makes
    "no process materializes the full dataset" checkable."""
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.launch.fit_gp import main as fit_gp_main

    n, d = knobs["mh_n"], knobs["mh_d"]
    hosts = knobs["mh_hosts"]
    store, _ = write_rff_store(os.path.join(workdir, f"mh{n}"), n, d, seed)
    cfg = SBVConfig(n_blocks=knobs["mh_blocks"], m=knobs["mh_m"],
                    alpha=knobs["alpha"], seed=seed)
    fit_kw = dict(inner_steps=knobs["mh_steps"],
                  outer_rounds=knobs["mh_rounds"],
                  stream_chunk=knobs["mh_chunk"], device_cache=0)

    t0 = time.time()
    ref = fit_sbv(store, None, cfg, **fit_kw)
    t_ref = time.time() - t0
    ref_nll = float(ref.history[-1][2])

    result_json = os.path.join(workdir, "mh_result.json")
    merged = fit_gp_main([
        "--store", store.path, "--distributed-hosts", str(hosts),
        "--blocks", str(knobs["mh_blocks"]), "--m", str(knobs["mh_m"]),
        "--inner-steps", str(knobs["mh_steps"]),
        "--outer-rounds", str(knobs["mh_rounds"]),
        "--stream-chunk", str(knobs["mh_chunk"]),
        "--device-cache-mb", "0", "--seed", str(seed),
        "--result-json", result_json,
    ])[0]

    parity = max(abs(r["nll"] - ref_nll) for r in merged["ranks"])
    measured = all(r["peak_rss_bytes"] is not None for r in merged["ranks"])
    rss_ratio = None
    if measured:
        rss_ratio = max(r["peak_rss_bytes"] / (2.0 * r["working_set_bytes"])
                        for r in merged["ranks"])
    slowdown = max(r["t_fit_s"] for r in merged["ranks"]) / t_ref
    out = {
        "mh_hosts": hosts, "mh_n": n,
        "mh_nll_parity": float(parity),
        "mh_nll_spread": float(merged["max_nll_spread"]),
        "mh_rss_measured": measured,
        "mh_rss_ratio": rss_ratio,
        "mh_slowdown_vs_serial": float(slowdown),
        "mh_max_halo_rows": max(r["stats"]["halo_rows"]
                                for r in merged["ranks"]),
        "mh_exchange_mb": max(r["stats"]["exchange_bytes"]
                              for r in merged["ranks"]) / MB,
    }
    print(f"[fig_streaming_scale] multihost@{n}x{hosts}: "
          f"nll parity {parity:.3e} (spread {out['mh_nll_spread']:.3e}), "
          f"rss ratio {rss_ratio if rss_ratio is None else round(rss_ratio, 3)}, "
          f"slowdown {slowdown:.2f}x vs serial")
    assert parity <= 1e-8, (
        f"multi-host nll diverged from the single-process fit: {parity:.3e}")
    if measured:
        assert rss_ratio <= 1.0, (
            f"a rank's peak RSS exceeded 2x its partitioned working set "
            f"(ratio {rss_ratio:.2f}) — the per-host memory contract broke")
    return out


def main(argv=None):
    ap = parser("fig_streaming_scale")
    ap.add_argument("--workdir", default=None,
                    help="store directory (default: a temp dir, removed "
                         "afterwards)")
    ap.add_argument("--skip-parity", action="store_true",
                    help="only run the RSS-bounded scale phase")
    ap.add_argument("--multihost-only", action="store_true",
                    help="run only the multi-process parity/memory phase "
                         "and save it as fig_streaming_mh (the CI "
                         "'distributed' gate)")
    args = ap.parse_args(argv)

    if args.scale == "smoke":
        n_scale, n_parity = 1_000_000, 200_000
        knobs = dict(d=4, rows_per_block=128, m=16, alpha=8.0,
                     stream_chunk=131072, parity_steps=4, scale_steps=2,
                     bs_pred=32, m_pred=32, n_test=8192,
                     tier_n=20_000, tier_d=16, tier_rows_per_block=8,
                     tier_m=4, tier_chunk=256, tier_steps=8,
                     mh_n=8000, mh_d=4, mh_hosts=2, mh_blocks=64, mh_m=8,
                     mh_chunk=2048, mh_steps=4, mh_rounds=2)
    else:  # paper: the 50M respiratory-scale run (hours; real hardware)
        n_scale, n_parity = 50_000_000, 200_000
        knobs = dict(d=8, rows_per_block=256, m=60, alpha=16.0,
                     stream_chunk=524288, parity_steps=4, scale_steps=30,
                     bs_pred=64, m_pred=120, n_test=100_000,
                     tier_n=200_000, tier_d=16, tier_rows_per_block=32,
                     tier_m=8, tier_chunk=2048, tier_steps=20,
                     mh_n=200_000, mh_d=8, mh_hosts=4, mh_blocks=1024,
                     mh_m=16, mh_chunk=32768, mh_steps=8, mh_rounds=2)

    calib = calibrate()
    workdir = args.workdir or tempfile.mkdtemp(prefix="sbv-streaming-")
    payload = {"scale": args.scale, "seed": args.seed, "calib_s": calib}
    try:
        if args.multihost_only:
            payload.update(multihost_phase(workdir, args.seed, knobs))
            payload["t_serial_norm"] = None
            table([payload],
                  ["mh_hosts", "mh_n", "mh_nll_parity", "mh_nll_spread",
                   "mh_rss_ratio", "mh_slowdown_vs_serial",
                   "mh_max_halo_rows", "mh_exchange_mb"],
                  title="streaming multihost")
            save("fig_streaming_mh", payload)
            return payload
        if not args.skip_parity:
            payload.update(parity_phase(workdir, n_parity, args.seed, knobs))
        payload.update(tier_phase(workdir, args.seed, knobs))
        payload.update(scale_phase(workdir, n_scale, args.seed, knobs))
    finally:
        if args.workdir is None:
            shutil.rmtree(workdir, ignore_errors=True)

    payload["t_fit_norm"] = payload["t_fit_s"] / calib
    payload["t_predict_norm"] = payload["t_predict_s"] / calib
    table([payload],
          ["n", "t_fit_s", "t_predict_s", "peak_rss_delta_mb",
           "rss_budget_mb", "incore_estimate_mb", "parity_fit",
           "parity_predict", "tier_speedup", "tier_steps_per_s_cached"],
          title="streaming scale")
    save("fig_streaming_scale", payload)
    return payload


if __name__ == "__main__":
    main()
