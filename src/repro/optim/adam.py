"""Adam/AdamW over arbitrary pytrees (no optax in this environment).

Moments are kept in fp32 regardless of parameter dtype (mixed-precision
training keeps bf16 params + fp32 optimizer state, the standard large-scale
recipe).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: object   # pytree like params, fp32
    nu: object   # pytree like params, fp32


def _f32(t):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)


def adam_init(params) -> AdamState:
    return AdamState(step=jnp.zeros((), jnp.int32), mu=_f32(params), nu=_f32(params))


def adam_update(
    grads, state: AdamState, params,
    lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """Returns (new_params, new_state). ``lr`` may be a scalar or schedule value."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * (g32 * g32)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)


def adamw_update(grads, state, params, lr, weight_decay=0.1, **kw):
    return adam_update(grads, state, params, lr, weight_decay=weight_decay, **kw)
