from .adam import AdamState, adam_init, adam_update, adamw_update
from .schedule import cosine_warmup

__all__ = ["AdamState", "adam_init", "adam_update", "adamw_update", "cosine_warmup"]
