"""Block prediction with conditional simulation (paper Eq. 3 + §5.1.5).

Test points are clustered into prediction blocks (bs_pred); each block is
conditioned on its m_pred nearest TRAINING points (no ordering constraint
— Eq. 3 conditions on the full training vector y). Per paper §5.1.5 the
per-point predictive distribution N(mu_j, sigma_j^2) is then sampled (1000
draws) to form sample means and 95% confidence intervals.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import build_blocks, scale_inputs
from .kernels_math import KernelParams
from .nns import filtered_knn_points
from .vecchia import _masked_cov


@dataclass
class Prediction:
    mean: np.ndarray       # (n*,) conditional mean mu_new
    var: np.ndarray        # (n*,) conditional marginal variance
    sim_mean: np.ndarray   # (n*,) conditional-simulation sample mean
    ci_low: np.ndarray     # (n*,) 95% CI bounds from simulation
    ci_high: np.ndarray


def _predict_one(params, nu, qx, qmask, nx, ny, nmask):
    sigma_con = _masked_cov(nx, nx, nmask, nmask, params, nu, identity=True)
    sigma_cross = _masked_cov(nx, qx, nmask, qmask, params, nu, identity=False)
    ynn = jnp.where(nmask, ny, 0.0)
    chol = jnp.linalg.cholesky(sigma_con)
    a = jax.scipy.linalg.solve_triangular(chol, sigma_cross, lower=True)
    z = jax.scipy.linalg.solve_triangular(chol, ynn, lower=True)
    mu = a.T @ z
    prior = params.sigma2 + params.nugget
    var = prior - jnp.sum(a * a, axis=0)
    return mu, jnp.maximum(var, 1e-12)


def predict_sbv(
    params: KernelParams,
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_test: np.ndarray,
    bs_pred: int = 25,
    m_pred: int = 200,
    nu: float = 3.5,
    alpha: float = 100.0,
    n_sims: int = 1000,
    seed: int = 0,
    n_workers: int = 1,
    beta_struct: np.ndarray | None = None,
) -> Prediction:
    """``beta_struct`` overrides the scaling used for clustering/NNS only
    (paper Fig. 4 isolates structure quality: BV = isotropic structure +
    true kernel; SBV = scaled structure + true kernel)."""
    beta = np.asarray(params.beta if beta_struct is None else beta_struct)
    xs_train = scale_inputs(x_train, beta)
    xs_test = scale_inputs(x_test, beta)
    n_test, d = x_test.shape

    # Training blocks give the coarse structure for filtered kNN.
    bc_train = max(1, x_train.shape[0] // max(4 * m_pred, 64))
    train_blocks = build_blocks(xs_train, bc_train, n_workers, beta, seed=seed)

    # Prediction blocks over the test points.
    bc_pred = max(1, n_test // bs_pred)
    test_blocks = build_blocks(xs_test, bc_pred, n_workers, beta, seed=seed + 1)
    neigh = filtered_knn_points(xs_train, train_blocks, test_blocks.centers, m_pred, alpha)

    bs_max = max(mb.size for mb in test_blocks.members)
    bcp = test_blocks.n_blocks
    qx = np.zeros((bcp, bs_max, d))
    qmask = np.zeros((bcp, bs_max), dtype=bool)
    nx = np.zeros((bcp, m_pred, d))
    ny = np.zeros((bcp, m_pred))
    nmask = np.zeros((bcp, m_pred), dtype=bool)
    for b, mb in enumerate(test_blocks.members):
        qx[b, : mb.size] = x_test[mb]
        qmask[b, : mb.size] = True
        nb = neigh[b][:m_pred]
        nx[b, : nb.size] = x_train[nb]
        ny[b, : nb.size] = y_train[nb]
        nmask[b, : nb.size] = True

    mu_b, var_b = jax.jit(
        jax.vmap(lambda a, b_, c, d_, e: _predict_one(params, nu, a, b_, c, d_, e))
    )(jnp.asarray(qx), jnp.asarray(qmask), jnp.asarray(nx), jnp.asarray(ny), jnp.asarray(nmask))

    mean = np.zeros(n_test)
    var = np.zeros(n_test)
    mu_b = np.asarray(mu_b)
    var_b = np.asarray(var_b)
    for b, mb in enumerate(test_blocks.members):
        mean[mb] = mu_b[b, : mb.size]
        var[mb] = var_b[b, : mb.size]

    # Conditional simulation (paper: 1000 draws from N(mu_j, sigma_j)).
    key = jax.random.PRNGKey(seed)
    draws = np.asarray(
        jax.random.normal(key, (n_sims, n_test)) * np.sqrt(var)[None, :] + mean[None, :]
    )
    sim_mean = draws.mean(axis=0)
    sim_std = draws.std(axis=0, ddof=1)
    z975 = 1.959963984540054
    return Prediction(
        mean=mean, var=var, sim_mean=sim_mean,
        ci_low=sim_mean - z975 * sim_std, ci_high=sim_mean + z975 * sim_std,
    )


def mspe(pred: np.ndarray, truth: np.ndarray) -> float:
    return float(np.mean((pred - truth) ** 2))


def rmspe(pred: np.ndarray, truth: np.ndarray) -> float:
    """Root Mean Squared Percentage Error (paper §6.2)."""
    denom = np.where(np.abs(truth) > 1e-12, truth, 1.0)
    return float(np.sqrt(np.mean(((pred - truth) / denom) ** 2)) * 100.0)
