"""Block prediction with conditional simulation (paper Eq. 3 + §5.1.5).

Serving-side mirror of the likelihood stack:

    pack   -- test points are clustered into prediction blocks (bs_pred);
              each block is conditioned on its m_pred nearest TRAINING
              points (no ordering constraint — Eq. 3 conditions on the
              full training vector y). Blocks + neighbors are packed into
              fixed-size padded arrays (``PackedPrediction``).
    predict - ONE vmapped/jitted call over the packed arrays computes every
              block conditional, with the per-point simulation draws
              (paper §5.1.5: 1000 samples of N(mu_j, sigma_j^2)) taken
              inside the same jitted program via ``jax.random``.
              ``backend='pallas'`` dispatches the conditional to the fused
              kernel in ``repro/kernels/sbv_predict.py``.
    scatter - padded per-block results land back in test-point order via
              the packed scatter indices (vectorized, no Python loop).

``chunk_size`` bounds device memory for arbitrary n_test: the training
index is built once, then fixed-shape chunks stream through the jitted
predict program (shapes are rounded up so the jit cache is reused).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import BlockStructure, build_blocks, scale_inputs
from .kernels_math import KernelParams
from .nns import _FlatBlocks, filtered_knn_points
from .packing import PackedPrediction, pack_prediction, round_up
from .vecchia import _masked_cov


@dataclass
class Prediction:
    """Prediction fields are (n*,) for single-output training data and
    (n*, p) when the training observations were (n, p) multi-output
    (docs/multioutput.md)."""

    mean: np.ndarray       # conditional mean mu_new
    var: np.ndarray        # conditional marginal variance
    sim_mean: np.ndarray   # conditional-simulation sample mean
    ci_low: np.ndarray     # 95% CI bounds from simulation
    ci_high: np.ndarray


@dataclass
class TrainIndex:
    """Host-side training-set structure reused across prediction chunks.

    In-core indexes hold the raw/scaled arrays; store-backed indexes (see
    ``build_train_index(..., stream_chunk=)``) hold lazy row views with
    ``xs=None``, a store handle, and the cached scaled-domain volume the
    filtered kNN needs (the one quantity otherwise derived from the full
    scaled array)."""

    x: np.ndarray          # (n, d) raw training inputs (or lazy row view)
    y: np.ndarray          # (n,) training observations (or lazy row view)
    xs: np.ndarray | None  # (n, d) scaled inputs; None when store-backed
    beta: np.ndarray       # (d,) structure scaling
    blocks: BlockStructure # coarse blocks for the filtered kNN
    flat: _FlatBlocks | None = None  # flattened block members, built once
    store: object = None             # row store behind a streaming index
    domain_volume: float | None = None


def build_train_index(
    x_train: np.ndarray,
    y_train: np.ndarray,
    beta: np.ndarray,
    m_pred: int,
    n_workers: int = 1,
    seed: int = 0,
    stream_chunk: int | None = None,
) -> TrainIndex:
    """Scale + coarse-block the training set once; reused per chunk.

    The flattened block index (``_FlatBlocks``) is cached here: it holds
    the full n x d gather of block members that ``filtered_knn_points``
    would otherwise rebuild on every query chunk.

    Pass ``x_train`` as a row store (``y_train=None``) and/or set
    ``stream_chunk`` for the out-of-core index: structure comes from
    mini-batch k-means passes and the flat index serves candidate gathers
    from the store with a bounded cache (docs/streaming.md). An in-core
    ``(x, y)`` with ``stream_chunk`` runs the identical code over a
    ``MemoryStore``, so the two agree bitwise on the same rows."""
    from repro.data.store import as_store, is_store

    if is_store(x_train) or stream_chunk is not None:
        from repro.data.streaming import (
            DEFAULT_STRUCT_BATCH, LazyFlatBlocks, streaming_kmeans_blocks,
        )

        store = as_store(x_train, y_train)
        beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (store.d,))
        bc_train = max(1, store.n_rows // max(4 * m_pred, 64))
        # Structure passes use the FIXED batch size (like the fit): the
        # index must not depend on the caller's packing window.
        blocks, radii, vol = streaming_kmeans_blocks(
            store, beta, bc_train, n_workers=n_workers, seed=seed,
            batch_rows=DEFAULT_STRUCT_BATCH,
        )
        flat = LazyFlatBlocks(blocks, radii, store, beta)
        return TrainIndex(x=store.x_rows, y=store.y_rows, xs=None, beta=beta,
                          blocks=blocks, flat=flat, store=store,
                          domain_volume=vol)
    x_train = np.asarray(x_train, dtype=np.float64)
    y_train = np.asarray(y_train, dtype=np.float64)
    beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (x_train.shape[1],))
    xs = scale_inputs(x_train, beta)
    bc_train = max(1, x_train.shape[0] // max(4 * m_pred, 64))
    blocks = build_blocks(xs, bc_train, n_workers, beta, seed=seed)
    return TrainIndex(x=x_train, y=y_train, xs=xs, beta=beta, blocks=blocks,
                      flat=_FlatBlocks(xs, blocks))


def scatter_packed(packed: PackedPrediction, *pairs) -> None:
    """Vectorized scatter: for each ``(padded_values, out)`` pair write
    ``out[q_idx[mask]] = padded_values[mask]`` (drops padding)."""
    msk = packed.q_mask
    idx = packed.q_idx[msk]
    for values, out in pairs:
        out[idx] = np.asarray(values)[msk]


def pack_queries(
    index: TrainIndex,
    x_test: np.ndarray,
    bs_pred: int,
    m_pred: int,
    alpha: float = 100.0,
    seed: int = 0,
    n_workers: int = 1,
    offset: int = 0,
    pad_shapes: bool = False,
    dtype=np.float64,
) -> PackedPrediction:
    """Cluster test points into prediction blocks, find each block's m_pred
    nearest training points, pack. ``offset`` shifts the scatter indices
    (chunked serving). ``pad_shapes`` rounds bs/bc up to multiples of 8 so
    successive chunks hit the same jit cache entry. ``dtype`` controls the
    packed array precision (use float32 for the compiled TPU Pallas path;
    float64 is fine in interpret mode / on CPU)."""
    x_test = np.asarray(x_test, dtype=np.float64)
    n_test = x_test.shape[0]
    xs_test = scale_inputs(x_test, index.beta)
    bc_pred = max(1, n_test // bs_pred)
    test_blocks = build_blocks(xs_test, bc_pred, n_workers, index.beta, seed=seed + 1)
    neigh = filtered_knn_points(index.xs, index.blocks, test_blocks.centers,
                                m_pred, alpha, flat=index.flat,
                                domain_volume=index.domain_volume)

    if index.store is not None:
        # Store-backed index: gather the union of neighbor rows once and
        # remap, instead of per-block fancy-indexing the full training set
        # (values and order preserved — packed arrays are bit-identical).
        from repro.data.streaming import localize_neighbors

        x_tr, y_tr, neigh = localize_neighbors(index.store, neigh)
    else:
        x_tr, y_tr = index.x, index.y

    bs_max = max(mb.size for mb in test_blocks.members)
    if pad_shapes:
        bs_max = round_up(bs_max, 8)
    packed = pack_prediction(
        x_test, x_tr, y_tr, test_blocks, neigh, m_pred, bs_max=bs_max,
        dtype=dtype,
    )
    if offset:
        packed.q_idx[packed.q_mask] += offset
    if pad_shapes:
        packed = packed.pad_to_blocks(round_up(packed.n_blocks, 8))
    return packed


def iter_query_chunks(
    index: TrainIndex,
    x_test: np.ndarray,
    bs_pred: int,
    m_pred: int,
    alpha: float = 100.0,
    seed: int = 0,
    n_workers: int = 1,
    chunk_size: int | None = None,
    dtype=np.float64,
):
    """Yield ``(chunk_id, PackedPrediction)`` over the test set.

    The single chunking protocol shared by ``predict_sbv`` and the serving
    driver: step clamped to >= bs_pred, per-chunk seed variation, scatter
    offsets, and jit-stable padded shapes in chunked mode all live HERE so
    the two paths cannot drift. ``x_test`` may be a row store, in which
    case each window is read on demand (``chunk_size`` is then required —
    reading an out-of-core test set whole would defeat the store)."""
    from repro.data.store import is_store

    if is_store(x_test):
        if chunk_size is None:
            raise ValueError("x_test is a store: pass chunk_size to bound "
                             "the per-window read")
        n_test = x_test.n_rows
        window = lambda a, b: x_test.read_slice(a, b)[0]
    else:
        x_test = np.asarray(x_test, dtype=np.float64)
        n_test = x_test.shape[0]
        window = lambda a, b: x_test[a:b]
    step = n_test if chunk_size is None else max(int(chunk_size), bs_pred)
    for ci, start in enumerate(range(0, n_test, step)):
        stop = min(n_test, start + step)
        yield ci, pack_queries(
            index, window(start, stop), bs_pred, m_pred, alpha=alpha,
            seed=seed + ci, n_workers=n_workers, offset=start,
            pad_shapes=chunk_size is not None, dtype=dtype,
        )


def _predict_multi_one(params, nu, qx, qmask, nx, ny, nmask):
    """Multi-output block conditional (docs/multioutput.md).

    ``ny`` is (m, p). One Cholesky of the shared unit-variance
    conditioning covariance serves all outputs: the mean is sigma2-free
    (the per-output scale cancels in cross @ con^-1 @ y), so the p means
    are just extra solve columns; the variance scales the shared
    unit-variance conditional by each output's sigma2."""
    p0 = params.structure_params()
    sigma_con = _masked_cov(nx, nx, nmask, nmask, p0, nu, identity=True)
    sigma_cross = _masked_cov(nx, qx, nmask, qmask, p0, nu, identity=False)
    ynn = jnp.where(nmask[:, None], ny, 0.0)
    chol = jnp.linalg.cholesky(sigma_con)
    a = jax.scipy.linalg.solve_triangular(chol, sigma_cross, lower=True)
    z = jax.scipy.linalg.solve_triangular(chol, ynn, lower=True)  # (m, p)
    mu = a.T @ z                                                  # (bs, p)
    var0 = (1.0 + params.tau2) - jnp.sum(a * a, axis=0)           # (bs,)
    var = var0[:, None] * params.sigma2[None, :]
    return mu, jnp.maximum(var, 1e-12)


def _predict_one(params, nu, qx, qmask, nx, ny, nmask):
    sigma_con = _masked_cov(nx, nx, nmask, nmask, params, nu, identity=True)
    sigma_cross = _masked_cov(nx, qx, nmask, qmask, params, nu, identity=False)
    ynn = jnp.where(nmask, ny, 0.0)
    chol = jnp.linalg.cholesky(sigma_con)
    a = jax.scipy.linalg.solve_triangular(chol, sigma_cross, lower=True)
    z = jax.scipy.linalg.solve_triangular(chol, ynn, lower=True)
    mu = a.T @ z
    prior = params.sigma2 + params.nugget
    var = prior - jnp.sum(a * a, axis=0)
    return mu, jnp.maximum(var, 1e-12)


@partial(jax.jit, static_argnames=("nu", "backend"))
def batched_block_predict(
    params: KernelParams,
    q_x, q_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
    backend: str = "ref",
):
    """Conditional mean/variance for every prediction block in one jitted
    call on packed arrays: (bc, bs_pred) each. Padded query slots carry
    mu=0 / var=prior; drop them with the mask.

    Backends: ``ref`` (vmapped jnp, differentiable), ``pallas`` (fused
    kernel on the given shapes), ``pallas_tiled`` (fused kernel on
    8x128-aligned tiles — the compiled f32 TPU serving path), ``auto``
    (resolved per batch shape by ``kernels.ops.select_backend`` — the
    bucketed execution layer uses this to mix backends across buckets).

    ``MultiOutputParams`` (with (bc, m, p) ``nn_y``) dispatches to the
    shared-Cholesky multi-output conditional and returns (bc, bs, p)
    mean/variance; the fused predict kernels stay single-output, so every
    backend resolves to the vmapped program there (the shared solve is
    already the dominant cost — see docs/multioutput.md)."""
    from .multioutput import MultiOutputParams

    if isinstance(params, MultiOutputParams):
        return jax.vmap(
            lambda a, b, c, d, e: _predict_multi_one(params, nu, a, b, c, d, e)
        )(q_x, q_mask, nn_x, nn_y, nn_mask)
    if backend == "auto":
        from repro.kernels import ops as kops

        backend = kops.select_backend(
            q_x.shape[1], nn_x.shape[1], kind="predict", dtype=q_x.dtype
        )
    if backend == "ref":
        return jax.vmap(
            lambda a, b, c, d, e: _predict_one(params, nu, a, b, c, d, e)
        )(q_x, q_mask, nn_x, nn_y, nn_mask)
    if backend in ("pallas", "pallas_tiled"):
        from repro.kernels import ops as kops

        return kops.sbv_predict(params, q_x, q_mask, nn_x, nn_y, nn_mask, nu=nu,
                                tiled=backend == "pallas_tiled")
    raise ValueError(f"unknown backend {backend!r}")


def packed_predict(
    params: KernelParams,
    packed: PackedPrediction,
    nu: float = 3.5,
    backend: str = "ref",
):
    """Mean/variance of a PackedPrediction (padded (bc, bs_pred) arrays)."""
    q_x, q_mask, nn_x, nn_y, nn_mask = (jnp.asarray(a) for a in packed.arrays())
    return batched_block_predict(
        params, q_x, q_mask, nn_x, nn_y, nn_mask, nu=nu, backend=backend
    )


@partial(jax.jit, static_argnames=("nu", "backend", "n_sims"))
def _predict_and_simulate(
    params, q_x, q_mask, nn_x, nn_y, nn_mask, key,
    nu: float, backend: str, n_sims: int,
):
    """End-to-end jitted per-chunk math: block conditionals + vectorized
    conditional simulation (paper §5.1.5) in one device program."""
    mu, var = batched_block_predict(
        params, q_x, q_mask, nn_x, nn_y, nn_mask, nu=nu, backend=backend
    )
    eps = jax.random.normal(key, (n_sims,) + mu.shape, dtype=mu.dtype)
    draws = mu[None] + jnp.sqrt(var)[None] * eps
    sim_mean = jnp.mean(draws, axis=0)
    sim_std = jnp.std(draws, axis=0, ddof=1)
    return mu, var, sim_mean, sim_std


@partial(jax.jit, static_argnames=("nu", "backend", "n_sims", "lo", "bc_full"))
def _predict_and_simulate_span(
    params, q_x, q_mask, nn_x, nn_y, nn_mask, key,
    nu: float, backend: str, n_sims: int, lo: int, bc_full: int,
):
    """One rank's block span of a chunk, with the FULL chunk's sim-draw
    stream: eps is generated at the whole-chunk ``(n_sims, bc_full, ...)``
    shape from the chunk's key and sliced to this rank's ``[lo, lo+bc)``
    block rows, so every block receives exactly the draws the serial
    ``_predict_and_simulate`` would hand it. Per-block conditionals are
    independent, so mean/var shard bitwise; the simulation columns agree
    to ~1 ulp (XLA fuses the eps slice into the sample reductions
    differently per span shape) — far inside the 1e-8 multi-host parity
    gate. A full-span slice (``lo=0, bc_full=bc``) is bitwise
    everywhere, which is the LoopbackComm contract."""
    mu, var = batched_block_predict(
        params, q_x, q_mask, nn_x, nn_y, nn_mask, nu=nu, backend=backend
    )
    eps = jax.random.normal(key, (n_sims, bc_full) + mu.shape[1:],
                            dtype=mu.dtype)[:, lo:lo + mu.shape[0]]
    draws = mu[None] + jnp.sqrt(var)[None] * eps
    sim_mean = jnp.mean(draws, axis=0)
    sim_std = jnp.std(draws, axis=0, ddof=1)
    return mu, var, sim_mean, sim_std


def _slice_prediction_blocks(p: PackedPrediction, lo: int,
                             hi: int) -> PackedPrediction:
    """A contiguous block-row view of a packed chunk (every field's
    leading axis is the block count; ``q_idx`` stays global, so the
    scatter of a slice lands in the right test rows)."""
    return PackedPrediction(
        q_x=p.q_x[lo:hi], q_mask=p.q_mask[lo:hi], q_idx=p.q_idx[lo:hi],
        nn_x=p.nn_x[lo:hi], nn_y=p.nn_y[lo:hi], nn_mask=p.nn_mask[lo:hi],
        owners=p.owners[lo:hi],
    )


def predict_sbv(
    params: KernelParams,
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_test: np.ndarray,
    bs_pred: int = 25,
    m_pred: int = 200,
    nu: float = 3.5,
    alpha: float = 100.0,
    n_sims: int = 1000,
    seed: int = 0,
    n_workers: int = 1,
    beta_struct: np.ndarray | None = None,
    backend: str = "ref",
    chunk_size: int | None = None,
    dtype=np.float64,
    n_buckets: int | None = None,
    stream_chunk: int | None = None,
    precision=None,
    tuning=None,
    multihost=None,
) -> Prediction:
    """Packed block prediction over the full test set.

    ``beta_struct`` overrides the scaling used for clustering/NNS only
    (paper Fig. 4 isolates structure quality: BV = isotropic structure +
    true kernel; SBV = scaled structure + true kernel). ``chunk_size``
    streams the test set through fixed-shape device programs so memory
    stays bounded for arbitrary n_test. ``n_buckets`` executes each chunk
    as size-buckets padded to their own ceilings (docs/packing.md) instead
    of one uniformly-padded batch; mean/var are unchanged (<=1e-10), only
    padding waste drops.

    Out-of-core: ``x_train`` (with ``y_train=None``) and/or ``x_test``
    may be row stores; ``stream_chunk`` selects the streaming training
    index (docs/streaming.md). In-core arrays with ``stream_chunk`` take
    the identical code path, so store-backed and in-core streaming
    predictions agree bitwise on the same rows.

    ``precision`` picks a ladder tier (str or PrecisionPolicy,
    docs/precision.md): coordinates pack at the tier's storage dtype and
    all conditional math runs at its accumulation dtype. Unlike the fit
    there is no per-chunk probe — budget enforcement happens at fit/tune
    time (``assign_precision`` / the autotuner); pass the fitted tier.
    ``tuning`` (TuningRecord / dict / checkpoint path) fills n_buckets,
    stream_chunk, and precision when unset, and backend when 'auto'.

    ``multihost`` (a ``MultihostContext`` / ``LoopbackComm``,
    repro/multihost.py) shards every chunk's prediction BLOCKS by owner
    rank: each rank computes its contiguous block span with the full
    chunk's simulation-draw stream (``_predict_and_simulate_span``),
    scatters into zero-filled result columns, and ONE allreduce-sum per
    call merges the disjoint columns (x + 0 is exact in any order, so
    the sum IS an allgather). Every rank must pass identical training
    and test data; all ranks return the full result — mean/var bitwise
    equal to the serial call, simulation columns to ~1 ulp (<= 1e-8
    gated). A ``LoopbackComm`` reproduces the serial call bitwise."""
    from repro.data.store import is_store

    if tuning is not None:
        from repro.tuning import as_record

        rec = as_record(tuning)
        if n_buckets is None:
            n_buckets = rec.n_buckets
        if stream_chunk is None and rec.stream_chunk:
            stream_chunk = rec.stream_chunk
        if precision is None and rec.precision:
            precision = rec.precision
        if backend == "auto" and rec.backend:
            backend = rec.backend

    tier = None
    if precision is not None:
        from .buckets import acc_dtype, as_policy

        pol = as_policy(precision)
        if pol.tier != "f64":
            tier = pol.tier
            dtype = acc_dtype(tier)  # queries pack at the accumulation width

    # -- Multi-output routing (docs/multioutput.md): a 2-D training y
    # keeps ONE training index / structure pass and scatters per-output
    # columns. (n, 1) squeezes to the single-output program so p=1 stays
    # BITWISE-identical to a 1-D y; p >= 2 coerces the params to the
    # shared-structure MultiOutputParams form.
    from .multioutput import as_multi_params, MultiOutputParams

    n_outputs = 1
    squeeze_back = False
    if not is_store(x_train) and y_train is not None:
        y_train = np.asarray(y_train)
        if y_train.ndim == 2:
            if y_train.shape[1] == 1:
                y_train = y_train[:, 0]
                squeeze_back = True
                if isinstance(params, MultiOutputParams):
                    params = params.output_params(0)
            else:
                n_outputs = y_train.shape[1]
    elif is_store(x_train):
        from repro.data.store import as_store

        y0 = np.asarray(as_store(x_train, y_train).read_slice(0, 1)[1])
        if y0.ndim == 2:
            n_outputs = y0.shape[1]
    if n_outputs > 1:
        params = as_multi_params(params, n_outputs,
                                 np.asarray(params.beta).shape[0])
    elif isinstance(params, MultiOutputParams):
        params = params.output_params(0)

    beta = np.asarray(params.beta if beta_struct is None else beta_struct)
    if is_store(x_test):
        n_test = x_test.n_rows
        if chunk_size is None:
            chunk_size = stream_chunk  # bound the test-window reads too
    else:
        x_test = np.asarray(x_test, dtype=np.float64)
        n_test = x_test.shape[0]
    index = build_train_index(x_train, y_train, beta, m_pred, n_workers, seed,
                              stream_chunk=stream_chunk)

    out_shape = (n_test,) if n_outputs == 1 else (n_test, n_outputs)
    mean = np.zeros(out_shape)
    var = np.zeros(out_shape)
    sim_mean = np.zeros(out_shape)
    sim_std = np.zeros(out_shape)
    key = jax.random.PRNGKey(seed)

    for ci, packed in iter_query_chunks(
        index, x_test, bs_pred, m_pred, alpha=alpha, seed=seed,
        n_workers=n_workers, chunk_size=chunk_size, dtype=dtype,
    ):
        if n_buckets:
            from .buckets import bucket_mults, bucket_prediction

            bs_mult, m_mult = bucket_mults(backend, precision=tier)
            pieces = bucket_prediction(
                packed, n_buckets=n_buckets, bs_mult=bs_mult, m_mult=m_mult,
            ).buckets
        else:
            pieces = [packed]
        if tier is not None:
            from .buckets import cast_prediction

            pieces = [cast_prediction(p, tier) for p in pieces]
        key_c = jax.random.fold_in(key, ci)
        for bi, piece in enumerate(pieces):
            # Uniform path keeps the pre-bucketing key stream (bit-stable
            # sim draws); buckets get independent per-bucket streams.
            key_b = key_c if not n_buckets else jax.random.fold_in(key_c, bi)
            if multihost is None:
                mu_b, var_b, sm_b, ss_b = _predict_and_simulate(
                    params, *(jnp.asarray(a) for a in piece.arrays()),
                    key_b, nu=nu, backend=backend, n_sims=n_sims,
                )
                scatter_packed(piece, (mu_b, mean), (var_b, var),
                               (sm_b, sim_mean), (ss_b, sim_std))
                continue
            # Multi-host: this rank computes only its contiguous block
            # span; the full-chunk eps stream is sliced inside the jit so
            # the draws match the serial path bitwise.
            from repro.multihost import partition_blocks

            bc_full = piece.n_blocks
            lo, hi = partition_blocks(bc_full, multihost.size)[multihost.rank]
            if hi > lo:
                sub = _slice_prediction_blocks(piece, lo, hi)
                mu_b, var_b, sm_b, ss_b = _predict_and_simulate_span(
                    params, *(jnp.asarray(a) for a in sub.arrays()),
                    key_b, nu=nu, backend=backend, n_sims=n_sims,
                    lo=lo, bc_full=bc_full,
                )
                scatter_packed(sub, (mu_b, mean), (var_b, var),
                               (sm_b, sim_mean), (ss_b, sim_std))

    if multihost is not None:
        # Ranks filled disjoint result rows (block spans own disjoint
        # query indices); one allreduce-sum of the zero-initialized
        # columns is an exact allgather — x + 0 in any reduction order.
        merged = multihost.allreduce(np.stack([mean, var, sim_mean, sim_std]))
        mean, var, sim_mean, sim_std = (merged[i] for i in range(4))

    if squeeze_back:
        # (n, 1) input: single-output math, multi-output result shape.
        mean, var, sim_mean, sim_std = (
            a[:, None] for a in (mean, var, sim_mean, sim_std))
    z975 = 1.959963984540054
    return Prediction(
        mean=mean, var=var, sim_mean=sim_mean,
        ci_low=sim_mean - z975 * sim_std, ci_high=sim_mean + z975 * sim_std,
    )


def mspe(pred: np.ndarray, truth: np.ndarray) -> float:
    return float(np.mean((pred - truth) ** 2))


def rmspe(pred: np.ndarray, truth: np.ndarray) -> float:
    """Root Mean Squared Percentage Error (paper §6.2)."""
    denom = np.where(np.abs(truth) > 1e-12, truth, 1.0)
    return float(np.sqrt(np.mean(((pred - truth) / denom) ** 2)) * 100.0)
