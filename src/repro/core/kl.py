"""KL divergence between exact GP and a Vecchia approximation (paper Eq. 4).

For zero-mean Gaussians, D_KL(exact || vecchia) reduces to the difference
of log-likelihoods evaluated at y = 0:
    D_KL = l_exact(theta; 0) - l_vecchia(theta; 0) >= 0.
"""
from __future__ import annotations

import numpy as np

from .exact_gp import exact_loglik
from .kernels_math import KernelParams
from .packing import PackedBlocks
from .vecchia import packed_loglik


def kl_divergence(
    params: KernelParams,
    x: np.ndarray,
    packed: PackedBlocks,
    nu: float = 3.5,
    backend: str = "ref",
) -> float:
    """Eq. 4. ``packed`` must have been built from the same x (y ignored)."""
    import jax.numpy as jnp

    zero_packed = PackedBlocks(
        blk_x=packed.blk_x,
        blk_y=np.zeros_like(packed.blk_y),
        blk_mask=packed.blk_mask,
        nn_x=packed.nn_x,
        nn_y=np.zeros_like(packed.nn_y),
        nn_mask=packed.nn_mask,
        owners=packed.owners,
    )
    l0 = exact_loglik(params, jnp.asarray(x), jnp.zeros(x.shape[0]), nu=nu)
    la = packed_loglik(params, zero_packed, nu=nu, backend=backend)
    return float(l0 - la)
