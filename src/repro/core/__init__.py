# The paper's primary contribution: the Scaled Block Vecchia GP.
from .kernels_math import KernelParams, cov_matrix, matern, scaled_sqdist
from .exact_gp import exact_loglik, exact_predict
from .packing import PackedBlocks, PackedPrediction
from .buckets import (
    BucketedBlocks, BucketedPrediction, bucket_blocks, bucket_prediction,
)
from .pipeline import SBVConfig, preprocess
from .predict import (
    Prediction, batched_block_predict, build_train_index, iter_query_chunks,
    pack_queries, packed_predict, predict_sbv, scatter_packed,
)
from .vecchia import batched_block_loglik, packed_loglik
from .kl import kl_divergence

__all__ = [
    "KernelParams", "cov_matrix", "matern", "scaled_sqdist",
    "exact_loglik", "exact_predict",
    "PackedBlocks", "PackedPrediction",
    "BucketedBlocks", "BucketedPrediction", "bucket_blocks", "bucket_prediction",
    "SBVConfig", "preprocess",
    "Prediction", "batched_block_predict", "build_train_index",
    "iter_query_chunks", "pack_queries", "packed_predict", "predict_sbv",
    "scatter_packed",
    "batched_block_loglik", "packed_loglik",
    "kl_divergence",
]
