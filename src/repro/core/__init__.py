# The paper's primary contribution: the Scaled Block Vecchia GP.
from .kernels_math import KernelParams, cov_matrix, matern, scaled_sqdist
from .exact_gp import exact_loglik, exact_predict
from .pipeline import SBVConfig, preprocess
from .vecchia import batched_block_loglik, packed_loglik
from .kl import kl_divergence

__all__ = [
    "KernelParams", "cov_matrix", "matern", "scaled_sqdist",
    "exact_loglik", "exact_predict",
    "SBVConfig", "preprocess",
    "batched_block_loglik", "packed_loglik",
    "kl_divergence",
]
