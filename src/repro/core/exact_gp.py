"""Exact dense GP (the ExaGeoStat-style baseline the paper compares against).

O(n^3) Cholesky-based log-likelihood and prediction. Used as ground truth
for KL-divergence validation (paper Eq. 4) and in Fig.-4-style benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels_math import KernelParams, cov_matrix

_LOG2PI = jnp.log(2.0 * jnp.pi)


def exact_loglik(params: KernelParams, x: jax.Array, y: jax.Array, nu: float = 3.5) -> jax.Array:
    """Dense GP log-likelihood (paper Eq. 1)."""
    n = x.shape[0]
    k = cov_matrix(x, x, params, nu=nu, add_nugget=True)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.solve_triangular(chol, y, lower=True)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))
    return -0.5 * n * _LOG2PI - 0.5 * logdet - 0.5 * jnp.dot(alpha, alpha)


def exact_logdet(params: KernelParams, x: jax.Array, nu: float = 3.5) -> jax.Array:
    k = cov_matrix(x, x, params, nu=nu, add_nugget=True)
    chol = jnp.linalg.cholesky(k)
    return 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))


def exact_predict(
    params: KernelParams,
    x_train: jax.Array,
    y_train: jax.Array,
    x_test: jax.Array,
    nu: float = 3.5,
):
    """Conditional mean and marginal variance at test points (paper §4.1)."""
    k_tt = cov_matrix(x_train, x_train, params, nu=nu, add_nugget=True)
    k_ts = cov_matrix(x_train, x_test, params, nu=nu)
    chol = jnp.linalg.cholesky(k_tt)
    a = jax.scipy.linalg.solve_triangular(chol, k_ts, lower=True)
    z = jax.scipy.linalg.solve_triangular(chol, y_train, lower=True)
    mean = a.T @ z
    prior_var = params.sigma2 + params.nugget
    var = prior_var - jnp.sum(a * a, axis=0)
    return mean, jnp.maximum(var, 1e-12)
