"""Multi-output SBV: one structure, batched per-output likelihoods (VPPE).

Parallel partial emulation (PAPERS.md, arXiv 2508.19144) extends Scaled
Vecchia to simulators that emit a whole output field per run: all p
outputs share ONE input scaling beta and ONE block/neighbor structure,
and differ only in their marginal variance. The parameterization here is

    K_j = sigma2_j * ( R(beta) + tau2 * I )        for output j,

i.e. a shared unit-variance correlation R with a shared RELATIVE nugget
tau2 and a per-output scale sigma2_j (absolute nugget nugget_j =
tau2 * sigma2_j). Every per-block conditional then factorizes through
the SAME Cholesky of the unit-variance joint covariance:

    chol_j = sqrt(sigma2_j) * chol0
    logdet_j = bs * log(sigma2_j) + logdet0
    q_j = q0_j / sigma2_j            (q0_j from one (m+bs, p)-RHS solve)

so one POTRF per block serves all p outputs and the per-output work is a
multi-column TRSV — exactly the batched-GEMM shape the packed/bucketed
layout already speaks; cost is sublinear in p vs p independent fits.

The per-output scales are PROFILED in closed form (sigma2_j = Q_j / n),
leaving a pooled profile likelihood over (log_beta, log_tau2):

    2 * nll(beta, tau2) = p*n*log(2 pi) + p*logdet0
                          + n * sum_j log(Q_j / n) + n*p .

``docs/multioutput.md`` states the full contract (shared structure,
p=1 bitwise guarantee, serving output masks).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels_math import KernelParams
from .vecchia import _LOG2PI, _masked_cov


class MultiOutputParams(NamedTuple):
    """Shared-structure multi-output kernel parameters (log scale).

    ``log_sigma2`` is (p,) — one marginal variance per output;
    ``log_beta`` is the SHARED (d,) input scaling; ``log_tau2`` the
    shared relative nugget (nugget_j = tau2 * sigma2_j). A NamedTuple so
    it is a pytree: jitted programs trace it, the checkpoint flattener
    round-trips it, and ``cast`` is a tree_map."""

    log_sigma2: jnp.ndarray  # (p,)
    log_beta: jnp.ndarray    # (d,)
    log_tau2: jnp.ndarray    # scalar

    @property
    def sigma2(self):
        return jnp.exp(self.log_sigma2)

    @property
    def beta(self):
        return jnp.exp(self.log_beta)

    @property
    def tau2(self):
        return jnp.exp(self.log_tau2)

    @property
    def nugget(self):
        return jnp.exp(self.log_tau2 + self.log_sigma2)  # (p,) absolute

    @property
    def n_outputs(self) -> int:
        return int(self.log_sigma2.shape[0])

    @classmethod
    def create(cls, sigma2, beta, tau2, d: int, p: int) -> "MultiOutputParams":
        sigma2 = jnp.broadcast_to(jnp.asarray(sigma2, jnp.float64), (p,))
        beta = jnp.broadcast_to(jnp.asarray(beta, jnp.float64), (d,))
        return cls(
            log_sigma2=jnp.log(sigma2),
            log_beta=jnp.log(beta),
            log_tau2=jnp.log(jnp.asarray(tau2, jnp.float64)),
        )

    def output_params(self, j: int) -> KernelParams:
        """The equivalent single-output ``KernelParams`` for output j."""
        return KernelParams(
            log_sigma2=self.log_sigma2[j],
            log_beta=self.log_beta,
            log_nugget=self.log_tau2 + self.log_sigma2[j],
        )

    def structure_params(self) -> KernelParams:
        """Unit-variance correlation params: sigma2=1, nugget=tau2.

        All shared-Cholesky math (stats, prediction) runs on these; the
        per-output sigma2 re-enter as closed-form scalings."""
        return KernelParams(
            log_sigma2=jnp.zeros((), self.log_beta.dtype),
            log_beta=self.log_beta,
            log_nugget=self.log_tau2,
        )


def _cast_multi(params: MultiOutputParams, dtype) -> MultiOutputParams:
    """Differentiable down-cast (precision ladder), like ``cast_params``."""
    return jax.tree.map(lambda a: jnp.asarray(a, dtype), params)


def _block_multi_stats_one(params0, nu, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask):
    """(logdet0, q0) of ONE block from the shared unit-variance Cholesky.

    ``blk_y`` is (bs, p) and ``nn_y`` (m, p): the joint-assembly solve of
    ``_block_loglik_joint_one`` with a (m+bs, p) right-hand side — one
    POTRF, p columns through the same TRSV. Identity padding keeps padded
    rows exactly inert (unit diag, zero y), so the per-output stats equal
    the single-output path's to machine precision."""
    x = jnp.concatenate([nn_x, blk_x], axis=0)
    mask = jnp.concatenate([nn_mask, blk_mask], axis=0)
    yv = jnp.concatenate([jnp.where(nn_mask[:, None], nn_y, 0.0),
                          jnp.where(blk_mask[:, None], blk_y, 0.0)], axis=0)
    m = nn_x.shape[0]

    sigma = _masked_cov(x, x, mask, mask, params0, nu, identity=True)
    chol = jnp.linalg.cholesky(sigma)
    v = jax.scipy.linalg.solve_triangular(chol, yv, lower=True)

    vb = v[m:]
    logdet0 = 2.0 * jnp.sum(jnp.where(blk_mask, jnp.log(jnp.diag(chol)[m:]), 0.0))
    q0 = jnp.sum(vb * vb, axis=0)  # (p,)
    return logdet0, q0


@partial(jax.jit, static_argnames=("nu",))
def batched_multi_stats(
    params0: KernelParams,
    blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
):
    """Dataset totals (logdet0, q0 (p,)) over all packed blocks."""
    ld, q = jax.vmap(
        lambda a, b, c, d, e, f: _block_multi_stats_one(params0, nu, a, b, c, d, e, f)
    )(blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)
    return jnp.sum(ld), jnp.sum(q, axis=0)


def packed_multi_stats(params: MultiOutputParams, packed, nu: float = 3.5,
                       backend: str = "ref"):
    """(logdet0, q0 (p,)) of a PackedBlocks OR BucketedBlocks dataset.

    Mirrors ``packed_loglik``'s dispatch: ``ref`` is the vmapped jnp
    path at the packed accumulation dtype; ``pallas`` the fused
    multi-stats kernel (``kernels.ops.sbv_multi_stats``); ``auto``
    resolves per batch shape. Bucketed inputs sum per-bucket stats."""
    from .buckets import BucketedBlocks

    if isinstance(packed, BucketedBlocks):
        ld = q = None
        for pk in packed.buckets:
            ld_b, q_b = packed_multi_stats(params, pk, nu=nu, backend=backend)
            ld = ld_b if ld is None else ld + ld_b
            q = q_b if q is None else q + q_b
        return ld, q
    if backend == "auto":
        from repro.kernels import ops as kops

        backend = kops.select_backend(
            packed.bs_max, packed.m, kind="loglik", dtype=packed.blk_x.dtype
        )
    arrs = tuple(jnp.asarray(a) for a in (
        packed.blk_x, packed.blk_y, packed.blk_mask,
        packed.nn_x, packed.nn_y, packed.nn_mask,
    ))
    if backend == "ref":
        from .kernels_math import cast_params

        acc = arrs[1].dtype
        return batched_multi_stats(
            cast_params(params.structure_params(), acc), *arrs, nu=nu
        )
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.sbv_multi_stats(params.structure_params(), *arrs, nu=nu)
    raise ValueError(f"unknown backend {backend!r}")


def multi_loglik(params: MultiOutputParams, packed, nu: float = 3.5,
                 backend: str = "ref") -> jax.Array:
    """Per-output log-likelihood vector (p,) from the shared stats.

    Equals ``packed_loglik(params.output_params(j), packed_j)`` for every
    output j on the same structure (pinned <= 1e-8 in
    tests/test_multioutput.py)."""
    logdet0, q0 = packed_multi_stats(params, packed, nu=nu, backend=backend)
    n = packed.n_points
    s2 = params.sigma2.astype(q0.dtype)
    return (-0.5 * n * _LOG2PI - 0.5 * logdet0
            - 0.5 * n * jnp.log(s2) - 0.5 * q0 / s2)


def profile_sigma2(q0: jax.Array, n: int) -> jax.Array:
    """Closed-form per-output MLE scale given unit-variance quadratics."""
    return q0 / n


def pooled_objective(logdet0, q0, n: int):
    """Pooled profile nll per data point: the quantity the multi fit
    minimizes over (log_beta, log_tau2); sigma2 is profiled out."""
    p = q0.shape[0]
    nll2 = (p * n * _LOG2PI + p * logdet0
            + n * jnp.sum(jnp.log(q0 / n)) + n * p)
    return 0.5 * nll2 / (n * p)


def multi_profile_neg_loglik_fn(packed, nu: float, backend: str):
    """loss(params) for the monolithic multi fit (autodiff-friendly)."""
    n = packed.n_points

    def f(params: MultiOutputParams):
        logdet0, q0 = packed_multi_stats(params, packed, nu=nu, backend=backend)
        return pooled_objective(logdet0, q0, n)

    return f


def with_profiled_sigma2(params: MultiOutputParams, packed, nu: float = 3.5,
                         backend: str = "ref") -> MultiOutputParams:
    """Return params with sigma2_j set to the closed-form profile MLE."""
    _, q0 = packed_multi_stats(params, packed, nu=nu, backend=backend)
    s2 = jnp.maximum(profile_sigma2(q0.astype(jnp.float64), packed.n_points),
                     1e-300)
    return params._replace(log_sigma2=jnp.log(s2))


def as_multi_params(params, p: int, d: int) -> MultiOutputParams:
    """Coerce a KernelParams (broadcast over outputs) or pass through."""
    if isinstance(params, MultiOutputParams):
        return params
    if isinstance(params, KernelParams):
        tau2 = params.nugget / params.sigma2
        return MultiOutputParams.create(params.sigma2, params.beta, tau2,
                                        d=d, p=p)
    raise TypeError(f"cannot coerce {type(params).__name__} to MultiOutputParams")
