"""Bucketed variable-size block execution (the canonical packed layout).

MAGMA — the paper's GPU backend — runs *variable-size* batched BLAS, so a
skewed k-means block-size distribution costs what it costs. A single
uniformly-padded batch (``PackedBlocks`` padded to the global ``bs_max``
and a uniform ``m``) does not have that property: one 3x outlier block
inflates every Cholesky/GEMM in the batch, and early-ordered blocks with
tiny conditioning sets still pay the full ``m``-sized factorization.

The bucketed layout recovers MAGMA's economics on fixed-shape hardware:
blocks are partitioned into K size-buckets with geometric ``bs``/``m``
ceilings (optionally tile-aligned per the TPU rules in ``packing.py``),
and each bucket is a small ``PackedBlocks``/``PackedPrediction`` padded
only to its own ceiling. Every consumer (likelihood, prediction,
distribution, serving) loops jitted per-bucket programs — one compile per
bucket *shape*, cached by jit — and sums logliks / scatters predictions.
Identity padding makes each bucket's math equal to the uniform layout's
(tested to 1e-10), so the only thing that changes is how much padded work
the device does; the ``occupancy`` metric (true FLOPs / padded FLOPs)
quantifies exactly that.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .packing import (
    TILE_LANE, TILE_SUBLANE, PackedBlocks, PackedPrediction, round_up,
)


def bucket_mults(backend: str) -> tuple[int, int]:
    """(bs_mult, m_mult) bucket-ceiling alignment for a kernel backend.

    The compiled TPU path wants 8x128-aligned shapes (see
    ``packing.tile_predict_shapes``); everything else buckets to exact
    geometric ceilings."""
    if backend == "pallas_tiled":
        return TILE_SUBLANE, TILE_LANE
    return 1, 1


def block_flops(bs, m):
    """Per-block likelihood work model: bs * (bs + m)^2.

    The joint-assembly path factorizes one (m+bs)x(m+bs) covariance; the
    bs-conditional share of that factorization plus the solves is
    O(bs * (bs+m)^2). Used for occupancy accounting and for balancing
    distributed shards by *work* rather than block count."""
    s = np.asarray(bs, dtype=np.float64)
    t = np.asarray(m, dtype=np.float64)
    return s * (s + t) ** 2


def predict_flops(bs, m):
    """Per-block prediction work model: chol(m) + joint solve vs bs RHS."""
    s = np.asarray(bs, dtype=np.float64)
    t = np.asarray(m, dtype=np.float64)
    return t ** 3 / 3.0 + t * t * s + t * s


def bucket_ceilings(sizes: np.ndarray, n_buckets: int, mult: int = 1) -> np.ndarray:
    """Geometric bucket ceilings covering ``sizes``, rounded up to ``mult``.

    Returns a sorted array of at most ``n_buckets`` distinct ceilings; the
    last ceiling always covers ``max(sizes)``. Degenerate inputs (uniform
    sizes, or ``mult`` coarser than the spread) collapse to one bucket —
    the uniform layout is the K=1 special case, not a different code path.
    """
    sizes = np.asarray(sizes)
    if sizes.size == 0:
        return np.asarray([mult], dtype=np.int64)
    lo = max(int(sizes.min()), 1)
    hi = max(int(sizes.max()), 1)
    if n_buckets <= 1 or hi <= lo:
        return np.asarray([round_up(hi, mult)], dtype=np.int64)
    edges = np.geomspace(lo, hi, num=n_buckets + 1)[1:]
    ceils = sorted({round_up(int(np.ceil(e)), mult) for e in edges})
    if ceils[-1] < hi:  # rounding can only round UP, but guard anyway
        ceils.append(round_up(hi, mult))
    return np.asarray(ceils, dtype=np.int64)


def assign_buckets(sizes: np.ndarray, ceilings: np.ndarray) -> np.ndarray:
    """Index of the smallest ceiling >= each size."""
    idx = np.searchsorted(ceilings, np.asarray(sizes))
    if idx.size and idx.max() >= ceilings.size:
        raise ValueError("size exceeds the largest bucket ceiling")
    return idx


def _true_sizes(mask: np.ndarray) -> np.ndarray:
    """Per-row count of real entries; asserts masks are contiguous prefixes
    (the packing contract every bucket slice relies on)."""
    counts = mask.sum(axis=1).astype(np.int64)
    expect = np.arange(mask.shape[1])[None, :] < counts[:, None]
    if not np.array_equal(mask.astype(bool), expect):
        raise ValueError("mask is not a contiguous prefix; cannot bucket")
    return counts


@dataclass
class BucketedBlocks:
    """K per-shape batches replacing one uniformly-padded batch.

    ``buckets[k]`` is a ``PackedBlocks`` padded to its own (bs, m) ceiling;
    ``ranks[k]`` holds each block's leading-dim index in the source uniform
    layout (= conditioning rank order), the scatter index that restores
    global order for any per-block quantity."""

    buckets: list
    ranks: list

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_blocks(self) -> int:
        return sum(pk.n_blocks for pk in self.buckets)

    @property
    def n_points(self) -> int:
        return sum(pk.n_points for pk in self.buckets)

    def occupancy(self) -> float:
        """True/padded FLOP ratio under the likelihood work model."""
        true, padded = loglik_work(self.buckets)
        return true / padded if padded else 1.0


@dataclass
class BucketedPrediction:
    """Prediction twin of ``BucketedBlocks``. Each bucket keeps its own
    global ``q_idx``, so per-bucket results scatter directly into the
    test-point-ordered output arrays — no extra reassembly index."""

    buckets: list
    ranks: list

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_blocks(self) -> int:
        return sum(pk.n_blocks for pk in self.buckets)

    @property
    def n_queries(self) -> int:
        return sum(pk.n_queries for pk in self.buckets)

    def occupancy(self) -> float:
        """True/padded FLOP ratio under the prediction work model."""
        true, padded = prediction_work(self.buckets)
        return true / padded if padded else 1.0


def loglik_work(buckets: list) -> tuple[float, float]:
    """(true, padded) likelihood FLOPs over a list of ``PackedBlocks``."""
    true = padded = 0.0
    for pk in buckets:
        bs_t = pk.blk_mask.sum(axis=1)
        m_t = pk.nn_mask.sum(axis=1)
        true += float(np.sum(block_flops(bs_t, m_t)))
        padded += pk.n_blocks * float(block_flops(pk.bs_max, pk.m))
    return true, padded


def prediction_work(buckets: list) -> tuple[float, float]:
    """(true, padded) prediction FLOPs over a list of ``PackedPrediction``."""
    true = padded = 0.0
    for pk in buckets:
        bs_t = pk.q_mask.sum(axis=1)
        m_t = pk.nn_mask.sum(axis=1)
        true += float(np.sum(predict_flops(bs_t, m_t)))
        padded += pk.n_blocks * float(predict_flops(pk.bs_pred, pk.m_pred))
    return true, padded


def _group(bs_true, m_true, bs_ceils, m_ceils):
    """Group block indices by (bs-ceiling, m-ceiling) cell, sorted so the
    bucket sequence (and therefore the compile order) is deterministic."""
    bs_a = assign_buckets(bs_true, bs_ceils)
    m_a = assign_buckets(m_true, m_ceils)
    cells: dict[tuple[int, int], list[int]] = {}
    for b, key in enumerate(zip(bs_a.tolist(), m_a.tolist())):
        cells.setdefault(key, []).append(b)
    out = []
    for key in sorted(cells):
        idx = np.asarray(cells[key], dtype=np.int64)
        out.append((int(bs_ceils[key[0]]), int(m_ceils[key[1]]), idx))
    return out


def bucket_blocks(
    packed: PackedBlocks,
    n_buckets: int = 4,
    bs_mult: int = 1,
    m_mult: int = 1,
    ceilings: tuple[np.ndarray, np.ndarray] | None = None,
) -> BucketedBlocks:
    """Partition a uniformly-padded ``PackedBlocks`` into size-buckets.

    ``n_buckets`` bounds the geometric levels *per dimension* (bs and m);
    the realized bucket count is the number of occupied (bs, m) cells,
    which skew keeps far below ``n_buckets**2`` in practice. ``bs_mult`` /
    ``m_mult`` align ceilings to hardware tiles (see
    ``packing.tile_predict_shapes``) so bucket shapes stay compile-cache
    friendly.

    ``ceilings=(bs_ceils, m_ceils)`` overrides the per-call ceiling
    computation with precomputed GLOBAL levels — the streaming fit
    buckets every spooled chunk against one ceiling set so the whole
    round compiles at most one program per occupied cell instead of one
    per (chunk, cell)."""
    bs_true = _true_sizes(packed.blk_mask)
    m_true = _true_sizes(packed.nn_mask)
    if ceilings is not None:
        bs_ceils, m_ceils = ceilings
    else:
        bs_ceils = bucket_ceilings(bs_true, n_buckets, bs_mult)
        m_ceils = bucket_ceilings(m_true, n_buckets, m_mult)

    buckets, ranks = [], []
    for bs_c, m_c, idx in _group(bs_true, m_true, bs_ceils, m_ceils):
        bs_c = min(bs_c, packed.bs_max)
        m_c = min(m_c, packed.m)
        buckets.append(PackedBlocks(
            blk_x=packed.blk_x[idx, :bs_c],
            blk_y=packed.blk_y[idx, :bs_c],
            blk_mask=packed.blk_mask[idx, :bs_c],
            nn_x=packed.nn_x[idx, :m_c],
            nn_y=packed.nn_y[idx, :m_c],
            nn_mask=packed.nn_mask[idx, :m_c],
            owners=packed.owners[idx],
        ))
        ranks.append(idx)
    return BucketedBlocks(buckets=buckets, ranks=ranks)


def bucket_prediction(
    packed: PackedPrediction,
    n_buckets: int = 4,
    bs_mult: int = 1,
    m_mult: int = 1,
) -> BucketedPrediction:
    """Prediction twin of ``bucket_blocks`` (same ceiling policy)."""
    bs_true = _true_sizes(packed.q_mask)
    m_true = _true_sizes(packed.nn_mask)
    bs_ceils = bucket_ceilings(bs_true, n_buckets, bs_mult)
    m_ceils = bucket_ceilings(m_true, n_buckets, m_mult)

    buckets, ranks = [], []
    for bs_c, m_c, idx in _group(bs_true, m_true, bs_ceils, m_ceils):
        bs_c = min(bs_c, packed.bs_pred)
        m_c = min(m_c, packed.m_pred)
        buckets.append(PackedPrediction(
            q_x=packed.q_x[idx, :bs_c],
            q_mask=packed.q_mask[idx, :bs_c],
            q_idx=packed.q_idx[idx, :bs_c],
            nn_x=packed.nn_x[idx, :m_c],
            nn_y=packed.nn_y[idx, :m_c],
            nn_mask=packed.nn_mask[idx, :m_c],
            owners=packed.owners[idx],
        ))
        ranks.append(idx)
    return BucketedPrediction(buckets=buckets, ranks=ranks)
