"""Bucketed variable-size block execution (the canonical packed layout).

MAGMA — the paper's GPU backend — runs *variable-size* batched BLAS, so a
skewed k-means block-size distribution costs what it costs. A single
uniformly-padded batch (``PackedBlocks`` padded to the global ``bs_max``
and a uniform ``m``) does not have that property: one 3x outlier block
inflates every Cholesky/GEMM in the batch, and early-ordered blocks with
tiny conditioning sets still pay the full ``m``-sized factorization.

The bucketed layout recovers MAGMA's economics on fixed-shape hardware:
blocks are partitioned into K size-buckets with geometric ``bs``/``m``
ceilings (optionally tile-aligned per the TPU rules in ``packing.py``),
and each bucket is a small ``PackedBlocks``/``PackedPrediction`` padded
only to its own ceiling. Every consumer (likelihood, prediction,
distribution, serving) loops jitted per-bucket programs — one compile per
bucket *shape*, cached by jit — and sums logliks / scatters predictions.
Identity padding makes each bucket's math equal to the uniform layout's
(tested to 1e-10), so the only thing that changes is how much padded work
the device does; the ``occupancy`` metric (true FLOPs / padded FLOPs)
quantifies exactly that.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .packing import (
    TILE_LANE, TILE_SUBLANE, PackedBlocks, PackedPrediction, round_up,
)


def bucket_mults(backend: str, precision: str | None = None) -> tuple[int, int]:
    """(bs_mult, m_mult) bucket-ceiling alignment for a kernel backend.

    The compiled TPU path wants 8x128-aligned shapes (see
    ``packing.tile_predict_shapes``) — doubled to 16x128 on the bf16
    assembly tier, whose native tile is (16, 128); everything else
    buckets to exact geometric ceilings."""
    if backend == "pallas_tiled":
        if precision == "bf16":
            return 2 * TILE_SUBLANE, TILE_LANE
        return TILE_SUBLANE, TILE_LANE
    return 1, 1


def block_flops(bs, m):
    """Per-block likelihood work model: bs * (bs + m)^2.

    The joint-assembly path factorizes one (m+bs)x(m+bs) covariance; the
    bs-conditional share of that factorization plus the solves is
    O(bs * (bs+m)^2). Used for occupancy accounting and for balancing
    distributed shards by *work* rather than block count."""
    s = np.asarray(bs, dtype=np.float64)
    t = np.asarray(m, dtype=np.float64)
    return s * (s + t) ** 2


def predict_flops(bs, m):
    """Per-block prediction work model: chol(m) + joint solve vs bs RHS."""
    s = np.asarray(bs, dtype=np.float64)
    t = np.asarray(m, dtype=np.float64)
    return t ** 3 / 3.0 + t * t * s + t * s


def bucket_ceilings(sizes: np.ndarray, n_buckets: int, mult: int = 1) -> np.ndarray:
    """Geometric bucket ceilings covering ``sizes``, rounded up to ``mult``.

    Returns a sorted array of at most ``n_buckets`` distinct ceilings; the
    last ceiling always covers ``max(sizes)``. Degenerate inputs (uniform
    sizes, or ``mult`` coarser than the spread) collapse to one bucket —
    the uniform layout is the K=1 special case, not a different code path.
    """
    sizes = np.asarray(sizes)
    if sizes.size == 0:
        return np.asarray([mult], dtype=np.int64)
    lo = max(int(sizes.min()), 1)
    hi = max(int(sizes.max()), 1)
    if n_buckets <= 1 or hi <= lo:
        return np.asarray([round_up(hi, mult)], dtype=np.int64)
    edges = np.geomspace(lo, hi, num=n_buckets + 1)[1:]
    ceils = sorted({round_up(int(np.ceil(e)), mult) for e in edges})
    if ceils[-1] < hi:  # rounding can only round UP, but guard anyway
        ceils.append(round_up(hi, mult))
    return np.asarray(ceils, dtype=np.int64)


def assign_buckets(sizes: np.ndarray, ceilings: np.ndarray) -> np.ndarray:
    """Index of the smallest ceiling >= each size."""
    idx = np.searchsorted(ceilings, np.asarray(sizes))
    if idx.size and idx.max() >= ceilings.size:
        raise ValueError("size exceeds the largest bucket ceiling")
    return idx


def _true_sizes(mask: np.ndarray) -> np.ndarray:
    """Per-row count of real entries; asserts masks are contiguous prefixes
    (the packing contract every bucket slice relies on)."""
    counts = mask.sum(axis=1).astype(np.int64)
    expect = np.arange(mask.shape[1])[None, :] < counts[:, None]
    if not np.array_equal(mask.astype(bool), expect):
        raise ValueError("mask is not a contiguous prefix; cannot bucket")
    return counts


@dataclass
class BucketedBlocks:
    """K per-shape batches replacing one uniformly-padded batch.

    ``buckets[k]`` is a ``PackedBlocks`` padded to its own (bs, m) ceiling;
    ``ranks[k]`` holds each block's leading-dim index in the source uniform
    layout (= conditioning rank order), the scatter index that restores
    global order for any per-block quantity."""

    buckets: list
    ranks: list

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_blocks(self) -> int:
        return sum(pk.n_blocks for pk in self.buckets)

    @property
    def n_points(self) -> int:
        return sum(pk.n_points for pk in self.buckets)

    def occupancy(self) -> float:
        """True/padded FLOP ratio under the likelihood work model."""
        true, padded = loglik_work(self.buckets)
        return true / padded if padded else 1.0


@dataclass
class BucketedPrediction:
    """Prediction twin of ``BucketedBlocks``. Each bucket keeps its own
    global ``q_idx``, so per-bucket results scatter directly into the
    test-point-ordered output arrays — no extra reassembly index."""

    buckets: list
    ranks: list

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_blocks(self) -> int:
        return sum(pk.n_blocks for pk in self.buckets)

    @property
    def n_queries(self) -> int:
        return sum(pk.n_queries for pk in self.buckets)

    def occupancy(self) -> float:
        """True/padded FLOP ratio under the prediction work model."""
        true, padded = prediction_work(self.buckets)
        return true / padded if padded else 1.0


def loglik_work(buckets: list) -> tuple[float, float]:
    """(true, padded) likelihood FLOPs over a list of ``PackedBlocks``."""
    true = padded = 0.0
    for pk in buckets:
        bs_t = pk.blk_mask.sum(axis=1)
        m_t = pk.nn_mask.sum(axis=1)
        true += float(np.sum(block_flops(bs_t, m_t)))
        padded += pk.n_blocks * float(block_flops(pk.bs_max, pk.m))
    return true, padded


def prediction_work(buckets: list) -> tuple[float, float]:
    """(true, padded) prediction FLOPs over a list of ``PackedPrediction``."""
    true = padded = 0.0
    for pk in buckets:
        bs_t = pk.q_mask.sum(axis=1)
        m_t = pk.nn_mask.sum(axis=1)
        true += float(np.sum(predict_flops(bs_t, m_t)))
        padded += pk.n_blocks * float(predict_flops(pk.bs_pred, pk.m_pred))
    return true, padded


def _group(bs_true, m_true, bs_ceils, m_ceils):
    """Group block indices by (bs-ceiling, m-ceiling) cell, sorted so the
    bucket sequence (and therefore the compile order) is deterministic."""
    bs_a = assign_buckets(bs_true, bs_ceils)
    m_a = assign_buckets(m_true, m_ceils)
    cells: dict[tuple[int, int], list[int]] = {}
    for b, key in enumerate(zip(bs_a.tolist(), m_a.tolist())):
        cells.setdefault(key, []).append(b)
    out = []
    for key in sorted(cells):
        idx = np.asarray(cells[key], dtype=np.int64)
        out.append((int(bs_ceils[key[0]]), int(m_ceils[key[1]]), idx))
    return out


def bucket_blocks(
    packed: PackedBlocks,
    n_buckets: int = 4,
    bs_mult: int = 1,
    m_mult: int = 1,
    ceilings: tuple[np.ndarray, np.ndarray] | None = None,
) -> BucketedBlocks:
    """Partition a uniformly-padded ``PackedBlocks`` into size-buckets.

    ``n_buckets`` bounds the geometric levels *per dimension* (bs and m);
    the realized bucket count is the number of occupied (bs, m) cells,
    which skew keeps far below ``n_buckets**2`` in practice. ``bs_mult`` /
    ``m_mult`` align ceilings to hardware tiles (see
    ``packing.tile_predict_shapes``) so bucket shapes stay compile-cache
    friendly.

    ``ceilings=(bs_ceils, m_ceils)`` overrides the per-call ceiling
    computation with precomputed GLOBAL levels — the streaming fit
    buckets every spooled chunk against one ceiling set so the whole
    round compiles at most one program per occupied cell instead of one
    per (chunk, cell)."""
    bs_true = _true_sizes(packed.blk_mask)
    m_true = _true_sizes(packed.nn_mask)
    if ceilings is not None:
        bs_ceils, m_ceils = ceilings
    else:
        bs_ceils = bucket_ceilings(bs_true, n_buckets, bs_mult)
        m_ceils = bucket_ceilings(m_true, n_buckets, m_mult)

    buckets, ranks = [], []
    for bs_c, m_c, idx in _group(bs_true, m_true, bs_ceils, m_ceils):
        bs_c = min(bs_c, packed.bs_max)
        m_c = min(m_c, packed.m)
        buckets.append(PackedBlocks(
            blk_x=packed.blk_x[idx, :bs_c],
            blk_y=packed.blk_y[idx, :bs_c],
            blk_mask=packed.blk_mask[idx, :bs_c],
            nn_x=packed.nn_x[idx, :m_c],
            nn_y=packed.nn_y[idx, :m_c],
            nn_mask=packed.nn_mask[idx, :m_c],
            owners=packed.owners[idx],
        ))
        ranks.append(idx)
    return BucketedBlocks(buckets=buckets, ranks=ranks)


# --------------------------------------------------------------------------
# Mixed-precision ladder (docs/precision.md)
#
# A ladder TIER names the covariance-ASSEMBLY storage dtype; accumulation
# (distance GEMM, Cholesky, solves, logdet) always runs at least at f32:
#
#     tier    coords stored/assembled    y/masks/params + accumulation
#     bf16    bfloat16                   float32
#     f32     float32                    float32
#     f64     float64                    float64
#
# Only the coordinates narrow — they are the covariance assembly's inputs
# and the bulk of the packed bytes ((bs+m) x d vs (bs+m) per block) — so a
# bf16 bucket halves its coordinate traffic and feeds the MXU's native
# bf16xbf16->f32 GEMM while the factorization stays in f32.

LADDER = ("bf16", "f32", "f64")  # narrowest -> widest demotion order

# Default per-tier relative nll error budgets vs the f64 reference.
# f32's bound is the parity class the pallas-vs-ref harness already pins
# (1e-6); bf16 coordinate rounding (~4e-3 relative) bounds the assembly
# error class the paper's low-precision MAGMA path accepts.
_TIER_BUDGETS = {"bf16": 5e-3, "f32": 1e-6, "f64": 0.0}


def storage_dtype(tier: str):
    """Coordinate (assembly) dtype of a ladder tier."""
    import jax.numpy as jnp

    return {"bf16": jnp.bfloat16, "f32": np.float32, "f64": np.float64}[tier]


def acc_dtype(tier: str):
    """Accumulation dtype of a ladder tier (observations/masks/params)."""
    return {"bf16": np.float32, "f32": np.float32, "f64": np.float64}[tier]


def dtype_tier(dt) -> str:
    """Inverse of ``storage_dtype``: the ladder tier a packed piece runs
    at, read off its coordinate dtype (telemetry tags compile-cache keys
    with this — same shape at two dtypes is two compiled programs)."""
    name = np.dtype(dt).name
    return {"float64": "f64", "float32": "f32", "bfloat16": "bf16"}.get(name,
                                                                        name)


@dataclass(frozen=True)
class PrecisionPolicy:
    """Per-bucket precision selection for the likelihood/prediction ladder.

    ``tier`` is the REQUESTED assembly tier; with ``probe=True`` (the
    default), ``assign_precision`` evaluates each bucket's nll at the
    candidate tier through the same masked-lane packed program the fit
    runs, compares against the f64 reference, and demotes the bucket one
    rung at a time (bf16 -> f32 -> f64) until the relative error fits the
    tier's budget — so whatever ends up running IS within budget by
    construction. ``error_budget`` overrides the per-tier defaults
    (``_TIER_BUDGETS``) with one hard bound for every rung: e.g.
    ``PrecisionPolicy("bf16", error_budget=1e-6)`` only keeps bf16
    buckets that happen to meet f32-class parity and silently runs the
    rest at f32."""

    tier: str = "f32"
    error_budget: float | None = None
    probe: bool = True

    def __post_init__(self):
        if self.tier not in LADDER:
            raise ValueError(f"unknown precision tier {self.tier!r}; "
                             f"expected one of {LADDER}")

    def budget_for(self, tier: str) -> float:
        if self.error_budget is not None:
            return float(self.error_budget)
        return _TIER_BUDGETS[tier]


def as_policy(precision) -> "PrecisionPolicy":
    """Coerce a tier name / None / policy into a ``PrecisionPolicy``."""
    if precision is None:
        return PrecisionPolicy(tier="f64", probe=False)
    if isinstance(precision, PrecisionPolicy):
        return precision
    return PrecisionPolicy(tier=str(precision))


def cast_packed(pk: PackedBlocks, tier: str) -> PackedBlocks:
    """Cast one likelihood bucket to a ladder tier: coordinates to the
    tier's storage dtype, observations to its accumulation dtype; boolean
    masks and owners are untouched."""
    st, ac = storage_dtype(tier), acc_dtype(tier)
    return PackedBlocks(
        blk_x=np.asarray(pk.blk_x, dtype=st),
        blk_y=np.asarray(pk.blk_y, dtype=ac),
        blk_mask=pk.blk_mask,
        nn_x=np.asarray(pk.nn_x, dtype=st),
        nn_y=np.asarray(pk.nn_y, dtype=ac),
        nn_mask=pk.nn_mask,
        owners=pk.owners,
    )


def cast_prediction(pk: PackedPrediction, tier: str) -> PackedPrediction:
    """Prediction twin of ``cast_packed`` (q_idx stays integral)."""
    st, ac = storage_dtype(tier), acc_dtype(tier)
    return PackedPrediction(
        q_x=np.asarray(pk.q_x, dtype=st),
        q_mask=pk.q_mask,
        q_idx=pk.q_idx,
        nn_x=np.asarray(pk.nn_x, dtype=st),
        nn_y=np.asarray(pk.nn_y, dtype=ac),
        nn_mask=pk.nn_mask,
        owners=pk.owners,
    )


def assign_precision(params, bucketed, policy: PrecisionPolicy,
                     nu: float = 3.5, backend: str = "ref") -> list:
    """Per-bucket ladder tiers under ``policy``, enforced by probing.

    Accepts a ``BucketedBlocks`` or a single ``PackedBlocks`` (treated as
    one bucket). For every bucket the candidate tier's nll runs through
    ``packed_loglik`` — the identical masked-lane program the fit uses —
    and is compared against the f64 reference; over-budget buckets demote
    one rung at a time. Returns tier names aligned with
    ``bucketed.buckets`` (probing is a handful of likelihood evaluations,
    paid once per structure refresh, not per optimizer step)."""
    from .vecchia import packed_loglik

    buckets = bucketed.buckets if isinstance(bucketed, BucketedBlocks) \
        else [bucketed]
    tiers = []
    for pk in buckets:
        tier = policy.tier
        if tier == "f64" or not policy.probe:
            tiers.append(tier)
            continue
        ref = float(packed_loglik(params, cast_packed(pk, "f64"),
                                  nu=nu, backend=backend))
        denom = max(1.0, abs(ref))
        while tier != "f64":
            got = float(packed_loglik(params, cast_packed(pk, tier),
                                      nu=nu, backend=backend))
            if abs(got - ref) / denom <= policy.budget_for(tier):
                break
            tier = LADDER[LADDER.index(tier) + 1]
        tiers.append(tier)
    return tiers


def apply_precision(bucketed: BucketedBlocks, tiers) -> BucketedBlocks:
    """Cast every bucket to its assigned tier (see ``assign_precision``)."""
    if isinstance(tiers, str):
        tiers = [tiers] * bucketed.n_buckets
    if len(tiers) != bucketed.n_buckets:
        raise ValueError(f"{len(tiers)} tiers for {bucketed.n_buckets} buckets")
    return BucketedBlocks(
        buckets=[cast_packed(pk, t) for pk, t in zip(bucketed.buckets, tiers)],
        ranks=bucketed.ranks,
    )


def bucket_prediction(
    packed: PackedPrediction,
    n_buckets: int = 4,
    bs_mult: int = 1,
    m_mult: int = 1,
) -> BucketedPrediction:
    """Prediction twin of ``bucket_blocks`` (same ceiling policy)."""
    bs_true = _true_sizes(packed.q_mask)
    m_true = _true_sizes(packed.nn_mask)
    bs_ceils = bucket_ceilings(bs_true, n_buckets, bs_mult)
    m_ceils = bucket_ceilings(m_true, n_buckets, m_mult)

    buckets, ranks = [], []
    for bs_c, m_c, idx in _group(bs_true, m_true, bs_ceils, m_ceils):
        bs_c = min(bs_c, packed.bs_pred)
        m_c = min(m_c, packed.m_pred)
        buckets.append(PackedPrediction(
            q_x=packed.q_x[idx, :bs_c],
            q_mask=packed.q_mask[idx, :bs_c],
            q_idx=packed.q_idx[idx, :bs_c],
            nn_x=packed.nn_x[idx, :m_c],
            nn_y=packed.nn_y[idx, :m_c],
            nn_mask=packed.nn_mask[idx, :m_c],
            owners=packed.owners[idx],
        ))
        ranks.append(idx)
    return BucketedPrediction(buckets=buckets, ranks=ranks)
