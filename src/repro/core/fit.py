"""MLE parameter estimation for SBV (paper Alg. 1 outer loop).

The paper optimizes the likelihood with derivative-free NLopt (BOBYQA).
The JAX build gets an *analytic gradient* through the whole batched
likelihood (beyond-paper improvement — typically 5-20x fewer iterations),
with the paper's scheme available as ``method='neldermead'`` for parity.

Scaled-Vecchia alternation: the block/neighbor structure is built with the
current beta estimate and refreshed every ``rescale_every`` outer rounds
(Katzfuss et al. 2022 do the same; structure refresh is the one step that
cannot be differentiated through).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam_init, adam_update

from .kernels_math import KernelParams
from .pipeline import SBVConfig, preprocess
from .vecchia import packed_loglik


@dataclass
class FitResult:
    params: KernelParams
    history: list = field(default_factory=list)  # (outer, inner, -loglik/n)
    packed: object = None
    stream_stats: dict | None = None  # set by the streaming (out-of-core) path


def neg_loglik_fn(packed, nu: float, backend: str):
    n = packed.n_points

    def f(params):
        return -packed_loglik(params, packed, nu=nu, backend=backend) / n

    return f


_MAP_BATCH = 16  # blocks vmapped per lax.map step of the streaming grad


def _chunk_grad_fn(nu: float, backend: str, n_points: int):
    """jitted value_and_grad of one packed chunk's -loglik/n contribution.

    All chunks of a structure round share one padded shape (see
    ``_fit_sbv_streaming``), so this compiles once per round.

    Device residency is the streaming fit's real memory ceiling: a
    vmapped value_and_grad over the whole chunk materializes O(10)
    buffers of (bc_chunk, bs+m, bs+m) during the backward pass — ~1GB at
    a 32k-row chunk — so the 'ref' path runs the CHECKPOINTED
    joint-assembly block likelihood under ``lax.map`` in ``_MAP_BATCH``-
    block steps: residuals per step are just the block inputs, recompute
    happens one mini-batch at a time, and the live set stays at a few
    ``_MAP_BATCH x (bs+m)^2`` buffers however large the chunk is."""
    from .vecchia import _block_loglik_joint_one

    def f(params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask):
        if backend == "ref":
            body = jax.checkpoint(
                lambda a: _block_loglik_joint_one(params, nu, *a)
            )
            per_block = jax.lax.map(
                body, (blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask),
                batch_size=_MAP_BATCH,
            )
            ll = jnp.sum(per_block)
        else:
            from repro.kernels import ops as kops

            ll = kops.sbv_loglik(params, blk_x, blk_y, blk_mask,
                                 nn_x, nn_y, nn_mask, nu=nu)
        return -ll / n_points

    return jax.jit(jax.value_and_grad(f))


def _fit_sbv_streaming(
    store, cfg, init, nu, lr, inner_steps, outer_rounds, backend, verbose,
    stream_chunk, n_buckets, spool_dir,
):
    """Out-of-core fit: every pass holds ~``stream_chunk`` data rows.

    Per outer round: streaming structure (mini-batch k-means + store-backed
    filtered NNS), then the rank-ordered blocks are packed into
    ``stream_chunk``-row chunks (gather-and-remap from the store), padded
    to ONE shared shape, and spooled to disk. Each inner step accumulates
    value+grad over the spooled chunks — the likelihood is a sum over
    blocks, so chunked accumulation differs from the monolithic in-core
    program only in float summation order (pinned <= 1e-10 in
    tests/test_streaming.py).
    """
    import shutil
    import tempfile

    from repro.data.streaming import (
        pack_block_chunk, PackedChunkSpool, streaming_moments,
        streaming_preprocess,
    )

    from .packing import round_up

    if backend == "auto":
        raise ValueError(
            "backend='auto' resolves per packed shape; pass 'ref' or "
            "'pallas' explicitly for the streaming fit"
        )
    n = store.n_rows
    d = store.d
    if init is None:
        _, var_y = streaming_moments(store)
        params = KernelParams.create(sigma2=var_y, beta=0.5, nugget=1e-3, d=d)
    else:
        params = init
    history = []
    stats = {"n_chunks": 0, "n_pieces": 0, "packed_chunk_bytes_max": 0,
             "spool_bytes": 0, "bs_max": 0, "bc": 0}

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        struct = streaming_preprocess(store, beta_np, cfg, stream_chunk)
        bc_pad = max(len(r) for r in struct.plan)

        if n_buckets:
            # GLOBAL bucket ceilings + per-cell bc padding: every chunk's
            # pieces land on one of <= occupied-cells shapes, so the
            # round compiles a bounded program set (per-chunk ceilings
            # would compile — and grow the XLA arena — per chunk).
            from .buckets import _group, bucket_ceilings

            bs_true = np.asarray(
                [struct.blocks.members[b].size for b in struct.blocks.order])
            m_true = np.asarray(
                [min(len(struct.neigh[b]), cfg.m) for b in struct.blocks.order])
            bs_ceils = bucket_ceilings(bs_true, n_buckets, 8)
            m_ceils = bucket_ceilings(m_true, n_buckets, 8)
            cell_bc: dict = {}
            for ranks in struct.plan:
                for bs_c, m_c, idx in _group(bs_true[ranks], m_true[ranks],
                                             bs_ceils, m_ceils):
                    # Same clamp bucket_blocks applies to piece shapes.
                    key = (min(bs_c, struct.bs_max), min(m_c, cfg.m))
                    cell_bc[key] = max(cell_bc.get(key, 0), round_up(idx.size, 8))

        work_dir = spool_dir or tempfile.mkdtemp(prefix="sbv-spool-")
        spool = PackedChunkSpool(os.path.join(work_dir, f"round{outer}"))
        try:
            for ranks in struct.plan:
                packed = pack_block_chunk(
                    store, struct.blocks, struct.neigh, ranks,
                    m=cfg.m, bs_max=struct.bs_max, dtype=cfg.dtype,
                )
                if n_buckets:
                    from .buckets import bucket_blocks

                    bucketed = bucket_blocks(packed, ceilings=(bs_ceils, m_ceils))
                    groups = _group(bs_true[ranks], m_true[ranks],
                                    bs_ceils, m_ceils)
                    pieces = [
                        p.pad_to_blocks(cell_bc[(min(bs_c, packed.bs_max),
                                                 min(m_c, packed.m))])
                        for (bs_c, m_c, _), p in zip(groups, bucketed.buckets)
                    ]
                else:
                    pieces = [packed.pad_to_blocks(bc_pad)]
                for p in pieces:
                    spool.add(p)
            stats.update(
                n_chunks=len(struct.plan), n_pieces=len(spool),
                packed_chunk_bytes_max=max(stats["packed_chunk_bytes_max"],
                                           spool.packed_bytes_max),
                spool_bytes=max(stats["spool_bytes"], spool.packed_bytes_total),
                bs_max=struct.bs_max, bc=struct.blocks.n_blocks,
            )

            grad_fn = _chunk_grad_fn(nu, backend, n)
            state = adam_init(params)
            for it in range(inner_steps):
                loss = None
                grad = None
                for piece in spool:
                    v, g = grad_fn(
                        params,
                        jnp.asarray(piece.blk_x), jnp.asarray(piece.blk_y),
                        jnp.asarray(piece.blk_mask), jnp.asarray(piece.nn_x),
                        jnp.asarray(piece.nn_y), jnp.asarray(piece.nn_mask),
                    )
                    loss = v if loss is None else loss + v
                    grad = g if grad is None else jax.tree.map(jnp.add, grad, g)
                params, state = adam_update(grad, state, params, lr)
                history.append((outer, it, float(loss)))
                if verbose and it % 10 == 0:
                    print(f"[fit-stream] outer={outer} it={it} "
                          f"nll/n={float(loss):.6f} pieces={len(spool)}")
        finally:
            spool.cleanup()
            if spool_dir is None:
                shutil.rmtree(work_dir, ignore_errors=True)
    return FitResult(params=params, history=history, packed=None,
                     stream_stats=stats)


def fit_sbv(
    x: np.ndarray,
    y: np.ndarray = None,
    cfg: SBVConfig = None,
    init: KernelParams | None = None,
    nu: float = 3.5,
    lr: float = 0.05,
    inner_steps: int = 60,
    outer_rounds: int = 3,
    backend: str = "ref",
    verbose: bool = False,
    distributed=None,   # optional (mesh, axis) for shard_map likelihood
    n_buckets: int | None = None,
    stream_chunk: int | None = None,
    spool_dir: str | None = None,
) -> FitResult:
    """Maximum-likelihood fit of (sigma^2, beta, nugget) with fixed nu.

    ``n_buckets`` runs the likelihood on the bucketed layout
    (docs/packing.md). Each Scaled-Vecchia structure refresh re-clusters
    with the current beta, which reshapes the block-size distribution —
    so the packing is RE-bucketed every outer round, keeping bucket
    ceilings matched to the refreshed skew.

    Out-of-core: pass ``x`` as a row store (``repro.data.ArrayStore`` /
    ``MemoryStore``, with ``y=None``) and/or set ``stream_chunk`` to fit
    through the streaming path (docs/streaming.md) — structure, packing
    and likelihood all run in bounded ~``stream_chunk``-row passes. An
    in-core ``(x, y)`` with ``stream_chunk`` set takes the identical code
    path over a ``MemoryStore``, so store-backed and in-core streaming
    fits agree bitwise on the same rows. In-core arrays WITHOUT
    ``stream_chunk`` keep the original monolithic fast path."""
    from repro.data.store import as_store, is_store

    if cfg is None:
        raise TypeError("fit_sbv requires an SBVConfig")
    if is_store(x) or stream_chunk is not None:
        if distributed is not None:
            raise NotImplementedError(
                "streaming + distributed likelihood is not wired yet; "
                "fit in-core for multi-device runs (ROADMAP open item)"
            )
        from repro.data.streaming import DEFAULT_STRUCT_BATCH

        store = as_store(x, y)
        return _fit_sbv_streaming(
            store, cfg, init, nu, lr, inner_steps, outer_rounds, backend,
            verbose, stream_chunk or DEFAULT_STRUCT_BATCH, n_buckets, spool_dir,
        )
    d = x.shape[1]
    params = init or KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3, d=d)
    history = []
    packed = None

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        packed, _ = preprocess(x, y, beta_np, cfg)
        if n_buckets:
            from .buckets import bucket_blocks

            packed = bucket_blocks(packed, n_buckets=n_buckets)
        if distributed is not None:
            from .distributed import distributed_neg_loglik_fn

            loss_fn = distributed_neg_loglik_fn(packed, nu, *distributed)
        else:
            loss_fn = jax.jit(neg_loglik_fn(packed, nu, backend))
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))

        state = adam_init(params)
        for it in range(inner_steps):
            loss, g = grad_fn(params)
            params, state = adam_update(g, state, params, lr)
            history.append((outer, it, float(loss)))
            if verbose and it % 10 == 0:
                print(f"[fit] outer={outer} it={it} nll/n={float(loss):.6f}")
    return FitResult(params=params, history=history, packed=packed)


def fit_neldermead(
    x, y, cfg: SBVConfig, init: KernelParams | None = None,
    nu: float = 3.5, maxiter: int = 400, backend: str = "ref",
) -> FitResult:
    """Derivative-free MLE (paper-faithful optimizer path, via scipy)."""
    from scipy.optimize import minimize

    d = x.shape[1]
    params = init or KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3, d=d)
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
    loss = jax.jit(neg_loglik_fn(packed, nu, backend))

    def unpack(v):
        return KernelParams(
            log_sigma2=jnp.asarray(v[0]), log_beta=jnp.asarray(v[1 : 1 + d]),
            log_nugget=jnp.asarray(v[1 + d]),
        )

    v0 = np.concatenate([[float(params.log_sigma2)], np.asarray(params.log_beta), [float(params.log_nugget)]])
    res = minimize(lambda v: float(loss(unpack(v))), v0, method="Nelder-Mead",
                   options={"maxiter": maxiter, "xatol": 1e-4, "fatol": 1e-7})
    return FitResult(params=unpack(res.x), history=[(0, res.nit, float(res.fun))], packed=packed)
