"""MLE parameter estimation for SBV (paper Alg. 1 outer loop).

The paper optimizes the likelihood with derivative-free NLopt (BOBYQA).
The JAX build gets an *analytic gradient* through the whole batched
likelihood (beyond-paper improvement — typically 5-20x fewer iterations),
with the paper's scheme available as ``method='neldermead'`` for parity.

Scaled-Vecchia alternation: the block/neighbor structure is built with the
current beta estimate and refreshed every ``rescale_every`` outer rounds
(Katzfuss et al. 2022 do the same; structure refresh is the one step that
cannot be differentiated through).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam_init, adam_update

from .kernels_math import KernelParams
from .pipeline import SBVConfig, preprocess
from .vecchia import packed_loglik


@dataclass
class FitResult:
    params: KernelParams
    history: list = field(default_factory=list)  # (outer, inner, -loglik/n)
    packed: object = None


def neg_loglik_fn(packed, nu: float, backend: str):
    n = packed.n_points

    def f(params):
        return -packed_loglik(params, packed, nu=nu, backend=backend) / n

    return f


def fit_sbv(
    x: np.ndarray,
    y: np.ndarray,
    cfg: SBVConfig,
    init: KernelParams | None = None,
    nu: float = 3.5,
    lr: float = 0.05,
    inner_steps: int = 60,
    outer_rounds: int = 3,
    backend: str = "ref",
    verbose: bool = False,
    distributed=None,   # optional (mesh, axis) for shard_map likelihood
    n_buckets: int | None = None,
) -> FitResult:
    """Maximum-likelihood fit of (sigma^2, beta, nugget) with fixed nu.

    ``n_buckets`` runs the likelihood on the bucketed layout
    (docs/packing.md). Each Scaled-Vecchia structure refresh re-clusters
    with the current beta, which reshapes the block-size distribution —
    so the packing is RE-bucketed every outer round, keeping bucket
    ceilings matched to the refreshed skew."""
    d = x.shape[1]
    params = init or KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3, d=d)
    history = []
    packed = None

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        packed, _ = preprocess(x, y, beta_np, cfg)
        if n_buckets:
            from .buckets import bucket_blocks

            packed = bucket_blocks(packed, n_buckets=n_buckets)
        if distributed is not None:
            from .distributed import distributed_neg_loglik_fn

            loss_fn = distributed_neg_loglik_fn(packed, nu, *distributed)
        else:
            loss_fn = jax.jit(neg_loglik_fn(packed, nu, backend))
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))

        state = adam_init(params)
        for it in range(inner_steps):
            loss, g = grad_fn(params)
            params, state = adam_update(g, state, params, lr)
            history.append((outer, it, float(loss)))
            if verbose and it % 10 == 0:
                print(f"[fit] outer={outer} it={it} nll/n={float(loss):.6f}")
    return FitResult(params=params, history=history, packed=packed)


def fit_neldermead(
    x, y, cfg: SBVConfig, init: KernelParams | None = None,
    nu: float = 3.5, maxiter: int = 400, backend: str = "ref",
) -> FitResult:
    """Derivative-free MLE (paper-faithful optimizer path, via scipy)."""
    from scipy.optimize import minimize

    d = x.shape[1]
    params = init or KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3, d=d)
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
    loss = jax.jit(neg_loglik_fn(packed, nu, backend))

    def unpack(v):
        return KernelParams(
            log_sigma2=jnp.asarray(v[0]), log_beta=jnp.asarray(v[1 : 1 + d]),
            log_nugget=jnp.asarray(v[1 + d]),
        )

    v0 = np.concatenate([[float(params.log_sigma2)], np.asarray(params.log_beta), [float(params.log_nugget)]])
    res = minimize(lambda v: float(loss(unpack(v))), v0, method="Nelder-Mead",
                   options={"maxiter": maxiter, "xatol": 1e-4, "fatol": 1e-7})
    return FitResult(params=unpack(res.x), history=[(0, res.nit, float(res.fun))], packed=packed)
