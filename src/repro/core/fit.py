"""MLE parameter estimation for SBV (paper Alg. 1 outer loop).

The paper optimizes the likelihood with derivative-free NLopt (BOBYQA).
The JAX build gets an *analytic gradient* through the whole batched
likelihood (beyond-paper improvement — typically 5-20x fewer iterations),
with the paper's scheme available as ``method='neldermead'`` for parity.

Scaled-Vecchia alternation: the block/neighbor structure is built with the
current beta estimate and refreshed every ``rescale_every`` outer rounds
(Katzfuss et al. 2022 do the same; structure refresh is the one step that
cannot be differentiated through).
"""
from __future__ import annotations

import functools
import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam_init, adam_update

from .kernels_math import KernelParams
from .pipeline import SBVConfig, preprocess
from .vecchia import packed_loglik


@dataclass
class FitResult:
    params: KernelParams  # or MultiOutputParams (multi-output fits)
    history: list = field(default_factory=list)  # (outer, inner, -loglik/n)
    packed: object = None
    stream_stats: dict | None = None  # set by the streaming (out-of-core) path
    precision_tiers: list | None = None  # per-bucket ladder tiers (last round)


def neg_loglik_fn(packed, nu: float, backend: str):
    n = packed.n_points

    def f(params):
        return -packed_loglik(params, packed, nu=nu, backend=backend) / n

    return f


_MAP_BATCH = 16  # blocks vmapped per lax.map step of the streaming grad


def _chunk_loglik(nu: float, backend: str):
    """Total loglik of one packed chunk — the body shared by the serial
    and the shard_map'd streaming gradients.

    Device residency is the streaming fit's real memory ceiling: a
    vmapped value_and_grad over the whole chunk materializes O(10)
    buffers of (bc_chunk, bs+m, bs+m) during the backward pass — ~1GB at
    a 32k-row chunk — so the 'ref' path runs the CHECKPOINTED
    joint-assembly block likelihood under ``lax.map`` in ``_MAP_BATCH``-
    block steps: residuals per step are just the block inputs, recompute
    happens one mini-batch at a time, and the live set stays at a few
    ``_MAP_BATCH x (bs+m)^2`` buffers however large the chunk is."""
    from .vecchia import _block_loglik_joint_one

    def ll(params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask):
        if backend == "ref":
            from .kernels_math import cast_params

            # Precision ladder: the piece's observation dtype is its
            # accumulation dtype (docs/precision.md); a no-op for the
            # default f64 spool layout.
            p = cast_params(params, jnp.asarray(blk_y).dtype)
            body = jax.checkpoint(
                lambda a: _block_loglik_joint_one(p, nu, *a)
            )
            per_block = jax.lax.map(
                body, (blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask),
                batch_size=_MAP_BATCH,
            )
            return jnp.sum(per_block)
        from repro.kernels import ops as kops

        return kops.sbv_loglik(params, blk_x, blk_y, blk_mask,
                               nn_x, nn_y, nn_mask, nu=nu)

    return ll


@functools.lru_cache(maxsize=64)
def _chunk_grad_fn(nu: float, backend: str, n_points: int, mesh=None,
                   axis: str | None = None):
    """jitted value_and_grad of one packed chunk's -loglik/n contribution.

    CACHED on (nu, backend, n, mesh, axis) — the structure refresh of a
    new outer round usually lands on the identical padded shapes, and a
    fresh ``jax.jit`` wrapper would discard the compiled executable even
    then. With the wrapper cached, per-shape compilation caching is
    jit's own (one compile per piece shape across ALL rounds and fits).
    The key includes the dataset size, so the cache is BOUNDED (a
    long-lived process sweeping many dataset sizes would otherwise pin a
    wrapper + executables per size forever); eviction just recompiles.

    With ``mesh``/``axis``, the chunk's block axis is shard_map'd over
    the mesh and the per-shard loglik is ``psum``'d before the global
    ``-ll/n`` — O(1) scalars of communication per chunk per step, the
    paper's Alg. 1 property — and the returned gradient is replicated,
    so chunked accumulation proceeds exactly as in the serial loop. Pass
    arrays already placed with ``NamedSharding(mesh, P(axis))`` on the
    leading (block) axis (the spool's device tier and H2D stage both
    do)."""
    ll = _chunk_loglik(nu, backend)
    if mesh is None:
        def f(params, *arrs):
            return -ll(params, *arrs) / n_points

        return jax.jit(jax.value_and_grad(f))

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = P(axis)

    def local(params, *arrs):
        return jax.lax.psum(ll(params, *arrs), axis)

    fn = shard_map(
        local, mesh=mesh, in_specs=(P(),) + (spec,) * 6, out_specs=P(),
        # pallas_call has no replication rule (same caveat as the
        # prediction shard_map); the psum output is replicated anyway
        check_rep=backend == "ref",
    )

    def f(params, *arrs):
        return -fn(params, *arrs) / n_points

    return jax.jit(jax.value_and_grad(f))


def _fit_sbv_multi(
    x, y, cfg, init, nu, lr, inner_steps, outer_rounds, backend, verbose,
    n_buckets, precision=None,
):
    """Monolithic multi-output fit (docs/multioutput.md).

    One structure pass per outer round shared by all p outputs; Adam
    minimizes the pooled profile likelihood over (log_beta, log_tau2)
    through the shared-Cholesky stats; per-output sigma2 are profiled in
    closed form at the end (their gradient in the pooled objective is
    identically zero, so they simply ride along in the pytree).

    ``precision`` applies the ladder tier CAST-ONLY (docs/precision.md):
    ``cast_packed`` narrows coordinates to the tier's storage dtype and
    the (bc, bs, p) observation columns to its accumulation dtype — the
    multi-RHS layout rides the same dtype fields, and the stats kernels
    already cast params to the data's accumulation dtype. The per-bucket
    nll probe is single-output-only, so ``probe`` is ignored here;
    budget enforcement is the tier's documented bound."""
    from .multioutput import (
        as_multi_params, MultiOutputParams, multi_profile_neg_loglik_fn,
        with_profiled_sigma2,
    )

    d = x.shape[1]
    p = y.shape[1]
    if init is None:
        params = MultiOutputParams.create(
            sigma2=np.maximum(np.var(y, axis=0), 1e-12), beta=0.5, tau2=1e-3,
            d=d, p=p,
        )
    else:
        params = as_multi_params(init, p, d)
    history = []
    packed = None
    tier = None
    if precision is not None:
        from .buckets import as_policy

        pol = as_policy(precision)
        if pol.tier != "f64":
            tier = pol.tier

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        packed, _ = preprocess(x, y, beta_np, cfg)
        if n_buckets:
            from .buckets import bucket_blocks

            packed = bucket_blocks(packed, n_buckets=n_buckets)
        if tier:
            from .buckets import apply_precision, BucketedBlocks, cast_packed

            packed = (apply_precision(packed, tier)
                      if isinstance(packed, BucketedBlocks)
                      else cast_packed(packed, tier))
        grad_fn = jax.jit(jax.value_and_grad(
            multi_profile_neg_loglik_fn(packed, nu, backend)))

        state = adam_init(params)
        for it in range(inner_steps):
            loss, g = grad_fn(params)
            params, state = adam_update(g, state, params, lr)
            history.append((outer, it, float(loss)))
            if verbose and it % 10 == 0:
                print(f"[fit-multi] outer={outer} it={it} "
                      f"nll/np={float(loss):.6f} p={p}")
    params = with_profiled_sigma2(params, packed, nu=nu, backend=backend)
    return FitResult(params=params, history=history, packed=packed)


@functools.lru_cache(maxsize=64)
def _multi_stats_chunk_fn(nu: float, backend: str):
    """jitted (params, *arrs) -> (logdet0, q0) of one spooled chunk.

    Ref backend mirrors ``_chunk_loglik``'s memory ceiling: the
    checkpointed per-block stats run under ``lax.map`` in _MAP_BATCH
    steps, so the live set never scales with the chunk block count."""
    from .multioutput import _block_multi_stats_one

    def f(params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask):
        from .kernels_math import cast_params

        p0 = cast_params(params.structure_params(), jnp.asarray(blk_y).dtype)
        if backend == "ref":
            body = jax.checkpoint(lambda a: _block_multi_stats_one(p0, nu, *a))
            ld, q = jax.lax.map(
                body, (blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask),
                batch_size=_MAP_BATCH,
            )
            return jnp.sum(ld), jnp.sum(q, axis=0)
        from repro.kernels import ops as kops

        return kops.sbv_multi_stats(p0, blk_x, blk_y, blk_mask,
                                    nn_x, nn_y, nn_mask, nu=nu)

    return jax.jit(f)


@functools.lru_cache(maxsize=64)
def _multi_wgrad_chunk_fn(nu: float, backend: str, n_points: int, p: int):
    """jitted grad of one chunk's weighted-stats scalar.

    The pooled profile objective takes logs of GLOBAL sums, so chunked
    accumulation is two passes per step: pass A sums (logdet0, q0) values
    over the chunks; pass B accumulates the gradient of
    ``(p*ld_c/2 + n/2 * sum_j q_cj / Q_j) / (n*p)`` with the weights
    1/Q_j frozen at pass A's totals — by the chain rule the sum over
    chunks is the EXACT gradient of the pooled objective."""
    stats = _multi_stats_chunk_fn(nu, backend)

    def f(params, w, *arrs):
        ld_c, q_c = stats(params, *arrs)
        s = 0.5 * p * ld_c + 0.5 * n_points * jnp.sum(w * q_c)
        return s / (n_points * p)

    return jax.jit(jax.grad(f))


def _fit_sbv_multi_streaming(
    store, cfg, init, nu, lr, inner_steps, outer_rounds, backend, verbose,
    stream_chunk, spool_dir, device_cache=None, prefetch: int = 2,
    precision=None,
):
    """Out-of-core multi-output fit: ``_fit_sbv_streaming``'s spool plan
    with the two-pass chunk accumulation of ``_multi_wgrad_chunk_fn``.
    Every pass holds ~stream_chunk data rows; blk_y/nn_y spool with their
    (…, p) output axis through the same npz tiers. ``precision`` is
    UNIFORM cast-only like the single-output streaming fit: every chunk
    is ``cast_packed`` to the tier before spooling (no per-piece probe),
    so the spool and H2D stage carry the narrow layout."""
    import shutil
    import tempfile

    from repro.data.streaming import (
        device_cache_budget, pack_block_chunk, PackedChunkSpool,
        streaming_preprocess,
    )

    from .multioutput import (
        as_multi_params, MultiOutputParams, pooled_objective, profile_sigma2,
    )

    n = store.n_rows
    d = store.d
    y0 = np.asarray(store.read_slice(0, 1)[1])
    if y0.ndim != 2:
        raise ValueError("multi-output streaming fit needs (n, p) store rows")
    p = int(y0.shape[1])
    if init is None:
        params = MultiOutputParams.create(sigma2=1.0, beta=0.5, tau2=1e-3,
                                          d=d, p=p)
    else:
        params = as_multi_params(init, p, d)
    tier = None
    if precision is not None:
        from .buckets import as_policy

        pol = as_policy(precision)
        if pol.tier != "f64":
            tier = pol.tier
    history = []
    stats = {"n_chunks": 0, "n_pieces": 0, "packed_chunk_bytes_max": 0,
             "spool_bytes": 0, "bs_max": 0, "bc": 0, "n_shards": 1,
             "n_outputs": p, "inner_steps_total": 0, "inner_time_s": 0.0,
             "precision": tier or "f64"}
    final_q = None

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        struct = streaming_preprocess(store, beta_np, cfg, stream_chunk)
        bc_pad = max(len(r) for r in struct.plan)

        if device_cache is None:
            acc_bytes = int(np.dtype(cfg.dtype).itemsize)
            reserve = 16 * _MAP_BATCH * (struct.bs_max + cfg.m) ** 2 * acc_bytes
            budget = device_cache_budget(reserve_bytes=reserve)
        else:
            budget = int(device_cache)
        work_dir = spool_dir or tempfile.mkdtemp(prefix="sbv-spool-")
        spool = PackedChunkSpool(os.path.join(work_dir, f"round{outer}"),
                                 device_budget=budget)
        try:
            for ranks in struct.plan:
                packed = pack_block_chunk(
                    store, struct.blocks, struct.neigh, ranks,
                    m=cfg.m, bs_max=struct.bs_max, dtype=cfg.dtype,
                )
                if tier:
                    from .buckets import cast_packed

                    packed = cast_packed(packed, tier)
                spool.add(packed.pad_to_blocks(bc_pad),
                          tag=_piece_backend(backend, packed))
            stats.update(
                n_chunks=len(struct.plan), n_pieces=len(spool),
                packed_chunk_bytes_max=max(stats["packed_chunk_bytes_max"],
                                           spool.packed_bytes_max),
                spool_bytes=max(stats["spool_bytes"], spool.packed_bytes_total),
                bs_max=struct.bs_max, bc=struct.blocks.n_blocks,
            )

            def chunk_stats(prms):
                ld = None
                q = None
                for arrs, tag in spool.iter_arrays(prefetch=prefetch):
                    ld_c, q_c = _multi_stats_chunk_fn(nu, tag)(prms, *arrs)
                    ld = ld_c if ld is None else ld + ld_c
                    q = q_c if q is None else q + q_c
                return ld, q

            state = adam_init(params)
            t_inner = time.perf_counter()
            for it in range(inner_steps):
                ld, q = chunk_stats(params)
                loss = pooled_objective(ld, q, n)
                w = 1.0 / jnp.maximum(q, 1e-300)
                grad = None
                for arrs, tag in spool.iter_arrays(prefetch=prefetch):
                    g = _multi_wgrad_chunk_fn(nu, tag, n, p)(params, w, *arrs)
                    grad = g if grad is None else jax.tree.map(jnp.add, grad, g)
                params, state = adam_update(grad, state, params, lr)
                history.append((outer, it, float(loss)))
                if verbose and it % 10 == 0:
                    print(f"[fit-multi-stream] outer={outer} it={it} "
                          f"nll/np={float(loss):.6f} pieces={len(spool)}")
            # Profile the per-output scales at the ROUND-FINAL params (one
            # extra values pass; the last round's result is the fit's).
            _, final_q = chunk_stats(params)
            stats["inner_time_s"] += time.perf_counter() - t_inner
            stats["inner_steps_total"] += inner_steps
        finally:
            spool.cleanup()
            if spool_dir is None:
                shutil.rmtree(work_dir, ignore_errors=True)
    s2 = jnp.maximum(
        profile_sigma2(jnp.asarray(final_q, jnp.float64), n), 1e-300)
    params = params._replace(log_sigma2=jnp.log(s2))
    return FitResult(params=params, history=history, packed=None,
                     stream_stats=stats)


def _piece_backend(backend: str, piece) -> str:
    """Resolve ``backend='auto'`` per spooled piece shape, exactly like the
    bucketed in-core path (``kernels.ops.select_backend``)."""
    if backend != "auto":
        return backend
    from repro.kernels import ops as kops

    return kops.select_backend(piece.bs_max, piece.m, kind="loglik",
                               dtype=piece.blk_x.dtype)


def _fit_sbv_streaming(
    store, cfg, init, nu, lr, inner_steps, outer_rounds, backend, verbose,
    stream_chunk, n_buckets, spool_dir, distributed=None,
    device_cache: int | None = None, prefetch: int = 2, multihost=None,
    precision=None,
):
    """Out-of-core fit: every pass holds ~``stream_chunk`` data rows.

    Per outer round: streaming structure (mini-batch k-means + store-backed
    filtered NNS), then the rank-ordered blocks are packed into
    ``stream_chunk``-row chunks (gather-and-remap from the store), padded
    to ONE shared shape, and handed to the two-tier ``PackedChunkSpool``.
    Each inner step accumulates value+grad over the pieces IN SPOOL
    ORDER — the likelihood is a sum over blocks, so chunked accumulation
    differs from the monolithic in-core program only in float summation
    order (pinned <= 1e-10 in tests/test_streaming.py), and the memory
    tier a piece lives in (HBM cache / prefetched H2D / cold disk)
    changes nothing bitwise.

    ``device_cache``: bytes of HBM for the device-resident tier — pieces
    within the budget are transferred once per round instead of once per
    step. ``None`` sizes it automatically from free device memory minus
    the gradient's live-set reserve; ``0`` disables (every piece re-reads
    from disk, the pre-tier behavior). ``prefetch``: disk-tier pieces
    staged ahead on a producer thread (0 = synchronous reads).

    ``distributed=(mesh, axis)`` shards every piece's block axis over the
    mesh (owner-contiguous, masked padding to the shard count) and runs
    the chunk gradient under ``shard_map`` with a scalar ``psum`` — the
    streaming twin of the in-core distributed likelihood. The block
    reorder changes only the summation order vs. the serial streaming
    fit (<= 1e-8 over an optimization run).

    ``multihost`` (a ``repro.multihost`` host comm) runs the
    MULTI-PROCESS mode: this process constructs, packs, and spools only
    its own partition (``multihost_preprocess`` over a
    ``PartitionedStore``), and each inner step walks the hosts' pieces in
    lockstep with one all-reduce of ``[loss, grad]`` per chunk per step —
    the same O(1)-scalars-per-chunk comms contract as the in-process
    ``distributed`` path, so optimizer state stays replicated and every
    host finishes with identical parameters. With a ``LoopbackComm`` the
    mode is bitwise the serial streaming fit; across P processes it
    differs only in float summation order (<= 1e-8, like chunking).
    """
    import shutil
    import tempfile

    from repro.data.streaming import (
        device_cache_budget, pack_block_chunk, PackedChunkSpool,
        streaming_moments, streaming_preprocess,
    )

    from .packing import round_up

    if multihost is not None:
        if distributed is not None:
            raise ValueError("multihost and in-process distributed= are "
                             "mutually exclusive (one device per host)")
        if n_buckets:
            raise NotImplementedError("bucketed piece shapes are not wired "
                                      "into the multihost mode yet")
        return _fit_sbv_multihost(
            store, cfg, init, nu, lr, inner_steps, outer_rounds, backend,
            verbose, stream_chunk, spool_dir, multihost,
            device_cache=device_cache, prefetch=prefetch, precision=precision,
        )

    # Streaming precision is UNIFORM (no per-piece probing: the probe's
    # f64 reference would double every round's disk traffic); pieces are
    # cast to the policy tier before spooling, so the spool, the H2D
    # stage, and the device cache all carry the narrow layout.
    tier = None
    if precision is not None:
        from .buckets import as_policy

        pol = as_policy(precision)
        if pol.tier != "f64":
            tier = pol.tier

    mesh = axis = sharding = None
    n_shards = 1
    if distributed is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from .distributed import shard_blocks_by_owner

        mesh, axis = distributed
        n_shards = int(np.prod([mesh.shape[a] for a in
                                (axis if isinstance(axis, tuple) else (axis,))]))
        sharding = NamedSharding(mesh, P(axis))
    n = store.n_rows
    d = store.d
    if init is None:
        _, var_y = streaming_moments(store)
        params = KernelParams.create(sigma2=var_y, beta=0.5, nugget=1e-3, d=d)
    else:
        params = init
    history = []
    stats = {"n_chunks": 0, "n_pieces": 0, "packed_chunk_bytes_max": 0,
             "spool_bytes": 0, "bs_max": 0, "bc": 0, "n_shards": n_shards,
             "device_cached_pieces": 0, "device_cached_bytes": 0,
             "h2d_bytes_per_step": 0, "inner_steps_total": 0,
             "inner_time_s": 0.0, "precision": tier or "f64",
             "device_cache_budget": 0}

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        struct = streaming_preprocess(store, beta_np, cfg, stream_chunk)
        bc_pad = max(len(r) for r in struct.plan)
        if n_shards > 1:
            # every piece's block count must divide the shard count; pad
            # the SHARED shape so all pieces still hit one compiled program
            bc_pad = round_up(bc_pad, n_shards)

        if n_buckets:
            # GLOBAL bucket ceilings + per-cell bc padding: every chunk's
            # pieces land on one of <= occupied-cells shapes, so the
            # round compiles a bounded program set (per-chunk ceilings
            # would compile — and grow the XLA arena — per chunk).
            from .buckets import _group, bucket_ceilings

            bs_true = np.asarray(
                [struct.blocks.members[b].size for b in struct.blocks.order])
            m_true = np.asarray(
                [min(len(struct.neigh[b]), cfg.m) for b in struct.blocks.order])
            bs_ceils = bucket_ceilings(bs_true, n_buckets, 8)
            m_ceils = bucket_ceilings(m_true, n_buckets, 8)
            cell_bc: dict = {}
            for ranks in struct.plan:
                for bs_c, m_c, idx in _group(bs_true[ranks], m_true[ranks],
                                             bs_ceils, m_ceils):
                    # Same clamp bucket_blocks applies to piece shapes.
                    key = (min(bs_c, struct.bs_max), min(m_c, cfg.m))
                    cell_bc[key] = max(cell_bc.get(key, 0), round_up(idx.size, 8))
            if n_shards > 1:
                cell_bc = {k: round_up(v, n_shards) for k, v in cell_bc.items()}

        if device_cache is None:
            # Auto budget: free device memory minus the grad live-set
            # reserve (the working_set_model device_grad term). The
            # reserve is PRECISION-AWARE: reduced tiers accumulate in
            # f32, so the backward live set is half the f64 bytes — the
            # freed reserve goes straight to the device-resident cache.
            acc_bytes = 4 if tier else int(np.dtype(cfg.dtype).itemsize)
            reserve = 16 * _MAP_BATCH * (struct.bs_max + cfg.m) ** 2 * acc_bytes
            budget = device_cache_budget(reserve_bytes=reserve)
        else:
            budget = int(device_cache)
        stats["device_cache_budget"] = max(stats["device_cache_budget"], budget)
        work_dir = spool_dir or tempfile.mkdtemp(prefix="sbv-spool-")
        spool = PackedChunkSpool(os.path.join(work_dir, f"round{outer}"),
                                 device_budget=budget, sharding=sharding)
        try:
            for ranks in struct.plan:
                packed = pack_block_chunk(
                    store, struct.blocks, struct.neigh, ranks,
                    m=cfg.m, bs_max=struct.bs_max, dtype=cfg.dtype,
                )
                if n_buckets:
                    from .buckets import bucket_blocks

                    bucketed = bucket_blocks(packed, ceilings=(bs_ceils, m_ceils))
                    groups = _group(bs_true[ranks], m_true[ranks],
                                    bs_ceils, m_ceils)
                    pieces = [
                        p.pad_to_blocks(cell_bc[(min(bs_c, packed.bs_max),
                                                 min(m_c, packed.m))])
                        for (bs_c, m_c, _), p in zip(groups, bucketed.buckets)
                    ]
                else:
                    pieces = [packed.pad_to_blocks(bc_pad)]
                for p in pieces:
                    if tier:
                        from .buckets import cast_packed

                        p = cast_packed(p, tier)
                    if n_shards > 1:
                        # owner-contiguous reorder; bc already divides the
                        # shard count, so the shape is unchanged
                        p = shard_blocks_by_owner(p, n_shards)
                    spool.add(p, tag=_piece_backend(backend, p))
            stats.update(
                n_chunks=len(struct.plan), n_pieces=len(spool),
                packed_chunk_bytes_max=max(stats["packed_chunk_bytes_max"],
                                           spool.packed_bytes_max),
                spool_bytes=max(stats["spool_bytes"], spool.packed_bytes_total),
                bs_max=struct.bs_max, bc=struct.blocks.n_blocks,
                # last-round values, consistent with n_pieces/n_chunks ...
                device_cached_pieces=spool.n_device,
                h2d_bytes_per_step=spool.disk_bytes_total,
                # ... except the cached-bytes PEAK across rounds, which is
                # what the working_set_model RSS ceiling has to cover
                device_cached_bytes=max(stats["device_cached_bytes"],
                                        spool.device_bytes),
            )

            state = adam_init(params)
            t_inner = time.perf_counter()
            for it in range(inner_steps):
                loss = None
                grad = None
                for arrs, piece_backend in spool.iter_arrays(prefetch=prefetch):
                    grad_fn = _chunk_grad_fn(nu, piece_backend, n, mesh, axis)
                    v, g = grad_fn(params, *arrs)
                    loss = v if loss is None else loss + v
                    grad = g if grad is None else jax.tree.map(jnp.add, grad, g)
                params, state = adam_update(grad, state, params, lr)
                history.append((outer, it, float(loss)))
                if verbose and it % 10 == 0:
                    print(f"[fit-stream] outer={outer} it={it} "
                          f"nll/n={float(loss):.6f} pieces={len(spool)} "
                          f"(device-cached {spool.n_device})")
            stats["inner_time_s"] += time.perf_counter() - t_inner
            stats["inner_steps_total"] += inner_steps
        finally:
            spool.cleanup()
            if spool_dir is None:
                shutil.rmtree(work_dir, ignore_errors=True)
    return FitResult(params=params, history=history, packed=None,
                     stream_stats=stats)


def _fit_sbv_multihost(
    store, cfg, init, nu, lr, inner_steps, outer_rounds, backend, verbose,
    stream_chunk, spool_dir, comm, device_cache: int | None = None,
    prefetch: int = 2, precision=None,
):
    """Multi-process streaming fit: one `jax.distributed` host per
    partition, construction and packing per host, one `[loss, grad]`
    all-reduce per chunk per step (see `_fit_sbv_streaming`)."""
    import shutil
    import tempfile

    from jax.flatten_util import ravel_pytree

    from repro.data.store import PartitionedStore
    from repro.data.streaming import (
        device_cache_budget, multihost_preprocess, pack_block_chunk,
        PackedChunkSpool, streaming_moments,
    )

    pstore = (store if isinstance(store, PartitionedStore)
              else PartitionedStore(store, comm.size, comm.rank))
    tier = None
    if precision is not None:
        from .buckets import as_policy

        pol = as_policy(precision)
        if pol.tier != "f64":
            tier = pol.tier
    n, d = pstore.n_rows, pstore.d
    if init is None:
        _, var_y = streaming_moments(pstore, comm=comm)
        params = KernelParams.create(sigma2=var_y, beta=0.5, nugget=1e-3, d=d)
    else:
        params = init
    _, unravel = ravel_pytree(params)
    n_param = int(np.asarray(ravel_pytree(params)[0]).size)
    history = []
    stats = {"n_chunks": 0, "n_pieces": 0, "packed_chunk_bytes_max": 0,
             "spool_bytes": 0, "bs_max": 0, "bc": 0, "n_shards": 1,
             "device_cached_pieces": 0, "device_cached_bytes": 0,
             "h2d_bytes_per_step": 0, "inner_steps_total": 0,
             "inner_time_s": 0.0, "n_hosts": comm.size, "rank": comm.rank,
             "lockstep_chunks": 0, "allreduce_scalars_per_chunk": 1 + n_param,
             "precision": tier or "f64", "device_cache_budget": 0}

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        struct = multihost_preprocess(pstore, beta_np, cfg, stream_chunk, comm)
        # Pad every LOCAL piece to one shared shape; hosts may compile
        # different shapes — nothing cross-host depends on them (the
        # lockstep all-reduce carries only the [loss, grad] vector).
        bc_pad = max((len(r) for r in struct.plan), default=1)

        if device_cache is None:
            acc_bytes = 4 if tier else int(np.dtype(cfg.dtype).itemsize)
            reserve = 16 * _MAP_BATCH * (struct.bs_max + cfg.m) ** 2 * acc_bytes
            budget = device_cache_budget(reserve_bytes=reserve)
        else:
            budget = int(device_cache)
        stats["device_cache_budget"] = max(stats["device_cache_budget"], budget)
        work_dir = spool_dir or tempfile.mkdtemp(prefix="sbv-spool-")
        spool = PackedChunkSpool(
            os.path.join(work_dir, f"rank{comm.rank}-round{outer}"),
            device_budget=budget)
        try:
            for ranks in struct.plan:
                packed = pack_block_chunk(
                    struct.table, struct.blocks, struct.neigh, ranks,
                    m=cfg.m, bs_max=struct.bs_max, dtype=cfg.dtype,
                )
                piece = packed.pad_to_blocks(bc_pad)
                if tier:
                    from .buckets import cast_packed

                    piece = cast_packed(piece, tier)
                spool.add(piece, tag=_piece_backend(backend, piece))
            # Hosts iterate the SAME number of lockstep chunk slots per
            # step; hosts out of local pieces contribute zeros.
            n_lock = int(comm.allreduce_scalar(float(len(spool)), op="max"))
            stats.update(
                n_chunks=len(struct.plan), n_pieces=len(spool),
                packed_chunk_bytes_max=max(stats["packed_chunk_bytes_max"],
                                           spool.packed_bytes_max),
                spool_bytes=max(stats["spool_bytes"], spool.packed_bytes_total),
                bs_max=struct.bs_max, bc=struct.blocks.n_blocks,
                device_cached_pieces=spool.n_device,
                h2d_bytes_per_step=spool.disk_bytes_total,
                device_cached_bytes=max(stats["device_cached_bytes"],
                                        spool.device_bytes),
                lockstep_chunks=n_lock,
                **{k: v for k, v in struct.stats.items()},
            )

            state = adam_init(params)
            t_inner = time.perf_counter()
            zeros_vec = np.zeros(1 + n_param)
            for it in range(inner_steps):
                loss = 0.0
                gsum = np.zeros(n_param)
                pieces = spool.iter_arrays(prefetch=prefetch)
                for _ in range(n_lock):
                    entry = next(pieces, None)
                    if entry is not None:
                        arrs, piece_backend = entry
                        grad_fn = _chunk_grad_fn(nu, piece_backend, n)
                        v, g = grad_fn(params, *arrs)
                        gflat = np.asarray(ravel_pytree(g)[0], np.float64)
                        vec = np.concatenate([[float(v)], gflat])
                    else:
                        vec = zeros_vec
                    red = comm.allreduce(vec)
                    loss += float(red[0])
                    gsum = gsum + red[1:]
                grad = jax.tree.map(
                    jnp.asarray, unravel(jnp.asarray(gsum)))
                params, state = adam_update(grad, state, params, lr)
                history.append((outer, it, float(loss)))
                if verbose and it % 10 == 0:
                    print(f"[fit-mh] rank={comm.rank} outer={outer} it={it} "
                          f"nll/n={float(loss):.6f} "
                          f"pieces={len(spool)}/{n_lock}")
            stats["inner_time_s"] += time.perf_counter() - t_inner
            stats["inner_steps_total"] += inner_steps
        finally:
            spool.cleanup()
            if spool_dir is None:
                shutil.rmtree(work_dir, ignore_errors=True)
    return FitResult(params=params, history=history, packed=None,
                     stream_stats=stats)


def fit_sbv(
    x: np.ndarray,
    y: np.ndarray = None,
    cfg: SBVConfig = None,
    init: KernelParams | None = None,
    nu: float = 3.5,
    lr: float = 0.05,
    inner_steps: int = 60,
    outer_rounds: int = 3,
    backend: str = "ref",
    verbose: bool = False,
    distributed=None,   # optional (mesh, axis) for shard_map likelihood
    n_buckets: int | None = None,
    stream_chunk: int | None = None,
    spool_dir: str | None = None,
    device_cache: int | None = None,
    prefetch: int = 2,
    multihost=None,  # host comm (repro.multihost) for the multi-process fit
    precision=None,  # ladder tier name or core.buckets.PrecisionPolicy
    tuning=None,     # TuningRecord (or its directory/path) from repro.tuning
) -> FitResult:
    """Maximum-likelihood fit of (sigma^2, beta, nugget) with fixed nu.

    ``n_buckets`` runs the likelihood on the bucketed layout
    (docs/packing.md). Each Scaled-Vecchia structure refresh re-clusters
    with the current beta, which reshapes the block-size distribution —
    so the packing is RE-bucketed every outer round, keeping bucket
    ceilings matched to the refreshed skew.

    Out-of-core: pass ``x`` as a row store (``repro.data.ArrayStore`` /
    ``MemoryStore``, with ``y=None``) and/or set ``stream_chunk`` to fit
    through the streaming path (docs/streaming.md) — structure, packing
    and likelihood all run in bounded ~``stream_chunk``-row passes. An
    in-core ``(x, y)`` with ``stream_chunk`` set takes the identical code
    path over a ``MemoryStore``, so store-backed and in-core streaming
    fits agree bitwise on the same rows. In-core arrays WITHOUT
    ``stream_chunk`` keep the original monolithic fast path.
    ``device_cache`` (bytes; None = auto, 0 = off) and ``prefetch``
    control the streaming inner loop's memory tiers — see
    ``_fit_sbv_streaming`` and docs/streaming.md. ``distributed=`` works
    with BOTH paths: in-core it shards the monolithic packed likelihood;
    streaming it shards every spooled piece (the 2.56B-point scaling
    configuration). ``multihost=`` (a host comm from
    ``repro.multihost``) runs the MULTI-PROCESS streaming fit: each
    ``jax.distributed`` process builds, packs, and spools only its own
    row partition and the hosts all-reduce ``[loss, grad]`` once per
    chunk per step (docs/streaming.md "multi-host construction").

    ``precision`` selects the mixed-precision ladder (docs/precision.md):
    a tier name (``'bf16'``/``'f32'``/``'f64'``) or a
    ``core.buckets.PrecisionPolicy``. In-core fits probe each bucket
    against the f64 reference every structure refresh and demote
    over-budget buckets; streaming fits cast uniformly to the policy
    tier. ``tuning`` pre-loads an autotuned configuration (a
    ``repro.tuning.TuningRecord`` or a checkpoint directory holding one):
    it fills ``n_buckets``/``stream_chunk``/``precision`` when the caller
    left them unset, and ``backend`` when it is ``'auto'``."""
    from repro.data.store import as_store, is_store

    if cfg is None:
        raise TypeError("fit_sbv requires an SBVConfig")
    if tuning is not None:
        from repro.tuning import as_record

        rec = as_record(tuning)
        if n_buckets is None:
            n_buckets = rec.n_buckets
        if stream_chunk is None and rec.stream_chunk:
            stream_chunk = rec.stream_chunk
        if precision is None and rec.precision:
            precision = rec.precision_policy()
        if backend == "auto" and rec.backend:
            backend = rec.backend
    if multihost is not None and not (is_store(x) or stream_chunk is not None):
        raise ValueError("multihost= requires the streaming path: pass a "
                         "row store and/or set stream_chunk")

    # -- Multi-output routing (docs/multioutput.md). A 2-D y with p >= 2
    # takes the shared-structure VPPE path; (n, 1) squeezes to the
    # single-output program so p=1 stays BITWISE-identical to a 1-D y.
    if not is_store(x) and y is not None and np.asarray(y).ndim == 2:
        y2 = np.asarray(y)
        if y2.shape[1] == 1:
            from .multioutput import MultiOutputParams

            init1 = (init.output_params(0)
                     if isinstance(init, MultiOutputParams) else init)
            return fit_sbv(
                x, y2[:, 0], cfg, init=init1, nu=nu, lr=lr,
                inner_steps=inner_steps, outer_rounds=outer_rounds,
                backend=backend, verbose=verbose, distributed=distributed,
                n_buckets=n_buckets, stream_chunk=stream_chunk,
                spool_dir=spool_dir, device_cache=device_cache,
                prefetch=prefetch, multihost=multihost, precision=precision,
            )
        if multihost is not None or distributed is not None:
            raise NotImplementedError("multi-output fits do not support "
                                      "multihost=/distributed= yet")
        if stream_chunk is not None:
            if n_buckets:
                raise NotImplementedError("bucketed piece shapes are not "
                                          "wired into the multi-output "
                                          "streaming fit yet")
            return _fit_sbv_multi_streaming(
                as_store(x, y2), cfg, init, nu, lr, inner_steps, outer_rounds,
                backend, verbose, stream_chunk, spool_dir,
                device_cache=device_cache, prefetch=prefetch,
                precision=precision,
            )
        return _fit_sbv_multi(x, y2, cfg, init, nu, lr, inner_steps,
                              outer_rounds, backend, verbose, n_buckets,
                              precision=precision)
    if is_store(x) and np.asarray(as_store(x, y).read_slice(0, 1)[1]).ndim == 2:
        if multihost is not None or distributed is not None:
            raise NotImplementedError("multi-output fits do not support "
                                      "multihost=/distributed= yet")
        if n_buckets:
            raise NotImplementedError("bucketed piece shapes are not wired "
                                      "into the multi-output streaming fit "
                                      "yet")
        from repro.data.streaming import DEFAULT_STRUCT_BATCH

        return _fit_sbv_multi_streaming(
            as_store(x, y), cfg, init, nu, lr, inner_steps, outer_rounds,
            backend, verbose, stream_chunk or DEFAULT_STRUCT_BATCH, spool_dir,
            device_cache=device_cache, prefetch=prefetch, precision=precision,
        )

    if is_store(x) or stream_chunk is not None:
        from repro.data.streaming import DEFAULT_STRUCT_BATCH

        store = as_store(x, y)
        return _fit_sbv_streaming(
            store, cfg, init, nu, lr, inner_steps, outer_rounds, backend,
            verbose, stream_chunk or DEFAULT_STRUCT_BATCH, n_buckets, spool_dir,
            distributed=distributed, device_cache=device_cache,
            prefetch=prefetch, multihost=multihost, precision=precision,
        )
    policy = None
    if precision is not None:
        from .buckets import as_policy

        policy = as_policy(precision)
        if policy.tier == "f64" and not policy.probe:
            policy = None
    d = x.shape[1]
    params = init or KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3, d=d)
    history = []
    packed = None
    tiers = None

    for outer in range(outer_rounds):
        beta_np = np.asarray(params.beta)
        packed, _ = preprocess(x, y, beta_np, cfg)
        if n_buckets:
            from .buckets import bucket_blocks

            packed = bucket_blocks(packed, n_buckets=n_buckets)
        if policy is not None:
            # Probe-and-demote at the CURRENT params, re-assigned every
            # structure refresh (re-clustering reshapes the buckets).
            from .buckets import (apply_precision, assign_precision,
                                  BucketedBlocks, cast_packed)

            tiers = assign_precision(params, packed, policy, nu=nu,
                                     backend=backend)
            if isinstance(packed, BucketedBlocks):
                packed = apply_precision(packed, tiers)
            else:
                packed = cast_packed(packed, tiers[0])
        if distributed is not None:
            from .distributed import distributed_neg_loglik_fn

            loss_fn = distributed_neg_loglik_fn(packed, nu, *distributed)
        else:
            loss_fn = jax.jit(neg_loglik_fn(packed, nu, backend))
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))

        state = adam_init(params)
        for it in range(inner_steps):
            loss, g = grad_fn(params)
            params, state = adam_update(g, state, params, lr)
            history.append((outer, it, float(loss)))
            if verbose and it % 10 == 0:
                print(f"[fit] outer={outer} it={it} nll/n={float(loss):.6f}")
    return FitResult(params=params, history=history, packed=packed,
                     precision_tiers=tiers)


def fit_neldermead(
    x, y, cfg: SBVConfig, init: KernelParams | None = None,
    nu: float = 3.5, maxiter: int = 400, backend: str = "ref",
) -> FitResult:
    """Derivative-free MLE (paper-faithful optimizer path, via scipy)."""
    from scipy.optimize import minimize

    d = x.shape[1]
    params = init or KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3, d=d)
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
    loss = jax.jit(neg_loglik_fn(packed, nu, backend))

    def unpack(v):
        return KernelParams(
            log_sigma2=jnp.asarray(v[0]), log_beta=jnp.asarray(v[1 : 1 + d]),
            log_nugget=jnp.asarray(v[1 + d]),
        )

    v0 = np.concatenate([[float(params.log_sigma2)], np.asarray(params.log_beta), [float(params.log_nugget)]])
    res = minimize(lambda v: float(loss(unpack(v))), v0, method="Nelder-Mead",
                   options={"maxiter": maxiter, "xatol": 1e-4, "fatol": 1e-7})
    return FitResult(params=unpack(res.x), history=[(0, res.nit, float(res.fun))], packed=packed)
