"""Pack irregular blocks + neighbor sets into fixed-size padded arrays.

MAGMA (the paper's GPU backend) supports variable-size batched BLAS; the
TPU MXU wants fixed tiles. We pad every block to ``bs_max`` rows and every
neighbor set to ``m`` rows and carry boolean masks. The likelihood kernel
applies *identity padding*: padded rows/cols of each covariance get a unit
diagonal and zero off-diagonals, padded observations are zero, and only
real points contribute the -0.5*log(2*pi) constant — provably (and
test-verifiably) leaving the likelihood unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .blocks import BlockStructure

# TPU f32 native tile: (sublane, lane) = (8, 128). In the fused predict
# kernel the per-block working set is (m, bs)-shaped (K_cross, the joint
# solve RHS) and (m, m) (K_con), with bs the sublane-side and m the
# lane/contraction side of the MXU ops — so bs rounds to 8 and m to 128
# for the compiled (non-interpret) path.
TILE_SUBLANE = 8
TILE_LANE = 128


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def _check_neighbors(nb: np.ndarray, b: int, n_source: int) -> np.ndarray:
    """Validate one block's neighbor index list before it is gathered.

    A fixed-width neighbor array padded with sentinels (-1, or repeats of
    the last index) would pass silently through ``x[nb]`` — negative
    indices wrap around in numpy — and be packed as REAL rows with
    ``nn_mask=True``, corrupting the likelihood with no error anywhere
    downstream. Packing therefore only accepts true (unpadded) index
    lists: under-full blocks must arrive SHORT, and the packer masks the
    tail itself."""
    nb = np.asarray(nb)
    if nb.ndim != 1:
        raise ValueError(f"block {b}: neighbor list must be 1-D, got shape {nb.shape}")
    if nb.size and (int(nb.min()) < 0 or int(nb.max()) >= n_source):
        raise ValueError(
            f"block {b}: neighbor indices outside [0, {n_source}) — pass true "
            "(unpadded) neighbor lists; sentinel padding would be gathered as "
            "real rows and masked True"
        )
    if np.unique(nb).size != nb.size:
        raise ValueError(
            f"block {b}: duplicate neighbor indices — repeat-of-last-index "
            "padding would gather duplicate conditioning rows (near-singular "
            "covariance); true kNN lists never repeat"
        )
    return nb


def tile_predict_shapes(
    bs: int, m: int, bs_mult: int = TILE_SUBLANE, m_mult: int = TILE_LANE
) -> tuple[int, int]:
    """Lane-aligned (bs, m) for the compiled TPU predict kernel."""
    return round_up(bs, bs_mult), round_up(m, m_mult)


@dataclass
class PackedBlocks:
    """Device-ready SoA layout. All arrays leading dim = bc (block count).

    Coordinates are stored RAW (unscaled): the scaling parameters beta live
    in the kernel parameters so that gradients flow through them. The
    preprocessing-time beta only shapes the block/neighbor structure.
    """

    blk_x: np.ndarray    # (bc, bs_max, d)
    blk_y: np.ndarray    # (bc, bs_max) or (bc, bs_max, p) multi-output
    blk_mask: np.ndarray  # (bc, bs_max) bool
    nn_x: np.ndarray     # (bc, m, d)
    nn_y: np.ndarray     # (bc, m) or (bc, m, p) multi-output
    nn_mask: np.ndarray  # (bc, m) bool
    owners: np.ndarray   # (bc,) worker id per block

    @property
    def n_blocks(self) -> int:
        return self.blk_x.shape[0]

    @property
    def bs_max(self) -> int:
        return self.blk_x.shape[1]

    @property
    def m(self) -> int:
        return self.nn_x.shape[1]

    @property
    def n_points(self) -> int:
        return int(self.blk_mask.sum())

    @property
    def n_outputs(self) -> int:
        """1 for the single-output layout, p for (bc, bs, p) observations."""
        return 1 if self.blk_y.ndim == 2 else int(self.blk_y.shape[2])

    def pad_to_blocks(self, bc_target: int) -> "PackedBlocks":
        """Append fully-masked dummy blocks (for even sharding)."""
        extra = bc_target - self.n_blocks
        if extra <= 0:
            return self
        z = lambda a: np.concatenate(
            [a, np.zeros((extra,) + a.shape[1:], dtype=a.dtype)], axis=0
        )
        return PackedBlocks(
            blk_x=z(self.blk_x), blk_y=z(self.blk_y), blk_mask=z(self.blk_mask),
            nn_x=z(self.nn_x), nn_y=z(self.nn_y), nn_mask=z(self.nn_mask),
            owners=z(self.owners),
        )


@dataclass
class PackedPrediction:
    """Device-ready layout for block prediction (paper Eq. 3).

    Prediction blocks are query (test) blocks; each conditions on its
    m_pred nearest TRAINING points. Same identity-padding contract as
    ``PackedBlocks``: padded neighbor rows factor through the conditional
    as the identity, padded query columns produce mu=0 / var=prior and are
    dropped at scatter time via ``q_mask``/``q_idx``.
    """

    q_x: np.ndarray      # (bc, bs_pred, d) raw query coords
    q_mask: np.ndarray   # (bc, bs_pred) bool
    q_idx: np.ndarray    # (bc, bs_pred) int32 global test index (0 on pads)
    nn_x: np.ndarray     # (bc, m_pred, d) raw training-neighbor coords
    nn_y: np.ndarray     # (bc, m_pred)
    nn_mask: np.ndarray  # (bc, m_pred) bool
    owners: np.ndarray   # (bc,) worker id per block

    @property
    def n_blocks(self) -> int:
        return self.q_x.shape[0]

    @property
    def bs_pred(self) -> int:
        return self.q_x.shape[1]

    @property
    def m_pred(self) -> int:
        return self.nn_x.shape[1]

    @property
    def n_queries(self) -> int:
        return int(self.q_mask.sum())

    @property
    def n_outputs(self) -> int:
        """1 for the single-output layout, p for (bc, m, p) observations."""
        return 1 if self.nn_y.ndim == 2 else int(self.nn_y.shape[2])

    def arrays(self) -> tuple:
        """The five device operands of the batched predict kernels."""
        return self.q_x, self.q_mask, self.nn_x, self.nn_y, self.nn_mask

    def pad_to_blocks(self, bc_target: int) -> "PackedPrediction":
        """Append fully-masked dummy blocks (even sharding / jit-shape reuse)."""
        extra = bc_target - self.n_blocks
        if extra <= 0:
            return self
        z = lambda a: np.concatenate(
            [a, np.zeros((extra,) + a.shape[1:], dtype=a.dtype)], axis=0
        )
        return PackedPrediction(
            q_x=z(self.q_x), q_mask=z(self.q_mask), q_idx=z(self.q_idx),
            nn_x=z(self.nn_x), nn_y=z(self.nn_y), nn_mask=z(self.nn_mask),
            owners=z(self.owners),
        )

    def pad_to_tiles(
        self, bs_mult: int = TILE_SUBLANE, m_mult: int = TILE_LANE
    ) -> "PackedPrediction":
        """Widen bs_pred/m_pred to lane-aligned tiles with masked padding.

        Padded query slots and neighbor rows carry zero mask, so the
        identity-padding contract makes them inert; only the shapes the
        compiled TPU kernel sees change."""
        bs_t, m_t = tile_predict_shapes(self.bs_pred, self.m_pred, bs_mult, m_mult)
        if bs_t == self.bs_pred and m_t == self.m_pred:
            return self
        w = lambda a, width: np.concatenate(
            [a, np.zeros(a.shape[:1] + (width - a.shape[1],) + a.shape[2:],
                         dtype=a.dtype)], axis=1
        ) if width > a.shape[1] else a
        return PackedPrediction(
            q_x=w(self.q_x, bs_t), q_mask=w(self.q_mask, bs_t),
            q_idx=w(self.q_idx, bs_t),
            nn_x=w(self.nn_x, m_t), nn_y=w(self.nn_y, m_t),
            nn_mask=w(self.nn_mask, m_t),
            owners=self.owners,
        )


def pack_prediction(
    x_test: np.ndarray,
    x_train: np.ndarray,
    y_train: np.ndarray,
    test_blocks: BlockStructure,
    neighbors: list[np.ndarray],
    m_pred: int,
    bs_max: int | None = None,
    dtype=np.float64,
) -> PackedPrediction:
    """Pack prediction blocks + per-block training neighbors into padded
    arrays. ``neighbors[b]`` indexes ``x_train`` (full training set, no
    ordering constraint — Eq. 3 conditions on the training vector y)."""
    bc = test_blocks.n_blocks
    d = x_test.shape[1]
    if bs_max is None:
        bs_max = max(mb.size for mb in test_blocks.members)

    q_x = np.zeros((bc, bs_max, d), dtype=dtype)
    q_mask = np.zeros((bc, bs_max), dtype=bool)
    q_idx = np.zeros((bc, bs_max), dtype=np.int32)
    nn_x = np.zeros((bc, m_pred, d), dtype=dtype)
    # Multi-output observations ((n, p) y) carry their output axis into
    # the packed layout; the 1-D layout is bitwise-unchanged.
    nn_y = np.zeros((bc, m_pred) + y_train.shape[1:], dtype=dtype)
    nn_mask = np.zeros((bc, m_pred), dtype=bool)
    owners = np.zeros(bc, dtype=np.int32)

    for b in range(bc):
        mb = test_blocks.members[b]
        if mb.size > bs_max:
            raise ValueError(f"prediction block {b} size {mb.size} > bs_max {bs_max}")
        q_x[b, : mb.size] = x_test[mb]
        q_mask[b, : mb.size] = True
        q_idx[b, : mb.size] = mb
        nb = _check_neighbors(neighbors[b], b, x_train.shape[0])[:m_pred]
        nn_x[b, : nb.size] = x_train[nb]
        nn_y[b, : nb.size] = y_train[nb]
        nn_mask[b, : nb.size] = True
        owners[b] = test_blocks.owners[b]
    return PackedPrediction(q_x, q_mask, q_idx, nn_x, nn_y, nn_mask, owners)


def pack_blocks(
    x_raw: np.ndarray,
    y: np.ndarray,
    blocks: BlockStructure,
    neighbors: list[np.ndarray],
    m: int,
    bs_max: int | None = None,
    dtype=np.float64,
) -> PackedBlocks:
    """Pack (x, y, block structure, neighbor lists) into padded arrays,
    ordered by conditioning rank (block 0 of the output = first block)."""
    bc = blocks.n_blocks
    d = x_raw.shape[1]
    if bs_max is None:
        bs_max = max(mb.size for mb in blocks.members)

    blk_x = np.zeros((bc, bs_max, d), dtype=dtype)
    # Multi-output observations ((n, p) y) carry their output axis into
    # the packed layout; the 1-D layout is bitwise-unchanged.
    blk_y = np.zeros((bc, bs_max) + y.shape[1:], dtype=dtype)
    blk_mask = np.zeros((bc, bs_max), dtype=bool)
    nn_x = np.zeros((bc, m, d), dtype=dtype)
    nn_y = np.zeros((bc, m) + y.shape[1:], dtype=dtype)
    nn_mask = np.zeros((bc, m), dtype=bool)
    owners = np.zeros(bc, dtype=np.int32)

    for rank, b in enumerate(blocks.order):
        mb = blocks.members[b]
        if mb.size > bs_max:
            raise ValueError(f"block {b} size {mb.size} > bs_max {bs_max}")
        blk_x[rank, : mb.size] = x_raw[mb]
        blk_y[rank, : mb.size] = y[mb]
        blk_mask[rank, : mb.size] = True
        nb = _check_neighbors(neighbors[b], b, x_raw.shape[0])[:m]
        nn_x[rank, : nb.size] = x_raw[nb]
        nn_y[rank, : nb.size] = y[nb]
        nn_mask[rank, : nb.size] = True
        owners[rank] = blocks.owners[b]
    return PackedBlocks(blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, owners)
