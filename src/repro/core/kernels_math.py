"""Scaled anisotropic Matérn kernels (paper Eq. 5/6), differentiable in JAX.

The paper parameterizes the covariance as

    K_theta(x, x') = sigma^2 * matern_nu(r) + nugget * 1{x == x'},
    r^2 = sum_i ((x_i - x'_i) / beta_i)^2,

with half-integer smoothness nu (all paper experiments use nu = 3.5).
Half-integer Matérn has a closed form exp(-r) * poly(r), which is what we
evaluate on device — no Bessel functions in the hot path (hardware
adaptation; scipy's general-nu Bessel form is used as a test oracle).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

SUPPORTED_NU = (0.5, 1.5, 2.5, 3.5)


class KernelParams(NamedTuple):
    """Unconstrained (log-space) kernel parameters: theta of the paper."""

    log_sigma2: jax.Array  # process variance, scalar
    log_beta: jax.Array    # per-dimension ranges, shape (d,)
    log_nugget: jax.Array  # noise variance sigma_0^2, scalar

    @property
    def sigma2(self):
        return jnp.exp(self.log_sigma2)

    @property
    def beta(self):
        return jnp.exp(self.log_beta)

    @property
    def nugget(self):
        return jnp.exp(self.log_nugget)

    @staticmethod
    def create(sigma2=1.0, beta=1.0, nugget=1e-8, d=None):
        beta = jnp.atleast_1d(jnp.asarray(beta, dtype=jnp.float64))
        if d is not None and beta.shape[0] == 1:
            beta = jnp.full((d,), beta[0])
        return KernelParams(
            log_sigma2=jnp.log(jnp.asarray(sigma2, dtype=jnp.float64)),
            log_beta=jnp.log(beta),
            log_nugget=jnp.log(jnp.asarray(nugget, dtype=jnp.float64)),
        )


def cast_params(params: KernelParams, dtype) -> KernelParams:
    """Cast the log-space parameters to an accumulation dtype.

    Differentiable (astype has a well-defined VJP), so a reduced-precision
    likelihood still yields full-precision gradients w.r.t. the caller's
    f64 master parameters — the mixed-precision optimizer contract of
    docs/precision.md."""
    return KernelParams(
        log_sigma2=jnp.asarray(params.log_sigma2).astype(dtype),
        log_beta=jnp.asarray(params.log_beta).astype(dtype),
        log_nugget=jnp.asarray(params.log_nugget).astype(dtype),
    )


def matern(r: jax.Array, nu: float) -> jax.Array:
    """Normalized half-integer Matérn correlation: 2^{1-nu}/Gamma(nu) r^nu K_nu(r).

    Closed forms (nu = p + 1/2):
        nu=0.5: exp(-r)
        nu=1.5: (1 + r) exp(-r)
        nu=2.5: (1 + r + r^2/3) exp(-r)
        nu=3.5: (1 + r + 2 r^2 / 5 + r^3 / 15) exp(-r)
    """
    if nu == 0.5:
        poly = 1.0
    elif nu == 1.5:
        poly = 1.0 + r
    elif nu == 2.5:
        poly = 1.0 + r + r * r / 3.0
    elif nu == 3.5:
        poly = 1.0 + r + 0.4 * (r * r) + (r * r * r) / 15.0
    else:  # pragma: no cover - guarded by SUPPORTED_NU
        raise ValueError(f"nu={nu} not in supported half-integer set {SUPPORTED_NU}")
    return poly * jnp.exp(-r)


def scaled_sqdist(x1: jax.Array, x2: jax.Array, beta: jax.Array) -> jax.Array:
    """Pairwise squared scaled distance. x1 (n1,d), x2 (n2,d) -> (n1,n2)."""
    z1 = x1 / beta
    z2 = x2 / beta
    d2 = (
        jnp.sum(z1 * z1, axis=-1)[:, None]
        + jnp.sum(z2 * z2, axis=-1)[None, :]
        - 2.0 * z1 @ z2.T
    )
    return jnp.maximum(d2, 0.0)


def cov_matrix(
    x1: jax.Array,
    x2: jax.Array,
    params: KernelParams,
    nu: float = 3.5,
    add_nugget: bool = False,
) -> jax.Array:
    """Scaled Matérn covariance between two point sets (paper Eq. 5/6).

    ``add_nugget`` adds nugget * I and must only be used when x1 is x2.
    """
    d2 = scaled_sqdist(x1, x2, params.beta)
    # sqrt is non-differentiable at 0; the tiny floor keeps intermediate
    # gradients finite. dd2/dparams == 0 on the diagonal so the chain rule
    # still yields exactly 0 there.
    r = jnp.sqrt(d2 + 1e-300)
    k = params.sigma2 * matern(r, nu)
    if add_nugget:
        n = x1.shape[0]
        k = k + params.nugget * jnp.eye(n, dtype=k.dtype)
    return k


def matern_scipy_oracle(r, nu):
    """General-nu Matérn via scipy Bessel K (host-only test oracle)."""
    import numpy as np
    from scipy.special import gamma, kv

    r = np.asarray(r, dtype=np.float64)
    out = np.where(
        r == 0.0,
        1.0,
        2.0 ** (1.0 - nu) / gamma(nu) * np.power(r, nu) * kv(nu, r),
    )
    return out
