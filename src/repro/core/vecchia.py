"""Block-Vecchia log-likelihood (paper Eq. 2 + Alg. 5) — pure-jnp reference.

Each block contributes the conditional Gaussian log-density
    log p(y_B | y_NN(B))
computed exactly as Alg. 5:
    Sigma_con   = K(NN, NN) + nugget I        (m x m)
    Sigma_cross = K(NN, B)                    (m x bs)
    Sigma_lk    = K(B, B)   + nugget I        (bs x bs)
    L  = chol(Sigma_con);  A = L^-1 Sigma_cross;  z = L^-1 y_NN
    Sigma_new = Sigma_lk - A^T A;  mu = A^T z
    L' = chol(Sigma_new);  v = L'^-1 (y_B - mu)
    ll = -0.5*bs*log(2pi) - sum(log diag L') - 0.5 v^T v

Identity padding makes the fixed-size batched version exact for irregular
block/neighbor counts (see packing.py). CV/SV are the bs=1 special case;
BV/CV are the beta=1 (isotropic) special case — all four paper variants are
parameterizations of this one function.

This module is the ``ref`` oracle for the fused Pallas kernel in
``repro/kernels/sbv_loglik.py``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernels_math import KernelParams, matern, scaled_sqdist

_LOG2PI = float(jnp.log(2.0 * jnp.pi))


def _masked_cov(xa, xb, mask_a, mask_b, params, nu, *, identity: bool):
    """Covariance with masked rows/cols zeroed; optionally unit diagonal on
    padded entries (only valid when xa is xb and masks coincide)."""
    d2 = scaled_sqdist(xa, xb, params.beta)
    # The sqrt-at-zero gradient guard must not underflow to 0.0 in the
    # dtype actually computing (1e-300 does in f32, reintroducing the
    # 0 * inf = NaN it exists to prevent); f64 keeps the historical value
    # so f64 results stay bitwise unchanged.
    eps = 1e-300 if d2.dtype == jnp.float64 else 1e-30
    r = jnp.sqrt(d2 + eps)
    k = params.sigma2 * matern(r, nu)
    mm = mask_a[:, None] & mask_b[None, :]
    k = jnp.where(mm, k, 0.0)
    if identity:
        n = xa.shape[0]
        eye = jnp.eye(n, dtype=k.dtype)
        k = k + params.nugget * jnp.where(mask_a, 1.0, 0.0)[:, None] * eye
        k = k + jnp.where(mask_a, 0.0, 1.0)[:, None] * eye  # unit diag on pads
    return k


def _block_loglik_one(params, nu, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask):
    sigma_con = _masked_cov(nn_x, nn_x, nn_mask, nn_mask, params, nu, identity=True)
    sigma_cross = _masked_cov(nn_x, blk_x, nn_mask, blk_mask, params, nu, identity=False)
    sigma_lk = _masked_cov(blk_x, blk_x, blk_mask, blk_mask, params, nu, identity=True)

    ynn = jnp.where(nn_mask, nn_y, 0.0)
    yb = jnp.where(blk_mask, blk_y, 0.0)

    chol_con = jnp.linalg.cholesky(sigma_con)
    a = jax.scipy.linalg.solve_triangular(chol_con, sigma_cross, lower=True)
    z = jax.scipy.linalg.solve_triangular(chol_con, ynn, lower=True)

    sigma_new = sigma_lk - a.T @ a
    mu = a.T @ z

    chol_new = jnp.linalg.cholesky(sigma_new)
    v = jax.scipy.linalg.solve_triangular(chol_new, yb - mu, lower=True)

    n_real = jnp.sum(blk_mask)
    logdet = 2.0 * jnp.sum(jnp.where(blk_mask, jnp.log(jnp.diag(chol_new)), 0.0))
    return -0.5 * n_real * _LOG2PI - 0.5 * logdet - 0.5 * jnp.dot(v, v)


def _block_loglik_joint_one(params, nu, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask):
    """Joint-assembly form (beyond-paper optimization, §Perf-1).

    Builds ONE (m+bs)x(m+bs) covariance over [nn; blk] and factorizes it
    once. With L = [[L11, 0], [L21, L22]] the block conditional falls out
    of the joint solve: Sigma_new = L22 L22^T and
    v = L22^{-1} (y_B - mu) is the tail of L^{-1} [y_nn; y_B]. Replaces
    the paper's POTRF+TRSM+GEMM+POTRF+TRSV MAGMA chain with POTRF+TRSV —
    ~2x fewer O(m^2)-sized HBM passes at equal FLOPs.
    """
    x = jnp.concatenate([nn_x, blk_x], axis=0)
    mask = jnp.concatenate([nn_mask, blk_mask], axis=0)
    yv = jnp.concatenate([jnp.where(nn_mask, nn_y, 0.0),
                          jnp.where(blk_mask, blk_y, 0.0)])
    m = nn_x.shape[0]

    sigma = _masked_cov(x, x, mask, mask, params, nu, identity=True)
    chol = jnp.linalg.cholesky(sigma)
    v = jax.scipy.linalg.solve_triangular(chol, yv, lower=True)

    vb = v[m:]
    n_real = jnp.sum(blk_mask)
    logdet = 2.0 * jnp.sum(jnp.where(blk_mask, jnp.log(jnp.diag(chol)[m:]), 0.0))
    return -0.5 * n_real * _LOG2PI - 0.5 * logdet - 0.5 * jnp.dot(vb, vb)


@partial(jax.jit, static_argnames=("nu",))
def batched_block_loglik_joint(
    params: KernelParams,
    blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
) -> jax.Array:
    """Joint-assembly batched likelihood (same value as
    ``batched_block_loglik``; see ``_block_loglik_joint_one``)."""
    per_block = jax.vmap(
        lambda a, b, c, d, e, f: _block_loglik_joint_one(params, nu, a, b, c, d, e, f)
    )(blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)
    return jnp.sum(per_block)


@partial(jax.jit, static_argnames=("nu",))
def batched_block_loglik_joint_remat(
    params: KernelParams,
    blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
) -> jax.Array:
    """Joint assembly with a checkpointed per-block body: the backward
    pass recomputes the covariance build instead of loading saved
    (m+bs)^2 intermediates (§Perf-1 iteration 2)."""
    body = jax.checkpoint(
        lambda a, b, c, d, e, f: _block_loglik_joint_one(params, nu, a, b, c, d, e, f)
    )
    per_block = jax.vmap(body)(blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)
    return jnp.sum(per_block)


@partial(jax.jit, static_argnames=("nu",))
def batched_block_loglik(
    params: KernelParams,
    blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
) -> jax.Array:
    """Sum of per-block conditional log-densities (vmapped reference)."""
    per_block = jax.vmap(
        lambda a, b, c, d, e, f: _block_loglik_one(params, nu, a, b, c, d, e, f)
    )(blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)
    return jnp.sum(per_block)


def packed_loglik(params: KernelParams, packed, nu: float = 3.5, backend: str = "ref") -> jax.Array:
    """Log-likelihood of a PackedBlocks OR BucketedBlocks dataset.

    backend='ref' uses this module's vmapped jnp path; backend='pallas'
    dispatches to the fused TPU kernel (interpret mode on CPU);
    backend='auto' picks per batch shape (``kernels.ops.select_backend``).

    A ``BucketedBlocks`` input loops its per-shape buckets through the
    same batched program — one compile per bucket shape, cached by jit —
    and sums the bucket logliks. Identity padding makes the result equal
    to the uniform single-bucket layout (pinned to 1e-10 in
    tests/test_buckets.py).
    """
    from .buckets import BucketedBlocks

    if isinstance(packed, BucketedBlocks):
        return bucketed_loglik(params, packed, nu=nu, backend=backend)
    if backend == "auto":
        from repro.kernels import ops as kops

        backend = kops.select_backend(
            packed.bs_max, packed.m, kind="loglik", dtype=packed.blk_x.dtype
        )
    if backend == "ref":
        # Precision ladder: the packed observation dtype is the
        # accumulation dtype (docs/precision.md). Casting the params down
        # keeps the vmapped program at that width instead of silently
        # promoting everything back to f64; a no-op for the default f64
        # layout. Differentiable — f64 master params get f64 gradients.
        from .kernels_math import cast_params

        acc = jnp.asarray(packed.blk_y).dtype
        return batched_block_loglik(
            cast_params(params, acc),
            jnp.asarray(packed.blk_x), jnp.asarray(packed.blk_y), jnp.asarray(packed.blk_mask),
            jnp.asarray(packed.nn_x), jnp.asarray(packed.nn_y), jnp.asarray(packed.nn_mask),
            nu=nu,
        )
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.sbv_loglik(
            params,
            jnp.asarray(packed.blk_x), jnp.asarray(packed.blk_y), jnp.asarray(packed.blk_mask),
            jnp.asarray(packed.nn_x), jnp.asarray(packed.nn_y), jnp.asarray(packed.nn_mask),
            nu=nu,
        )
    raise ValueError(f"unknown backend {backend!r}")


def bucketed_loglik(params: KernelParams, bucketed, nu: float = 3.5,
                    backend: str = "ref") -> jax.Array:
    """Sum of per-bucket packed logliks (variable-size batched execution).

    Each bucket is a ``PackedBlocks`` padded only to its own ceiling, so
    the device does Sigma true work + per-bucket slack instead of padding
    every block to the global maximum. Differentiable: gradients flow
    through each bucket's program independently."""
    lls = [packed_loglik(params, pk, nu=nu, backend=backend)
           for pk in bucketed.buckets]
    total = lls[0]
    for ll in lls[1:]:
        total = total + ll
    return total
