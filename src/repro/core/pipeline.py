"""End-to-end SBV preprocessing (paper Alg. 1 steps 1-3, host-side).

scale -> partition to workers -> RAC -> order -> filtered NNS -> pack.
Executed once on CPU (as in the paper); the packed result is what the
device-side likelihood iterates over.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .blocks import BlockStructure, build_blocks, scale_inputs
from .nns import brute_force_nns, filtered_nns
from .packing import PackedBlocks, pack_blocks


@dataclass
class SBVConfig:
    """Preprocessing hyper-parameters (paper Table 1 notation)."""

    n_blocks: int            # bc: total block count K
    m: int                   # m_est: nearest neighbors per block
    n_workers: int = 1       # P: shards of the device mesh
    alpha: float = 100.0     # NNS expansion factor (Eq. 7)
    seed: int = 0
    clustering: str = "rac"  # 'rac' (paper) | 'kmeans' (BV paper)
    ordering: str = "random" # 'random' (paper) | 'coord' | 'maxmin'
    nns: str = "filtered"    # 'filtered' (paper) | 'brute' (oracle)
    bs_max: int | None = None
    dtype: type = np.float64


def preprocess(
    x: np.ndarray, y: np.ndarray, beta: np.ndarray, cfg: SBVConfig
) -> tuple[PackedBlocks, BlockStructure]:
    """Full SBV preprocessing with scaling parameters ``beta``.

    ``beta`` shapes only the block/NN structure; raw coordinates are packed
    so the likelihood stays differentiable in the kernel's own beta.
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (x.shape[1],))
    xs = scale_inputs(x, beta)
    blocks = build_blocks(
        xs,
        n_blocks=cfg.n_blocks,
        n_workers=cfg.n_workers,
        beta=beta,
        seed=cfg.seed,
        method=cfg.clustering,
        ordering=cfg.ordering,
    )
    if cfg.nns == "filtered":
        neigh = filtered_nns(xs, blocks, cfg.m, alpha=cfg.alpha)
    elif cfg.nns == "brute":
        neigh = brute_force_nns(xs, blocks, cfg.m)
    else:
        raise ValueError(f"unknown nns method {cfg.nns!r}")
    packed = pack_blocks(x, y, blocks, neigh, cfg.m, bs_max=cfg.bs_max, dtype=cfg.dtype)
    return packed, blocks
