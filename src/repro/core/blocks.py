"""Host-side preprocessing: scaling + partitioning (Alg. 2), RAC (Alg. 3).

The paper runs these once on CPU before the iterated GPU likelihood loop;
we do the same (numpy). "Workers" are the P shards of the device mesh —
the MPI_Alltoall of Alg. 2 becomes a host-side permutation that assigns
each point an owner shard, giving the same locality property: points that
are close in the *scaled* space land on the same worker.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def scale_inputs(x: np.ndarray, beta: np.ndarray) -> np.ndarray:
    """x_ij := x_ij / beta_j (Alg. 2 line 4)."""
    return np.asarray(x, dtype=np.float64) / np.asarray(beta, dtype=np.float64)


def most_relevant_dim(beta: np.ndarray) -> int:
    """The partitioning dimension d' of Alg. 2.

    The paper prints ``argmax beta_i`` but its Fig. 2 and the bucket formula
    ``int(x * P * beta_{d'})`` (which needs x*beta in [0,1), i.e. x in the
    *scaled* space) both partition along the dimension with the LARGEST
    scaled extent == smallest beta == highest relevance 1/beta. We resolve
    the typo in favor of argmin(beta); see DESIGN.md.
    """
    return int(np.argmin(np.asarray(beta)))


def partition_points(x_scaled: np.ndarray, n_workers: int, beta: np.ndarray) -> np.ndarray:
    """Assign each point an owner worker by its d'-coordinate (Alg. 2 line 7).

    Returns owner ids in [0, n_workers). Equal-mass bucketing via quantiles
    keeps workers balanced even for non-uniform inputs (the paper's
    fixed-width ``int(x * P * beta)`` buckets assume uniformity; quantile
    buckets preserve its locality while guaranteeing balance).
    """
    dprime = most_relevant_dim(beta)
    coord = x_scaled[:, dprime]
    # Quantile edges -> near-equal worker loads.
    qs = np.quantile(coord, np.linspace(0.0, 1.0, n_workers + 1)[1:-1])
    owners = np.searchsorted(qs, coord, side="right")
    return owners.astype(np.int32)


def rac_cluster(x_scaled: np.ndarray, n_blocks: int, rng: np.random.Generator, chunk: int = 65536) -> np.ndarray:
    """Random Anchor Clustering (Alg. 3): labels in [0, n_blocks).

    Anchors are n_blocks points drawn without replacement; every point joins
    its nearest anchor (in scaled space). O(n * n_blocks) done in chunks.
    """
    n = x_scaled.shape[0]
    n_blocks = min(n_blocks, n)
    anchor_idx = rng.choice(n, size=n_blocks, replace=False)
    anchors = x_scaled[anchor_idx]  # (K, d)
    a2 = np.sum(anchors * anchors, axis=1)
    labels = np.empty(n, dtype=np.int64)
    for s in range(0, n, chunk):
        xs = x_scaled[s : s + chunk]
        d2 = np.sum(xs * xs, axis=1)[:, None] - 2.0 * xs @ anchors.T + a2[None, :]
        labels[s : s + chunk] = np.argmin(d2, axis=1)
    return labels


def kmeans_cluster(
    x_scaled: np.ndarray, n_blocks: int, rng: np.random.Generator, iters: int = 10
) -> np.ndarray:
    """K-means alternative (the BV paper's choice; RAC replaces it in SBV)."""
    labels = rac_cluster(x_scaled, n_blocks, rng)
    x = x_scaled
    for _ in range(iters):
        centers = np.zeros((n_blocks, x.shape[1]))
        counts = np.bincount(labels, minlength=n_blocks).astype(np.float64)
        np.add.at(centers, labels, x)
        nonempty = counts > 0
        centers[nonempty] /= counts[nonempty, None]
        # Re-seed empty clusters at random points.
        n_empty = int((~nonempty).sum())
        if n_empty:
            centers[~nonempty] = x[rng.choice(x.shape[0], size=n_empty, replace=False)]
        c2 = np.sum(centers * centers, axis=1)
        d2 = np.sum(x * x, axis=1)[:, None] - 2.0 * x @ centers.T + c2[None, :]
        new_labels = np.argmin(d2, axis=1)
        if np.array_equal(new_labels, labels):
            break
        labels = new_labels
    return labels


@dataclass
class BlockStructure:
    """Block decomposition of a dataset in scaled space."""

    labels: np.ndarray            # (n,) block id per point
    order: np.ndarray             # (bc,) block ids in conditioning order
    rank_of_block: np.ndarray     # (bc,) rank[block_id] = position in order
    centers: np.ndarray           # (bc, d) block centroids (scaled space)
    owners: np.ndarray            # (bc,) owner worker per block
    members: list = field(default_factory=list)  # list of index arrays per block id

    @property
    def n_blocks(self) -> int:
        return len(self.order)


def build_blocks(
    x_scaled: np.ndarray,
    n_blocks: int,
    n_workers: int,
    beta: np.ndarray,
    seed: int = 0,
    method: str = "rac",
    ordering: str = "random",
) -> BlockStructure:
    """Partition points to workers, cluster per worker, order blocks.

    Per the paper, clustering is local to each worker (no communication) and
    block ordering is a random permutation. ``ordering='coord'`` (sort block
    centers along d') is kept as a beyond-paper option — it tends to improve
    neighbor quality for near-1D-relevant problems.
    """
    rng = np.random.default_rng(seed)
    n = x_scaled.shape[0]
    owners_pt = partition_points(x_scaled, n_workers, beta)

    labels = np.full(n, -1, dtype=np.int64)
    block_owner = []
    next_block = 0
    for p in range(n_workers):
        idx = np.nonzero(owners_pt == p)[0]
        if idx.size == 0:
            continue
        k_p = max(1, int(round(n_blocks * idx.size / n)))
        k_p = min(k_p, idx.size)
        cluster_fn = rac_cluster if method == "rac" else kmeans_cluster
        local = cluster_fn(x_scaled[idx], k_p, rng)
        # Drop empty local clusters, compact ids.
        uniq, local = np.unique(local, return_inverse=True)
        labels[idx] = local + next_block
        next_block += uniq.size
        block_owner.extend([p] * uniq.size)

    bc = next_block
    members = [np.nonzero(labels == b)[0] for b in range(bc)]
    centers = np.stack([x_scaled[mb].mean(axis=0) for mb in members])

    if ordering == "random":
        order = rng.permutation(bc)
    elif ordering == "coord":
        order = np.argsort(centers[:, most_relevant_dim(beta)], kind="stable")
    elif ordering == "maxmin":
        order = _maxmin_order(centers, rng)
    else:
        raise ValueError(f"unknown ordering {ordering!r}")
    rank_of_block = np.empty(bc, dtype=np.int64)
    rank_of_block[order] = np.arange(bc)

    return BlockStructure(
        labels=labels,
        order=np.asarray(order, dtype=np.int64),
        rank_of_block=rank_of_block,
        centers=centers,
        owners=np.asarray(block_owner, dtype=np.int32),
        members=members,
    )


def _maxmin_order(centers: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Greedy max-min ordering of block centers (Guinness 2018 style)."""
    k = centers.shape[0]
    start = int(rng.integers(k))
    chosen = [start]
    d2 = np.sum((centers - centers[start]) ** 2, axis=1)
    d2[start] = -np.inf
    for _ in range(k - 1):
        nxt = int(np.argmax(d2))
        chosen.append(nxt)
        nd2 = np.sum((centers - centers[nxt]) ** 2, axis=1)
        d2 = np.minimum(d2, nd2)
        d2[nxt] = -np.inf
    return np.asarray(chosen, dtype=np.int64)
