"""Filtered m-nearest-neighbor search (paper Alg. 4 + Eq. 7).

For every block (query = its center, in scaled space) we need the m nearest
*points* drawn from blocks that come EARLIER in the conditioning order.
The paper avoids a full O(n) scan per query with a two-stage filter:

  coarse: keep candidate blocks near the query center (their MPI_Alltoall
          candidate exchange);
  fine:   keep candidate points within radius lambda of the query center;
  exact:  brute-force top-m among survivors.

lambda (Eq. 7) is chosen so a ball of radius lambda holds ~ alpha * m
points under a uniform density. Two robustness upgrades over the printed
algorithm (DESIGN.md §3):

* the density estimate is explicit (bounding-box volume of the scaled
  inputs) instead of assuming a unit domain, so the formula survives
  arbitrary beta;
* the coarse filter admits block j when dist(c_i, c_j) <= lambda +
  radius_j (radius_j = max member distance to its center), which makes the
  two-stage filter EXACT: every point within lambda of the query is
  guaranteed to survive to the fine stage. A doubling fallback handles
  balls that come up short of m points.
"""
from __future__ import annotations

import math

import numpy as np

from .blocks import BlockStructure


def unit_ball_volume(d: int) -> float:
    return math.pi ** (d / 2.0) / math.gamma(d / 2.0 + 1.0)


def nns_radius(n: int, m: int, d: int, domain_volume: float, alpha: float = 100.0) -> float:
    """Eq. 7 with explicit domain volume: ball(lambda) ~ alpha*m points."""
    target_frac = min(1.0, alpha * m / max(n, 1))
    lam_d = target_frac * domain_volume / unit_ball_volume(d)
    return lam_d ** (1.0 / d)


def _scaled_domain_volume(x_scaled: np.ndarray) -> float:
    ext = x_scaled.max(axis=0) - x_scaled.min(axis=0)
    med = np.median(ext[ext > 0]) if np.any(ext > 0) else 1.0
    ext = np.maximum(ext, 1e-6 * med)  # guard constant dims
    return float(np.prod(ext))


class _FlatBlocks:
    """Block members flattened once for fast candidate slicing.

    The streaming twin (``repro.data.streaming.LazyFlatBlocks``) keeps the
    same index bookkeeping but serves member coordinates from the backing
    store on demand instead of holding the full n x d gather — any code
    that sticks to ``rows_of_blocks`` / ``points_of_blocks`` (as the NNS
    loops below do) runs unchanged, and bounded, on either.
    """

    def __init__(self, x_scaled: np.ndarray, blocks: BlockStructure):
        sizes = np.asarray([mb.size for mb in blocks.members], dtype=np.int64)
        self.sizes = sizes
        self.starts = np.concatenate([[0], np.cumsum(sizes)])
        self.flat_idx = (
            np.concatenate(blocks.members) if blocks.n_blocks else np.empty(0, np.int64)
        )
        self.flat_pts = x_scaled[self.flat_idx]
        self.flat_rank = np.repeat(blocks.rank_of_block, sizes)
        self.n_rows = x_scaled.shape[0]
        self.d = x_scaled.shape[1]
        # Block radius: max member distance to the block center.
        self.radii = np.array(
            [
                np.sqrt(np.max(np.sum((x_scaled[mb] - c) ** 2, axis=1))) if mb.size else 0.0
                for mb, c in zip(blocks.members, blocks.centers)
            ]
        )

    def rows_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        if block_ids.size == 0:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(
            [np.arange(self.starts[b], self.starts[b + 1]) for b in block_ids]
        )

    def points_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        """Scaled member coordinates of the given blocks, concatenated in
        block order (row-aligned with ``rows_of_blocks(block_ids)``)."""
        if block_ids.size == 0:
            return np.empty((0, self.d))
        if block_ids.size == 1:
            b = int(block_ids[0])
            return self.flat_pts[self.starts[b]:self.starts[b + 1]]
        return np.concatenate(
            [self.flat_pts[self.starts[b]:self.starts[b + 1]] for b in block_ids]
        )


def filtered_nns(
    x_scaled: np.ndarray | None,
    blocks: BlockStructure,
    m: int,
    alpha: float = 100.0,
    center_chunk: int = 2048,
    flat: _FlatBlocks | None = None,
    domain_volume: float | None = None,
) -> list[np.ndarray]:
    """Exact preceding-block m-NNS per block via filtered candidate sets.

    Returns ``neigh[b]`` = global point indices (up to m; fewer for
    early-ordered blocks) sorted by distance to the center of block b.
    ``flat`` lets callers reuse a prebuilt ``_FlatBlocks`` of
    ``(x_scaled, blocks)`` — building one does a full n x d gather.
    Streaming callers pass ``x_scaled=None`` with a store-backed ``flat``
    plus a precomputed ``domain_volume`` (chunk-accumulated min/max extent
    gives the same floats as the in-core formula).
    """
    if flat is None:
        flat = _FlatBlocks(x_scaled, blocks)
    bc = blocks.n_blocks
    n, d = flat.n_rows, flat.d
    if domain_volume is None:
        domain_volume = _scaled_domain_volume(x_scaled)
    lam = nns_radius(n, m, d, domain_volume, alpha)

    centers = blocks.centers
    ranks = blocks.rank_of_block
    c2 = np.sum(centers * centers, axis=1)
    neigh: list[np.ndarray] = [np.empty(0, np.int64)] * bc

    for s in range(0, bc, center_chunk):
        e = min(bc, s + center_chunk)
        q = centers[s:e]
        dc = np.sum(q * q, axis=1)[:, None] - 2.0 * q @ centers.T + c2[None, :]
        np.sqrt(np.maximum(dc, 0.0, out=dc), out=dc)
        for bi in range(s, e):
            if ranks[bi] > 0:
                neigh[bi] = _one_block(bi, centers[bi], dc[bi - s], lam, m, ranks, flat)
    return neigh


def _topm(rows: np.ndarray, d2p: np.ndarray, m: int, flat: _FlatBlocks) -> np.ndarray:
    k = min(m, rows.size)
    if rows.size > k:
        part = np.argpartition(d2p, k - 1)[:k]
    else:
        part = np.arange(rows.size)
    part = part[np.argsort(d2p[part], kind="stable")]
    return flat.flat_idx[rows[part]].astype(np.int64)


def _one_block(bi, center, dist_c, lam, m, ranks, flat) -> np.ndarray:
    my_rank = ranks[bi]
    n_prec = int(my_rank)  # number of preceding blocks
    lam_try = lam
    for _ in range(40):
        keep = (dist_c <= lam_try + flat.radii) & (ranks < my_rank)
        cand_blocks = np.nonzero(keep)[0]
        covered = cand_blocks.size >= n_prec
        if cand_blocks.size:
            rows = flat.rows_of_blocks(cand_blocks)
            d2p = np.sum((flat.points_of_blocks(cand_blocks) - center) ** 2, axis=1)
            fine = d2p <= lam_try * lam_try
            n_fine = int(fine.sum())
            if n_fine >= m:
                return _topm(rows[fine], d2p[fine], m, flat)
            if covered:
                # Whole preceding set is already candidate: brute is exact.
                return _topm(rows, d2p, m, flat)
        elif covered:  # no preceding blocks at all
            return np.empty(0, dtype=np.int64)
        lam_try *= 2.0
    raise RuntimeError("filtered NNS failed to converge (degenerate geometry?)")


def filtered_knn_points(
    x_scaled: np.ndarray | None,
    blocks: BlockStructure,
    queries: np.ndarray,
    m: int,
    alpha: float = 100.0,
    center_chunk: int = 2048,
    flat: _FlatBlocks | None = None,
    domain_volume: float | None = None,
) -> list[np.ndarray]:
    """Unconstrained k-NN of arbitrary query points against ALL training
    points, via the same coarse(block)/fine(point) filter. Used by the
    prediction stage (Eq. 3: NN(B_j^*) drawn from the full training set).

    ``flat`` lets chunked/persistent serving reuse one ``_FlatBlocks`` of
    the training set instead of re-flattening (a full n x d gather) per
    query chunk. Store-backed indexes pass ``x_scaled=None`` with a lazy
    ``flat`` and a cached ``domain_volume`` (see ``TrainIndex``)."""
    if flat is None:
        flat = _FlatBlocks(x_scaled, blocks)
    n, d = flat.n_rows, flat.d
    nq = queries.shape[0]
    if domain_volume is None:
        domain_volume = _scaled_domain_volume(x_scaled)
    lam = nns_radius(n, m, d, domain_volume, alpha)
    centers = blocks.centers
    c2 = np.sum(centers * centers, axis=1)
    bc = blocks.n_blocks
    out: list[np.ndarray] = [np.empty(0, np.int64)] * nq

    for s in range(0, nq, center_chunk):
        e = min(nq, s + center_chunk)
        q = queries[s:e]
        dc = np.sum(q * q, axis=1)[:, None] - 2.0 * q @ centers.T + c2[None, :]
        np.sqrt(np.maximum(dc, 0.0, out=dc), out=dc)
        for qi in range(s, e):
            lam_try = lam
            for _ in range(40):
                keep = dc[qi - s] <= lam_try + flat.radii
                cand = np.nonzero(keep)[0]
                covered = cand.size >= bc
                if cand.size:
                    rows = flat.rows_of_blocks(cand)
                    d2p = np.sum((flat.points_of_blocks(cand) - queries[qi]) ** 2, axis=1)
                    fine = d2p <= lam_try * lam_try
                    if int(fine.sum()) >= m:
                        out[qi] = _topm(rows[fine], d2p[fine], m, flat)
                        break
                    if covered:
                        out[qi] = _topm(rows, d2p, m, flat)
                        break
                lam_try *= 2.0
            else:
                raise RuntimeError("filtered kNN failed to converge")
    return out


def brute_force_nns(x_scaled: np.ndarray, blocks: BlockStructure, m: int) -> list[np.ndarray]:
    """Reference O(n)-per-query implementation (test oracle)."""
    ranks = blocks.rank_of_block
    pt_rank = ranks[blocks.labels]
    out = []
    for b in range(blocks.n_blocks):
        rows = np.nonzero(pt_rank < ranks[b])[0]
        if rows.size == 0:
            out.append(np.empty(0, dtype=np.int64))
            continue
        d2 = np.sum((x_scaled[rows] - blocks.centers[b]) ** 2, axis=1)
        k = min(m, rows.size)
        part = np.argpartition(d2, k - 1)[:k] if rows.size > k else np.arange(rows.size)
        part = part[np.argsort(d2[part], kind="stable")]
        out.append(rows[part].astype(np.int64))
    return out
