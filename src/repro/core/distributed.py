"""Distributed SBV likelihood via shard_map (paper Alg. 1 steps 4-5).

Worker p's blocks live on shard p of the mesh axis; each shard computes its
batched local likelihood and a single scalar ``psum`` replaces the paper's
MPI_Allreduce — communication per optimization iteration is O(1) scalars,
the property that makes SBV scale near-linearly (paper Fig. 9).

Host-side preprocessing already grouped blocks by owner (Alg. 2's
MPI_Alltoall locality), so sharding the packed arrays on the leading block
axis IS the paper's data distribution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .kernels_math import KernelParams
from .packing import PackedBlocks, PackedPrediction
from .vecchia import batched_block_loglik


def shard_blocks_by_owner(packed: PackedBlocks, n_workers: int) -> PackedBlocks:
    """Reorder blocks so each worker's blocks are contiguous, then pad the
    block count to a multiple of n_workers with fully-masked dummy blocks
    (identity padding => zero likelihood contribution)."""
    order = np.argsort(packed.owners, kind="stable")
    def g(a):
        return a[order]
    packed = PackedBlocks(
        blk_x=g(packed.blk_x), blk_y=g(packed.blk_y), blk_mask=g(packed.blk_mask),
        nn_x=g(packed.nn_x), nn_y=g(packed.nn_y), nn_mask=g(packed.nn_mask),
        owners=g(packed.owners),
    )
    bc = packed.n_blocks
    target = ((bc + n_workers - 1) // n_workers) * n_workers
    if target != bc:
        packed = packed.pad_to_blocks(target)
    # Round-robin interleave is NOT used: contiguous-by-owner matches the
    # paper's locality. But padding must land per-worker; with quantile
    # partitioning worker loads are near-equal so tail padding suffices.
    return packed


def distributed_loglik(
    params: KernelParams,
    packed: PackedBlocks,
    mesh: Mesh,
    axis: str = "workers",
    nu: float = 3.5,
):
    """Total log-likelihood with blocks sharded over ``axis`` of ``mesh``."""
    spec = P(axis)
    sharding = NamedSharding(mesh, spec)
    arrs = [
        jnp.asarray(a)
        for a in (packed.blk_x, packed.blk_y, packed.blk_mask,
                  packed.nn_x, packed.nn_y, packed.nn_mask)
    ]
    arrs = [jax.device_put(a, sharding) for a in arrs]

    def local(p, bx, by, bm, nx, ny, nm):
        ll = batched_block_loglik(p, bx, by, bm, nx, ny, nm, nu=nu)
        return jax.lax.psum(ll, axis)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), spec, spec, spec, spec, spec, spec),
        out_specs=P(),
    )
    return jax.jit(fn)(params, *arrs)


def shard_prediction_by_owner(packed: PackedPrediction, n_workers: int) -> PackedPrediction:
    """Prediction-side twin of ``shard_blocks_by_owner``: contiguous-by-owner
    block order + fully-masked padding to a multiple of n_workers. Padded
    blocks produce mu=0/var=prior and are dropped at scatter time, so the
    reorder is free of correctness constraints — it only preserves the
    paper's locality (a worker serves the query blocks whose neighbors it
    already owns)."""
    order = np.argsort(packed.owners, kind="stable")
    g = lambda a: a[order]
    packed = PackedPrediction(
        q_x=g(packed.q_x), q_mask=g(packed.q_mask), q_idx=g(packed.q_idx),
        nn_x=g(packed.nn_x), nn_y=g(packed.nn_y), nn_mask=g(packed.nn_mask),
        owners=g(packed.owners),
    )
    bc = packed.n_blocks
    target = ((bc + n_workers - 1) // n_workers) * n_workers
    if target != bc:
        packed = packed.pad_to_blocks(target)
    return packed


@functools.lru_cache(maxsize=None)
def _predict_shard_fn(mesh: Mesh, axis: str, nu: float, backend: str):
    """Cached jitted shard_map for prediction — chunked serving calls
    ``distributed_predict`` once per chunk and must hit the same compiled
    program (Mesh is hashable; the cache key is the full config)."""
    from .predict import batched_block_predict

    spec = P(axis)

    def local(p, qx, qm, nx, ny, nm):
        return batched_block_predict(p, qx, qm, nx, ny, nm, nu=nu, backend=backend)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(),) + (spec,) * 5,
        out_specs=(spec, spec),
        # pallas_call has no replication rule; outputs are per-shard anyway
        check_rep=False,
    ))


def distributed_predict(
    params: KernelParams,
    packed: PackedPrediction,
    mesh: Mesh,
    axis: str = "workers",
    nu: float = 3.5,
    backend: str = "ref",
):
    """Batched block prediction with blocks sharded over ``axis``.

    Each shard computes the conditionals of its own blocks; unlike the
    likelihood there is NO collective — per-block outputs stay sharded
    (out_specs = blocks axis) and the host gathers them for the scatter.
    Returns ``(mu, var)`` as (bc, bs_pred) arrays in the order of
    ``packed`` (call ``shard_prediction_by_owner`` first so bc divides)."""
    sharding = NamedSharding(mesh, P(axis))
    arrs = [
        jax.device_put(jnp.asarray(a), sharding) for a in packed.arrays()
    ]
    mu, var = _predict_shard_fn(mesh, axis, nu, backend)(params, *arrs)
    return mu, var


def sharded_packed_predict(
    params: KernelParams,
    packed: PackedPrediction,
    mesh: Mesh,
    axis: str = "workers",
    nu: float = 3.5,
    backend: str = "ref",
):
    """One sharded micro-batch: owner-contiguous reorder + padded sharding +
    distributed block conditionals.

    The serving pipeline's per-chunk compute when a mesh is attached.
    Returns ``(packed, mu, var)`` — the REORDERED packed (its ``q_idx``
    matches the output block order) so the caller scatters with the right
    indices. The shard_map program is cached per (mesh, axis, nu, backend),
    so successive micro-batches of the same padded shape hit one compiled
    executable."""
    n_shards = int(np.prod([mesh.shape[a] for a in
                            (axis if isinstance(axis, tuple) else (axis,))]))
    packed = shard_prediction_by_owner(packed, n_shards)
    mu, var = distributed_predict(params, packed, mesh, axis=axis, nu=nu,
                                  backend=backend)
    return packed, mu, var


def distributed_bucketed_loglik(
    params: KernelParams,
    bucketed,
    mesh: Mesh,
    axis: str = "workers",
    nu: float = 3.5,
):
    """Total loglik of a ``BucketedBlocks`` with each bucket sharded over
    ``axis``: per-bucket owner-contiguous reorder + masked padding to the
    worker count, one psum per bucket.

    Sharding bucket-by-bucket is what balances *work*, not block counts:
    under the uniform layout an equal-count split can hand one shard the
    outlier blocks (its true Sigma bs*(bs+m)^2 dwarfs the others'), but
    here every shard receives an equal slice of EVERY bucket, and within
    a bucket block sizes agree to the geometric-ceiling width — so
    per-shard true work is near-equal by construction, no explicit
    balancer needed.

    One-shot convenience (traces and compiles each bucket's program per
    call, like ``distributed_loglik``); optimizer loops should use
    ``distributed_neg_loglik_fn``, which builds, places, and jits every
    bucket program once."""
    n_workers = int(np.prod([mesh.shape[a] for a in
                             (axis if isinstance(axis, tuple) else (axis,))]))
    total = None
    for pk in bucketed.buckets:
        ll = distributed_loglik(params, shard_blocks_by_owner(pk, n_workers),
                                mesh, axis=axis, nu=nu)
        total = ll if total is None else total + ll
    return total


def distributed_neg_loglik_fn(packed, nu, mesh, axis="workers"):
    """Loss closure for fit_sbv(distributed=(mesh, axis)).

    Accepts a uniform ``PackedBlocks`` or a ``BucketedBlocks``; bucketed
    inputs are sharded bucket-by-bucket (each bucket one shard_map'd
    psum), which balances per-shard work — see
    ``distributed_bucketed_loglik``."""
    from .buckets import BucketedBlocks

    n_workers = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    if isinstance(packed, BucketedBlocks):
        return _bucketed_neg_loglik_fn(packed, nu, mesh, axis, n_workers)
    packed = shard_blocks_by_owner(packed, n_workers)
    spec = P(axis)
    sharding = NamedSharding(mesh, spec)
    arrs = [
        jax.device_put(jnp.asarray(a), sharding)
        for a in (packed.blk_x, packed.blk_y, packed.blk_mask,
                  packed.nn_x, packed.nn_y, packed.nn_mask)
    ]
    n = packed.n_points

    local = lambda p, bx, by, bm, nx, ny, nm: jax.lax.psum(
        batched_block_loglik(p, bx, by, bm, nx, ny, nm, nu=nu), axis
    )
    fn = shard_map(local, mesh=mesh, in_specs=(P(),) + (spec,) * 6, out_specs=P())

    def loss(params):
        return -fn(params, *arrs) / n

    return jax.jit(loss)


def _bucketed_neg_loglik_fn(bucketed, nu, mesh, axis, n_workers):
    """Per-bucket sharded arrays are placed once; the jitted loss sums one
    shard_map'd psum per bucket shape."""
    spec = P(axis)
    sharding = NamedSharding(mesh, spec)
    n = bucketed.n_points

    per_bucket = []
    for pk in bucketed.buckets:
        pk = shard_blocks_by_owner(pk, n_workers)
        arrs = [
            jax.device_put(jnp.asarray(a), sharding)
            for a in (pk.blk_x, pk.blk_y, pk.blk_mask,
                      pk.nn_x, pk.nn_y, pk.nn_mask)
        ]
        local = lambda p, bx, by, bm, nx, ny, nm: jax.lax.psum(
            batched_block_loglik(p, bx, by, bm, nx, ny, nm, nu=nu), axis
        )
        fn = shard_map(local, mesh=mesh, in_specs=(P(),) + (spec,) * 6,
                       out_specs=P())
        per_bucket.append((fn, arrs))

    def loss(params):
        total = None
        for fn, arrs in per_bucket:
            ll = fn(params, *arrs)
            total = ll if total is None else total + ll
        return -total / n

    return jax.jit(loss)
