"""Distributed SBV likelihood via shard_map (paper Alg. 1 steps 4-5).

Worker p's blocks live on shard p of the mesh axis; each shard computes its
batched local likelihood and a single scalar ``psum`` replaces the paper's
MPI_Allreduce — communication per optimization iteration is O(1) scalars,
the property that makes SBV scale near-linearly (paper Fig. 9).

Host-side preprocessing already grouped blocks by owner (Alg. 2's
MPI_Alltoall locality), so sharding the packed arrays on the leading block
axis IS the paper's data distribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .kernels_math import KernelParams
from .packing import PackedBlocks
from .vecchia import batched_block_loglik


def shard_blocks_by_owner(packed: PackedBlocks, n_workers: int) -> PackedBlocks:
    """Reorder blocks so each worker's blocks are contiguous, then pad the
    block count to a multiple of n_workers with fully-masked dummy blocks
    (identity padding => zero likelihood contribution)."""
    order = np.argsort(packed.owners, kind="stable")
    def g(a):
        return a[order]
    packed = PackedBlocks(
        blk_x=g(packed.blk_x), blk_y=g(packed.blk_y), blk_mask=g(packed.blk_mask),
        nn_x=g(packed.nn_x), nn_y=g(packed.nn_y), nn_mask=g(packed.nn_mask),
        owners=g(packed.owners),
    )
    bc = packed.n_blocks
    target = ((bc + n_workers - 1) // n_workers) * n_workers
    if target != bc:
        packed = packed.pad_to_blocks(target)
    # Round-robin interleave is NOT used: contiguous-by-owner matches the
    # paper's locality. But padding must land per-worker; with quantile
    # partitioning worker loads are near-equal so tail padding suffices.
    return packed


def distributed_loglik(
    params: KernelParams,
    packed: PackedBlocks,
    mesh: Mesh,
    axis: str = "workers",
    nu: float = 3.5,
):
    """Total log-likelihood with blocks sharded over ``axis`` of ``mesh``."""
    spec = P(axis)
    sharding = NamedSharding(mesh, spec)
    arrs = [
        jnp.asarray(a)
        for a in (packed.blk_x, packed.blk_y, packed.blk_mask,
                  packed.nn_x, packed.nn_y, packed.nn_mask)
    ]
    arrs = [jax.device_put(a, sharding) for a in arrs]

    def local(p, bx, by, bm, nx, ny, nm):
        ll = batched_block_loglik(p, bx, by, bm, nx, ny, nm, nu=nu)
        return jax.lax.psum(ll, axis)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), spec, spec, spec, spec, spec, spec),
        out_specs=P(),
    )
    return jax.jit(fn)(params, *arrs)


def distributed_neg_loglik_fn(packed, nu, mesh, axis="workers"):
    """Loss closure for fit_sbv(distributed=(mesh, axis))."""
    n_workers = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    packed = shard_blocks_by_owner(packed, n_workers)
    spec = P(axis)
    sharding = NamedSharding(mesh, spec)
    arrs = [
        jax.device_put(jnp.asarray(a), sharding)
        for a in (packed.blk_x, packed.blk_y, packed.blk_mask,
                  packed.nn_x, packed.nn_y, packed.nn_mask)
    ]
    n = packed.n_points

    local = lambda p, bx, by, bm, nx, ny, nm: jax.lax.psum(
        batched_block_loglik(p, bx, by, bm, nx, ny, nm, nu=nu), axis
    )
    fn = shard_map(local, mesh=mesh, in_specs=(P(),) + (spec,) * 6, out_specs=P())

    def loss(params):
        return -fn(params, *arrs) / n

    return jax.jit(loss)
