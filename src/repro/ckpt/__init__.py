from .checkpoint import (
    CheckpointManager,
    load_checkpoint,
    load_tuning_record,
    restore_train_state,
    save_checkpoint,
    save_tuning_record,
)
