from .checkpoint import (
    CheckpointManager,
    load_checkpoint,
    restore_train_state,
    save_checkpoint,
)
