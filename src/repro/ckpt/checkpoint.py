"""Checkpoint / restart for 1000+-node posture (DESIGN.md §5).

Design:
* A checkpoint = a directory ``step_<n>/`` holding one ``manifest.json``
  plus one ``.npz`` shard per (host, pytree-chunk). Parameters are stored
  in CANONICAL (unsharded, name-keyed) layout, so restore works onto a
  different mesh/host count than the save — this is what makes restarts
  ELASTIC (scale the job up or down and resume).
* Writes are atomic: shards land in ``step_<n>.tmp/`` and the directory is
  renamed only after the manifest is fsync'd. A crash mid-save never
  corrupts the latest complete checkpoint.
* ``CheckpointManager`` adds async (background-thread) saves — the train
  loop hands off host copies and keeps stepping — keep-last-k GC, and a
  SIGTERM handler for preemption-safe final saves.
* Data-iterator state (and any other JSON-serializable extras) ride in the
  manifest so restore resumes the exact stream position.

On a real multi-host fleet each host writes only the shards it owns
(``host_shards(params, host_id, n_hosts)``); this single-process build
exercises the same code path with n_hosts=1.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree, prefix=""):
    """Flatten a pytree-of-dicts/NamedTuples into {dotted-name: leaf}."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}."))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    """Rebuild ``template``'s structure with leaves taken from ``flat``."""
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}{k}.") for k in template}
    if hasattr(template, "_fields"):
        return type(template)(
            *[_unflatten_into(getattr(template, k), flat, f"{prefix}{k}.") for k in template._fields]
        )
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}.") for i, v in enumerate(template)
        )
    name = prefix[:-1]
    leaf = flat[name]
    want_dtype = template.dtype if hasattr(template, "dtype") else None
    if want_dtype is not None and leaf.dtype != want_dtype:
        leaf = leaf.astype(want_dtype)
    return leaf


def save_checkpoint(directory: str, step: int, state, extras: dict | None = None,
                    host_id: int = 0, n_hosts: int = 1) -> str:
    """Atomic synchronous save. Returns the final checkpoint path."""
    flat = _flatten(state)
    names = sorted(flat)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)

    # Each host owns a contiguous slice of the name list (canonical layout).
    mine = names[host_id::n_hosts]
    shard = {}
    for name in mine:
        leaf = flat[name]
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype — store as uint16 bit pattern.
        if arr.dtype == jax.numpy.bfloat16:
            shard[name] = arr.view(np.uint16)
            shard["__bf16__" + name] = np.array(1)
        else:
            shard[name] = arr
    np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **shard)

    if host_id == 0:
        manifest = {
            "step": step,
            "names": names,
            "n_hosts": n_hosts,
            "extras": extras or {},
            "time": time.time(),
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
    # single-host rename; on a fleet host 0 renames after a barrier
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(path: str) -> tuple[dict, dict]:
    """Returns (flat name->np.ndarray, manifest dict)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    flat = {}
    for fn in sorted(os.listdir(path)):
        if not fn.endswith(".npz"):
            continue
        with np.load(os.path.join(path, fn)) as z:
            for name in z.files:
                if name.startswith("__bf16__"):
                    continue
                arr = z[name]
                if "__bf16__" + name in z.files:
                    arr = arr.view(jax.numpy.bfloat16)
                flat[name] = arr
    missing = set(manifest["names"]) - set(flat)
    if missing:
        raise IOError(f"checkpoint {path} missing leaves: {sorted(missing)[:5]}...")
    return flat, manifest


def restore_train_state(path: str, template, shardings=None):
    """Rebuild ``template``-structured state from ``path``.

    ``shardings`` (optional pytree of NamedSharding) re-shards onto the
    CURRENT mesh — which may differ from the saving mesh (elastic restart).
    """
    flat, manifest = load_checkpoint(path)
    state = _unflatten_into(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda leaf, s: jax.device_put(leaf, s) if s is not None else jax.numpy.asarray(leaf),
            state, shardings,
        )
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state, manifest


_TUNING_RECORD = "tuning_record.json"


def save_tuning_record(directory: str, record: dict) -> str:
    """Atomically persist an autotuner record (a plain JSON-serializable
    dict — ``repro.tuning.TuningRecord.to_dict()``) next to the
    checkpoints, so a later ``fit_sbv``/``predict_sbv``/``GPServer``
    starts pre-tuned without re-measuring. Same tmp+rename discipline as
    ``save_checkpoint``: a crash mid-write never corrupts the record."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, _TUNING_RECORD)
    tmp = final + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    return final


def load_tuning_record(directory: str) -> dict | None:
    """Record dict from a checkpoint directory (or a direct path to the
    json file); ``None`` when absent."""
    path = directory
    if os.path.isdir(path):
        path = os.path.join(path, _TUNING_RECORD)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    steps = [d for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp0")
             and os.path.exists(os.path.join(directory, d, _MANIFEST))]
    if not steps:
        return None
    return os.path.join(directory, max(steps))


class CheckpointManager:
    """Async double-buffered saves + keep-last-k GC + SIGTERM drain.

    ``save()`` snapshots device arrays to host (blocking only for the copy),
    then writes on a background thread; at most one write is in flight —
    a second save waits (double buffering). ``close()`` drains the queue.
    """

    def __init__(self, directory: str, keep: int = 3, install_sigterm: bool = False):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)
        if install_sigterm:
            self._prev_handler = signal.signal(signal.SIGTERM, self._on_sigterm)
        self._last_state_fn = None

    # -- preemption ---------------------------------------------------
    def _on_sigterm(self, signum, frame):
        self.close()
        if self._last_state_fn is not None:
            step, state, extras = self._last_state_fn()
            save_checkpoint(self.directory, step, state, extras)
        raise SystemExit(143)

    def register_state_provider(self, fn):
        """fn() -> (step, state, extras); called on SIGTERM for a final save."""
        self._last_state_fn = fn

    # -- async save ---------------------------------------------------
    def save(self, step: int, state, extras: dict | None = None, block: bool = False):
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)
        self.wait()  # double buffer: at most one outstanding write

        def write():
            save_checkpoint(self.directory, step, host_state, extras)
            self._gc()

        if block:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()

    def close(self):
        self.wait()

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.directory)
            if d.startswith("step_") and ".tmp" not in d
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def latest(self) -> str | None:
        return latest_checkpoint(self.directory)
