"""repro: Scaled Block Vecchia (SBV) GP emulation framework in JAX.

GP numerics want fp64 on the host path (the paper runs MAGMA d-routines);
the LM zoo uses explicit fp32/bf16 dtypes throughout, so enabling x64
globally is safe for both sides.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
