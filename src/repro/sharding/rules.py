"""Sharding rules: parameter/batch/cache PartitionSpecs for any mesh.

Strategy (1000+-node posture, DESIGN.md §5):
* batch (DP) over ('pod', 'data');
* FSDP/ZeRO-3: the weight's input-feature dim shards over ('pod', 'data')
  — XLA inserts per-layer all-gathers inside the layer scan;
* TP (Megatron column/row) over 'model': output features of in-projections,
  input features of out-projections;
* EP: MoE expert dim over 'model' (experts pre-padded to divide it);
* every rule checks divisibility and falls back to replication, so the same
  table serves 512-device production meshes and 8-device test meshes.

Rules match parameter NAME (leaf dict key) + tensor RANK (stacked-layer
params carry a leading L axis; MoE expert weights carry L and E axes).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes):
    """axes if they divide dim, else None (replicate)."""
    if axes is None or dim % _axsize(mesh, axes) != 0:
        return None
    return axes if not (isinstance(axes, tuple) and len(axes) == 1) else axes[0]


# name -> role table
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "wr", "wk", "wv", "wg", "wz", "wx",
        "w_cm_1", "w_cm_r", "lm_head"}
_ROW = {"wo", "w_down", "w_cm_2"}
_SMALL_COL = {"wB", "wC", "wdt", "w_lora_a", "router"}


def _spec_for(name: str, shape: tuple, mesh: Mesh) -> P:
    f = fsdp_axes(mesh) or None
    rank = len(shape)

    if name == "embed":  # (V, D)
        return P(_fit(mesh, shape[0], "model"), _fit(mesh, shape[1], f))

    if name in _COL:
        if rank == 2:    # (Din, Dout) e.g. lm_head
            return P(_fit(mesh, shape[0], f), _fit(mesh, shape[1], "model"))
        if rank == 3:    # (L, Din, Dout)
            return P(None, _fit(mesh, shape[1], f), _fit(mesh, shape[2], "model"))
        if rank == 4:    # (L, E, Din, Dout) MoE experts
            return P(None, _fit(mesh, shape[1], "model"), _fit(mesh, shape[2], f), None)

    if name in _ROW:
        if rank == 2:
            return P(_fit(mesh, shape[0], "model"), _fit(mesh, shape[1], f))
        if rank == 3:
            return P(None, _fit(mesh, shape[1], "model"), _fit(mesh, shape[2], f))
        if rank == 4:
            return P(None, _fit(mesh, shape[1], "model"), None, _fit(mesh, shape[3], f))

    if name in _SMALL_COL and rank >= 2:
        # (L, Din, small) — shard the big input dim only
        return P(*([None] * (rank - 2)), _fit(mesh, shape[-2], f), None)

    if name == "w_lora_b" and rank == 3:   # (L, lora, Dout)
        return P(None, None, _fit(mesh, shape[2], "model"))

    if name == "conv_w" and rank == 3:     # (L, K, d_inner)
        return P(None, None, _fit(mesh, shape[2], "model"))

    return P(*([None] * rank))             # norms, scalars, mu, biases...


def param_specs(params, mesh: Mesh):
    """Pytree of PartitionSpecs matching ``params`` (works on shape trees)."""

    def one(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        return _spec_for(name or "", tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    dp = dp_axes(mesh)
    if not dp or global_batch % _axsize(mesh, dp) != 0:
        return P(None, None)
    return P(dp, None)


def cache_specs(cache, mesh: Mesh) -> object:
    """Decode-cache PartitionSpecs: batch over DP when divisible; heads (or
    failing that, sequence) over 'model'."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        rank = len(shape)
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        if rank == 0:
            return P()
        if name in ("k", "v"):
            # (L_or_G, B, S, Hkv, hd)
            b = _fit(mesh, shape[1], dp or None)
            h = _fit(mesh, shape[3], "model")
            s = None if h is not None else _fit(mesh, shape[2], "model")
            return P(None, b, s, h, None)
        if name == "ssd":
            # (... , B, H, P, N) - batch over dp, heads over model
            lead = rank - 4
            b = _fit(mesh, shape[-4], dp or None)
            h = _fit(mesh, shape[-3], "model")
            return P(*([None] * lead), b, h, None, None)
        if name == "conv":
            lead = rank - 3
            b = _fit(mesh, shape[-3], dp or None)
            c = _fit(mesh, shape[-1], "model")
            return P(*([None] * lead), b, None, c)
        if name == "wkv":
            # (L, B, H, K, V)
            b = _fit(mesh, shape[1], dp or None)
            h = _fit(mesh, shape[2], "model")
            return P(None, b, h, None, None)
        if name in ("last1", "last2"):
            b = _fit(mesh, shape[1], dp or None)
            d = _fit(mesh, shape[3], "model")
            return P(None, b, None, d)
        # pos etc.
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(one, cache)
