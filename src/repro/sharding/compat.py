"""JAX version-compat shims for the mesh/sharding API surface.

The repo targets the modern mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``AxisType``) but must also run on
older installs (e.g. 0.4.x) where none of those exist. Policy (see
docs/predict.md "JAX compat"): import the new names defensively and fall
back to the legacy physical-mesh context manager, which provides the same
observable behavior for everything this codebase needs:

* ``get_abstract_mesh()``      -> the ambient mesh (``.empty`` when none);
* ``set_mesh(mesh)``           -> context manager activating ``mesh``;
* ``make_mesh(shape, axes)``   -> mesh constructor (Auto axes when supported).

Model/test code must import these from here, never from ``jax`` directly.
"""
from __future__ import annotations

import jax

try:  # modern JAX
    from jax.sharding import get_abstract_mesh  # type: ignore[attr-defined]
except ImportError:  # legacy: read the physical-mesh context (``with mesh:``)
    def get_abstract_mesh():
        from jax._src.mesh import thread_resources

        return thread_resources.env.physical_mesh


# Pick the set_mesh variant matching get_abstract_mesh: every JAX that has
# jax.sharding.get_abstract_mesh also ships one of the modern setters, so
# trying them in order keeps the pair consistent (a legacy `with mesh:`
# context would NOT be visible to the modern abstract-mesh getter).
if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
elif hasattr(jax.sharding, "set_mesh"):
    set_mesh = jax.sharding.set_mesh  # type: ignore[attr-defined]
elif hasattr(jax.sharding, "use_mesh"):
    set_mesh = jax.sharding.use_mesh  # type: ignore[attr-defined]
else:
    def set_mesh(mesh):
        """Legacy fallback: a ``Mesh`` is itself a context manager that
        installs the ambient mesh read back by ``get_abstract_mesh``."""
        return mesh


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types when the install has them."""
    try:
        from jax.sharding import AxisType  # type: ignore[attr-defined]

        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)
