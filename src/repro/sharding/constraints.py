"""Activation sharding constraints that degrade to no-ops.

``constrain(x, *axes)`` applies ``with_sharding_constraint`` when an
ambient mesh (``jax.set_mesh``) is present, mapping each logical axis spec
onto mesh axes that exist AND divide the dimension; anything else
replicates. Model code can therefore annotate the intended production
sharding (Megatron activation placement) while unit tests and single-
device runs execute the identical code with zero ceremony.

Axis spec entries: None (replicate), a mesh-axis name, a tuple of names,
or BATCH (shorthand for the data-parallel axes ('pod', 'data'))."""
from __future__ import annotations

import os

import jax
from jax.sharding import PartitionSpec as P

from .compat import get_abstract_mesh

# A/B kill switch for §Perf: REPRO_NO_CONSTRAINTS=1 disables every
# activation constraint so the un-annotated model can be re-measured
# under the same cost instrument.
_DISABLED = os.environ.get("REPRO_NO_CONSTRAINTS", "") == "1"

BATCH = ("pod", "data")
FULL_BATCH = ("pod", "data", "model")  # batch over EVERY axis (recurrent blocks)


def _resolve(mesh, dim: int, entry):
    """Longest prefix of the requested axes that exists and divides dim."""
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    names = tuple(n for n in names if n in mesh.axis_names)
    best: tuple = ()
    size = 1
    for n in names:
        size *= mesh.shape[n]
        if dim % size == 0:
            best = best + (n,)
        else:
            break
    if not best or all(mesh.shape[n] == 1 for n in best):
        return None
    return best if len(best) > 1 else best[0]


def constrain(x, *axes):
    mesh = get_abstract_mesh()
    if _DISABLED or mesh.empty:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"constrain: {len(axes)} axes for rank-{x.ndim} array")
    spec = P(*[_resolve(mesh, d, a) for d, a in zip(x.shape, axes)])
    return jax.lax.with_sharding_constraint(x, spec)


def model_divides(dim: int) -> bool:
    """True if ``dim`` is shardable over the full 'model' axis."""
    mesh = get_abstract_mesh()
    if mesh.empty or "model" not in mesh.axis_names:
        return True
    size = mesh.shape["model"]
    return size == 1 or dim % size == 0
