from .rules import (
    batch_spec, cache_specs, dp_axes, fsdp_axes, param_specs, tp_size,
)

__all__ = ["batch_spec", "cache_specs", "dp_axes", "fsdp_axes", "param_specs", "tp_size"]
