"""Peak-RSS measurement shared by benchmarks and the launch drivers.

The streaming pipeline's acceptance criteria are memory ceilings ("peak
RSS delta below 2x the modeled working set"), so the measurement lives
next to the library code that both the single-host benchmark
(``benchmarks/fig_streaming_scale.py``) and the multi-host launch path
(``repro.launch.fit_gp --distributed-hosts``) need: every fitting
process — including spawned ranks — scopes a sampler around its fit
region and reports the same statistic.
"""
from __future__ import annotations

import threading


def _status_kb(field: str) -> int | None:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return int(line.split()[1])
    except OSError:
        return None
    return None


class PeakRssSampler:
    """Track peak VmRSS over a region by polling /proc/self/status.

    VmHWM + clear_refs would be exact, but clear_refs is often denied in
    containers; a 5ms poll reliably catches the sustained allocations a
    working-set ceiling is about (chunk windows, packed arrays, device
    buffers), everywhere /proc exists. ``stop()`` returns peak minus
    the baseline captured at ``start()``, in bytes.
    """

    def __init__(self, interval_s: float = 0.005):
        self._interval = interval_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self.baseline_kb = None
        self.peak_kb = None

    def _run(self):
        while not self._stop.is_set():
            kb = _status_kb("VmRSS")
            if kb is not None and (self.peak_kb is None or kb > self.peak_kb):
                self.peak_kb = kb
            self._stop.wait(self._interval)

    def start(self) -> "PeakRssSampler":
        self.baseline_kb = _status_kb("VmRSS")
        self.peak_kb = self.baseline_kb
        if self.baseline_kb is not None:
            self._thread.start()
        return self

    def stop(self) -> int | None:
        """Peak-minus-baseline in bytes, or None if /proc is unreadable."""
        self._stop.set()
        if self.baseline_kb is None:
            return None
        self._thread.join(timeout=5.0)
        kb = _status_kb("VmRSS")  # catch a final high-water at stop time
        if kb is not None and kb > self.peak_kb:
            self.peak_kb = kb
        return max(self.peak_kb - self.baseline_kb, 0) * 1024
