"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B,H,S,hd), k/v (B,H,T,hd) -> (B,H,S,hd). Dense materialized ref."""
    b, h, s, hd = q.shape
    t = k.shape[2]
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    dist = qpos - kpos
    allow = jnp.ones((s, t), bool)
    if causal:
        allow = allow & (dist >= 0)
    if window > 0:
        allow = allow & (dist < window)
    scores = jnp.where(allow[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
