"""Flash attention (fwd) Pallas TPU kernel — online-softmax over KV tiles.

The XLA attention path materializes (B, H, Q, T) fp32 scores in HBM; at
32k context that single tensor class dominates the memory roofline term
of every attention train/prefill cell (§Roofline). This kernel keeps the
score tile VMEM-resident:

    grid = (batch*heads, Q_tiles); each cell loops KV tiles with the
    online-softmax recurrence (running max M, normalizer L, accumulator O):
        S   = Q K_t^T * scale (+ softcap) (+ causal/window mask)
        M'  = max(M, rowmax(S));  P = exp(S - M')
        O   = O * exp(M - M') + P V_t;  L = L * exp(M - M') + rowsum(P)
    out = O / L

HBM per (b,h): Q read once, K/V read once per Q-tile*, O written once —
no (Q, T) tensor ever leaves VMEM.
(*K/V re-reads across Q tiles are the standard flash trade; with
Q_tile = 512, K/V traffic is T/512 x smaller than one score pass.)

VMEM working set per cell (f32): q (Qt, hd) + k/v tiles (Kt, hd) +
scores (Qt, Kt) + acc (Qt, hd) ~= 512*128*4*4 + 512*512*4 ~= 2.1 MB << 16 MB.

GQA: pass the kv head index map via head grouping outside (the wrapper
repeats KV heads lazily by index arithmetic — no materialized repeat).
Supports causal masking, sliding window, and gemma-style score softcap.
Backward runs through XLA (jax.custom_vjp with the ref computation) —
the fwd kernel is the serving/prefill hot path; a fused bwd kernel is
future work (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 512
DEFAULT_K_TILE = 512
_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  k_tile: int, kv_len: int, q_tile: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (Qt, hd)
    qt = q.shape[0]

    m = jnp.full((qt,), _NEG, jnp.float32)
    l = jnp.zeros((qt,), jnp.float32)
    acc = jnp.zeros((qt, q_ref.shape[-1]), jnp.float32)

    q_pos = qi * q_tile + jax.lax.iota(jnp.int32, qt)

    n_kv = kv_len // k_tile

    def body(kj, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(kj * k_tile, k_tile), :]
        v = v_ref[0, pl.dslice(kj * k_tile, k_tile), :]
        s = jnp.dot(q, k.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)   # (Qt, Kt)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = kj * k_tile + jax.lax.iota(jnp.int32, k_tile)
        dist = q_pos[:, None] - k_pos[None, :]
        allow = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            allow = allow & (dist >= 0)
        if window > 0:
            allow = allow & (dist < window)
        s = jnp.where(allow, s, _NEG)

        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_tile", "k_tile", "interpret"),
)
def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_tile: int = DEFAULT_Q_TILE,
    k_tile: int = DEFAULT_K_TILE,
    interpret: bool | None = None,
):
    """q (B, H, S, hd); k/v (B, H, T, hd) -> (B, H, S, hd).

    GQA callers repeat KV heads (cheap index view) before the call or map
    heads so H matches. S % q_tile == 0 and T % k_tile == 0 (pad upstream;
    fully-masked pad rows are safe: out = 0/1-guarded).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, s, hd = q.shape
    t = k.shape[2]
    qt = min(q_tile, s)
    kt = min(k_tile, t)
    assert s % qt == 0 and t % kt == 0, (s, t, qt, kt)
    scale = hd ** -0.5

    bh = b * h
    qr = q.reshape(bh, s, hd)
    kr = k.reshape(bh, t, hd)
    vr = v.reshape(bh, t, hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, k_tile=kt, kv_len=t, q_tile=qt,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // qt),
        in_specs=[
            pl.BlockSpec((1, qt, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qt, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, hd)
