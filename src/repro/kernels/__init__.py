# Pallas TPU kernels for the compute hot-spots (DESIGN.md §3):
#   sbv_loglik.py      — the paper's MAGMA pipeline fused per block
#   matern_cov.py      — tiled scaled-Matern covariance
#   flash_attention.py — online-softmax attention (LM substrate)
# ops.py holds the jit'd public wrappers; ref/flash_ref are jnp oracles.
