"""Fused SBV block prediction Pallas TPU kernel (paper Eq. 3).

Mirror of ``sbv_loglik.py`` for the serving side: ONE grid cell per
prediction block runs the whole conditional on a VMEM-resident working
set —

    scaled distances -> Matern(nu) -> chol(m x m)
    -> joint triangular solve against [K_cross | y_nn]
    -> mu = A^T z,  var = (sigma2 + nugget) - colsum(A * A)

HBM traffic per block is one read of the coordinates (O((m + bs) d)) and
one (bs,) mean + (bs,) variance write, replacing the POTRF/TRSM/TRSV/
GEMV round-trip chain a batched-BLAS backend pays per prediction batch.

Identity padding (packing.pack_prediction) needs no branches: padded
neighbor rows factor through the m x m Cholesky as the identity and
contribute nothing to the solve; padded query columns have zero
cross-covariance, yielding mu = 0 and var = prior, both discarded at
scatter time by the query mask.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.core.packing import tile_predict_shapes

from .sbv_loglik import _cholesky_inplace, _forward_sub, _masked_cov_tile


def _sbv_predict_kernel(
    beta_ref, scal_ref,
    q_x_ref, q_m_ref, nn_x_ref, nn_y_ref, nn_m_ref,
    mu_ref, var_ref,
    *, nu: float, narrow_gemm: bool = False,
):
    beta = beta_ref[...]              # (d,) accumulation dtype
    sigma2 = scal_ref[0]
    nugget = scal_ref[1]
    acc = beta.dtype                  # ladder accumulation dtype

    # Same assembly/accumulation split as the likelihood kernel: coords
    # scale at their own storage width, the GEMM accumulates in ``acc``.
    xq = q_x_ref[0]
    xn = nn_x_ref[0]
    zq = xq / beta.astype(xq.dtype)   # (bs, d) scaled query coords
    zn = xn / beta.astype(xn.dtype)   # (m, d) scaled neighbor coords
    mq = q_m_ref[0]                   # (bs,) float mask, acc dtype
    mn = nn_m_ref[0]                  # (m,)
    yn = nn_y_ref[0] * mn

    k_con = _masked_cov_tile(zn, zn, mn, mn, sigma2, nugget, nu, identity=True,
                             acc=acc, narrow_gemm=narrow_gemm)
    k_cross = _masked_cov_tile(zn, zq, mn, mq, sigma2, nugget, nu,
                               identity=False, acc=acc, narrow_gemm=narrow_gemm)

    # Same tier-aware pivot clamp as the likelihood kernel: bf16 assembly
    # error can nudge k_con off positive-definite near the nugget scale.
    if xq.dtype == acc:
        floor = 1e-30
    else:
        floor = jnp.finfo(xq.dtype).eps * sigma2

    l_con = _cholesky_inplace(k_con, floor=floor)
    # Joint solve against [K_cross | y_nn]: one substitution pass.
    rhs = jnp.concatenate([k_cross, yn[:, None]], axis=1)   # (m, bs+1)
    sol = _forward_sub(l_con, rhs)
    a = sol[:, :-1]                   # (m, bs)
    z = sol[:, -1]                    # (m,)

    mu = jnp.dot(a.T, z, preferred_element_type=a.dtype)
    prior = sigma2 + nugget
    var = prior - jnp.sum(a * a, axis=0)
    mu_ref[0] = mu * mq
    var_ref[0] = jnp.maximum(var, 1e-12)


@functools.partial(jax.jit, static_argnames=("nu", "interpret"))
def sbv_predict_pallas(
    beta, sigma2, nugget,
    q_x, q_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
    interpret: bool | None = None,
):
    """Per-block conditional means and marginal variances, each (bc, bs).

    Observations/masks set the ACCUMULATION dtype (f32 on TPU; f64 ok in
    interpret mode); coordinates may arrive one ladder rung narrower
    (bf16) for reduced-precision covariance assembly — docs/precision.md.
    Masks are float (1.0 real / 0.0 pad).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bc, bs, d = q_x.shape
    m = nn_x.shape[1]
    dtype = nn_y.dtype  # accumulation dtype; q_x/nn_x may be narrower
    scal = jnp.stack([jnp.asarray(sigma2, dtype), jnp.asarray(nugget, dtype)])
    beta = jnp.asarray(beta, dtype)

    grid = (bc,)
    # Narrow MXU GEMM operands on hardware, f32-upcast in interpret mode
    # (faithful MXU accumulation emulation — see _masked_cov_tile).
    kernel = functools.partial(_sbv_predict_kernel, nu=nu,
                               narrow_gemm=not interpret)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),            # beta (replicated)
            pl.BlockSpec((2,), lambda i: (0,)),            # sigma2, nugget
            pl.BlockSpec((1, bs, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bc, bs), dtype),
            jax.ShapeDtypeStruct((bc, bs), dtype),
        ),
        interpret=interpret,
    )(beta, scal, q_x, q_mask, nn_x, nn_y, nn_mask)


@functools.partial(jax.jit, static_argnames=("nu", "interpret"))
def sbv_predict_tiled(
    beta, sigma2, nugget,
    q_x, q_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
    interpret: bool | None = None,
):
    """Tile-aligned predict: pad bs -> multiple of 8 (sublane) and
    m -> multiple of 128 (lane), run the fused kernel on the aligned f32
    tiles, slice the outputs back to the caller's (bc, bs).

    This is the compiled (non-interpret) TPU entry point: Mosaic lays the
    per-block (m, m)/(m, bs) working set on native (8, 128) f32 tiles with
    no relayout, and the MXU contractions run at full-lane occupancy. The
    identity-padding contract keeps the added lanes inert (zero masks =>
    unit-diagonal Cholesky rows, zero cross-covariance), so outputs match
    the unaligned shapes exactly; padding happens INSIDE the jit so the
    caller's shapes stay the cache key.

    On TPU the coordinate inputs must be f32 or bf16 (the compiled
    kernel's native MXU dtypes; bf16 assembly pads bs to the doubled
    16-sublane tile — see docs/precision.md); interpret mode (CPU)
    accepts f64 as well.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not interpret and q_x.dtype not in (jnp.float32, jnp.bfloat16):
        raise TypeError(
            "compiled TPU predict kernel needs float32 or bfloat16 assembly "
            f"inputs, got {q_x.dtype}"
        )
    bc, bs, _ = q_x.shape
    m = nn_x.shape[1]
    # bf16 min tile is (16, 128): the sublane side doubles vs f32's (8, 128).
    sublane = 16 if q_x.dtype == jnp.bfloat16 else 8
    bs_t, m_t = tile_predict_shapes(bs, m, bs_mult=sublane)

    pad1 = lambda a, width: jnp.pad(a, ((0, 0), (0, width - a.shape[1]))
                                    + ((0, 0),) * (a.ndim - 2))
    mu, var = sbv_predict_pallas(
        beta, sigma2, nugget,
        pad1(q_x, bs_t), pad1(q_mask, bs_t),
        pad1(nn_x, m_t), pad1(nn_y, m_t), pad1(nn_mask, m_t),
        nu=nu, interpret=interpret,
    )
    return mu[:, :bs], var[:, :bs]
