"""Batched scaled-Matern covariance tile kernel (pl.pallas_call + BlockSpec).

Builds K(Xa, Xb) for a batch of point-set pairs with 2D output tiling:
grid = (batch, ceil(na/TN), ceil(nb/TM)); each cell computes a (TN, TM)
covariance tile from (TN, d) and (TM, d) coordinate slabs held in VMEM.
Used by the prediction path and as the simple exemplar kernel; the fused
likelihood kernel (sbv_loglik.py) inlines the same math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sbv_loglik import _matern_poly


def _cov_kernel(xa_ref, xb_ref, beta_ref, scal_ref, out_ref, *, nu: float,
                narrow_gemm: bool = False):
    beta = beta_ref[...]             # accumulation dtype
    sigma2 = scal_ref[0]
    acc = beta.dtype
    xa = xa_ref[0]
    xb = xb_ref[0]
    # Assembly at the coords' storage width, accumulation in ``acc``
    # (precision ladder; docs/precision.md) — identical to the legacy
    # single-dtype path when the inputs all share one dtype. The GEMM
    # operands stay narrow only on hardware (``narrow_gemm``): interpret
    # mode's dot rounds at the operand width instead of honoring the
    # f32 accumulation, so it upcasts to reproduce MXU numerics (see
    # sbv_loglik._masked_cov_tile).
    za = xa / beta.astype(xa.dtype)  # (TN, d)
    zb = xb / beta.astype(xb.dtype)  # (TM, d)
    za_a = za.astype(acc)
    zb_a = zb.astype(acc)
    ga, gb = (za, zb) if narrow_gemm else (za_a, zb_a)
    d2 = (
        jnp.sum(za_a * za_a, axis=-1)[:, None]
        + jnp.sum(zb_a * zb_a, axis=-1)[None, :]
        - 2.0 * jnp.dot(ga, gb.T, preferred_element_type=acc)
    )
    r = jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-30)
    out_ref[0] = sigma2 * _matern_poly(r, nu)


@functools.partial(jax.jit, static_argnames=("nu", "tile_n", "tile_m", "interpret"))
def matern_cov_pallas(
    xa, xb, beta, sigma2,
    nu: float = 3.5,
    tile_n: int = 128,
    tile_m: int = 128,
    interpret: bool | None = None,
):
    """Batched covariance: xa (B, na, d), xb (B, nb, d) -> (B, na, nb).

    bf16 coords run bf16-assembly with f32 accumulation and an f32
    output; any other dtype keeps the legacy single-dtype behavior."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, na, d = xa.shape
    nb = xb.shape[1]
    dtype = jnp.float32 if xa.dtype == jnp.bfloat16 else xa.dtype
    tn = min(tile_n, na)
    tm = min(tile_m, nb)
    # Pad to tile multiples; padded coords are zeros — results cropped below.
    pad_n = (-na) % tn
    pad_m = (-nb) % tm
    if pad_n:
        xa = jnp.pad(xa, ((0, 0), (0, pad_n), (0, 0)))
    if pad_m:
        xb = jnp.pad(xb, ((0, 0), (0, pad_m), (0, 0)))
    gn = (na + pad_n) // tn
    gm = (nb + pad_m) // tm
    scal = jnp.asarray([sigma2], dtype)
    beta = jnp.asarray(beta, dtype)

    out = pl.pallas_call(
        functools.partial(_cov_kernel, nu=nu, narrow_gemm=not interpret),
        grid=(b, gn, gm),
        in_specs=[
            pl.BlockSpec((1, tn, d), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, tm, d), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((d,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tn, tm), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((b, na + pad_n, nb + pad_m), dtype),
        interpret=interpret,
    )(xa, xb, beta, scal)
    return out[:, :na, :nb]
