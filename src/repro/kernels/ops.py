"""Jitted public wrappers around the Pallas kernels.

``sbv_loglik`` is differentiable: the forward pass runs the fused Pallas
kernel; the backward pass is the VJP of the pure-jnp reference (the
likelihood is a scalar, so the cotangent is a scalar — the rebuild is one
extra likelihood-shaped pass, exactly what MAGMA-based codes pay for finite
differences, but here it is an analytic gradient).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernels_math import KernelParams
from repro.core.vecchia import batched_block_loglik

from .matern_cov import matern_cov_pallas
from .sbv_loglik import sbv_loglik_pallas, sbv_multi_stats_pallas
from .sbv_predict import sbv_predict_pallas, sbv_predict_tiled


def ladder_dtypes(dtype):
    """(assembly, accumulation) dtypes for a storage dtype on the ladder.

    bf16 coordinates assemble at bf16 and accumulate in f32 (the MXU's
    native mixed-precision GEMM); f32/f64 storage accumulates at its own
    width. See docs/precision.md for the ladder contract."""
    import numpy as _np

    if _np.dtype(dtype) == _np.dtype(jnp.bfloat16):
        return jnp.bfloat16, jnp.float32
    return dtype, dtype


def _ref_total(params: KernelParams, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu):
    return batched_block_loglik(
        params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu=nu
    )


@partial(jax.custom_vjp, nondiff_argnums=(7,))
def sbv_loglik(params: KernelParams, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu=3.5):
    """Total SBV log-likelihood via the fused Pallas kernel.

    The coordinate dtype selects the precision tier: bf16 coords run
    bf16-assembly with f32 accumulation (params/observations/masks cast
    to f32); f32/f64 inputs run the legacy single-dtype kernel."""
    _, acc = ladder_dtypes(blk_x.dtype)
    per_block = sbv_loglik_pallas(
        params.beta.astype(acc),
        params.sigma2.astype(acc),
        params.nugget.astype(acc),
        blk_x, blk_y.astype(acc), blk_mask.astype(acc),
        nn_x, nn_y.astype(acc), nn_mask.astype(acc),
        nu=nu,
    )
    return jnp.sum(per_block)


def _fwd(params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu):
    out = sbv_loglik(params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu)
    return out, (params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)


def _bwd(nu, res, g):
    params, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask = res
    grad_fn = jax.grad(
        lambda p, by, ny: _ref_total(
            p, blk_x, by, blk_mask.astype(bool), nn_x, ny, nn_mask.astype(bool), nu
        ),
        argnums=(0, 1, 2),
    )
    gp, gby, gny = grad_fn(params, blk_y, nn_y)
    scale = lambda t: jax.tree.map(lambda a: a * g, t)
    zeros_like = lambda a: jnp.zeros_like(a)
    return (
        scale(gp), zeros_like(blk_x), scale(gby), zeros_like(blk_mask),
        zeros_like(nn_x), scale(gny), zeros_like(nn_mask),
    )


sbv_loglik.defvjp(_fwd, _bwd)


@partial(jax.custom_vjp, nondiff_argnums=(7,))
def sbv_multi_stats(params0: KernelParams, blk_x, blk_y, blk_mask,
                    nn_x, nn_y, nn_mask, nu=3.5):
    """Multi-output dataset stats ``(logdet0, q0 (p,))`` via the fused
    kernel: one Cholesky per block, all p outputs as extra RHS columns.

    ``params0`` is the UNIT-VARIANCE correlation (sigma2=1, nugget=tau2,
    see ``core.multioutput``). Differentiable like ``sbv_loglik``: the
    forward pass is the fused kernel, the backward pass the VJP of the
    pure-jnp reference."""
    _, acc = ladder_dtypes(blk_x.dtype)
    per_block = sbv_multi_stats_pallas(
        params0.beta.astype(acc),
        params0.sigma2.astype(acc),
        params0.nugget.astype(acc),
        blk_x, blk_y.astype(acc), blk_mask.astype(acc),
        nn_x, nn_y.astype(acc), nn_mask.astype(acc),
        nu=nu,
    )
    return jnp.sum(per_block[:, 0]), jnp.sum(per_block[:, 1:], axis=0)


def _ms_fwd(params0, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu):
    out = sbv_multi_stats(params0, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu)
    return out, (params0, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)


def _ms_bwd(nu, res, g):
    from repro.core.multioutput import batched_multi_stats

    params0, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask = res
    g_ld, g_q = g

    def combo(p, by, ny):
        ld, q = batched_multi_stats(
            p, blk_x, by, blk_mask.astype(bool), nn_x, ny,
            nn_mask.astype(bool), nu=nu,
        )
        return g_ld * ld + jnp.sum(g_q * q)

    gp, gby, gny = jax.grad(combo, argnums=(0, 1, 2))(params0, blk_y, nn_y)
    zeros_like = lambda a: jnp.zeros_like(a)
    return (
        gp, zeros_like(blk_x), gby, zeros_like(blk_mask),
        zeros_like(nn_x), gny, zeros_like(nn_mask),
    )


sbv_multi_stats.defvjp(_ms_fwd, _ms_bwd)


def select_backend(bs: int, m: int, kind: str = "predict", dtype=None) -> str:
    """Resolve ``backend='auto'`` to a concrete kernel per batch shape.

    The bucketed execution layer calls this once per bucket, so one packed
    dataset can mix backends: big tile-aligned f32 buckets take the
    compiled ``pallas_tiled`` path, mid-size buckets the fused ``pallas``
    kernel, and small ragged buckets the vmapped ``ref`` program (where
    kernel launch overhead would dominate). ``kind`` is ``'predict'`` or
    ``'loglik'`` (the loglik kernel has no tiled variant).

    Dtype policy (the full matrix is pinned in tests/test_buckets.py):
    the compiled tiled path takes f32 buckets aligned to the native
    (8, 128) tile and bf16-assembly buckets aligned to bf16's doubled
    (16, 128) sublane tile; f64 — which the compiled TPU kernel refuses —
    and unaligned/narrow shapes fall through to the fused ``pallas``
    kernel or the vmapped ``ref`` program by size.
    """
    import numpy as _np

    dt = None if dtype is None else _np.dtype(dtype)
    bf16 = dt is not None and dt == _np.dtype(jnp.bfloat16)
    tiled_ok = bf16 or (dt is not None and dt == _np.float32)
    sublane = 16 if bf16 else 8
    if kind == "predict" and tiled_ok and bs % sublane == 0 and m % 128 == 0:
        return "pallas_tiled"
    if bs * m >= 2048:
        return "pallas"
    return "ref"


def sbv_predict(params: KernelParams, q_x, q_mask, nn_x, nn_y, nn_mask, nu=3.5,
                tiled: bool = False):
    """Batched block conditional mean/variance via the fused Pallas kernel.

    Returns ``(mu, var)`` each shaped (bc, bs_pred); padded query slots
    carry mu=0 / var=prior and must be dropped by the caller's mask.
    ``tiled=True`` routes through ``sbv_predict_tiled`` (bs/m rounded to
    the native 8x128 f32 tile — the compiled non-interpret TPU path).
    Serving-only path: not differentiable (prediction conditions on fixed
    fitted parameters; use the ref backend to differentiate). bf16 query/
    neighbor coords run bf16-assembly with f32 accumulation."""
    _, acc = ladder_dtypes(q_x.dtype)
    fn = sbv_predict_tiled if tiled else sbv_predict_pallas
    return fn(
        params.beta.astype(acc),
        params.sigma2.astype(acc),
        params.nugget.astype(acc),
        q_x, q_mask.astype(acc),
        nn_x, nn_y.astype(acc), nn_mask.astype(acc),
        nu=nu,
    )


def matern_cov(xa, xb, params: KernelParams, nu: float = 3.5, tile: int = 128):
    """Batched scaled-Matern covariance via the tiled Pallas kernel."""
    _, acc = ladder_dtypes(xa.dtype)
    return matern_cov_pallas(
        xa, xb, params.beta.astype(acc), params.sigma2.astype(acc),
        nu=nu, tile_n=tile, tile_m=tile,
    )


# flash attention: fwd-fused kernel; see kernels/flash_attention.py
from .flash_attention import flash_attention  # noqa: E402,F401
