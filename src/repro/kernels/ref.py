"""Pure-jnp oracles for every Pallas kernel in this package.

The Vecchia likelihood oracle is the production reference implementation in
``repro.core.vecchia`` (re-exported here so kernel tests read one module);
the covariance oracle mirrors ``repro.core.kernels_math.cov_matrix``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernels_math import KernelParams, matern, scaled_sqdist
from repro.core.vecchia import batched_block_loglik


def sbv_loglik_ref(
    beta, sigma2, nugget, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask, nu=3.5
):
    """Total loglik via the vmapped jnp reference (f64-capable)."""
    params = KernelParams(
        log_sigma2=jnp.log(jnp.asarray(sigma2, jnp.float64)),
        log_beta=jnp.log(jnp.asarray(beta, jnp.float64)),
        log_nugget=jnp.log(jnp.asarray(nugget, jnp.float64)),
    )
    return batched_block_loglik(
        params,
        blk_x, blk_y, blk_mask.astype(bool),
        nn_x, nn_y, nn_mask.astype(bool),
        nu=nu,
    )


def matern_cov_ref(xa, xb, beta, sigma2, nu=3.5):
    """Batched covariance oracle: (B, na, d) x (B, nb, d) -> (B, na, nb)."""

    def one(a, b):
        r = jnp.sqrt(scaled_sqdist(a, b, jnp.asarray(beta, a.dtype)) + 1e-30)
        return sigma2 * matern(r, nu)

    return jax.vmap(one)(xa, xb)
