"""Fused SBV block log-likelihood Pallas TPU kernel.

The paper's hot loop is five MAGMA batched BLAS launches per likelihood
evaluation (POTRF, TRSM, TRSV, GEMM, GEMV), each round-tripping GPU HBM.
TPU adaptation (DESIGN.md §3): ONE grid cell per block runs the whole
pipeline on a VMEM-resident working set —

    scaled distances -> Matern(nu) -> chol(m x m) -> joint triangular solve
    -> Schur complement -> chol(bs x bs) -> solve -> logdet + quadratic form

HBM traffic per block drops from O(m^2) x 5 round trips to one read of the
coordinates (O((m+bs) d)) and one scalar write.

Numerical notes:
* Cholesky is a left-looking column loop; column writes use mask-selects
  (no dynamic lane slicing — TPU-friendly, interpret-mode exact).
* Identity padding (packing.py) means padded rows factor through as the
  identity: no branches needed inside the kernel.
* Working set at the paper's large setting (m=512, bs=128, f32):
  m^2 + m(bs+1) + 2 bs^2 + ... ~ 1.5 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LOG2PI = 1.8378770664093453  # log(2*pi)


def _matern_poly(r, nu: float):
    if nu == 0.5:
        poly = jnp.ones_like(r)
    elif nu == 1.5:
        poly = 1.0 + r
    elif nu == 2.5:
        poly = 1.0 + r + r * r / 3.0
    elif nu == 3.5:
        poly = 1.0 + r + 0.4 * (r * r) + (r * r * r) / 15.0
    else:
        raise ValueError(f"unsupported nu={nu}")
    return poly * jnp.exp(-r)


def _masked_cov_tile(za, zb, mask_a, mask_b, sigma2, nugget, nu, identity: bool,
                     acc=None, narrow_gemm: bool = False):
    """Covariance tile between pre-scaled coords; masked, optional unit-diag pad.

    ``acc`` is the accumulation dtype of the precision ladder
    (docs/precision.md): norms, sqrt/exp, and everything downstream run
    in ``acc``; the distance GEMM accumulates in ``acc`` via
    ``preferred_element_type``. ``narrow_gemm=True`` feeds the GEMM its
    operands at the coords' own storage width — the MXU's native bf16
    mode (exact bf16xbf16 products, f32 accumulation). Interpret mode
    must pass False: its dot ignores the accumulation request and rounds
    at the operand width, injecting an unstructured O(eps_bf16 |z|^2)
    error that breaks positive-definiteness of the assembled covariance.
    Upcasting the operands reproduces the hardware MXU numerics exactly
    (bf16 products are representable in f32), so both paths compute the
    true kernel matrix of the bf16-rounded points — PD by construction.
    ``acc=None`` is the legacy single-dtype path (bitwise unchanged)."""
    acc = za.dtype if acc is None else acc
    za_a = za.astype(acc)
    zb_a = zb.astype(acc)
    ga, gb = (za, zb) if narrow_gemm else (za_a, zb_a)
    d2 = (
        jnp.sum(za_a * za_a, axis=-1)[:, None]
        + jnp.sum(zb_a * zb_a, axis=-1)[None, :]
        - 2.0 * jnp.dot(ga, gb.T, preferred_element_type=acc)
    )
    r = jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-30)
    k = sigma2 * _matern_poly(r, nu)
    mm = mask_a[:, None] * mask_b[None, :]
    k = k * mm
    if identity:
        n = za.shape[0]
        eye = jnp.eye(n, dtype=k.dtype)
        k = k + (nugget * mask_a + (1.0 - mask_a))[:, None] * eye
    return k


def _cholesky_inplace(a, floor=1e-30):
    """Left-looking Cholesky of SPD ``a`` via mask-select column writes.

    ``floor`` is the pivot clamp. The 1e-30 default only guards exact
    zeros; reduced-precision assembly (bf16 tier) passes an
    eps(storage)-scaled floor instead, because its unstructured GEMM
    error can push Schur-complement eigenvalues slightly negative — a
    tiny clamped pivot would otherwise amplify into overflow/NaN. The
    clamp turns an indefinite direction into a bounded, *measurable*
    likelihood error, which the precision ladder's probe-and-demote
    harness then judges against the tier budget (docs/precision.md)."""
    n = a.shape[0]
    idx = jax.lax.iota(jnp.int32, n)

    def body(j, l):
        kmask = (idx < j).astype(l.dtype)          # (n,) columns < j are final
        lj = l[j, :] * kmask                        # row j restricted to final cols
        s = jnp.dot(l, lj, preferred_element_type=l.dtype)  # s_i = sum_{k<j} L_ik L_jk
        djj = jnp.sqrt(jnp.maximum(l[j, j] - s[j], floor))
        col = (l[:, j] - s) / djj
        col = jnp.where(idx == j, djj, col)
        col = jnp.where(idx < j, 0.0, col)          # zero strictly-upper part
        write = (idx[None, :] == j).astype(l.dtype)  # one-hot column mask
        return l * (1.0 - write) + col[:, None] * write

    return jax.lax.fori_loop(0, n, body, a)


def _forward_sub(l, b):
    """Solve L X = B (L lower-triangular) by masked row-wise substitution."""
    n = l.shape[0]
    idx = jax.lax.iota(jnp.int32, n)

    def body(i, x):
        rmask = (idx < i).astype(l.dtype)
        li = l[i, :] * rmask
        acc = jnp.dot(li, x, preferred_element_type=l.dtype)  # (ncols,)
        xi = (x[i, :] - acc) / l[i, i]
        write = (idx[:, None] == i).astype(l.dtype)
        return x * (1.0 - write) + xi[None, :] * write

    return jax.lax.fori_loop(0, n, body, b)


def _sbv_kernel(
    beta_ref, scal_ref,
    blk_x_ref, blk_y_ref, blk_m_ref, nn_x_ref, nn_y_ref, nn_m_ref,
    out_ref,
    *, nu: float, narrow_gemm: bool = False,
):
    beta = beta_ref[...]              # (d,) accumulation dtype
    sigma2 = scal_ref[0]
    nugget = scal_ref[1]
    acc = beta.dtype                  # ladder accumulation dtype

    # Coordinate scaling stays at the coords' own storage width so a
    # bf16-assembly bucket's distance GEMM sees narrow operands; the
    # contraction accumulates in ``acc`` inside _masked_cov_tile.
    xb = blk_x_ref[0]
    xn = nn_x_ref[0]
    zb = xb / beta.astype(xb.dtype)   # (bs, d) scaled block coords
    zn = xn / beta.astype(xn.dtype)   # (m, d)
    mb = blk_m_ref[0]                 # (bs,) float mask, acc dtype
    mn = nn_m_ref[0]                  # (m,)
    yb = blk_y_ref[0] * mb
    yn = nn_y_ref[0] * mn

    k_con = _masked_cov_tile(zn, zn, mn, mn, sigma2, nugget, nu, identity=True,
                             acc=acc, narrow_gemm=narrow_gemm)
    k_cross = _masked_cov_tile(zn, zb, mn, mb, sigma2, nugget, nu,
                               identity=False, acc=acc, narrow_gemm=narrow_gemm)
    k_lk = _masked_cov_tile(zb, zb, mb, mb, sigma2, nugget, nu, identity=True,
                            acc=acc, narrow_gemm=narrow_gemm)

    # Narrow-assembly tiers clamp Cholesky pivots at the assembly
    # round-off scale (eps * sigma2): the bf16 GEMM's unstructured error
    # can make the Schur complement slightly indefinite, and the default
    # 1e-30 floor would let a clamped pivot blow up the substitution.
    if xb.dtype == acc:
        floor = 1e-30
    else:
        floor = jnp.finfo(xb.dtype).eps * sigma2

    l_con = _cholesky_inplace(k_con, floor=floor)
    # Joint solve against [K_cross | y_nn]: one substitution pass.
    rhs = jnp.concatenate([k_cross, yn[:, None]], axis=1)   # (m, bs+1)
    sol = _forward_sub(l_con, rhs)
    a = sol[:, :-1]                   # (m, bs)
    z = sol[:, -1]                    # (m,)

    sigma_new = k_lk - jnp.dot(a.T, a, preferred_element_type=a.dtype)
    mu = jnp.dot(a.T, z, preferred_element_type=a.dtype)

    l_new = _cholesky_inplace(sigma_new, floor=floor)
    v = _forward_sub(l_new, (yb - mu)[:, None])[:, 0]

    n_real = jnp.sum(mb)
    diag = jnp.diagonal(l_new)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.maximum(diag, 1e-30)) * mb)
    ll = -0.5 * n_real * _LOG2PI - 0.5 * logdet - 0.5 * jnp.dot(v, v)
    out_ref[0] = ll


def _sbv_multi_kernel(
    beta_ref, scal_ref,
    blk_x_ref, blk_y_ref, blk_m_ref, nn_x_ref, nn_y_ref, nn_m_ref,
    out_ref,
    *, nu: float, narrow_gemm: bool = False,
):
    """Multi-output per-block stats: ONE Cholesky, (m, bs+p) joint solve.

    The single-output kernel's RHS ``[K_cross | y_nn]`` widens to
    ``[K_cross | Y_nn]`` with Y (m, p) — the per-output work rides the
    same substitution passes as extra columns (docs/multioutput.md).
    Runs on the UNIT-VARIANCE correlation (sigma2=1, nugget=tau2); the
    per-output scales re-enter in closed form outside the kernel.
    Output row: [logdet0, q_1 .. q_p]."""
    beta = beta_ref[...]
    sigma2 = scal_ref[0]
    nugget = scal_ref[1]
    acc = beta.dtype

    xb = blk_x_ref[0]
    xn = nn_x_ref[0]
    zb = xb / beta.astype(xb.dtype)
    zn = xn / beta.astype(xn.dtype)
    mb = blk_m_ref[0]                 # (bs,) float mask
    mn = nn_m_ref[0]                  # (m,)
    yb = blk_y_ref[0] * mb[:, None]   # (bs, p)
    yn = nn_y_ref[0] * mn[:, None]    # (m, p)
    bs = yb.shape[0]

    k_con = _masked_cov_tile(zn, zn, mn, mn, sigma2, nugget, nu, identity=True,
                             acc=acc, narrow_gemm=narrow_gemm)
    k_cross = _masked_cov_tile(zn, zb, mn, mb, sigma2, nugget, nu,
                               identity=False, acc=acc, narrow_gemm=narrow_gemm)
    k_lk = _masked_cov_tile(zb, zb, mb, mb, sigma2, nugget, nu, identity=True,
                            acc=acc, narrow_gemm=narrow_gemm)

    if xb.dtype == acc:
        floor = 1e-30
    else:
        floor = jnp.finfo(xb.dtype).eps * sigma2

    l_con = _cholesky_inplace(k_con, floor=floor)
    rhs = jnp.concatenate([k_cross, yn], axis=1)            # (m, bs+p)
    sol = _forward_sub(l_con, rhs)
    a = sol[:, :bs]                   # (m, bs)
    z = sol[:, bs:]                   # (m, p)

    sigma_new = k_lk - jnp.dot(a.T, a, preferred_element_type=a.dtype)
    mu = jnp.dot(a.T, z, preferred_element_type=a.dtype)    # (bs, p)

    l_new = _cholesky_inplace(sigma_new, floor=floor)
    v = _forward_sub(l_new, yb - mu)                        # (bs, p)

    diag = jnp.diagonal(l_new)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.maximum(diag, 1e-30)) * mb)
    q = jnp.sum(v * v, axis=0)                              # (p,)
    out_ref[0] = jnp.concatenate([jnp.reshape(logdet, (1,)), q])


@functools.partial(jax.jit, static_argnames=("nu", "interpret"))
def sbv_multi_stats_pallas(
    beta, sigma2, nugget,
    blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
    interpret: bool | None = None,
):
    """Per-block multi-output stats, shape (bc, 1+p): column 0 is the
    unit-variance logdet, columns 1..p the per-output quadratics. Same
    dtype/precision contract as ``sbv_loglik_pallas``; observations are
    (bc, bs, p) / (bc, m, p)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bc, bs, d = blk_x.shape
    m = nn_x.shape[1]
    p = blk_y.shape[2]
    dtype = blk_y.dtype
    scal = jnp.stack([jnp.asarray(sigma2, dtype), jnp.asarray(nugget, dtype)])
    beta = jnp.asarray(beta, dtype)

    grid = (bc,)
    kernel = functools.partial(_sbv_multi_kernel, nu=nu,
                               narrow_gemm=not interpret)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),            # beta (replicated)
            pl.BlockSpec((2,), lambda i: (0,)),            # sigma2, nugget
            pl.BlockSpec((1, bs, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bs, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1 + p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, 1 + p), dtype),
        interpret=interpret,
    )(beta, scal, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)


@functools.partial(jax.jit, static_argnames=("nu", "interpret"))
def sbv_loglik_pallas(
    beta, sigma2, nugget,
    blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask,
    nu: float = 3.5,
    interpret: bool | None = None,
):
    """Per-block log-likelihoods, shape (bc,). Sum for the total.

    Observations/masks set the ACCUMULATION dtype (f32 on TPU; f64 ok in
    interpret mode); coordinates may additionally arrive one ladder rung
    narrower (bf16) for reduced-precision covariance assembly — see
    docs/precision.md. Masks are float (1.0 real / 0.0 pad).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bc, bs, d = blk_x.shape
    m = nn_x.shape[1]
    dtype = blk_y.dtype  # accumulation dtype; blk_x may be narrower
    scal = jnp.stack([jnp.asarray(sigma2, dtype), jnp.asarray(nugget, dtype)])
    beta = jnp.asarray(beta, dtype)

    grid = (bc,)
    # Compiled TPU runs feed the MXU narrow (bf16) GEMM operands;
    # interpret mode upcasts them to reproduce the MXU's f32 accumulation
    # (its dot otherwise rounds at the operand width — see
    # _masked_cov_tile).
    kernel = functools.partial(_sbv_kernel, nu=nu, narrow_gemm=not interpret)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),            # beta (replicated)
            pl.BlockSpec((2,), lambda i: (0,)),            # sigma2, nugget
            pl.BlockSpec((1, bs, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, bs), lambda i: (i, 0)),
            pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bc,), dtype),
        interpret=interpret,
    )(beta, scal, blk_x, blk_y, blk_mask, nn_x, nn_y, nn_mask)
