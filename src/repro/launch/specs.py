"""ShapeDtypeStruct stand-ins + step builders for every dry-run cell.

``input_specs(arch, shape)`` returns (step_fn, arg_specs, in_shardings,
out_shardings, donate) — everything ``jax.jit(...).lower()`` needs, with no
device allocation. [audio]/[vlm] archs consume precomputed token ids (the
modality frontend is a stub per the assignment).

The SBV GP runtime is an extra dry-run target ("sbv-gp"): one gradient
step of the distributed block-Vecchia likelihood, blocks sharded over all
mesh axes flattened into the paper's P workers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models.model import init_params, make_empty_cache, serve_step, prefill_step
from repro.sharding.rules import batch_spec, cache_specs, param_specs, tp_size
from repro.training.train_step import TrainState, make_train_step, train_state_init


def _named(mesh: Mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_params(cfg, tp: int = 1):
    return jax.eval_shape(lambda k: init_params(k, cfg, tp), jax.random.key(0))


def abstract_train_state(cfg, tp: int = 1):
    params = abstract_params(cfg, tp)
    return jax.eval_shape(train_state_init, params)


def train_cell(cfg, shape, mesh: Mesh):
    """Lowerable train_step for (arch, train shape, mesh)."""
    tp = tp_size(mesh)
    state = abstract_train_state(cfg, tp)
    tok = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)

    pspecs = param_specs(state.params, mesh)
    sspecs = TrainState(
        params=pspecs,
        opt=type(state.opt)(step=P(), mu=pspecs, nu=pspecs),
        step=P(),
    )
    bspec = batch_spec(mesh, shape.global_batch)

    step = make_train_step(cfg, tp=tp)
    in_shardings = (_named(mesh, sspecs), _named(mesh, bspec), _named(mesh, bspec))
    out_shardings = (_named(mesh, sspecs), _named(mesh, {"loss": P(), "grad_norm": P()}))
    return step, (state, tok, tok), in_shardings, out_shardings, (0,)


def prefill_cell(cfg, shape, mesh: Mesh):
    tp = tp_size(mesh)
    params = abstract_params(cfg, tp)
    tok = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
    pspecs = param_specs(params, mesh)
    bspec = batch_spec(mesh, shape.global_batch)

    cache_len = shape.seq_len
    fn = functools.partial(prefill_step, cfg=cfg, cache_len=cache_len, tp=tp)
    step = lambda p, t: fn(p, t)

    cache = jax.eval_shape(
        lambda p, t: fn(p, t)[1], params, tok
    )
    cspecs = cache_specs(cache, mesh)
    logits_spec = P(bspec[0], None)  # (B, V) — batch over dp
    in_shardings = (_named(mesh, pspecs), _named(mesh, bspec))
    out_shardings = (_named(mesh, logits_spec), _named(mesh, cspecs))
    return step, (params, tok), in_shardings, out_shardings, ()


def decode_cell(cfg, shape, mesh: Mesh):
    """One-token serve_step against a seq_len-deep cache."""
    tp = tp_size(mesh)
    params = abstract_params(cfg, tp)
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda p: make_empty_cache(p, cfg, b, shape.seq_len, tp=tp), params
    )
    pspecs = param_specs(params, mesh)
    cspecs = cache_specs(cache, mesh)
    bspec = batch_spec(mesh, b)

    def step(p, t, c):
        return serve_step(p, t, c, cfg, tp=tp)

    logits_spec = P(bspec[0], None)
    in_shardings = (_named(mesh, pspecs), _named(mesh, bspec), _named(mesh, cspecs))
    out_shardings = (_named(mesh, logits_spec), _named(mesh, cspecs))
    return step, (params, tok, cache), in_shardings, out_shardings, (2,)


# ------------------------------------------------------------- SBV GP ----

SBV_GP_SHAPES = {
    # paper workloads: MetaRVM 50M pts d=10 (bs=100, m=400: paper's largest
    # accuracy config), and the Fig.9 strong-scaling 128M-point run.
    "fit_50m": dict(n=50_000_000, d=10, bs=100, m=400),
    "fit_128m": dict(n=128_000_000, d=10, bs=100, m=200),
}


def sbv_gp_cell(shape_name: str, mesh: Mesh, variant: str = "magma"):
    """One MLE gradient step of the distributed SBV likelihood.

    Blocks are sharded over ALL mesh axes (flattened = the paper's P
    workers). The lowered graph contains the batched per-block pipeline +
    the scalar psum (the paper's MPI_Allreduce).

    variant: 'magma' = the paper-faithful POTRF/TRSM/GEMM/TRSV chain;
             'joint' = single joint-Cholesky assembly (§Perf-1);
             'joint_remat' = joint + checkpointed covariance build.
    """
    from repro.core.kernels_math import KernelParams
    from repro.core.vecchia import batched_block_loglik, batched_block_loglik_joint

    spec = SBV_GP_SHAPES[shape_name]
    n, d, bs, m = spec["n"], spec["d"], spec["bs"], spec["m"]
    bc = n // bs
    n_dev = mesh.size
    bc = ((bc + n_dev - 1) // n_dev) * n_dev
    axes = tuple(mesh.axis_names)

    f64 = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    args = (
        KernelParams(
            log_sigma2=jax.ShapeDtypeStruct((), f64),
            log_beta=jax.ShapeDtypeStruct((d,), f64),
            log_nugget=jax.ShapeDtypeStruct((), f64),
        ),
        jax.ShapeDtypeStruct((bc, bs, d), f64),   # blk_x
        jax.ShapeDtypeStruct((bc, bs), f64),      # blk_y
        jax.ShapeDtypeStruct((bc, bs), jnp.bool_),
        jax.ShapeDtypeStruct((bc, m, d), f64),    # nn_x
        jax.ShapeDtypeStruct((bc, m), f64),       # nn_y
        jax.ShapeDtypeStruct((bc, m), jnp.bool_),
    )

    blocks = P(axes)

    fwd_only = variant.endswith("_fwd")
    base = variant[:-4] if fwd_only else variant
    if base == "magma":
        loglik_fn = batched_block_loglik
    elif base in ("joint", "joint_remat"):
        loglik_fn = batched_block_loglik_joint
        if base == "joint_remat":
            from repro.core.vecchia import batched_block_loglik_joint_remat
            loglik_fn = batched_block_loglik_joint_remat
    else:
        raise ValueError(variant)

    if fwd_only:
        # paper-parity path: derivative-free NLopt evaluates the likelihood
        # only; no backward pass is lowered.
        def step(params, bx, by, bm, nx, ny, nm):
            return (-loglik_fn(params, bx, by, bm, nx, ny, nm, nu=3.5) / n,
                    params)
    else:
        def step(params, bx, by, bm, nx, ny, nm):
            def nll(p):
                return -loglik_fn(p, bx, by, bm, nx, ny, nm, nu=3.5) / n
            loss, g = jax.value_and_grad(nll)(params)
            return loss, g

    in_shardings = (_named(mesh, P()),) + tuple(_named(mesh, blocks) for _ in range(6))
    out_shardings = (_named(mesh, P()), _named(mesh, P()))
    return step, args, in_shardings, out_shardings, ()


# ------------------------------------------------------------ registry ----

def build_cell(arch: str, shape_name: str, mesh: Mesh, **opts):
    """(arch, shape, mesh) -> (step_fn, arg_specs, in_sh, out_sh, donate)."""
    if arch == "sbv-gp":
        return sbv_gp_cell(shape_name, mesh, **opts)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh)
    if shape.kind == "decode":
        return decode_cell(cfg, shape, mesh)
    raise ValueError(shape.kind)
