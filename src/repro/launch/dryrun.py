import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: `.lower().compile()` must succeed on the single-pod 16x16 mesh
and the 2-pod (2,16,16) mesh for every assigned architecture x input
shape, plus the SBV GP runtime cells. For each cell we record
``memory_analysis()`` (fits-in-HBM evidence) and ``cost_analysis()`` +
parsed collective bytes (the §Roofline inputs) into a JSON results file.

Usage:
    python -m repro.launch.dryrun                       # all cells, both meshes
    python -m repro.launch.dryrun --arch gemma2-9b      # one arch
    python -m repro.launch.dryrun --shape train_4k --mesh pod
    python -m repro.launch.dryrun --out results.json --resume
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis.hlo_analysis import analyze_compiled, model_flops, roofline
from repro.configs import ARCHS, SHAPES, applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SBV_GP_SHAPES, build_cell
from repro.sharding.compat import set_mesh

MESHES = {"pod": False, "multipod": True}


def run_cell(arch: str, shape_name: str, mesh_name: str, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    step, args, in_sh, out_sh, donate = build_cell(arch, shape_name, mesh)

    t0 = time.time()
    jitted = jax.jit(
        step, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=donate or None,
    )
    with set_mesh(mesh):  # activates activation-sharding constraints
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    if arch == "sbv-gp":
        spec = SBV_GP_SHAPES[shape_name]
        # per-block flops: 2 chol (m^3/3, bs^3/3) + trsm (m^2 bs) + gemm (m bs^2)
        m, bs = spec["m"], spec["bs"]
        bc = spec["n"] / bs
        mflops = bc * (m**3 / 3 + bs**3 / 3 + m * m * bs + m * bs * bs) * 2.0  # fwd+bwd ~2x
    else:
        mflops = model_flops(get_config(arch), SHAPES[shape_name])

    rep = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_devices=mesh.size, mflops=mflops,
    )
    rep.extra = {"t_lower_s": t_lower, "t_compile_s": t_compile}
    if verbose:
        ma_line = (f"peak {rep.peak_memory/2**30:.2f} GiB/dev "
                   f"(args {rep.arg_bytes/2**30:.2f} + temp {rep.temp_bytes/2**30:.2f})")
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s) {ma_line}")
        print("         " + roofline(rep))
    return rep.to_dict()


def all_cells(archs=None, shapes=None, meshes=None):
    archs = archs or (list(ARCHS) + ["sbv-gp"])
    meshes = meshes or list(MESHES)
    for arch in archs:
        if arch == "sbv-gp":
            snames = shapes or list(SBV_GP_SHAPES)
            snames = [s for s in snames if s in SBV_GP_SHAPES]
        else:
            snames = shapes or list(SHAPES)
            snames = [s for s in snames if s in SHAPES]
        for sname in snames:
            if arch != "sbv-gp":
                ok, why = applicable(get_config(arch), sname)
                if not ok:
                    yield (arch, sname, None, {"skipped": why})
                    continue
            for mname in meshes:
                yield (arch, sname, mname, None)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", action="append", default=None, choices=list(MESHES))
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args(argv)

    results = {}
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    failures = []
    for arch, sname, mname, skip in all_cells(args.arch, args.shape, args.mesh):
        if skip is not None:
            key = f"{arch}|{sname}|-"
            results[key] = {"arch": arch, "shape": sname, **skip}
            print(f"[dryrun] {arch} x {sname}: SKIP ({skip['skipped'][:60]}...)")
            continue
        key = f"{arch}|{sname}|{mname}"
        if args.resume and key in results and "error" not in results[key]:
            continue
        try:
            results[key] = run_cell(arch, sname, mname)
        except Exception as e:
            traceback.print_exc()
            results[key] = {"arch": arch, "shape": sname, "mesh": mname,
                            "error": f"{type(e).__name__}: {e}"}
            failures.append(key)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_ok = sum(1 for v in results.values() if "error" not in v and "skipped" not in v)
    n_skip = sum(1 for v in results.values() if "skipped" in v)
    print(f"\n[dryrun] {n_ok} cells OK, {n_skip} skipped, {len(failures)} FAILED -> {args.out}")
    if failures:
        print("FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
