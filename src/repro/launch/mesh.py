"""Production meshes.

All constructors are FUNCTIONS so importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).

Axes:
* (pod, data, model): multi-pod production: 2 pods x 16 x 16 = 512 chips.
* (data, model): single-pod 16 x 16 = 256 chips.
* GP runs flatten everything into one 'workers' axis — the paper's P MPI
  ranks; its only hot-path collective is a scalar psum.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.sharding.compat import make_mesh


def _mesh(shape, axes) -> Mesh:
    return make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_worker_mesh(n_workers: int | None = None) -> Mesh:
    """1-D mesh for the SBV GP runtime (axis name 'workers')."""
    n = n_workers or len(jax.devices())
    return _mesh((n,), ("workers",))


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    return _mesh(shape, axes)
