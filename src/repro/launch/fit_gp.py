"""SBV GP fitting driver — the paper's main entry point.

    PYTHONPATH=src python -m repro.launch.fit_gp --n 20000 --d 10 \
        --blocks 400 --m 60 --workers 1 --dataset synthetic

Datasets: synthetic (paper §6.1), satdrag (§6.2-like), metarvm (§6.3-like).
``--workers k`` runs the distributed likelihood over a k-device mesh
(CPU devices stand in for the paper's MPI ranks).

Out-of-core (docs/streaming.md): ``--store DIR`` fits straight from an
``ArrayStore`` directory instead of materializing the dataset in RAM;
``--write-store DIR`` generates the synthetic dataset chunk-by-chunk into
a store first (then fits from it), and ``--stream-chunk`` bounds the rows
held on host per pass:

    PYTHONPATH=src python -m repro.launch.fit_gp --dataset synthetic \
        --n 1000000 --write-store /tmp/sbv-1m --stream-chunk 131072

Multi-process (docs/streaming.md "multi-host construction"):
``--distributed-hosts K`` re-launches this driver as K rank processes
connected through ``jax.distributed`` — each rank owns one partition of
the store, builds its share of the block structure (k-means all-reduce +
halo NNS exchange), spools only its own pieces, and joins the others in
a lockstep per-chunk loss/grad all-reduce. The parent merges the
per-rank ``--result-json`` files. Heavy imports stay INSIDE ``main``:
a rank must call ``jax.distributed.initialize`` before anything
initializes the JAX backend, so the module must import clean.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time

import numpy as np


def load_dataset(name: str, n: int, seed: int, outputs: int = 1):
    from repro.data.gp_sim import (metarvm_dataset, metarvm_field_dataset,
                                   paper_synthetic, satellite_drag_like)

    if outputs > 1:
        if name != "metarvm":
            raise SystemExit("--outputs > 1 requires --dataset metarvm "
                             "(the multi-output field variant)")
        return metarvm_field_dataset(seed, n, p=outputs)
    if name == "synthetic":
        x, y, params = paper_synthetic(seed, n)
        return x, y
    if name == "satdrag":
        return satellite_drag_like(seed, n)
    if name == "metarvm":
        return metarvm_dataset(seed, n)
    raise ValueError(name)


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "satdrag", "metarvm"])
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--outputs", type=int, default=1, metavar="P",
                    help="emulate P outputs jointly through the shared-"
                         "structure multi-output fit (docs/multioutput.md); "
                         "metarvm only — snapshots the epidemic trajectory "
                         "at P evenly spaced days")
    ap.add_argument("--blocks", type=int, default=400)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--m-pred", type=int, default=120)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--inner-steps", type=int, default=40)
    ap.add_argument("--outer-rounds", type=int, default=2)
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "pallas", "auto"])
    ap.add_argument("--test-frac", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="fit from an existing ArrayStore directory "
                         "(out-of-core; --n/--dataset are ignored)")
    ap.add_argument("--write-store", default=None, metavar="DIR",
                    help="generate the dataset chunk-by-chunk into a new "
                         "store at DIR, then fit from it")
    ap.add_argument("--stream-chunk", type=int, default=None,
                    help="max dataset rows held on host per streaming pass "
                         "(implies the out-of-core fit path)")
    ap.add_argument("--device-cache-mb", type=float, default=None,
                    help="HBM budget (MB) for the streaming fit's "
                         "device-resident spool tier; default sizes it from "
                         "free device memory, 0 disables the cache")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="disk-tier spool pieces staged ahead of the device "
                         "by the H2D producer thread (0 = synchronous reads)")
    ap.add_argument("--precision", default=None,
                    choices=["bf16", "f32", "f64"],
                    help="covariance-assembly ladder tier (docs/precision.md);"
                         " in-core fits probe per bucket and demote rungs "
                         "that exceed the tier's error budget")
    ap.add_argument("--autotune", action="store_true",
                    help="measure candidate (buckets x precision) shapes on "
                         "a dataset sample first and fit with the winner "
                         "(docs/precision.md); with --tuning-record PATH the "
                         "measured record is persisted there")
    ap.add_argument("--tuning-record", default=None, metavar="PATH",
                    help="persisted autotuner record: with --autotune the "
                         "save destination, otherwise loaded to start the "
                         "fit pre-tuned")
    ap.add_argument("--distributed-hosts", type=int, default=0, metavar="K",
                    help="spawn K rank processes over jax.distributed and "
                         "run the multi-host streaming fit (requires the "
                         "out-of-core path: --store/--write-store)")
    ap.add_argument("--result-json", default=None, metavar="PATH",
                    help="write the run summary as JSON (rank processes "
                         "write PATH.rank<r>; the parent merges them)")
    return ap


def write_store(args):
    """Chunked synthetic generation into a store (bounded RAM)."""
    from repro.data.store import ArrayStore

    # The synthetic dataset is a GP DRAW, so its chunks must come from one
    # shared function realization (paper_synthetic_chunks fixes the RFF
    # weights once); satdrag/metarvm are deterministic simulators of x,
    # so re-seeding their x-sampling per chunk is sound.
    gen_rows = 65536
    if args.dataset == "synthetic":
        from repro.data.gp_sim import paper_synthetic_chunks

        chunks = paper_synthetic_chunks(args.seed, args.n, gen_rows=gen_rows)
    else:
        def _sim_chunks():
            done, part = 0, 0
            while done < args.n:
                k = min(args.n - done, gen_rows)
                yield load_dataset(args.dataset, k, args.seed + part)
                done += k
                part += 1

        chunks = _sim_chunks()
    first_x, first_y = next(chunks)
    with ArrayStore.create(args.write_store, first_x.shape[1]) as w:
        w.append(first_x, first_y)
        for xp, yp in chunks:
            w.append(xp, yp)
    store = ArrayStore(args.write_store)
    print(f"[fit_gp] wrote store {args.write_store}: "
          f"{store.n_rows} rows x {store.d} dims, {store.n_shards} shards")
    return store


# -- multi-host launch ------------------------------------------------------


def _spawn_hosts(args) -> dict:
    """Parent mode: launch K rank copies of this driver and merge results.

    The parent only prepares the store and babysits processes — it never
    touches jax.distributed, so heavy imports are safe here."""
    if args.write_store:
        write_store(args)
        store_dir = args.write_store
    elif args.store:
        store_dir = args.store
    else:
        raise SystemExit("--distributed-hosts requires --store or "
                         "--write-store (ranks share one store directory)")

    from repro.multihost import ENV_COORD, ENV_NPROCS, ENV_RANK

    k = int(args.distributed_hosts)
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()

    child_argv = [sys.executable, "-m", "repro.launch.fit_gp",
                  "--store", store_dir,
                  "--blocks", str(args.blocks), "--m", str(args.m),
                  "--inner-steps", str(args.inner_steps),
                  "--outer-rounds", str(args.outer_rounds),
                  "--backend", args.backend, "--seed", str(args.seed),
                  "--prefetch", str(args.prefetch)]
    if args.stream_chunk:
        child_argv += ["--stream-chunk", str(args.stream_chunk)]
    if args.precision:
        child_argv += ["--precision", args.precision]
    if args.device_cache_mb is not None:
        child_argv += ["--device-cache-mb", str(args.device_cache_mb)]
    if args.result_json:
        child_argv += ["--result-json", args.result_json]

    env = dict(os.environ)
    env.setdefault("PYTHONPATH",
                   os.path.dirname(os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__)))))
    procs = []
    for r in range(k):
        e = dict(env)
        e[ENV_RANK] = str(r)
        e[ENV_NPROCS] = str(k)
        e[ENV_COORD] = f"127.0.0.1:{port}"
        procs.append(subprocess.Popen(child_argv, env=e,
                                      stdout=subprocess.PIPE,
                                      stderr=subprocess.STDOUT))
    failed = False
    for r, p in enumerate(procs):
        out, _ = p.communicate(timeout=3600)
        text = out.decode(errors="replace")
        for line in text.splitlines():
            print(f"[rank {r}] {line}")
        if p.returncode != 0:
            print(f"[fit_gp] rank {r} exited with {p.returncode}")
            failed = True
    if failed:
        raise SystemExit("multi-host fit failed — see rank logs above")

    merged = None
    if args.result_json:
        ranks = []
        for r in range(k):
            with open(f"{args.result_json}.rank{r}") as f:
                ranks.append(json.load(f))
        nlls = [rk["nll"] for rk in ranks]
        merged = {"n_hosts": k, "nll": nlls[0],
                  "max_nll_spread": float(max(nlls) - min(nlls)),
                  "ranks": ranks}
        with open(args.result_json, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"[fit_gp] merged {k} rank results -> {args.result_json} "
              f"(nll={nlls[0]:.9f}, spread={merged['max_nll_spread']:.3g})")
    return merged or {"n_hosts": k}


def _run_rank(ctx, args) -> dict:
    """Child mode: one rank of the multi-host streaming fit.

    Ranks fit only (prediction stays a single-process concern for now)
    and report their partition telemetry + peak RSS so the launcher and
    the benchmarks can assert the per-host memory contract."""
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.data.store import ArrayStore
    from repro.data.streaming import working_set_model
    from repro.memwatch import PeakRssSampler

    if not args.store:
        raise SystemExit("rank processes need --store")
    store = ArrayStore(args.store)
    cfg = SBVConfig(n_blocks=args.blocks, m=args.m, seed=args.seed)
    device_cache = (None if args.device_cache_mb is None
                    else int(args.device_cache_mb * 2**20))

    sampler = PeakRssSampler().start()
    t0 = time.time()
    res = fit_sbv(store, None, cfg, inner_steps=args.inner_steps,
                  outer_rounds=args.outer_rounds, backend=args.backend,
                  stream_chunk=args.stream_chunk, verbose=True,
                  device_cache=device_cache, prefetch=args.prefetch,
                  multihost=ctx, precision=args.precision)
    t_fit = time.time() - t0
    peak = sampler.stop()

    st = res.stream_stats
    ws = working_set_model(st, store.n_rows, store.d, args.m,
                           args.stream_chunk or store.n_rows)
    out = {
        "rank": ctx.rank, "n_hosts": ctx.size,
        "nll": float(res.history[-1][2]), "t_fit_s": t_fit,
        "sigma2": float(res.params.sigma2),
        "beta": np.asarray(res.params.beta).tolist(),
        "nugget": float(res.params.nugget),
        "peak_rss_bytes": peak,
        "working_set_bytes": int(ws["total"]),
        "stats": {key: v for key, v in st.items()
                  if isinstance(v, (int, float, str, bool))},
    }
    print(f"[fit_gp] rank {ctx.rank}/{ctx.size}: nll={out['nll']:.9f} "
          f"fit {t_fit:.1f}s, owned {st.get('owned_rows')}/{store.n_rows} "
          f"rows (+{st.get('halo_rows', 0)} halo), "
          f"exchange {st.get('exchange_bytes', 0) / 2**20:.1f}MB")
    if args.result_json:
        with open(f"{args.result_json}.rank{ctx.rank}", "w") as f:
            json.dump(out, f, indent=1)
    ctx.shutdown()
    return out


def main(argv=None):
    # Rank processes must connect BEFORE any import initializes the JAX
    # backend — repro.multihost imports jax lazily, so this is safe.
    from repro.multihost import MultihostContext

    ctx = MultihostContext.from_env()
    args = build_parser().parse_args(argv)
    if args.outputs > 1 and (args.store or args.write_store
                             or args.distributed_hosts):
        raise SystemExit("--outputs > 1 runs the in-core multi-output fit; "
                         "combine it with --stream-chunk for the streaming "
                         "path, not --store/--write-store/--distributed-hosts")

    if ctx is not None:
        return _run_rank(ctx, args), None
    if args.distributed_hosts and args.distributed_hosts > 1:
        return _spawn_hosts(args), None

    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.core.predict import predict_sbv

    store = None
    if args.store:
        from repro.data.store import ArrayStore

        store = ArrayStore(args.store)
    elif args.write_store:
        store = write_store(args)

    def _tune(x_t, y_t, cfg_t):
        """Resolve the tuning input: measure (--autotune) or load a
        persisted record (--tuning-record without --autotune)."""
        if args.autotune:
            from repro.tuning import autotune_loglik

            t_a = time.time()
            rec = autotune_loglik(x_t, y_t, cfg_t, backend=args.backend,
                                  save_dir=args.tuning_record, verbose=True)
            print(f"[fit_gp] autotune {time.time() - t_a:.1f}s -> "
                  f"buckets={rec.n_buckets} precision={rec.precision} "
                  f"stream-chunk={rec.stream_chunk}")
            return rec
        return args.tuning_record

    if store is not None:
        rng = np.random.default_rng(args.seed + 999)
        # Probe set: a bounded random row sample. The streaming fit trains
        # on every row, so this MSPE is in-sample — a surrogate sanity
        # check, not a generalization score.
        n_test = min(5000, max(1, int(store.n_rows * args.test_frac)))
        x_te, y_te = store.read_rows(
            rng.choice(store.n_rows, size=n_test, replace=False))
        y_te_c = y_te  # streaming path fits the raw observations
        mu_y = 0.0
        cfg = SBVConfig(n_blocks=args.blocks, m=args.m,
                        n_workers=args.workers, seed=args.seed)
        distributed = None
        if args.workers > 1:
            from repro.launch.mesh import make_worker_mesh

            distributed = (make_worker_mesh(args.workers), "workers")
        device_cache = (None if args.device_cache_mb is None
                        else int(args.device_cache_mb * 2**20))
        tuning = None
        if args.autotune or args.tuning_record:
            # Autotune on a bounded head sample of the store; the record's
            # stream_chunk recommendation still uses the FULL row count.
            if args.autotune:
                x_s, y_s = store.read_slice(0, min(store.n_rows, 20_000))
                tuning = _tune(x_s, y_s, cfg)
            else:
                tuning = _tune(None, None, cfg)

        t0 = time.time()
        res = fit_sbv(store, None, cfg, inner_steps=args.inner_steps,
                      outer_rounds=args.outer_rounds, backend=args.backend,
                      stream_chunk=args.stream_chunk, verbose=True,
                      distributed=distributed, device_cache=device_cache,
                      prefetch=args.prefetch, precision=args.precision,
                      tuning=tuning)
        t_fit = time.time() - t0
        beta = np.asarray(res.params.beta)
        st = res.stream_stats
        print(f"[fit_gp] streaming fit {store.n_rows} pts in {t_fit:.1f}s "
              f"({st['n_chunks']} chunks/round, "
              f"{st['device_cached_pieces']}/{st['n_pieces']} pieces "
              f"device-cached, {st['h2d_bytes_per_step'] / 2**20:.1f}MB "
              f"H2D/step); sigma2={float(res.params.sigma2):.4f}")
        print("[fit_gp] relevance 1/beta:", np.round(1.0 / beta, 3))

        t0 = time.time()
        pred = predict_sbv(res.params, store, None, x_te, bs_pred=5,
                           m_pred=args.m_pred, chunk_size=4096,
                           stream_chunk=args.stream_chunk)
        t_pred = time.time() - t0
    else:
        x, y = load_dataset(args.dataset, args.n, args.seed,
                            outputs=args.outputs)
        n_test = int(y.shape[0] * args.test_frac)
        x_tr, y_tr = x[:-n_test], y[:-n_test]
        x_te, y_te = x[-n_test:], y[-n_test:]
        mu_y = y_tr.mean(axis=0)  # per-output centering (scalar when 1-D)
        y_tr_c, y_te_c = y_tr - mu_y, y_te - mu_y

        cfg = SBVConfig(n_blocks=args.blocks, m=args.m, n_workers=args.workers,
                        seed=args.seed)
        distributed = None
        if args.workers > 1:
            from repro.launch.mesh import make_worker_mesh

            mesh = make_worker_mesh(args.workers)
            distributed = (mesh, "workers")

        tuning = _tune(x_tr, y_tr_c, cfg) \
            if (args.autotune or args.tuning_record) else None

        t0 = time.time()
        res = fit_sbv(x_tr, y_tr_c, cfg, inner_steps=args.inner_steps,
                      outer_rounds=args.outer_rounds, backend=args.backend,
                      distributed=distributed, verbose=True,
                      stream_chunk=args.stream_chunk,
                      precision=args.precision, tuning=tuning)
        t_fit = time.time() - t0
        beta = np.asarray(res.params.beta)
        sigma2 = np.asarray(res.params.sigma2)
        nugget = np.asarray(res.params.nugget)
        if sigma2.ndim:  # multi-output: per-output vectors
            print(f"[fit_gp] fit {len(y_tr)} pts x {sigma2.size} outputs in "
                  f"{t_fit:.1f}s; sigma2={np.round(sigma2, 4)} "
                  f"tau2={float(res.params.tau2):.2e}")
        else:
            print(f"[fit_gp] fit {len(y_tr)} pts in {t_fit:.1f}s; "
                  f"sigma2={float(sigma2):.4f} nugget={float(nugget):.2e}")
        print("[fit_gp] relevance 1/beta:", np.round(1.0 / beta, 3))

        t0 = time.time()
        pred = predict_sbv(res.params, x_tr, y_tr_c, x_te,
                           bs_pred=5, m_pred=args.m_pred)
        t_pred = time.time() - t0
    mspe = float(np.mean((pred.mean - y_te_c) ** 2))
    denom = np.where(np.abs(y_te) > 1e-8, y_te, 1.0)
    rmspe = float(np.sqrt(np.mean(((pred.mean + mu_y - y_te) / denom) ** 2))) * 100
    cover = float(np.mean((y_te_c >= pred.ci_low) & (y_te_c <= pred.ci_high))) * 100
    print(f"[fit_gp] predict {n_test} pts in {t_pred:.1f}s: "
          f"MSPE={mspe:.5f} RMSPE={rmspe:.2f}% CI95-coverage={cover:.1f}%")
    if args.result_json:
        payload = {"nll": float(res.history[-1][2]), "t_fit_s": t_fit,
                   "t_predict_s": t_pred, "mspe": mspe, "rmspe_pct": rmspe,
                   "sigma2": np.asarray(res.params.sigma2).tolist(),
                   "beta": np.asarray(res.params.beta).tolist(),
                   "nugget": np.asarray(res.params.nugget).tolist()}
        with open(args.result_json, "w") as f:
            json.dump(payload, f, indent=1)
    return res, mspe


if __name__ == "__main__":
    main()
