"""SBV GP fitting driver — the paper's main entry point.

    PYTHONPATH=src python -m repro.launch.fit_gp --n 20000 --d 10 \
        --blocks 400 --m 60 --workers 1 --dataset synthetic

Datasets: synthetic (paper §6.1), satdrag (§6.2-like), metarvm (§6.3-like).
``--workers k`` runs the distributed likelihood over a k-device mesh
(CPU devices stand in for the paper's MPI ranks).

Out-of-core (docs/streaming.md): ``--store DIR`` fits straight from an
``ArrayStore`` directory instead of materializing the dataset in RAM;
``--write-store DIR`` generates the synthetic dataset chunk-by-chunk into
a store first (then fits from it), and ``--stream-chunk`` bounds the rows
held on host per pass:

    PYTHONPATH=src python -m repro.launch.fit_gp --dataset synthetic \
        --n 1000000 --write-store /tmp/sbv-1m --stream-chunk 131072
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.core.predict import predict_sbv
from repro.data.gp_sim import metarvm_dataset, paper_synthetic, satellite_drag_like


def load_dataset(name: str, n: int, seed: int):
    if name == "synthetic":
        x, y, params = paper_synthetic(seed, n)
        return x, y
    if name == "satdrag":
        return satellite_drag_like(seed, n)
    if name == "metarvm":
        return metarvm_dataset(seed, n)
    raise ValueError(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "satdrag", "metarvm"])
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--blocks", type=int, default=400)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--m-pred", type=int, default=120)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--inner-steps", type=int, default=40)
    ap.add_argument("--outer-rounds", type=int, default=2)
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "pallas", "auto"])
    ap.add_argument("--test-frac", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="fit from an existing ArrayStore directory "
                         "(out-of-core; --n/--dataset are ignored)")
    ap.add_argument("--write-store", default=None, metavar="DIR",
                    help="generate the dataset chunk-by-chunk into a new "
                         "store at DIR, then fit from it")
    ap.add_argument("--stream-chunk", type=int, default=None,
                    help="max dataset rows held on host per streaming pass "
                         "(implies the out-of-core fit path)")
    ap.add_argument("--device-cache-mb", type=float, default=None,
                    help="HBM budget (MB) for the streaming fit's "
                         "device-resident spool tier; default sizes it from "
                         "free device memory, 0 disables the cache")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="disk-tier spool pieces staged ahead of the device "
                         "by the H2D producer thread (0 = synchronous reads)")
    args = ap.parse_args(argv)

    store = None
    if args.store:
        from repro.data.store import ArrayStore

        store = ArrayStore(args.store)
    elif args.write_store:
        from repro.data.store import ArrayStore

        # Chunked generation: bounded RAM even for paper-scale --n. The
        # synthetic dataset is a GP DRAW, so its chunks must come from one
        # shared function realization (paper_synthetic_chunks fixes the
        # RFF weights once); satdrag/metarvm are deterministic simulators
        # of x, so re-seeding their x-sampling per chunk is sound.
        gen_rows = 65536
        if args.dataset == "synthetic":
            from repro.data.gp_sim import paper_synthetic_chunks

            chunks = paper_synthetic_chunks(args.seed, args.n, gen_rows=gen_rows)
        else:
            def _sim_chunks():
                done, part = 0, 0
                while done < args.n:
                    k = min(args.n - done, gen_rows)
                    yield load_dataset(args.dataset, k, args.seed + part)
                    done += k
                    part += 1

            chunks = _sim_chunks()
        first_x, first_y = next(chunks)
        with ArrayStore.create(args.write_store, first_x.shape[1]) as w:
            w.append(first_x, first_y)
            for xp, yp in chunks:
                w.append(xp, yp)
        store = ArrayStore(args.write_store)
        print(f"[fit_gp] wrote store {args.write_store}: "
              f"{store.n_rows} rows x {store.d} dims, {store.n_shards} shards")

    if store is not None:
        rng = np.random.default_rng(args.seed + 999)
        # Probe set: a bounded random row sample. The streaming fit trains
        # on every row, so this MSPE is in-sample — a surrogate sanity
        # check, not a generalization score.
        n_test = min(5000, max(1, int(store.n_rows * args.test_frac)))
        x_te, y_te = store.read_rows(
            rng.choice(store.n_rows, size=n_test, replace=False))
        y_te_c = y_te  # streaming path fits the raw observations
        mu_y = 0.0
        cfg = SBVConfig(n_blocks=args.blocks, m=args.m,
                        n_workers=args.workers, seed=args.seed)
        distributed = None
        if args.workers > 1:
            from repro.launch.mesh import make_worker_mesh

            distributed = (make_worker_mesh(args.workers), "workers")
        device_cache = (None if args.device_cache_mb is None
                        else int(args.device_cache_mb * 2**20))

        t0 = time.time()
        res = fit_sbv(store, None, cfg, inner_steps=args.inner_steps,
                      outer_rounds=args.outer_rounds, backend=args.backend,
                      stream_chunk=args.stream_chunk, verbose=True,
                      distributed=distributed, device_cache=device_cache,
                      prefetch=args.prefetch)
        t_fit = time.time() - t0
        beta = np.asarray(res.params.beta)
        st = res.stream_stats
        print(f"[fit_gp] streaming fit {store.n_rows} pts in {t_fit:.1f}s "
              f"({st['n_chunks']} chunks/round, "
              f"{st['device_cached_pieces']}/{st['n_pieces']} pieces "
              f"device-cached, {st['h2d_bytes_per_step'] / 2**20:.1f}MB "
              f"H2D/step); sigma2={float(res.params.sigma2):.4f}")
        print("[fit_gp] relevance 1/beta:", np.round(1.0 / beta, 3))

        t0 = time.time()
        pred = predict_sbv(res.params, store, None, x_te, bs_pred=5,
                           m_pred=args.m_pred, chunk_size=4096,
                           stream_chunk=args.stream_chunk)
        t_pred = time.time() - t0
    else:
        x, y = load_dataset(args.dataset, args.n, args.seed)
        n_test = int(len(y) * args.test_frac)
        x_tr, y_tr = x[:-n_test], y[:-n_test]
        x_te, y_te = x[-n_test:], y[-n_test:]
        mu_y = y_tr.mean()
        y_tr_c, y_te_c = y_tr - mu_y, y_te - mu_y

        cfg = SBVConfig(n_blocks=args.blocks, m=args.m, n_workers=args.workers,
                        seed=args.seed)
        distributed = None
        if args.workers > 1:
            from repro.launch.mesh import make_worker_mesh

            mesh = make_worker_mesh(args.workers)
            distributed = (mesh, "workers")

        t0 = time.time()
        res = fit_sbv(x_tr, y_tr_c, cfg, inner_steps=args.inner_steps,
                      outer_rounds=args.outer_rounds, backend=args.backend,
                      distributed=distributed, verbose=True,
                      stream_chunk=args.stream_chunk)
        t_fit = time.time() - t0
        beta = np.asarray(res.params.beta)
        print(f"[fit_gp] fit {len(y_tr)} pts in {t_fit:.1f}s; "
              f"sigma2={float(res.params.sigma2):.4f} nugget={float(res.params.nugget):.2e}")
        print("[fit_gp] relevance 1/beta:", np.round(1.0 / beta, 3))

        t0 = time.time()
        pred = predict_sbv(res.params, x_tr, y_tr_c, x_te,
                           bs_pred=5, m_pred=args.m_pred)
        t_pred = time.time() - t0
    mspe = float(np.mean((pred.mean - y_te_c) ** 2))
    denom = np.where(np.abs(y_te) > 1e-8, y_te, 1.0)
    rmspe = float(np.sqrt(np.mean(((pred.mean + mu_y - y_te) / denom) ** 2))) * 100
    cover = float(np.mean((y_te_c >= pred.ci_low) & (y_te_c <= pred.ci_high))) * 100
    print(f"[fit_gp] predict {n_test} pts in {t_pred:.1f}s: "
          f"MSPE={mspe:.5f} RMSPE={rmspe:.2f}% CI95-coverage={cover:.1f}%")
    return res, mspe


if __name__ == "__main__":
    main()
