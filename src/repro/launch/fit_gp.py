"""SBV GP fitting driver — the paper's main entry point.

    PYTHONPATH=src python -m repro.launch.fit_gp --n 20000 --d 10 \
        --blocks 400 --m 60 --workers 1 --dataset synthetic

Datasets: synthetic (paper §6.1), satdrag (§6.2-like), metarvm (§6.3-like).
``--workers k`` runs the distributed likelihood over a k-device mesh
(CPU devices stand in for the paper's MPI ranks).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.core.predict import predict_sbv
from repro.data.gp_sim import metarvm_dataset, paper_synthetic, satellite_drag_like


def load_dataset(name: str, n: int, seed: int):
    if name == "synthetic":
        x, y, params = paper_synthetic(seed, n)
        return x, y
    if name == "satdrag":
        return satellite_drag_like(seed, n)
    if name == "metarvm":
        return metarvm_dataset(seed, n)
    raise ValueError(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "satdrag", "metarvm"])
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--blocks", type=int, default=400)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--m-pred", type=int, default=120)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--inner-steps", type=int, default=40)
    ap.add_argument("--outer-rounds", type=int, default=2)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--test-frac", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    x, y = load_dataset(args.dataset, args.n, args.seed)
    n_test = int(len(y) * args.test_frac)
    x_tr, y_tr = x[:-n_test], y[:-n_test]
    x_te, y_te = x[-n_test:], y[-n_test:]
    mu_y = y_tr.mean()
    y_tr_c, y_te_c = y_tr - mu_y, y_te - mu_y

    cfg = SBVConfig(n_blocks=args.blocks, m=args.m, n_workers=args.workers,
                    seed=args.seed)
    distributed = None
    if args.workers > 1:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh(args.workers)
        distributed = (mesh, "workers")

    t0 = time.time()
    res = fit_sbv(x_tr, y_tr_c, cfg, inner_steps=args.inner_steps,
                  outer_rounds=args.outer_rounds, backend=args.backend,
                  distributed=distributed, verbose=True)
    t_fit = time.time() - t0
    beta = np.asarray(res.params.beta)
    print(f"[fit_gp] fit {len(y_tr)} pts in {t_fit:.1f}s; "
          f"sigma2={float(res.params.sigma2):.4f} nugget={float(res.params.nugget):.2e}")
    print("[fit_gp] relevance 1/beta:", np.round(1.0 / beta, 3))

    t0 = time.time()
    pred = predict_sbv(res.params, x_tr, y_tr_c, x_te,
                       bs_pred=5, m_pred=args.m_pred)
    t_pred = time.time() - t0
    mspe = float(np.mean((pred.mean - y_te_c) ** 2))
    denom = np.where(np.abs(y_te) > 1e-8, y_te, 1.0)
    rmspe = float(np.sqrt(np.mean(((pred.mean + mu_y - y_te) / denom) ** 2))) * 100
    cover = float(np.mean((y_te_c >= pred.ci_low) & (y_te_c <= pred.ci_high))) * 100
    print(f"[fit_gp] predict {n_test} pts in {t_pred:.1f}s: "
          f"MSPE={mspe:.5f} RMSPE={rmspe:.2f}% CI95-coverage={cover:.1f}%")
    return res, mspe


if __name__ == "__main__":
    main()
