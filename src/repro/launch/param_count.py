"""Analytic parameter counts per architecture (for 6*N*D roofline terms)."""
from __future__ import annotations


def _attn_params(cfg) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    q = d * cfg.n_heads * hd
    kv = 2 * d * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * d
    return q + kv + o


def _mlp_params(cfg) -> int:
    if cfg.mlp_kind == "swiglu":
        return 3 * cfg.d_model * cfg.d_ff
    return 2 * cfg.d_model * cfg.d_ff  # relu2: up + down


def _moe_params_per_layer(cfg, active: bool) -> int:
    e = cfg.n_experts_active if active else cfg.n_experts
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    shared = 3 * cfg.d_model * cfg.shared_d_ff if cfg.shared_d_ff else 0
    router = cfg.d_model * cfg.n_experts
    return e * per_expert + shared + router


def _mamba2_params(cfg) -> int:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    in_proj = d * (2 * di + 2 * ns + h)     # x, z, B, C, dt
    conv = cfg.ssm_conv * di
    out = di * d
    return in_proj + conv + out + h + di    # + A, D, skip


def _rwkv6_params(cfg) -> int:
    d = cfg.d_model
    tm = 4 * d * d + d * cfg.d_ff * 0       # r,k,v,g projections + output
    tm = 5 * d * d                           # r,k,v,g,o
    lora = 6 * (d * 32 + 32 * d)            # data-dependent decay LoRAs (approx)
    cm = 2 * d * cfg.d_ff                    # channel mix k,v (+ r: d*d)
    return tm + lora + cm + d * d


def layer_params(cfg, active: bool = False) -> int:
    if cfg.block_kind == "rwkv6":
        return _rwkv6_params(cfg)
    if cfg.block_kind == "mamba2":
        base = _mamba2_params(cfg)
        return base
    # attn stack
    attn = _attn_params(cfg)
    if cfg.n_experts:
        return attn + _moe_params_per_layer(cfg, active)
    return attn + _mlp_params(cfg)


def param_count(cfg, active: bool = False) -> int:
    """Non-embedding parameter count (total or active-per-token for MoE)."""
    n = cfg.n_layers * layer_params(cfg, active)
    if cfg.attn_every:  # zamba2 shared attention block
        n += _attn_params(cfg) + _mlp_params(cfg)
    return n


def active_param_count(cfg) -> int:
    return param_count(cfg, active=True)


def total_param_count(cfg) -> int:
    """Including embeddings (and untied lm_head)."""
    n = param_count(cfg, active=False) + cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model
    return n
