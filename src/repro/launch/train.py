"""LM training driver: mesh + sharded train_step + checkpoint/restart.

CPU-scale entry point exercising the full production path (sharding rules,
set_mesh constraints, checkpoint manager, token stream):

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 20 --mesh 2x2 --ckpt-dir /tmp/ck --ckpt-every 10

On a fleet the same file runs under one process per host with
jax.distributed.initialize(); nothing else changes (the mesh constructor
sees all addressable devices).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager, restore_train_state
from repro.ckpt.checkpoint import latest_checkpoint
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.models.model import init_params
from repro.sharding.rules import batch_spec, param_specs, tp_size
from repro.training.train_step import TrainState, make_train_step, train_state_init
from repro.sharding.compat import set_mesh


def make_mesh(spec: str):
    from repro.launch.mesh import _mesh

    dims = tuple(int(t) for t in spec.split("x"))
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return _mesh(dims, names)


def state_shardings(state, mesh):
    pspecs = param_specs(state.params, mesh)
    sspecs = TrainState(
        params=pspecs,
        opt=type(state.opt)(step=P(), mu=pspecs, nu=pspecs),
        step=P(),
    )
    return jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                        is_leaf=lambda x: isinstance(x, P))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", help="CPU-size config")
    ap.add_argument("--override", action="append", default=[],
                    help="config field override, e.g. --override n_layers=12")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    over = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        over[k] = type(getattr(cfg, k))(v) if not isinstance(getattr(cfg, k), bool) else v == "True"
    if args.reduced:
        cfg = cfg.reduced(**over)
    elif over:
        from dataclasses import replace

        cfg = replace(cfg, **over)
    mesh = make_mesh(args.mesh)
    tp = tp_size(mesh)

    params = init_params(jax.random.key(0), cfg, tp)
    state = train_state_init(params)
    ssh = state_shardings(state, mesh)
    state = jax.device_put(state, ssh)
    bsh = NamedSharding(mesh, batch_spec(mesh, args.batch))

    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=17)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3, install_sigterm=True)
        if args.resume:
            path = latest_checkpoint(args.ckpt_dir)
            if path:
                state, manifest = restore_train_state(path, state, ssh)
                stream.load_state_dict(manifest["extras"]["stream"])
                start_step = int(manifest["step"])
                print(f"[train] resumed from {path} at step {start_step}")

    step_fn = jax.jit(
        make_train_step(cfg, tp=tp, lr=args.lr, grad_accum=args.grad_accum),
        in_shardings=(ssh, bsh, bsh),
        out_shardings=(ssh, NamedSharding(mesh, P())),
        donate_argnums=(0,),
    )

    if mgr:
        # preemption-safe: SIGTERM triggers a final checkpoint
        snap = {"state": state, "step": start_step}
        mgr.register_state_provider(
            lambda: (snap["step"], snap["state"], {"stream": stream.state_dict()})
        )

    with set_mesh(mesh):
        t_last = time.time()
        for i in range(start_step, start_step + args.steps):
            tok, lab = stream.next()
            state, metrics = step_fn(state, jnp.asarray(tok), jnp.asarray(lab))
            if mgr:
                snap = {"state": state, "step": i + 1}
            if (i + 1) % 10 == 0 or i == start_step:
                loss = float(metrics["loss"])
                dt = time.time() - t_last
                t_last = time.time()
                print(f"[train] step {i+1} loss {loss:.4f} ({dt:.2f}s)")
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state, {"stream": stream.state_dict()})
    if mgr:
        mgr.save(start_step + args.steps, state,
                 {"stream": stream.state_dict()}, block=True)
        mgr.close()
    print("[train] done; final loss", float(metrics["loss"]))
    return state


if __name__ == "__main__":
    main()
