"""Batched serving drivers.

LM mode (default): prefill + decode loop with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 64 --max-new 32 --mesh 1x1

GP mode: persistent SBV prediction service (paper Eq. 3; docs/serving.md).
A ``GPServer`` builds the training index + compiled predict program once,
then serves a stream of asynchronous requests: the micro-batcher coalesces
them into fixed-shape padded batches and each batch runs the
double-buffered chunk pipeline (host packs chunk k+1 while the device
computes chunk k).

    PYTHONPATH=src python -m repro.launch.serve gp --n-train 20000 \
        --n-test 100000 --chunk 4096 --bs-pred 25 --m-pred 120 \
        --backend pallas_tiled --dtype f32 --workers 4 --requests 64

``--replicas N`` fronts N scheduler-mode server replicas with the
compile-cache-affinity router (docs/serving.md "Multi-replica routing");
``--distributed-hosts K`` re-launches this driver as K rank processes
over ``jax.distributed``: each rank serves its rendezvous-owned slice of
the request stream through a local router, then the ranks collectively
run the multi-host ``predict_sbv(multihost=)`` parity probe. Heavy jax
imports stay inside ``main``'s LM branch so rank processes can connect
before the JAX backend initializes.

``--compare`` additionally races the synchronous chunk loop against the
double-buffered pipeline on the same workload and cross-checks parity.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _gp_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser("serve gp")
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "satdrag", "metarvm"])
    ap.add_argument("--n-train", type=int, default=20_000)
    ap.add_argument("--outputs", type=int, default=1, metavar="P",
                    help="serve a P-output model (metarvm field variant; "
                         "docs/multioutput.md) — requests carry an output "
                         "mask and results are (n, P)")
    ap.add_argument("--n-test", type=int, default=100_000)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--bs-pred", type=int, default=25)
    ap.add_argument("--m-pred", type=int, default=120)
    ap.add_argument("--backend", default=None,
                    choices=["ref", "pallas", "pallas_tiled", "auto"],
                    help="kernel backend (default ref, or the tuning "
                         "record's choice with --tuning-record)")
    ap.add_argument("--dtype", default="f64", choices=["f32", "f64"],
                    help="packed-array precision; use f32 for the compiled "
                         "(non-interpret) TPU Pallas kernel")
    ap.add_argument("--precision", default=None,
                    choices=["bf16", "f32", "f64"],
                    help="covariance-assembly ladder tier "
                         "(docs/precision.md); overrides --dtype")
    ap.add_argument("--tuning-record", default=None, metavar="PATH",
                    help="start pre-tuned from a persisted autotuner record "
                         "(checkpoint dir or tuning_record.json); fills "
                         "--buckets/--stream-chunk/--precision/--backend "
                         "where those flags are unset")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=32,
                    help="split the test set into this many concurrent "
                         "requests (exercises the micro-batcher)")
    ap.add_argument("--max-points", type=int, default=None,
                    help="micro-batch dispatch threshold (default: --chunk)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="max batching delay after the first queued request")
    ap.add_argument("--adaptive-wait", action="store_true",
                    help="scale the batching window within [0, max-wait-ms] "
                         "from the observed request inter-arrival EMA")
    ap.add_argument("--buckets", type=int, default=None,
                    help="bucket each chunk by size with this many geometric "
                         "ceiling levels per dimension (realized buckets = "
                         "occupied (bs, m) cells, each padded to its own "
                         "ceiling; docs/packing.md); reports padding "
                         "occupancy")
    ap.add_argument("--pipeline", default="double", choices=["double", "sync"],
                    help="double = overlap host packing with device compute")
    ap.add_argument("--scheduler", default="drain",
                    choices=["drain", "continuous"],
                    help="continuous = SGLang-style running batch: SLO-aware "
                         "admission at every chunk boundary, cancellation, "
                         "backpressure (docs/serving.md); drain = the classic "
                         "coalesce-and-drain micro-batcher")
    ap.add_argument("--slo", default="interactive",
                    choices=["interactive", "bulk"],
                    help="SLO class of the generated request stream "
                         "(--scheduler continuous)")
    ap.add_argument("--queue-bound", type=int, default=None, metavar="POINTS",
                    help="bound the admission queue at this many queued "
                         "points; overflowing submits fail fast with "
                         "AdmissionQueueFull (--scheduler continuous)")
    ap.add_argument("--spool-threshold", type=int, default=None,
                    metavar="POINTS",
                    help="requests at least this large stream results to a "
                         "disk spool sink instead of RAM "
                         "(--scheduler continuous)")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="front N server replicas (threads sharing one "
                         "training index) with the shape-affinity router "
                         "(docs/serving.md); implies --scheduler continuous")
    ap.add_argument("--routing", default="affinity",
                    choices=["affinity", "random", "round_robin"],
                    help="replica routing policy (--replicas > 1): affinity "
                         "= rendezvous-hashed compile-shape signature with "
                         "least-outstanding-work spill")
    ap.add_argument("--spill-points", type=int, default=None, metavar="PTS",
                    help="spill an affinity-routed request to the least "
                         "loaded replica when its preferred replica has "
                         "more than this many outstanding points")
    ap.add_argument("--distributed-hosts", type=int, default=0, metavar="K",
                    help="spawn K rank processes over jax.distributed: each "
                         "serves its rendezvous-owned request slice through "
                         "a local router, then all ranks run the multi-host "
                         "predict_sbv(multihost=) parity probe "
                         "(synthetic dataset only)")
    ap.add_argument("--result-json", default=None, metavar="PATH",
                    help="write the serve summary as JSON (rank processes "
                         "write PATH.rank<r>; the parent merges them)")
    ap.add_argument("--compare", action="store_true",
                    help="race sync vs double-buffered on the same workload "
                         "and cross-check parity against predict_sbv")
    ap.add_argument("--train-store", default=None, metavar="DIR",
                    help="serve from an on-disk ArrayStore training set "
                         "(out-of-core index; docs/streaming.md) — requires "
                         "fitted params, so only --dataset synthetic")
    ap.add_argument("--stream-chunk", type=int, default=None,
                    help="rows per streaming-index pass (with --train-store)")
    return ap


# -- multi-host serve launch ------------------------------------------------


def _spawn_serve_hosts(args) -> dict:
    """Parent mode: launch K rank copies of ``serve gp`` and merge results.

    The parent never touches jax.distributed — it only picks a
    coordinator port, babysits the rank processes, and merges their
    ``--result-json`` files (mirrors ``fit_gp._spawn_hosts``)."""
    import json
    import os
    import socket
    import subprocess
    import sys

    from repro.multihost import ENV_COORD, ENV_NPROCS, ENV_RANK

    if args.dataset != "synthetic" or args.train_store:
        raise SystemExit("--distributed-hosts serves the in-core synthetic "
                         "dataset (ranks regenerate it deterministically)")
    if args.workers > 1 or args.outputs > 1:
        raise SystemExit("--distributed-hosts is exclusive with --workers "
                         "and --outputs (one device per rank)")

    k = int(args.distributed_hosts)
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()

    child_argv = [sys.executable, "-m", "repro.launch.serve", "gp",
                  "--n-train", str(args.n_train),
                  "--n-test", str(args.n_test),
                  "--chunk", str(args.chunk),
                  "--bs-pred", str(args.bs_pred),
                  "--m-pred", str(args.m_pred),
                  "--backend", args.backend, "--dtype", args.dtype,
                  "--seed", str(args.seed),
                  "--requests", str(args.requests),
                  "--replicas", str(max(1, args.replicas)),
                  "--routing", args.routing,
                  "--scheduler", "continuous", "--slo", args.slo]
    if args.precision:
        child_argv += ["--precision", args.precision]
    if args.buckets:
        child_argv += ["--buckets", str(args.buckets)]
    if args.spill_points is not None:
        child_argv += ["--spill-points", str(args.spill_points)]
    if args.result_json:
        child_argv += ["--result-json", args.result_json]

    env = dict(os.environ)
    env.setdefault("PYTHONPATH",
                   os.path.dirname(os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__)))))
    procs = []
    for r in range(k):
        e = dict(env)
        e[ENV_RANK] = str(r)
        e[ENV_NPROCS] = str(k)
        e[ENV_COORD] = f"127.0.0.1:{port}"
        procs.append(subprocess.Popen(child_argv, env=e,
                                      stdout=subprocess.PIPE,
                                      stderr=subprocess.STDOUT))
    failed = False
    for r, p in enumerate(procs):
        out, _ = p.communicate(timeout=3600)
        for line in out.decode(errors="replace").splitlines():
            print(f"[rank {r}] {line}")
        if p.returncode != 0:
            print(f"[serve-gp] rank {r} exited with {p.returncode}")
            failed = True
    if failed:
        raise SystemExit("multi-host serve failed — see rank logs above")

    merged = {"n_hosts": k}
    if args.result_json:
        ranks = []
        for r in range(k):
            with open(f"{args.result_json}.rank{r}") as f:
                ranks.append(json.load(f))
        merged = {
            "n_hosts": k,
            "n_requests": sum(rk["n_requests"] for rk in ranks),
            "n_points": sum(rk["n_points"] for rk in ranks),
            "multihost_parity_max": max(rk["multihost_parity_max"]
                                        for rk in ranks),
            "served_parity_max": max(rk["served_parity_max"]
                                     for rk in ranks),
            "ranks": ranks,
        }
        with open(args.result_json, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"[serve-gp] merged {k} rank results -> {args.result_json} "
              f"(multihost parity={merged['multihost_parity_max']:.3g}, "
              f"served parity={merged['served_parity_max']:.3g})")
    return merged


def _serve_rank(ctx, args, params, x, y, x_test, cfg) -> dict:
    """Child mode: one rank of the multi-host serve plane.

    Each rank fronts its local replicas with a router and serves the
    slice of the request stream whose rendezvous owner it is (zero
    coordination — every rank computes the same ownership table from the
    request index). The collective part follows: every rank runs
    ``predict_sbv(multihost=ctx)`` over the FULL test set (blocks
    sharded by owner, one allreduce merge) and checks it against its own
    serial ``predict_sbv`` — the cross-host prediction parity probe."""
    import json

    from repro.core.predict import predict_sbv
    from repro.serving import GPServer, ReplicaRouter
    from repro.serving.router import rendezvous_rank

    servers = [GPServer(params, x, y, cfg)]
    servers += [GPServer(params, x, y, cfg, index=servers[0].index)
                for _ in range(max(1, args.replicas) - 1)]
    router = ReplicaRouter(servers, routing=args.routing,
                           spill_points=args.spill_points, seed=args.seed)

    bounds = np.linspace(0, args.n_test, args.requests + 1).astype(int)
    spans = [(a, b) for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
    owned = [i for i in range(len(spans))
             if rendezvous_rank(("req", i), ctx.size,
                                salt=args.seed) == ctx.rank]
    with router:
        router.warmup()
        t0 = time.time()
        futs = {i: router.submit(x_test[spans[i][0]:spans[i][1]],
                                 slo=args.slo) for i in owned}
        served = {i: f.result() for i, f in futs.items()}
        dt = time.time() - t0

    dtype = np.float32 if args.dtype == "f32" else np.float64
    kw = dict(bs_pred=args.bs_pred, m_pred=args.m_pred, seed=args.seed,
              n_sims=2, chunk_size=args.chunk, backend=args.backend,
              dtype=dtype, n_buckets=args.buckets, precision=args.precision)
    t0 = time.time()
    mh = predict_sbv(params, x, y, x_test, multihost=ctx, **kw)
    t_mh = time.time() - t0
    serial = predict_sbv(params, x, y, x_test, **kw)
    parity = max(
        float(np.abs(mh.mean - serial.mean).max()),
        float(np.abs(mh.var - serial.var).max()),
        float(np.abs(mh.sim_mean - serial.sim_mean).max()),
    )
    # Scheduler-mode replicas pack with the base seed, so each served
    # request must reproduce ITS OWN lone predict_sbv call under any
    # routing (the 1e-12 parity contract); probe a bounded sample.
    served_err = 0.0
    for i in owned[:4]:
        a, b = spans[i]
        ref = predict_sbv(params, x, y, x_test[a:b], **kw)
        served_err = max(
            served_err,
            float(np.abs(np.asarray(served[i].mean) - ref.mean).max()),
            float(np.abs(np.asarray(served[i].var) - ref.var).max()))

    rs = router.stats.summary()
    out = {
        "rank": ctx.rank, "n_hosts": ctx.size,
        "n_requests": len(owned),
        "n_points": int(sum(spans[i][1] - spans[i][0] for i in owned)),
        "serve_s": dt, "multihost_predict_s": t_mh,
        "multihost_parity_max": parity,
        "served_parity_max": served_err,
        "affinity_hit_rate": rs["affinity_hit_rate"],
        "replica_requests": rs["replica_requests"],
        "total_compiled_shapes": router.summary()["total_compiled_shapes"],
    }
    print(f"[serve-gp] rank {ctx.rank}/{ctx.size}: served "
          f"{out['n_requests']}/{len(spans)} requests "
          f"({out['n_points']} pts) in {dt:.2f}s over "
          f"{len(servers)} replicas ({args.routing}); multihost predict "
          f"{t_mh:.2f}s parity={parity:.3g} served parity={served_err:.3g}")
    if args.result_json:
        with open(f"{args.result_json}.rank{ctx.rank}", "w") as f:
            json.dump(out, f, indent=1)
    ctx.shutdown()
    return out


def serve_gp(argv=None):
    """Persistent micro-batching SBV prediction service.

    The test set is split into ``--requests`` asynchronous requests that
    are submitted concurrently; the server coalesces them into padded
    micro-batches and runs each through the double-buffered chunk
    pipeline. ``--workers k`` shards every chunk's prediction blocks over
    a k-device mesh (``distributed_predict``); the scatter stays
    host-side. ``--pipeline sync`` falls back to the strictly serial
    chunk loop (the pre-server behavior), and ``--compare`` races both
    on the same workload. ``--replicas N`` serves through the
    compile-cache-affinity ``ReplicaRouter``."""
    # Rank processes must connect BEFORE anything initializes the JAX
    # backend (repro.multihost imports jax lazily, so this is safe).
    from repro.multihost import MultihostContext

    ctx = MultihostContext.from_env()
    args = _gp_parser().parse_args(argv)
    if args.tuning_record:
        from repro.tuning import as_record

        rec = as_record(args.tuning_record)
        if args.buckets is None:
            args.buckets = rec.n_buckets
        if args.stream_chunk is None:
            args.stream_chunk = rec.stream_chunk
        if args.precision is None:
            args.precision = rec.precision
        if args.backend is None and rec.backend:
            args.backend = rec.backend
        print(f"[serve-gp] tuning record: buckets={args.buckets} "
              f"precision={args.precision} backend={args.backend} "
              f"stream-chunk={args.stream_chunk}")
    if args.backend is None:
        args.backend = "ref"
    if ctx is None and args.distributed_hosts and args.distributed_hosts > 1:
        return _spawn_serve_hosts(args)
    if (args.replicas > 1 or ctx is not None) \
            and args.scheduler != "continuous":
        print("[serve-gp] replica routing requires the continuous "
              "scheduler; enabling it")
        args.scheduler = "continuous"
    dtype = np.float32 if args.dtype == "f32" else np.float64

    from repro.data.gp_sim import paper_synthetic
    from repro.launch.fit_gp import load_dataset
    from repro.serving import (
        BatchingPolicy, GPServer, GPServerConfig, PipelineConfig,
        ReplicaRouter, SchedulerPolicy, predict_pipelined,
        predict_synchronous,
    )

    if args.outputs > 1 and (args.train_store or args.dataset == "synthetic"):
        raise SystemExit("--outputs > 1 requires --dataset metarvm "
                         "(in-core; the multi-output field variant)")
    if args.train_store:
        from repro.data.store import ArrayStore

        if args.dataset != "synthetic":
            raise SystemExit("--train-store serves synthetic-generator "
                             "params; fit other datasets via fit_gp first")
        store = ArrayStore(args.train_store)
        # Kernel params from the same generator family (the store is
        # assumed to hold a draw of it); the index is built out-of-core.
        _, _, params = paper_synthetic(args.seed, 128, d=store.d)
        x, y = store, None
    elif args.dataset == "synthetic":
        x, y, params = paper_synthetic(args.seed, args.n_train)
    else:
        x, y = load_dataset(args.dataset, args.n_train, args.seed,
                            outputs=args.outputs)
        from repro.core.fit import fit_sbv
        from repro.core.pipeline import SBVConfig

        cfg = SBVConfig(n_blocks=max(1, args.n_train // 128), m=60, seed=args.seed)
        params = fit_sbv(x, y, cfg, inner_steps=30, outer_rounds=1).params

    rng = np.random.default_rng(args.seed + 1)
    d = x.d if args.train_store else x.shape[1]
    x_test = rng.uniform(size=(args.n_test, d))

    mesh = None
    if args.workers > 1:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh(args.workers)

    pipe_cfg = PipelineConfig(
        bs_pred=args.bs_pred, m_pred=args.m_pred, backend=args.backend,
        dtype=dtype, chunk_size=args.chunk, n_workers=args.workers,
        n_buckets=args.buckets, stream_chunk=args.stream_chunk,
        precision=args.precision,
    )
    sched_policy = None
    if args.scheduler == "continuous":
        sched_policy = SchedulerPolicy(queue_bound=args.queue_bound,
                                       spool_threshold=args.spool_threshold)
    cfg = GPServerConfig(
        pipeline=pipe_cfg,
        policy=BatchingPolicy(max_points=args.max_points or args.chunk,
                              max_wait_s=args.max_wait_ms / 1e3,
                              adaptive=args.adaptive_wait),
        scheduler=sched_policy,
        pipelined=args.pipeline == "double",
        seed=args.seed,
    )
    if ctx is not None:
        return _serve_rank(ctx, args, params, x, y, x_test, cfg)

    t0 = time.time()
    server = GPServer(params, x, y, cfg, mesh=mesh)
    replicas = [server]
    replicas += [GPServer(params, x, y, cfg, mesh=mesh, index=server.index)
                 for _ in range(args.replicas - 1)]
    n_train = x.n_rows if args.train_store else len(y)
    print(f"[serve-gp] train index over {n_train} pts "
          f"(x{len(replicas)} replicas): {time.time()-t0:.2f}s")
    front = server if args.replicas == 1 else ReplicaRouter(
        replicas, routing=args.routing, spill_points=args.spill_points,
        seed=args.seed)

    with front:
        t0 = time.time()
        front.warmup()
        print(f"[serve-gp] warmup (compile): {time.time()-t0:.2f}s")

        # Concurrent request stream: near-equal splits of the test set.
        bounds = np.linspace(0, args.n_test, args.requests + 1).astype(int)
        t0 = time.time()
        futs = [front.submit(x_test[a:b], slo=args.slo)
                for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
        results = [f.result() for f in futs]
        dt = time.time() - t0

        if server.n_outputs > 1:
            # Exercise the per-request output mask: a masked request's
            # result carries just the requested columns.
            fut = front.submit(x_test[:min(64, args.n_test)], slo=args.slo,
                               outputs=[server.n_outputs - 1])
            front.flush()
            masked = fut.result()
            assert masked.mean.shape[1] == 1, masked.mean.shape
            print(f"[serve-gp] {server.n_outputs}-output model; masked "
                  f"request returned {masked.mean.shape} (1 column)")

    def _arrays(res):
        return res.sink.materialize() if res.sink is not None \
            else (res.mean, res.var)

    parts = [_arrays(r) for r in results]
    mean = np.concatenate([m for m, _ in parts])
    var = np.concatenate([v for _, v in parts])
    stats = server.stats.summary()
    print(f"[serve-gp] {args.n_test} predictions / {len(futs)} requests in "
          f"{dt:.2f}s: {args.n_test/dt:.0f} pts/s (backend={args.backend}, "
          f"workers={args.workers}, pipeline={args.pipeline}, "
          f"scheduler={args.scheduler})")
    print(f"[serve-gp] batches={stats['n_batches']} "
          f"occupancy={stats['mean_batch_points']:.0f} pts/batch "
          f"latency p50={stats['latency_p50_s']*1e3:.1f}ms "
          f"p95={stats['latency_p95_s']*1e3:.1f}ms "
          f"compiled-shapes={stats['n_compiled_shapes']} "
          f"padding-occupancy={stats['padding_occupancy']:.3f}")
    if args.replicas > 1:
        rsum = front.summary()
        print(f"[serve-gp] router: replicas={args.replicas} "
              f"routing={args.routing} "
              f"affinity-hit={rsum['affinity_hit_rate']:.2f} "
              f"spill-rate={rsum['spill_rate']:.2f} "
              f"requests={rsum['replica_requests']} "
              f"shapes={[r['n_compiled_shapes'] for r in rsum['replicas']]} "
              f"(total {rsum['total_compiled_shapes']})")
    if args.scheduler == "continuous":
        per_cls = " ".join(
            f"{name}: n={c['n']} p50={c['latency_p50_s']*1e3:.1f}ms "
            f"p99={c['latency_p99_s']*1e3:.1f}ms"
            for name, c in stats["by_class"].items())
        print(f"[serve-gp] {per_cls} | queue-peak={stats['queue_depth_peak']} "
              f"preempted={stats['n_preempted']} "
              f"rejected={stats['n_rejected']} "
              f"cancelled={stats['n_cancelled']}")
    assert np.all(np.isfinite(mean)) and np.all(var > 0)

    if args.result_json:
        import json

        out = {"n_test": args.n_test, "n_requests": len(futs),
               "elapsed_s": dt, "points_per_s": args.n_test / dt,
               "server": {k: v for k, v in stats.items()
                          if isinstance(v, (int, float, str, bool))}}
        if args.replicas > 1:
            out["router"] = front.summary()
        with open(args.result_json, "w") as f:
            json.dump(out, f, indent=1)

    if args.compare:
        from repro.core.predict import predict_sbv

        # Warm the jit cache on the exact chunk-shape sequence first so the
        # race measures steady-state serving, not compilation.
        predict_synchronous(params, server.index, x_test, pipe_cfg,
                            seed=args.seed, mesh=mesh)
        for name, runner in (("sync", predict_synchronous),
                             ("double", predict_pipelined)):
            t0 = time.time()
            m_r, v_r = runner(params, server.index, x_test, pipe_cfg,
                              seed=args.seed, mesh=mesh)
            dt_r = time.time() - t0
            print(f"[serve-gp] compare {name:6s}: {dt_r:.2f}s "
                  f"({args.n_test/dt_r:.0f} pts/s)")
            if name == "sync":
                m_sync, v_sync = m_r, v_r
        err = max(abs(m_r - m_sync).max(), abs(v_r - v_sync).max())
        print(f"[serve-gp] compare parity double vs sync: max|delta|={err:.2e}")
        assert err == 0.0, "pipelined chunk loop must be bitwise equal to sync"
        ref = predict_sbv(params, x, y, x_test, bs_pred=args.bs_pred,
                          m_pred=args.m_pred, seed=args.seed, n_sims=2,
                          chunk_size=args.chunk, n_workers=args.workers,
                          backend="ref", dtype=dtype,
                          stream_chunk=args.stream_chunk,
                          precision=args.precision)
        err = max(abs(m_r - ref.mean).max(), abs(v_r - ref.var).max())
        # Cross-BACKEND parity at a narrow tier is bounded by the tier's
        # assembly rounding, not by the f64 chunk-protocol tolerance.
        tol = {"bf16": 0.5, "f32": 1e-3}.get(
            args.precision, 1e-5 if dtype == np.float64 else 1e-3)
        print(f"[serve-gp] compare parity vs predict_sbv: max|delta|={err:.2e}")
        assert err <= tol, err

    # Serving returns the analytic conditionals only; conditional-simulation
    # UQ (paper §5.1.5) is the library path: predict_sbv(..., n_sims=...).
    return mean, var


def main(argv=None):
    import sys

    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "gp":
        return serve_gp(argv[1:])

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.train import make_mesh
    from repro.models.model import init_params, prefill_step, serve_step
    from repro.sharding.compat import set_mesh
    from repro.sharding.rules import cache_specs, param_specs, tp_size

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(args.mesh)
    tp = tp_size(mesh)
    cache_len = args.prompt_len + args.max_new

    params = init_params(jax.random.key(0), cfg, tp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       param_specs(params, mesh),
                       is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, psh)

    rng = np.random.default_rng(3)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)), jnp.int32
    )

    with set_mesh(mesh):
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, t: prefill_step(p, t, cfg, cache_len, tp=tp)
        )(params, prompt)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           cache_specs(cache, mesh),
                           is_leaf=lambda x: isinstance(x, P))
        cache = jax.device_put(cache, csh)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

        decode = jax.jit(
            lambda p, t, c: serve_step(p, t, c, cfg, tp=tp),
            donate_argnums=(2,),
        )
        out = [tok]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        dt = time.time() - t0
        toks = jnp.concatenate(out, axis=1)
    rate = args.batch * (args.max_new - 1) / dt
    print(f"[serve] decoded {args.max_new-1} steps x {args.batch} seqs: "
          f"{dt:.2f}s ({rate:.1f} tok/s)")
    print("[serve] sample tokens:", np.asarray(toks[0, :16]))
    return toks


if __name__ == "__main__":
    main()
