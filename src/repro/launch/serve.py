"""Batched serving driver: prefill + decode loop with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 64 --max-new 32 --mesh 1x1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import init_params, prefill_step, serve_step
from repro.sharding.rules import batch_spec, cache_specs, param_specs, tp_size
from repro.launch.train import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(args.mesh)
    tp = tp_size(mesh)
    cache_len = args.prompt_len + args.max_new

    params = init_params(jax.random.key(0), cfg, tp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       param_specs(params, mesh),
                       is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, psh)

    rng = np.random.default_rng(3)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)), jnp.int32
    )

    with jax.set_mesh(mesh):
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, t: prefill_step(p, t, cfg, cache_len, tp=tp)
        )(params, prompt)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           cache_specs(cache, mesh),
                           is_leaf=lambda x: isinstance(x, P))
        cache = jax.device_put(cache, csh)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

        decode = jax.jit(
            lambda p, t, c: serve_step(p, t, c, cfg, tp=tp),
            donate_argnums=(2,),
        )
        out = [tok]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        dt = time.time() - t0
        toks = jnp.concatenate(out, axis=1)
    rate = args.batch * (args.max_new - 1) / dt
    print(f"[serve] decoded {args.max_new-1} steps x {args.batch} seqs: "
          f"{dt:.2f}s ({rate:.1f} tok/s)")
    print("[serve] sample tokens:", np.asarray(toks[0, :16]))
    return toks


if __name__ == "__main__":
    main()
