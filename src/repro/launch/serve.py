"""Batched serving drivers.

LM mode (default): prefill + decode loop with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 64 --max-new 32 --mesh 1x1

GP mode: persistent SBV prediction service (paper Eq. 3; docs/serving.md).
A ``GPServer`` builds the training index + compiled predict program once,
then serves a stream of asynchronous requests: the micro-batcher coalesces
them into fixed-shape padded batches and each batch runs the
double-buffered chunk pipeline (host packs chunk k+1 while the device
computes chunk k).

    PYTHONPATH=src python -m repro.launch.serve gp --n-train 20000 \
        --n-test 100000 --chunk 4096 --bs-pred 25 --m-pred 120 \
        --backend pallas_tiled --dtype f32 --workers 4 --requests 64

``--compare`` additionally races the synchronous chunk loop against the
double-buffered pipeline on the same workload and cross-checks parity.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import init_params, prefill_step, serve_step
from repro.sharding.compat import set_mesh
from repro.sharding.rules import cache_specs, param_specs, tp_size
from repro.launch.train import make_mesh


def serve_gp(argv=None):
    """Persistent micro-batching SBV prediction service.

    The test set is split into ``--requests`` asynchronous requests that
    are submitted concurrently; the server coalesces them into padded
    micro-batches and runs each through the double-buffered chunk
    pipeline. ``--workers k`` shards every chunk's prediction blocks over
    a k-device mesh (``distributed_predict``); the scatter stays
    host-side. ``--pipeline sync`` falls back to the strictly serial
    chunk loop (the pre-server behavior), and ``--compare`` races both
    on the same workload."""
    ap = argparse.ArgumentParser("serve gp")
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "satdrag", "metarvm"])
    ap.add_argument("--n-train", type=int, default=20_000)
    ap.add_argument("--outputs", type=int, default=1, metavar="P",
                    help="serve a P-output model (metarvm field variant; "
                         "docs/multioutput.md) — requests carry an output "
                         "mask and results are (n, P)")
    ap.add_argument("--n-test", type=int, default=100_000)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--bs-pred", type=int, default=25)
    ap.add_argument("--m-pred", type=int, default=120)
    ap.add_argument("--backend", default=None,
                    choices=["ref", "pallas", "pallas_tiled", "auto"],
                    help="kernel backend (default ref, or the tuning "
                         "record's choice with --tuning-record)")
    ap.add_argument("--dtype", default="f64", choices=["f32", "f64"],
                    help="packed-array precision; use f32 for the compiled "
                         "(non-interpret) TPU Pallas kernel")
    ap.add_argument("--precision", default=None,
                    choices=["bf16", "f32", "f64"],
                    help="covariance-assembly ladder tier "
                         "(docs/precision.md); overrides --dtype")
    ap.add_argument("--tuning-record", default=None, metavar="PATH",
                    help="start pre-tuned from a persisted autotuner record "
                         "(checkpoint dir or tuning_record.json); fills "
                         "--buckets/--stream-chunk/--precision/--backend "
                         "where those flags are unset")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=32,
                    help="split the test set into this many concurrent "
                         "requests (exercises the micro-batcher)")
    ap.add_argument("--max-points", type=int, default=None,
                    help="micro-batch dispatch threshold (default: --chunk)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="max batching delay after the first queued request")
    ap.add_argument("--adaptive-wait", action="store_true",
                    help="scale the batching window within [0, max-wait-ms] "
                         "from the observed request inter-arrival EMA")
    ap.add_argument("--buckets", type=int, default=None,
                    help="bucket each chunk by size with this many geometric "
                         "ceiling levels per dimension (realized buckets = "
                         "occupied (bs, m) cells, each padded to its own "
                         "ceiling; docs/packing.md); reports padding "
                         "occupancy")
    ap.add_argument("--pipeline", default="double", choices=["double", "sync"],
                    help="double = overlap host packing with device compute")
    ap.add_argument("--scheduler", default="drain",
                    choices=["drain", "continuous"],
                    help="continuous = SGLang-style running batch: SLO-aware "
                         "admission at every chunk boundary, cancellation, "
                         "backpressure (docs/serving.md); drain = the classic "
                         "coalesce-and-drain micro-batcher")
    ap.add_argument("--slo", default="interactive",
                    choices=["interactive", "bulk"],
                    help="SLO class of the generated request stream "
                         "(--scheduler continuous)")
    ap.add_argument("--queue-bound", type=int, default=None, metavar="POINTS",
                    help="bound the admission queue at this many queued "
                         "points; overflowing submits fail fast with "
                         "AdmissionQueueFull (--scheduler continuous)")
    ap.add_argument("--spool-threshold", type=int, default=None,
                    metavar="POINTS",
                    help="requests at least this large stream results to a "
                         "disk spool sink instead of RAM "
                         "(--scheduler continuous)")
    ap.add_argument("--compare", action="store_true",
                    help="race sync vs double-buffered on the same workload "
                         "and cross-check parity against predict_sbv")
    ap.add_argument("--train-store", default=None, metavar="DIR",
                    help="serve from an on-disk ArrayStore training set "
                         "(out-of-core index; docs/streaming.md) — requires "
                         "fitted params, so only --dataset synthetic")
    ap.add_argument("--stream-chunk", type=int, default=None,
                    help="rows per streaming-index pass (with --train-store)")
    args = ap.parse_args(argv)
    if args.tuning_record:
        from repro.tuning import as_record

        rec = as_record(args.tuning_record)
        if args.buckets is None:
            args.buckets = rec.n_buckets
        if args.stream_chunk is None:
            args.stream_chunk = rec.stream_chunk
        if args.precision is None:
            args.precision = rec.precision
        if args.backend is None and rec.backend:
            args.backend = rec.backend
        print(f"[serve-gp] tuning record: buckets={args.buckets} "
              f"precision={args.precision} backend={args.backend} "
              f"stream-chunk={args.stream_chunk}")
    if args.backend is None:
        args.backend = "ref"
    dtype = np.float32 if args.dtype == "f32" else np.float64

    from repro.data.gp_sim import paper_synthetic
    from repro.launch.fit_gp import load_dataset
    from repro.serving import (
        BatchingPolicy, GPServer, GPServerConfig, PipelineConfig,
        SchedulerPolicy, predict_pipelined, predict_synchronous,
    )

    if args.outputs > 1 and (args.train_store or args.dataset == "synthetic"):
        raise SystemExit("--outputs > 1 requires --dataset metarvm "
                         "(in-core; the multi-output field variant)")
    if args.train_store:
        from repro.data.store import ArrayStore

        if args.dataset != "synthetic":
            raise SystemExit("--train-store serves synthetic-generator "
                             "params; fit other datasets via fit_gp first")
        store = ArrayStore(args.train_store)
        # Kernel params from the same generator family (the store is
        # assumed to hold a draw of it); the index is built out-of-core.
        _, _, params = paper_synthetic(args.seed, 128, d=store.d)
        x, y = store, None
    elif args.dataset == "synthetic":
        x, y, params = paper_synthetic(args.seed, args.n_train)
    else:
        x, y = load_dataset(args.dataset, args.n_train, args.seed,
                            outputs=args.outputs)
        from repro.core.fit import fit_sbv
        from repro.core.pipeline import SBVConfig

        cfg = SBVConfig(n_blocks=max(1, args.n_train // 128), m=60, seed=args.seed)
        params = fit_sbv(x, y, cfg, inner_steps=30, outer_rounds=1).params

    rng = np.random.default_rng(args.seed + 1)
    d = x.d if args.train_store else x.shape[1]
    x_test = rng.uniform(size=(args.n_test, d))

    mesh = None
    if args.workers > 1:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh(args.workers)

    pipe_cfg = PipelineConfig(
        bs_pred=args.bs_pred, m_pred=args.m_pred, backend=args.backend,
        dtype=dtype, chunk_size=args.chunk, n_workers=args.workers,
        n_buckets=args.buckets, stream_chunk=args.stream_chunk,
        precision=args.precision,
    )
    sched_policy = None
    if args.scheduler == "continuous":
        sched_policy = SchedulerPolicy(queue_bound=args.queue_bound,
                                       spool_threshold=args.spool_threshold)
    cfg = GPServerConfig(
        pipeline=pipe_cfg,
        policy=BatchingPolicy(max_points=args.max_points or args.chunk,
                              max_wait_s=args.max_wait_ms / 1e3,
                              adaptive=args.adaptive_wait),
        scheduler=sched_policy,
        pipelined=args.pipeline == "double",
        seed=args.seed,
    )

    t0 = time.time()
    server = GPServer(params, x, y, cfg, mesh=mesh)
    n_train = x.n_rows if args.train_store else len(y)
    print(f"[serve-gp] train index over {n_train} pts: {time.time()-t0:.2f}s")

    with server:
        t0 = time.time()
        server.warmup()
        print(f"[serve-gp] warmup (compile): {time.time()-t0:.2f}s")

        # Concurrent request stream: near-equal splits of the test set.
        bounds = np.linspace(0, args.n_test, args.requests + 1).astype(int)
        t0 = time.time()
        futs = [server.submit(x_test[a:b], slo=args.slo)
                for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
        results = [f.result() for f in futs]
        dt = time.time() - t0

        if server.n_outputs > 1:
            # Exercise the per-request output mask: a masked request's
            # result carries just the requested columns.
            fut = server.submit(x_test[:min(64, args.n_test)], slo=args.slo,
                                outputs=[server.n_outputs - 1])
            server.flush()
            masked = fut.result()
            assert masked.mean.shape[1] == 1, masked.mean.shape
            print(f"[serve-gp] {server.n_outputs}-output model; masked "
                  f"request returned {masked.mean.shape} (1 column)")

    def _arrays(res):
        return res.sink.materialize() if res.sink is not None \
            else (res.mean, res.var)

    parts = [_arrays(r) for r in results]
    mean = np.concatenate([m for m, _ in parts])
    var = np.concatenate([v for _, v in parts])
    stats = server.stats.summary()
    print(f"[serve-gp] {args.n_test} predictions / {len(futs)} requests in "
          f"{dt:.2f}s: {args.n_test/dt:.0f} pts/s (backend={args.backend}, "
          f"workers={args.workers}, pipeline={args.pipeline}, "
          f"scheduler={args.scheduler})")
    print(f"[serve-gp] batches={stats['n_batches']} "
          f"occupancy={stats['mean_batch_points']:.0f} pts/batch "
          f"latency p50={stats['latency_p50_s']*1e3:.1f}ms "
          f"p95={stats['latency_p95_s']*1e3:.1f}ms "
          f"compiled-shapes={stats['n_compiled_shapes']} "
          f"padding-occupancy={stats['padding_occupancy']:.3f}")
    if args.scheduler == "continuous":
        per_cls = " ".join(
            f"{name}: n={c['n']} p50={c['latency_p50_s']*1e3:.1f}ms "
            f"p99={c['latency_p99_s']*1e3:.1f}ms"
            for name, c in stats["by_class"].items())
        print(f"[serve-gp] {per_cls} | queue-peak={stats['queue_depth_peak']} "
              f"preempted={stats['n_preempted']} "
              f"rejected={stats['n_rejected']} "
              f"cancelled={stats['n_cancelled']}")
    assert np.all(np.isfinite(mean)) and np.all(var > 0)

    if args.compare:
        from repro.core.predict import predict_sbv

        # Warm the jit cache on the exact chunk-shape sequence first so the
        # race measures steady-state serving, not compilation.
        predict_synchronous(params, server.index, x_test, pipe_cfg,
                            seed=args.seed, mesh=mesh)
        for name, runner in (("sync", predict_synchronous),
                             ("double", predict_pipelined)):
            t0 = time.time()
            m_r, v_r = runner(params, server.index, x_test, pipe_cfg,
                              seed=args.seed, mesh=mesh)
            dt_r = time.time() - t0
            print(f"[serve-gp] compare {name:6s}: {dt_r:.2f}s "
                  f"({args.n_test/dt_r:.0f} pts/s)")
            if name == "sync":
                m_sync, v_sync = m_r, v_r
        err = max(abs(m_r - m_sync).max(), abs(v_r - v_sync).max())
        print(f"[serve-gp] compare parity double vs sync: max|delta|={err:.2e}")
        assert err == 0.0, "pipelined chunk loop must be bitwise equal to sync"
        ref = predict_sbv(params, x, y, x_test, bs_pred=args.bs_pred,
                          m_pred=args.m_pred, seed=args.seed, n_sims=2,
                          chunk_size=args.chunk, n_workers=args.workers,
                          backend="ref", dtype=dtype,
                          stream_chunk=args.stream_chunk,
                          precision=args.precision)
        err = max(abs(m_r - ref.mean).max(), abs(v_r - ref.var).max())
        # Cross-BACKEND parity at a narrow tier is bounded by the tier's
        # assembly rounding, not by the f64 chunk-protocol tolerance.
        tol = {"bf16": 0.5, "f32": 1e-3}.get(
            args.precision, 1e-5 if dtype == np.float64 else 1e-3)
        print(f"[serve-gp] compare parity vs predict_sbv: max|delta|={err:.2e}")
        assert err <= tol, err

    # Serving returns the analytic conditionals only; conditional-simulation
    # UQ (paper §5.1.5) is the library path: predict_sbv(..., n_sims=...).
    return mean, var


def main(argv=None):
    import sys

    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "gp":
        return serve_gp(argv[1:])
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(args.mesh)
    tp = tp_size(mesh)
    cache_len = args.prompt_len + args.max_new

    params = init_params(jax.random.key(0), cfg, tp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       param_specs(params, mesh),
                       is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, psh)

    rng = np.random.default_rng(3)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)), jnp.int32
    )

    with set_mesh(mesh):
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, t: prefill_step(p, t, cfg, cache_len, tp=tp)
        )(params, prompt)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           cache_specs(cache, mesh),
                           is_leaf=lambda x: isinstance(x, P))
        cache = jax.device_put(cache, csh)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

        decode = jax.jit(
            lambda p, t, c: serve_step(p, t, c, cfg, tp=tp),
            donate_argnums=(2,),
        )
        out = [tok]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        dt = time.time() - t0
        toks = jnp.concatenate(out, axis=1)
    rate = args.batch * (args.max_new - 1) / dt
    print(f"[serve] decoded {args.max_new-1} steps x {args.batch} seqs: "
          f"{dt:.2f}s ({rate:.1f} tok/s)")
    print("[serve] sample tokens:", np.asarray(toks[0, :16]))
    return toks


if __name__ == "__main__":
    main()
