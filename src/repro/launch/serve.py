"""Batched serving drivers.

LM mode (default): prefill + decode loop with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 64 --max-new 32 --mesh 1x1

GP mode: chunked SBV prediction (paper Eq. 3) — the training index is
built once, then arbitrary n_test streams through fixed-shape jitted
chunks so device memory stays bounded no matter how many queries arrive.

    PYTHONPATH=src python -m repro.launch.serve gp --n-train 20000 \
        --n-test 100000 --chunk 4096 --bs-pred 25 --m-pred 120 \
        --backend pallas --workers 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import init_params, prefill_step, serve_step
from repro.sharding.compat import set_mesh
from repro.sharding.rules import batch_spec, cache_specs, param_specs, tp_size
from repro.launch.train import make_mesh


def serve_gp(argv=None):
    """Chunked SBV prediction server (bounded memory for arbitrary n_test).

    ``--workers k`` shards each chunk's prediction blocks over a k-device
    mesh (``distributed_predict``); the scatter stays host-side."""
    ap = argparse.ArgumentParser("serve gp")
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "satdrag", "metarvm"])
    ap.add_argument("--n-train", type=int, default=20_000)
    ap.add_argument("--n-test", type=int, default=100_000)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--bs-pred", type=int, default=25)
    ap.add_argument("--m-pred", type=int, default=120)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--dtype", default="f64", choices=["f32", "f64"],
                    help="packed-array precision; use f32 for the compiled "
                         "(non-interpret) TPU Pallas kernel")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    dtype = np.float32 if args.dtype == "f32" else np.float64

    from repro.core.predict import (
        build_train_index, iter_query_chunks, packed_predict, scatter_packed,
    )
    from repro.data.gp_sim import paper_synthetic
    from repro.launch.fit_gp import load_dataset

    if args.dataset == "synthetic":
        x, y, params = paper_synthetic(args.seed, args.n_train)
    else:
        x, y = load_dataset(args.dataset, args.n_train, args.seed)
        from repro.core.fit import fit_sbv
        from repro.core.pipeline import SBVConfig

        cfg = SBVConfig(n_blocks=max(1, args.n_train // 128), m=60, seed=args.seed)
        params = fit_sbv(x, y, cfg, inner_steps=30, outer_rounds=1).params

    rng = np.random.default_rng(args.seed + 1)
    x_test = rng.uniform(size=(args.n_test, x.shape[1]))

    t0 = time.time()
    index = build_train_index(x, y, np.asarray(params.beta), args.m_pred,
                              n_workers=args.workers, seed=args.seed)
    print(f"[serve-gp] train index over {len(y)} pts: {time.time()-t0:.2f}s")

    mesh = None
    if args.workers > 1:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh(args.workers)

    mean = np.zeros(args.n_test)
    var = np.zeros(args.n_test)
    t0 = time.time()
    n_chunks = 0
    for ci, packed in iter_query_chunks(
        index, x_test, args.bs_pred, args.m_pred, seed=args.seed,
        n_workers=args.workers, chunk_size=args.chunk, dtype=dtype,
    ):
        tc = time.time()
        if mesh is not None:
            from repro.core.distributed import (
                distributed_predict, shard_prediction_by_owner,
            )

            packed = shard_prediction_by_owner(packed, args.workers)
            mu_b, var_b = distributed_predict(params, packed, mesh,
                                              backend=args.backend)
        else:
            mu_b, var_b = packed_predict(params, packed, backend=args.backend)
        scatter_packed(packed, (mu_b, mean), (var_b, var))
        n_chunks += 1
        if ci < 3 or ci % 16 == 0:
            print(f"[serve-gp] chunk {ci}: {packed.n_queries} pts "
                  f"(bc={packed.n_blocks}, bs={packed.bs_pred}) "
                  f"{time.time()-tc:.3f}s")
    dt = time.time() - t0
    print(f"[serve-gp] {args.n_test} predictions in {dt:.2f}s over {n_chunks} "
          f"chunks: {args.n_test/dt:.0f} pts/s (backend={args.backend}, "
          f"workers={args.workers})")
    assert np.all(np.isfinite(mean)) and np.all(var > 0)
    # Serving returns the analytic conditionals only; conditional-simulation
    # UQ (paper §5.1.5) is the library path: predict_sbv(..., n_sims=...).
    return mean, var


def main(argv=None):
    import sys

    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "gp":
        return serve_gp(argv[1:])
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(args.mesh)
    tp = tp_size(mesh)
    cache_len = args.prompt_len + args.max_new

    params = init_params(jax.random.key(0), cfg, tp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       param_specs(params, mesh),
                       is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, psh)

    rng = np.random.default_rng(3)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)), jnp.int32
    )

    with set_mesh(mesh):
        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, t: prefill_step(p, t, cfg, cache_len, tp=tp)
        )(params, prompt)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           cache_specs(cache, mesh),
                           is_leaf=lambda x: isinstance(x, P))
        cache = jax.device_put(cache, csh)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

        decode = jax.jit(
            lambda p, t, c: serve_step(p, t, c, cfg, tp=tp),
            donate_argnums=(2,),
        )
        out = [tok]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        dt = time.time() - t0
        toks = jnp.concatenate(out, axis=1)
    rate = args.batch * (args.max_new - 1) / dt
    print(f"[serve] decoded {args.max_new-1} steps x {args.batch} seqs: "
          f"{dt:.2f}s ({rate:.1f} tok/s)")
    print("[serve] sample tokens:", np.asarray(toks[0, :16]))
    return toks


if __name__ == "__main__":
    main()
