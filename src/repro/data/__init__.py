from .gp_sim import metarvm_simulate, sample_gp_exact, sample_gp_rff, satellite_drag_like
from .store import ArrayStore, ArrayStoreWriter, MemoryStore, as_store, is_store

__all__ = [
    "metarvm_simulate", "sample_gp_exact", "sample_gp_rff", "satellite_drag_like",
    "ArrayStore", "ArrayStoreWriter", "MemoryStore", "as_store", "is_store",
]
