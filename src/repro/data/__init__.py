from .gp_sim import metarvm_simulate, sample_gp_exact, sample_gp_rff, satellite_drag_like

__all__ = ["metarvm_simulate", "sample_gp_exact", "sample_gp_rff", "satellite_drag_like"]
