"""Deterministic, checkpointable LM token stream.

A counter-based PRNG stream: batch ``i`` is a pure function of (seed, i), so
* any worker can regenerate any batch (no coordination),
* the iterator state is ONE integer — it rides in the checkpoint manifest
  and restore resumes the exact position,
* straggler mitigation / elastic restarts never skew the data order.

``shard`` slices the global batch for a data-parallel worker; on a real
fleet each host feeds only its addressable slice.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, global_batch: int, seq_len: int, seed: int = 0,
                 start_batch: int = 0):
        self.vocab = vocab
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.batch_idx = start_batch

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        return {"batch_idx": self.batch_idx, "seed": self.seed}

    def load_state_dict(self, s: dict):
        self.batch_idx = int(s["batch_idx"])
        self.seed = int(s["seed"])

    # -- iteration -------------------------------------------------------
    def _gen(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, idx))
        return rng.integers(
            0, self.vocab, size=(self.global_batch, self.seq_len + 1), dtype=np.int64
        ).astype(np.int32)

    def next(self, shard: tuple[int, int] = (0, 1)):
        """Returns (tokens, labels) for this worker's slice of the batch."""
        wid, nw = shard
        assert self.global_batch % nw == 0
        per = self.global_batch // nw
        full = self._gen(self.batch_idx)
        self.batch_idx += 1
        mine = full[wid * per : (wid + 1) * per]
        return mine[:, :-1], mine[:, 1:]

    def __iter__(self):
        while True:
            yield self.next()
