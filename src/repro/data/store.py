"""Sharded on-disk array store for out-of-core SBV datasets.

The paper's headline runs (50M-point respiratory emulation, 2.56B points
across 512 GPUs) only work because every pipeline stage streams through
bounded device memory. This module is the host-side half of that story:
a dataset is a DIRECTORY of fixed-size ``.npy`` row shards plus a small
JSON manifest, and consumers read it through three bounded primitives —

* ``iter_chunks(rows)``   — sequential windows of at most ``rows`` rows
  (windows may span shards; only the shards a window touches are read);
* ``read_slice(a, b)``    — one explicit window;
* ``read_rows(idx)``      — random-access gather of arbitrary row indices,
  grouped by shard and served through short-lived memory maps so the
  resident set stays bounded by the gather size, not the file size.

Shards are plain ``.npy`` files so every chunk is debuggable with nothing
but numpy, and float64 rows round-trip bit-exactly — which is what makes
the store-backed fit/predict paths *bitwise* equal to their in-core
twins (tests/test_streaming.py).

``MemoryStore`` is the in-RAM implementation of the same protocol: the
streaming construction code is written against the protocol, so "in-core"
vs "out-of-core" differ only in where the bytes live.
"""
from __future__ import annotations

import json
import os

import numpy as np

MANIFEST = "manifest.json"
DEFAULT_SHARD_ROWS = 131072


def is_store(obj) -> bool:
    """True for anything speaking the row-store protocol (duck-typed)."""
    return all(hasattr(obj, a) for a in ("n_rows", "d", "iter_chunks", "read_rows"))


def as_store(x, y=None):
    """Coerce ``(x, y)`` arrays to a ``MemoryStore``; pass stores through."""
    if is_store(x):
        if y is not None:
            raise ValueError("pass y=None when x is already a store")
        return x
    return MemoryStore(x, y)


class MemoryStore:
    """In-RAM twin of ``ArrayStore`` (same read protocol, zero IO)."""

    def __init__(self, x: np.ndarray, y: np.ndarray | None):
        self.x = np.asarray(x, dtype=np.float64)
        if self.x.ndim != 2:
            raise ValueError(f"x must be (n, d), got shape {self.x.shape}")
        self.y = (np.zeros(self.x.shape[0]) if y is None
                  else np.asarray(y, dtype=np.float64))
        # (n,) single-output or (n, p) multi-output observation rows.
        if self.y.ndim not in (1, 2) or self.y.shape[0] != self.x.shape[0]:
            raise ValueError(f"y must be ({self.x.shape[0]},) or "
                             f"({self.x.shape[0]}, p), got {self.y.shape}")

    @property
    def n_rows(self) -> int:
        return self.x.shape[0]

    @property
    def d(self) -> int:
        return self.x.shape[1]

    @property
    def dtype(self):
        return self.x.dtype

    @property
    def x_rows(self):
        return self.x

    @property
    def y_rows(self):
        return self.y

    def read_slice(self, start: int, stop: int):
        return self.x[start:stop], self.y[start:stop]

    def read_rows(self, idx: np.ndarray):
        idx = np.asarray(idx, dtype=np.int64)
        return self.x[idx], self.y[idx]

    def read_all(self):
        return self.x, self.y

    def iter_chunks(self, rows: int | None = None):
        n = self.n_rows
        rows = n if rows is None else max(1, int(rows))
        for start in range(0, n, rows):
            stop = min(n, start + rows)
            yield start, self.x[start:stop], self.y[start:stop]


class ArrayStoreWriter:
    """Append-only writer; ``finalize()`` seals the manifest.

    Rows are buffered to at most one shard and flushed as ``.npy`` files,
    so writing an arbitrarily large dataset needs ~one shard of RAM.
    """

    def __init__(self, path: str, d: int, dtype="float64",
                 shard_rows: int = DEFAULT_SHARD_ROWS):
        self.path = path
        self.d = int(d)
        self.dtype = np.dtype(dtype)
        self.shard_rows = int(shard_rows)
        if self.shard_rows <= 0:
            raise ValueError("shard_rows must be positive")
        os.makedirs(path, exist_ok=True)
        self._shards: list[dict] = []
        self._buf_x: list[np.ndarray] = []
        self._buf_y: list[np.ndarray] = []
        self._buf_rows = 0
        self._finalized = False

    def append(self, x: np.ndarray, y: np.ndarray) -> None:
        if self._finalized:
            raise RuntimeError("writer already finalized")
        x = np.ascontiguousarray(x, dtype=self.dtype)
        y = np.ascontiguousarray(y, dtype=self.dtype)
        if x.ndim != 2 or x.shape[1] != self.d:
            raise ValueError(f"expected (k, {self.d}) rows, got {x.shape}")
        if y.ndim not in (1, 2) or y.shape[0] != x.shape[0]:
            raise ValueError(f"y shape {y.shape} != ({x.shape[0]},) or "
                             f"({x.shape[0]}, p)")
        self._buf_x.append(x)
        self._buf_y.append(y)
        self._buf_rows += x.shape[0]
        while self._buf_rows >= self.shard_rows:
            self._flush(self.shard_rows)

    def _flush(self, rows: int) -> None:
        if rows <= 0:
            return
        x = np.concatenate(self._buf_x) if len(self._buf_x) != 1 else self._buf_x[0]
        y = np.concatenate(self._buf_y) if len(self._buf_y) != 1 else self._buf_y[0]
        head_x, tail_x = x[:rows], x[rows:]
        head_y, tail_y = y[:rows], y[rows:]
        i = len(self._shards)
        x_name, y_name = f"x_{i:05d}.npy", f"y_{i:05d}.npy"
        np.save(os.path.join(self.path, x_name), head_x)
        np.save(os.path.join(self.path, y_name), head_y)
        self._shards.append({"rows": int(head_x.shape[0]), "x": x_name, "y": y_name})
        self._buf_x = [tail_x] if tail_x.shape[0] else []
        self._buf_y = [tail_y] if tail_y.shape[0] else []
        self._buf_rows = int(tail_x.shape[0])

    def finalize(self) -> "ArrayStore":
        if self._finalized:
            raise RuntimeError("writer already finalized")
        if self._buf_rows:
            self._flush(self._buf_rows)
        manifest = {
            "version": 1,
            "n_rows": int(sum(s["rows"] for s in self._shards)),
            "d": self.d,
            "dtype": self.dtype.name,
            "shard_rows": self.shard_rows,
            "shards": self._shards,
        }
        with open(os.path.join(self.path, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
        self._finalized = True
        return ArrayStore(self.path)

    def __enter__(self) -> "ArrayStoreWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is None:
            self.finalize()


class _RowsView:
    """Lazy fancy-indexable view of one field of a store.

    Quacks enough like an ``(n,)``/``(n, d)`` ndarray — ``.shape`` plus
    ``view[idx]`` gathers — for code written against in-core arrays
    (``pack_prediction``, ``GPServer``) to run unchanged on a store.
    """

    def __init__(self, store, field: str):
        self._store = store
        self._field = field

    @property
    def shape(self) -> tuple:
        if self._field == "x":
            return (self._store.n_rows, self._store.d)
        return (self._store.n_rows,)

    def __len__(self) -> int:
        return self._store.n_rows

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            start, stop, step = idx.indices(self._store.n_rows)
            if step != 1:
                raise IndexError("strided slices are not supported on stores")
            x, y = self._store.read_slice(start, stop)
            return x if self._field == "x" else y
        idx = np.asarray(idx, np.int64)
        if idx.ndim == 0:
            # Scalar index: ndarray semantics drop the row axis —
            # ``view[5]`` is ``(d,)``/scalar, not ``(1, d)``/``(1,)``.
            i = int(idx)
            if i < 0:
                i += self._store.n_rows
            if not 0 <= i < self._store.n_rows:
                raise IndexError(
                    f"row index {int(idx)} outside [0, {self._store.n_rows})")
            x, y = self._store.read_rows(np.asarray([i], np.int64))
            return x[0] if self._field == "x" else y[0]
        x, y = self._store.read_rows(idx)
        return x if self._field == "x" else y


class ArrayStore:
    """Reader over a finalized store directory (see module docstring)."""

    def __init__(self, path: str):
        self.path = path
        mpath = os.path.join(path, MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(f"no {MANIFEST} in {path!r} — not a store?")
        with open(mpath) as f:
            m = json.load(f)
        if m.get("version") != 1:
            raise ValueError(f"unsupported store version {m.get('version')!r}")
        self._m = m
        self._rows = np.asarray([s["rows"] for s in m["shards"]], dtype=np.int64)
        self._starts = np.concatenate([[0], np.cumsum(self._rows)])
        if int(self._starts[-1]) != int(m["n_rows"]):
            raise ValueError(
                f"manifest n_rows={m['n_rows']} != sum of shard rows "
                f"{int(self._starts[-1])} — corrupt manifest"
            )
        missing = [s[f] for s in m["shards"] for f in ("x", "y")
                   if not os.path.exists(os.path.join(path, s[f]))]
        if missing:
            raise FileNotFoundError(f"store {path!r} is missing shards: {missing}")

    # -- metadata ------------------------------------------------------

    @property
    def n_rows(self) -> int:
        return int(self._m["n_rows"])

    @property
    def d(self) -> int:
        return int(self._m["d"])

    @property
    def dtype(self):
        return np.dtype(self._m["dtype"])

    @property
    def n_shards(self) -> int:
        return len(self._m["shards"])

    @property
    def shard_rows(self) -> int:
        return int(self._m["shard_rows"])

    @property
    def x_rows(self) -> _RowsView:
        return _RowsView(self, "x")

    @property
    def y_rows(self) -> _RowsView:
        return _RowsView(self, "y")

    def verify(self) -> None:
        """Check every shard's npy header against the manifest."""
        for i, s in enumerate(self._m["shards"]):
            x = np.load(os.path.join(self.path, s["x"]), mmap_mode="r")
            y = np.load(os.path.join(self.path, s["y"]), mmap_mode="r")
            if x.shape != (s["rows"], self.d) or y.shape != (s["rows"],):
                raise ValueError(
                    f"shard {i}: shapes x={x.shape} y={y.shape} disagree with "
                    f"manifest rows={s['rows']} d={self.d}"
                )
            if x.dtype != self.dtype or y.dtype != self.dtype:
                raise ValueError(f"shard {i}: dtype {x.dtype}/{y.dtype} != {self.dtype}")
            del x, y  # unmap promptly

    # -- reads ---------------------------------------------------------

    def _shard_arrays(self, i: int):
        """Short-lived memory maps of shard i (caller must drop refs)."""
        s = self._m["shards"][i]
        x = np.load(os.path.join(self.path, s["x"]), mmap_mode="r")
        y = np.load(os.path.join(self.path, s["y"]), mmap_mode="r")
        return x, y

    def read_slice(self, start: int, stop: int):
        """Rows [start, stop) as in-core arrays (copies; maps are dropped)."""
        start = max(0, int(start))
        stop = min(self.n_rows, int(stop))
        if stop <= start:
            return (np.empty((0, self.d), self.dtype), np.empty(0, self.dtype))
        s0 = int(np.searchsorted(self._starts, start, side="right") - 1)
        s1 = int(np.searchsorted(self._starts, stop, side="left"))
        xs, ys = [], []
        for i in range(s0, s1):
            a = max(start, int(self._starts[i])) - int(self._starts[i])
            b = min(stop, int(self._starts[i + 1])) - int(self._starts[i])
            sx, sy = self._shard_arrays(i)
            xs.append(np.array(sx[a:b]))
            ys.append(np.array(sy[a:b]))
            del sx, sy
        if len(xs) == 1:
            return xs[0], ys[0]
        return np.concatenate(xs), np.concatenate(ys)

    def read_rows(self, idx: np.ndarray):
        """Gather arbitrary rows, preserving the requested order.

        Indices are grouped by shard and read through short-lived memory
        maps; sorting within each shard keeps the page access sequential.
        """
        idx = np.asarray(idx, dtype=np.int64)
        if idx.ndim != 1:
            raise ValueError(f"read_rows wants a 1-D index array, got {idx.shape}")
        if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= self.n_rows):
            raise IndexError(f"row index outside [0, {self.n_rows})")
        x = np.empty((idx.size, self.d), dtype=self.dtype)
        y = np.empty(idx.size, dtype=self.dtype)
        if idx.size == 0:
            return x, y
        shard_of = np.searchsorted(self._starts, idx, side="right") - 1
        for i in np.unique(shard_of):
            where = np.nonzero(shard_of == i)[0]
            local = idx[where] - int(self._starts[i])
            order = np.argsort(local, kind="stable")
            sx, sy = self._shard_arrays(int(i))
            x[where[order]] = sx[local[order]]
            y[where[order]] = sy[local[order]]
            del sx, sy
        return x, y

    def read_all(self):
        return self.read_slice(0, self.n_rows)

    def iter_chunks(self, rows: int | None = None):
        """Yield ``(start, x_window, y_window)`` sequential windows.

        ``rows=None`` uses the manifest shard size. The last window is
        ragged (``n_rows % rows`` rows) unless rows divides n_rows.
        """
        rows = int(self._m["shard_rows"]) if rows is None else max(1, int(rows))
        for start in range(0, self.n_rows, rows):
            stop = min(self.n_rows, start + rows)
            x, y = self.read_slice(start, stop)
            yield start, x, y

    # -- construction --------------------------------------------------

    @classmethod
    def create(cls, path: str, d: int, dtype="float64",
               shard_rows: int = DEFAULT_SHARD_ROWS) -> ArrayStoreWriter:
        return ArrayStoreWriter(path, d, dtype=dtype, shard_rows=shard_rows)

    @classmethod
    def from_arrays(cls, path: str, x: np.ndarray, y: np.ndarray,
                    shard_rows: int = DEFAULT_SHARD_ROWS) -> "ArrayStore":
        x = np.asarray(x)
        with cls.create(path, x.shape[1], dtype=x.dtype, shard_rows=shard_rows) as w:
            w.append(x, np.asarray(y, dtype=x.dtype))
        return cls(path)


def partition_bounds(n_rows: int, n_parts: int, align: int = 1) -> np.ndarray:
    """Row boundaries of an even, ``align``-multiple partition of ``n_rows``.

    Returns ``(n_parts + 1,)`` monotone bounds with part p owning
    ``[bounds[p], bounds[p + 1])``. Boundaries snap to multiples of
    ``align`` (shard size for an ``ArrayStore``: a host then only touches
    its own shard files on sequential passes) except the final bound,
    which is always ``n_rows``. Tail parts may be empty when
    ``n_rows < n_parts * align`` — consumers must tolerate zero-row
    partitions.
    """
    n_parts = max(1, int(n_parts))
    align = max(1, int(align))
    per = -(-n_rows // n_parts)           # ceil split ...
    per = -(-per // align) * align        # ... rounded up to the alignment
    bounds = np.minimum(np.arange(n_parts + 1, dtype=np.int64) * per, n_rows)
    return bounds


class PartitionedStore:
    """One host's row-range view of a shared store (multi-host Alg. 2).

    Speaks the full row-store protocol, but ``iter_chunks`` walks ONLY
    the rows of this partition — every sequential construction pass over
    a ``PartitionedStore`` touches ~``n_rows / n_parts`` rows, which is
    what bounds each host's share of the multi-host streaming build.
    Chunk windows stay aligned to the GLOBAL ``[k*rows, (k+1)*rows)``
    grid (clipped to the partition), so the union of all hosts' windows
    is exactly the single-process window sequence.

    Random access (``read_rows`` / ``read_slice``) deliberately passes
    through to the parent store — the paper's setting is a shared
    parallel filesystem, and construction needs a few tiny global
    gathers (k-means seeding). ``remote_rows_read`` counts rows served
    from outside the partition so tests can pin that the steady-state
    pipeline never leans on it.
    """

    def __init__(self, store, n_parts: int, part: int, align: int | None = None):
        if not 0 <= int(part) < int(n_parts):
            raise ValueError(f"part {part} outside [0, {n_parts})")
        self.parent = store
        self.n_parts = int(n_parts)
        self.part = int(part)
        if align is None:
            align = getattr(store, "shard_rows", 1)
            # Shard alignment only helps while it doesn't starve parts.
            if align > 1 and store.n_rows < self.n_parts * align:
                align = 1
        self._bounds = partition_bounds(store.n_rows, self.n_parts, align)
        self.start = int(self._bounds[self.part])
        self.stop = int(self._bounds[self.part + 1])
        self.remote_rows_read = 0  # telemetry: rows gathered outside the part

    # -- metadata (global, protocol-compatible) ------------------------

    @property
    def n_rows(self) -> int:
        return self.parent.n_rows

    @property
    def d(self) -> int:
        return self.parent.d

    @property
    def dtype(self):
        return self.parent.dtype

    @property
    def n_local(self) -> int:
        return self.stop - self.start

    # -- reads ---------------------------------------------------------

    def read_slice(self, start: int, stop: int):
        self.remote_rows_read += max(
            0, min(stop, self.parent.n_rows) - max(start, 0)
        ) - max(0, min(stop, self.stop) - max(start, self.start))
        return self.parent.read_slice(start, stop)

    def read_rows(self, idx: np.ndarray):
        idx = np.asarray(idx, dtype=np.int64)
        self.remote_rows_read += int(np.sum((idx < self.start) | (idx >= self.stop)))
        return self.parent.read_rows(idx)

    def iter_chunks(self, rows: int | None = None):
        """Global-grid chunk windows clipped to this partition."""
        n = self.parent.n_rows
        rows = n if rows is None else max(1, int(rows))
        first = (self.start // rows) * rows
        for gstart in range(first, self.stop, rows):
            a, b = max(gstart, self.start), min(gstart + rows, self.stop)
            if a >= b:
                continue
            x, y = self.parent.read_slice(a, b)
            yield a, x, y
