"""Streaming (out-of-core) SBV construction over a row store.

Every stage of the in-core preprocessing pipeline
(scale -> block -> order -> NNS -> pack) assumes the full ``(n, d)``
dataset sits in host RAM. This module rebuilds each stage as a pass over
``store.iter_chunks(rows)`` windows so the resident working set is
bounded by the chunk size, not ``n`` — the property that carries the
paper from 200k points to 50M:

* ``streaming_kmeans_blocks`` — mini-batch k-means over chunk iterators
  (Sculley-style center updates with per-epoch count resets, so the
  single-chunk case reduces EXACTLY to Lloyd iterations — the parity
  hook tests/test_streaming.py pins), then one labeling pass that also
  accumulates exact centroids, per-dimension extents (for the Eq. 7 NNS
  radius) and a radius pass against the final centers;
* ``LazyFlatBlocks`` — the store-backed twin of ``core.nns._FlatBlocks``:
  same index bookkeeping, but member coordinates are gathered on demand
  (LRU-cached per block). Block ids are relabeled in center-coordinate
  order, so the NNS sweep visits spatially adjacent blocks consecutively
  and the cache turns the gather stream into ~one pass over the store;
* ``plan_block_chunks`` / ``pack_block_chunk`` / ``PackedChunkSpool`` —
  conditioning-rank-ordered groups of blocks whose member+neighbor rows
  fit the ``stream_chunk`` budget, packed via the existing
  ``pack_blocks`` on a gathered-and-remapped row subset, and spooled to
  ``.npz`` files so likelihood passes re-read bounded chunks instead of
  holding the packed dataset.

Bitwise contract: all arithmetic is elementwise or reduction ops whose
operand order is independent of where the rows live, so a ``MemoryStore``
and an ``ArrayStore`` holding the same rows produce identical structures,
packings, and fits (tests/test_streaming.py pins this at 0 difference;
the 1e-10 tolerances in the acceptance gate cover the chunked-vs-
monolithic likelihood summation order, not the IO layer).
"""
from __future__ import annotations

import os
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.blocks import BlockStructure, most_relevant_dim, scale_inputs
from repro.core.nns import _FlatBlocks, filtered_nns
from repro.core.packing import PackedBlocks, pack_blocks

DEFAULT_STRUCT_BATCH = 65536  # rows per structure pass (decoupled from
                              # stream_chunk so the packing window can vary
                              # without changing the k-means trajectory)
ROW_TILE = 2048               # rows per assignment distance tile
MAX_D2_ENTRIES = 2 << 20      # bound on distance-tile size (entries)


# -- chunked moments -------------------------------------------------------


def streaming_moments(store, batch_rows: int = DEFAULT_STRUCT_BATCH):
    """(mean, variance) of y accumulated chunk-wise (population variance,
    matching ``np.var`` up to summation order)."""
    n = store.n_rows
    s = s2 = 0.0
    for _, _, yw in store.iter_chunks(batch_rows):
        s += float(np.sum(yw))
        s2 += float(np.sum(yw * yw))
    mean = s / max(n, 1)
    return mean, max(s2 / max(n, 1) - mean * mean, 0.0)


# -- mini-batch k-means blocking ------------------------------------------


def _center_tile(n_centers: int) -> int:
    """Centers per distance tile: keeps row_tile x center_tile bounded."""
    return max(32, min(2048, MAX_D2_ENTRIES // ROW_TILE))


def _assign_chunk(xs: np.ndarray, centers: np.ndarray, c2: np.ndarray):
    """Nearest-center label per row, tiled over rows AND centers so the
    distance buffer never exceeds ROW_TILE x center-tile entries.

    The assignment is memory-bound (n x k distance entries dwarf the
    rank-d GEMM), so the tiles run in float32 with the row-norm term
    dropped — ``argmin_j ||x - c_j||^2 = argmin_j (c2_j - 2 x.c_j)`` —
    and in-place updates: ~3x less traffic than the naive f64 broadcast.
    Labels are a clustering heuristic (everything downstream that needs
    exactness — radii, centroids, NNS — recomputes in f64), and both
    store backends run the identical instruction stream, so bitwise
    memory/disk parity is preserved. Strict-< running best keeps
    numpy's first-minimum tie-breaking across center tiles."""
    n, k = xs.shape[0], centers.shape[0]
    ct = _center_tile(k)
    cen32 = np.ascontiguousarray(centers.T, dtype=np.float32)  # (d, k)
    c232 = c2.astype(np.float32)
    labels = np.empty(n, dtype=np.int64)
    for rs in range(0, n, ROW_TILE):
        xr = xs[rs:rs + ROW_TILE].astype(np.float32)
        rows = np.arange(xr.shape[0])
        best = np.full(xr.shape[0], np.inf, dtype=np.float32)
        lab = np.zeros(xr.shape[0], dtype=np.int64)
        for cs in range(0, k, ct):
            d2 = xr @ cen32[:, cs:cs + ct]
            d2 *= -2.0
            d2 += c232[cs:cs + ct][None, :]
            j = np.argmin(d2, axis=1)
            v = d2[rows, j]
            upd = v < best
            best[upd] = v[upd]
            lab[upd] = j[upd] + cs
        labels[rs:rs + ROW_TILE] = lab
    return labels


def _label_sums(labels: np.ndarray, xs: np.ndarray, k: int):
    """Per-label row counts and coordinate sums (bincount per dim: C-fast)."""
    counts = np.bincount(labels, minlength=k).astype(np.float64)
    sums = np.stack(
        [np.bincount(labels, weights=xs[:, j], minlength=k)
         for j in range(xs.shape[1])], axis=1,
    )
    return counts, sums


def streaming_kmeans_blocks(
    store,
    beta: np.ndarray,
    n_blocks: int,
    n_workers: int = 1,
    seed: int = 0,
    epochs: int = 2,
    batch_rows: int = DEFAULT_STRUCT_BATCH,
    ordering: str = "random",
):
    """Mini-batch k-means blocking over chunk iterators.

    Returns ``(BlockStructure, radii, domain_volume)`` — everything the
    filtered NNS needs, with nothing larger than index arrays held in
    RAM. Deterministic given (store contents, seed, batch_rows); with
    ``batch_rows >= n`` every epoch is exactly one Lloyd iteration.

    Block ids are assigned in center-coordinate order along the most
    relevant dimension, so id-ordered sweeps (the NNS loop) visit
    spatially adjacent blocks consecutively — that locality is what makes
    the store-backed lazy gather cache effective.
    """
    rng = np.random.default_rng(seed)
    n, d = store.n_rows, store.d
    beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (d,))
    k = min(int(n_blocks), n)

    init_idx = rng.choice(n, size=k, replace=False)
    centers = scale_inputs(store.read_rows(init_idx)[0], beta)

    for _ in range(max(int(epochs), 0)):
        counts = np.zeros(k)
        c2 = np.sum(centers * centers, axis=1)
        for _, xw, _ in store.iter_chunks(batch_rows):
            xs = scale_inputs(xw, beta)
            lab = _assign_chunk(xs, centers, c2)
            k_c, sums = _label_sums(lab, xs, k)
            counts += k_c
            nz = k_c > 0
            centers[nz] += (sums[nz] - k_c[nz, None] * centers[nz]) / counts[nz, None]
            c2 = np.sum(centers * centers, axis=1)
        empty = counts == 0
        if empty.any():
            re_idx = rng.choice(n, size=int(empty.sum()), replace=False)
            centers[empty] = scale_inputs(store.read_rows(re_idx)[0], beta)

    # Final labeling pass: exact centroids + scaled-domain extents.
    labels = np.empty(n, dtype=np.int64)
    counts = np.zeros(k)
    sums = np.zeros((k, d))
    mins = np.full(d, np.inf)
    maxs = np.full(d, -np.inf)
    c2 = np.sum(centers * centers, axis=1)
    for start, xw, _ in store.iter_chunks(batch_rows):
        xs = scale_inputs(xw, beta)
        lab = _assign_chunk(xs, centers, c2)
        labels[start:start + xs.shape[0]] = lab
        k_c, s_c = _label_sums(lab, xs, k)
        counts += k_c
        sums += s_c
        np.minimum(mins, xs.min(axis=0), out=mins)
        np.maximum(maxs, xs.max(axis=0), out=maxs)

    # Compact away empty blocks, then relabel in center-coordinate order.
    occupied = np.nonzero(counts > 0)[0]
    centers = sums[occupied] / counts[occupied][:, None]
    dprime = most_relevant_dim(beta)
    coord_order = np.argsort(centers[:, dprime], kind="stable")
    centers = centers[coord_order]
    bc = occupied.size
    old_to_new = np.full(k, -1, dtype=np.int64)
    old_to_new[occupied[coord_order]] = np.arange(bc)
    labels = old_to_new[labels]

    # Radius pass against the FINAL centers (upper bound the coarse
    # filter relies on; running centers would under-estimate it).
    r2 = np.zeros(bc)
    for start, xw, _ in store.iter_chunks(batch_rows):
        xs = scale_inputs(xw, beta)
        lab = labels[start:start + xs.shape[0]]
        d2 = np.sum((xs - centers[lab]) ** 2, axis=1)
        np.maximum.at(r2, lab, d2)
    radii = np.sqrt(r2)

    # Members from one stable argsort (ascending indices within a block,
    # matching np.nonzero order in the in-core builder).
    by_block = np.argsort(labels, kind="stable")
    sizes = np.bincount(labels, minlength=bc)
    members = np.split(by_block, np.cumsum(sizes)[:-1])

    # Owner shard per block by quantile bucketing of the center coordinate
    # (same locality property as the per-point Alg. 2 partition).
    if n_workers > 1:
        qs = np.quantile(centers[:, dprime],
                         np.linspace(0.0, 1.0, n_workers + 1)[1:-1])
        owners = np.searchsorted(qs, centers[:, dprime], side="right")
    else:
        owners = np.zeros(bc, dtype=np.int64)

    if ordering == "random":
        order = rng.permutation(bc)
    elif ordering == "coord":
        order = np.arange(bc)  # ids are already in coordinate order
    elif ordering == "maxmin":
        from repro.core.blocks import _maxmin_order

        order = _maxmin_order(centers, rng)  # centers are in-RAM: bc x d
    else:
        raise ValueError(f"unknown streaming ordering {ordering!r}")
    rank_of_block = np.empty(bc, dtype=np.int64)
    rank_of_block[order] = np.arange(bc)

    ext = maxs - mins
    med = np.median(ext[ext > 0]) if np.any(ext > 0) else 1.0
    ext = np.maximum(ext, 1e-6 * med)
    domain_volume = float(np.prod(ext))

    blocks = BlockStructure(
        labels=labels,
        order=np.asarray(order, dtype=np.int64),
        rank_of_block=rank_of_block,
        centers=centers,
        owners=np.asarray(owners, dtype=np.int32),
        members=members,
    )
    return blocks, radii, domain_volume


# -- store-backed flat block index ----------------------------------------


class LazyFlatBlocks(_FlatBlocks):
    """``_FlatBlocks`` over a store: coordinates gathered on demand.

    Holds the same index bookkeeping (sizes/starts/flat_idx/flat_rank/
    radii) but no ``flat_pts``; ``points_of_blocks`` serves scaled member
    coordinates from a bytes-bounded per-block LRU cache, batching all
    cache misses of a call into one ``read_rows`` gather.
    """

    def __init__(self, blocks: BlockStructure, radii: np.ndarray, store,
                 beta: np.ndarray, cache_bytes: int = 32 << 20):
        sizes = np.asarray([mb.size for mb in blocks.members], dtype=np.int64)
        self.sizes = sizes
        self.starts = np.concatenate([[0], np.cumsum(sizes)])
        self.flat_idx = (
            np.concatenate(blocks.members) if blocks.n_blocks else np.empty(0, np.int64)
        )
        self.flat_rank = np.repeat(blocks.rank_of_block, sizes)
        self.radii = np.asarray(radii)
        self.n_rows = store.n_rows
        self.d = store.d
        self._store = store
        self._beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (store.d,))
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self._cache_bytes = 0
        self._cache_cap = int(cache_bytes)
        self.gathered_rows = 0  # telemetry: store rows actually read

    def _evict(self) -> None:
        while self._cache_bytes > self._cache_cap and len(self._cache) > 1:
            _, old = self._cache.popitem(last=False)
            self._cache_bytes -= old.nbytes

    def points_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        block_ids = np.asarray(block_ids, dtype=np.int64)
        if block_ids.size == 0:
            return np.empty((0, self.d))
        missing = [int(b) for b in block_ids if int(b) not in self._cache]
        if missing:
            rows = np.concatenate(
                [self.flat_idx[self.starts[b]:self.starts[b + 1]] for b in missing]
            )
            pts = scale_inputs(self._store.read_rows(rows)[0], self._beta)
            self.gathered_rows += rows.size
            off = 0
            for b in missing:
                k = int(self.sizes[b])
                self._cache[b] = pts[off:off + k]
                self._cache_bytes += self._cache[b].nbytes
                off += k
            self._evict()
        out = []
        for b in block_ids:
            b = int(b)
            pts = self._cache[b]
            self._cache.move_to_end(b)
            out.append(pts)
        return out[0] if len(out) == 1 else np.concatenate(out)


def streaming_filtered_nns(
    store, blocks: BlockStructure, radii: np.ndarray, beta: np.ndarray,
    m: int, alpha: float = 100.0, domain_volume: float | None = None,
    cache_bytes: int = 32 << 20,
):
    """Filtered preceding-block NNS with store-backed candidate gathers.

    The query sweep runs in block-id order == center-coordinate order
    (see ``streaming_kmeans_blocks``), so consecutive queries share most
    of their candidate blocks and the LRU cache bounds re-reads.
    Returns ``(neighbors, flat)`` so callers can keep the warm index.
    """
    flat = LazyFlatBlocks(blocks, radii, store, beta, cache_bytes=cache_bytes)
    bc = max(blocks.n_blocks, 1)
    center_chunk = max(16, min(2048, MAX_D2_ENTRIES // bc))
    neigh = filtered_nns(None, blocks, m, alpha=alpha, center_chunk=center_chunk,
                         flat=flat, domain_volume=domain_volume)
    return neigh, flat


# -- chunked packing -------------------------------------------------------


def plan_block_chunks(blocks: BlockStructure, neigh: list, m: int,
                      stream_chunk: int) -> list[np.ndarray]:
    """Group conditioning ranks so each group's member+neighbor rows fit
    the ``stream_chunk`` budget. Groups are contiguous in rank order;
    a single oversized block still gets its own chunk (the budget is a
    target, not a validity condition)."""
    plans: list[np.ndarray] = []
    cur: list[int] = []
    rows = 0
    for rank, b in enumerate(blocks.order):
        cost = int(blocks.members[b].size) + min(len(neigh[b]), m)
        if cur and rows + cost > stream_chunk:
            plans.append(np.asarray(cur, dtype=np.int64))
            cur, rows = [], 0
        cur.append(rank)
        rows += cost
    if cur:
        plans.append(np.asarray(cur, dtype=np.int64))
    return plans


def pack_block_chunk(
    store, blocks: BlockStructure, neigh: list, ranks: np.ndarray,
    m: int, bs_max: int, dtype=np.float64,
) -> PackedBlocks:
    """Pack one rank-chunk by gathering the union of its member+neighbor
    rows once and remapping indices into the gathered subset — the packed
    arrays are bit-identical to the same blocks' slices of an in-core
    ``pack_blocks`` (gathers preserve values and relative order)."""
    bids = blocks.order[ranks]
    pieces = [blocks.members[b] for b in bids] + [neigh[b][:m] for b in bids]
    rows_needed = np.unique(np.concatenate(pieces)) if pieces else np.empty(0, np.int64)
    xg, yg = store.read_rows(rows_needed)

    def remap(a):
        return np.searchsorted(rows_needed, a)

    kb = len(bids)
    mini = BlockStructure(
        labels=np.empty(0, dtype=np.int64),
        order=np.arange(kb, dtype=np.int64),
        rank_of_block=np.arange(kb, dtype=np.int64),
        centers=np.zeros((kb, store.d)),
        owners=np.asarray([blocks.owners[b] for b in bids], dtype=np.int32),
        members=[remap(blocks.members[b]) for b in bids],
    )
    neigh_local = [remap(neigh[b][:m]) for b in bids]
    return pack_blocks(xg, yg, mini, neigh_local, m, bs_max=bs_max, dtype=dtype)


_SPOOL_KEYS = ("blk_x", "blk_y", "blk_mask", "nn_x", "nn_y", "nn_mask")


def _host_available_bytes() -> int | None:
    """MemAvailable from /proc/meminfo (the CPU backend's 'free HBM')."""
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    return None


def device_cache_budget(frac: float = 0.5, reserve_bytes: int = 0) -> int:
    """Byte budget for the device-resident spool tier.

    ``frac`` of the accelerator's free memory (``Device.memory_stats`` —
    GPU/TPU report ``bytes_limit``/``bytes_in_use``) minus
    ``reserve_bytes``, the headroom the caller needs for compute (the
    streaming fit passes its ``working_set_model`` device-grad term so
    the cache can never squeeze out the backward pass's live set). On the
    CPU backend, device memory IS host RAM, so MemAvailable stands in;
    when neither source is readable, a conservative 4GB is assumed.
    """
    import jax

    free = None
    dev = jax.devices()[0]
    try:
        stats = dev.memory_stats()
    except Exception:
        stats = None
    if stats and stats.get("bytes_limit"):
        free = int(stats["bytes_limit"]) - int(stats.get("bytes_in_use", 0))
    if free is None:
        free = _host_available_bytes() or (4 << 30)
    return max(0, int(frac * free) - int(reserve_bytes))


class PackedChunkSpool:
    """Two-tier cache of packed chunk pieces for one structure round.

    The likelihood inner loop re-reads every piece once per optimizer
    step, so WHERE the pieces wait between steps is the streaming fit's
    hot-path bandwidth question:

    * **device tier** — pieces added while cumulative bytes fit
      ``device_budget`` are transferred ONCE (``device_put``, optionally
      with a ``sharding`` for the distributed fit) and stay resident
      across every inner step of the round; re-reads cost nothing.
    * **disk tier** — overflow pieces spool to uncompressed ``.npz`` as
      before (float64 round-trips bit-exactly, so spooling never
      perturbs the fit) and are re-staged per step; ``iter_arrays``
      hides that behind compute with a ``Prefetcher`` H2D pipeline.

    Iteration order is ALWAYS add order regardless of tier, so the grad
    accumulation order — and therefore the fit, bitwise — is identical
    whether a piece sat in HBM, behind the prefetcher, or on cold disk
    (pinned in tests/test_streaming.py).
    """

    def __init__(self, path: str, device_budget: int = 0, sharding=None):
        self.path = path
        self.device_budget = int(device_budget)
        self.sharding = sharding
        # entries: (kind, payload, tag, nbytes); payload is a tuple of
        # device arrays ("dev") or an .npz path ("disk"); ``tag`` is an
        # opaque caller label (the fit stores the resolved backend).
        self._entries: list[tuple] = []
        self._made_dir = False
        self.packed_bytes_max = 0
        self.packed_bytes_total = 0
        self.device_bytes = 0
        self.disk_bytes_total = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def n_device(self) -> int:
        return sum(1 for e in self._entries if e[0] == "dev")

    @property
    def n_disk(self) -> int:
        return len(self) - self.n_device

    def _put_device(self, a: np.ndarray):
        import jax
        import jax.numpy as jnp

        if self.sharding is not None:
            return jax.device_put(a, self.sharding)
        return jnp.asarray(a)

    def add(self, packed: PackedBlocks, tag=None) -> None:
        arrs = tuple(getattr(packed, k) for k in _SPOOL_KEYS)
        nbytes = sum(a.nbytes for a in arrs)
        self.packed_bytes_max = max(self.packed_bytes_max, nbytes)
        self.packed_bytes_total += nbytes
        if self.device_bytes + nbytes <= self.device_budget:
            dev = tuple(self._put_device(a) for a in arrs)
            self._entries.append(("dev", dev, tag, nbytes))
            self.device_bytes += nbytes
            return
        if not self._made_dir:
            os.makedirs(self.path, exist_ok=True)
            self._made_dir = True
        f = os.path.join(self.path, f"chunk_{len(self._entries):05d}.npz")
        np.savez(f, owners=packed.owners,
                 **{k: a for k, a in zip(_SPOOL_KEYS, arrs)})
        self._entries.append(("disk", f, tag, nbytes))
        self.disk_bytes_total += nbytes

    def _stage(self, entry):
        """(device-array tuple, tag) for one entry — the H2D hot path.

        Disk entries are read and transferred here; running this on the
        Prefetcher's producer thread is what hides disk+transfer time
        behind the consumer's compute."""
        kind, payload, tag, _nb = entry
        if kind == "dev":
            return payload, tag
        with np.load(payload) as z:
            return tuple(self._put_device(z[k]) for k in _SPOOL_KEYS), tag

    def iter_arrays(self, prefetch: int = 2):
        """Yield ``(arrays, tag)`` per piece, in add order.

        With ``prefetch > 0`` and disk-tier pieces present, staging runs
        on a producer thread ``prefetch`` items ahead (2 = double
        buffer): the host reads and transfers piece k+1 while the device
        computes on piece k. ``prefetch=0`` is the synchronous loop —
        bitwise identical output, serial staging."""
        if prefetch > 0 and self.n_disk:
            from repro.prefetch import Prefetcher

            with Prefetcher(iter(self._entries), depth=prefetch,
                            stage=self._stage, name="sbv-h2d") as staged:
                yield from staged
        else:
            for entry in self._entries:
                yield self._stage(entry)

    def cleanup(self) -> None:
        for kind, payload, *_ in self._entries:
            if kind == "disk":
                try:
                    os.remove(payload)
                except OSError:
                    pass
        self._entries = []  # drops the device-tier references too
        try:
            os.rmdir(self.path)
        except OSError:
            pass


@dataclass
class StreamStructure:
    """One outer round's streaming preprocessing product."""

    blocks: BlockStructure
    neigh: list
    flat: LazyFlatBlocks
    domain_volume: float
    plan: list
    bs_max: int


def streaming_preprocess(
    store, beta: np.ndarray, cfg, stream_chunk: int,
    struct_batch: int | None = None, cache_bytes: int = 32 << 20,
) -> StreamStructure:
    """scale -> mini-batch k-means -> order -> store-backed NNS -> plan.

    The streaming counterpart of ``core.pipeline.preprocess``; clustering
    is mini-batch k-means (the one pass-structured algorithm) regardless
    of ``cfg.clustering``, and the structure batch size is decoupled from
    ``stream_chunk`` so the packing window can change without changing
    the block structure."""
    blocks, radii, vol = streaming_kmeans_blocks(
        store, beta, cfg.n_blocks, n_workers=cfg.n_workers, seed=cfg.seed,
        batch_rows=struct_batch or DEFAULT_STRUCT_BATCH,
        ordering=cfg.ordering,
    )
    neigh, flat = streaming_filtered_nns(
        store, blocks, radii, beta, cfg.m, alpha=cfg.alpha,
        domain_volume=vol, cache_bytes=cache_bytes,
    )
    plan = plan_block_chunks(blocks, neigh, cfg.m, stream_chunk)
    bs_max = int(max(mb.size for mb in blocks.members))
    if cfg.bs_max is not None:
        bs_max = max(bs_max, cfg.bs_max)
    return StreamStructure(blocks=blocks, neigh=neigh, flat=flat,
                           domain_volume=vol, plan=plan, bs_max=bs_max)


# -- prediction-side gather ------------------------------------------------


def working_set_model(stream_stats: dict, n_rows: int, d: int, m: int,
                      stream_chunk: int, n_caches: int = 2) -> dict:
    """Bytes model of the streaming fit's resident working set.

    Shared by the RSS gates (tests/test_streaming.py and
    benchmarks/fig_streaming_scale.py) so they can assert
    ``peak_rss_delta <= 2 x total`` against one definition. Terms:

    * chunk windows — raw rows + scaled copy + one transient (3x);
    * packed chunk  — host .npz load + device transfer + arena slack (4x);
    * device grad   — the ``lax.map``-batched checkpointed backward keeps
      ~16 live buffer sets of ``_MAP_BATCH x (bs_max+m)^2`` (forward
      recompute + cotangents), independent of chunk size;
    * NNS scan      — worst-case candidate gather: with a near-isotropic
      beta in higher d the coarse filter can admit most blocks for one
      query, so the transient is O(n x d) (concat + squared distances);
    * index arrays  — labels/members/flat_idx/flat_rank + neighbor lists;
    * gather caches — the LRU block-point caches (fit and predict index);
    * device spool  — the device-resident spool tier (docs/streaming.md
      "inner-loop memory tiers"): on the CPU backend device arrays ARE
      host RSS, so cached pieces count double (buffer + transfer
      transient). Only present when the run actually cached pieces.

    The same constants applied to the WHOLE dataset give
    ``incore_total``: what the monolithic path would hold resident. The
    gates require ``2 x total < incore_total`` so the ceiling actually
    distinguishes streaming from slurping.
    """
    from repro.core.fit import _MAP_BATCH

    st = stream_stats
    joint2 = (st["bs_max"] + m) ** 2
    terms = {
        "chunk_windows": 3 * stream_chunk * (d + 1) * 8,
        "packed_chunk": 4 * st["packed_chunk_bytes_max"],
        "device_grad": 16 * _MAP_BATCH * joint2 * 8,
        "nns_scan": 3 * n_rows * d * 8,
        "index_arrays": 4 * n_rows * 8 + st["bc"] * m * 8,
        "gather_caches": n_caches * (32 << 20),
    }
    if st.get("device_cached_bytes"):
        terms["device_spool"] = 2 * st["device_cached_bytes"]
    total = sum(terms.values())
    incore_total = (
        2 * n_rows * (d + 1) * 8      # raw + scaled arrays resident
        + 2 * st["spool_bytes"]        # packed dataset, host + device
        + 4 * st["bc"] * joint2 * 8    # vmapped grad live set over all blocks
    )
    return {"terms": terms, "total": total, "incore_total": incore_total}


def localize_neighbors(store, neighbors: list):
    """Gather the union of neighbor rows once and remap each list into the
    gathered subset — hands ``pack_prediction`` small in-core arrays in
    place of the full training set. Values and per-list order are
    preserved, so the packed arrays are bit-identical to the in-core
    path's."""
    if neighbors:
        rows_needed = np.unique(np.concatenate([np.asarray(nb) for nb in neighbors]))
    else:
        rows_needed = np.empty(0, np.int64)
    xg, yg = store.read_rows(rows_needed)
    remapped = [np.searchsorted(rows_needed, np.asarray(nb)) for nb in neighbors]
    return xg, yg, remapped
