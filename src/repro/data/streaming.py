"""Streaming (out-of-core) SBV construction over a row store.

Every stage of the in-core preprocessing pipeline
(scale -> block -> order -> NNS -> pack) assumes the full ``(n, d)``
dataset sits in host RAM. This module rebuilds each stage as a pass over
``store.iter_chunks(rows)`` windows so the resident working set is
bounded by the chunk size, not ``n`` — the property that carries the
paper from 200k points to 50M:

* ``streaming_kmeans_blocks`` — mini-batch k-means over chunk iterators
  (Sculley-style center updates with per-epoch count resets, so the
  single-chunk case reduces EXACTLY to Lloyd iterations — the parity
  hook tests/test_streaming.py pins), then one labeling pass that also
  accumulates exact centroids, per-dimension extents (for the Eq. 7 NNS
  radius) and a radius pass against the final centers;
* ``LazyFlatBlocks`` — the store-backed twin of ``core.nns._FlatBlocks``:
  same index bookkeeping, but member coordinates are gathered on demand
  (LRU-cached per block). Block ids are relabeled in center-coordinate
  order, so the NNS sweep visits spatially adjacent blocks consecutively
  and the cache turns the gather stream into ~one pass over the store;
* ``plan_block_chunks`` / ``pack_block_chunk`` / ``PackedChunkSpool`` —
  conditioning-rank-ordered groups of blocks whose member+neighbor rows
  fit the ``stream_chunk`` budget, packed via the existing
  ``pack_blocks`` on a gathered-and-remapped row subset, and spooled to
  ``.npz`` files so likelihood passes re-read bounded chunks instead of
  holding the packed dataset.

Bitwise contract: all arithmetic is elementwise or reduction ops whose
operand order is independent of where the rows live, so a ``MemoryStore``
and an ``ArrayStore`` holding the same rows produce identical structures,
packings, and fits (tests/test_streaming.py pins this at 0 difference;
the 1e-10 tolerances in the acceptance gate cover the chunked-vs-
monolithic likelihood summation order, not the IO layer).
"""
from __future__ import annotations

import os
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.blocks import BlockStructure, most_relevant_dim, scale_inputs
from repro.core.nns import _FlatBlocks, filtered_nns
from repro.core.packing import PackedBlocks, pack_blocks

DEFAULT_STRUCT_BATCH = 65536  # rows per structure pass (decoupled from
                              # stream_chunk so the packing window can vary
                              # without changing the k-means trajectory)
ROW_TILE = 2048               # rows per assignment distance tile
MAX_D2_ENTRIES = 2 << 20      # bound on distance-tile size (entries)


# -- chunked moments -------------------------------------------------------


def streaming_moments(store, batch_rows: int = DEFAULT_STRUCT_BATCH, comm=None):
    """(mean, variance) of y accumulated chunk-wise (population variance,
    matching ``np.var`` up to summation order).

    Two shifted passes: pass 1 accumulates the mean, pass 2 accumulates
    ``sum((y - mean)^2)``. The one-pass ``E[y^2] - mean^2`` form cancels
    catastrophically when ``|mean| >> std`` (a y offset of 1e8 collapses
    the variance to the clamp at 0, silently initializing ``sigma2 ~ 0``
    for the streaming fit); the shifted form keeps full precision there
    while still visiting identical windows on either store backend, so
    MemoryStore/ArrayStore parity stays bitwise.

    ``comm`` (a ``repro.multihost`` host comm) all-reduces the pass
    sums so each host only walks its own partition of the rows.
    """
    n = store.n_rows
    s = 0.0
    for _, _, yw in store.iter_chunks(batch_rows):
        s += float(np.sum(yw))
    if comm is not None:
        s = float(comm.allreduce(np.asarray([s]))[0])
    mean = s / max(n, 1)
    ss = 0.0
    for _, _, yw in store.iter_chunks(batch_rows):
        r = yw - mean
        ss += float(np.sum(r * r))
    if comm is not None:
        ss = float(comm.allreduce(np.asarray([ss]))[0])
    return mean, ss / max(n, 1)


# -- mini-batch k-means blocking ------------------------------------------


def _center_tile(n_centers: int) -> int:
    """Centers per distance tile: keeps row_tile x center_tile bounded."""
    return max(32, min(2048, MAX_D2_ENTRIES // ROW_TILE))


def _assign_chunk(xs: np.ndarray, centers: np.ndarray, c2: np.ndarray):
    """Nearest-center label per row, tiled over rows AND centers so the
    distance buffer never exceeds ROW_TILE x center-tile entries.

    The assignment is memory-bound (n x k distance entries dwarf the
    rank-d GEMM), so the tiles run in float32 with the row-norm term
    dropped — ``argmin_j ||x - c_j||^2 = argmin_j (c2_j - 2 x.c_j)`` —
    and in-place updates: ~3x less traffic than the naive f64 broadcast.
    Labels are a clustering heuristic (everything downstream that needs
    exactness — radii, centroids, NNS — recomputes in f64), and both
    store backends run the identical instruction stream, so bitwise
    memory/disk parity is preserved. Strict-< running best keeps
    numpy's first-minimum tie-breaking across center tiles."""
    n, k = xs.shape[0], centers.shape[0]
    ct = _center_tile(k)
    cen32 = np.ascontiguousarray(centers.T, dtype=np.float32)  # (d, k)
    c232 = c2.astype(np.float32)
    labels = np.empty(n, dtype=np.int64)
    for rs in range(0, n, ROW_TILE):
        xr = xs[rs:rs + ROW_TILE].astype(np.float32)
        rows = np.arange(xr.shape[0])
        best = np.full(xr.shape[0], np.inf, dtype=np.float32)
        lab = np.zeros(xr.shape[0], dtype=np.int64)
        for cs in range(0, k, ct):
            d2 = xr @ cen32[:, cs:cs + ct]
            d2 *= -2.0
            d2 += c232[cs:cs + ct][None, :]
            j = np.argmin(d2, axis=1)
            v = d2[rows, j]
            upd = v < best
            best[upd] = v[upd]
            lab[upd] = j[upd] + cs
        labels[rs:rs + ROW_TILE] = lab
    return labels


def _label_sums(labels: np.ndarray, xs: np.ndarray, k: int):
    """Per-label row counts and coordinate sums (bincount per dim: C-fast)."""
    counts = np.bincount(labels, minlength=k).astype(np.float64)
    sums = np.stack(
        [np.bincount(labels, weights=xs[:, j], minlength=k)
         for j in range(xs.shape[1])], axis=1,
    )
    return counts, sums


def streaming_kmeans_blocks(
    store,
    beta: np.ndarray,
    n_blocks: int,
    n_workers: int = 1,
    seed: int = 0,
    epochs: int = 2,
    batch_rows: int = DEFAULT_STRUCT_BATCH,
    ordering: str = "random",
):
    """Mini-batch k-means blocking over chunk iterators.

    Returns ``(BlockStructure, radii, domain_volume)`` — everything the
    filtered NNS needs, with nothing larger than index arrays held in
    RAM. Deterministic given (store contents, seed, batch_rows); with
    ``batch_rows >= n`` every epoch is exactly one Lloyd iteration.

    Block ids are assigned in center-coordinate order along the most
    relevant dimension, so id-ordered sweeps (the NNS loop) visit
    spatially adjacent blocks consecutively — that locality is what makes
    the store-backed lazy gather cache effective.
    """
    rng = np.random.default_rng(seed)
    n, d = store.n_rows, store.d
    beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (d,))
    k = min(int(n_blocks), n)

    init_idx = rng.choice(n, size=k, replace=False)
    centers = scale_inputs(store.read_rows(init_idx)[0], beta)

    for _ in range(max(int(epochs), 0)):
        counts = np.zeros(k)
        c2 = np.sum(centers * centers, axis=1)
        for _, xw, _ in store.iter_chunks(batch_rows):
            xs = scale_inputs(xw, beta)
            lab = _assign_chunk(xs, centers, c2)
            k_c, sums = _label_sums(lab, xs, k)
            counts += k_c
            nz = k_c > 0
            centers[nz] += (sums[nz] - k_c[nz, None] * centers[nz]) / counts[nz, None]
            c2 = np.sum(centers * centers, axis=1)
        empty = counts == 0
        if empty.any():
            re_idx = rng.choice(n, size=int(empty.sum()), replace=False)
            centers[empty] = scale_inputs(store.read_rows(re_idx)[0], beta)

    # Final labeling pass: exact centroids + scaled-domain extents.
    labels = np.empty(n, dtype=np.int64)
    counts = np.zeros(k)
    sums = np.zeros((k, d))
    mins = np.full(d, np.inf)
    maxs = np.full(d, -np.inf)
    c2 = np.sum(centers * centers, axis=1)
    for start, xw, _ in store.iter_chunks(batch_rows):
        xs = scale_inputs(xw, beta)
        lab = _assign_chunk(xs, centers, c2)
        labels[start:start + xs.shape[0]] = lab
        k_c, s_c = _label_sums(lab, xs, k)
        counts += k_c
        sums += s_c
        np.minimum(mins, xs.min(axis=0), out=mins)
        np.maximum(maxs, xs.max(axis=0), out=maxs)

    # Compact away empty blocks, then relabel in center-coordinate order.
    occupied = np.nonzero(counts > 0)[0]
    centers = sums[occupied] / counts[occupied][:, None]
    dprime = most_relevant_dim(beta)
    coord_order = np.argsort(centers[:, dprime], kind="stable")
    centers = centers[coord_order]
    bc = occupied.size
    old_to_new = np.full(k, -1, dtype=np.int64)
    old_to_new[occupied[coord_order]] = np.arange(bc)
    labels = old_to_new[labels]

    # Radius pass against the FINAL centers (upper bound the coarse
    # filter relies on; running centers would under-estimate it).
    r2 = np.zeros(bc)
    for start, xw, _ in store.iter_chunks(batch_rows):
        xs = scale_inputs(xw, beta)
        lab = labels[start:start + xs.shape[0]]
        d2 = np.sum((xs - centers[lab]) ** 2, axis=1)
        np.maximum.at(r2, lab, d2)
    radii = np.sqrt(r2)

    # Members from one stable argsort (ascending indices within a block,
    # matching np.nonzero order in the in-core builder).
    by_block = np.argsort(labels, kind="stable")
    sizes = np.bincount(labels, minlength=bc)
    members = np.split(by_block, np.cumsum(sizes)[:-1])

    # Owner shard per block by quantile bucketing of the center coordinate
    # (same locality property as the per-point Alg. 2 partition).
    if n_workers > 1:
        qs = np.quantile(centers[:, dprime],
                         np.linspace(0.0, 1.0, n_workers + 1)[1:-1])
        owners = np.searchsorted(qs, centers[:, dprime], side="right")
    else:
        owners = np.zeros(bc, dtype=np.int64)

    if ordering == "random":
        order = rng.permutation(bc)
    elif ordering == "coord":
        order = np.arange(bc)  # ids are already in coordinate order
    elif ordering == "maxmin":
        from repro.core.blocks import _maxmin_order

        order = _maxmin_order(centers, rng)  # centers are in-RAM: bc x d
    else:
        raise ValueError(f"unknown streaming ordering {ordering!r}")
    rank_of_block = np.empty(bc, dtype=np.int64)
    rank_of_block[order] = np.arange(bc)

    ext = maxs - mins
    med = np.median(ext[ext > 0]) if np.any(ext > 0) else 1.0
    ext = np.maximum(ext, 1e-6 * med)
    domain_volume = float(np.prod(ext))

    blocks = BlockStructure(
        labels=labels,
        order=np.asarray(order, dtype=np.int64),
        rank_of_block=rank_of_block,
        centers=centers,
        owners=np.asarray(owners, dtype=np.int32),
        members=members,
    )
    return blocks, radii, domain_volume


# -- store-backed flat block index ----------------------------------------


class LazyFlatBlocks(_FlatBlocks):
    """``_FlatBlocks`` over a store: coordinates gathered on demand.

    Holds the same index bookkeeping (sizes/starts/flat_idx/flat_rank/
    radii) but no ``flat_pts``; ``points_of_blocks`` serves scaled member
    coordinates from a bytes-bounded per-block LRU cache, batching all
    cache misses of a call into one ``read_rows`` gather.
    """

    def __init__(self, blocks: BlockStructure, radii: np.ndarray, store,
                 beta: np.ndarray, cache_bytes: int = 32 << 20):
        sizes = np.asarray([mb.size for mb in blocks.members], dtype=np.int64)
        self.sizes = sizes
        self.starts = np.concatenate([[0], np.cumsum(sizes)])
        self.flat_idx = (
            np.concatenate(blocks.members) if blocks.n_blocks else np.empty(0, np.int64)
        )
        self.flat_rank = np.repeat(blocks.rank_of_block, sizes)
        self.radii = np.asarray(radii)
        self.n_rows = store.n_rows
        self.d = store.d
        self._store = store
        self._beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (store.d,))
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self._cache_bytes = 0
        self._cache_cap = int(cache_bytes)
        self.gathered_rows = 0  # telemetry: store rows actually read

    def _evict(self) -> None:
        while self._cache_bytes > self._cache_cap and len(self._cache) > 1:
            _, old = self._cache.popitem(last=False)
            self._cache_bytes -= old.nbytes

    def points_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        block_ids = np.asarray(block_ids, dtype=np.int64)
        if block_ids.size == 0:
            return np.empty((0, self.d))
        # Dedupe the miss list (preserving first-occurrence order): a
        # duplicate id in one call must be gathered and accounted ONCE —
        # double-counting ``_cache_bytes`` for a single retained copy
        # inflates the counter permanently and drives the LRU into
        # premature eviction.
        missing = list(dict.fromkeys(
            int(b) for b in block_ids if int(b) not in self._cache))
        if missing:
            rows = np.concatenate(
                [self.flat_idx[self.starts[b]:self.starts[b + 1]] for b in missing]
            )
            pts = scale_inputs(self._store.read_rows(rows)[0], self._beta)
            self.gathered_rows += rows.size
            off = 0
            for b in missing:
                k = int(self.sizes[b])
                self._cache[b] = pts[off:off + k]
                self._cache_bytes += self._cache[b].nbytes
                off += k
            self._evict()
        out = []
        for b in block_ids:
            b = int(b)
            pts = self._cache[b]
            self._cache.move_to_end(b)
            out.append(pts)
        return out[0] if len(out) == 1 else np.concatenate(out)


def streaming_filtered_nns(
    store, blocks: BlockStructure, radii: np.ndarray, beta: np.ndarray,
    m: int, alpha: float = 100.0, domain_volume: float | None = None,
    cache_bytes: int = 32 << 20,
):
    """Filtered preceding-block NNS with store-backed candidate gathers.

    The query sweep runs in block-id order == center-coordinate order
    (see ``streaming_kmeans_blocks``), so consecutive queries share most
    of their candidate blocks and the LRU cache bounds re-reads.
    Returns ``(neighbors, flat)`` so callers can keep the warm index.
    """
    flat = LazyFlatBlocks(blocks, radii, store, beta, cache_bytes=cache_bytes)
    bc = max(blocks.n_blocks, 1)
    center_chunk = max(16, min(2048, MAX_D2_ENTRIES // bc))
    neigh = filtered_nns(None, blocks, m, alpha=alpha, center_chunk=center_chunk,
                         flat=flat, domain_volume=domain_volume)
    return neigh, flat


# -- chunked packing -------------------------------------------------------


def plan_block_chunks(blocks: BlockStructure, neigh: list, m: int,
                      stream_chunk: int, ranks=None) -> list[np.ndarray]:
    """Group conditioning ranks so each group's member+neighbor rows fit
    the ``stream_chunk`` budget. Groups are contiguous in rank order;
    a single oversized block still gets its own chunk (the budget is a
    target, not a validity condition). ``ranks`` restricts the plan to a
    subsequence of conditioning ranks (a host's owned blocks in the
    multi-host build); the default plans every rank."""
    plans: list[np.ndarray] = []
    cur: list[int] = []
    rows = 0
    rank_seq = range(len(blocks.order)) if ranks is None else ranks
    for rank in rank_seq:
        rank = int(rank)
        b = blocks.order[rank]
        cost = int(blocks.members[b].size) + min(len(neigh[b]), m)
        if cur and rows + cost > stream_chunk:
            plans.append(np.asarray(cur, dtype=np.int64))
            cur, rows = [], 0
        cur.append(rank)
        rows += cost
    if cur:
        plans.append(np.asarray(cur, dtype=np.int64))
    return plans


def pack_block_chunk(
    store, blocks: BlockStructure, neigh: list, ranks: np.ndarray,
    m: int, bs_max: int, dtype=np.float64,
) -> PackedBlocks:
    """Pack one rank-chunk by gathering the union of its member+neighbor
    rows once and remapping indices into the gathered subset — the packed
    arrays are bit-identical to the same blocks' slices of an in-core
    ``pack_blocks`` (gathers preserve values and relative order)."""
    bids = blocks.order[ranks]
    pieces = [blocks.members[b] for b in bids] + [neigh[b][:m] for b in bids]
    rows_needed = np.unique(np.concatenate(pieces)) if pieces else np.empty(0, np.int64)
    xg, yg = store.read_rows(rows_needed)

    def remap(a):
        return np.searchsorted(rows_needed, a)

    kb = len(bids)
    mini = BlockStructure(
        labels=np.empty(0, dtype=np.int64),
        order=np.arange(kb, dtype=np.int64),
        rank_of_block=np.arange(kb, dtype=np.int64),
        centers=np.zeros((kb, store.d)),
        owners=np.asarray([blocks.owners[b] for b in bids], dtype=np.int32),
        members=[remap(blocks.members[b]) for b in bids],
    )
    neigh_local = [remap(neigh[b][:m]) for b in bids]
    return pack_blocks(xg, yg, mini, neigh_local, m, bs_max=bs_max, dtype=dtype)


_SPOOL_KEYS = ("blk_x", "blk_y", "blk_mask", "nn_x", "nn_y", "nn_mask")


def _npz_encode(items: dict) -> dict:
    """npz-safe view of a named-array bundle.

    ``np.savez`` silently stores ml_dtypes bfloat16 as a void dtype that
    cannot be read back, so bf16 arrays (the precision ladder's narrow
    coordinate tier, docs/precision.md) are spooled as their uint16 bit
    pattern under a ``__bf16__<name>`` flag key — the same convention as
    ckpt/checkpoint.py — and re-viewed on load. Bit-exact round trip."""
    import ml_dtypes

    out = {}
    for k, a in items.items():
        if a.dtype == ml_dtypes.bfloat16:
            out[f"__bf16__{k}"] = a.view(np.uint16)
        else:
            out[k] = a
    return out


def _npz_read(z, k: str) -> np.ndarray:
    """Read one array from an npz written via ``_npz_encode``."""
    if k in z:
        return z[k]
    import ml_dtypes

    return z[f"__bf16__{k}"].view(ml_dtypes.bfloat16)


def _host_available_bytes() -> int | None:
    """MemAvailable from /proc/meminfo (the CPU backend's 'free HBM')."""
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    return None


def device_cache_budget(frac: float = 0.5, reserve_bytes: int = 0) -> int:
    """Byte budget for the device-resident spool tier.

    ``frac`` of the accelerator's free memory (``Device.memory_stats`` —
    GPU/TPU report ``bytes_limit``/``bytes_in_use``) minus
    ``reserve_bytes``, the headroom the caller needs for compute (the
    streaming fit passes its ``working_set_model`` device-grad term so
    the cache can never squeeze out the backward pass's live set). On the
    CPU backend, device memory IS host RAM, so MemAvailable stands in;
    when neither source is readable, a conservative 4GB is assumed.
    """
    import jax

    free = None
    dev = jax.devices()[0]
    try:
        stats = dev.memory_stats()
    except Exception:
        stats = None
    if stats and stats.get("bytes_limit"):
        free = int(stats["bytes_limit"]) - int(stats.get("bytes_in_use", 0))
    if free is None:
        free = _host_available_bytes() or (4 << 30)
    return max(0, int(frac * free) - int(reserve_bytes))


class PackedChunkSpool:
    """Two-tier cache of packed chunk pieces for one structure round.

    The likelihood inner loop re-reads every piece once per optimizer
    step, so WHERE the pieces wait between steps is the streaming fit's
    hot-path bandwidth question:

    * **device tier** — pieces added while cumulative bytes fit
      ``device_budget`` are transferred ONCE (``device_put``, optionally
      with a ``sharding`` for the distributed fit) and stay resident
      across every inner step of the round; re-reads cost nothing.
    * **disk tier** — overflow pieces spool to uncompressed ``.npz`` as
      before (float64 round-trips bit-exactly, so spooling never
      perturbs the fit) and are re-staged per step; ``iter_arrays``
      hides that behind compute with a ``Prefetcher`` H2D pipeline.

    Iteration order is ALWAYS add order regardless of tier, so the grad
    accumulation order — and therefore the fit, bitwise — is identical
    whether a piece sat in HBM, behind the prefetcher, or on cold disk
    (pinned in tests/test_streaming.py).
    """

    def __init__(self, path: str, device_budget: int = 0, sharding=None,
                 device_stage: bool = True):
        self.path = path
        self.device_budget = int(device_budget)
        self.sharding = sharding
        # device_stage=False keeps staged arrays as host numpy (no
        # device_put): the serving-side result sink spools outputs that
        # are consumed on the host, so a device round-trip would be pure
        # overhead (and would perturb nothing anyway — float64 .npz
        # round-trips are bit-exact either way).
        self.device_stage = device_stage
        # entries: (kind, payload, tag, nbytes, keys); payload is a tuple
        # of staged arrays ("dev") or an .npz path ("disk"); ``tag`` is an
        # opaque caller label (the fit stores the resolved backend).
        # ``keys`` is None for the positional packed-piece layout
        # (_SPOOL_KEYS) or the entry's own name tuple for ``add_arrays``
        # bundles, which stage back as dicts.
        self._entries: list[tuple] = []
        self._made_dir = False
        self.packed_bytes_max = 0
        self.packed_bytes_total = 0
        self.device_bytes = 0
        self.disk_bytes_total = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def n_device(self) -> int:
        return sum(1 for e in self._entries if e[0] == "dev")

    @property
    def n_disk(self) -> int:
        return len(self) - self.n_device

    def _put_device(self, a: np.ndarray):
        if not self.device_stage:
            return np.asarray(a)
        import jax
        import jax.numpy as jnp

        if self.sharding is not None:
            return jax.device_put(a, self.sharding)
        return jnp.asarray(a)

    def add(self, packed: PackedBlocks, tag=None) -> None:
        arrs = tuple(getattr(packed, k) for k in _SPOOL_KEYS)
        nbytes = sum(a.nbytes for a in arrs)
        self.packed_bytes_max = max(self.packed_bytes_max, nbytes)
        self.packed_bytes_total += nbytes
        if self.device_bytes + nbytes <= self.device_budget:
            dev = tuple(self._put_device(a) for a in arrs)
            self._entries.append(("dev", dev, tag, nbytes, None))
            self.device_bytes += nbytes
            return
        if not self._made_dir:
            os.makedirs(self.path, exist_ok=True)
            self._made_dir = True
        f = os.path.join(self.path, f"chunk_{len(self._entries):05d}.npz")
        np.savez(f, owners=packed.owners,
                 **_npz_encode({k: a for k, a in zip(_SPOOL_KEYS, arrs)}))
        self._entries.append(("disk", f, tag, nbytes, None))
        self.disk_bytes_total += nbytes

    def add_arrays(self, arrays: dict, tag=None) -> None:
        """Spool one ad-hoc named-array bundle under the same two-tier /
        add-order contract as ``add``. This is the serving-side sink
        entry point (``serving/pipeline.py::SpoolResultSink``): the keys
        are the caller's own, and ``iter_arrays`` stages the bundle back
        as a dict instead of the positional packed-piece tuple."""
        items = {k: np.asarray(v) for k, v in arrays.items()}
        nbytes = sum(a.nbytes for a in items.values())
        keys = tuple(items)
        self.packed_bytes_max = max(self.packed_bytes_max, nbytes)
        self.packed_bytes_total += nbytes
        if self.device_bytes + nbytes <= self.device_budget:
            dev = {k: self._put_device(a) for k, a in items.items()}
            self._entries.append(("dev", dev, tag, nbytes, keys))
            self.device_bytes += nbytes
            return
        if not self._made_dir:
            os.makedirs(self.path, exist_ok=True)
            self._made_dir = True
        f = os.path.join(self.path, f"chunk_{len(self._entries):05d}.npz")
        np.savez(f, **_npz_encode(items))
        self._entries.append(("disk", f, tag, nbytes, keys))
        self.disk_bytes_total += nbytes

    def _stage(self, entry):
        """(staged arrays, tag) for one entry — the H2D hot path.

        Disk entries are read and transferred here; running this on the
        Prefetcher's producer thread is what hides disk+transfer time
        behind the consumer's compute."""
        kind, payload, tag, _nb, keys = entry
        if kind == "dev":
            return payload, tag
        with np.load(payload) as z:
            if keys is None:
                return tuple(self._put_device(_npz_read(z, k))
                             for k in _SPOOL_KEYS), tag
            return {k: self._put_device(_npz_read(z, k)) for k in keys}, tag

    def iter_arrays(self, prefetch: int = 2):
        """Yield ``(arrays, tag)`` per piece, in add order.

        With ``prefetch > 0`` and disk-tier pieces present, staging runs
        on a producer thread ``prefetch`` items ahead (2 = double
        buffer): the host reads and transfers piece k+1 while the device
        computes on piece k. ``prefetch=0`` is the synchronous loop —
        bitwise identical output, serial staging."""
        if prefetch > 0 and self.n_disk:
            from repro.prefetch import Prefetcher

            with Prefetcher(iter(self._entries), depth=prefetch,
                            stage=self._stage, name="sbv-h2d") as staged:
                yield from staged
        else:
            for entry in self._entries:
                yield self._stage(entry)

    def cleanup(self) -> None:
        for kind, payload, *_ in self._entries:
            if kind == "disk":
                try:
                    os.remove(payload)
                except OSError:
                    pass
        self._entries = []  # drops the device-tier references too
        try:
            os.rmdir(self.path)
        except OSError:
            pass
        # Reset the per-round state so the spool object is reusable: the
        # directory is gone, so a later overflow-to-disk ``add`` must
        # recreate it (stale ``_made_dir`` made ``np.savez`` crash with
        # FileNotFoundError), and the tier gauges describe CURRENT
        # entries (``packed_bytes_max/total`` stay cumulative — they are
        # high-water telemetry, not occupancy).
        self._made_dir = False
        self.device_bytes = 0
        self.disk_bytes_total = 0


@dataclass
class StreamStructure:
    """One outer round's streaming preprocessing product."""

    blocks: BlockStructure
    neigh: list
    flat: LazyFlatBlocks
    domain_volume: float
    plan: list
    bs_max: int


def streaming_preprocess(
    store, beta: np.ndarray, cfg, stream_chunk: int,
    struct_batch: int | None = None, cache_bytes: int = 32 << 20,
) -> StreamStructure:
    """scale -> mini-batch k-means -> order -> store-backed NNS -> plan.

    The streaming counterpart of ``core.pipeline.preprocess``; clustering
    is mini-batch k-means (the one pass-structured algorithm) regardless
    of ``cfg.clustering``, and the structure batch size is decoupled from
    ``stream_chunk`` so the packing window can change without changing
    the block structure."""
    blocks, radii, vol = streaming_kmeans_blocks(
        store, beta, cfg.n_blocks, n_workers=cfg.n_workers, seed=cfg.seed,
        batch_rows=struct_batch or DEFAULT_STRUCT_BATCH,
        ordering=cfg.ordering,
    )
    neigh, flat = streaming_filtered_nns(
        store, blocks, radii, beta, cfg.m, alpha=cfg.alpha,
        domain_volume=vol, cache_bytes=cache_bytes,
    )
    plan = plan_block_chunks(blocks, neigh, cfg.m, stream_chunk)
    bs_max = int(max(mb.size for mb in blocks.members))
    if cfg.bs_max is not None:
        bs_max = max(bs_max, cfg.bs_max)
    return StreamStructure(blocks=blocks, neigh=neigh, flat=flat,
                           domain_volume=vol, plan=plan, bs_max=bs_max)


# -- multi-host construction (Alg. 2 across processes) ---------------------
#
# The single-process streaming build above bounds RAM; this section bounds
# it PER HOST. Each `jax.distributed` process owns one `PartitionedStore`
# row range, and the stages communicate exactly like the paper's MPI
# pipeline:
#
#   k-means      — per-host labeling of local windows; per-window
#                  (count, sum) all-reduce, so every host applies the
#                  identical center update (the single-process trajectory
#                  when partition bounds align to the window grid);
#   membership   — each local row is sent once to the host owning its
#                  block (Alg. 2's MPI_Alltoall), giving the owner a
#                  `HostRowTable` of ~n/P rows: the only copy of the data
#                  it keeps resident;
#   filtered NNS — each host sweeps only its owned query blocks; foreign
#                  candidate blocks admitted by the coarse filter
#                  (dist <= lam + radius_j, replicated centers/radii) are
#                  pulled from their owners in lockstep halo-exchange
#                  rounds — `_one_block` runs UNCHANGED over a flat-blocks
#                  view that raises `_HaloMiss` for absent blocks, so the
#                  candidate-set semantics are identical to the
#                  single-process sweep;
#   packing      — `plan_block_chunks(ranks=owned)` + the unchanged
#                  `pack_block_chunk` against the row table, spooled to a
#                  per-host `PackedChunkSpool`.
#
# No stage materializes the full dataset or the full packed set on any
# process. With `LoopbackComm` (P=1) every all-reduce is the identity and
# the construction is bitwise the single-process one (pinned in
# tests/test_multihost.py).


@dataclass
class MultihostStructure:
    """One host's share of a multi-process streaming preprocessing round."""

    blocks: BlockStructure     # global order/centers/host-owners; members
                               # filled for owned (+ fetched halo) blocks,
                               # None elsewhere; labels are LOCAL rows only
    neigh: list                # neighbor ids for owned blocks, [] elsewhere
    table: "HostRowTable"      # rows of owned blocks + fetched halo rows
    host_of_block: np.ndarray  # (bc,) owning host per block id
    sizes: np.ndarray          # (bc,) GLOBAL block sizes
    domain_volume: float
    plan: list                 # rank-chunks over owned ranks only
    bs_max: int                # GLOBAL max block size (shared piece shapes)
    stats: dict


class HostRowTable:
    """Sorted (global id -> row) table of the rows a host keeps resident.

    Built from the membership exchange (rows of owned blocks) and grown
    by halo fetches; `read_rows` serves any subset in requested order via
    one searchsorted, so `pack_block_chunk` runs against it unchanged.
    """

    def __init__(self, d: int):
        self._d = int(d)
        self.gid = np.empty(0, np.int64)
        self.x = np.empty((0, self._d))
        self.y = np.empty(0)

    @property
    def d(self) -> int:
        return self._d

    @property
    def n_rows(self) -> int:
        return int(self.gid.size)

    def add(self, ids: np.ndarray, x: np.ndarray, y: np.ndarray) -> None:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        gid = np.concatenate([self.gid, ids])
        order = np.argsort(gid, kind="stable")
        self.gid = gid[order]
        self.x = np.concatenate([self.x, np.asarray(x, np.float64)])[order]
        self.y = np.concatenate([self.y, np.asarray(y, np.float64)])[order]

    def read_rows(self, idx: np.ndarray):
        idx = np.asarray(idx, np.int64)
        pos = np.searchsorted(self.gid, idx)
        if idx.size:
            bad = (pos >= self.gid.size) | (self.gid[np.minimum(pos, self.gid.size - 1)] != idx)
            if bad.any():
                raise KeyError(
                    f"{int(bad.sum())} rows absent from this host's table "
                    f"(first: {idx[bad][:5].tolist()})")
        return self.x[pos], self.y[pos]


def multihost_kmeans_blocks(
    pstore,
    beta: np.ndarray,
    n_blocks: int,
    comm,
    seed: int = 0,
    epochs: int = 2,
    batch_rows: int = DEFAULT_STRUCT_BATCH,
    ordering: str = "random",
):
    """`streaming_kmeans_blocks` with per-window (count, sum) all-reduce.

    Every host walks only its `PartitionedStore` windows but applies the
    same center update per GLOBAL window (hosts whose partition misses a
    window contribute zeros), so the center trajectory — and, with
    window-aligned partitions, its exact floats — matches the
    single-process mini-batch k-means. The rng stream (seeding, empty-
    block reseeds, the final permutation) is consumed identically on all
    hosts, so everything replicated stays replicated.

    Returns ``(blocks, labels_local, radii, domain_volume,
    host_of_block)`` where ``blocks.members`` is filled ONLY for blocks
    this host owns (ascending global ids, the single-process member
    order) and ``blocks.labels`` holds the host's LOCAL rows.
    """
    rng = np.random.default_rng(seed)
    n, d = pstore.n_rows, pstore.d
    beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (d,))
    k = min(int(n_blocks), n)
    batch_rows = max(1, int(batch_rows))
    n_windows = -(-n // batch_rows)

    init_idx = rng.choice(n, size=k, replace=False)
    centers = scale_inputs(pstore.read_rows(init_idx)[0], beta)

    def _local_windows(gstart, it, pending):
        """Local (xs, lab) pieces of the global window at ``gstart``."""
        pieces = []
        while pending[0] is not None and \
                gstart <= pending[0][0] < gstart + batch_rows:
            a, xw, _ = pending[0]
            xs = scale_inputs(xw, beta)
            pieces.append((a, xs))
            pending[0] = next(it, None)
        return pieces

    for _ in range(max(int(epochs), 0)):
        counts = np.zeros(k)
        c2 = np.sum(centers * centers, axis=1)
        it = pstore.iter_chunks(batch_rows)
        pending = [next(it, None)]
        for gstart in range(0, n, batch_rows):
            k_c = np.zeros(k)
            sums = np.zeros((k, d))
            for _, xs in _local_windows(gstart, it, pending):
                lab = _assign_chunk(xs, centers, c2)
                kc_w, s_w = _label_sums(lab, xs, k)
                k_c += kc_w
                sums += s_w
            red = comm.allreduce(np.concatenate([k_c[:, None], sums], axis=1))
            k_c, sums = red[:, 0], red[:, 1:]
            counts += k_c
            nz = k_c > 0
            centers[nz] += (sums[nz] - k_c[nz, None] * centers[nz]) / counts[nz, None]
            c2 = np.sum(centers * centers, axis=1)
        empty = counts == 0
        if empty.any():
            re_idx = rng.choice(n, size=int(empty.sum()), replace=False)
            centers[empty] = scale_inputs(pstore.read_rows(re_idx)[0], beta)

    # Final labeling pass: LOCAL labels; exact global centroids/extents
    # via one all-reduce of the per-host accumulators.
    n_local = pstore.n_local
    local_start = pstore.start
    labels_local = np.empty(n_local, dtype=np.int64)
    counts = np.zeros(k)
    sums = np.zeros((k, d))
    mins = np.full(d, np.inf)
    maxs = np.full(d, -np.inf)
    c2 = np.sum(centers * centers, axis=1)
    for a, xw, _ in pstore.iter_chunks(batch_rows):
        xs = scale_inputs(xw, beta)
        lab = _assign_chunk(xs, centers, c2)
        labels_local[a - local_start:a - local_start + xs.shape[0]] = lab
        k_c, s_c = _label_sums(lab, xs, k)
        counts += k_c
        sums += s_c
        np.minimum(mins, xs.min(axis=0), out=mins)
        np.maximum(maxs, xs.max(axis=0), out=maxs)
    counts = comm.allreduce(counts)
    sums = comm.allreduce(sums)
    mins = comm.allreduce(mins, op="min")
    maxs = comm.allreduce(maxs, op="max")

    occupied = np.nonzero(counts > 0)[0]
    centers = sums[occupied] / counts[occupied][:, None]
    sizes = counts[occupied]
    dprime = most_relevant_dim(beta)
    coord_order = np.argsort(centers[:, dprime], kind="stable")
    centers = centers[coord_order]
    sizes = np.rint(sizes[coord_order]).astype(np.int64)
    bc = occupied.size
    old_to_new = np.full(k, -1, dtype=np.int64)
    old_to_new[occupied[coord_order]] = np.arange(bc)
    labels_local = old_to_new[labels_local]

    # Radius pass against the final centers; max all-reduced per block.
    r2 = np.zeros(bc)
    for a, xw, _ in pstore.iter_chunks(batch_rows):
        xs = scale_inputs(xw, beta)
        lab = labels_local[a - local_start:a - local_start + xs.shape[0]]
        d2 = np.sum((xs - centers[lab]) ** 2, axis=1)
        np.maximum.at(r2, lab, d2)
    r2 = comm.allreduce(r2, op="max")
    radii = np.sqrt(r2)

    # Block -> owning HOST by quantile bucketing of the center coordinate
    # (the per-process analogue of the in-process worker owners).
    if comm.size > 1:
        qs = np.quantile(centers[:, dprime],
                         np.linspace(0.0, 1.0, comm.size + 1)[1:-1])
        host_of_block = np.searchsorted(qs, centers[:, dprime], side="right")
    else:
        host_of_block = np.zeros(bc, dtype=np.int64)
    host_of_block = host_of_block.astype(np.int64)

    if ordering == "random":
        order = rng.permutation(bc)
    elif ordering == "coord":
        order = np.arange(bc)
    elif ordering == "maxmin":
        from repro.core.blocks import _maxmin_order

        order = _maxmin_order(centers, rng)
    else:
        raise ValueError(f"unknown streaming ordering {ordering!r}")
    rank_of_block = np.empty(bc, dtype=np.int64)
    rank_of_block[order] = np.arange(bc)

    ext = maxs - mins
    med = np.median(ext[ext > 0]) if np.any(ext > 0) else 1.0
    ext = np.maximum(ext, 1e-6 * med)
    domain_volume = float(np.prod(ext))

    blocks = BlockStructure(
        labels=labels_local,
        order=np.asarray(order, dtype=np.int64),
        rank_of_block=rank_of_block,
        centers=centers,
        owners=host_of_block.astype(np.int32),
        members=[None] * bc,
    )
    return blocks, radii, domain_volume, host_of_block, sizes


def _membership_exchange(pstore, blocks: BlockStructure, host_of_block,
                         comm) -> HostRowTable:
    """Route every local row to the host owning its block (Alg. 2
    alltoall) and fill ``blocks.members`` for this host's owned blocks.

    Rows travel with their global ids and labels; the receiver sorts by
    global id, so member lists come out ascending — the single-process
    member order — and the returned ``HostRowTable`` holds exactly the
    rows of the owned blocks.
    """
    me = comm.rank
    labels = blocks.labels
    dest = host_of_block[labels] if labels.size else np.empty(0, np.int64)
    gids = pstore.start + np.arange(pstore.n_local, dtype=np.int64)
    payloads = {}
    # One bulk local read, then slice per destination (bounded by the
    # partition size, which is the point of the partitioned store).
    if labels.size:
        xw, yw = pstore.parent.read_slice(pstore.start, pstore.stop)
        for h in range(comm.size):
            sel = np.nonzero(dest == h)[0]
            if sel.size:
                payloads[h] = {"ids": gids[sel], "lab": labels[sel],
                               "x": xw[sel], "y": yw[sel]}
    got = comm.exchange(payloads)

    bc = blocks.n_blocks
    if got:
        gid = np.concatenate([p["ids"] for p in got.values()])
        lab = np.concatenate([p["lab"] for p in got.values()])
        xr = np.concatenate([p["x"] for p in got.values()])
        yr = np.concatenate([p["y"] for p in got.values()])
        order = np.argsort(gid, kind="stable")
        gid, lab, xr, yr = gid[order], lab[order], xr[order], yr[order]
    else:
        gid = np.empty(0, np.int64)
        lab = np.empty(0, np.int64)
        xr = np.empty((0, pstore.d))
        yr = np.empty(0)
    by_block = np.argsort(lab, kind="stable")
    counts = np.bincount(lab, minlength=bc)
    splits = np.split(gid[by_block], np.cumsum(counts)[:-1])
    for b in np.nonzero(host_of_block == me)[0]:
        blocks.members[int(b)] = splits[b].astype(np.int64)
    table = HostRowTable(pstore.d)
    table.add(gid, xr, yr)
    return table


class _HaloMiss(Exception):
    """A candidate block's members aren't resident yet (needs a fetch)."""

    def __init__(self, missing):
        super().__init__(f"missing blocks {sorted(missing)[:8]}")
        self.missing = list(missing)


class _IdFlatView:
    """Virtual ``flat_idx``: flat position -> global row id, served from
    per-block id arrays (no O(n) replicated index array per host)."""

    def __init__(self, starts: np.ndarray, ids: dict):
        self._starts = starts
        self._ids = ids

    def __getitem__(self, pos):
        pos = np.asarray(pos, np.int64)
        scalar = pos.ndim == 0
        p = np.atleast_1d(pos)
        out = np.empty(p.size, np.int64)
        blk = np.searchsorted(self._starts, p, side="right") - 1
        for b in np.unique(blk):
            ids = self._ids.get(int(b))
            if ids is None:
                raise _HaloMiss([int(b)])
            sel = blk == b
            out[sel] = ids[p[sel] - self._starts[b]]
        return out[0] if scalar else out


class HaloFlatBlocks:
    """`_FlatBlocks` interface over owned + halo-fetched blocks.

    Index bookkeeping (sizes/starts/radii) is GLOBAL — it derives from
    the replicated k-means summaries, O(bc) per host. Member ids and
    scaled coordinates exist only for owned blocks (lazily scaled from
    the row table) and for halo blocks ingested by `_fetch_halo`; asking
    for any other block raises `_HaloMiss`, which the NNS sweep turns
    into the next halo-exchange round. Because `_one_block` sees the
    exact same candidate admission, concat order, and coordinates as the
    single-process sweep, the neighbor lists match it exactly wherever
    the (eps-level) center differences don't flip a tie.
    """

    def __init__(self, sizes: np.ndarray, radii: np.ndarray, n_rows: int,
                 d: int, table: HostRowTable, members: list,
                 host_of_block: np.ndarray, rank: int):
        self.sizes = np.asarray(sizes, np.int64)
        self.starts = np.concatenate([[0], np.cumsum(self.sizes)])
        self.radii = np.asarray(radii)
        self.n_rows = int(n_rows)
        self.d = int(d)
        self._table = table
        self._ids: dict[int, np.ndarray] = {
            int(b): members[int(b)]
            for b in np.nonzero(host_of_block == rank)[0]
        }
        self._owned = set(self._ids)
        self._coords: dict[int, np.ndarray] = {}
        self._beta = None  # set by the sweep before any gather
        self.halo_rows = 0
        self.halo_blocks = 0
        self.flat_idx = _IdFlatView(self.starts, self._ids)

    def has_block(self, b: int) -> bool:
        return int(b) in self._ids

    def ingest(self, b: int, ids: np.ndarray, pts_scaled: np.ndarray) -> None:
        b = int(b)
        if b in self._ids:
            return
        self._ids[b] = np.asarray(ids, np.int64)
        self._coords[b] = pts_scaled
        self.halo_rows += int(ids.size)
        self.halo_blocks += 1

    def rows_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        if block_ids.size == 0:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(
            [np.arange(self.starts[b], self.starts[b + 1]) for b in block_ids]
        )

    def _coords_of(self, b: int) -> np.ndarray:
        b = int(b)
        pts = self._coords.get(b)
        if pts is None:
            ids = self._ids.get(b)
            if ids is None:
                raise _HaloMiss([b])
            pts = scale_inputs(self._table.read_rows(ids)[0], self._beta)
            self._coords[b] = pts
        return pts

    def points_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        if block_ids.size == 0:
            return np.empty((0, self.d))
        missing = [int(b) for b in block_ids if int(b) not in self._ids]
        if missing:
            raise _HaloMiss(missing)
        out = [self._coords_of(b) for b in block_ids]
        return out[0] if len(out) == 1 else np.concatenate(out)


def _fetch_halo(comm, needs, flat: HaloFlatBlocks, members: list,
                table: HostRowTable, host_of_block, beta) -> None:
    """One lockstep halo-exchange round (request + reply alltoalls).

    COLLECTIVE: all hosts must call together, `needs` may be empty.
    Requested blocks are served by their owners from the row table
    (member order = ascending global ids, same as local blocks); arrivals
    are ingested into the flat index AND the row table, so both the NNS
    retry and the later packing see them.
    """
    req: dict[int, list] = {}
    for b in needs:
        req.setdefault(int(host_of_block[b]), []).append(int(b))
    got = comm.exchange({
        h: {"blocks": np.asarray(sorted(bs), np.int64)}
        for h, bs in req.items() if h != comm.rank
    })
    replies = {}
    for src, p in got.items():
        bids = p["blocks"]
        mlists = [members[int(b)] for b in bids]
        sizes = np.asarray([mm.size for mm in mlists], np.int64)
        ids = (np.concatenate(mlists) if mlists else np.empty(0, np.int64))
        xg, yg = table.read_rows(ids)
        replies[src] = {"blocks": bids, "sizes": sizes,
                        "ids": ids, "x": xg, "y": yg}
    got2 = comm.exchange(replies)
    for p in got2.values():
        off = 0
        new_ids, new_x, new_y = [], [], []
        for b, sz in zip(p["blocks"], p["sizes"]):
            sz = int(sz)
            ids_b = p["ids"][off:off + sz]
            if not flat.has_block(int(b)):
                flat.ingest(int(b), ids_b,
                            scale_inputs(p["x"][off:off + sz], beta))
                members[int(b)] = ids_b.astype(np.int64)
                new_ids.append(ids_b)
                new_x.append(p["x"][off:off + sz])
                new_y.append(p["y"][off:off + sz])
            off += sz
        if new_ids:
            table.add(np.concatenate(new_ids), np.concatenate(new_x),
                      np.concatenate(new_y))


def multihost_filtered_nns(
    blocks: BlockStructure, sizes: np.ndarray, radii: np.ndarray,
    table: HostRowTable, host_of_block: np.ndarray, beta: np.ndarray,
    m: int, comm, alpha: float = 100.0, domain_volume: float = 1.0,
):
    """Per-host filtered NNS over owned query blocks with halo exchange.

    Round 0 proactively fetches every foreign preceding block the coarse
    filter admits at the base Eq. 7 radius (computable from replicated
    centers/radii alone — the Alg. 2 candidate exchange); the doubling
    fallback inside `_one_block` then drives additional lockstep rounds
    only for queries whose ball came up short. All hosts run the same
    number of exchange rounds (an all-reduce counts outstanding misses),
    so no host can deadlock waiting for a peer.
    """
    from repro.core.nns import _one_block, nns_radius

    me = comm.rank
    bc = blocks.n_blocks
    centers = blocks.centers
    ranks = blocks.rank_of_block
    n, d = int(np.sum(sizes)), centers.shape[1] if bc else table.d
    lam = nns_radius(n, m, d, domain_volume, alpha)
    flat = HaloFlatBlocks(sizes, radii, n, d, table, blocks.members,
                          host_of_block, me)
    flat._beta = np.broadcast_to(np.asarray(beta, np.float64), (d,))
    c2 = np.sum(centers * centers, axis=1)

    owned_q = [int(b) for b in np.nonzero(host_of_block == me)[0]
               if ranks[b] > 0]
    # Center distances with the EXACT chunked expression of the
    # single-process `filtered_nns` sweep (same center_chunk grid, same
    # GEMM shapes), so a LoopbackComm run reproduces its floats bitwise.
    center_chunk = max(16, min(2048, MAX_D2_ENTRIES // max(bc, 1)))
    dist_cache: dict[int, np.ndarray] = {}
    owned_set = set(owned_q)
    for s in range(0, bc, center_chunk):
        e = min(bc, s + center_chunk)
        if not owned_set.intersection(range(s, e)):
            continue
        q = centers[s:e]
        dc = np.sum(q * q, axis=1)[:, None] - 2.0 * q @ centers.T + c2[None, :]
        np.sqrt(np.maximum(dc, 0.0, out=dc), out=dc)
        for bi in range(s, e):
            if bi in owned_set:
                dist_cache[bi] = dc[bi - s]

    # Round 0: the admitted-at-lam candidate exchange.
    needs = set()
    for bi in owned_q:
        keep = (dist_cache[bi] <= lam + radii) & (ranks < ranks[bi])
        for j in np.nonzero(keep)[0]:
            j = int(j)
            if not flat.has_block(j):
                needs.add(j)
    _fetch_halo(comm, needs, flat, blocks.members, table, host_of_block, beta)

    neigh: list = [np.empty(0, np.int64)] * bc
    pending = owned_q
    rounds = 1
    while True:
        misses: set[int] = set()
        still = []
        for bi in pending:
            try:
                neigh[bi] = _one_block(bi, centers[bi], dist_cache[bi], lam,
                                       m, ranks, flat)
            except _HaloMiss as e:
                misses.update(int(b) for b in e.missing)
                still.append(bi)
        outstanding = comm.allreduce_scalar(float(len(misses)))
        if outstanding == 0:
            break
        _fetch_halo(comm, misses, flat, blocks.members, table,
                    host_of_block, beta)
        pending = still
        rounds += 1
        if rounds > 64:
            raise RuntimeError("halo-exchange NNS failed to converge")
    stats = {"halo_rounds": rounds, "halo_blocks": flat.halo_blocks,
             "halo_rows": flat.halo_rows}
    return neigh, flat, stats


def multihost_preprocess(
    pstore, beta: np.ndarray, cfg, stream_chunk: int, comm,
    struct_batch: int | None = None,
) -> MultihostStructure:
    """The multi-process `streaming_preprocess`: every stage holds only
    this host's share (partition windows, owned-block rows, admitted halo
    blocks) while the replicated summaries stay O(bc)."""
    bytes0 = getattr(comm, "bytes_sent", 0) + getattr(comm, "bytes_recv", 0)
    blocks, radii, vol, host_of_block, sizes = multihost_kmeans_blocks(
        pstore, beta, cfg.n_blocks, comm, seed=cfg.seed,
        batch_rows=struct_batch or DEFAULT_STRUCT_BATCH,
        ordering=cfg.ordering,
    )
    table = _membership_exchange(pstore, blocks, host_of_block, comm)
    owned_rows = table.n_rows
    neigh, _flat, halo_stats = multihost_filtered_nns(
        blocks, sizes, radii, table, host_of_block, beta, cfg.m, comm,
        alpha=cfg.alpha, domain_volume=vol,
    )
    owned_ranks = np.sort(blocks.rank_of_block[host_of_block == comm.rank])
    plan = plan_block_chunks(blocks, neigh, cfg.m, stream_chunk,
                             ranks=owned_ranks)
    bs_max = int(sizes.max()) if sizes.size else 0
    if cfg.bs_max is not None:
        bs_max = max(bs_max, cfg.bs_max)
    stats = {
        "n_hosts": comm.size, "rank": comm.rank,
        "rows_local": pstore.n_local, "owned_rows": owned_rows,
        "owned_blocks": int(np.sum(host_of_block == comm.rank)),
        "exchange_bytes": getattr(comm, "bytes_sent", 0)
        + getattr(comm, "bytes_recv", 0) - bytes0,
        **halo_stats,
    }
    return MultihostStructure(
        blocks=blocks, neigh=neigh, table=table,
        host_of_block=host_of_block, sizes=sizes, domain_volume=vol,
        plan=plan, bs_max=bs_max, stats=stats,
    )


# -- prediction-side gather ------------------------------------------------


def working_set_model(stream_stats: dict, n_rows: int, d: int, m: int,
                      stream_chunk: int, n_caches: int = 2) -> dict:
    """Bytes model of the streaming fit's resident working set.

    Shared by the RSS gates (tests/test_streaming.py and
    benchmarks/fig_streaming_scale.py) so they can assert
    ``peak_rss_delta <= 2 x total`` against one definition. Terms:

    * chunk windows — raw rows + scaled copy + one transient (3x);
    * packed chunk  — host .npz load + device transfer + arena slack (4x);
    * device grad   — the ``lax.map``-batched checkpointed backward keeps
      ~16 live buffer sets of ``_MAP_BATCH x (bs_max+m)^2`` (forward
      recompute + cotangents), independent of chunk size;
    * NNS scan      — worst-case candidate gather: with a near-isotropic
      beta in higher d the coarse filter can admit most blocks for one
      query, so the transient is O(n x d) (concat + squared distances);
    * index arrays  — labels/members/flat_idx/flat_rank + neighbor lists;
    * gather caches — the LRU block-point caches (fit and predict index);
    * device spool  — the device-resident spool tier (docs/streaming.md
      "inner-loop memory tiers"): on the CPU backend device arrays ARE
      host RSS, so cached pieces count double (buffer + transfer
      transient). Only present when the run actually cached pieces.

    MULTI-HOST runs (``stream_stats`` carrying ``n_hosts > 1`` from the
    multihost fit) get the PER-HOST version of the n-scaled terms: the
    NNS scan and index arrays cover only the rows this host can touch
    (owned-block rows + ingested halo rows), and two terms are added —
    the resident ``HostRowTable`` (+ exchange transients) and the
    partition-pass window spike of the membership exchange. Everything
    else (chunk windows, packed piece, device grad) is already per-host.

    The same constants applied to the WHOLE dataset give
    ``incore_total``: what the monolithic path would hold resident. The
    gates require ``2 x total < incore_total`` so the ceiling actually
    distinguishes streaming from slurping.
    """
    from repro.core.fit import _MAP_BATCH

    st = stream_stats
    joint2 = (st["bs_max"] + m) ** 2
    # The backward live set is sized by the run's ACCUMULATION dtype
    # (docs/precision.md): reduced ladder tiers (bf16/f32) accumulate in
    # f32, halving the device-grad term; the packed-chunk term needs no
    # adjustment because packed_chunk_bytes_max is measured on the
    # already-cast pieces.
    acc_bytes = 4 if st.get("precision", "f64") in ("bf16", "f32") else 8
    terms = {
        "chunk_windows": 3 * stream_chunk * (d + 1) * 8,
        "packed_chunk": 4 * st["packed_chunk_bytes_max"],
        "device_grad": 16 * _MAP_BATCH * joint2 * acc_bytes,
        "nns_scan": 3 * n_rows * d * 8,
        "index_arrays": 4 * n_rows * 8 + st["bc"] * m * 8,
        "gather_caches": n_caches * (32 << 20),
    }
    if st.get("n_hosts", 1) > 1:
        resident = int(st["owned_rows"]) + int(st.get("halo_rows", 0))
        terms["nns_scan"] = 3 * resident * d * 8
        terms["index_arrays"] = 4 * resident * 8 + st["bc"] * m * 8
        terms["row_table"] = 3 * resident * (d + 2) * 8
        terms["partition_pass"] = 3 * int(st["rows_local"]) * (d + 1) * 8
    if st.get("device_cached_bytes"):
        terms["device_spool"] = 2 * st["device_cached_bytes"]
    total = sum(terms.values())
    incore_total = (
        2 * n_rows * (d + 1) * 8      # raw + scaled arrays resident
        + 2 * st["spool_bytes"]        # packed dataset, host + device
        + 4 * st["bc"] * joint2 * 8    # vmapped grad live set over all blocks
    )
    return {"terms": terms, "total": total, "incore_total": incore_total}


def localize_neighbors(store, neighbors: list):
    """Gather the union of neighbor rows once and remap each list into the
    gathered subset — hands ``pack_prediction`` small in-core arrays in
    place of the full training set. Values and per-list order are
    preserved, so the packed arrays are bit-identical to the in-core
    path's."""
    if neighbors:
        rows_needed = np.unique(np.concatenate([np.asarray(nb) for nb in neighbors]))
    else:
        rows_needed = np.empty(0, np.int64)
    xg, yg = store.read_rows(rows_needed)
    remapped = [np.searchsorted(rows_needed, np.asarray(nb)) for nb in neighbors]
    return xg, yg, remapped
