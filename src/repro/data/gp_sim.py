"""Dataset generators for the paper's three experiment families.

* synthetic anisotropic GP draws (paper §6.1): exact Cholesky draw for
  small n; random-Fourier-feature (RFF) draws for millions of points
  (beyond-paper enabler — exact draws are O(n^3)). The Matern spectral
  density is a multivariate Student-t with 2*nu dof, so RFF frequencies
  are sampled as z / sqrt(g), z ~ N(0, I_d), g ~ Gamma(nu, 1/nu) — after
  dimension-wise scaling by 1/beta.
* satellite-drag-like benchmark (paper §6.2): an 8-d smooth surrogate with
  the paper's structure (3 strongly relevant dims).
* MetaRVM-like compartmental simulator (paper §6.3): a deterministic
  S/V/E/P/A/I/H/R daily-step model over the 10 Table-4 parameters whose
  output is accumulated hospitalizations over 100 days. By construction
  dh and dr barely influence the output — matching the paper's estimated
  relevances (Fig. 7).
"""
from __future__ import annotations

import numpy as np

from repro.core.kernels_math import KernelParams, cov_matrix


def sample_gp_exact(seed: int, x: np.ndarray, params: KernelParams, nu: float = 3.5) -> np.ndarray:
    """Exact zero-mean GP draw via dense Cholesky. O(n^3); n <= ~5000."""
    import jax.numpy as jnp

    n = x.shape[0]
    k = cov_matrix(jnp.asarray(x), jnp.asarray(x), params, nu=nu, add_nugget=True)
    chol = np.linalg.cholesky(np.asarray(k) + 1e-10 * np.eye(n))
    rng = np.random.default_rng(seed)
    return chol @ rng.standard_normal(n)


def sample_gp_rff(
    seed: int, x: np.ndarray, params: KernelParams, nu: float = 3.5, n_features: int = 4096
) -> np.ndarray:
    """Approximate GP draw via random Fourier features; O(n * n_features)."""
    rng = np.random.default_rng(seed)
    n, d = x.shape
    beta = np.asarray(params.beta)
    sigma2 = float(params.sigma2)
    nugget = float(params.nugget)
    # Matern(nu) spectral measure == multivariate t_{2nu}: z / sqrt(W), W~Gamma(nu, scale=1/nu)
    z = rng.standard_normal((n_features, d))
    g = rng.gamma(shape=nu, scale=1.0 / nu, size=(n_features, 1))
    omega = z / np.sqrt(g) / beta[None, :]
    phase = rng.uniform(0.0, 2.0 * np.pi, size=n_features)
    w = rng.standard_normal(n_features)
    proj = x @ omega.T + phase[None, :]
    y = np.sqrt(2.0 * sigma2 / n_features) * (np.cos(proj) @ w)
    if nugget > 0:
        y = y + np.sqrt(nugget) * rng.standard_normal(n)
    return y


def paper_synthetic(seed: int, n: int, d: int = 10, exact_threshold: int = 3000):
    """Paper §6.1 setup: x ~ U[0,1]^10, Matern nu=3.5, beta = (.05,.05,5...5)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, d))
    beta = np.full(d, 5.0)
    beta[:2] = 0.05
    params = KernelParams.create(sigma2=1.0, beta=beta, nugget=0.0 + 1e-8, d=d)
    sampler = sample_gp_exact if n <= exact_threshold else sample_gp_rff
    y = sampler(seed + 1, x, params)
    return x, y, params


def paper_synthetic_chunks(seed: int, n: int, d: int = 10, gen_rows: int = 65536,
                           n_features: int = 4096):
    """Chunked generator of ONE ``paper_synthetic``-family GP realization.

    The RFF weights (frequencies, phases, feature coefficients) are drawn
    once and shared across every yielded ``(x, y)`` chunk, so the
    concatenation is a single function draw — per-chunk calls to
    ``paper_synthetic`` with different seeds would concatenate
    INDEPENDENT realizations, which fits to a pure-nugget model. RAM
    stays at ``gen_rows x n_features`` no matter how large ``n`` is;
    used by the streaming CLI to write paper-scale stores."""
    rng = np.random.default_rng(seed)
    nu = 3.5
    beta = np.full(d, 5.0)
    beta[:2] = 0.05
    sigma2, nugget = 1.0, 1e-8
    z = rng.standard_normal((n_features, d))
    g = rng.gamma(shape=nu, scale=1.0 / nu, size=(n_features, 1))
    omega = z / np.sqrt(g) / beta[None, :]
    phase = rng.uniform(0.0, 2.0 * np.pi, size=n_features)
    w = rng.standard_normal(n_features)
    done = 0
    while done < n:
        k = min(n - done, gen_rows)
        x = rng.uniform(size=(k, d))
        y = np.sqrt(2.0 * sigma2 / n_features) * (
            np.cos(x @ omega.T + phase[None, :]) @ w
        )
        y = y + np.sqrt(nugget) * rng.standard_normal(k)
        yield x, y
        done += k


def satellite_drag_like(seed: int, n: int):
    """8-d drag-coefficient surrogate: smooth, anisotropic, 3 dominant dims
    (matching the paper's Fig. 6 finding that the last 3 dims dominate)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, 8))
    vel, t_srf, t_atm, yaw, pitch, acc1, acc2, extra = [x[:, i] for i in range(8)]
    # Panel-drag-flavored response: dominated by pitch, acc1, acc2.
    y = (
        2.2
        + 1.5 * np.cos(np.pi * pitch) ** 2
        + 1.2 * acc1 * (1.0 - 0.5 * acc2)
        + 0.8 * np.exp(-2.0 * (acc2 - 0.5) ** 2)
        + 0.08 * np.sin(2 * np.pi * yaw)
        + 0.05 * vel * t_atm
        + 0.02 * t_srf
        + 0.0 * extra
    )
    y = y + 0.01 * rng.standard_normal(n)
    return x, y


METARVM_BOUNDS = {
    "ts": (0.1, 0.9), "tv": (0.1, 0.9), "dv": (30.0, 90.0), "de": (1.0, 5.0),
    "dp": (1.0, 3.0), "da": (1.0, 9.0), "ds": (1.0, 9.0), "dh": (1.0, 5.0),
    "dr": (30.0, 90.0), "ve": (0.3, 0.8),
}


def metarvm_sample_inputs(seed: int, n: int) -> np.ndarray:
    """Uniform draws inside the Table-4 bounds, columns in Table-4 order."""
    rng = np.random.default_rng(seed)
    lo = np.array([b[0] for b in METARVM_BOUNDS.values()])
    hi = np.array([b[1] for b in METARVM_BOUNDS.values()])
    return lo + (hi - lo) * rng.uniform(size=(n, 10))


def metarvm_simulate(theta: np.ndarray, days: int = 100) -> np.ndarray:
    """Deterministic compartmental respiratory-virus model (vectorized).

    Compartments (fractions of one population): S susceptible, V vaccinated,
    E exposed, P infectious presymptomatic, A infectious asymptomatic,
    I infectious symptomatic, H hospitalized, R recovered.
    Output: accumulated hospital admissions over ``days``.
    """
    th = np.atleast_2d(np.asarray(theta, dtype=np.float64))
    ts, tv, dv, de, dp, da, ds, dh, dr, ve = [th[:, i] for i in range(10)]
    nb = th.shape[0]

    contact = 0.55      # fixed daily contact rate
    p_asym = 0.4        # P -> A split
    p_hosp = 0.12       # I -> H split
    vax_rate = 0.01     # S -> V per day

    s = np.full(nb, 0.989)
    v = np.zeros(nb)
    e = np.full(nb, 0.001)
    p = np.zeros(nb)
    a = np.zeros(nb)
    i_ = np.full(nb, 0.01)
    h = np.zeros(nb)
    r = np.zeros(nb)
    cum_h = np.zeros(nb)

    for _ in range(days):
        infectious = p + a + i_
        foi_s = 1.0 - np.exp(-contact * ts * infectious)
        foi_v = 1.0 - np.exp(-contact * tv * (1.0 - ve) * infectious)
        new_e = s * foi_s + v * foi_v
        e_out = e / de
        p_out = p / dp
        a_out = a / da
        i_out = i_ / ds
        h_out = h / dh
        r_out = r / dr
        v_wane = v / dv
        new_v = vax_rate * s
        new_h = p_hosp * i_out

        s = s - s * foi_s - new_v + r_out + v_wane
        v = v + new_v - v * foi_v - v_wane
        e = e + new_e - e_out
        p = p + e_out - p_out
        a = a + p_asym * p_out - a_out
        i_ = i_ + (1.0 - p_asym) * p_out - i_out
        h = h + new_h - h_out
        r = r + a_out + (1.0 - p_hosp) * i_out + h_out - r_out
        cum_h = cum_h + new_h

    return cum_h if theta.ndim > 1 else cum_h[0]


def metarvm_dataset(seed: int, n: int, normalize: bool = True):
    """(X in [0,1]^10, y) pairs per paper §6.3 (inputs scaled to unit cube,
    output normalized to mean 1)."""
    theta = metarvm_sample_inputs(seed, n)
    y = metarvm_simulate(theta)
    lo = np.array([b[0] for b in METARVM_BOUNDS.values()])
    hi = np.array([b[1] for b in METARVM_BOUNDS.values()])
    x01 = (theta - lo) / (hi - lo)
    if normalize:
        y = y / max(y.mean(), 1e-12)
    return x01, y


def metarvm_field_simulate(theta: np.ndarray, p: int,
                           days: int = 100) -> np.ndarray:
    """The epidemic TRAJECTORY instead of its endpoint: accumulated
    hospital admissions snapshotted at ``p`` evenly spaced days.

    Returns (n, p) with column j the cumulative admissions through day
    ``round((j+1) * days / p)`` — the last column is exactly
    ``metarvm_simulate(theta, days)``. One simulator sweep produces all
    p outputs, which is what makes this the natural multi-output
    emulation target (docs/multioutput.md): the outputs share one input
    space and one smoothness structure but differ in scale as the
    epidemic accumulates."""
    if p < 1:
        raise ValueError(f"need p >= 1 output snapshots, got {p}")
    th = np.atleast_2d(np.asarray(theta, dtype=np.float64))
    snap_days = np.rint(np.arange(1, p + 1) * days / p).astype(int)
    snap_days[-1] = days
    out = np.zeros((th.shape[0], p))
    for j, day in enumerate(snap_days):
        out[:, j] = metarvm_simulate(th, days=int(day))
    return out


def metarvm_field_dataset(seed: int, n: int, p: int, days: int = 100,
                          normalize: bool = True):
    """Multi-output MetaRVM: (X in [0,1]^10, Y (n, p)) with each column
    normalized to mean 1 (per-output scale is what the VPPE per-output
    sigma2 absorbs — see docs/multioutput.md)."""
    theta = metarvm_sample_inputs(seed, n)
    y = metarvm_field_simulate(theta, p, days=days)
    lo = np.array([b[0] for b in METARVM_BOUNDS.values()])
    hi = np.array([b[1] for b in METARVM_BOUNDS.values()])
    x01 = (theta - lo) / (hi - lo)
    if normalize:
        y = y / np.maximum(y.mean(axis=0), 1e-12)
    return x01, y
