"""Multi-process host communication for the distributed streaming build.

The paper's Alg. 2 runs construction per MPI rank with two communication
primitives: an all-reduce over small dense summaries (k-means centers and
counts, radii, loss/grad scalars) and a point-to-point candidate/member
exchange. This module provides both on top of ``jax.distributed``:

* **collectives** — a one-device-per-host mesh with the *gloo* CPU
  collective backend; ``allreduce`` runs a cached jitted
  ``shard_map``-``psum``/``pmax`` so every host gets the identical
  reduced bytes (which is what keeps optimizer states replicated without
  a broadcast);
* **point-to-point** — the ``jax.distributed`` coordination service's
  key-value store moves ``npz``-serialized array payloads between host
  pairs (``exchange``). The KV store is a rendezvous service, not an
  interconnect — fine for construction metadata and the bounded halo
  rows it carries here; the steady-state inner loop communicates ONLY
  through ``allreduce`` (O(1) scalars per chunk per step, the Alg. 1
  contract).

``LoopbackComm`` implements the same interface degenerately for one
process; every ``comm=``-aware code path can therefore be exercised (and
is pinned bitwise against the single-process path) without spawning
processes. See docs/streaming.md "multi-host construction".
"""
from __future__ import annotations

import base64
import functools
import io
import os

import numpy as np

# Environment contract for launched worker processes (repro.launch.fit_gp
# spawns local ranks with these; a real cluster can export them instead).
ENV_RANK = "REPRO_DIST_RANK"
ENV_NPROCS = "REPRO_DIST_NPROCS"
ENV_COORD = "REPRO_DIST_COORD"

_KV_PART_BYTES = 2 << 20  # KV values are chunked to stay rendezvous-friendly


def _flat(key: str) -> str:
    """Keep KV keys slash-free: the coordination service treats ``/`` as
    a directory separator (``key_value_dir_get``), so flat keys avoid any
    ambiguity with the namespace GC."""
    return key.replace("/", ".")


def partition_blocks(n_blocks: int, size: int) -> list:
    """Contiguous ``[lo, hi)`` block spans per rank (``np.array_split``
    semantics: the first ``n_blocks % size`` ranks carry one extra).
    Every rank computes the identical table from the identical packed
    chunk, so block ownership in the multi-host predict path
    (``predict_sbv(multihost=)``) needs zero coordination."""
    base, extra = divmod(int(n_blocks), int(size))
    spans, lo = [], 0
    for r in range(int(size)):
        hi = lo + base + (1 if r < extra else 0)
        spans.append((lo, hi))
        lo = hi
    return spans


class LoopbackComm:
    """Single-process implementation of the host-comm interface.

    ``allreduce`` is the identity (so it perturbs no floats — the
    ``multihost=`` fit path with a LoopbackComm is bitwise the plain
    streaming fit) and ``exchange`` hands each payload straight back.
    """

    rank = 0
    size = 1

    def allreduce(self, vec, op: str = "sum") -> np.ndarray:
        return np.asarray(vec, dtype=np.float64).copy()

    def allreduce_scalar(self, v: float, op: str = "sum") -> float:
        return float(v)

    def exchange(self, payloads: dict) -> dict:
        out = {}
        if 0 in payloads:
            out[0] = {k: np.asarray(v) for k, v in payloads[0].items()}
        return out

    def barrier(self, tag: str = "") -> None:
        pass

    def shutdown(self) -> None:
        pass


class MultihostContext:
    """Host comm over an initialized ``jax.distributed`` runtime."""

    def __init__(self, rank: int, size: int, client, mesh):
        self.rank = int(rank)
        self.size = int(size)
        self._client = client
        self._mesh = mesh
        self._seq = 0
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.timeout_ms = 600_000

    # -- construction --------------------------------------------------

    @classmethod
    def connect(cls, coordinator: str, num_processes: int,
                process_id: int) -> "MultihostContext":
        """Initialize ``jax.distributed`` (gloo CPU collectives) and build
        the one-device-per-host mesh. Must run before any other jax use
        in the process."""
        import jax

        # The CPU backend refuses multi-process computations unless the
        # gloo collective implementation is selected BEFORE initialize.
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=int(num_processes),
                                   process_id=int(process_id))
        from jax._src.distributed import global_state
        from jax.sharding import Mesh

        devices = np.asarray(jax.devices())
        if devices.size != int(num_processes):
            raise RuntimeError(
                f"expected one device per process, got {devices.size} devices "
                f"for {num_processes} processes")
        mesh = Mesh(devices, ("hosts",))
        return cls(process_id, num_processes, global_state.client, mesh)

    @classmethod
    def from_env(cls) -> "MultihostContext | None":
        """Connect from the ``REPRO_DIST_*`` environment, or None."""
        if ENV_RANK not in os.environ:
            return None
        return cls.connect(os.environ[ENV_COORD],
                           int(os.environ[ENV_NPROCS]),
                           int(os.environ[ENV_RANK]))

    # -- collectives ----------------------------------------------------

    @functools.lru_cache(maxsize=32)
    def _allreduce_fn(self, length: int, op: str):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def local(x):  # x: this host's (1, length) shard
            v = jnp.squeeze(x, axis=0)
            if op == "sum":
                return jax.lax.psum(v, "hosts")
            return jax.lax.pmax(v, "hosts")

        return jax.jit(shard_map(local, mesh=self._mesh,
                                 in_specs=(P("hosts"),), out_specs=P()))

    def allreduce(self, vec, op: str = "sum") -> np.ndarray:
        """Element-wise sum/max/min across hosts of a float64 vector.

        The reduced result is identical bytes on every host (a collective
        allreduce agrees on one result), which is what keeps replicated
        state — centers, optimizer moments, parameters — in lockstep
        without any broadcast step.
        """
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        arr = np.asarray(vec, dtype=np.float64)
        flat = arr.ravel()
        if flat.size == 0:
            return arr.copy()
        neg = op == "min"
        local = (-flat if neg else flat)[None, :]
        sharding = NamedSharding(self._mesh, P("hosts"))
        g = jax.make_array_from_process_local_data(sharding, local)
        out = np.asarray(self._allreduce_fn(flat.size, "max" if neg else op)(g))
        if neg:
            out = -out
        return out.reshape(arr.shape)

    def allreduce_scalar(self, v: float, op: str = "sum") -> float:
        return float(self.allreduce(np.asarray([v], dtype=np.float64), op)[0])

    # -- point-to-point -------------------------------------------------

    # Payloads go through the *string* KV API with base64 values: the
    # ``*_bytes`` getter binding in current jaxlib segfaults
    # intermittently (races in its future-to-bytes conversion), while the
    # string path is the one jax itself exercises for device coordination.
    # Raw bytes are chunked BEFORE encoding so each stored value stays
    # near _KV_PART_BYTES.

    def _kv_put(self, key: str, blob: bytes) -> None:
        n_parts = -(-len(blob) // _KV_PART_BYTES)
        for i in range(n_parts):
            part = blob[i * _KV_PART_BYTES:(i + 1) * _KV_PART_BYTES]
            self._client.key_value_set(
                _flat(f"{key}.p{i}"), base64.b64encode(part).decode("ascii"))
        self._client.key_value_set(_flat(f"{key}.meta"), str(n_parts))

    def _kv_get(self, key: str) -> bytes:
        n_parts = int(self._client.blocking_key_value_get(
            _flat(f"{key}.meta"), self.timeout_ms))
        parts = [base64.b64decode(self._client.blocking_key_value_get(
            _flat(f"{key}.p{i}"), self.timeout_ms)) for i in range(n_parts)]
        return b"".join(parts)

    def _kv_delete(self, key: str) -> None:
        try:
            self._client.key_value_delete(key)
        except Exception:
            pass  # best-effort GC; stale keys are seq-namespaced anyway

    @staticmethod
    def _pack(payload: dict) -> bytes:
        buf = io.BytesIO()
        np.savez(buf, **{k: np.ascontiguousarray(v)
                         for k, v in payload.items()})
        return buf.getvalue()

    @staticmethod
    def _unpack(blob: bytes) -> dict:
        with np.load(io.BytesIO(blob)) as z:
            return {k: z[k] for k in z.files}

    def exchange(self, payloads: dict) -> dict:
        """All-to-all of ``{dest_rank: {name: array}}`` payload dicts.

        COLLECTIVE: every host must call it the same number of times
        (missing destinations send implicit empty payloads). Returns
        ``{src_rank: {name: array}}`` with an entry for every peer that
        sent a non-empty payload (plus self, if addressed). Keys are
        sequence-numbered and garbage-collected after a barrier, so the
        coordination service holds at most one round in flight.
        """
        seq = self._seq
        self._seq += 1
        out = {}
        mine = payloads.get(self.rank)
        if mine is not None:
            out[self.rank] = {k: np.asarray(v) for k, v in mine.items()}
        sent_keys = []
        for dst in range(self.size):
            if dst == self.rank:
                continue
            payload = payloads.get(dst)
            blob = self._pack(payload) if payload else b""
            key = f"repro.x{seq}.{self.rank}to{dst}"
            self._kv_put(key, blob)
            sent_keys.append(key)
            self.bytes_sent += len(blob)
        for src in range(self.size):
            if src == self.rank:
                continue
            blob = self._kv_get(f"repro.x{seq}.{src}to{self.rank}")
            self.bytes_recv += len(blob)
            if blob:
                out[src] = self._unpack(blob)
        self.barrier(f"x{seq}")
        for key in sent_keys:
            self._kv_delete(key)
        return out

    def barrier(self, tag: str = "") -> None:
        self._client.wait_at_barrier(_flat(f"repro.bar.{tag}"), self.timeout_ms)

    def shutdown(self) -> None:
        import jax

        jax.distributed.shutdown()
