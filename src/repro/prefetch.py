"""Bounded producer-thread prefetch: the shared double-buffer primitive.

Two hot paths in this codebase overlap host-side staging with device
compute, and both reduce to the same shape: a producer thread walks a
source iterator, runs a staging function on each item (numpy packing,
disk reads, H2D transfer — all of which release the GIL in their hot
parts), and feeds a bounded queue; the consumer drains the queue and
dispatches device work. ``Prefetcher`` is that shape, extracted from the
serving pipeline (``serving/pipeline.py``) so the streaming fit's H2D
spool reader (``data/streaming.py``) runs the identical, identically
tested machinery instead of a second copy:

* item ORDER is preserved (single producer, FIFO queue) — consumers that
  accumulate floating-point sums see the same summation order as the
  synchronous loop, which is what makes "pipelined == sync bitwise"
  provable;
* ``depth`` bounds the number of staged items in flight (2 = classic
  double buffer), so prefetching never grows the resident working set
  beyond ``depth`` staged items;
* exceptions raised by the source or the stage function surface in the
  consumer at the point of the failed item;
* closing early (consumer error, ``break``) unblocks and joins the
  producer — no leaked threads, no deadlocked ``put``.
"""
from __future__ import annotations

import queue
import threading

_DONE = object()


class Prefetcher:
    """Iterate ``src`` through a bounded queue fed by a daemon thread.

    ``stage`` (optional) is applied to every item ON the producer thread;
    use it for the work that should hide behind the consumer's compute.
    Use as a context manager (or call ``close()``) so the thread is
    always joined::

        with Prefetcher(chunks, depth=2, stage=pack) as items:
            for item in items:
                ...
    """

    def __init__(self, src, depth: int = 2, stage=None, name: str = "prefetch"):
        self._src = src
        self._stage = stage
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(depth)))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, name=name, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that aborts when the consumer has gone away."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self):
        try:
            for item in self._src:
                out = self._stage(item) if self._stage is not None else item
                if not self._put(out):
                    return
            self._put(_DONE)
        except BaseException as exc:  # surface staging errors to the consumer
            self._put(exc)

    def __iter__(self):
        while True:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                # Nothing queued: only keep waiting while the producer can
                # still deliver. After ``close()`` (stop set) or after an
                # exception/sentinel already drained the queue (thread
                # dead), a bare ``get()`` would block forever. The final
                # non-blocking drain closes the race where the producer
                # enqueued its last item between our timeout and its exit.
                if self._stop.is_set() or not self._thread.is_alive():
                    try:
                        item = self._q.get_nowait()
                    except queue.Empty:
                        return
                else:
                    continue
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    def close(self):
        self._stop.set()
        self._thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
