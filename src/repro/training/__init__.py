from .train_step import TrainState, make_train_step, train_state_init
from .serve import make_decode_step, make_prefill_step
