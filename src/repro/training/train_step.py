"""LM train step: CE loss + Adam, grad-accum microbatching, mixed precision.

Large-scale recipe (DESIGN.md §5):
* params live in the model dtype (bf16 for the assigned archs) with fp32
  Adam moments — the fp32 "master" information is (mu, nu, step);
* the global batch is split into ``grad_accum`` microbatches scanned
  sequentially; XLA sees ONE jitted step, so the psum over the data axis
  happens once per step (communication ~ O(params), not O(params*accum));
* optional int8 gradient compression with error feedback (beyond-paper
  distributed-optimization trick; exact when disabled).

The returned step function is pure and jit/pjit friendly: callers supply
shardings at jit time (see repro/launch/train.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import lm_loss
from repro.optim import adam_init, adam_update, AdamState


class TrainState(NamedTuple):
    params: object
    opt: AdamState
    step: jax.Array


def train_state_init(params) -> TrainState:
    return TrainState(params=params, opt=adam_init(params), step=jnp.zeros((), jnp.int32))


def _compress_int8(g, err):
    """Stochastic-free deterministic int8 quantization with error feedback.

    g is replaced by Q(g + err); the residual (g + err) - Q(...) becomes the
    new error. Scales are per-tensor absmax/127.
    """
    def one(gl, el):
        t = gl.astype(jnp.float32) + el
        scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(t / scale), -127, 127)
        deq = q * scale
        return deq.astype(gl.dtype), (t - deq)

    flat_g, td = jax.tree.flatten(g)
    flat_e = td.flatten_up_to(err)
    out = [one(a, b) for a, b in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def make_train_step(
    cfg,
    tp: int = 1,
    lr: float = 3e-4,
    grad_accum: int = 1,
    weight_decay: float = 0.0,
    compress: bool = False,
):
    """Build ``step(state, tokens, labels) -> (state, metrics)``.

    tokens/labels: (global_batch, seq) int32. When ``grad_accum > 1`` the
    batch axis is reshaped to (accum, micro, seq) and scanned; gradients are
    averaged in fp32.
    """

    def loss_fn(params, tok, lab):
        return lm_loss(params, tok, lab, cfg, tp=tp)

    grad_one = jax.value_and_grad(loss_fn)

    def step(state: TrainState, tokens, labels, compress_err=None):
        b = tokens.shape[0]
        assert b % grad_accum == 0, (b, grad_accum)
        micro = b // grad_accum

        if grad_accum == 1:
            loss, grads = grad_one(state.params, tokens, labels)
        else:
            tok = tokens.reshape(grad_accum, micro, -1)
            lab = labels.reshape(grad_accum, micro, -1)

            def body(acc, tl):
                l, g = grad_one(state.params, tl[0], tl[1])
                loss_acc, g_acc = acc
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32) / grad_accum, g_acc, g
                )
                return (loss_acc + l / grad_accum, g_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_g), (tok, lab))

        if compress:
            if compress_err is None:
                compress_err = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params
                )
            grads, compress_err = _compress_int8(grads, compress_err)

        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        params, opt = adam_update(
            grads, state.opt, state.params, lr, weight_decay=weight_decay
        )
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm}
        if compress:
            return new_state, metrics, compress_err
        return new_state, metrics

    return step
