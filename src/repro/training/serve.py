"""Serving steps: prefill (prompt -> cache) and decode (one token).

These are the functions the decode_32k / long_500k dry-run cells lower:
``serve_step`` consumes one new token against a KV cache of length seq_len.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.model import prefill_step, serve_step


def make_prefill_step(cfg, cache_len: int, tp: int = 1):
    def step(params, tokens):
        return prefill_step(params, tokens, cfg, cache_len, tp=tp)

    return step


def make_decode_step(cfg, tp: int = 1):
    def step(params, tokens, cache):
        logits, cache = serve_step(params, tokens, cache, cfg, tp=tp)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return step


def greedy_generate(params, prompt, cfg, max_new: int, cache_len: int, tp: int = 1):
    """Reference autoregressive loop (smoke tests / examples, not perf path)."""
    logits, cache = prefill_step(params, prompt, cfg, cache_len, tp=tp)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    decode = make_decode_step(cfg, tp)
    for _ in range(max_new - 1):
        tok, _, cache = decode(params, tok, cache)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
