"""RWKV-6 "Finch" block: data-dependent per-channel decay linear recurrence.

Per head (key dim K, value dim V):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(wlog_t)) produced by a LoRA from the token-shifted
input (the RWKV6 novelty vs RWKV5's static decay).

TPU adaptation: exact CHUNKED evaluation. Inside a chunk all decay factors
appear only as exp(clog_t - clog_s) with t >= s, which is <= 1 — so the
(Q, Q, K) decay tensor is numerically safe without clamping (the factored
r~ = r exp(c), k~ = k exp(-c) trick used by GLA-style kernels overflows for
strong decay). Chunk of 16 keeps the tensor small while cutting sequential
steps 16x vs a token scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.constraints import FULL_BATCH, constrain

from .layers import dense_init, rms_norm

import os

_CHUNK = int(os.environ.get("REPRO_RWKV_CHUNK", "16"))
_LORA = 64


def rwkv6_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hk = cfg.head_dim
    ks = jax.random.split(key, 12)
    return {
        "mu": (0.5 * jnp.ones((5, d), jnp.float32)).astype(dtype),  # r,k,v,g,w shifts
        "wr": dense_init(ks[0], d, h * hk, dtype),
        "wk": dense_init(ks[1], d, h * hk, dtype),
        "wv": dense_init(ks[2], d, h * hk, dtype),
        "wg": dense_init(ks[3], d, h * hk, dtype),
        "w_base": jnp.full((h * hk,), -0.6, jnp.float32),
        "w_lora_a": dense_init(ks[4], d, _LORA, dtype),
        "w_lora_b": dense_init(ks[5], _LORA, h * hk, dtype, scale=0.01),
        "u_bonus": jnp.zeros((h, hk), jnp.float32),
        "ln_out": jnp.zeros((h * hk,), jnp.float32),
        "wo": dense_init(ks[6], h * hk, d, dtype, scale=(h * hk) ** -0.5),
        # channel-mix
        "mu_cm": (0.5 * jnp.ones((2, d), jnp.float32)).astype(dtype),
        "w_cm_r": dense_init(ks[7], d, d, dtype),
        "w_cm_1": dense_init(ks[8], d, cfg.d_ff, dtype),
        "w_cm_2": dense_init(ks[9], cfg.d_ff, d, dtype, scale=cfg.d_ff ** -0.5),
    }


def _token_shift(x, last=None):
    """Previous-token features; ``last`` (B,1,D) carries across calls."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _wkv_chunk(carry, inp):
    """carry S (B,H,K,V) fp32; inp r,k,v (B,Q,H,K|V), logw (B,Q,H,K), u (H,K)."""
    s_prev = carry
    r, k, v, logw, u = inp
    b, q, h, kd = r.shape
    clog = jnp.cumsum(logw, axis=1)                        # (B,Q,H,K)
    cshift = jnp.pad(clog, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :q]  # clog_{t-1}
    # intra: A[t,s] = sum_K r_t exp(c_{t-1} - c_s) k_s   (strictly s < t)
    dten = cshift[:, :, None] - clog[:, None, :, :]        # (B,Q,Q,H,K) t,s
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)
    dten = jnp.where(mask[None, :, :, None, None], jnp.exp(dten), 0.0)
    amat = jnp.einsum("bthk,bshk,btshk->bhts", r.astype(jnp.float32),
                      k.astype(jnp.float32), dten)
    y = jnp.einsum("bhts,bshv->bthv", amat, v.astype(jnp.float32))
    # diagonal u-bonus: y_t += (r_t . (u*k_t)) v_t
    diag = jnp.einsum("bthk,hk,bthk->bth", r.astype(jnp.float32), u,
                      k.astype(jnp.float32))
    y = y + diag[..., None] * v.astype(jnp.float32)
    # inter: y_t += (r_t * exp(c_{t-1})) S_prev
    y = y + jnp.einsum("bthk,bhkv->bthv",
                       r.astype(jnp.float32) * jnp.exp(cshift), s_prev)
    # carry: S = sum_s exp(c_last - c_s) k_s v_s + exp(c_last) S_prev
    wtail = jnp.exp(clog[:, -1:, :, :] - clog)             # (B,Q,H,K)
    s_new = jnp.einsum("bshk,bshv->bhkv", k.astype(jnp.float32) * wtail,
                       v.astype(jnp.float32))
    s_new = s_new + jnp.exp(clog[:, -1])[..., None] * s_prev
    return s_new, y


def _heads(x, h, hk):
    return x.reshape(x.shape[0], x.shape[1], h, hk)


def rwkv6_time_mix(params, x, cfg, state=None, last_tok=None):
    b, s, d = x.shape
    h, hk = cfg.n_heads, cfg.head_dim
    dtype = x.dtype
    xs = _token_shift(x, last_tok)
    mix = lambda i: x + params["mu"][i] * (xs - x)
    r = _heads(mix(0) @ params["wr"], h, hk)
    k = _heads(mix(1) @ params["wk"], h, hk)
    v = _heads(mix(2) @ params["wv"], h, hk)
    g = jax.nn.silu(mix(3) @ params["wg"])
    wx = mix(4)
    wlog = params["w_base"] + (jnp.tanh(wx @ params["w_lora_a"]) @ params["w_lora_b"]).astype(jnp.float32)
    logw = -jnp.exp(wlog)                                  # (B,S,H*K) < 0
    logw = _heads(logw, h, hk)

    if state is None:
        state = jnp.zeros((b, h, hk, hk), jnp.float32)
    if s > 1:
        # The recurrence has no TP dimension (40 heads don't divide a
        # 16-way axis; K/V are tiny). Without constraints XLA replicates
        # the whole wkv scan across 'model' — measured as THE dominant
        # memory term of the rwkv6 train cell. Batch over every mesh axis
        # instead (context-parallel for recurrent blocks); `constrain`
        # falls back to a prefix when the batch doesn't divide all axes.
        cst = lambda a: constrain(a, FULL_BATCH, *([None] * (a.ndim - 1)))
        r, k, v, logw = cst(r), cst(k), cst(v), cst(logw)
        g = cst(g)
        state = cst(state)
    q = min(_CHUNK, s)
    pad = (-s) % q
    if pad:
        # zero k (no state additions) + zero logw (no decay) => padded steps
        # are exact no-ops on the recurrence.
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
    nc = (s + pad) // q
    resh = lambda a: a.reshape((b, nc, q) + a.shape[2:]).transpose(1, 0, 2, 3, 4)
    u = params["u_bonus"]
    state, y = jax.lax.scan(
        lambda c, i: _wkv_chunk(c, (*i, u)), state,
        (resh(r), resh(k), resh(v), resh(logw)),
    )
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, s + pad, h * hk)[:, :s].astype(dtype)
    y = rms_norm(y, params["ln_out"], cfg.norm_eps) * g
    return y @ params["wo"], state, x[:, -1:]


def rwkv6_time_mix_decode(params, x, cfg, state, last_tok):
    """One-token step: x (B,1,D). Returns (y, new_state, new_last)."""
    b = x.shape[0]
    h, hk = cfg.n_heads, cfg.head_dim
    xs = last_tok
    mix = lambda i: x + params["mu"][i] * (xs - x)
    r = _heads(mix(0) @ params["wr"], h, hk)[:, 0]         # (B,H,K)
    k = _heads(mix(1) @ params["wk"], h, hk)[:, 0]
    v = _heads(mix(2) @ params["wv"], h, hk)[:, 0]
    g = jax.nn.silu(mix(3) @ params["wg"])
    wlog = params["w_base"] + (jnp.tanh(mix(4) @ params["w_lora_a"]) @ params["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, h, hk)

    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   state + params["u_bonus"][None, :, :, None] * kv)
    state = w[..., None] * state + kv
    y = y.reshape(b, 1, h * hk).astype(x.dtype)
    y = rms_norm(y, params["ln_out"], cfg.norm_eps) * g
    return y @ params["wo"], state, x


def rwkv6_channel_mix(params, x, cfg, last_tok=None):
    xs = _token_shift(x, last_tok)
    xk = x + params["mu_cm"][0] * (xs - x)
    xr = x + params["mu_cm"][1] * (xs - x)
    r = jax.nn.sigmoid(xr @ params["w_cm_r"])
    k = jnp.square(jax.nn.relu(xk @ params["w_cm_1"]))
    return r * (k @ params["w_cm_2"]), x[:, -1:]
