from .model import (
    embed_tokens, init_params, lm_loss, logits_fn, make_empty_cache,
    model_dtype, prefill_step, serve_step,
)

__all__ = [
    "embed_tokens", "init_params", "lm_loss", "logits_fn", "make_empty_cache",
    "model_dtype", "prefill_step", "serve_step",
]
