"""Shared neural-net layers: RMSNorm, RoPE, SwiGLU, embeddings, softcap.

Parameters are plain dict pytrees; per-layer parameters are stacked on a
leading L axis and consumed by lax.scan (compile time independent of depth
— essential for 42-88-layer dry-runs at 512 devices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def mlp_init(key, d_model: int, d_ff: int, dtype, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype, scale=d_ff ** -0.5),
    }
    if kind == "swiglu":
        p["w_gate"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def mlp_apply(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    if kind == "relu2":  # nemotron/minitron squared-ReLU
        return jnp.square(jax.nn.relu(x @ p["w_up"])) @ p["w_down"]
    raise ValueError(kind)


def cross_entropy(logits, labels, vocab: int):
    """Mean CE over tokens; logits (..., V) fp32 logsumexp for stability."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
