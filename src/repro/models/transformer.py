"""Decoder assembly: scan-over-layers blocks for all five family patterns.

* uniform attention stacks (musicgen / internlm2 / minitron / mistral /
  chameleon) — window 0 (global);
* gemma2 — per-layer window array scanned alongside params (local/global
  alternation lives INSIDE one scan), attn softcap, sandwich norms;
* MoE stacks (dbrx / qwen2-moe) — attention + grouped-dispatch MoE;
* rwkv6 — time-mix + channel-mix, attention-free;
* zamba2 hybrid — groups of ``attn_every`` mamba2 layers followed by ONE
  SHARED attention block (weights reused every group, its KV cache is
  per-application).

Per-layer parameters are stacked on a leading axis; compile time is
independent of depth. Caches are stacked the same way and travel through
scan as xs/ys.
"""
from __future__ import annotations

import functools

import os

import jax
import jax.numpy as jnp

from repro.sharding.constraints import BATCH, constrain

from .attention import (
    _expand_kv, attn_init, attention, attention_decode, cache_expand_factor,
)

# Megatron sequence-parallelism: residual-stream activations at block
# boundaries are sharded over ('model', seq). XLA then reduce-scatters the
# row-parallel matmul outputs and all-gathers before the next column-
# parallel input, and every norm/residual elementwise pass runs on 1/tp of
# the tokens. A/B switch for §Perf.
_SEQ_PARALLEL = os.environ.get("REPRO_NO_SEQPAR", "") != "1"


def _residual_sp(x, cfg):
    """(B, S, D) residual constraint at block boundaries (train/prefill).

    Skipped for MoE blocks: the expert dispatch needs a different layout
    and the seq-sharded residual just adds reshards around it (measured:
    dbrx train dominant term 26.1 -> 32.9 s with SP on — refuted there,
    confirmed for dense blocks)."""
    if not _SEQ_PARALLEL or x.shape[1] == 1 or cfg.n_experts:
        return x
    return constrain(x, BATCH, "model", None)
from .layers import mlp_apply, mlp_init, rms_norm
from .moe import moe_forward, moe_init
from .rwkv6 import (
    rwkv6_channel_mix, rwkv6_init, rwkv6_time_mix, rwkv6_time_mix_decode,
)
from .ssm import mamba2_decode, mamba2_forward, mamba2_init


def padded_experts(cfg, tp: int = 1) -> int:
    """Pad expert count up to a multiple of the model-axis size."""
    if not cfg.n_experts:
        return 0
    return ((cfg.n_experts + tp - 1) // tp) * tp


# ---------------------------------------------------------------- init ----

def _norm(d):
    return jnp.zeros((d,), jnp.float32)


def attn_block_init(key, cfg, dtype, tp: int = 1):
    k1, k2 = jax.random.split(key)
    p = {"ln1": _norm(cfg.d_model), "attn": attn_init(k1, cfg, dtype),
         "ln2": _norm(cfg.d_model)}
    if cfg.n_experts:
        p["moe"] = moe_init(k2, cfg, dtype, padded_experts(cfg, tp))
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_kind)
    if cfg.sandwich_norm:
        p["ln1_post"] = _norm(cfg.d_model)
        p["ln2_post"] = _norm(cfg.d_model)
    return p


def mamba_block_init(key, cfg, dtype):
    return {"ln1": _norm(cfg.d_model), "mamba": mamba2_init(key, cfg, dtype)}


def rwkv_block_init(key, cfg, dtype):
    return {"ln1": _norm(cfg.d_model), "ln2": _norm(cfg.d_model),
            "rwkv": rwkv6_init(key, cfg, dtype)}


def stack_init(key, cfg, dtype, tp: int = 1):
    """Stacked per-layer params (+ shared attention block for hybrids)."""
    if cfg.block_kind == "attn":
        init_one = functools.partial(attn_block_init, cfg=cfg, dtype=dtype, tp=tp)
        n = cfg.n_layers
    elif cfg.block_kind == "mamba2":
        init_one = functools.partial(mamba_block_init, cfg=cfg, dtype=dtype)
        n = cfg.n_layers
    elif cfg.block_kind == "rwkv6":
        init_one = functools.partial(rwkv_block_init, cfg=cfg, dtype=dtype)
        n = cfg.n_layers
    else:
        raise ValueError(cfg.block_kind)
    keys = jax.random.split(key, n + 1)
    stacked = jax.vmap(lambda k: init_one(k))(keys[:n])
    out = {"layers": stacked}
    if cfg.attn_every:
        out["shared_attn"] = attn_block_init(keys[n], cfg, dtype, tp)
    return out


def layer_windows(cfg):
    """Per-layer sliding-window scalars for the scan (0 = global attn)."""
    if cfg.local_global and cfg.sliding_window:
        pat = [cfg.sliding_window if i % 2 == 0 else 0 for i in range(cfg.n_layers)]
    elif cfg.sliding_window:
        pat = [cfg.sliding_window] * cfg.n_layers
    else:
        pat = [0] * cfg.n_layers
    return jnp.asarray(pat, jnp.int32)


# ------------------------------------------------------------- forward ----

def _attn_block_fwd(p, x, cfg, window, positions, tp):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h = attention(p["attn"], h, cfg, window=window, positions=positions)
    if cfg.sandwich_norm:
        h = rms_norm(h, p["ln1_post"], cfg.norm_eps)
    x = _residual_sp(x + h, cfg)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        h, aux = moe_forward(p["moe"], h, cfg, padded_experts(cfg, tp))
    else:
        h, aux = mlp_apply(p["mlp"], h, cfg.mlp_kind), 0.0
    if cfg.sandwich_norm:
        h = rms_norm(h, p["ln2_post"], cfg.norm_eps)
    return _residual_sp(x + h, cfg), aux


def forward_train(params, x, cfg, positions, tp: int = 1):
    """x (B,S,D) embeddings -> hidden (B,S,D); returns (hidden, aux_loss).

    With ``cfg.remat`` each scan-layer body is wrapped in jax.checkpoint:
    the backward pass recomputes per-layer activations instead of saving
    O(L) residuals — the standard activation-checkpoint policy that makes
    train_4k fit at production batch sizes.
    """
    wins = layer_windows(cfg)
    ckpt = jax.checkpoint if cfg.remat else (lambda f: f)

    if cfg.block_kind == "attn":
        @ckpt
        def body(carry, pw):
            x, aux = carry
            p, w = pw
            x, a = _attn_block_fwd(p, x, cfg, w, positions, tp)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), (params["layers"], wins))
        return x, aux

    if cfg.block_kind == "rwkv6":
        @ckpt
        def body(x, p):
            h, _, _ = rwkv6_time_mix(p["rwkv"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
            x = x + h
            h, _ = rwkv6_channel_mix(p["rwkv"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
            return x + h, None
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, 0.0

    if cfg.block_kind == "mamba2":
        if cfg.attn_every:
            return _hybrid_train(params, x, cfg, positions, wins, tp)
        @ckpt
        def body(x, p):
            h, _ = mamba2_forward(p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
            return x + h, None
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, 0.0

    raise ValueError(cfg.block_kind)


def _hybrid_train(params, x, cfg, positions, wins, tp):
    """zamba2: groups of attn_every mamba layers + shared attention."""
    g = cfg.n_layers // cfg.attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]), params["layers"]
    )
    shared = params["shared_attn"]
    ckpt = jax.checkpoint if cfg.remat else (lambda f: f)

    @ckpt
    def group_body(x, gp):
        def inner(x, p):
            h, _ = mamba2_forward(p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
            return x + h, None
        x, _ = jax.lax.scan(inner, x, gp)
        x, _ = _attn_block_fwd(shared, x, cfg, jnp.int32(0), positions, tp)
        return x, None

    x, _ = jax.lax.scan(group_body, x, grouped)
    return x, 0.0


# -------------------------------------------------------------- prefill ----

def prefill(params, x, cfg, positions, cache_len: int, tp: int = 1):
    """Forward over the prompt, building the decode cache.

    Returns (hidden (B,S,D), cache pytree). Attention K/V are written into
    length-``cache_len`` buffers.
    """
    b, s, _ = x.shape
    wins = layer_windows(cfg)
    dtype = x.dtype

    def pad_kv(kv):
        return jnp.zeros((b, cache_len) + kv.shape[2:], dtype).at[:, :s].set(kv)

    if cfg.block_kind == "attn":
        r_exp = cache_expand_factor(cfg, tp)

        def body(x, pw):
            p, w = pw
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            hd = cfg.head_dim
            from .attention import _split_heads
            from .layers import apply_rope
            k = apply_rope(_split_heads(h @ p["attn"]["wk"], cfg.n_kv_heads, hd),
                           positions, cfg.rope_theta)
            v = _split_heads(h @ p["attn"]["wv"], cfg.n_kv_heads, hd)
            if r_exp > 1:  # head-shardable decode cache (see cache_expand_factor)
                k, v = _expand_kv(k, r_exp), _expand_kv(v, r_exp)
            x, _ = _attn_block_fwd(p, x, cfg, w, positions, tp)
            return x, (pad_kv(k), pad_kv(v))
        x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], wins))
        return x, {"k": ck, "v": cv, "pos": jnp.int32(s)}

    if cfg.block_kind == "rwkv6":
        def body(x, p):
            h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, wkv_state, last1 = rwkv6_time_mix(p["rwkv"], h1, cfg)
            x = x + h
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            h, last2 = rwkv6_channel_mix(p["rwkv"], h2, cfg)
            return x + h, (wkv_state, last1, last2)
        x, (wkv, l1, l2) = jax.lax.scan(body, x, params["layers"])
        return x, {"wkv": wkv, "last1": l1, "last2": l2, "pos": jnp.int32(s)}

    if cfg.block_kind == "mamba2":
        kconv = cfg.ssm_conv - 1
        if cfg.attn_every:
            g = cfg.n_layers // cfg.attn_every
            grouped = jax.tree.map(
                lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]), params["layers"])
            shared = params["shared_attn"]

            def group_body(x, gp):
                def inner(x, p):
                    h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
                    h, st = mamba2_forward(p["mamba"], h1, cfg)
                    conv_tail = jnp.pad(h1 @ p["mamba"]["wx"], ((0, 0), (kconv, 0), (0, 0)))[:, s : s + kconv]
                    return x + h, (st, conv_tail)
                x, (ssd, conv) = jax.lax.scan(inner, x, gp)
                h = rms_norm(x, shared["ln1"], cfg.norm_eps)
                from .attention import _split_heads
                from .layers import apply_rope
                hd = cfg.head_dim
                k = apply_rope(_split_heads(h @ shared["attn"]["wk"], cfg.n_kv_heads, hd),
                               positions, cfg.rope_theta)
                v = _split_heads(h @ shared["attn"]["wv"], cfg.n_kv_heads, hd)
                x, _ = _attn_block_fwd(shared, x, cfg, jnp.int32(0), positions, tp)
                return x, (ssd, conv, pad_kv(k), pad_kv(v))

            x, (ssd, conv, ck, cv) = jax.lax.scan(group_body, x, grouped)
            return x, {"ssd": ssd, "conv": conv, "k": ck, "v": cv, "pos": jnp.int32(s)}

        def body(x, p):
            h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, st = mamba2_forward(p["mamba"], h1, cfg)
            conv_tail = jnp.pad(h1 @ p["mamba"]["wx"], ((0, 0), (kconv, 0), (0, 0)))[:, s : s + kconv]
            return x + h, (st, conv_tail)
        x, (ssd, conv) = jax.lax.scan(body, x, params["layers"])
        return x, {"ssd": ssd, "conv": conv, "pos": jnp.int32(s)}

    raise ValueError(cfg.block_kind)


# --------------------------------------------------------------- decode ----

def decode_step(params, x, cfg, cache, tp: int = 1):
    """One-token decode. x (B,1,D). Returns (hidden (B,1,D), new cache)."""
    pos = cache["pos"]
    wins = layer_windows(cfg)

    if cfg.block_kind == "attn":
        def body(x, pwc):
            p, w, ck, cv = pwc
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, ck, cv = attention_decode(p["attn"], h, ck, cv, pos, cfg, window=w)
            if cfg.sandwich_norm:
                h = rms_norm(h, p["ln1_post"], cfg.norm_eps)
            x = x + h
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                h, _ = moe_forward(p["moe"], h, cfg, padded_experts(cfg, tp))
            else:
                h = mlp_apply(p["mlp"], h, cfg.mlp_kind)
            if cfg.sandwich_norm:
                h = rms_norm(h, p["ln2_post"], cfg.norm_eps)
            return x + h, (ck, cv)
        x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], wins, cache["k"], cache["v"]))
        return x, {"k": ck, "v": cv, "pos": pos + 1}

    if cfg.block_kind == "rwkv6":
        def body(x, pc):
            p, wkv, l1, l2 = pc
            h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, wkv, l1 = rwkv6_time_mix_decode(p["rwkv"], h1, cfg, wkv, l1)
            x = x + h
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            hcm, l2n = rwkv6_channel_mix(p["rwkv"], h2, cfg, last_tok=l2)
            return x + hcm, (wkv, l1, l2n)
        x, (wkv, l1, l2) = jax.lax.scan(
            body, x, (params["layers"], cache["wkv"], cache["last1"], cache["last2"]))
        return x, {"wkv": wkv, "last1": l1, "last2": l2, "pos": pos + 1}

    if cfg.block_kind == "mamba2":
        if cfg.attn_every:
            g = cfg.n_layers // cfg.attn_every
            grouped = jax.tree.map(
                lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]), params["layers"])
            shared = params["shared_attn"]

            def group_body(x, gc):
                gp, ssd, conv, ck, cv = gc
                def inner(x, pc):
                    p, st, cs = pc
                    h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
                    h, newc = mamba2_decode(p["mamba"], h1, {"ssd": st, "conv": cs}, cfg)
                    return x + h, (newc["ssd"], newc["conv"])
                x, (ssd, conv) = jax.lax.scan(inner, x, (gp, ssd, conv))
                h = rms_norm(x, shared["ln1"], cfg.norm_eps)
                h, ck, cv = attention_decode(shared["attn"], h, ck, cv, pos, cfg, window=jnp.int32(0))
                x = x + h
                h = rms_norm(x, shared["ln2"], cfg.norm_eps)
                h = mlp_apply(shared["mlp"], h, cfg.mlp_kind)
                return x + h, (ssd, conv, ck, cv)

            x, (ssd, conv, ck, cv) = jax.lax.scan(
                group_body, x, (grouped, cache["ssd"], cache["conv"], cache["k"], cache["v"]))
            return x, {"ssd": ssd, "conv": conv, "k": ck, "v": cv, "pos": pos + 1}

        def body(x, pc):
            p, st, cs = pc
            h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, newc = mamba2_decode(p["mamba"], h1, {"ssd": st, "conv": cs}, cfg)
            return x + h, (newc["ssd"], newc["conv"])
        x, (ssd, conv) = jax.lax.scan(body, x, (params["layers"], cache["ssd"], cache["conv"]))
        return x, {"ssd": ssd, "conv": conv, "pos": pos + 1}

    raise ValueError(cfg.block_kind)


def init_cache(params, cfg, batch, cache_len, dtype, tp: int = 1):
    """Empty decode cache (for decode-shape dry-runs without a prefill)."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    hkv *= cache_expand_factor(cfg, tp)
    if cfg.block_kind == "attn":
        kv = jnp.zeros((cfg.n_layers, batch, cache_len, hkv, hd), dtype)
        return {"k": kv, "v": kv, "pos": jnp.int32(cache_len - 1)}
    if cfg.block_kind == "rwkv6":
        return {
            "wkv": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, hd, hd), jnp.float32),
            "last1": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
            "last2": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
            "pos": jnp.int32(cache_len - 1),
        }
    if cfg.block_kind == "mamba2":
        n_m = cfg.n_layers
        base = {
            "ssd": jnp.zeros((n_m, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((n_m, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "pos": jnp.int32(cache_len - 1),
        }
        if cfg.attn_every:
            g = cfg.n_layers // cfg.attn_every
            base["ssd"] = base["ssd"].reshape((g, cfg.attn_every) + base["ssd"].shape[1:])
            base["conv"] = base["conv"].reshape((g, cfg.attn_every) + base["conv"].shape[1:])
            kv = jnp.zeros((g, batch, cache_len, hkv, hd), dtype)
            base["k"] = kv
            base["v"] = kv
        return base
    raise ValueError(cfg.block_kind)
