"""LM wrapper: embeddings, chunked vocab-sharded loss, prefill/decode heads.

``lm_loss`` streams the output projection + cross-entropy over sequence
chunks under jax.checkpoint, so the (B, S, V) logits tensor never
materializes (a 256k-vocab 4k-seq logits tensor would be tens of GB).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import cross_entropy, rms_norm, softcap
from .transformer import decode_step, forward_train, init_cache, prefill, stack_init

_LOSS_CHUNK = 512


def model_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_params(key, cfg, tp: int = 1):
    dtype = model_dtype(cfg)
    k_emb, k_stack, k_head = jax.random.split(key, 3)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "stack": stack_init(k_stack, cfg, dtype, tp),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not getattr(cfg, "tie_embeddings", False):
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab), jnp.float32) * 0.02
        ).astype(dtype)
    return params


def embed_tokens(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head_matrix(params):
    if "lm_head" in params:
        return params["lm_head"]
    return params["embed"].T


def logits_fn(params, hidden, cfg):
    logits = hidden @ _head_matrix(params)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def lm_loss(params, tokens, labels, cfg, tp: int = 1, aux_weight: float = 0.01):
    """Mean next-token CE + MoE aux loss; loss head chunked over sequence."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(params, tokens, cfg)
    hidden, aux = forward_train(params["stack"], x, cfg, positions, tp)
    hidden = rms_norm(hidden, params["ln_f"], cfg.norm_eps)

    head = _head_matrix(params)
    chunk = min(_LOSS_CHUNK, s)
    assert s % chunk == 0
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, l):
        logits = softcap((h @ head).astype(jnp.float32), cfg.logit_softcap)
        return cross_entropy(logits, l, cfg.vocab)

    def body(acc, hl):
        h, l = hl
        return acc + chunk_loss(h, l), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc))
    loss = total / nc
    if cfg.n_experts:
        loss = loss + aux_weight * aux / cfg.n_layers
    return loss


def prefill_step(params, tokens, cfg, cache_len: int, tp: int = 1):
    """Prompt forward; returns (last-token logits (B,V), cache)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(params, tokens, cfg)
    hidden, cache = prefill(params["stack"], x, cfg, positions, cache_len, tp)
    hidden = rms_norm(hidden[:, -1:], params["ln_f"], cfg.norm_eps)
    return logits_fn(params, hidden, cfg)[:, 0], cache


def serve_step(params, tokens, cache, cfg, tp: int = 1):
    """One decode step: tokens (B,1) int32 -> (logits (B,V), new cache)."""
    x = embed_tokens(params, tokens, cfg)
    hidden, cache = decode_step(params["stack"], x, cfg, cache, tp)
    hidden = rms_norm(hidden, params["ln_f"], cfg.norm_eps)
    return logits_fn(params, hidden, cfg)[:, 0], cache


def make_empty_cache(params, cfg, batch, cache_len, tp: int = 1):
    return init_cache(params.get("stack"), cfg, batch, cache_len,
                      model_dtype(cfg), tp=tp)
