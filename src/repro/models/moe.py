"""Top-k MoE with GShard-style grouped dense dispatch (+ shared experts).

Tokens are split into groups of ``_GROUP`` tokens; capacity and the
one-hot dispatch/combine tensors are per-group, so dispatch memory is
O(T * E * capacity_per_group) = O(T * k * GROUP * cf) instead of O(T^2).
Dense einsum dispatch partitions cleanly under SPMD: groups shard over the
batch ('data') axes, experts over 'model' (EP) — the g->e einsum is the
all-to-all. Expert counts that do not divide the mesh axis are PADDED with
unroutable dummies (router logits -inf), e.g. qwen2-moe 60 -> 64.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_init, swiglu

_GROUP = 1024  # tokens per dispatch group


def moe_init(key, cfg, dtype, n_experts_padded: int | None = None):
    e = n_experts_padded or cfg.n_experts
    d, f = cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    def expert_w(k, din, dout, scale):
        return (jax.random.normal(k, (e, din, dout), jnp.float32) * scale).astype(dtype)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "w_gate": expert_w(ks[1], d, f, d ** -0.5),
        "w_up": expert_w(ks[2], d, f, d ** -0.5),
        "w_down": expert_w(ks[3], f, d, f ** -0.5),
    }
    if cfg.shared_d_ff:
        p["shared"] = mlp_init(ks[4], d, cfg.shared_d_ff, dtype)
    return p


def moe_forward(params, x, cfg, n_experts_padded: int | None = None):
    """x (B,S,D) -> (out (B,S,D), load-balance aux loss)."""
    b, s, d = x.shape
    e_real = cfg.n_experts
    e = n_experts_padded or e_real
    k = cfg.n_experts_active
    t = b * s
    gs = min(_GROUP, t)
    assert t % gs == 0, (t, gs)
    g = t // gs
    xt = x.reshape(g, gs, d)

    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (G,gs,E)
    if e > e_real:
        logits = jnp.where(jnp.arange(e) >= e_real, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k routing: iterative argmax (k is small), renormalized weights
    gates = jnp.zeros_like(probs)
    remaining = probs
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        onehot = jax.nn.one_hot(idx, e, dtype=probs.dtype)
        gates = gates + onehot * probs
        remaining = remaining * (1.0 - onehot)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # per-group capacity
    capacity = max(int(cfg.capacity_factor * gs * k / e_real), 1)
    selected = gates > 0.0
    pos_in_e = jnp.cumsum(selected.astype(jnp.int32), axis=1) - 1      # (G,gs,E)
    keep = selected & (pos_in_e < capacity)
    gates = jnp.where(keep, gates, 0.0)
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos_in_e, -1), capacity, dtype=x.dtype)        # (G,gs,E,C)

    dispatch = pos_oh
    combine = pos_oh * gates[..., None].astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xt)                    # (E,G,C,D)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, params["w_gate"]))
    h = h * jnp.einsum("egcd,edf->egcf", xe, params["w_up"])
    ye = jnp.einsum("egcf,efd->egcd", h, params["w_down"])             # (E,G,C,D)
    out = jnp.einsum("gtec,egcd->gtd", combine, ye).reshape(b, s, d)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(selected.astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e_real * jnp.sum(frac_tokens * frac_probs) / k

    if cfg.shared_d_ff:
        sh = params["shared"]
        out = out + swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])
    return out, aux
