"""Mamba-2 (SSD) block — chunked state-space dual form, TPU-friendly.

Recurrence (per head h, headdim P, state N):
    h_t = a_t * h_{t-1} + (dt_t x_t) B_t^T        a_t = exp(-exp(A_log) dt_t)
    y_t = C_t h_t + D x_t
The chunked form turns the scan into (Q x Q) matmuls per chunk — decay is
a SCALAR per (step, head), so the intra-chunk decay matrix is cheap (this
is exactly why Mamba-2 maps better to matrix units than RWKV's per-channel
decay; see rwkv6.py).

Simplifications vs the reference CUDA impl (recorded in DESIGN.md):
single B/C group (G=1), short conv applied to x only, gated RMSNorm as
norm(y) * silu(z).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.constraints import FULL_BATCH, constrain

from .layers import dense_init, rms_norm

_CHUNK = 128


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], d, di, dtype),
        "wx": dense_init(ks[1], d, di, dtype),
        "wB": dense_init(ks[2], d, n, dtype),
        "wC": dense_init(ks[3], d, n, dtype),
        "wdt": dense_init(ks[4], d, h, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D_skip": jnp.ones((h,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (cfg.ssm_conv, di), jnp.float32) * 0.2).astype(dtype),
        "norm": jnp.zeros((di,), jnp.float32),
        "wo": dense_init(ks[6], di, d, dtype, scale=di ** -0.5),
    }


def _causal_conv(x, w):
    """Depthwise causal conv via K shifted adds. x (B,S,C), w (K,C)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[k - 1 - i]
    return out


def _ssd_chunk(carry, inp, heads, p_dim):
    """One SSD chunk. carry: h (B,H,P,N). inp: xbar (B,Q,H,P), Bc/Cc (B,Q,N),
    loga (B,Q,H)."""
    h_prev = carry
    xbar, bc, cc, loga = inp
    clog = jnp.cumsum(loga, axis=1)                     # (B,Q,H) inclusive
    # intra-chunk: y[t] = sum_{s<=t} (C_t . B_s) exp(clog_t - clog_s) xbar_s
    gt = jnp.einsum("btn,bsn->bts", cc, bc)             # (B,Q,Q)
    dmat = clog[:, :, None, :] - clog[:, None, :, :]    # (B,Q,Q,H) t,s
    q = loga.shape[1]
    causal = jnp.tril(jnp.ones((q, q), bool))
    dmat = jnp.where(causal[None, :, :, None], jnp.exp(dmat), 0.0)
    y_intra = jnp.einsum("bts,btsh,bshp->bthp", gt.astype(jnp.float32),
                         dmat, xbar.astype(jnp.float32))
    # inter-chunk: y[t] += exp(clog_t) * C_t h_prev
    y_inter = jnp.einsum("btn,bhpn->bthp", cc.astype(jnp.float32), h_prev)
    y_inter = y_inter * jnp.exp(clog)[..., None]
    # carry: h_end = sum_s exp(clog_last - clog_s) xbar_s B_s + exp(clog_last) h_prev
    wdecay = jnp.exp(clog[:, -1:, :] - clog)            # (B,Q,H)
    h_new = jnp.einsum("bqh,bqhp,bqn->bhpn", wdecay, xbar.astype(jnp.float32),
                       bc.astype(jnp.float32))
    h_new = h_new + jnp.exp(clog[:, -1])[:, :, None, None] * h_prev
    return h_new, (y_intra + y_inter)


def mamba2_forward(params, x, cfg, state=None):
    """x (B,S,D) -> (y (B,S,D), final ssd state (B,H,P,N)).

    ``state`` is the initial SSD state (decode-prefill continuity); conv
    state handling for step-decode lives in mamba2_decode.
    """
    b, s, _ = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dtype = x.dtype
    z = x @ params["wz"]
    xr = jax.nn.silu(_causal_conv(x @ params["wx"], params["conv_w"]))
    bproj = x @ params["wB"]
    cproj = x @ params["wC"]
    dt = jax.nn.softplus((x @ params["wdt"]).astype(jnp.float32)
                         + params["dt_bias"])           # (B,S,H)
    loga = -jnp.exp(params["A_log"]) * dt               # (B,S,H) in (-inf,0)

    xh = xr.reshape(b, s, h, p)
    xbar = xh * dt[..., None].astype(dtype)
    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)
    if s > 1:
        # Same rationale as rwkv6: the SSD scan has no TP dimension (the
        # state is per-head and tiny) — batch over every mesh axis, or
        # XLA replicates the whole chunk scan across 'model'.
        cst = lambda a: constrain(a, FULL_BATCH, *([None] * (a.ndim - 1)))
        xbar, bproj, cproj, loga = cst(xbar), cst(bproj), cst(cproj), cst(loga)
        state = cst(state)

    q = min(_CHUNK, s)
    pad = (-s) % q
    if pad:
        # zero xbar/B (no state additions) + zero loga (no decay) => no-ops.
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        xbar, bproj, cproj, loga = zpad(xbar), zpad(bproj), zpad(cproj), zpad(loga)
    nc = (s + pad) // q
    resh = lambda a: a.reshape((b, nc, q) + a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
    xs = (resh(xbar), resh(bproj), resh(cproj), resh(loga))
    state, y = jax.lax.scan(lambda c, i: _ssd_chunk(c, i, h, p), state, xs)
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, s + pad, h, p)[:, :s]
    y = y + params["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, -1).astype(dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["wo"], state


def mamba2_init_cache(cfg, batch, dtype):
    return {
        "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


def mamba2_decode(params, x, cache, cfg):
    """Single-token step. x (B,1,D) -> (y (B,1,D), new cache)."""
    b = x.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dtype = x.dtype
    z = x @ params["wz"]
    xp = x @ params["wx"]                               # (B,1,di)
    window = jnp.concatenate([cache["conv"], xp], axis=1)   # (B,K,di)
    xr = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, params["conv_w"]))[:, None, :]
    new_conv = window[:, 1:, :]

    bproj = x @ params["wB"]                            # (B,1,N)
    cproj = x @ params["wC"]
    dt = jax.nn.softplus((x @ params["wdt"]).astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt)         # (B,1,H)

    xh = xr.reshape(b, h, p)
    xbar = (xh * dt[:, 0, :, None].astype(dtype)).astype(jnp.float32)
    ssd = cache["ssd"] * a[:, 0, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xbar, bproj[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", cproj[:, 0].astype(jnp.float32), ssd)
    y = y + params["D_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, -1).astype(dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["wo"], {"ssd": ssd, "conv": new_conv}
