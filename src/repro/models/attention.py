"""GQA attention with sliding-window / softcap variants + KV-cache decode.

One implementation serves all seven attention archs: full causal, local
(sliding window), gemma2 local/global alternation (the window arrives as a
traced per-layer scalar so the layer pattern can live inside lax.scan), and
attention-logit softcaps.

Long sequences stream over QUERY chunks (lax.scan) so the fp32 score tile
is (B, Hq, Qc, T) instead of (B, Hq, S, T) — the pure-JAX analogue of a
flash kernel's outer loop; 32k prefill stays within HBM. Decode reads and
writes a (B, S_max, Hkv, hd) cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.constraints import BATCH, constrain, model_divides

from .layers import apply_rope, dense_init, softcap

_Q_CHUNK = 1024


def attn_init(key, cfg, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": dense_init(kq, d, cfg.n_heads * cfg.head_dim, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ko, cfg.n_heads * cfg.head_dim, d, dtype,
                         scale=(cfg.n_heads * cfg.head_dim) ** -0.5),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def _expand_kv(k, n_rep):
    """(B,T,Hkv,hd) -> (B,T,Hq,hd); query head h uses kv group h // n_rep.

    Megatron-GQA TP: the explicit repeat keeps the einsums on FULL query
    heads, so the 'model' axis shards attention activations by head. (The
    earlier (G, rep)-factored einsum broke XLA sharding propagation — a
    16-head tensor reshaped to (8, 2) cannot carry a 16-way sharding — and
    silently replicated all attention compute across the model axis.)
    """
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _gqa_scores(q, k, n_rep):
    """q (B,S,Hq,hd), k (B,T,Hkv,hd) -> scores (B,Hq,S,T)."""
    return jnp.einsum("bsqh,btqh->bqst", q, _expand_kv(k, n_rep))


def _gqa_out(probs, v, n_rep):
    """probs (B,Hq,S,T), v (B,T,Hkv,hd) -> (B,S,Hq,hd)."""
    return jnp.einsum("bqst,btqh->bsqh", probs, _expand_kv(v, n_rep))


def _gqa_scores_grouped(q, k):
    """Grouped (no-repeat) score einsum for DECODE reads.

    q (B,S,Hq,hd), k (B,T,G,hd), G | Hq. Splitting Hq -> (G, rep) keeps
    the cache-head axis intact, so a head-sharded cache propagates —
    and the rep-expanded cache is never materialized (the repeat form
    would write an n_rep x copy of the whole cache every layer).
    """
    b, s, hq, hd = q.shape
    g = k.shape[2]
    qg = q.reshape(b, s, g, hq // g, hd)
    sc = jnp.einsum("bsgrh,btgh->bgrst", qg, k)
    return sc.reshape(b, hq, s, k.shape[1])


def _gqa_out_grouped(probs, v, hq):
    """probs (B,Hq,S,T), v (B,T,G,hd) -> (B,S,Hq,hd); G | Hq."""
    b, _, s, t = probs.shape
    g = v.shape[2]
    pg = probs.reshape(b, g, hq // g, s, t)
    out = jnp.einsum("bgrst,btgh->bsgrh", pg, v)
    return out.reshape(b, s, hq, v.shape[-1])


def _attend_block(q, k, v, qpos, kpos, window, attn_softcap, n_rep, dtype):
    """One (Q-chunk x full-KV) attention tile with causal+window mask.

    Activation sharding: heads over 'model' when the head count divides
    it (Megatron TP); otherwise the QUERY-sequence dim (Megatron
    sequence-parallel attention — e.g. minitron's 24 heads on a 16-way
    axis). Without the fallback XLA re-gathers score-sized tensors every
    chunk x layer (measured 6.8 TB wire/device on minitron prefill_32k).
    """
    hd = q.shape[-1]
    by_head = model_divides(q.shape[2])
    scores = _gqa_scores(q, k, n_rep).astype(jnp.float32) * (hd ** -0.5)
    scores = (constrain(scores, BATCH, "model", None, None) if by_head
              else constrain(scores, BATCH, None, "model", None))
    scores = softcap(scores, attn_softcap)
    dist = qpos[:, :, None] - kpos[:, None, :]
    allow = (dist >= 0) & ((window <= 0) | (dist < window))
    scores = jnp.where(allow[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = _gqa_out(probs, v, n_rep)
    return (constrain(out, BATCH, None, "model", None) if by_head
            else constrain(out, BATCH, "model", None, None))


def _flash_enabled(cfg) -> bool:
    if cfg.use_flash == "always":
        return True
    if cfg.use_flash == "never":
        return False
    return jax.default_backend() == "tpu"


def attention(params, x, cfg, *, window, positions):
    """Full-sequence (training / prefill) attention.

    window: traced scalar; <=0 means global, >0 limits lookback distance.
    positions: (B, S) int32 token positions.

    Global-attention archs route through the fused flash kernel
    (kernels/flash_attention.py) on TPU: the (B,H,S,T) score tensor stays
    in VMEM instead of dominating the HBM roofline term. The local/global
    (gemma2) pattern carries a TRACED window through lax.scan, which the
    static-shape kernel cannot consume — it keeps the XLA streaming path.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    by_head = model_divides(cfg.n_heads)
    if by_head:
        q = constrain(q, BATCH, None, "model", None)
    k = apply_rope(k, positions, cfg.rope_theta)
    # Pin K/V to kv-head sharding (or replication when Hkv doesn't divide
    # the model axis). Without this XLA shards the small head_dim instead
    # and every score einsum contracts over a sharded dim -> partial-sum
    # all-reduces of score-sized tensors (measured: the 2nd/3rd largest
    # collectives in minitron train_4k).
    k = constrain(k, BATCH, None, "model", None)
    v = constrain(v, BATCH, None, "model", None)

    if (_flash_enabled(cfg) and cfg.sliding_window == 0
            and not cfg.local_global):
        from repro.kernels.flash_attention import flash_attention

        kf = _expand_kv(k, n_rep).swapaxes(1, 2)     # (B,H,T,hd)
        vf = _expand_kv(v, n_rep).swapaxes(1, 2)
        qt = max(min(512, s), 1)
        while s % qt:
            qt //= 2
        o = flash_attention(q.swapaxes(1, 2), kf, vf, causal=True,
                            softcap=cfg.attn_softcap,
                            q_tile=qt, k_tile=qt)
        out = constrain(o.swapaxes(1, 2), BATCH, None, "model", None)
        return out.reshape(b, s, -1) @ params["wo"]

    if s <= _Q_CHUNK:
        if not by_head:
            q = constrain(q, BATCH, "model", None, None)
        out = _attend_block(q, k, v, positions, positions, window,
                            cfg.attn_softcap, n_rep, x.dtype)
    else:
        assert s % _Q_CHUNK == 0, f"seq {s} not divisible by q-chunk {_Q_CHUNK}"
        nc = s // _Q_CHUNK
        qc = q.reshape(b, nc, _Q_CHUNK, cfg.n_heads, hd).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(b, nc, _Q_CHUNK).transpose(1, 0, 2)
        if not by_head:
            # sequence-parallel fallback: shard WITHIN each query chunk
            # (the scan dim itself must stay unsharded)
            qc = constrain(qc, None, BATCH, "model", None, None)

        def body(_, qp):
            qi, pi = qp
            o = _attend_block(qi, k, v, pi, positions, window,
                              cfg.attn_softcap, n_rep, x.dtype)
            return (), o

        _, out = jax.lax.scan(body, (), (qc, pc))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, cfg.n_heads, hd)
    return out.reshape(b, s, -1) @ params["wo"]


def cache_expand_factor(cfg, tp: int) -> int:
    """Duplication factor r for the decode KV cache (1 = no expansion).

    When Hkv doesn't divide the model axis, a (B,S,Hkv,hd) cache can only
    seq-shard — and the per-token dynamic-update-slice then forces an
    involuntary full rematerialization (measured: ~1-3 s/token of
    collectives on every kv=8 arch). Duplicating each kv head r times —
    the SMALLEST r dividing n_rep with (Hkv*r) % tp == 0 — makes the
    cache head-shardable, so decode reads become fully local, at r x
    cache memory (r=2 for every kv=8 arch on the 16-way axis). The
    grouped einsums infer the repetition from the cache shape, so partial
    expansion needs no further changes.
    """
    if tp <= 1 or cfg.n_kv_heads % tp == 0:
        return 1
    n_rep = cfg.n_heads // cfg.n_kv_heads
    for r in range(2, n_rep + 1):
        if n_rep % r == 0 and (cfg.n_kv_heads * r) % tp == 0:
            return r
    return 1


def cache_expand_kv(cfg, tp: int) -> bool:
    return cache_expand_factor(cfg, tp) > 1


def attention_decode(params, x, cache_k, cache_v, pos, cfg, *, window):
    """Single-token decode. x (B,1,D); cache (B,Smax,Hc,hd); pos scalar.

    Hc is either Hkv (grouped cache) or Hq (expanded cache — see
    ``cache_expand_kv``); the repetition factor is inferred from the
    cache shape. Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    hd = cfg.head_dim
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)      # (B,1,Hq,hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)   # (B,1,Hkv,hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    posb = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    if cache_k.shape[2] != cfg.n_kv_heads:  # (partially) expanded cache
        r = cache_k.shape[2] // cfg.n_kv_heads
        k, v = _expand_kv(k, r), _expand_kv(v, r)

    zero = jnp.zeros((), jnp.int32)
    idx = (zero, jnp.asarray(pos, jnp.int32), zero, zero)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), idx)
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), idx)

    scores = _gqa_scores_grouped(q, cache_k).astype(jnp.float32) * (hd ** -0.5)
    scores = softcap(scores, cfg.attn_softcap)               # (B,Hq,1,Smax)
    kpos = jnp.arange(cache_k.shape[1], dtype=jnp.int32)
    allow = (kpos <= pos) & ((window <= 0) | (kpos > pos - window))
    scores = jnp.where(allow[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out_grouped(probs, cache_v, cfg.n_heads)
    return out.reshape(b, 1, -1) @ params["wo"], cache_k, cache_v
