"""musicgen-large [audio]: decoder-only over EnCodec tokens (arXiv:2306.05284).

Backbone only — the EnCodec frontend is a stub: input_specs() feeds
precomputed codebook token ids (vocab 2048)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, frontend="encodec-stub",
)
