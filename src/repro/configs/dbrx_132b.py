"""dbrx-132b [moe]: 16 experts top-4 fine-grained MoE
(hf:databricks/dbrx-base; unverified)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100_352,
    n_experts=16, n_experts_active=4, moe_d_ff=10752,
)
