"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent per-channel decay
(arXiv:2404.05892)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab=65_536, block_kind="rwkv6",
)
