"""Config registry: ``get_config(arch_id)`` and the assigned-architecture list."""
from .base import ModelConfig
from .shapes import SHAPES, ShapeSpec, applicable

from .musicgen_large import CONFIG as _musicgen_large
from .gemma2_9b import CONFIG as _gemma2_9b
from .internlm2_1_8b import CONFIG as _internlm2_1_8b
from .minitron_4b import CONFIG as _minitron_4b
from .mistral_large_123b import CONFIG as _mistral_large_123b
from .zamba2_2_7b import CONFIG as _zamba2_2_7b
from .dbrx_132b import CONFIG as _dbrx_132b
from .qwen2_moe_a2_7b import CONFIG as _qwen2_moe_a2_7b
from .rwkv6_3b import CONFIG as _rwkv6_3b
from .chameleon_34b import CONFIG as _chameleon_34b

ARCHS: dict[str, ModelConfig] = {
    "musicgen-large": _musicgen_large,
    "gemma2-9b": _gemma2_9b,
    "internlm2-1.8b": _internlm2_1_8b,
    "minitron-4b": _minitron_4b,
    "mistral-large-123b": _mistral_large_123b,
    "zamba2-2.7b": _zamba2_2_7b,
    "dbrx-132b": _dbrx_132b,
    "qwen2-moe-a2.7b": _qwen2_moe_a2_7b,
    "rwkv6-3b": _rwkv6_3b,
    "chameleon-34b": _chameleon_34b,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


__all__ = ["ARCHS", "ModelConfig", "SHAPES", "ShapeSpec", "applicable", "get_config"]
