"""qwen2-moe-a2.7b [moe]: 60 routed top-4 + shared expert
(hf:Qwen/Qwen1.5-MoE-A2.7B). 60 experts pad to 64 on a 16-way model axis."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151_936,
    n_experts=60, n_experts_active=4, moe_d_ff=1408, shared_d_ff=5632,
)
