"""Assigned input-shape set (same four shapes for every LM arch) and the
(arch x shape) applicability rule."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only the attention-free /
# hybrid archs run it (DESIGN.md §4). All assigned archs are decoder-only,
# so no decode-shape skips beyond this one.
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and cfg.family not in _LONG_OK_FAMILIES:
        return False, (
            f"{cfg.name} is pure full-attention ({cfg.family}); 500k-context "
            "decode has no sub-quadratic mechanism in the published arch — skipped"
        )
    return True, ""
