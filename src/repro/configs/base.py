"""Model configuration dataclass shared by all 10 assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention variants
    sliding_window: int = 0          # >0: local attention window
    local_global: bool = False       # gemma2: alternate local/global layers
    attn_softcap: float = 0.0        # gemma2 attention-logit softcap
    logit_softcap: float = 0.0       # gemma2 final-logit softcap
    sandwich_norm: bool = False      # gemma2 pre+post block norms
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0        # top-k
    moe_d_ff: int = 0                # per-expert hidden dim
    shared_d_ff: int = 0             # qwen2-moe shared-expert hidden dim
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64

    # hybrid (zamba2): one SHARED attention block applied every k core layers
    attn_every: int = 0

    # block kind of the core stack: attn | mamba2 | rwkv6
    block_kind: str = "attn"

    norm_eps: float = 1e-5
    remat: bool = True               # rematerialize each layer's activations
    use_flash: str = "auto"          # flash-attn kernel: auto|always|never
    emb_scale: bool = False          # gemma-style sqrt(d_model) embed multiplier
    mlp_kind: str = "swiglu"         # swiglu | relu2 (nemotron/minitron)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # frontend stub for [audio]/[vlm]: backbone consumes precomputed tokens
    frontend: str = "none"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **over) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else 2 * max(self.attn_every, 1)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=256,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.n_experts:
            # capacity_factor >= E/k guarantees no capacity drops, making
            # prefill-vs-decode smoke checks exact (drops are a large-scale
            # load-balancing artifact, not a correctness property).
            kw.update(n_experts=4, n_experts_active=min(self.n_experts_active, 2),
                      moe_d_ff=64, shared_d_ff=64 if self.shared_d_ff else 0,
                      capacity_factor=4.0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16)
        if self.attn_every:
            kw.update(attn_every=2, n_layers=4)
        kw.update(over)
        return replace(self, **kw)
