"""chameleon-34b [vlm]: early-fusion over VQ image tokens (arXiv:2405.09818;
unverified). VQ tokenizer frontend is a stub: input_specs() feeds token ids."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65_536, frontend="vq-stub",
)
