"""gemma2-9b [dense]: local+global alternating attention, softcaps, sandwich
norms, tied 256k embeddings (arXiv:2408.00118)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256_000,
    sliding_window=4096, local_global=True,
    attn_softcap=50.0, logit_softcap=30.0, sandwich_norm=True,
    emb_scale=True, tie_embeddings=True,
)
