"""Double-buffered chunk pipeline: host packing overlaps device compute.

The one-shot serving loop is strictly serial per chunk:

    pack k -> dispatch k -> block on k -> scatter k -> pack k+1 -> ...

but packing is host-side numpy (block assembly + filtered kNN + the
``PackedPrediction`` gather) and compute is a jitted device program that
JAX dispatches ASYNCHRONOUSLY — the call returns before the result is
ready. The pipeline exploits that:

* a producer thread runs ``iter_query_chunks`` and keeps up to
  ``prefetch`` packed chunks in a bounded queue (double buffer);
* the consumer dispatches chunk k's device program, then — while the
  device crunches — scatters chunk k-1's now-ready results and the
  producer packs chunk k+1.

Steady state: packing cost and scatter cost disappear behind device
compute; per-chunk wall time approaches max(pack, compute) instead of
pack + compute. Results are BITWISE identical to the synchronous loop
(same ``iter_query_chunks`` protocol, same jitted program, same scatter).

The producer-thread machinery itself lives in ``repro.prefetch``
(``Prefetcher``) — it is shared with the streaming fit's H2D spool
reader, so both overlap paths run one tested implementation.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.kernels_math import KernelParams
from repro.core.predict import (
    TrainIndex, iter_query_chunks, pack_queries, packed_predict, scatter_packed,
)
from repro.prefetch import Prefetcher

from .telemetry import ServerStats


@dataclass
class PipelineConfig:
    """Knobs of the chunked prediction read path (shared by the sync and
    double-buffered drivers so the two cannot drift)."""

    bs_pred: int = 25
    m_pred: int = 120
    nu: float = 3.5
    alpha: float = 100.0
    backend: str = "ref"      # 'ref' | 'pallas' | 'pallas_tiled' | 'auto'
    dtype: type = np.float64  # float32 for the compiled TPU kernel
    chunk_size: int | None = 4096
    n_workers: int = 1
    prefetch: int = 2         # packed chunks in flight (2 = double buffer)
    n_buckets: int | None = None  # size-bucketed micro-batches (docs/packing.md)
    stream_chunk: int | None = None  # out-of-core train index (docs/streaming.md)
    precision: str | None = None  # ladder tier (docs/precision.md); None = f64

    def __post_init__(self):
        # Normalize the precision knob once: a PrecisionPolicy or tier
        # string collapses to the tier name, f64 collapses to None, and
        # a narrow tier pins dtype to its accumulation width (queries
        # pack at acc; coordinates drop to storage in the chunk split).
        if self.precision is not None:
            from repro.core.buckets import acc_dtype, as_policy

            tier = as_policy(self.precision).tier
            if tier == "f64":
                self.precision = None
            else:
                self.precision = tier
                self.dtype = acc_dtype(tier)


def tuned_config(tuning, **overrides) -> PipelineConfig:
    """Build a ``PipelineConfig`` from a persisted autotuner record
    (TuningRecord / dict / checkpoint path — see ``repro.tuning``),
    with explicit ``overrides`` winning over the record. This is how
    ``serve gp --tuning-record`` starts pre-tuned."""
    from repro.tuning import as_record

    rec = as_record(tuning)
    kw = {}
    if rec.n_buckets:
        kw["n_buckets"] = rec.n_buckets
    if rec.stream_chunk:
        kw["stream_chunk"] = rec.stream_chunk
    if rec.precision:
        kw["precision"] = rec.precision
    if rec.backend:
        kw["backend"] = rec.backend
    kw.update(overrides)
    return PipelineConfig(**kw)


def _n_rows(x_test) -> int:
    """Row count of an in-core array OR a row store."""
    from repro.data.store import is_store

    if is_store(x_test):
        return x_test.n_rows
    return int(np.asarray(x_test).shape[0])


def n_outputs_of(params) -> int:
    """Output count of a parameter set: 1 for ``KernelParams``, ``p`` for
    ``MultiOutputParams`` (core/multioutput.py). The serving layer sizes
    its result buffers off this so multi-output models flow through the
    same chunk engine with ``(n, p)`` mean/var."""
    from repro.core.multioutput import MultiOutputParams

    if isinstance(params, MultiOutputParams):
        return params.n_outputs
    return 1


def _result_zeros(n: int, n_outputs: int) -> tuple[np.ndarray, np.ndarray]:
    shape = (n,) if n_outputs == 1 else (n, n_outputs)
    return np.zeros(shape), np.zeros(shape)


def make_chunk_split(cfg: PipelineConfig):
    """Return ``split(packed) -> [packed_piece, ...]`` — the host-side
    bucketing step of one chunk (the uniform layout is the one-piece
    special case). Pure numpy: the pipelined driver runs it on the
    PRODUCER thread so the slice copies overlap device compute like the
    rest of packing. The precision tier's storage cast also lands here
    (host numpy, overlapped) — queries pack at the accumulation dtype
    and coordinates drop to the storage dtype per piece."""
    tier = cfg.precision
    if not cfg.n_buckets:
        if tier is None:
            return lambda packed: [packed]
        from repro.core.buckets import cast_prediction

        return lambda packed: [cast_prediction(packed, tier)]

    from repro.core.buckets import bucket_mults, bucket_prediction, cast_prediction
    from repro.core.packing import round_up

    # Serving quantizes bucket shapes harder than the one-shot path:
    # ceilings to multiples of 8 and block counts padded to multiples
    # of 8 (masked dummies, inert), so steady-state traffic converges
    # to a bounded set of compile-cache keys just like the uniform
    # `pad_shapes` protocol.
    bs_mult, m_mult = (max(v, 8)
                       for v in bucket_mults(cfg.backend, precision=tier))

    def split(packed):
        pieces = bucket_prediction(packed, n_buckets=cfg.n_buckets,
                                   bs_mult=bs_mult, m_mult=m_mult).buckets
        pieces = [p.pad_to_blocks(round_up(p.n_blocks, 8)) for p in pieces]
        if tier is not None:
            pieces = [cast_prediction(p, tier) for p in pieces]
        return pieces

    return split


def make_chunk_compute(params: KernelParams, cfg: PipelineConfig, mesh=None,
                       axis: str = "workers"):
    """Return ``compute(pieces) -> [(packed_piece, mu, var), ...]`` over
    the (already split) pieces of one chunk; every piece is dispatched
    asynchronously through the jitted predict program. With a mesh, each
    piece's blocks are sharded by owner first (which reorders them —
    hence every piece is returned alongside its outputs so the scatter
    uses matching indices)."""
    if mesh is None:
        def compute(pieces):
            out = []
            for piece in pieces:
                mu, var = packed_predict(params, piece, nu=cfg.nu,
                                         backend=cfg.backend)
                out.append((piece, mu, var))
            return out
        return compute

    from repro.core.distributed import sharded_packed_predict

    def compute(pieces):
        return [
            sharded_packed_predict(params, piece, mesh, axis=axis,
                                   nu=cfg.nu, backend=cfg.backend)
            for piece in pieces
        ]

    return compute


def _record_pieces(stats: ServerStats | None, pieces) -> None:
    """Per-piece shape + padding-occupancy telemetry for ONE chunk (the
    chunk counter advances once however many bucket pieces it split into).
    One key is recorded PER PIECE, tagged with the piece's precision tier
    — each bucket shape at each dtype is its own compiled program, and
    the affinity router reads this set as the warm-cache signal."""
    if stats is None:
        return
    from repro.core.buckets import dtype_tier, prediction_work

    for i, (piece, _, _) in enumerate(pieces):
        stats.record_chunk_shape(piece.n_blocks, piece.bs_pred, piece.m_pred,
                                 count_chunk=i == 0,
                                 tier=dtype_tier(piece.q_x.dtype))
    stats.record_occupancy(*prediction_work([p for p, _, _ in pieces]))


def _chunks(index: TrainIndex, x_test: np.ndarray, cfg: PipelineConfig,
            seed: int):
    return iter_query_chunks(
        index, x_test, cfg.bs_pred, cfg.m_pred, alpha=cfg.alpha, seed=seed,
        n_workers=cfg.n_workers, chunk_size=cfg.chunk_size, dtype=cfg.dtype,
    )


def request_chunk_bounds(n: int, chunk_size: int | None,
                         bs_pred: int) -> list[tuple[int, int]]:
    """Per-request chunk bounds — the EXACT stepping of
    ``iter_query_chunks`` (``core/predict.py``), extracted so the
    continuous scheduler can enumerate a request's chunks up front.
    Chunk ``ci`` covering rows ``[start, stop)`` must be packed with
    ``pack_scheduled`` below; together they guarantee the scheduler's
    per-request results are those of a per-request ``predict_sbv`` call,
    no matter how admission interleaves requests."""
    step = n if chunk_size is None else max(int(chunk_size), bs_pred)
    return [(start, min(n, start + step)) for start in range(0, n, step)]


def pack_scheduled(index: TrainIndex, cfg: PipelineConfig, item,
                   seed: int = 0):
    """Pack one scheduled (request, chunk) unit with the per-request
    ``iter_query_chunks`` protocol: the request's own array is the test
    set, ``offset``/``seed`` advance within the request. The scheduler
    only ever reorders WHICH of these units runs when — what each unit
    computes is pinned here, which is the whole 1e-12 parity contract."""
    return pack_queries(
        index, item.entry.req.x[item.start:item.stop], cfg.bs_pred,
        cfg.m_pred, alpha=cfg.alpha, seed=seed + item.ci,
        n_workers=cfg.n_workers, offset=item.start,
        pad_shapes=cfg.chunk_size is not None, dtype=cfg.dtype,
    )


def run_chunk_stream(
    params: KernelParams,
    cfg: PipelineConfig,
    jobs,
    emit,
    mesh=None,
    stats: ServerStats | None = None,
) -> None:
    """The double-buffered chunk engine, decoupled from any one request.

    ``jobs`` yields ``(tag, pack_fn)`` pairs; ``pack_fn()`` runs on the
    producer thread (host packing overlaps device compute), the consumer
    dispatches each chunk's device program asynchronously and calls
    ``emit(tag, piece, mu, var)`` one chunk LATER — i.e. while the device
    crunches chunk k, chunk k-1's results are landed. Because ``jobs`` is
    a generator pulled lazily (bounded queue of depth ``cfg.prefetch``),
    every pull is a chunk boundary: a scheduler-backed ``jobs`` can admit
    newly arrived requests and honor cancellations between any two
    chunks.

    A job with ``pack_fn=None`` is a BARRIER: it lands whatever is still
    in flight without computing anything. An endless jobs source (the
    continuous scheduler) MUST emit barriers when it idles, otherwise
    the one-chunk-delayed emit strands the last chunk of a burst until
    the next arrival. ``predict_pipelined`` is a thin wrapper over this
    function, so the drain-mode and continuous-mode paths run one engine
    and cannot drift."""
    split = make_chunk_split(cfg)
    compute = make_chunk_compute(params, cfg, mesh)

    inflight = None  # (tag, [(piece, mu_dev, var_dev), ...]) — not yet forced

    def land(slot):
        tag, pieces = slot
        for piece, mu, vr in pieces:
            emit(tag, piece, mu, vr)

    with Prefetcher(jobs, depth=cfg.prefetch,
                    stage=lambda job: (
                        job[0], None if job[1] is None else split(job[1]())),
                    name="sbv-packer") as staged:
        for tag, host_pieces in staged:
            if host_pieces is None:        # barrier: flush the delayed emit
                if inflight is not None:
                    land(inflight)
                    inflight = None
                continue
            pieces = compute(host_pieces)  # async dispatch, returns early
            _record_pieces(stats, pieces)
            if inflight is not None:
                land(inflight)
            inflight = (tag, pieces)
        if inflight is not None:
            land(inflight)


def predict_synchronous(
    params: KernelParams,
    index: TrainIndex,
    x_test: np.ndarray,
    cfg: PipelineConfig,
    seed: int = 0,
    mesh=None,
    stats: ServerStats | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """The strictly serial chunk loop (pack -> compute -> block -> scatter).

    Kept as the pipeline's correctness twin and benchmark baseline.
    ``x_test`` may be a row store; windows are then read on demand inside
    ``iter_query_chunks``."""
    n_test = _n_rows(x_test)
    mean, var = _result_zeros(n_test, n_outputs_of(params))
    split = make_chunk_split(cfg)
    compute = make_chunk_compute(params, cfg, mesh)
    for _, packed in _chunks(index, x_test, cfg, seed):
        pieces = compute(split(packed))
        _record_pieces(stats, pieces)
        for piece, mu, vr in pieces:
            scatter_packed(piece, (mu, mean), (vr, var))  # forces the result
    return mean, var


def predict_pipelined(
    params: KernelParams,
    index: TrainIndex,
    x_test: np.ndarray,
    cfg: PipelineConfig,
    seed: int = 0,
    mesh=None,
    stats: ServerStats | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Double-buffered chunk loop: identical results, overlapped phases.

    While the device computes chunk k, the host scatters chunk k-1 and the
    producer thread packs chunk k+1 (numpy releases the GIL in the hot
    gathers, so the threads genuinely overlap). With a store-backed
    ``x_test`` the producer also does the window READS off the critical
    path — IO overlaps device compute exactly like packing does."""
    n_test = _n_rows(x_test)
    mean, var = _result_zeros(n_test, n_outputs_of(params))
    if n_test == 0:
        return mean, var

    # The packed chunk is built lazily on the PRODUCER thread: the jobs
    # generator itself is iterated there (Prefetcher contract), so
    # wrapping each already-packed chunk in a thunk keeps the exact
    # pack/split/compute/scatter ordering of the original inline loop —
    # results stay bitwise identical to predict_synchronous.
    jobs = ((ci, (lambda p=packed: p))
            for ci, packed in _chunks(index, x_test, cfg, seed))

    def emit(_tag, piece, mu, vr):
        scatter_packed(piece, (mu, mean), (vr, var))  # forces the result

    run_chunk_stream(params, cfg, jobs, emit, mesh=mesh, stats=stats)
    return mean, var


class SpoolResultSink:
    """Disk-backed per-request result sink (the backpressure story's
    out-of-core leg): each completed chunk's (index, mean, var) triple is
    spooled through ``PackedChunkSpool`` (``data/streaming.py``) with a
    zero device budget, so a bulk sweep's full result never lives in
    server RAM. ``float64`` ``.npz`` round-trips are bit-exact, so
    ``materialize()`` reproduces the in-RAM result identically — the
    parity contract survives the disk hop."""

    def __init__(self, path: str, n_points: int, n_outputs: int = 1):
        from repro.data.streaming import PackedChunkSpool

        self.n_points = int(n_points)
        self.n_outputs = int(n_outputs)
        self._spool = PackedChunkSpool(path, device_budget=0,
                                       device_stage=False)
        self._n_added = 0

    def add(self, piece, mu, var) -> None:
        """Spool one computed chunk piece (masked rows only)."""
        msk = np.asarray(piece.q_mask)
        self._spool.add_arrays(
            {"idx": np.asarray(piece.q_idx)[msk],
             "mean": np.asarray(mu)[msk],
             "var": np.asarray(var)[msk]},
            tag=self._n_added,
        )
        self._n_added += 1

    @property
    def n_chunks(self) -> int:
        return self._n_added

    @property
    def spooled_bytes(self) -> int:
        return self._spool.disk_bytes_total

    def iter_chunks(self):
        """Yield ``(idx, mean, var)`` per spooled piece, in spool order —
        the bounded-memory read path (one piece resident at a time)."""
        for arrays, _tag in self._spool.iter_arrays(prefetch=0):
            yield arrays["idx"], arrays["mean"], arrays["var"]

    def materialize(self) -> tuple[np.ndarray, np.ndarray]:
        """Assemble the full (mean, var) in RAM — convenience for callers
        that decide the result fits after all."""
        mean, var = _result_zeros(self.n_points, self.n_outputs)
        for idx, mu, vr in self.iter_chunks():
            mean[idx] = mu
            var[idx] = vr
        return mean, var

    def cleanup(self) -> None:
        self._spool.cleanup()
