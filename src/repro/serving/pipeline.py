"""Double-buffered chunk pipeline: host packing overlaps device compute.

The one-shot serving loop is strictly serial per chunk:

    pack k -> dispatch k -> block on k -> scatter k -> pack k+1 -> ...

but packing is host-side numpy (block assembly + filtered kNN + the
``PackedPrediction`` gather) and compute is a jitted device program that
JAX dispatches ASYNCHRONOUSLY — the call returns before the result is
ready. The pipeline exploits that:

* a producer thread runs ``iter_query_chunks`` and keeps up to
  ``prefetch`` packed chunks in a bounded queue (double buffer);
* the consumer dispatches chunk k's device program, then — while the
  device crunches — scatters chunk k-1's now-ready results and the
  producer packs chunk k+1.

Steady state: packing cost and scatter cost disappear behind device
compute; per-chunk wall time approaches max(pack, compute) instead of
pack + compute. Results are BITWISE identical to the synchronous loop
(same ``iter_query_chunks`` protocol, same jitted program, same scatter).

The producer-thread machinery itself lives in ``repro.prefetch``
(``Prefetcher``) — it is shared with the streaming fit's H2D spool
reader, so both overlap paths run one tested implementation.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.kernels_math import KernelParams
from repro.core.predict import (
    TrainIndex, iter_query_chunks, packed_predict, scatter_packed,
)
from repro.prefetch import Prefetcher

from .telemetry import ServerStats


@dataclass
class PipelineConfig:
    """Knobs of the chunked prediction read path (shared by the sync and
    double-buffered drivers so the two cannot drift)."""

    bs_pred: int = 25
    m_pred: int = 120
    nu: float = 3.5
    alpha: float = 100.0
    backend: str = "ref"      # 'ref' | 'pallas' | 'pallas_tiled' | 'auto'
    dtype: type = np.float64  # float32 for the compiled TPU kernel
    chunk_size: int | None = 4096
    n_workers: int = 1
    prefetch: int = 2         # packed chunks in flight (2 = double buffer)
    n_buckets: int | None = None  # size-bucketed micro-batches (docs/packing.md)
    stream_chunk: int | None = None  # out-of-core train index (docs/streaming.md)


def _n_rows(x_test) -> int:
    """Row count of an in-core array OR a row store."""
    from repro.data.store import is_store

    if is_store(x_test):
        return x_test.n_rows
    return int(np.asarray(x_test).shape[0])


def make_chunk_split(cfg: PipelineConfig):
    """Return ``split(packed) -> [packed_piece, ...]`` — the host-side
    bucketing step of one chunk (the uniform layout is the one-piece
    special case). Pure numpy: the pipelined driver runs it on the
    PRODUCER thread so the slice copies overlap device compute like the
    rest of packing."""
    if not cfg.n_buckets:
        return lambda packed: [packed]

    from repro.core.buckets import bucket_mults, bucket_prediction
    from repro.core.packing import round_up

    # Serving quantizes bucket shapes harder than the one-shot path:
    # ceilings to multiples of 8 and block counts padded to multiples
    # of 8 (masked dummies, inert), so steady-state traffic converges
    # to a bounded set of compile-cache keys just like the uniform
    # `pad_shapes` protocol.
    bs_mult, m_mult = (max(v, 8) for v in bucket_mults(cfg.backend))

    def split(packed):
        pieces = bucket_prediction(packed, n_buckets=cfg.n_buckets,
                                   bs_mult=bs_mult, m_mult=m_mult).buckets
        return [p.pad_to_blocks(round_up(p.n_blocks, 8)) for p in pieces]

    return split


def make_chunk_compute(params: KernelParams, cfg: PipelineConfig, mesh=None,
                       axis: str = "workers"):
    """Return ``compute(pieces) -> [(packed_piece, mu, var), ...]`` over
    the (already split) pieces of one chunk; every piece is dispatched
    asynchronously through the jitted predict program. With a mesh, each
    piece's blocks are sharded by owner first (which reorders them —
    hence every piece is returned alongside its outputs so the scatter
    uses matching indices)."""
    if mesh is None:
        def compute(pieces):
            out = []
            for piece in pieces:
                mu, var = packed_predict(params, piece, nu=cfg.nu,
                                         backend=cfg.backend)
                out.append((piece, mu, var))
            return out
        return compute

    from repro.core.distributed import sharded_packed_predict

    def compute(pieces):
        return [
            sharded_packed_predict(params, piece, mesh, axis=axis,
                                   nu=cfg.nu, backend=cfg.backend)
            for piece in pieces
        ]

    return compute


def _record_pieces(stats: ServerStats | None, pieces) -> None:
    """Per-piece shape + padding-occupancy telemetry for ONE chunk (the
    chunk counter advances once however many bucket pieces it split into)."""
    if stats is None:
        return
    from repro.core.buckets import prediction_work

    for i, (piece, _, _) in enumerate(pieces):
        stats.record_chunk_shape(piece.n_blocks, piece.bs_pred, piece.m_pred,
                                 count_chunk=i == 0)
    stats.record_occupancy(*prediction_work([p for p, _, _ in pieces]))


def _chunks(index: TrainIndex, x_test: np.ndarray, cfg: PipelineConfig,
            seed: int):
    return iter_query_chunks(
        index, x_test, cfg.bs_pred, cfg.m_pred, alpha=cfg.alpha, seed=seed,
        n_workers=cfg.n_workers, chunk_size=cfg.chunk_size, dtype=cfg.dtype,
    )


def predict_synchronous(
    params: KernelParams,
    index: TrainIndex,
    x_test: np.ndarray,
    cfg: PipelineConfig,
    seed: int = 0,
    mesh=None,
    stats: ServerStats | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """The strictly serial chunk loop (pack -> compute -> block -> scatter).

    Kept as the pipeline's correctness twin and benchmark baseline.
    ``x_test`` may be a row store; windows are then read on demand inside
    ``iter_query_chunks``."""
    n_test = _n_rows(x_test)
    mean = np.zeros(n_test)
    var = np.zeros(n_test)
    split = make_chunk_split(cfg)
    compute = make_chunk_compute(params, cfg, mesh)
    for _, packed in _chunks(index, x_test, cfg, seed):
        pieces = compute(split(packed))
        _record_pieces(stats, pieces)
        for piece, mu, vr in pieces:
            scatter_packed(piece, (mu, mean), (vr, var))  # forces the result
    return mean, var


def predict_pipelined(
    params: KernelParams,
    index: TrainIndex,
    x_test: np.ndarray,
    cfg: PipelineConfig,
    seed: int = 0,
    mesh=None,
    stats: ServerStats | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Double-buffered chunk loop: identical results, overlapped phases.

    While the device computes chunk k, the host scatters chunk k-1 and the
    producer thread packs chunk k+1 (numpy releases the GIL in the hot
    gathers, so the threads genuinely overlap). With a store-backed
    ``x_test`` the producer also does the window READS off the critical
    path — IO overlaps device compute exactly like packing does."""
    n_test = _n_rows(x_test)
    mean = np.zeros(n_test)
    var = np.zeros(n_test)
    if n_test == 0:
        return mean, var

    split = make_chunk_split(cfg)
    compute = make_chunk_compute(params, cfg, mesh)

    inflight = None  # [(piece, mu_dev, var_dev), ...] — dispatched, not forced
    # The bucket split is host numpy — the stage fn keeps it off the
    # consumer's critical path, same as the rest of packing.
    with Prefetcher(_chunks(index, x_test, cfg, seed), depth=cfg.prefetch,
                    stage=lambda kv: split(kv[1]), name="sbv-packer") as staged:
        for item in staged:
            pieces = compute(item)   # async dispatch, returns early
            _record_pieces(stats, pieces)
            if inflight is not None:
                for p_prev, mu_prev, vr_prev in inflight:
                    scatter_packed(p_prev, (mu_prev, mean), (vr_prev, var))
            inflight = pieces
        if inflight is not None:
            for p_prev, mu_prev, vr_prev in inflight:
                scatter_packed(p_prev, (mu_prev, mean), (vr_prev, var))
    return mean, var
