"""Per-request latency + batching telemetry for the persistent GP server.

Every request carries a trace (submit -> dispatch -> done); the server
aggregates them under a lock so `GPServer.stats()` can report queue wait,
end-to-end latency percentiles, micro-batch occupancy, and how many
distinct compiled shapes the jit cache saw (the shape-stability signal:
a healthy steady state converges to a handful of keys and stops growing).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field


def now() -> float:
    return time.perf_counter()


@dataclass
class RequestTrace:
    """Timeline of one predict request through the server."""

    n_points: int
    t_submit: float = field(default_factory=now)
    t_dispatch: float = 0.0   # when its micro-batch left the queue
    t_done: float = 0.0       # when its future resolved

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.t_dispatch - self.t_submit)

    @property
    def latency_s(self) -> float:
        return max(0.0, self.t_done - self.t_submit)


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[k]


class ServerStats:
    """Thread-safe aggregate counters for one ``GPServer`` lifetime.

    Counters are exact over the lifetime; the per-request/per-batch
    samples behind the percentiles are a sliding window (``window``
    most recent) so a server that runs forever holds bounded memory."""

    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self._window = window
        self.n_requests = 0
        self.n_points = 0
        self.n_batches = 0
        self.n_chunks = 0
        self.batch_sizes: deque[int] = deque(maxlen=window)    # reqs/batch
        self.batch_points: deque[int] = deque(maxlen=window)   # pts/batch
        self.latencies_s: deque[float] = deque(maxlen=window)
        self.queue_waits_s: deque[float] = deque(maxlen=window)
        self.compiled_shapes: set[tuple] = set()  # (bc, bs, m, tier) seen by jit
        self.true_flops = 0.0    # padding-occupancy accounting: useful work
        self.padded_flops = 0.0  # ... vs what the padded shapes execute
        # Continuous-scheduler signals (scheduler.py): per-SLO-class
        # latency windows plus admission-queue / policy-event counters.
        self.class_latencies: dict[str, deque] = {}
        self.class_counts: dict[str, int] = {}
        self.n_cancelled = 0
        self.n_preempted = 0
        self.n_rejected = 0            # AdmissionQueueFull submits
        self.queue_depth_points = 0    # current gauge
        self.queue_depth_peak = 0      # lifetime high-water mark
        self.t_start = now()

    def record_batch(self, n_requests: int, n_points: int) -> None:
        with self._lock:
            self.n_batches += 1
            self.batch_sizes.append(n_requests)
            self.batch_points.append(n_points)

    def record_chunk_shape(self, bc: int, bs: int, m: int,
                           count_chunk: bool = True,
                           tier: str = "f64") -> None:
        """Track one device-program shape; ``count_chunk=False`` records a
        further bucket piece of an already-counted chunk, so ``n_chunks``
        keeps meaning chunks processed, not pieces dispatched. The key
        carries the precision ``tier`` because the jit cache does too:
        the same ``(bc, bs, m)`` at two dtypes is two compiled programs,
        and the affinity router's signal must not collapse them."""
        with self._lock:
            self.n_chunks += 1 if count_chunk else 0
            self.compiled_shapes.add((bc, bs, m, tier))

    def compiled_shape_keys(self) -> set[tuple]:
        """Snapshot of the ``(bc, bs, m, tier)`` keys seen so far (a copy;
        safe to iterate while the server keeps recording)."""
        with self._lock:
            return set(self.compiled_shapes)

    def reset(self, preserve_shapes: bool = True) -> None:
        """Zero every counter and window and restart the qps clock.

        ``compiled_shapes`` is kept by default: the process-level jit
        cache it mirrors survives a stats reset, so dropping the keys
        would fake recompiles that will never happen. Pass
        ``preserve_shapes=False`` to clear it too (fresh-server
        accounting in benchmarks)."""
        with self._lock:
            self.n_requests = 0
            self.n_points = 0
            self.n_batches = 0
            self.n_chunks = 0
            self.batch_sizes.clear()
            self.batch_points.clear()
            self.latencies_s.clear()
            self.queue_waits_s.clear()
            if not preserve_shapes:
                self.compiled_shapes.clear()
            self.true_flops = 0.0
            self.padded_flops = 0.0
            self.class_latencies = {}
            self.class_counts = {}
            self.n_cancelled = 0
            self.n_preempted = 0
            self.n_rejected = 0
            self.queue_depth_points = 0
            self.queue_depth_peak = 0
            self.t_start = now()

    def record_occupancy(self, true_flops: float, padded_flops: float) -> None:
        """Accumulate the padding-occupancy ratio's numerator/denominator
        (occupancy = Sigma true FLOPs / Sigma padded FLOPs; 1.0 = zero
        padding waste — the bucketed layout's whole point)."""
        with self._lock:
            self.true_flops += float(true_flops)
            self.padded_flops += float(padded_flops)

    def record_request(self, trace: RequestTrace, slo: str | None = None) -> None:
        with self._lock:
            self.n_requests += 1
            self.n_points += trace.n_points
            self.latencies_s.append(trace.latency_s)
            self.queue_waits_s.append(trace.queue_wait_s)
            if slo is not None:
                if slo not in self.class_latencies:
                    self.class_latencies[slo] = deque(maxlen=self._window)
                    self.class_counts[slo] = 0
                self.class_latencies[slo].append(trace.latency_s)
                self.class_counts[slo] += 1

    def record_queue_depth(self, points: int) -> None:
        """Admission-queue gauge (points), with a lifetime high-water mark."""
        with self._lock:
            self.queue_depth_points = int(points)
            self.queue_depth_peak = max(self.queue_depth_peak, int(points))

    def record_cancelled(self) -> None:
        with self._lock:
            self.n_cancelled += 1

    def record_preemption(self) -> None:
        """One pick that jumped ahead of older lower-priority work."""
        with self._lock:
            self.n_preempted += 1

    def record_rejected(self) -> None:
        """One submit refused by the bounded admission queue."""
        with self._lock:
            self.n_rejected += 1

    def summary(self) -> dict:
        with self._lock:
            lat = sorted(self.latencies_s)
            waits = sorted(self.queue_waits_s)
            elapsed = max(now() - self.t_start, 1e-9)
            return {
                "n_requests": self.n_requests,
                "n_points": self.n_points,
                "n_batches": self.n_batches,
                "n_chunks": self.n_chunks,
                "points_per_s": self.n_points / elapsed,
                "mean_batch_requests": (
                    sum(self.batch_sizes) / len(self.batch_sizes)
                    if self.batch_sizes else 0.0
                ),
                "mean_batch_points": (
                    sum(self.batch_points) / len(self.batch_points)
                    if self.batch_points else 0.0
                ),
                "latency_p50_s": _percentile(lat, 0.50),
                "latency_p95_s": _percentile(lat, 0.95),
                "latency_p99_s": _percentile(lat, 0.99),
                "queue_wait_p50_s": _percentile(waits, 0.50),
                "n_compiled_shapes": len(self.compiled_shapes),
                "padding_occupancy": (
                    self.true_flops / self.padded_flops
                    if self.padded_flops else 1.0
                ),
                "n_cancelled": self.n_cancelled,
                "n_preempted": self.n_preempted,
                "n_rejected": self.n_rejected,
                "queue_depth_points": self.queue_depth_points,
                "queue_depth_peak": self.queue_depth_peak,
                "by_class": {
                    name: {
                        "n": self.class_counts[name],
                        "latency_p50_s": _percentile(sorted(d), 0.50),
                        "latency_p99_s": _percentile(sorted(d), 0.99),
                    }
                    for name, d in self.class_latencies.items()
                },
            }
