"""Continuous-batching scheduler: a persistent running batch admitting
requests at every chunk boundary (docs/serving.md, "Continuous batching").

The drain-mode server (``MicroBatcher`` + ``_process``) computes a whole
micro-batch to completion before looking at the queue again; a bulk
sweep therefore holds the device hostage for its full duration and an
interactive point query arriving one chunk too late waits out the whole
sweep. This scheduler replaces that loop with SGLang-style continuous
batching:

* **unit of work** — one *(request, chunk)* pair. A request's chunks are
  enumerated up front by ``request_chunk_bounds`` and packed by
  ``pack_scheduled`` (``pipeline.py``) with the request's OWN
  ``iter_query_chunks`` protocol, so per-request results are exactly
  those of a per-request ``predict_sbv`` call — the scheduler reorders
  which unit runs when, never what a unit computes. That is the whole
  1e-12 parity contract, and why admission order is a pure policy knob.
* **chunk boundary = decision point** — ``next_chunk`` is pulled by the
  double-buffered pipeline (``run_chunk_stream``) once per chunk; each
  pull reaps cancellations, admits newly queued requests into the
  running batch, and picks the next unit.
* **SLO classes** — start-time fair queuing over classes: pick the
  backlogged class with the smallest virtual time (priority breaks
  ties), then advance its clock by ``1/weight``. A newly backlogged
  class enters at the current virtual time, so an interactive arrival
  preempts queued bulk work at the very next boundary, while bulk's
  weight guarantees it a bounded share of boundaries (starvation-free:
  with weights 3:1, every 4 consecutive picks contain a bulk chunk
  whenever bulk is backlogged).
* **cancellation** — ``cancel(future)`` (or a plain ``future.cancel()``)
  marks the request; the next boundary drops its remaining chunks from
  the running batch. Chunks already dispatched to the device complete
  but their results are discarded. Futures are never marked running
  until resolution, so client-side ``cancel()`` always "wins" the race.
* **backpressure** — the admission queue is bounded in query points
  (``SchedulerPolicy.queue_bound``; overflow raises
  ``AdmissionQueueFull``), and requests of ``spool_threshold`` points or
  more stream their results into a disk-backed ``SpoolResultSink``
  instead of RAM.

Determinism: every decision runs on an injectable ``clock`` and
``next_chunk(idle_timeout_s=0)`` is one strictly non-blocking pass, so a
fake clock plus scripted arrivals replays any schedule exactly —
``tests/test_scheduler.py`` is the executable spec built on that.
"""
from __future__ import annotations

import os
import tempfile
import threading
from collections import deque

import numpy as np

from .batching import (
    AdmissionQueueFull, ArrivalWindow, BatchingPolicy, SchedulerPolicy,
    ServeRequest,
)
from .pipeline import request_chunk_bounds
from .telemetry import now

from repro.core.predict import scatter_packed


class _Entry:
    """One admitted request inside the running batch."""

    __slots__ = ("req", "cls", "bounds", "next_ci", "done", "cancelled",
                 "mean", "var", "sink", "t_admit", "finalized")

    def __init__(self, req: ServeRequest, cls, bounds, t_admit: float):
        self.req = req
        self.cls = cls
        self.bounds = bounds      # [(start, stop), ...] — all chunks
        self.next_ci = 0          # chunks handed to the pipeline so far
        self.done = 0             # chunks completed so far
        self.cancelled = False
        self.mean = None          # result buffers (RAM mode) ...
        self.var = None
        self.sink = None          # ... or the spool sink (out-of-core mode)
        self.t_admit = t_admit
        self.finalized = False    # terminal bookkeeping done exactly once

    @property
    def n_chunks(self) -> int:
        return len(self.bounds)


class ScheduledChunk:
    """One schedulable unit: chunk ``ci`` (request rows [start, stop)) of
    one admitted request — the ``tag`` flowing through
    ``run_chunk_stream`` and back into ``complete_chunk``."""

    __slots__ = ("entry", "ci", "start", "stop")

    def __init__(self, entry: _Entry, ci: int, start: int, stop: int):
        self.entry = entry
        self.ci = ci
        self.start = start
        self.stop = stop

    @property
    def request(self) -> ServeRequest:
        return self.entry.req

    @property
    def n_points(self) -> int:
        return self.stop - self.start


def _default_result(entry: _Entry):
    return entry.sink if entry.sink is not None else (entry.mean, entry.var)


class ContinuousScheduler:
    """The running batch + admission queue + SLO policy state machine.

    Thread contract: ``submit``/``cancel``/``flush``/``close`` are called
    from request threads; ``next_chunk`` from the pipeline's producer
    thread; ``complete_chunk`` from the consumer (dispatch) thread. One
    condition variable serializes all of it.
    """

    def __init__(
        self,
        policy: SchedulerPolicy | None = None,
        window: BatchingPolicy | None = None,
        chunk_size: int | None = 4096,
        bs_pred: int = 25,
        clock=now,
        stats=None,
        result_factory=None,
        sink_factory=None,
        n_outputs: int = 1,
    ):
        self.policy = policy or SchedulerPolicy()
        self.window_policy = window or BatchingPolicy()
        self.chunk_size = chunk_size
        self.bs_pred = bs_pred
        self.n_outputs = int(n_outputs)
        self._clock = clock
        self.stats = stats
        self._result_factory = result_factory or _default_result
        self._sink_factory = sink_factory
        self._window = ArrivalWindow(self.window_policy, clock=clock)
        self._cond = threading.Condition(threading.Lock())
        self._queue: deque[ServeRequest] = deque()
        self._queued_points = 0
        self._last_arrival = 0.0
        self._active: dict[str, list[_Entry]] = {
            name: [] for name in self.policy.classes
        }
        self._inflight: set[_Entry] = set()   # fully scheduled, not complete
        self._vtime: dict[str, float] = {name: 0.0 for name in self.policy.classes}
        self._vnow = 0.0                      # virtual time of the last pick
        self._by_future: dict = {}            # future -> ServeRequest | _Entry
        self._closed = False
        self._force = False                   # flush(): skip the idle window
        self._spool_root: str | None = None
        self._sink_seq = 0

    # -- request side --------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        """Enqueue one request for admission at the next chunk boundary.

        Raises ``AdmissionQueueFull`` when ``queue_bound`` (total queued
        points) would be exceeded — the backpressure signal; callers
        retry, shed, or block on their side."""
        n = int(req.x.shape[0])
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if req.slo not in self.policy.classes:
                raise ValueError(
                    f"unknown SLO class {req.slo!r}; "
                    f"have {sorted(self.policy.classes)}"
                )
            bound = self.policy.queue_bound
            if bound is not None and self._queued_points + n > bound:
                if self.stats is not None:
                    self.stats.record_rejected()
                raise AdmissionQueueFull(
                    f"admission queue holds {self._queued_points} points; "
                    f"{n} more would exceed queue_bound={bound}"
                )
            req.t_arrival = self._window.observe()
            self._last_arrival = req.t_arrival
            self._queue.append(req)
            self._queued_points += n
            self._by_future[req.future] = req
            if self.stats is not None:
                self.stats.record_queue_depth(self._queued_points)
            self._cond.notify_all()

    def cancel(self, future) -> bool:
        """Request cancellation; takes effect at the next chunk boundary.

        Returns False when the future is unknown here (never submitted,
        or already resolved)."""
        with self._cond:
            target = self._by_future.get(future)
            if target is None:
                return False
            target.cancelled = True
            self._cond.notify_all()
            return True

    def flush(self) -> None:
        """Admit whatever is queued at the next boundary, window or not."""
        with self._cond:
            self._force = True
            self._cond.notify_all()

    def close(self) -> None:
        """Stop accepting submits; the running batch and queue drain."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def queue_depth_points(self) -> int:
        with self._cond:
            return self._queued_points

    @property
    def outstanding_points(self) -> int:
        """Queued + admitted-but-unlanded query points — the router's
        least-outstanding-work spill signal. ``_active`` (partially
        scheduled) and ``_inflight`` (fully scheduled, not complete)
        entries are disjoint by construction, so each is summed once."""
        with self._cond:
            total = self._queued_points
            entries = [e for lst in self._active.values() for e in lst]
            entries.extend(self._inflight)
            for e in entries:
                total += sum(stop - start for start, stop in e.bounds[e.done:])
            return total

    def drain_pending(self) -> list[ServeRequest]:
        """Remove and return still-queued requests (post-close cleanup:
        the server fails their futures instead of stranding them)."""
        with self._cond:
            pending = list(self._queue)
            self._queue.clear()
            self._queued_points = 0
            for req in pending:
                self._by_future.pop(req.future, None)
            if self.stats is not None:
                self.stats.record_queue_depth(0)
            return pending

    def fail_all(self, exc: BaseException) -> None:
        """Terminal failure (the pipeline engine died): fail every
        outstanding future so no client blocks forever."""
        with self._cond:
            entries = set(self._inflight)
            for lst in self._active.values():
                entries.update(lst)
                lst.clear()
            self._inflight.clear()
            reqs = [e.req for e in entries if not e.finalized] + list(self._queue)
            for e in entries:
                e.finalized = True
            self._queue.clear()
            self._queued_points = 0
            self._by_future.clear()
            self._cond.notify_all()
        for req in reqs:
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(exc)

    # -- scheduling side (pipeline threads) ----------------------------

    def next_chunk(self, idle_timeout_s: float = 0.0) -> ScheduledChunk | None:
        """THE chunk boundary: reap cancellations, admit queued requests,
        pick the next unit by weighted-fair virtual time.

        With ``idle_timeout_s <= 0`` this is one strictly non-blocking
        pass (returns None when nothing is runnable) — deterministic
        under a fake clock, which is how the scheduler tests drive it. A
        positive timeout polls the condition variable up to that long
        (real-clock server use). Returns None on timeout, and None
        permanently once closed and fully drained."""
        deadline = None
        with self._cond:
            while True:
                self._reap()
                self._admit()
                item = self._pick()
                if item is not None:
                    return item
                if (self._closed and not self._queue
                        and not any(self._active.values())):
                    return None
                if idle_timeout_s <= 0:
                    return None
                t = self._clock()
                if deadline is None:
                    deadline = t + idle_timeout_s
                remaining = deadline - t
                if remaining <= 0:
                    return None
                # Poll-capped wait: a deferred idle-window admission has
                # a clock deadline no notify will fire for.
                self._cond.wait(timeout=min(remaining, 0.05))

    def complete_chunk(self, item: ScheduledChunk, piece, mu, var) -> None:
        """Land one computed chunk: scatter into the request's buffers
        (or spool sink) and resolve the future once the request is whole.
        Cancelled entries' results are discarded."""
        e = item.entry
        _PENDING = object()
        result = _PENDING
        with self._cond:
            e.done += 1
            live = not (e.finalized or e.cancelled or e.req.future.cancelled())
            if live:
                mu = np.asarray(mu)
                var = np.asarray(var)
                if e.sink is not None:
                    e.sink.add(piece, mu, var)
                else:
                    scatter_packed(piece, (mu, e.mean), (var, e.var))
            if e.cancelled or e.req.future.cancelled():
                if not e.finalized:
                    self._finalize_cancel(e)
                if e.done >= e.next_ci:   # last in-flight chunk landed
                    self._inflight.discard(e)
                    if e.sink is not None:
                        e.sink.cleanup()
            elif e.done == e.n_chunks:
                e.finalized = True
                self._inflight.discard(e)
                self._by_future.pop(e.req.future, None)
                e.req.trace.t_done = self._clock()
                if self.stats is not None:
                    self.stats.record_request(e.req.trace, slo=e.cls.name)
                result = self._result_factory(e)
            self._cond.notify_all()
        if result is not _PENDING:
            # Resolve OUTSIDE the lock: done-callbacks run inline and may
            # re-enter the scheduler (e.g. submit a follow-up request).
            if e.req.future.set_running_or_notify_cancel():
                e.req.future.set_result(result)

    # -- internals (all called with the lock held) ---------------------

    def _n_active(self) -> int:
        return sum(len(lst) for lst in self._active.values()) + len(self._inflight)

    def _reap(self) -> None:
        """Make cancellations effective: drop cancelled requests from the
        queue and cancelled entries' remaining chunks from the running
        batch. This runs at every boundary — the 'within one chunk'
        cancellation guarantee."""
        if self._queue:
            kept: deque[ServeRequest] = deque()
            for req in self._queue:
                if req.cancelled or req.future.cancelled():
                    self._queued_points -= int(req.x.shape[0])
                    self._by_future.pop(req.future, None)
                    req.future.cancel()
                    if self.stats is not None:
                        self.stats.record_cancelled()
                else:
                    kept.append(req)
            self._queue = kept
        for lst in self._active.values():
            for e in list(lst):
                if e.cancelled or e.req.future.cancelled():
                    lst.remove(e)
                    self._finalize_cancel(e)
        for e in list(self._inflight):
            if (e.cancelled or e.req.future.cancelled()) and not e.finalized:
                self._finalize_cancel(e)

    def _finalize_cancel(self, e: _Entry) -> None:
        if not e.finalized:   # idempotent: reap + complete can both land here
            e.cancelled = True
            e.finalized = True
            self._by_future.pop(e.req.future, None)
            e.req.future.cancel()
            if self.stats is not None:
                self.stats.record_cancelled()
        if e.done >= e.next_ci:   # nothing in flight — drop it now
            self._inflight.discard(e)
            if e.sink is not None:
                e.sink.cleanup()

    def _admit(self) -> None:
        if not self._queue:
            self._force = False
            return
        busy = bool(self._inflight) or any(self._active.values())
        if not busy and not self._force and not self._closed:
            # Idle device: the adaptive batching window applies exactly
            # as in drain mode — wait briefly for coalescing partners
            # unless the queue already trips max_points. When the device
            # is BUSY the window is moot: admission at a boundary is
            # free, so arrivals join the running batch immediately.
            # Anchor on the MOST RECENT arrival: each new request re-arms
            # the coalescing window (adaptive EMA shrinks it under load).
            if (self._queued_points < self.window_policy.max_points
                    and self._clock() < self._last_arrival
                    + self._window.effective_wait_s()):
                return
        self._force = False
        cap = self.policy.max_active_requests
        while self._queue:
            # On close, the cap is waived: everything queued must drain.
            if not self._closed and self._n_active() >= cap:
                break
            req = self._queue.popleft()
            self._queued_points -= int(req.x.shape[0])
            self._admit_one(req)
        if self.stats is not None:
            self.stats.record_queue_depth(self._queued_points)

    def _admit_one(self, req: ServeRequest) -> None:
        cls = self.policy.classes[req.slo]
        n = int(req.x.shape[0])
        t = self._clock()
        e = _Entry(req, cls, request_chunk_bounds(n, self.chunk_size,
                                                  self.bs_pred), t)
        req.trace.t_dispatch = t
        thr = self.policy.spool_threshold
        if thr is not None and n >= thr:
            e.sink = self._make_sink(req)
        else:
            shape = (n,) if self.n_outputs == 1 else (n, self.n_outputs)
            e.mean = np.zeros(shape)
            e.var = np.zeros(shape)
        if not self._active[cls.name]:
            # Newly backlogged class enters at the running batch's
            # virtual time — this is what lets interactive arrivals
            # preempt queued bulk chunks at the next pick.
            self._vtime[cls.name] = max(self._vtime[cls.name], self._vnow)
        self._active[cls.name].append(e)
        self._by_future[req.future] = e

    def _pick(self) -> ScheduledChunk | None:
        backlogged = [name for name, lst in self._active.items() if lst]
        if not backlogged:
            return None
        name = min(backlogged, key=lambda c: (
            self._vtime[c], self.policy.classes[c].priority, c))
        cls = self.policy.classes[name]
        lst = self._active[name]
        e = lst[0]
        if self.stats is not None:
            for other in backlogged:
                # A preemption: this pick jumps ahead of OLDER admitted
                # work in a lower-priority class.
                if (other != name
                        and self.policy.classes[other].priority > cls.priority
                        and self._active[other][0].t_admit < e.t_admit):
                    self.stats.record_preemption()
                    break
        ci = e.next_ci
        start, stop = e.bounds[ci]
        e.next_ci += 1
        if e.next_ci >= e.n_chunks:
            lst.pop(0)
            self._inflight.add(e)
        self._vnow = self._vtime[name]
        self._vtime[name] += 1.0 / max(cls.weight, 1e-9)
        return ScheduledChunk(e, ci, start, stop)

    def _make_sink(self, req: ServeRequest):
        if self._sink_factory is not None:
            return self._sink_factory(req)
        from .pipeline import SpoolResultSink

        if self._spool_root is None:
            self._spool_root = (self.policy.spool_dir
                                or tempfile.mkdtemp(prefix="sbv-serve-sink-"))
        self._sink_seq += 1
        path = os.path.join(self._spool_root, f"req_{self._sink_seq:06d}")
        return SpoolResultSink(path, int(req.x.shape[0]),
                               n_outputs=self.n_outputs)
