# Persistent batched GP serving (docs/serving.md):
#   batching.py  — request micro-batching (max-size/max-wait policy)
#   pipeline.py  — double-buffered chunk pipeline (pack k+1 || compute k)
#   server.py    — GPServer: owns the train index + compiled predict program
#   telemetry.py — per-request latency + batch-occupancy stats
from .batching import BatchingPolicy, MicroBatcher, PredictRequest
from .pipeline import PipelineConfig, predict_pipelined, predict_synchronous
from .server import GPServer, GPServerConfig, ServeResult
from .telemetry import RequestTrace, ServerStats

__all__ = [
    "BatchingPolicy", "MicroBatcher", "PredictRequest",
    "PipelineConfig", "predict_pipelined", "predict_synchronous",
    "GPServer", "GPServerConfig", "ServeResult",
    "RequestTrace", "ServerStats",
]
