# Persistent batched GP serving (docs/serving.md):
#   batching.py  — request micro-batching + SLO / scheduler policy types
#   scheduler.py — continuous-batching scheduler (running batch, SLO-aware
#                  admission at chunk boundaries, cancellation, backpressure)
#   pipeline.py  — double-buffered chunk engine (pack k+1 || compute k),
#                  per-request pack protocol, spool-backed result sink
#   server.py    — GPServer: owns the train index + compiled predict program
#   router.py    — ReplicaRouter: N replicas behind one submit(), routed by
#                  compile-cache shape affinity (rendezvous hashing + spill)
#   telemetry.py — per-request / per-SLO-class latency + occupancy stats
from .batching import (
    AdmissionQueueFull, ArrivalWindow, BatchingPolicy, MicroBatcher,
    PredictRequest, SchedulerPolicy, ServeRequest, SLOClass,
)
from .pipeline import (
    PipelineConfig, SpoolResultSink, pack_scheduled, predict_pipelined,
    predict_synchronous, request_chunk_bounds, run_chunk_stream, tuned_config,
)
from .router import (
    ReplicaRouter, RouterStats, rendezvous_rank, request_shape_signature,
)
from .scheduler import ContinuousScheduler, ScheduledChunk
from .server import GPServer, GPServerConfig, ServeResult
from .telemetry import RequestTrace, ServerStats

__all__ = [
    "AdmissionQueueFull", "ArrivalWindow", "BatchingPolicy", "MicroBatcher",
    "PredictRequest", "SchedulerPolicy", "ServeRequest", "SLOClass",
    "PipelineConfig", "SpoolResultSink", "pack_scheduled",
    "predict_pipelined", "predict_synchronous", "request_chunk_bounds",
    "run_chunk_stream", "tuned_config",
    "ReplicaRouter", "RouterStats", "rendezvous_rank",
    "request_shape_signature",
    "ContinuousScheduler", "ScheduledChunk",
    "GPServer", "GPServerConfig", "ServeResult",
    "RequestTrace", "ServerStats",
]
