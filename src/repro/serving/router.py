"""Multi-replica prediction routing with compile-cache affinity.

One ``GPServer`` owns one jit cache's worth of compiled predict programs.
With N replicas (threads locally, rank processes across hosts), spraying
requests uniformly makes EVERY replica compile EVERY shape the traffic
contains — N copies of every compile, and a cold cache on whichever
replica a request lands. The GPU-Vecchia throughput studies (arXiv
2407.02740, 2410.04477) put batched-kernel shape reuse at the top of the
prediction cost profile, and ``ServerStats.compiled_shapes`` already
tracks exactly that signal per replica — so the router closes the loop:

* **shape signature** — ``request_shape_signature`` maps a request's row
  count through the SAME stepping the serving stack uses
  (``request_chunk_bounds`` + the ``pad_shapes`` block rounding +
  ``bucket_mults`` quantization) into the ``(bc, bs, m, tier)`` key
  space ``ServerStats.compiled_shapes`` records. Equal-size requests
  under one config share a signature by construction, so they share all
  realized compile keys.
* **rendezvous hashing** — each signature scores every replica with a
  keyed blake2b digest and prefers the max (highest-random-weight
  hashing): deterministic, coordination-free, and stable — removing a
  replica only remaps the signatures it owned. Python's salted
  ``hash()`` is deliberately NOT used (routing must agree across
  processes and runs).
* **least-outstanding-work spill** — affinity is a preference, not a
  pin: when the preferred replica's outstanding work (queued + admitted
  unfinished points, ``GPServer.outstanding_points``) exceeds
  ``spill_points``, or its bounded admission queue rejects the submit
  (``AdmissionQueueFull``), the request spills to the least-loaded
  replica. Steady-state traffic hits warm caches; bursts still balance.

Parity contract: replicas must run the continuous scheduler
(``GPServerConfig.scheduler`` set) with identical pipeline configs and
seeds — scheduler mode packs every request with the base seed, so ANY
replica returns exactly the lone ``predict_sbv(..., seed=config.seed)``
answer and routing can never change a result (<= 1e-12, gated). Drain
mode's per-batch seeds break that, so drain-mode replicas are refused.
"""
from __future__ import annotations

import hashlib
import threading

import numpy as np

from .batching import AdmissionQueueFull
from .pipeline import PipelineConfig, request_chunk_bounds


def _signature_tier(cfg: PipelineConfig) -> str:
    from repro.core.buckets import dtype_tier

    return cfg.precision or dtype_tier(cfg.dtype)


def request_shape_signature(n: int, cfg: PipelineConfig) -> tuple:
    """The compile-key profile of an ``n``-row request under ``cfg``.

    Chunk stepping follows ``request_chunk_bounds`` exactly; each chunk
    contributes the ``(bc, bs, m, tier)`` key of its uniform padded
    layout (block count rounded to 8 under the chunked ``pad_shapes``
    protocol, mirroring ``pack_queries``). Bucketed configs append the
    ``bucket_mults`` quantization the chunk split applies, since it
    reshapes the realized per-bucket keys. Two requests with equal
    signatures realize identical compile-cache keys — the affinity
    invariant the router routes on. (Bucketed realized keys also depend
    on the data's block-size skew, but that skew is a function of the
    chunk profile + config, both of which the signature pins.)
    """
    from repro.core.packing import round_up

    tier = _signature_tier(cfg)
    keys = set()
    for start, stop in request_chunk_bounds(n, cfg.chunk_size, cfg.bs_pred):
        bc = max(1, (stop - start) // cfg.bs_pred)
        if cfg.chunk_size is not None:
            bc = round_up(bc, 8)
        keys.add((bc, cfg.bs_pred, cfg.m_pred, tier))
    sig = tuple(sorted(keys))
    if cfg.n_buckets:
        from repro.core.buckets import bucket_mults

        bs_mult, m_mult = (max(v, 8)
                           for v in bucket_mults(cfg.backend,
                                                 precision=cfg.precision))
        sig = sig + (("buckets", cfg.n_buckets, bs_mult, m_mult),)
    return sig


def rendezvous_rank(signature, n_replicas: int, salt: int = 0) -> int:
    """Highest-random-weight owner of ``signature`` among ``n_replicas``.

    Deterministic across processes and runs (keyed blake2b, never the
    salted builtin ``hash``); removing a replica only remaps signatures
    it owned. Also used by the multi-host serve plane to partition a
    request stream across ranks with zero coordination."""
    if n_replicas <= 0:
        raise ValueError("need at least one replica")
    sig = repr(signature).encode()
    best, best_score = 0, b""
    for r in range(n_replicas):
        score = hashlib.blake2b(
            sig, digest_size=8, key=f"{salt}|{r}".encode()
        ).digest()
        if score > best_score:
            best, best_score = r, score
    return best


class RouterStats:
    """Thread-safe routing counters (the tentpole's telemetry surface):
    per-replica request/point totals, affinity hit-rate (requests landing
    on their rendezvous-preferred replica) and spill rate."""

    def __init__(self, n_replicas: int):
        self._lock = threading.Lock()
        self.n_replicas = int(n_replicas)
        self.n_requests = 0
        self.n_points = 0
        self.affinity_hits = 0
        self.n_spilled = 0
        self.replica_requests = [0] * self.n_replicas
        self.replica_points = [0] * self.n_replicas
        self.replica_spills = [0] * self.n_replicas  # spilled ONTO replica

    def record(self, replica: int, preferred: int, n_points: int,
               spilled: bool) -> None:
        with self._lock:
            self.n_requests += 1
            self.n_points += int(n_points)
            self.replica_requests[replica] += 1
            self.replica_points[replica] += int(n_points)
            if replica == preferred:
                self.affinity_hits += 1
            if spilled:
                self.n_spilled += 1
                self.replica_spills[replica] += 1

    def summary(self) -> dict:
        with self._lock:
            n = max(self.n_requests, 1)
            return {
                "n_replicas": self.n_replicas,
                "n_requests": self.n_requests,
                "n_points": self.n_points,
                "affinity_hits": self.affinity_hits,
                "affinity_hit_rate": self.affinity_hits / n,
                "n_spilled": self.n_spilled,
                "spill_rate": self.n_spilled / n,
                "replica_requests": list(self.replica_requests),
                "replica_points": list(self.replica_points),
                "replica_spills": list(self.replica_spills),
            }


class ReplicaRouter:
    """Front N ``GPServer`` replicas behind the one-server API.

    ``submit()/flush()/stop()`` mirror ``GPServer``; routing policy:

    * ``"affinity"`` (default) — rendezvous-preferred replica, with
      least-outstanding-work spill past ``spill_points`` or on
      ``AdmissionQueueFull``;
    * ``"random"`` — seeded uniform choice (the recompile-ratio
      baseline the CI gate compares affinity against);
    * ``"round_robin"`` — strict rotation.

    Replicas must be scheduler-mode servers sharing one pipeline config
    and seed (checked at construction — the per-request parity
    contract). Local replicas are threads over one process jit cache;
    ``compiled_shapes`` per replica is then the shapes each replica's
    traffic TOUCHED — the honest per-cache proxy for the rank-process
    deployment, where each replica really owns a cache.
    """

    def __init__(self, replicas, routing: str = "affinity",
                 spill_points: int | None = None, seed: int = 0):
        if not replicas:
            raise ValueError("need at least one replica")
        if routing not in ("affinity", "random", "round_robin"):
            raise ValueError(f"unknown routing policy {routing!r}")
        for i, rep in enumerate(replicas):
            rcfg = getattr(rep, "config", None)
            if rcfg is not None and rcfg.scheduler is None:
                raise ValueError(
                    f"replica {i} runs the drain-mode loop; routing "
                    "requires scheduler-mode replicas (drain mode's "
                    "per-batch seeds break the per-request parity "
                    "contract — set GPServerConfig.scheduler)"
                )
        self._check_uniform(replicas)
        self.replicas = list(replicas)
        self.routing = routing
        self.spill_points = spill_points
        self.seed = int(seed)
        self.stats = RouterStats(len(replicas))
        self._cfg = self._pipeline_cfg(replicas[0])
        self._lock = threading.Lock()
        self._rr = 0
        self._rng = np.random.default_rng(seed)

    @staticmethod
    def _pipeline_cfg(replica) -> PipelineConfig:
        rcfg = getattr(replica, "config", None)
        return rcfg.pipeline if rcfg is not None else PipelineConfig()

    @staticmethod
    def _check_uniform(replicas) -> None:
        def key(rep):
            rcfg = getattr(rep, "config", None)
            if rcfg is None:
                return None
            p = rcfg.pipeline
            return (rcfg.seed, p.chunk_size, p.bs_pred, p.m_pred, p.nu,
                    p.alpha, p.backend, np.dtype(p.dtype).name, p.n_buckets,
                    p.precision)

        keys = {key(rep) for rep in replicas} - {None}
        if len(keys) > 1:
            raise ValueError(
                "replicas disagree on pipeline config/seed "
                f"({sorted(map(str, keys))}); identical configs are the "
                "routing-independence (parity) contract"
            )

    # -- lifecycle (fan out to every replica) --------------------------

    def start(self) -> "ReplicaRouter":
        for rep in self.replicas:
            rep.start()
        return self

    def stop(self, timeout_s: float = 120.0) -> None:
        errs = []
        for rep in self.replicas:
            try:
                rep.stop(timeout_s=timeout_s)
            except Exception as exc:  # keep stopping the rest
                errs.append(exc)
        if errs:
            raise errs[0]

    def __enter__(self) -> "ReplicaRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def flush(self) -> None:
        for rep in self.replicas:
            rep.flush()

    def warmup(self, n_points: int | None = None):
        """Warm every replica's cache (one synthetic batch each)."""
        return [rep.warmup(n_points) for rep in self.replicas]

    # -- routing -------------------------------------------------------

    def preferred_replica(self, n: int) -> int:
        """The rendezvous owner of an ``n``-row request (affinity target
        before any spill) — exposed for tests and telemetry."""
        return rendezvous_rank(request_shape_signature(n, self._cfg),
                               len(self.replicas), salt=self.seed)

    def _outstanding(self, i: int) -> int:
        return int(getattr(self.replicas[i], "outstanding_points", 0))

    def _least_outstanding(self, exclude=()) -> int:
        candidates = [i for i in range(len(self.replicas))
                      if i not in exclude]
        return min(candidates, key=lambda i: (self._outstanding(i), i))

    def submit(self, x, slo: str = "interactive", outputs=None):
        """Route one predict request; returns the replica's Future.

        Raises ``AdmissionQueueFull`` only when EVERY replica rejects."""
        n = int(np.asarray(x).shape[0]) if np.asarray(x).ndim > 1 else 1
        pref = self.preferred_replica(n)
        if self.routing == "random":
            target = int(self._rng.integers(len(self.replicas)))
        elif self.routing == "round_robin":
            with self._lock:
                target = self._rr % len(self.replicas)
                self._rr += 1
        else:
            target = pref
            if (self.spill_points is not None
                    and self._outstanding(pref) > self.spill_points):
                spill_to = self._least_outstanding()
                if self._outstanding(spill_to) < self._outstanding(pref):
                    target = spill_to
        tried = []
        while True:
            try:
                fut = self.replicas[target].submit(x, slo=slo,
                                                   outputs=outputs)
                break
            except AdmissionQueueFull:
                tried.append(target)
                if len(tried) == len(self.replicas):
                    raise
                target = self._least_outstanding(exclude=tried)
        self.stats.record(target, pref, n,
                          spilled=(self.routing == "affinity"
                                   and target != pref))
        return fut

    # -- telemetry -----------------------------------------------------

    def summary(self) -> dict:
        """Routing counters + per-replica server telemetry: qps, compile
        keys seen (the recompile count under process replicas), queue
        gauges — the ``serve gp --replicas`` report."""
        out = self.stats.summary()
        per = []
        for i, rep in enumerate(self.replicas):
            stats = getattr(rep, "stats", None)
            s = stats.summary() if stats is not None else {}
            per.append({
                "replica": i,
                "n_requests": s.get("n_requests", 0),
                "n_points": s.get("n_points", 0),
                "points_per_s": s.get("points_per_s", 0.0),
                "n_compiled_shapes": s.get("n_compiled_shapes", 0),
                "queue_depth_peak": s.get("queue_depth_peak", 0),
            })
        out["replicas"] = per
        out["total_compiled_shapes"] = sum(r["n_compiled_shapes"]
                                           for r in per)
        return out
