"""Persistent batched GP serving process (the paper's throughput story,
made a long-running service instead of a one-shot CLI loop).

``GPServer`` owns the train-side state exactly once —

* the ``TrainIndex`` (scaled inputs, coarse blocks, cached flat block
  index for the filtered kNN), and
* the compiled predict program (the jit cache of
  ``batched_block_predict`` / the fused Pallas kernel),

then serves asynchronous predict requests of arbitrary size forever:
requests are coalesced into fixed-shape padded micro-batches by the
max-size/max-wait policy (``batching.py``) and each micro-batch streams
through the double-buffered chunk pipeline (``pipeline.py``), so host
packing of chunk k+1 overlaps device compute of chunk k.

Shape stability: chunked packing rounds (bc, bs) to multiples of 8 and
the ``pallas_tiled`` backend rounds (bs, m) to the native 8x128 f32 tile
inside the jit, so steady-state traffic hits a handful of compile-cache
keys no matter how request sizes vary (``stats()['n_compiled_shapes']``).

Bucketed micro-batches: with ``PipelineConfig(n_buckets=K)`` each chunk
executes as size-buckets padded only to their own ceilings
(docs/packing.md) instead of one uniformly-padded batch; the padding
waste saved is reported as ``stats()['padding_occupancy']`` (true FLOPs
over padded FLOPs — 1.0 means no waste).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.kernels_math import KernelParams
from repro.core.predict import TrainIndex, build_train_index

from .batching import (
    BatchingPolicy, MicroBatcher, PredictRequest, SchedulerPolicy,
    ServeRequest, concat_requests,
)
from .pipeline import (
    PipelineConfig, n_outputs_of, pack_scheduled, predict_pipelined,
    predict_synchronous, run_chunk_stream,
)
from .scheduler import ContinuousScheduler
from .telemetry import ServerStats, now


def _mask_outputs(arr, outputs, copy: bool = True):
    """Gather a request's output columns from a full-output result array.

    ``outputs=None`` (or a 1-D single-output array) passes through; a
    fancy-index gather copies by construction, so ``copy`` only governs
    the pass-through path (the drain loop hands out slices of a shared
    batch buffer and must copy; scheduler entries own their buffers)."""
    if arr is None:
        return None
    if outputs is not None and arr.ndim == 2:
        return arr[:, outputs]
    return arr.copy() if copy else arr


@dataclass
class ServeResult:
    """Per-request result. In-RAM requests carry ``mean``/``var``; bulk
    requests routed through the out-of-core sink carry ``sink`` instead
    (a ``SpoolResultSink`` — ``iter_chunks()`` for bounded-memory reads,
    ``materialize()`` to assemble in RAM after all)."""

    mean: np.ndarray | None
    var: np.ndarray | None
    latency_s: float
    queue_wait_s: float
    sink: object = None


@dataclass
class GPServerConfig:
    """Everything the server needs beyond the fitted kernel parameters.

    ``scheduler=None`` keeps the original drain-and-rebatch loop
    (micro-batches coalesced by concatenation — the benchmark baseline);
    a ``SchedulerPolicy`` switches dispatch to the continuous-batching
    scheduler (``scheduler.py``): per-request chunking, SLO-aware
    admission at every chunk boundary, cancellation, backpressure."""

    pipeline: PipelineConfig = field(default_factory=PipelineConfig)
    policy: BatchingPolicy = field(default_factory=BatchingPolicy)
    scheduler: SchedulerPolicy | None = None
    pipelined: bool = True    # False = synchronous chunk loop (baseline)
    seed: int = 0


class GPServer:
    """Persistent micro-batching SBV prediction server.

    Usage::

        server = GPServer(params, x_train, y_train, config)
        with server:                       # starts the dispatch thread
            fut = server.submit(x_query)   # returns concurrent.futures.Future
            res = fut.result()             # ServeResult(mean, var, latency)

    Requests submitted within one batching window are coalesced; because
    coalescing just concatenates query arrays before the shared packed
    pipeline, per-request results equal the matching slices of a single
    ``predict_sbv`` call on the concatenation.
    """

    def __init__(
        self,
        params: KernelParams,
        x_train: np.ndarray,
        y_train: np.ndarray,
        config: GPServerConfig | None = None,
        beta_struct: np.ndarray | None = None,
        mesh=None,
        index: TrainIndex | None = None,
    ):
        self.params = params
        self.config = config or GPServerConfig()
        self.mesh = mesh
        self.stats = ServerStats()
        beta = np.asarray(params.beta if beta_struct is None else beta_struct)
        cfg = self.config.pipeline
        if index is not None:
            # Prebuilt index (must match m_pred/seed): lets several server
            # configurations share one construction pass.
            self.index = index
        else:
            self.index = build_train_index(
                x_train, y_train, beta, cfg.m_pred,
                n_workers=cfg.n_workers, seed=self.config.seed,
                stream_chunk=cfg.stream_chunk,
            )
        self.d = self.index.x.shape[1]
        self.n_outputs = n_outputs_of(params)
        self._batcher = MicroBatcher(self.config.policy)
        self._sched: ContinuousScheduler | None = None
        self._thread: threading.Thread | None = None
        self._n_batches = 0

    def _make_scheduler(self) -> ContinuousScheduler:
        cfg = self.config.pipeline
        return ContinuousScheduler(
            policy=self.config.scheduler,
            window=self.config.policy,
            chunk_size=cfg.chunk_size,
            bs_pred=cfg.bs_pred,
            stats=self.stats,
            result_factory=self._make_result,
            n_outputs=self.n_outputs,
        )

    # -- lifecycle -----------------------------------------------------

    def start(self) -> "GPServer":
        if self._thread is not None:
            return self
        if self.config.scheduler is not None:
            if self._sched is None or self._sched.closed:  # fresh after stop()
                self._sched = self._make_scheduler()
            target = self._continuous_loop
        else:
            if self._batcher.closed:  # restart after stop(): fresh batcher
                self._batcher = MicroBatcher(self.config.policy)
            target = self._dispatch_loop
        self._thread = threading.Thread(
            target=target, name="gp-server", daemon=True
        )
        self._thread.start()
        return self

    def _fail_pending(self, message: str) -> None:
        source = self._sched if self._sched is not None else self._batcher
        for req in source.drain_pending():
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(RuntimeError(message))

    def stop(self, timeout_s: float = 120.0) -> None:
        """Drain pending requests, then stop the dispatch thread.

        Raises ``TimeoutError`` if the dispatch thread is still processing
        after ``timeout_s`` (the server is NOT stopped in that case) — but
        only AFTER failing still-queued futures, so no client blocks
        forever on a request the wedged dispatcher will never pick up."""
        if self._thread is None:
            return
        source = self._sched if self._sched is not None else self._batcher
        source.close()
        self._thread.join(timeout=timeout_s)
        if self._thread.is_alive():
            self._fail_pending(
                f"server stop timed out after {timeout_s}s; request abandoned"
            )
            raise TimeoutError(
                f"gp-server dispatch thread still busy after {timeout_s}s"
            )
        self._thread = None
        self._fail_pending("server stopped")

    def __enter__(self) -> "GPServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request path --------------------------------------------------

    def _norm_outputs(self, outputs) -> np.ndarray | None:
        """Validate an output-index mask against the model's output count.

        ``None`` means all outputs. A mask that selects every output in
        order collapses back to ``None`` (no column gather on the result
        path — keeps single-output requests bitwise untouched)."""
        if outputs is None:
            return None
        out = np.atleast_1d(np.asarray(outputs, dtype=np.intp))
        if out.ndim != 1 or out.size == 0:
            raise ValueError("outputs must be a non-empty 1-D index list")
        if out.min() < 0 or out.max() >= self.n_outputs:
            raise ValueError(
                f"output indices must lie in [0, {self.n_outputs}); "
                f"got {outputs!r}"
            )
        if out.size == self.n_outputs and np.array_equal(
                out, np.arange(self.n_outputs)):
            return None
        return out

    def submit(self, x: np.ndarray, slo: str = "interactive",
               outputs=None) -> Future:
        """Enqueue a predict request; resolves to a ``ServeResult``.

        ``slo`` picks the request's service class in continuous-scheduler
        mode (``SchedulerPolicy.classes``; default classes are
        ``interactive`` and ``bulk``) and is ignored in drain mode. May
        raise ``AdmissionQueueFull`` under backpressure.

        ``outputs`` (multi-output models only) is an output-index mask:
        the result's mean/var carry just those columns, in the order
        given. Compute is unaffected — the shared Cholesky already pays
        for all p outputs (docs/multioutput.md), so the server computes
        everything and slices per request. Spool-backed bulk results
        (``ServeResult.sink``) always carry all outputs."""
        if self._thread is None:
            raise RuntimeError("GPServer.submit before start()")
        x = np.array(x, dtype=np.float64, copy=True)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self.d:
            raise ValueError(f"expected (n, {self.d}) queries, got {x.shape}")
        out = self._norm_outputs(outputs)
        if self._sched is not None:
            req = ServeRequest(x=x, future=Future(), outputs=out, slo=slo)
            self._sched.submit(req)
        else:
            req = PredictRequest(x=x, future=Future(), outputs=out)
            self._batcher.put(req)
        return req.future

    @property
    def outstanding_points(self) -> int:
        """Queued + admitted-but-unfinished query points on this server —
        the router's least-outstanding-work signal. Drain mode has no
        per-chunk accounting; it reports 0 (the router refuses drain-mode
        replicas anyway — see ``serving/router.py``)."""
        if self._sched is not None:
            return self._sched.outstanding_points
        return 0

    def cancel(self, future: Future) -> bool:
        """Cancel an in-flight request; effective at the next chunk
        boundary in scheduler mode (queued-or-running both work), queued
        requests only in drain mode. Returns True if the cancellation
        was accepted."""
        if self._sched is not None:
            return self._sched.cancel(future)
        return future.cancel()

    def predict(self, x: np.ndarray, timeout_s: float | None = None) -> ServeResult:
        """Synchronous convenience: submit + wait."""
        return self.submit(x).result(timeout=timeout_s)

    def flush(self) -> None:
        """Dispatch whatever is queued without waiting out the batch window."""
        if self._sched is not None:
            self._sched.flush()
        else:
            self._batcher.flush()

    def warmup(self, n_points: int | None = None) -> ServeResult:
        """Push one synthetic batch through to populate the jit cache before
        real traffic arrives (first-compile cost off the critical path)."""
        n = n_points or max(self.config.pipeline.bs_pred * 8, 64)
        rng = np.random.default_rng(self.config.seed + 17)
        if self.index.store is not None:
            # Store-backed index: bounding box from a bounded row probe
            # instead of a full scan (warmup only needs plausible inputs).
            probe, _ = self.index.store.read_slice(
                0, min(4096, self.index.store.n_rows))
            lo, hi = probe.min(axis=0), probe.max(axis=0)
        else:
            lo = self.index.x.min(axis=0)
            hi = self.index.x.max(axis=0)
        x = lo + (hi - lo) * rng.uniform(size=(n, self.d))
        fut = self.submit(x)
        self.flush()
        return fut.result()

    # -- dispatch ------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            batch = self._batcher.next_batch()
            if batch:
                try:
                    self._process(batch)
                except BaseException as exc:
                    # _process resolves per-request failures itself; anything
                    # escaping here must not kill the sole dispatch thread.
                    for req in batch:
                        if not req.future.done():
                            req.future.set_exception(exc)
            elif self._batcher.closed:
                return

    def _process(self, batch: list[PredictRequest]) -> None:
        t_dispatch = now()
        # Claim each future; drop requests whose client cancelled while
        # queued (set_result on a cancelled future raises InvalidStateError).
        batch = [req for req in batch
                 if req.future.set_running_or_notify_cancel()]
        if not batch:
            return
        for req in batch:
            req.trace.t_dispatch = t_dispatch
        x, slices = concat_requests(batch)
        self.stats.record_batch(len(batch), x.shape[0])
        # Deterministic per-batch seed, equal to the base seed for the first
        # batch so a fresh server reproduces predict_sbv exactly.
        seed = self.config.seed + 100003 * self._n_batches
        self._n_batches += 1
        runner = predict_pipelined if self.config.pipelined else predict_synchronous
        try:
            mean, var = runner(
                self.params, self.index, x, self.config.pipeline,
                seed=seed, mesh=self.mesh, stats=self.stats,
            )
        except BaseException as exc:
            for req in batch:
                req.future.set_exception(exc)
            return
        t_done = now()
        for req, sl in zip(batch, slices):
            req.trace.t_done = t_done
            self.stats.record_request(req.trace)
            req.future.set_result(ServeResult(
                mean=_mask_outputs(mean[sl], req.outputs),
                var=_mask_outputs(var[sl], req.outputs),
                latency_s=req.trace.latency_s,
                queue_wait_s=req.trace.queue_wait_s,
            ))

    # -- continuous-batching dispatch (config.scheduler set) -----------

    def _make_result(self, entry) -> ServeResult:
        trace = entry.req.trace
        out = entry.req.outputs
        mean, var = ((None, None) if entry.sink is not None
                     else (_mask_outputs(entry.mean, out, copy=False),
                           _mask_outputs(entry.var, out, copy=False)))
        return ServeResult(
            mean=mean, var=var,
            latency_s=trace.latency_s, queue_wait_s=trace.queue_wait_s,
            sink=entry.sink,
        )

    def _continuous_loop(self) -> None:
        """Drive the double-buffered engine from the scheduler: each pull
        of the jobs generator is a chunk boundary (admission + reap +
        weighted-fair pick); each emit lands one chunk back into its
        request. All requests pack with the SAME base seed, so every
        request reproduces ``predict_sbv(..., seed=config.seed)`` exactly
        regardless of when it was admitted."""
        sched = self._sched
        cfg = self.config.pipeline
        seed = self.config.seed

        def jobs():
            while True:
                item = sched.next_chunk(idle_timeout_s=0.05)
                if item is not None:
                    yield item, (lambda it=item: pack_scheduled(
                        self.index, cfg, it, seed=seed))
                elif sched.closed:
                    return
                else:
                    # Idle barrier: land the delayed in-flight chunk so a
                    # burst's LAST chunk resolves now, not at the next
                    # arrival (run_chunk_stream emits one chunk late).
                    yield None, None

        try:
            run_chunk_stream(self.params, cfg, jobs(),
                             sched.complete_chunk, mesh=self.mesh,
                             stats=self.stats)
        except BaseException as exc:
            # The engine died (producer pack error surfaces here too):
            # no future may be left hanging on a loop that exited.
            sched.fail_all(exc)
