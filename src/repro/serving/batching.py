"""Request micro-batching for the persistent GP server.

The workload shape (VPPE-style emulation: many concurrent small queries)
wants the opposite of the one-shot CLI loop: requests of arbitrary size
arrive asynchronously and must be coalesced into device-efficient
micro-batches without letting any single request wait unboundedly.

Policy (the classic max-size/max-wait pair):

* a batch DISPATCHES as soon as it holds >= ``max_points`` query points
  (enough to fill the packed device program), and
* a non-empty batch never waits longer than ``max_wait_s`` after its
  first request arrived (latency bound under light load).

Coalesced requests are concatenated into one query array; the packed
prediction pipeline then sees a single test set, so micro-batched results
are IDENTICAL to a single ``predict_sbv`` call on the concatenation (the
equivalence the serving tests pin down).
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from .telemetry import RequestTrace, now


@dataclass
class BatchingPolicy:
    """Dispatch thresholds for the micro-batcher.

    With ``adaptive=True`` the wait window scales with observed traffic:
    the batcher tracks an EMA of request inter-arrival times and waits
    ``window_factor * ema`` (clamped to ``[0, max_wait_s]``) — under
    heavy traffic the window stays wide enough to coalesce the next few
    arrivals, while sparse traffic (expected gap beyond the cap) stops
    paying the full ``max_wait_s`` latency tax for a coalescing partner
    that is not coming."""

    max_points: int = 4096     # dispatch once this many points are queued
    max_wait_s: float = 0.010  # ... or this long after the first request
    max_requests: int = 1024   # hard cap on requests per batch
    adaptive: bool = False     # scale the wait window from arrival rate
    window_factor: float = 4.0 # target ~this many further arrivals/window
    ema_alpha: float = 0.2     # EMA weight of the newest inter-arrival gap


class ArrivalWindow:
    """Inter-arrival EMA -> the batching window currently in force.

    The adaptive-window machinery shared by the drain-mode ``MicroBatcher``
    and the continuous scheduler's idle-admission gate (scheduler.py): both
    observe arrivals on an injectable clock and derive the same
    ``clamp(window_factor * ema, [0, max_wait_s])`` window, so the two
    admission paths cannot drift and both stay deterministic under a fake
    clock."""

    def __init__(self, policy: BatchingPolicy, clock=now):
        self.policy = policy
        self._clock = clock
        self._lock = threading.Lock()
        self._last_arrival: float | None = None
        self._ema_gap_s: float | None = None

    def observe(self) -> float:
        """Stamp one arrival; returns the clock reading used."""
        t = self._clock()
        with self._lock:
            if self._last_arrival is not None:
                gap = max(t - self._last_arrival, 0.0)
                a = self.policy.ema_alpha
                self._ema_gap_s = (
                    gap if self._ema_gap_s is None
                    else (1.0 - a) * self._ema_gap_s + a * gap
                )
            self._last_arrival = t
        return t

    def effective_wait_s(self) -> float:
        """The batching window currently in force (see BatchingPolicy)."""
        pol = self.policy
        with self._lock:
            ema = self._ema_gap_s
        if not pol.adaptive or ema is None:
            return pol.max_wait_s
        return min(pol.max_wait_s, max(0.0, pol.window_factor * ema))


# -- SLO-aware scheduling policy (consumed by scheduler.py) ----------------


@dataclass(frozen=True)
class SLOClass:
    """One service class of the continuous scheduler.

    ``priority`` breaks virtual-time ties (lower wins — an interactive
    arrival lands at the running batch's virtual time and therefore
    preempts queued bulk work at the next chunk boundary); ``weight`` is
    the weighted-fair share of chunk boundaries when several classes are
    backlogged, which is what keeps bulk work starvation-free."""

    name: str
    priority: int
    weight: float


INTERACTIVE = SLOClass("interactive", priority=0, weight=3.0)
BULK = SLOClass("bulk", priority=1, weight=1.0)


def default_slo_classes() -> dict[str, SLOClass]:
    return {c.name: c for c in (INTERACTIVE, BULK)}


class AdmissionQueueFull(RuntimeError):
    """Backpressure: the bounded admission queue cannot take this request."""


@dataclass
class SchedulerPolicy:
    """Continuous-batching scheduler knobs (docs/serving.md).

    ``queue_bound`` bounds the admission queue in query POINTS — a submit
    that would exceed it raises ``AdmissionQueueFull`` so producers feel
    backpressure instead of growing host RAM. ``spool_threshold`` routes
    the results of any request at least that large to a disk-backed
    ``SpoolResultSink`` (pipeline.py), so a bulk sweep never holds its
    full mean/var in RAM server-side."""

    classes: dict[str, SLOClass] = field(default_factory=default_slo_classes)
    queue_bound: int | None = None       # max queued points (None = unbounded)
    max_active_requests: int = 64        # running-batch cap
    spool_threshold: int | None = None   # spool results of requests >= this
    spool_dir: str | None = None         # default: a fresh tempdir


@dataclass
class PredictRequest:
    """One in-flight request: a query array + the future holding its slice
    of the micro-batch result."""

    x: np.ndarray
    future: Future
    outputs: np.ndarray | None = None  # output-index mask (None = all outputs)
    trace: RequestTrace = field(init=False)
    t_arrival: float = field(init=False, default=0.0)  # batcher-clock stamp

    def __post_init__(self):
        self.trace = RequestTrace(n_points=self.x.shape[0])


@dataclass
class ServeRequest(PredictRequest):
    """A scheduler-mode request: carries its SLO class and cancel flag."""

    slo: str = "interactive"
    cancelled: bool = field(init=False, default=False)


class MicroBatcher:
    """Blocking queue + coalescing loop shared by the server's worker.

    ``put`` is called from request threads; ``next_batch`` is called by
    the single dispatch thread and returns a list of requests forming one
    micro-batch (or an empty list on timeout so the caller can check for
    shutdown). A ``flush`` wakes the dispatcher immediately.
    """

    _FLUSH = object()

    def __init__(self, policy: BatchingPolicy, clock=now):
        self.policy = policy
        self._q: queue.Queue = queue.Queue()
        self._closed = threading.Event()
        self._clock = clock            # injectable for deterministic tests
        self._window = ArrivalWindow(policy, clock=clock)

    def put(self, req: PredictRequest) -> None:
        if self._closed.is_set():
            raise RuntimeError("server is stopped")
        req.t_arrival = self._window.observe()
        self._q.put(req)

    @property
    def _ema_gap_s(self) -> float | None:
        return self._window._ema_gap_s

    def effective_wait_s(self) -> float:
        """The batching window currently in force (see BatchingPolicy)."""
        return self._window.effective_wait_s()

    def flush(self) -> None:
        """Force the dispatcher to emit whatever is queued right now."""
        self._q.put(self._FLUSH)

    def close(self) -> None:
        self._closed.set()
        self._q.put(self._FLUSH)  # wake the dispatcher

    def drain_pending(self) -> list[PredictRequest]:
        """Remove and return whatever is still queued (post-close cleanup:
        a ``put`` can race ``close`` and land after the dispatcher's final
        drain — the server fails these futures instead of stranding them)."""
        pending: list[PredictRequest] = []
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                return pending
            if item is not self._FLUSH:
                pending.append(item)

    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    def next_batch(self, idle_timeout_s: float = 0.05) -> list[PredictRequest]:
        """Coalesce queued requests into one micro-batch.

        Blocks up to ``idle_timeout_s`` for the first request; once one
        arrives, keeps accumulating until the policy's max_points /
        max_requests trip or max_wait_s elapses (or a flush arrives).

        Requests already sitting in the queue are ALWAYS drained (up to
        the size caps) regardless of the deadline: a backlog that built
        up while the previous batch was computing costs zero extra
        latency to coalesce, and waiting only applies when the queue has
        gone empty before the window closed.
        """
        pol = self.policy
        batch: list[PredictRequest] = []
        points = 0
        try:
            first = self._q.get(timeout=idle_timeout_s)
        except queue.Empty:
            return batch
        if first is self._FLUSH:
            return batch
        batch.append(first)
        points += first.x.shape[0]
        # Deadline math runs entirely on the batcher's clock (t_arrival is
        # stamped by put() with the same clock), so the adaptive window is
        # deterministically testable with a fake clock.
        deadline = first.t_arrival + self.effective_wait_s()

        while (points < pol.max_points and len(batch) < pol.max_requests
               and not self._closed.is_set()):
            try:
                nxt = self._q.get_nowait()   # drain backlog unconditionally
            except queue.Empty:
                remaining = deadline - self._clock()
                if remaining <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=remaining)
                except queue.Empty:
                    break
            if nxt is self._FLUSH:
                break
            batch.append(nxt)
            points += nxt.x.shape[0]
        return batch


def concat_requests(batch: list[PredictRequest]) -> tuple[np.ndarray, list[slice]]:
    """Stack request arrays into one query set + per-request result slices."""
    sizes = [req.x.shape[0] for req in batch]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    x = np.concatenate([req.x for req in batch], axis=0)
    slices = [slice(int(offsets[i]), int(offsets[i + 1])) for i in range(len(batch))]
    return x, slices
