"""Shape autotuner: measured candidate runs -> persistent TuningRecord.

The bucketed/mixed-precision execution layer has a handful of shape
knobs whose best settings depend on the dataset's block-size histogram
and the device (docs/packing.md, docs/precision.md): the bucket count K,
the per-bucket (bs, m) ceilings that K induces, the tile multiples, the
kernel backend, and the precision tier. Analytic work models
(``core.buckets.loglik_work``) rank candidates by padded FLOPs, but the
crossover points (kernel launch overhead vs padding waste, narrow-tier
assembly vs cast overhead) are device facts — so the autotuner MEASURES:
each candidate layout runs the real ``packed_loglik`` program a few
times on the actual device and the fastest wall-clock wins.

Probing cost is bounded: candidates run on a row subsample
(``sample_rows``) and each is a handful of likelihood evaluations, paid
once per (dataset, device) pairing — the whole point of persisting the
winner as a ``TuningRecord`` next to the checkpoint.
"""
from __future__ import annotations

import time

import numpy as np


def _size_stats(sizes: np.ndarray) -> dict:
    sizes = np.asarray(sizes, dtype=np.float64)
    if sizes.size == 0:
        return {"min": 0, "p50": 0, "max": 0, "mean": 0.0}
    return {
        "min": int(sizes.min()),
        "p50": int(np.median(sizes)),
        "max": int(sizes.max()),
        "mean": float(sizes.mean()),
    }


def _time_loglik(params, packed, nu, backend, repeats: int) -> float:
    """Best-of-N wall time of one likelihood evaluation (compile excluded:
    the first call warms jit; min-of-N suppresses scheduler noise the
    same way benchmarks/common.py's calibration does)."""
    import jax

    from repro.core.vecchia import packed_loglik

    jax.block_until_ready(packed_loglik(params, packed, nu=nu, backend=backend))
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(
            packed_loglik(params, packed, nu=nu, backend=backend))
        best = min(best, time.perf_counter() - t0)
    return best


def recommend_stream_chunk(n_rows: int, d: int, m: int, bs_avg: float,
                           tier: str = "f64", budget: int | None = None,
                           frac: float = 0.25) -> int | None:
    """Streaming rows-per-pass from the device byte budget.

    Inverts the ``working_set_model`` packed-chunk term: a chunk costs
    ~4x its packed bytes resident (host load + device transfer + arena
    slack), and a packed row carries its coordinates at the tier's
    storage width, its observation at the accumulation width, one mask
    byte, and an amortized ``m / bs_avg`` share of its block's neighbor
    rows. ``frac`` of the budget goes to the chunk window (the rest
    stays with the device spool cache + grad live set). Returns ``None``
    when the whole dataset fits inside one chunk — in-core execution is
    strictly better than streaming overhead then."""
    from repro.core.buckets import acc_dtype, storage_dtype

    if budget is None:
        from repro.data.streaming import device_cache_budget

        budget = device_cache_budget()
    st = np.dtype(storage_dtype(tier)).itemsize
    ac = np.dtype(acc_dtype(tier)).itemsize
    per_row = (d * st + ac + 1) * (1.0 + m / max(bs_avg, 1.0))
    chunk = int(frac * budget / (4.0 * per_row))
    chunk = max(4096, chunk)
    if chunk >= n_rows:
        return None
    return chunk


def autotune_loglik(
    x: np.ndarray,
    y: np.ndarray,
    cfg,
    params=None,
    nu: float = 3.5,
    backend: str = "auto",
    tiers=("bf16", "f32", "f64"),
    bucket_grid=(0, 2, 4, 8),
    error_budget: float | None = None,
    repeats: int = 3,
    sample_rows: int | None = 20000,
    save_dir: str | None = None,
    verbose: bool = False,
):
    """Measure the (K x tier) candidate grid and return the TuningRecord.

    ``bucket_grid`` entries are bucket levels K (0 = unbucketed uniform
    layout); ``tiers`` are precision-ladder candidates, each enforced by
    ``assign_precision`` probing before timing — a candidate is timed at
    the tiers it would ACTUALLY run, so an over-budget bf16 request is
    measured (and recorded) as its demoted mix, never as a fantasy
    configuration. ``sample_rows`` caps the measurement subsample
    (None = full dataset). ``save_dir`` persists the record
    (``tuning_record.json``) for ``fit_sbv(tuning=...)`` /
    ``predict_sbv(tuning=...)`` / ``serve gp --tuning-record``."""
    import jax

    from repro.core.buckets import (
        apply_precision, assign_precision, bucket_blocks, cast_packed,
        loglik_work, PrecisionPolicy, _true_sizes,
    )
    from repro.core.kernels_math import KernelParams
    from repro.core.pipeline import preprocess
    from repro.data.streaming import device_cache_budget
    from repro.kernels import ops as kops

    from .record import TuningRecord

    x = np.asarray(x)
    y = np.asarray(y)
    n_full, d = x.shape
    if sample_rows is not None and n_full > sample_rows:
        # Deterministic stride subsample keeps the spatial spread (and
        # therefore the block-size histogram's shape) intact.
        idx = np.linspace(0, n_full - 1, sample_rows).astype(np.int64)
        x_s, y_s = x[idx], y[idx]
    else:
        x_s, y_s = x, y
    if params is None:
        params = KernelParams.create(
            sigma2=float(np.var(y_s)), beta=0.5, nugget=1e-3, d=d)

    packed, _ = preprocess(x_s, y_s, np.asarray(params.beta), cfg)
    bs_true = _true_sizes(packed.blk_mask)
    m_true = _true_sizes(packed.nn_mask)
    histogram = {"bs": _size_stats(bs_true), "m": _size_stats(m_true)}

    candidates = []
    best = None
    for k in bucket_grid:
        layout = bucket_blocks(packed, n_buckets=k) if k else packed
        for tier in tiers:
            policy = PrecisionPolicy(tier=tier, error_budget=error_budget)
            assigned = assign_precision(params, layout, policy, nu=nu,
                                        backend=backend)
            if k:
                cast = apply_precision(layout, assigned)
                occ = cast.occupancy()
            else:
                cast = cast_packed(packed, assigned[0])
                true_f, padded_f = loglik_work([cast])
                occ = true_f / padded_f if padded_f else 1.0
            t = _time_loglik(params, cast, nu, backend, repeats)
            cand = {"n_buckets": k or None, "precision": tier,
                    "tiers": list(assigned), "time_s": t, "occupancy": occ}
            candidates.append(cand)
            if verbose:
                print(f"[autotune] K={k or '-'} tier={tier} -> "
                      f"{t * 1e3:.2f} ms occ={occ:.3f} tiers={assigned}")
            if best is None or t < best[0]:
                best = (t, k, tier, assigned, cast, occ)

    _, k_win, tier_win, tiers_win, cast_win, occ_win = best
    if k_win:
        bs_ceils = [int(pk.bs_max) for pk in cast_win.buckets]
        m_ceils = [int(pk.m) for pk in cast_win.buckets]
    else:
        bs_ceils = [int(packed.bs_max)]
        m_ceils = [int(packed.m)]

    # Predict-side tile multiples for the winning tier: the compiled
    # tiled predict kernel doubles the sublane tile on bf16 assembly.
    from repro.core.buckets import acc_dtype, bucket_mults, storage_dtype

    pred_backend = kops.select_backend(
        int(packed.bs_max), int(packed.m), kind="predict",
        dtype=storage_dtype(tier_win))
    bs_mult, m_mult = bucket_mults(pred_backend, precision=tier_win)

    acc_bytes = np.dtype(acc_dtype(tier_win)).itemsize
    reserve = 16 * 16 * (int(packed.bs_max) + int(packed.m)) ** 2 * acc_bytes
    budget = device_cache_budget(reserve_bytes=reserve)
    stream_chunk = recommend_stream_chunk(
        n_full, d, int(packed.m), float(max(bs_true.mean(), 1.0)),
        tier=tier_win, budget=budget)

    record = TuningRecord(
        n_buckets=k_win or None,
        bs_ceilings=bs_ceils,
        m_ceilings=m_ceils,
        bs_mult=int(bs_mult),
        m_mult=int(m_mult),
        backend=backend,
        precision=tier_win,
        bucket_tiers=list(tiers_win),
        error_budget=error_budget,
        stream_chunk=stream_chunk,
        device_cache_budget=int(budget),
        occupancy=float(occ_win),
        histogram=histogram,
        candidates=candidates,
        meta={
            "n_rows": int(n_full), "sampled_rows": int(x_s.shape[0]),
            "d": int(d), "m": int(packed.m), "bs_max": int(packed.bs_max),
            "nu": float(nu), "device": jax.default_backend(),
            "repeats": int(repeats),
        },
    )
    if save_dir is not None:
        record.save(save_dir)
    return record
