"""Persistent shape autotuner (docs/precision.md).

Measures short candidate runs over the observed block-size histogram and
device memory budget, picks the execution shape (bucket count/ceilings,
tile multiples, backend, precision tier, streaming chunk), and persists
the winner as a ``TuningRecord`` next to the checkpoint so later fits,
prediction, and serving start pre-tuned.
"""
from .autotune import autotune_loglik, recommend_stream_chunk
from .record import RECORD_VERSION, TuningRecord, as_record

__all__ = [
    "RECORD_VERSION",
    "TuningRecord",
    "as_record",
    "autotune_loglik",
    "recommend_stream_chunk",
]
