"""Persistent autotuner output: one JSON-serializable TuningRecord.

The autotuner (``repro.tuning.autotune``) measures short candidate runs
and distills the winner into a ``TuningRecord`` — the execution-shape
knobs that ``fit_sbv``, ``predict_sbv``, and the serving ``GPServer``
otherwise discover per process (bucket count and ceilings, tile
multiples, kernel backend, precision tier, streaming chunk size, device
cache budget). Persisting it next to the checkpoint
(``ckpt.save_tuning_record`` -> ``tuning_record.json``) lets every later
process start pre-tuned: reloading the record reproduces the autotuner's
choices without re-measuring (pinned in tests/test_ckpt.py).

The record keeps the evidence, not just the verdict: the observed
block-size histogram and the full measured candidate table ride along so
a reader (or a regression gate) can audit WHY a shape won.
"""
from __future__ import annotations

import os
from dataclasses import asdict, dataclass, field


RECORD_VERSION = 1


@dataclass
class TuningRecord:
    """Autotuned execution shape for one (dataset, device) pairing.

    All fields are JSON-plain so the record round-trips through
    ``ckpt.save_tuning_record`` byte-stably. ``None`` means "knob not
    tuned — keep the caller's default"."""

    version: int = RECORD_VERSION
    n_buckets: int | None = None          # K (bucket levels per dim); None = unbucketed
    bs_ceilings: list | None = None       # realized block-size bucket ceilings
    m_ceilings: list | None = None        # realized neighbor-count ceilings
    bs_mult: int = 1                      # tile multiple for bs ceilings
    m_mult: int = 1                       # tile multiple for m ceilings
    backend: str | None = None            # kernel backend ('auto' resolves per bucket)
    precision: str | None = None          # requested ladder tier (docs/precision.md)
    bucket_tiers: list | None = None      # probe-enforced per-bucket tiers at tune time
    error_budget: float | None = None     # PrecisionPolicy override, if any
    stream_chunk: int | None = None       # streaming rows per pass; None = in-core
    device_cache_budget: int | None = None  # spool device-tier bytes at tune time
    occupancy: float | None = None        # true/padded FLOP ratio of the winner
    histogram: dict | None = None         # observed {bs: {...}, m: {...}} size stats
    candidates: list = field(default_factory=list)  # measured candidate table
    meta: dict = field(default_factory=dict)        # n, d, device, timings...

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TuningRecord":
        known = {f for f in cls.__dataclass_fields__}
        rec = cls(**{k: v for k, v in d.items() if k in known})
        if rec.version > RECORD_VERSION:
            raise ValueError(
                f"tuning record version {rec.version} is newer than this "
                f"build understands ({RECORD_VERSION})")
        return rec

    def precision_policy(self):
        """The record's precision choice as a ``core.buckets.PrecisionPolicy``
        (probing stays ON so a drifted dataset still demotes safely)."""
        from repro.core.buckets import PrecisionPolicy

        return PrecisionPolicy(tier=self.precision or "f64",
                               error_budget=self.error_budget)

    # -- persistence ---------------------------------------------------
    def save(self, directory: str) -> str:
        """Write ``tuning_record.json`` into ``directory`` (atomic)."""
        from repro.ckpt import save_tuning_record

        return save_tuning_record(directory, self.to_dict())

    @classmethod
    def load(cls, directory: str) -> "TuningRecord | None":
        """Load from a checkpoint directory or a direct json path."""
        from repro.ckpt import load_tuning_record

        d = load_tuning_record(directory)
        return None if d is None else cls.from_dict(d)


def as_record(obj) -> TuningRecord:
    """Coerce a TuningRecord / dict / path into a ``TuningRecord``.

    A string is treated as a checkpoint directory or json path; a missing
    record there is an error (the caller explicitly asked to pre-tune)."""
    if isinstance(obj, TuningRecord):
        return obj
    if isinstance(obj, dict):
        return TuningRecord.from_dict(obj)
    if isinstance(obj, (str, os.PathLike)):
        rec = TuningRecord.load(os.fspath(obj))
        if rec is None:
            raise FileNotFoundError(f"no tuning record at {obj!r}")
        return rec
    raise TypeError(f"cannot interpret {type(obj).__name__} as a TuningRecord")
