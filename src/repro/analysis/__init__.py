from .hlo_analysis import (
    HW,
    CellReport,
    analyze_compiled,
    collective_bytes,
    model_flops,
    roofline,
)
