"""Roofline terms from a compiled dry-run artifact (no real hardware).

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on the
TARGET hardware (TPU v5e-class constants from the assignment):

    compute    = HLO_FLOPs_per_device   / peak_FLOPs          (197 TF bf16)
    memory     = HLO_bytes_per_device   / HBM_bw              (819 GB/s)
    collective = wire_bytes_per_device  / ICI_link_bw         (50 GB/s/link)

``compiled.cost_analysis()`` runs on the POST-SPMD module, so its flops /
bytes are already per-device. Collective bytes are parsed from the
optimized HLO text: XLA prints each collective's RESULT shape and replica
groups; per-device wire bytes use the standard ring model

    all-gather       (g-1)/g * result_bytes        (receives all but own shard)
    all-reduce       2 (g-1)/g * result_bytes      (reduce-scatter + all-gather)
    reduce-scatter   (g-1) * result_bytes          (operand = g * result)
    all-to-all       (g-1)/g * result_bytes
    collective-permute  result_bytes

The dominant term is the bottleneck the perf loop iterates on; the
"useful-compute" ratio MODEL_FLOPS / (flops_per_device * chips) catches
remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class HW:
    """Per-chip peak numbers (TPU v5e-class, from the assignment)."""

    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    ici_bw: float = 50e9            # bytes/s per link
    hbm_bytes: float = 16e9         # capacity (v5e 16 GB)


DEFAULT_HW = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# e.g.  %all-gather.3 = f32[4096,512]{1,0} all-gather(%x), ... replica_groups=[16,32]<=...
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Total bytes of one shape or tuple-of-shapes literal."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0]
        return max(1, first.count(",") + 1)
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device wire bytes by collective kind (ring model, see module doc)."""
    out = {k: 0.0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if op == "all-gather":
            wire = result_bytes * (g - 1) / g
        elif op == "all-reduce":
            wire = 2.0 * result_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = result_bytes * (g - 1)          # operand = g * result
        elif op == "all-to-all":
            wire = result_bytes * (g - 1) / g
        else:  # collective-permute
            wire = result_bytes
        out[op] += wire
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = counts
    return out


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs per step: 6*N*D (dense) / 6*N_active*D (MoE),
    N = non-embedding params, D = processed tokens. Decode steps process
    global_batch tokens; train processes batch*seq and costs 3x forward."""
    from repro.launch.param_count import active_param_count

    n_active = active_param_count(cfg)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    toks = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * toks


@dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_detail: dict
    peak_memory: float
    arg_bytes: float
    temp_bytes: float
    model_flops: float = 0.0
    extra: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / DEFAULT_HW.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / DEFAULT_HW.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / DEFAULT_HW.ici_bw

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_step(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        hlo_total = self.flops_per_dev * self.n_devices
        return self.model_flops / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS / (chips * peak * t_step): the MFU the compiled graph
        could reach if it hit the dominant roofline exactly."""
        denom = self.n_devices * DEFAULT_HW.peak_flops * self.t_step
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_detail": {k: v for k, v in self.coll_detail.items()},
            "peak_memory": self.peak_memory,
            "arg_bytes": self.arg_bytes,
            "temp_bytes": self.temp_bytes,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "t_step": self.t_step,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "extra": self.extra,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, mflops: float = 0.0) -> CellReport:
    """Derive the three roofline terms from the compiled artifact.

    FLOPs/bytes/collectives come from the trip-count-aware text cost model
    (repro.analysis.hlo_cost) because ``compiled.cost_analysis()`` counts
    while-loop bodies once; the XLA numbers are kept in ``extra`` for
    reference.
    """
    from repro.analysis.hlo_cost import CostModel

    txt = compiled.as_text()
    cm = CostModel(txt, n_devices=n_devices)
    flops = cm.flops()
    byts = cm.bytes_accessed()
    coll = cm.collective_bytes()
    cost = compiled.cost_analysis()
    try:
        ma = compiled.memory_analysis()
        peak = float(ma.peak_memory_in_bytes)
        argb = float(ma.argument_size_in_bytes)
        temp = float(ma.temp_size_in_bytes)
    except Exception:  # backend without memory analysis
        peak = argb = temp = float("nan")
    rep = CellReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=flops, bytes_per_dev=byts,
        coll_bytes_per_dev=coll["total"], coll_detail=coll,
        peak_memory=peak, arg_bytes=argb, temp_bytes=temp,
        model_flops=mflops,
    )
    rep.extra = {
        "xla_flops_once": float(cost.get("flops", 0.0)),
        "xla_bytes_once": float(cost.get("bytes accessed", 0.0)),
    }
    return rep


def roofline(report: CellReport) -> str:
    """One-paragraph summary line for EXPERIMENTS.md tables."""
    r = report
    return (
        f"{r.arch:>20s} {r.shape:>12s} {r.mesh:>9s} | "
        f"comp {r.t_compute*1e3:9.3f}ms  mem {r.t_memory*1e3:9.3f}ms  "
        f"coll {r.t_collective*1e3:9.3f}ms | {r.bottleneck:10s} | "
        f"useful {r.useful_ratio*100:5.1f}%  roofline-MFU {r.roofline_fraction*100:5.1f}%"
    )
