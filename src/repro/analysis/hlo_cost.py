"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, so a
scan-over-layers transformer is undercounted by ~L x (verified empirically:
flops of an 8-step scan == flops of a 1-step scan). This module re-derives
FLOPs / HBM bytes / collective wire bytes by parsing the post-SPMD HLO
text, building the computation call graph, and multiplying each
computation's costs by its loop trip count:

* while bodies/conditions: trip count = the integer constant in the loop
  condition computation (jax scans lower to 0..L counters; the max int
  constant in the condition is the bound);
* fusion interiors contribute FLOPs (elementwise work inside the fusion)
  but no HBM bytes (only the fusion's boundary operands/results move);
* dots: 2 * result_elems * contraction_size (operand shapes resolved from
  the per-computation symbol table);
* LAPACK custom-calls (the GP cells): potrf = B n^3/3, trsm = B n^2 k;
* collectives use the ring model (see hlo_analysis) x trip multiplier.

All counts are per-device: the text is the post-partitioning module.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLEE_RE = re.compile(r"(calls|condition|body|to_apply|true_computation|"
                        r"false_computation)=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"constant\((-?\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")
_CCTARGET_RE = re.compile(r'custom_call_target="([^"]+)"')

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exp", "log", "tanh", "sqrt", "rsqrt", "power",
    "and", "or", "xor", "not", "compare", "select", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "cosine", "sine",
    "logistic", "exponential-minus-one", "log-plus-one", "remainder",
    "atan2", "is-finite", "cbrt", "tan", "erf", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "stochastic-convert",
}
_ZERO_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "custom-call-start",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _bytes_of(text: str) -> float:
    return float(sum(_DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in _shapes(text)))


def _elems_of(text: str) -> float:
    return float(sum(math.prod(dims) for _, dims in _shapes(text)))


@dataclass
class Instr:
    name: str
    rtype: str        # result type text
    opcode: str
    operands: list[str]
    rest: str         # attribute tail of the line
    payload: str = "" # raw args text (constant values live here)


@dataclass
class Computation:
    name: str
    entry: bool = False
    instrs: list[Instr] = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # instr name -> rtype text


def parse_module(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = ""
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(name=m.group(2), entry=bool(m.group(1)))
                if cur.entry:
                    entry_name = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, tail = m.groups()
        depth = 0
        args_end = len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    args_end = i
                    break
                depth -= 1
        args = tail[:args_end]
        rest = tail[args_end + 1:]
        operands = re.findall(r"%([\w\.\-]+)", args)
        ins = Instr(name=name, rtype=rtype, opcode=opcode, operands=operands,
                    rest=rest, payload=args)
        cur.instrs.append(ins)
        cur.symbols[name] = rtype
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry_name


class CostModel:
    def __init__(self, text: str, n_devices: int = 1):
        self.comps, self.entry = parse_module(text)
        self.n_devices = n_devices
        self._mult = self._multipliers()

    # ----------------------------------------------------------- graph ----
    def _multipliers(self) -> dict:
        mult = defaultdict(float)
        mult[self.entry] = 1.0
        order = [self.entry]
        seen = {self.entry}
        # BFS over call graph
        i = 0
        while i < len(order):
            cname = order[i]
            i += 1
            comp = self.comps.get(cname)
            if comp is None:
                continue
            m = mult[cname]
            for ins in comp.instrs:
                callees = _CALLEE_RE.findall(ins.rest)
                branches = _BRANCHES_RE.search(ins.rest)
                factor = 1.0
                if ins.opcode == "while":
                    cond_name = dict(callees).get("condition")
                    cond = self.comps.get(cond_name)
                    factor = float(self._comp_const_bound(cond)) if cond else 1.0
                for kind, callee in callees:
                    f = factor if ins.opcode == "while" else 1.0
                    mult[callee] += m * f
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)
                if branches:
                    for bname in re.findall(r"%([\w\.\-]+)", branches.group(1)):
                        mult[bname] += m
                        if bname not in seen:
                            seen.add(bname)
                            order.append(bname)
        return mult

    def _comp_const_bound(self, comp: Computation) -> int:
        """Loop trip count = largest positive int constant in the condition
        computation (jax scan conditions compare a 0-based counter < L)."""
        vals = [1]
        for ins in comp.instrs:
            if ins.opcode == "constant":
                m = re.match(r"^\s*(-?\d+)\s*$", ins.payload)
                if m:
                    vals.append(int(m.group(1)))
        return max(vals)

    # --------------------------------------------------- fusion interior ----
    def _boundary(self) -> set:
        """Computations whose instructions MOVE HBM bytes (entry + loop
        bodies/conds + branches) — i.e. not fusion/reduce interiors."""
        interior = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                for kind, callee in _CALLEE_RE.findall(ins.rest):
                    if kind in ("calls", "to_apply"):
                        interior.add(callee)
        return set(self.comps) - interior

    # ------------------------------------------------------------ costs ----
    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = _elems_of(ins.rtype)
        m = _CONTRACT_RE.search(ins.rest)
        contraction = 1.0
        if m and ins.operands:
            lhs = comp.symbols.get(ins.operands[0], "")
            sh = _shapes(lhs)
            if sh:
                dims = sh[0][1]
                for di in m.group(1).split(","):
                    if di and int(di) < len(dims):
                        contraction *= dims[int(di)]
        return 2.0 * out_elems * contraction

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = _elems_of(ins.rtype)
        wm = _WINDOW_SIZE_RE.search(ins.rest)
        window = 1.0
        if wm:
            for t in wm.group(1).split("x"):
                window *= int(t)
        fgc = int(_FGC_RE.search(ins.rest).group(1)) if _FGC_RE.search(ins.rest) else 1
        in_feat = 1.0
        if ins.operands:
            sh = _shapes(comp.symbols.get(ins.operands[0], ""))
            if sh:
                # feature dim unknown without dim_labels; assume depthwise
                # unless fgc == 1 and input rank >= 3 (then use last dim).
                dims = sh[0][1]
                if fgc == 1 and len(dims) >= 3:
                    in_feat = dims[-1]
        return 2.0 * out_elems * window * (in_feat / fgc if fgc else 1.0)

    def _custom_call_flops(self, comp: Computation, ins: Instr) -> float:
        tgt = _CCTARGET_RE.search(ins.rest)
        t = tgt.group(1) if tgt else ""
        shapes = [_shapes(comp.symbols.get(o, "")) for o in ins.operands]
        if "potrf" in t and shapes and shapes[0]:
            dims = shapes[0][0][1]
            n = dims[-1]
            b = math.prod(dims[:-2]) if len(dims) > 2 else 1
            return b * n ** 3 / 3.0
        if "trsm" in t and len(shapes) >= 2 and shapes[0] and shapes[1]:
            a = shapes[0][0][1]
            bsh = shapes[1][0][1]
            n = a[-1]
            k = bsh[-1]
            b = math.prod(a[:-2]) if len(a) > 2 else 1
            return b * n * n * k
        if ("getrf" in t or "geqrf" in t) and shapes and shapes[0]:
            dims = shapes[0][0][1]
            n = dims[-1]
            b = math.prod(dims[:-2]) if len(dims) > 2 else 1
            return 2.0 * b * n ** 3 / 3.0
        return 0.0

    def flops(self) -> float:
        total = 0.0
        for comp in self.comps.values():
            m = self._mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.opcode == "dot":
                    total += m * self._dot_flops(comp, ins)
                elif ins.opcode == "convolution":
                    total += m * self._conv_flops(comp, ins)
                elif ins.opcode == "custom-call":
                    total += m * self._custom_call_flops(comp, ins)
                elif ins.opcode in _ELEMENTWISE:
                    total += m * _elems_of(ins.rtype)
                elif ins.opcode in ("reduce", "reduce-window"):
                    op_b = sum(_elems_of(comp.symbols.get(o, "")) for o in ins.operands[:1])
                    total += m * op_b
        return total

    def flops_split(self) -> dict:
        """{'mxu': dot/conv/solver flops, 'vpu': elementwise+reduce flops}."""
        mxu = vpu = 0.0
        for comp in self.comps.values():
            m = self._mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.opcode == "dot":
                    mxu += m * self._dot_flops(comp, ins)
                elif ins.opcode == "convolution":
                    mxu += m * self._conv_flops(comp, ins)
                elif ins.opcode == "custom-call":
                    mxu += m * self._custom_call_flops(comp, ins)
                elif ins.opcode in _ELEMENTWISE:
                    vpu += m * _elems_of(ins.rtype)
                elif ins.opcode in ("reduce", "reduce-window"):
                    vpu += m * sum(_elems_of(comp.symbols.get(o, ""))
                                   for o in ins.operands[:1])
        return {"mxu": mxu, "vpu": vpu}

    def top_bytes(self, k: int = 20) -> list:
        """Top-k (bytes x multiplier, opcode, instr, comp) — profiler view."""
        rows = []
        boundary = self._boundary()
        for cname in boundary:
            comp = self.comps[cname]
            m = self._mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.opcode in _ZERO_BYTES_OPS:
                    continue
                b = self._instr_bytes(comp, ins)
                if b:
                    rows.append((m * b, ins.opcode, ins.name, cname, m))
        rows.sort(reverse=True)
        return rows[:k]

    def _instr_bytes(self, comp: Computation, ins: Instr) -> float:
        rb = _bytes_of(ins.rtype)
        if ins.opcode == "dynamic-slice":
            return 2.0 * rb
        if ins.opcode == "dynamic-update-slice":
            upd = _bytes_of(comp.symbols.get(ins.operands[1], "")) if len(ins.operands) > 1 else rb
            return 2.0 * upd
        if ins.opcode == "gather":
            return 2.0 * rb
        if ins.opcode == "scatter":
            upd = _bytes_of(comp.symbols.get(ins.operands[-1], "")) if ins.operands else rb
            return 2.0 * upd
        if ins.opcode in ("broadcast", "iota", "rng", "rng-bit-generator"):
            return rb
        if ins.opcode == "fusion":
            return self._fusion_bytes(comp, ins, rb)
        ob = sum(_bytes_of(comp.symbols.get(o, "")) for o in ins.operands)
        return rb + ob

    def _fusion_bytes(self, comp: Computation, ins: Instr, rb: float) -> float:
        """Fusion traffic with slice/update awareness.

        Scan stacking (fwd residual saves) fuses a dynamic-update-slice
        whose RESULT is the whole (L, ...) buffer but whose real traffic
        is the updated slice (the buffer aliases in place); the backward
        reads back through in-fusion dynamic-slices. Charging full
        buffer/operand sizes over-counts every scan-based model by ~L x.
        Rules:
          * root DUS (possibly behind bitcasts): result = 2 x update size,
            and the aliased buffer operand is free;
          * an operand consumed ONLY by (dynamic-)slice ops inside the
            fusion is charged at the slices' result sizes;
          * everything else: full size.
        """
        m = re.search(r"calls=%([\w\.\-]+)", ins.rest)
        callee = self.comps.get(m.group(1)) if m else None
        if callee is None:
            return rb + sum(_bytes_of(comp.symbols.get(o, "")) for o in ins.operands)

        # param index -> param instr name; uses map inside the callee
        params: dict[int, str] = {}
        uses: dict[str, list[Instr]] = {}
        for fi in callee.instrs:
            if fi.opcode == "parameter":
                pm = re.match(r"^\s*(\d+)\s*$", fi.payload)
                if pm:
                    params[int(pm.group(1))] = fi.name
            for o in fi.operands:
                uses.setdefault(o, []).append(fi)

        def _through_bitcast(name: str) -> Instr | None:
            cur = callee.symbols.get(name) and name
            seen = 0
            while cur is not None and seen < 8:
                instr = next((i for i in callee.instrs if i.name == cur), None)
                if instr is None:
                    return None
                if instr.opcode in ("bitcast", "copy", "reshape", "transpose"):
                    cur = instr.operands[0] if instr.operands else None
                    seen += 1
                    continue
                return instr
            return None

        root = callee.instrs[-1] if callee.instrs else None
        aliased_param = None
        total = rb
        if root is not None:
            r_eff = _through_bitcast(root.name) or root
            if r_eff.opcode == "dynamic-update-slice" and len(r_eff.operands) > 1:
                upd = _bytes_of(callee.symbols.get(r_eff.operands[1], ""))
                total = 2.0 * upd
                buf = _through_bitcast(r_eff.operands[0])
                if buf is not None and buf.opcode == "parameter":
                    aliased_param = buf.name

        for idx, opname in enumerate(ins.operands):
            pname = params.get(idx)
            if pname is None:
                total += _bytes_of(comp.symbols.get(opname, ""))
                continue
            if pname == aliased_param:
                continue
            consumers = uses.get(pname, [])
            slice_like = [c for c in consumers
                          if c.opcode in ("dynamic-slice", "slice")]
            if consumers and len(slice_like) == len(consumers):
                total += sum(_bytes_of(c.rtype) for c in slice_like)
            else:
                total += _bytes_of(comp.symbols.get(opname, ""))
        return total

    def bytes_accessed(self) -> float:
        total = 0.0
        for cname in self._boundary():
            comp = self.comps[cname]
            m = self._mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.opcode in _ZERO_BYTES_OPS:
                    continue
                total += m * self._instr_bytes(comp, ins)
        return total

    def fused_bytes_estimate(self) -> float:
        """HBM bytes under a TPU-like fusion assumption.

        The CPU backend materializes elementwise chains as separate kLoop
        fusions; the TPU backend fuses producer->consumer elementwise ops
        into one pass. For every single-use edge between two elementwise/
        fusion instructions in the same computation we drop the
        intermediate's write+read (2 x result bytes). Reported alongside
        the raw count; used uniformly for baseline and optimized variants.
        """
        total = self.bytes_accessed()
        fusable = _ELEMENTWISE | {"fusion", "broadcast", "reduce", "convert",
                                  "copy", "transpose", "reshape"}
        for cname in self._boundary():
            comp = self.comps[cname]
            m = self._mult.get(cname, 0.0)
            if m == 0.0:
                continue
            # use counts within this computation
            uses: dict[str, int] = {}
            consumers: dict[str, str] = {}
            for ins in comp.instrs:
                for o in ins.operands:
                    uses[o] = uses.get(o, 0) + 1
                    consumers[o] = ins.opcode
            for ins in comp.instrs:
                if ins.opcode not in fusable or ins.opcode in _ZERO_BYTES_OPS:
                    continue
                if uses.get(ins.name) == 1 and consumers.get(ins.name) in fusable:
                    total -= m * 2.0 * _bytes_of(ins.rtype)
        return max(total, 0.0)

    def collective_bytes(self) -> dict:
        out = {k: 0.0 for k in _COLLECTIVES}
        counts = {k: 0 for k in _COLLECTIVES}
        for comp in self.comps.values():
            m = self._mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                op = ins.opcode.replace("-start", "")
                if op not in _COLLECTIVES or ins.opcode.endswith("-done"):
                    continue
                rb = _bytes_of(ins.rtype)
                g = self._group_size(ins.rest)
                if g <= 1:
                    continue
                if op == "all-gather":
                    wire = rb * (g - 1) / g
                elif op == "all-reduce":
                    wire = 2.0 * rb * (g - 1) / g
                elif op == "reduce-scatter":
                    wire = rb * (g - 1)
                elif op == "all-to-all":
                    wire = rb * (g - 1) / g
                else:
                    wire = rb
                out[op] += m * wire
                counts[op] += 1
        out["total"] = sum(out[k] for k in _COLLECTIVES)
        out["counts"] = counts
        return out

    def top_collectives(self, k: int = 15) -> list:
        """Top-k (wire bytes x mult, kind, group size, instr, comp)."""
        rows = []
        for comp in self.comps.values():
            m = self._mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                op = ins.opcode.replace("-start", "")
                if op not in _COLLECTIVES or ins.opcode.endswith("-done"):
                    continue
                rb = _bytes_of(ins.rtype)
                g = self._group_size(ins.rest)
                if g <= 1:
                    continue
                if op == "all-gather":
                    wire = rb * (g - 1) / g
                elif op == "all-reduce":
                    wire = 2.0 * rb * (g - 1) / g
                elif op == "reduce-scatter":
                    wire = rb * (g - 1)
                elif op == "all-to-all":
                    wire = rb * (g - 1) / g
                else:
                    wire = rb
                rows.append((m * wire, op, g, ins.name, comp.name, m))
        rows.sort(reverse=True)
        return rows[:k]

    def _group_size(self, rest: str) -> int:
        m = _GROUPS_RE.search(rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(rest)
        if m:
            first = m.group(1).split("},{")[0]
            return max(1, first.count(",") + 1)
        return self.n_devices
