"""Multi-replica prediction router (docs/serving.md "Multi-replica
routing").

Contracts (ISSUE tentpole):
(a) routing NEVER changes a result — under any policy every request
    matches its own lone ``predict_sbv`` call to 1e-12 (scheduler-mode
    replicas pack with the base seed);
(b) shape affinity — equal-size requests share a signature and land on
    one rendezvous-preferred replica, so only that replica's compile
    cache grows;
(c) rendezvous hashing is deterministic across processes (keyed blake2b,
    not the salted builtin ``hash``) and minimally disruptive: removing
    a replica only remaps the signatures it owned;
(d) saturation spills to the least-outstanding replica instead of
    queueing behind the preferred one, and ``AdmissionQueueFull`` walks
    the spill chain before re-raising;
(e) the 2-rank subprocess serve drives the whole plane end-to-end:
    local routers per rank + the collective ``predict_sbv(multihost=)``
    probe vs serial <= 1e-8.
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import predict_sbv
from repro.data.gp_sim import paper_synthetic
from repro.serving import (
    AdmissionQueueFull, BatchingPolicy, GPServer, GPServerConfig,
    PipelineConfig, ReplicaRouter, SchedulerPolicy, rendezvous_rank,
    request_shape_signature,
)

pytestmark = pytest.mark.router

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def problem():
    x, y, params = paper_synthetic(seed=0, n=400, d=4)
    return params, x, y


def _server_cfg(seed=3, queue_bound=None, **pipe_kw):
    pipe_kw.setdefault("bs_pred", 8)
    pipe_kw.setdefault("m_pred", 32)
    pipe_kw.setdefault("chunk_size", 64)
    return GPServerConfig(
        pipeline=PipelineConfig(**pipe_kw),
        policy=BatchingPolicy(max_points=100_000, max_wait_s=30.0),
        scheduler=SchedulerPolicy(queue_bound=queue_bound),
        seed=seed,
    )


def _make_replicas(problem, n, cfg=None):
    params, x, y = problem
    cfg = cfg or _server_cfg()
    reps = [GPServer(params, x, y, cfg)]
    reps += [GPServer(params, x, y, cfg, index=reps[0].index)
             for _ in range(n - 1)]
    return reps


# -- rendezvous hashing -----------------------------------------------------


def test_rendezvous_deterministic_and_spread():
    sigs = [((bc, 8, 32, "f64"),) for bc in range(1, 65)]
    owners = [rendezvous_rank(s, 4) for s in sigs]
    assert owners == [rendezvous_rank(s, 4) for s in sigs]  # pure
    assert set(owners) == {0, 1, 2, 3}  # every replica owns something
    # a different salt is a different (deterministic) assignment
    assert owners != [rendezvous_rank(s, 4, salt=1) for s in sigs]


def test_rendezvous_minimal_disruption_on_replica_removal():
    """HRW property: dropping the last replica only remaps signatures it
    owned — everything else keeps its owner (warm caches survive)."""
    sigs = [((bc, bs, m, "f64"),) for bc in range(1, 33)
            for bs, m in ((8, 32), (16, 64))]
    before = {s: rendezvous_rank(s, 4) for s in sigs}
    after = {s: rendezvous_rank(s, 3) for s in sigs}
    for s in sigs:
        if before[s] < 3:
            assert after[s] == before[s]


def test_rendezvous_rejects_zero_replicas():
    with pytest.raises(ValueError):
        rendezvous_rank(("x",), 0)


# -- shape signatures -------------------------------------------------------


def test_signature_equal_sizes_share_equal_keys():
    cfg = PipelineConfig(bs_pred=8, m_pred=32, chunk_size=512)
    assert request_shape_signature(100, cfg) == request_shape_signature(100, cfg)
    # same padded chunk profile => same signature even if n differs
    # (100//8=12 and 104//8=13 blocks both round up to 16)
    sig_a = request_shape_signature(100, cfg)
    sig_b = request_shape_signature(104, cfg)
    assert sig_a == sig_b
    # a much larger request realizes a different chunk profile
    assert request_shape_signature(3000, cfg) != sig_a


def test_signature_tracks_config_knobs():
    base = PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64)
    assert request_shape_signature(100, base) != request_shape_signature(
        100, PipelineConfig(bs_pred=8, m_pred=48, chunk_size=64))
    assert request_shape_signature(100, base) != request_shape_signature(
        100, PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64,
                            precision="f32"))
    bucketed = PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64,
                              n_buckets=2)
    sig = request_shape_signature(100, bucketed)
    assert any(k[0] == "buckets" for k in sig)


# -- construction contracts -------------------------------------------------


def test_router_refuses_drain_mode_replicas(problem):
    params, x, y = problem
    cfg = GPServerConfig(pipeline=PipelineConfig(bs_pred=8, m_pred=32,
                                                 chunk_size=64),
                         scheduler=None, seed=3)
    with pytest.raises(ValueError, match="drain"):
        ReplicaRouter([GPServer(params, x, y, cfg)])


def test_router_refuses_mismatched_configs(problem):
    params, x, y = problem
    a = GPServer(params, x, y, _server_cfg(seed=3))
    b = GPServer(params, x, y, _server_cfg(seed=4), index=a.index)
    with pytest.raises(ValueError, match="disagree"):
        ReplicaRouter([a, b])
    c = GPServer(params, x, y, _server_cfg(m_pred=48), index=a.index)
    with pytest.raises(ValueError, match="disagree"):
        ReplicaRouter([a, c])


def test_router_rejects_unknown_policy_and_empty(problem):
    with pytest.raises(ValueError):
        ReplicaRouter([])
    reps = _make_replicas(problem, 1)
    with pytest.raises(ValueError):
        ReplicaRouter(reps, routing="sticky")


# -- routing policy (stub replicas: no numerics) ----------------------------


class _StubReplica:
    """Matches the slice of the GPServer surface the router touches."""

    def __init__(self, outstanding=0, reject=False):
        self.outstanding_points = outstanding
        self.reject = reject
        self.submitted = []

    def submit(self, x, slo="interactive", outputs=None):
        if self.reject:
            raise AdmissionQueueFull("full")
        self.submitted.append(np.asarray(x).shape[0])
        return "fut"


def test_affinity_prefers_rendezvous_owner():
    reps = [_StubReplica() for _ in range(3)]
    r = ReplicaRouter(reps, routing="affinity")
    n = 100
    pref = r.preferred_replica(n)
    for _ in range(5):
        r.submit(np.zeros((n, 3)))
    assert len(reps[pref].submitted) == 5
    s = r.stats.summary()
    assert s["affinity_hit_rate"] == 1.0 and s["n_spilled"] == 0


def test_spill_to_least_outstanding_past_threshold():
    reps = [_StubReplica(outstanding=0) for _ in range(3)]
    r = ReplicaRouter(reps, routing="affinity", spill_points=500)
    pref = r.preferred_replica(64)
    reps[pref].outstanding_points = 1000  # saturate the preferred replica
    r.submit(np.zeros((64, 3)))
    landed = [i for i, rep in enumerate(reps) if rep.submitted]
    assert landed != [pref]
    s = r.stats.summary()
    assert s["n_spilled"] == 1 and s["affinity_hits"] == 0
    # under the threshold, affinity sticks even when others are idle
    reps[pref].outstanding_points = 100
    r.submit(np.zeros((64, 3)))
    assert len(reps[pref].submitted) == 1


def test_no_spill_when_everyone_is_as_loaded():
    reps = [_StubReplica(outstanding=1000) for _ in range(3)]
    r = ReplicaRouter(reps, routing="affinity", spill_points=500)
    pref = r.preferred_replica(64)
    r.submit(np.zeros((64, 3)))  # spilling elsewhere would not help
    assert len(reps[pref].submitted) == 1


def test_admission_full_walks_spill_chain_then_reraises():
    reps = [_StubReplica(reject=True) for _ in range(3)]
    pref = ReplicaRouter(reps, routing="affinity").preferred_replica(64)
    reps[pref].reject = False
    r = ReplicaRouter(reps, routing="affinity")
    r.submit(np.zeros((64, 3)))  # preferred accepts
    reps[pref].reject = True
    with pytest.raises(AdmissionQueueFull):
        r.submit(np.zeros((64, 3)))  # every replica rejected
    # one healthy spare catches the spill
    reps[(pref + 1) % 3].reject = False
    r.submit(np.zeros((64, 3)))
    assert len(reps[(pref + 1) % 3].submitted) == 1


def test_round_robin_rotates_and_random_is_seeded():
    reps = [_StubReplica() for _ in range(3)]
    r = ReplicaRouter(reps, routing="round_robin")
    for _ in range(6):
        r.submit(np.zeros((10, 2)))
    assert [len(rep.submitted) for rep in reps] == [2, 2, 2]

    picks = []
    for seed in (7, 7, 8):
        reps = [_StubReplica() for _ in range(3)]
        r = ReplicaRouter(reps, routing="random", seed=seed)
        for _ in range(16):
            r.submit(np.zeros((10, 2)))
        picks.append(tuple(len(rep.submitted) for rep in reps))
    assert picks[0] == picks[1]  # same seed, same spray


def test_router_stats_counters():
    reps = [_StubReplica() for _ in range(2)]
    r = ReplicaRouter(reps, routing="round_robin")
    for n in (10, 20, 30):
        r.submit(np.zeros((n, 2)))
    s = r.stats.summary()
    assert s["n_requests"] == 3 and s["n_points"] == 60
    assert sum(s["replica_requests"]) == 3
    assert sum(s["replica_points"]) == 60
    assert 0.0 <= s["affinity_hit_rate"] <= 1.0


# -- parity: routing never changes a result ---------------------------------


@pytest.mark.parametrize("routing", ["affinity", "random", "round_robin"])
def test_routed_requests_match_lone_predict_sbv(problem, routing):
    """THE tentpole contract: whatever replica a request lands on, the
    result is its own ``predict_sbv(..., seed=cfg.seed)`` to 1e-12."""
    params, x, y = problem
    reps = _make_replicas(problem, 3)
    rng = np.random.default_rng(5)
    requests = [rng.uniform(size=(n, 4)) for n in (33, 70, 33, 12, 70, 1)]
    router = ReplicaRouter(reps, routing=routing, seed=1)
    with router:
        futs = [router.submit(xq) for xq in requests]
        router.flush()
        results = [f.result(timeout=300) for f in futs]
    for xq, res in zip(requests, results):
        ref = predict_sbv(params, x, y, xq, bs_pred=8, m_pred=32, seed=3,
                          chunk_size=64, n_sims=2)
        np.testing.assert_allclose(res.mean, np.asarray(ref.mean),
                                   rtol=0, atol=1e-12)
        np.testing.assert_allclose(res.var, np.asarray(ref.var),
                                   rtol=0, atol=1e-12)
    # routing telemetry saw every request
    assert router.stats.summary()["n_requests"] == len(requests)


def test_affinity_colocates_and_random_sprays_shapes(problem):
    """Affinity's point: one size class touches ONE replica's cache.
    Submit one size class many times — affinity grows one replica's
    compiled-shape set, round_robin grows all three."""
    rng = np.random.default_rng(6)
    requests = [rng.uniform(size=(64, 4)) for _ in range(9)]

    def shapes_touched(routing):
        reps = _make_replicas(problem, 3)
        router = ReplicaRouter(reps, routing=routing, seed=0)
        with router:
            futs = [router.submit(xq) for xq in requests]
            router.flush()
            [f.result(timeout=300) for f in futs]
        return [len(rep.stats.compiled_shape_keys()) for rep in reps]

    aff = shapes_touched("affinity")
    rr = shapes_touched("round_robin")
    assert sum(1 for v in aff if v > 0) == 1  # one warm cache
    assert sum(1 for v in rr if v > 0) == 3   # three cold-started caches
    assert sum(aff) < sum(rr)


def test_concurrent_submits_are_thread_safe(problem):
    reps = _make_replicas(problem, 2)
    router = ReplicaRouter(reps, routing="affinity", seed=0)
    rng = np.random.default_rng(9)
    requests = [rng.uniform(size=(24, 4)) for _ in range(12)]
    futs = [None] * len(requests)
    with router:
        def worker(k):
            futs[k] = router.submit(requests[k])

        threads = [threading.Thread(target=worker, args=(k,))
                   for k in range(len(requests))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        router.flush()
        results = [f.result(timeout=300) for f in futs]
    params, x, y = problem
    for xq, res in zip(requests, results):
        ref = predict_sbv(params, x, y, xq, bs_pred=8, m_pred=32, seed=3,
                          chunk_size=64, n_sims=2)
        np.testing.assert_allclose(res.mean, np.asarray(ref.mean),
                                   rtol=0, atol=1e-12)
    assert router.stats.summary()["n_requests"] == len(requests)


# -- the multi-host serve plane (real rank subprocesses) --------------------


def test_two_rank_serve_and_multihost_predict_parity(tmp_path):
    """End-to-end over ``jax.distributed``: 2 rank processes each serve
    their rendezvous-owned request slice through a local router, then
    collectively run ``predict_sbv(multihost=)`` and compare against the
    serial predict — the ISSUE gate: multihost parity <= 1e-8, served
    per-request parity <= 1e-12."""
    result = str(tmp_path / "serve.json")
    cmd = [sys.executable, "-m", "repro.launch.serve", "gp",
           "--n-train", "500", "--n-test", "600", "--chunk", "256",
           "--bs-pred", "8", "--m-pred", "30", "--requests", "6",
           "--distributed-hosts", "2", "--seed", "0",
           "--result-json", result]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, (
        f"distributed serve failed:\n{proc.stdout}\n{proc.stderr}")
    with open(result) as f:
        merged = json.load(f)
    assert merged["n_hosts"] == 2
    assert len(merged["ranks"]) == 2
    # every request served exactly once across the ranks
    assert merged["n_requests"] == 6
    assert merged["n_points"] == 600
    assert merged["multihost_parity_max"] <= 1e-8
    assert merged["served_parity_max"] <= 1e-12
    # both ranks took a share (rendezvous spreads 6 requests over 2)
    assert all(rk["n_requests"] > 0 for rk in merged["ranks"])
