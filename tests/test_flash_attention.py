"""Flash-attention kernel vs dense oracle: shape/dtype/mask sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_ref import flash_attention_ref


def rand_qkv(key, b, h, s, t, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, h, t, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, h, t, hd), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 2, 128, 128, 32), (2, 3, 256, 256, 64)])
def test_matches_ref_causal(dtype, shape):
    b, h, s, t, hd = shape
    q, k, v = rand_qkv(jax.random.key(0), b, h, s, t, hd, dtype)
    got = flash_attention(q, k, v, q_tile=64, k_tile=64, interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [0, 64, 17])
def test_sliding_window(window):
    q, k, v = rand_qkv(jax.random.key(1), 1, 2, 128, 128, 32, jnp.float32)
    got = flash_attention(q, k, v, window=window, q_tile=64, k_tile=32,
                          interpret=True)
    want = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_softcap():
    q, k, v = rand_qkv(jax.random.key(2), 1, 2, 128, 128, 32, jnp.float32)
    got = flash_attention(q, k, v, softcap=30.0, q_tile=64, k_tile=64,
                          interpret=True)
    want = flash_attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_cross_attention_longer_kv():
    """Decode-like: queries shorter than KV (non-square, non-causal)."""
    q, k, v = rand_qkv(jax.random.key(3), 2, 2, 64, 512, 32, jnp.float32)
    got = flash_attention(q, k, v, causal=False, q_tile=64, k_tile=128,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_tile_size_invariance():
    q, k, v = rand_qkv(jax.random.key(4), 1, 1, 256, 256, 64, jnp.float32)
    a = flash_attention(q, k, v, q_tile=256, k_tile=256, interpret=True)
    b = flash_attention(q, k, v, q_tile=64, k_tile=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
