"""Correctness of the Vecchia core: exactness identities, masking, KL."""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    KernelParams, SBVConfig, exact_loglik, kl_divergence, packed_loglik, preprocess,
)
from repro.core.blocks import build_blocks, scale_inputs
from repro.core.nns import brute_force_nns, filtered_nns
from repro.core.packing import PackedBlocks, pack_blocks


def make_data(n=80, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, d))
    y = rng.normal(size=n)
    return x, y


PAR = KernelParams.create(sigma2=1.3, beta=[0.3, 0.5, 2.0], nugget=1e-2, d=3)


def test_single_block_full_set_is_exact():
    """bc=1 => the lone block term is the exact joint density."""
    x, y = make_data(40)
    cfg = SBVConfig(n_blocks=1, m=8)
    packed, _ = preprocess(x, y, PAR.beta, cfg)
    ll = packed_loglik(PAR, packed)
    ll0 = exact_loglik(PAR, jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(float(ll), float(ll0), rtol=1e-10)


def test_full_conditioning_is_exact():
    """m >= n and bs=1 (classic Vecchia, all predecessors) => exact loglik."""
    x, y = make_data(30)
    cfg = SBVConfig(n_blocks=30, m=30, nns="brute")
    packed, _ = preprocess(x, y, PAR.beta, cfg)
    ll = packed_loglik(PAR, packed)
    ll0 = exact_loglik(PAR, jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(float(ll), float(ll0), rtol=1e-9)


def test_block_full_conditioning_is_exact():
    """Blocked version with all preceding points as neighbors => exact."""
    x, y = make_data(36)
    cfg = SBVConfig(n_blocks=6, m=36, nns="brute")
    packed, _ = preprocess(x, y, PAR.beta, cfg)
    ll = packed_loglik(PAR, packed)
    ll0 = exact_loglik(PAR, jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(float(ll), float(ll0), rtol=1e-9)


def test_padding_invariance():
    """Growing bs_max / m padding never changes the likelihood."""
    x, y = make_data(60)
    cfg = SBVConfig(n_blocks=10, m=12)
    packed, blocks = preprocess(x, y, PAR.beta, cfg)
    ll = packed_loglik(PAR, packed)

    xs = scale_inputs(x, np.asarray(PAR.beta))
    neigh = filtered_nns(xs, blocks, 12)
    packed_big = pack_blocks(x, y, blocks, neigh, m=20, bs_max=packed.bs_max + 7)
    # m=20 slots but only 12 neighbors filled -> extra padding only
    packed_big = PackedBlocks(
        blk_x=packed_big.blk_x, blk_y=packed_big.blk_y, blk_mask=packed_big.blk_mask,
        nn_x=packed_big.nn_x, nn_y=packed_big.nn_y,
        nn_mask=packed_big.nn_mask & (np.cumsum(packed_big.nn_mask, axis=1) <= 12),
        owners=packed_big.owners,
    )
    ll_big = packed_loglik(PAR, packed_big)
    np.testing.assert_allclose(float(ll), float(ll_big), rtol=1e-10)


def test_dummy_block_padding_invariance():
    x, y = make_data(50)
    cfg = SBVConfig(n_blocks=8, m=10)
    packed, _ = preprocess(x, y, PAR.beta, cfg)
    ll = packed_loglik(PAR, packed)
    ll_pad = packed_loglik(PAR, packed.pad_to_blocks(packed.n_blocks + 5))
    np.testing.assert_allclose(float(ll), float(ll_pad), rtol=1e-10)


def test_kl_nonnegative_and_decreasing_in_m():
    x, _ = make_data(120, seed=3)
    y = np.zeros(120)
    kls = []
    for m in (4, 16, 60):
        cfg = SBVConfig(n_blocks=24, m=m, seed=1)
        packed, _ = preprocess(x, y, PAR.beta, cfg)
        kls.append(kl_divergence(PAR, x, packed))
    assert all(k >= -1e-8 for k in kls), kls
    assert kls[-1] <= kls[0] + 1e-8, kls


def test_scaling_identity():
    """SBV with kernel beta on X == isotropic BV on X/beta (exact identity)."""
    x, y = make_data(50, seed=5)
    beta = np.array([0.25, 0.8, 3.0])
    cfg = SBVConfig(n_blocks=10, m=14, seed=2)
    packed_raw, _ = preprocess(x, y, beta, cfg)
    par_aniso = KernelParams.create(sigma2=1.0, beta=beta, nugget=1e-3)
    ll_aniso = packed_loglik(par_aniso, packed_raw)

    packed_scaled, _ = preprocess(x / beta, y, np.ones(3), SBVConfig(n_blocks=10, m=14, seed=2))
    par_iso = KernelParams.create(sigma2=1.0, beta=np.ones(3), nugget=1e-3)
    ll_iso = packed_loglik(par_iso, packed_scaled)
    np.testing.assert_allclose(float(ll_aniso), float(ll_iso), rtol=1e-9)


def test_filtered_nns_matches_brute_force():
    x, _ = make_data(300, d=4, seed=7)
    beta = np.array([0.2, 0.4, 1.0, 5.0])
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, n_blocks=40, n_workers=4, beta=beta, seed=3)
    for alpha in (2.0, 30.0, 100.0):
        got = filtered_nns(xs, blocks, m=12, alpha=alpha)
        want = brute_force_nns(xs, blocks, m=12)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


def test_rac_blocks_partition_points():
    x, _ = make_data(200, d=2, seed=9)
    blocks = build_blocks(x, n_blocks=25, n_workers=4, beta=np.ones(2), seed=4)
    all_idx = np.sort(np.concatenate(blocks.members))
    np.testing.assert_array_equal(all_idx, np.arange(200))
    assert blocks.n_blocks == len(blocks.members)
    # ranks are a permutation
    np.testing.assert_array_equal(np.sort(blocks.rank_of_block), np.arange(blocks.n_blocks))
