"""Packed prediction pipeline vs the exact-GP oracle and the fused kernel.

Three contracts (ISSUE acceptance criteria):
(a) when every training point is a neighbor (m_pred >= n_train) the block
    conditional IS the exact GP conditional — mean/var match exact_predict;
(b) backend='pallas' (interpret mode on CPU) matches backend='ref';
(c) identity padding is inert: dummy blocks / padded rows change nothing.
"""
import numpy as np
import pytest

from repro.core import KernelParams, exact_predict, predict_sbv
from repro.core.packing import PackedPrediction, pack_prediction
from repro.core.predict import (
    batched_block_predict, build_train_index, pack_queries, packed_predict,
)
from repro.data.gp_sim import paper_synthetic


def _setup(seed=0, n_train=60, n_test=40, d=3):
    x, y, params = paper_synthetic(seed=seed, n=max(n_train, 200), d=d)
    x, y = x[:n_train], y[:n_train]
    rng = np.random.default_rng(seed + 1)
    xt = rng.uniform(size=(n_test, d))
    return params, x, y, xt


def test_predict_matches_exact_gp_when_all_neighbors():
    params, x, y, xt = _setup()
    # m_pred >= n_train: every block conditions on the full training set.
    pred = predict_sbv(params, x, y, xt, bs_pred=8, m_pred=80, seed=0)
    em, ev = exact_predict(params, x, y, xt)
    np.testing.assert_allclose(pred.mean, np.asarray(em), atol=1e-4, rtol=0)
    np.testing.assert_allclose(pred.var, np.asarray(ev), atol=1e-4, rtol=0)


def test_predict_chunked_matches_exact_gp():
    params, x, y, xt = _setup(seed=2)
    pred = predict_sbv(params, x, y, xt, bs_pred=8, m_pred=80, seed=2,
                       chunk_size=16)
    em, ev = exact_predict(params, x, y, xt)
    np.testing.assert_allclose(pred.mean, np.asarray(em), atol=1e-4, rtol=0)
    np.testing.assert_allclose(pred.var, np.asarray(ev), atol=1e-4, rtol=0)


def test_pallas_backend_matches_ref():
    params, x, y, xt = _setup(seed=1)
    index = build_train_index(x, y, np.asarray(params.beta), 24, seed=1)
    packed = pack_queries(index, xt, bs_pred=8, m_pred=24, seed=1)
    mu_r, var_r = packed_predict(params, packed, backend="ref")
    mu_p, var_p = packed_predict(params, packed, backend="pallas")
    np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(var_p), np.asarray(var_r),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_masked_padding_is_inert(backend):
    """Dummy blocks + extra padded query/neighbor slots change nothing."""
    params, x, y, xt = _setup(seed=3)
    index = build_train_index(x, y, np.asarray(params.beta), 24, seed=3)
    packed = pack_queries(index, xt, bs_pred=8, m_pred=24, seed=3)

    # Repack the same structure with wider padding + 3 dummy blocks.
    bs = packed.bs_pred
    pad = lambda a, w: np.concatenate(
        [a, np.zeros(a.shape[:1] + (w,) + a.shape[2:], dtype=a.dtype)], axis=1)
    wider = PackedPrediction(
        q_x=pad(packed.q_x, 5), q_mask=pad(packed.q_mask, 5),
        q_idx=pad(packed.q_idx, 5),
        nn_x=pad(packed.nn_x, 7), nn_y=pad(packed.nn_y, 7),
        nn_mask=pad(packed.nn_mask, 7),
        owners=packed.owners,
    ).pad_to_blocks(packed.n_blocks + 3)

    mu_a, var_a = packed_predict(params, packed, backend=backend)
    mu_b, var_b = packed_predict(params, wider, backend=backend)
    msk = packed.q_mask
    np.testing.assert_allclose(
        np.asarray(mu_b)[: packed.n_blocks, :bs][msk], np.asarray(mu_a)[msk],
        rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(var_b)[: packed.n_blocks, :bs][msk], np.asarray(var_a)[msk],
        rtol=1e-12, atol=1e-12)


def test_scatter_covers_every_test_point_once():
    params, x, y, xt = _setup(seed=4, n_test=37)
    index = build_train_index(x, y, np.asarray(params.beta), 16, seed=4)
    packed = pack_queries(index, xt, bs_pred=5, m_pred=16, seed=4)
    idx = packed.q_idx[packed.q_mask]
    assert sorted(idx.tolist()) == list(range(37))


def test_backend_and_chunking_consistent_with_loop_free_path():
    """predict_sbv with pallas backend equals ref end to end (simulation
    uses the same key stream, so sim outputs agree too)."""
    params, x, y, xt = _setup(seed=5)
    a = predict_sbv(params, x, y, xt, bs_pred=8, m_pred=32, seed=5,
                    n_sims=64, backend="ref")
    b = predict_sbv(params, x, y, xt, bs_pred=8, m_pred=32, seed=5,
                    n_sims=64, backend="pallas")
    np.testing.assert_allclose(b.mean, a.mean, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(b.var, a.var, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(b.ci_low, a.ci_low, atol=1e-4, rtol=1e-4)
