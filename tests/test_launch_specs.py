"""Launch-layer tests.

In-process: param counts + abstract param trees (no mesh needed).
Subprocess (8 virtual devices, same pattern as test_distributed_gp):
spec-building for every (arch x shape), tiny-mesh end-to-end train-step
compile, sharding-rule divisibility fallback.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.param_count import active_param_count, total_param_count
from repro.launch.specs import abstract_params


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_abstract_params_build(arch):
    cfg = get_config(arch)
    tree = abstract_params(cfg, tp=16)
    assert len(jax.tree.leaves(tree)) > 3


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_close_to_eval_shape(arch):
    """Analytic count (used for 6ND roofline terms) within 30% of the
    real parameter tree."""
    cfg = get_config(arch)
    tree = abstract_params(cfg, tp=1)
    real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
    analytic = total_param_count(cfg)
    assert 0.7 < analytic / real < 1.3, (arch, analytic, real)
    assert active_param_count(cfg) <= analytic


_MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import ARCHS, SHAPES, applicable, get_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import build_cell
    from repro.sharding.rules import batch_spec, param_specs
    from repro.models.model import init_params
    from repro.sharding.compat import set_mesh
    from repro.training.train_step import make_train_step, train_state_init

    mesh = make_test_mesh((2, 2))

    # 1. every applicable cell builds specs + NamedShardings
    n_cells = 0
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for shape in sorted(SHAPES):
            ok, _ = applicable(cfg, shape)
            if not ok:
                continue
            step, args, in_sh, out_sh, donate = build_cell(arch, shape, mesh)
            for s in jax.tree.leaves(in_sh):
                assert isinstance(s, NamedSharding), (arch, shape, s)
            n_cells += 1
    assert n_cells == 32, n_cells  # 40 cells - 8 long_500k full-attn skips

    # 2. tiny end-to-end train compile+run on the 2x2 mesh
    cfg = get_config("internlm2-1.8b").reduced(n_layers=2, vocab=256)
    params = init_params(jax.random.key(0), cfg, tp=2)
    state = train_state_init(params)
    pspec = param_specs(state.params, mesh)
    sspec = type(state)(params=pspec,
                        opt=type(state.opt)(step=P(), mu=pspec, nu=pspec),
                        step=P())
    ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                       is_leaf=lambda x: isinstance(x, P))
    bsh = NamedSharding(mesh, batch_spec(mesh, 4))
    tok = jnp.zeros((4, 64), jnp.int32)
    step = make_train_step(cfg, tp=2, lr=1e-3)
    with set_mesh(mesh):
        state2, metrics = jax.jit(
            step, in_shardings=(ssh, bsh, bsh), donate_argnums=(0,)
        )(state, tok, tok)
    assert np.isfinite(float(metrics["loss"]))

    # 3. divisibility fallback
    specs = param_specs({"wq": jnp.zeros((4, 6, 10)), "odd": jnp.zeros((7,))}, mesh)
    assert specs["wq"] == P(None, "data", "model"), specs
    assert specs["odd"] == P(None)
    specs2 = param_specs({"wq": jnp.zeros((4, 5, 6))}, mesh)
    assert specs2["wq"] == P(None, None, "model"), specs2
    print("MESH_OK", n_cells)
    """
)


def test_mesh_cells_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=900,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "MESH_OK" in r.stdout
