"""Multi-host streaming construction + fit (docs/streaming.md).

The contract under test: K `jax.distributed` rank processes, each owning
one row-partition of a shared store, must produce the SAME fit as the
single-process streaming path — the partitioned k-means allreduce, the
halo NNS exchange and the lockstep per-chunk loss/grad allreduce add
parallelism, not numerics. Fast in-process layers (partition geometry,
``PartitionedStore`` pass-through, ``LoopbackComm`` bitwise parity) run
everywhere; the ``multihost``-marked tests spawn real rank subprocesses
through ``repro.launch.fit_gp --distributed-hosts`` and pin nll parity
plus the per-host peak-RSS ceiling.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.gp_sim import paper_synthetic
from repro.data.store import (ArrayStore, PartitionedStore,
                              partition_bounds)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# One shared configuration for every serial-vs-distributed comparison in
# this file (the rank CLI flags below must mirror these).
BLOCKS, M, INNER, OUTER, CHUNK, SEED = 24, 8, 4, 2, 600, 0


# -- partition geometry -----------------------------------------------------


def test_partition_bounds_alignment_and_coverage():
    b = partition_bounds(1000, 3, align=128)
    assert b[0] == 0 and b[-1] == 1000
    assert np.all(np.diff(b) >= 0)
    # interior boundaries snap to the alignment; the final one never does
    assert all(v % 128 == 0 for v in b[1:-1])
    assert np.array_equal(b, [0, 384, 768, 1000])


def test_partition_bounds_empty_tail_parts():
    # n_rows < n_parts * align: tail parts collapse to zero rows and the
    # union still covers every row exactly once.
    b = partition_bounds(100, 4, align=64)
    assert np.array_equal(b, [0, 64, 100, 100, 100])
    widths = np.diff(b)
    assert widths.sum() == 100 and np.all(widths >= 0)


def test_partitioned_store_rejects_bad_part(tmp_path):
    x, y, _ = paper_synthetic(seed=0, n=300, d=3)
    st = ArrayStore.from_arrays(str(tmp_path / "pp"), x, y, shard_rows=128)
    with pytest.raises(ValueError):
        PartitionedStore(st, 2, 2)
    with pytest.raises(ValueError):
        PartitionedStore(st, 2, -1)


def test_partitioned_store_union_matches_serial(tmp_path):
    """The union of all parts' chunk windows IS the serial window
    sequence — same global grid, same rows, nothing duplicated."""
    x, y, _ = paper_synthetic(seed=1, n=1500, d=3)
    st = ArrayStore.from_arrays(str(tmp_path / "un"), x, y, shard_rows=256)
    serial = [(s, xw.copy(), yw.copy()) for s, xw, yw in st.iter_chunks(400)]
    for n_parts in (2, 3):
        parts = [PartitionedStore(st, n_parts, p) for p in range(n_parts)]
        assert sum(p.n_local for p in parts) == st.n_rows
        # partition boundaries snap to whole shards (shard_rows=256)
        for p in parts[:-1]:
            assert p.stop % 256 == 0 or p.stop == st.n_rows
        got = sorted(
            (s, xw, yw) for p in parts for s, xw, yw in p.iter_chunks(400))
        # windows re-assemble the serial pass exactly (a window split by a
        # partition boundary appears as adjacent clipped pieces)
        cat_x = np.concatenate([xw for _, xw, _ in got])
        ser_x = np.concatenate([xw for _, xw, _ in serial])
        assert np.array_equal(cat_x, ser_x)
        cat_y = np.concatenate([yw for _, _, yw in got])
        assert np.array_equal(cat_y, np.concatenate(
            [yw for _, _, yw in serial]))
        # every piece sits on the global [k*rows, (k+1)*rows) grid,
        # clipped to its partition
        for (s, xw, _), p in [(c, p) for p in parts
                              for c in p.iter_chunks(400)]:
            assert s % 400 == 0 or s == p.start
            assert p.start <= s < p.stop


def test_partitioned_store_passthrough_and_telemetry(tmp_path):
    """Random access passes through to the parent store (shared-FS
    semantics) while ``remote_rows_read`` counts exactly the rows served
    from outside the partition."""
    x, y, _ = paper_synthetic(seed=2, n=600, d=3)
    st = ArrayStore.from_arrays(str(tmp_path / "tm"), x, y, shard_rows=128)
    p = PartitionedStore(st, 2, 0)
    assert (p.n_rows, p.d) == (600, 3)

    inside = np.arange(p.start, min(p.start + 10, p.stop))
    xi, yi = p.read_rows(inside)
    assert np.array_equal(xi, x[inside]) and np.array_equal(yi, y[inside])
    assert p.remote_rows_read == 0

    outside = np.array([p.stop, p.stop + 1, p.start])  # 2 remote, 1 local
    p.read_rows(outside)
    assert p.remote_rows_read == 2

    p2 = PartitionedStore(st, 2, 1)
    xs, _ = p2.read_slice(p2.start - 5, p2.start + 5)  # 5 remote rows
    assert np.array_equal(xs, x[p2.start - 5:p2.start + 5])
    assert p2.remote_rows_read == 5


# -- single-process comm parity --------------------------------------------


def test_loopback_fit_is_bitwise_serial(tmp_path):
    """``multihost=LoopbackComm()`` must be the identity on the fit: the
    multi-host code path with one host reproduces the plain streaming
    fit BITWISE (allreduce is a copy, exchange a loopback)."""
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.multihost import LoopbackComm

    x, y, _ = paper_synthetic(seed=0, n=900, d=3)
    st = ArrayStore.from_arrays(str(tmp_path / "lb"), x, y, shard_rows=256)
    cfg = SBVConfig(n_blocks=16, m=M, seed=SEED)
    kw = dict(inner_steps=3, outer_rounds=2, stream_chunk=400,
              device_cache=0, backend="ref")
    ref = fit_sbv(st, None, cfg, **kw)
    mh = fit_sbv(st, None, cfg, multihost=LoopbackComm(), **kw)
    assert [h[:2] for h in ref.history] == [h[:2] for h in mh.history]
    assert all(a[2] == b[2] for a, b in zip(ref.history, mh.history))
    for f in ("sigma2", "nugget"):
        assert float(getattr(ref.params, f)) == float(getattr(mh.params, f))
    assert np.array_equal(np.asarray(ref.params.beta),
                          np.asarray(mh.params.beta))


def test_partition_blocks_spans():
    from repro.multihost import partition_blocks

    assert partition_blocks(10, 3) == [(0, 4), (4, 7), (7, 10)]
    assert partition_blocks(2, 4) == [(0, 1), (1, 2), (2, 2), (2, 2)]
    for n, k in ((1, 1), (17, 5), (64, 8)):
        spans = partition_blocks(n, k)
        assert spans[0][0] == 0 and spans[-1][1] == n
        assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
        widths = [hi - lo for lo, hi in spans]
        assert max(widths) - min(widths) <= 1


def test_loopback_predict_is_bitwise_serial():
    """``predict_sbv(multihost=LoopbackComm())`` owns every block span,
    so the sharded path must reproduce the plain predict BITWISE (the
    full-span eps slice is the identity and allreduce is a copy)."""
    from repro.core.predict import predict_sbv
    from repro.multihost import LoopbackComm

    x, y, params = paper_synthetic(seed=0, n=400, d=3)
    rng = np.random.default_rng(1)
    xq = rng.uniform(size=(111, 3))
    kw = dict(bs_pred=8, m_pred=24, seed=3, n_sims=3, chunk_size=64)
    ref = predict_sbv(params, x, y, xq, **kw)
    mh = predict_sbv(params, x, y, xq, multihost=LoopbackComm(), **kw)
    for f in ("mean", "var", "sim_mean", "ci_low", "ci_high"):
        assert np.array_equal(np.asarray(getattr(ref, f)),
                              np.asarray(getattr(mh, f))), f


# -- real rank subprocesses -------------------------------------------------


@pytest.fixture(scope="module")
def mh_store(tmp_path_factory):
    x, y, _ = paper_synthetic(seed=0, n=2000, d=4)
    path = str(tmp_path_factory.mktemp("mh") / "store")
    return ArrayStore.from_arrays(path, x, y, shard_rows=512)


@pytest.fixture(scope="module")
def serial_nll(mh_store):
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig

    cfg = SBVConfig(n_blocks=BLOCKS, m=M, seed=SEED)
    res = fit_sbv(mh_store, None, cfg, inner_steps=INNER,
                  outer_rounds=OUTER, backend="ref", stream_chunk=CHUNK,
                  device_cache=0)
    return float(res.history[-1][2])


def _run_distributed(mh_store, tmp_path, hosts: int) -> dict:
    """Launch the real multi-rank fit through the fit_gp driver."""
    result = str(tmp_path / "result.json")
    cmd = [sys.executable, "-m", "repro.launch.fit_gp",
           "--store", mh_store.path, "--distributed-hosts", str(hosts),
           "--blocks", str(BLOCKS), "--m", str(M),
           "--inner-steps", str(INNER), "--outer-rounds", str(OUTER),
           "--stream-chunk", str(CHUNK), "--device-cache-mb", "0",
           "--seed", str(SEED), "--backend", "ref",
           "--result-json", result]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, (
        f"distributed fit failed:\n{proc.stdout}\n{proc.stderr}")
    with open(result) as f:
        return json.load(f)


def _check_parity_and_memory(merged, serial_nll, hosts):
    assert merged["n_hosts"] == hosts
    assert len(merged["ranks"]) == hosts
    # lockstep allreduce: every rank lands on the SAME nll ...
    assert merged["max_nll_spread"] == 0.0
    # ... and it matches the single-process streaming fit (the local
    # piece count differs per rank, so only summation ORDER may change)
    assert abs(merged["nll"] - serial_nll) <= 1e-8
    for rk in merged["ranks"]:
        # per-host memory contract: peak RSS within 2x the partitioned
        # working-set model (skip where /proc is unreadable)
        if rk["peak_rss_bytes"] is not None:
            assert rk["peak_rss_bytes"] <= 2 * rk["working_set_bytes"], (
                f"rank {rk['rank']}: peak {rk['peak_rss_bytes']} > 2x "
                f"working set {rk['working_set_bytes']}")


@pytest.mark.multihost
def test_two_host_fit_matches_serial(mh_store, serial_nll, tmp_path):
    merged = _run_distributed(mh_store, tmp_path, hosts=2)
    _check_parity_and_memory(merged, serial_nll, hosts=2)


@pytest.mark.multihost
def test_four_host_fit_matches_serial(mh_store, serial_nll, tmp_path):
    merged = _run_distributed(mh_store, tmp_path, hosts=4)
    _check_parity_and_memory(merged, serial_nll, hosts=4)
