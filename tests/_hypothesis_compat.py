"""Import hypothesis or stub it: ``@given`` tests skip when it's absent.

``hypothesis`` is an optional dev dependency (requirements-dev.txt). Test
modules that mix property tests with plain pytest tests import
``given/settings/st`` from here so the plain tests keep running on
environments without hypothesis instead of erroring at collection.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: decorated tests skip, module still collects
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.<anything>(...) returns an inert placeholder at collection."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
