"""MLE fit + prediction behaviour on synthetic data."""
import numpy as np

from repro.core import KernelParams, SBVConfig
from repro.core.fit import fit_neldermead, fit_sbv
from repro.core.predict import mspe, predict_sbv
from repro.data.gp_sim import (
    metarvm_dataset, metarvm_simulate, paper_synthetic, sample_gp_exact, sample_gp_rff,
    satellite_drag_like,
)


def test_fit_improves_loglik_and_recovers_scale():
    x, y, true_params = paper_synthetic(seed=0, n=400, d=4)
    cfg = SBVConfig(n_blocks=40, m=24, seed=0)
    res = fit_sbv(x, y, cfg, inner_steps=40, outer_rounds=2, lr=0.1)
    losses = [h[2] for h in res.history]
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])
    # variance within a factor ~3 of truth
    assert 0.3 < float(res.params.sigma2) < 3.5


def test_fit_identifies_relevant_dimensions():
    """Relevant dims (small beta) should get much larger 1/beta than noise dims."""
    x, y, _ = paper_synthetic(seed=1, n=500, d=6)
    cfg = SBVConfig(n_blocks=50, m=30, seed=1)
    res = fit_sbv(x, y, cfg, inner_steps=80, outer_rounds=2, lr=0.1)
    inv_beta = 1.0 / np.asarray(res.params.beta)
    relevant = inv_beta[:2].min()
    irrelevant = inv_beta[2:].max()
    assert relevant > 2.0 * irrelevant, inv_beta


def test_neldermead_path_runs():
    x, y, _ = paper_synthetic(seed=2, n=150, d=3)
    cfg = SBVConfig(n_blocks=15, m=16, seed=2)
    res = fit_neldermead(x, y, cfg, maxiter=60)
    assert np.isfinite(res.history[-1][2])


def test_predict_interpolates_training_points():
    x, y, true_params = paper_synthetic(seed=3, n=300, d=3)
    pred = predict_sbv(true_params, x, y, x[:50], bs_pred=5, m_pred=60, seed=3)
    # tiny nugget -> near-interpolation at training inputs
    assert mspe(pred.mean, y[:50]) < 1e-3 * float(np.var(y))


def test_predict_beats_mean_baseline_on_heldout():
    x, y, true_params = paper_synthetic(seed=4, n=600, d=4)
    xtr, ytr, xte, yte = x[:500], y[:500], x[500:], y[500:]
    pred = predict_sbv(true_params, xtr, ytr, xte, bs_pred=5, m_pred=60, seed=4)
    assert mspe(pred.mean, yte) < 0.5 * float(np.var(yte))


def test_predict_ci_coverage_reasonable():
    x, y, true_params = paper_synthetic(seed=5, n=600, d=3)
    xtr, ytr, xte, yte = x[:500], y[:500], x[500:], y[500:]
    pred = predict_sbv(true_params, xtr, ytr, xte, bs_pred=5, m_pred=80, seed=5)
    cover = np.mean((yte >= pred.ci_low) & (yte <= pred.ci_high))
    assert cover > 0.75, cover


def test_rff_draw_matches_exact_covariance_statistics():
    """RFF sample variance ~ sigma2 and lengthscale structure sane."""
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(800, 2))
    params = KernelParams.create(sigma2=2.0, beta=[0.2, 0.2], nugget=1e-8)
    ys = np.stack([sample_gp_rff(s, x, params, n_features=2048) for s in range(8)])
    var = ys.var(axis=1).mean()
    assert 1.2 < var < 3.0, var


def test_metarvm_relevance_structure():
    """dh and dr must barely move the output (paper Fig. 7 finding)."""
    theta = np.tile(
        np.array([[0.5, 0.5, 60.0, 3.0, 2.0, 5.0, 5.0, 3.0, 60.0, 0.55]]), (5, 1)
    )
    base = metarvm_simulate(theta[:1])[0]
    hi = theta.copy()
    hi[0, 7] = 5.0   # dh max
    hi[1, 8] = 90.0  # dr max
    hi[2, 0] = 0.9   # ts max
    hi[3, 6] = 9.0   # ds max
    out = metarvm_simulate(hi)
    assert abs(out[0] - base) / base < 0.02   # dh ~ irrelevant
    assert abs(out[1] - base) / base < 0.10   # dr ~ weak
    assert abs(out[2] - base) / base > 0.25   # ts ~ strong
    assert base > 0 and np.all(np.isfinite(out))


def test_metarvm_dataset_shapes_and_conservation():
    x, y = metarvm_dataset(seed=0, n=64)
    assert x.shape == (64, 10) and y.shape == (64,)
    assert np.all(x >= 0) and np.all(x <= 1)
    assert np.all(y >= 0) and abs(y.mean() - 1.0) < 1e-9


def test_satdrag_like_shapes():
    x, y = satellite_drag_like(0, 200)
    assert x.shape == (200, 8) and np.all(np.isfinite(y))
