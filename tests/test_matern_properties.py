"""Matern kernel math: scipy oracle cross-check + hypothesis invariants."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st  # skips @given tests if absent

from repro.core import KernelParams, cov_matrix, matern
from repro.core.kernels_math import matern_scipy_oracle, scaled_sqdist


@pytest.mark.parametrize("nu", [0.5, 1.5, 2.5, 3.5])
def test_closed_form_matches_bessel_oracle(nu):
    r = np.linspace(1e-6, 12.0, 200)
    got = np.asarray(matern(jnp.asarray(r), nu))
    want = matern_scipy_oracle(r, nu)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(5, 30),
    d=st.integers(1, 8),
    seed=st.integers(0, 10_000),
    nu=st.sampled_from([0.5, 1.5, 2.5, 3.5]),
)
def test_covariance_is_psd(n, d, seed, nu):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(size=(n, d)))
    beta = rng.uniform(0.05, 5.0, size=d)
    params = KernelParams.create(sigma2=rng.uniform(0.1, 3.0), beta=beta, nugget=1e-8)
    k = np.asarray(cov_matrix(x, x, params, nu=nu, add_nugget=True))
    np.testing.assert_allclose(k, k.T, atol=1e-12)
    eig = np.linalg.eigvalsh(k)
    assert eig.min() > -1e-8, eig.min()


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 20), d=st.integers(1, 6), seed=st.integers(0, 10_000)
)
def test_scaled_sqdist_matches_naive(n, d, seed):
    rng = np.random.default_rng(seed)
    x1 = rng.normal(size=(n, d))
    x2 = rng.normal(size=(n + 1, d))
    beta = rng.uniform(0.1, 4.0, size=d)
    got = np.asarray(scaled_sqdist(jnp.asarray(x1), jnp.asarray(x2), jnp.asarray(beta)))
    want = ((x1[:, None, :] - x2[None, :, :]) / beta) ** 2
    np.testing.assert_allclose(got, want.sum(-1), rtol=1e-8, atol=1e-10)


def test_matern_boundary_values():
    for nu in (0.5, 1.5, 2.5, 3.5):
        assert float(matern(jnp.asarray(0.0), nu)) == pytest.approx(1.0)
        assert float(matern(jnp.asarray(50.0), nu)) < 1e-15
