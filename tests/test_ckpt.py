"""Checkpoint layer: roundtrip, atomicity, keep-k GC, elastic restore."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import (
    CheckpointManager, load_checkpoint, restore_train_state, save_checkpoint,
)
from repro.ckpt.checkpoint import latest_checkpoint
from repro.optim import adam_init


def make_state(seed=0):
    k = jax.random.key(seed)
    params = {
        "a": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"w": jax.random.normal(k, (3, 3), jnp.bfloat16)},
    }
    return {"params": params, "opt": adam_init(params),
            "step": jnp.int32(7)}


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    state = make_state()
    path = save_checkpoint(str(tmp_path), 7, state, extras={"stream": {"i": 3}})
    restored, manifest = restore_train_state(path, state)
    assert manifest["step"] == 7
    assert manifest["extras"]["stream"]["i"] == 3
    assert_tree_equal(state, restored)


def test_bf16_preserved(tmp_path):
    state = make_state()
    path = save_checkpoint(str(tmp_path), 1, state)
    flat, _ = load_checkpoint(path)
    assert flat["params.nested.w"].dtype == jnp.bfloat16


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = make_state()
    for step in (1, 2, 3, 4):
        mgr.save(step, state, block=True)
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004"], kept
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000004")


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = make_state()
    mgr.save(10, state)            # async
    mgr.save(11, state)            # waits for 10, then async 11
    mgr.close()
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000010", "step_00000011"], names


def test_elastic_restore_new_sharding(tmp_path):
    """Restore works with device_put onto a (different) sharding tree."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = make_state()
    path = save_checkpoint(str(tmp_path), 3, state)
    from repro.sharding.compat import make_mesh

    mesh = make_mesh((1,), ("workers",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = restore_train_state(path, state, sh)
    assert_tree_equal(state, restored)


def test_crash_mid_save_leaves_no_partial(tmp_path):
    """A .tmp directory must never be visible as a valid checkpoint."""
    state = make_state()
    save_checkpoint(str(tmp_path), 1, state)
    os.makedirs(tmp_path / "step_00000002.tmp0/")  # simulated dead save
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000001")


def test_tuning_record_roundtrip(tmp_path):
    """TuningRecord persists next to the checkpoints and reloads to the
    exact same execution choices (dict-identical), from the directory or
    the json file path; absent records read as None, newer versions
    refuse to load."""
    import pytest

    from repro.ckpt import load_tuning_record
    from repro.tuning import TuningRecord, as_record

    assert load_tuning_record(str(tmp_path)) is None
    assert TuningRecord.load(str(tmp_path)) is None

    rec = TuningRecord(
        n_buckets=4, bs_ceilings=[16, 32], m_ceilings=[30, 30],
        bs_mult=16, m_mult=128, backend="auto", precision="bf16",
        bucket_tiers=["bf16", "f64"], error_budget=None, stream_chunk=65536,
        device_cache_budget=1 << 30, occupancy=0.71,
        histogram={"bs": {"min": 3, "p50": 12, "max": 31, "mean": 13.0}},
        candidates=[{"n_buckets": 4, "precision": "bf16", "time_s": 0.01}],
        meta={"device": "cpu", "n_rows": 100000},
    )
    path = rec.save(str(tmp_path))
    assert os.path.basename(path) == "tuning_record.json"

    for src in (str(tmp_path), path):
        back = TuningRecord.load(src)
        assert back is not None and back.to_dict() == rec.to_dict()
        assert as_record(src).to_dict() == rec.to_dict()
    # a crashed write never corrupts the record: only the final name loads
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))

    newer = dict(rec.to_dict(), version=rec.version + 1)
    with pytest.raises(ValueError):
        TuningRecord.from_dict(newer)
    with pytest.raises(FileNotFoundError):
        as_record(str(tmp_path / "nope"))
