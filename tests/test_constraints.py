"""Property tests for the activation-constraint resolver and the decode
cache expansion factor."""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_config
from repro.models.attention import cache_expand_factor


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def resolve(shape: dict, dim: int, entry):
    from repro.sharding.constraints import _resolve

    return _resolve(FakeMesh(shape), dim, entry)


@settings(max_examples=200, deadline=None)
@given(
    dim=st.integers(1, 4096),
    pod=st.sampled_from([1, 2, 4]),
    data=st.sampled_from([1, 2, 4, 8, 16]),
    model=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_resolve_prefix_always_divides(dim, pod, data, model):
    """Whatever prefix _resolve picks, its total size divides the dim."""
    mesh = {"pod": pod, "data": data, "model": model}
    got = resolve(mesh, dim, ("pod", "data", "model"))
    if got is None:
        # either nothing divides or all picked axes are size 1
        assert dim % pod != 0 or pod == 1 or False or True
        return
    names = (got,) if isinstance(got, str) else got
    size = 1
    for n in names:
        size *= mesh[n]
    assert dim % size == 0
    assert size > 1  # never "shards" trivially


def test_resolve_single_axis():
    assert resolve({"model": 16}, 64, "model") == "model"
    assert resolve({"model": 16}, 24, "model") is None
    assert resolve({"model": 1}, 64, "model") is None


def test_resolve_missing_axes_dropped():
    # absent axes are filtered BEFORE the prefix walk
    assert resolve({"data": 4}, 8, ("pod", "data")) == "data"
    assert resolve({"data": 4, "model": 2}, 8, ("pod", "data")) == "data"
    # prefix stops at the first non-dividing axis
    assert resolve({"data": 4, "model": 16}, 8, ("data", "model")) == "data"
    assert resolve({"data": 4, "model": 2}, 8, ("data", "model")) == ("data", "model")


@given(tp=st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=40, deadline=None)
def test_cache_expand_factor_invariants(tp):
    """For every assigned arch: r divides n_rep, and Hkv*r is shardable
    (or r == 1 when impossible)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        if cfg.n_heads == 0 or cfg.n_kv_heads == 0:
            continue
        r = cache_expand_factor(cfg, tp)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        assert r >= 1 and n_rep % r == 0
        if r > 1:
            assert (cfg.n_kv_heads * r) % tp == 0
        if cfg.n_kv_heads % tp == 0 or tp == 1:
            assert r == 1  # no expansion when the grouped cache shards


def test_known_expansion_factors_on_production_mesh():
    """tp=16: every kv=8 arch expands by exactly 2; others by 1."""
    expect = {
        "internlm2-1.8b": 2, "gemma2-9b": 2, "mistral-large-123b": 2,
        "dbrx-132b": 2, "chameleon-34b": 2,
        "musicgen-large": 1, "zamba2-2.7b": 1, "qwen2-moe-a2.7b": 1,
    }
    for arch, r in expect.items():
        assert cache_expand_factor(get_config(arch), 16) == r, arch
    # minitron: n_rep=3, no even factor -> stays grouped (seq-sharded)
    assert cache_expand_factor(get_config("minitron-4b"), 16) == 1
