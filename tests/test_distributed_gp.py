"""Distributed SBV == serial SBV, run in a subprocess with 8 virtual devices
(the main pytest process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import KernelParams, SBVConfig, preprocess
    from repro.core.vecchia import packed_loglik
    from repro.core.distributed import distributed_loglik, shard_blocks_by_owner
    from repro.core.fit import fit_sbv
    from repro.data.gp_sim import paper_synthetic

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("workers",))

    x, y, params = paper_synthetic(seed=0, n=400, d=4)
    cfg = SBVConfig(n_blocks=48, m=20, n_workers=8, seed=0)
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)

    ll_serial = float(packed_loglik(params, packed))
    sharded = shard_blocks_by_owner(packed, 8)
    ll_dist = float(distributed_loglik(params, sharded, mesh))
    np.testing.assert_allclose(ll_dist, ll_serial, rtol=1e-10)

    # distributed gradient-based fit reduces the loss
    res = fit_sbv(x, y, cfg, inner_steps=15, outer_rounds=1, lr=0.1,
                  distributed=(mesh, "workers"))
    losses = [h[2] for h in res.history]
    assert losses[-1] < losses[0], losses
    print("DIST_OK", ll_dist)
    """
)


def test_distributed_loglik_matches_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "DIST_OK" in r.stdout


PREDICT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import KernelParams
    from repro.core.distributed import distributed_predict, shard_prediction_by_owner
    from repro.core.predict import (
        build_train_index, pack_queries, packed_predict, scatter_packed,
    )
    from repro.data.gp_sim import paper_synthetic

    assert jax.device_count() == 8, jax.device_count()

    x, y, params = paper_synthetic(seed=0, n=400, d=4)
    rng = np.random.default_rng(9)
    xt = rng.uniform(size=(120, 4))

    def scattered(packed, mu, var):
        # gather per-point results regardless of block order/padding
        m = np.zeros(120); v = np.zeros(120)
        scatter_packed(packed, (mu, m), (var, v))
        return m, v

    # serial reference (single vmapped call, no sharding)
    index = build_train_index(x, y, np.asarray(params.beta), 40,
                              n_workers=4, seed=0)
    packed = pack_queries(index, xt, bs_pred=8, m_pred=40, seed=0, n_workers=4)
    m_ser, v_ser = scattered(packed, *packed_predict(params, packed))

    # 1-shard vs 4-shard distributed prediction: same mean/var bitwise-close
    for nw in (1, 4):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:nw]), ("workers",))
        sharded = shard_prediction_by_owner(packed, nw)
        mu, var = distributed_predict(params, sharded, mesh)
        m_d, v_d = scattered(sharded, mu, var)
        np.testing.assert_allclose(m_d, m_ser, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(v_d, v_ser, rtol=1e-12, atol=1e-12)
    print("DIST_PREDICT_OK")
    """
)


def test_distributed_predict_matches_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", PREDICT_SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "DIST_PREDICT_OK" in r.stdout
