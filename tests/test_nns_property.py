"""Property tests: the filtered NNS is EXACT, not approximate.

The two-stage coarse/fine filter (paper Alg. 4 + Eq. 7, with the
radius-augmented coarse admission of DESIGN.md §3) must return exactly
the brute-force answer for every geometry:

* random anisotropic betas (the scaled space the filter operates in),
* degenerate/duplicate points (distance ties),
* n < m and tiny-alpha settings (the doubling-fallback path in
  ``_one_block``, previously untested).

Ties are compared by neighbor DISTANCE multisets (a tie can be broken
either way depending on candidate order); index sets are compared
whenever distances are unique.
"""
import numpy as np
import pytest

from repro.core.blocks import build_blocks, scale_inputs
from repro.core.nns import (
    _FlatBlocks, brute_force_nns, filtered_knn_points, filtered_nns,
)


def _beta(rng, d):
    """Random anisotropic scaling over ~3 orders of magnitude."""
    return 10.0 ** rng.uniform(-1.5, 1.0, size=d)


def _dists(xs, center, idx):
    return np.sqrt(np.sum((xs[idx] - center) ** 2, axis=1))


def _assert_same_neighbors(xs, center, got, want):
    """Equal neighbor count + equal sorted distances; equal index sets
    when distances are unique (ties may break either way)."""
    assert got.size == want.size
    dg = _dists(xs, center, got)
    dw = _dists(xs, center, want)
    np.testing.assert_allclose(dg, dw, rtol=0, atol=1e-9)
    if np.unique(np.round(dw, 9)).size == dw.size:
        assert set(got.tolist()) == set(want.tolist())


def _brute_knn_points(xs, queries, m):
    """O(n)-per-query oracle for the unconstrained prediction kNN."""
    out = []
    for q in queries:
        d2 = np.sum((xs - q) ** 2, axis=1)
        k = min(m, xs.shape[0])
        part = np.argpartition(d2, k - 1)[:k] if xs.shape[0] > k else np.arange(xs.shape[0])
        part = part[np.argsort(d2[part], kind="stable")]
        out.append(part.astype(np.int64))
    return out


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("d", [2, 5])
@pytest.mark.parametrize("alpha", [100.0, 1.5])
def test_filtered_nns_equals_brute_force(seed, d, alpha):
    """alpha=1.5 starves the initial ball so the doubling fallback runs."""
    rng = np.random.default_rng(seed)
    n, m = 160, 12
    x = rng.uniform(size=(n, d))
    beta = _beta(rng, d)
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, 20, 1, beta, seed=seed)
    got = filtered_nns(xs, blocks, m, alpha=alpha)
    want = brute_force_nns(xs, blocks, m)
    for b in range(blocks.n_blocks):
        _assert_same_neighbors(xs, blocks.centers[b], got[b], want[b])


@pytest.mark.parametrize("seed", [0, 5])
def test_filtered_nns_duplicate_points(seed):
    """Exactly-duplicated rows (tied distances) still give exact answers."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(size=(30, 3))
    x = np.concatenate([base, base, base + 1e-12])  # 90 pts, heavy ties
    beta = np.asarray([0.1, 1.0, 10.0])
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, 10, 1, beta, seed=seed)
    got = filtered_nns(xs, blocks, 8, alpha=3.0)
    want = brute_force_nns(xs, blocks, 8)
    for b in range(blocks.n_blocks):
        assert got[b].size == want[b].size
        np.testing.assert_allclose(
            _dists(xs, blocks.centers[b], got[b]),
            _dists(xs, blocks.centers[b], want[b]),
            rtol=0, atol=1e-9,
        )


def test_filtered_nns_fewer_points_than_m():
    """n < m: every block must receive ALL preceding points."""
    rng = np.random.default_rng(7)
    x = rng.uniform(size=(15, 2))
    beta = np.asarray([0.5, 2.0])
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, 5, 1, beta, seed=7)
    got = filtered_nns(xs, blocks, 50, alpha=1.0)
    want = brute_force_nns(xs, blocks, 50)
    ranks = blocks.rank_of_block
    pt_rank = ranks[blocks.labels]
    for b in range(blocks.n_blocks):
        n_prec = int(np.sum(pt_rank < ranks[b]))
        assert got[b].size == n_prec  # everything preceding, nothing more
        _assert_same_neighbors(xs, blocks.centers[b], got[b], want[b])


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("alpha", [100.0, 1.5])
def test_filtered_knn_points_equals_brute_force(seed, alpha):
    rng = np.random.default_rng(seed)
    n, d, m, nq = 180, 4, 15, 37
    x = rng.uniform(size=(n, d))
    beta = _beta(rng, d)
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, 16, 1, beta, seed=seed)
    queries = scale_inputs(rng.uniform(size=(nq, d)), beta)
    got = filtered_knn_points(xs, blocks, queries, m, alpha=alpha)
    want = _brute_knn_points(xs, queries, m)
    for qi in range(nq):
        _assert_same_neighbors(xs, queries[qi], got[qi], want[qi])


def test_filtered_knn_points_m_exceeds_n():
    rng = np.random.default_rng(3)
    x = rng.uniform(size=(12, 3))
    beta = np.ones(3)
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, 4, 1, beta, seed=3)
    queries = scale_inputs(rng.uniform(size=(5, 3)), beta)
    got = filtered_knn_points(xs, blocks, queries, 40, alpha=1.0)
    want = _brute_knn_points(xs, queries, 40)
    for qi in range(5):
        assert got[qi].size == 12  # the whole training set, sorted
        _assert_same_neighbors(xs, queries[qi], got[qi], want[qi])


def test_prebuilt_flat_index_gives_identical_results():
    """The cached ``_FlatBlocks`` (TrainIndex.flat) is a pure reuse: passing
    it must not change a single neighbor."""
    rng = np.random.default_rng(11)
    x = rng.uniform(size=(120, 3))
    beta = _beta(rng, 3)
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, 12, 1, beta, seed=11)
    flat = _FlatBlocks(xs, blocks)
    queries = scale_inputs(rng.uniform(size=(20, 3)), beta)

    a = filtered_knn_points(xs, blocks, queries, 10, flat=flat)
    b = filtered_knn_points(xs, blocks, queries, 10)
    for ga, gb in zip(a, b):
        np.testing.assert_array_equal(ga, gb)
    a = filtered_nns(xs, blocks, 10, flat=flat)
    b = filtered_nns(xs, blocks, 10)
    for ga, gb in zip(a, b):
        np.testing.assert_array_equal(ga, gb)
