"""Cost-model ground truth: trip counts, dot flops, solver custom-calls,
collective ring model."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import CostModel


def compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


@pytest.mark.parametrize("L", [1, 4, 8])
def test_scan_flops_scale_with_trip_count(L):
    w = jnp.zeros((L, 256, 256), jnp.float32)
    x = jnp.zeros((32, 256), jnp.float32)

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    cm = CostModel(compile_text(f, w, x))
    expected = L * 2 * 32 * 256 * 256
    assert abs(cm.flops() - expected) / expected < 0.05


def test_dot_contraction_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    cm = CostModel(compile_text(lambda a, b: a @ b, a, b))
    expected = 2 * 64 * 128 * 32
    assert abs(cm.flops_split()["mxu"] - expected) / expected < 0.01


@pytest.mark.xfail(
    strict=False,
    reason="XLA-version-dependent: some CPU lowerings of cholesky/trsm "
           "inflate counted custom-call FLOPs past the 3x analytic bound",
)
def test_cholesky_trsm_custom_calls():
    a = jnp.eye(32)[None].repeat(4, 0) * 2.0
    b = jnp.ones((4, 32, 8))

    def f(a, b):
        L = jnp.linalg.cholesky(a)
        return jnp.sum(jax.scipy.linalg.solve_triangular(L, b, lower=True))

    cm = CostModel(compile_text(f, a, b))
    potrf = 4 * 32 ** 3 / 3
    trsm = 4 * 32 * 32 * 8
    mxu = cm.flops_split()["mxu"]
    assert mxu >= 0.95 * (potrf + trsm), (mxu, potrf + trsm)
    assert mxu <= 3.0 * (potrf + trsm)


def test_collective_ring_model():
    txt = """
HloModule m, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[1024,256]) -> f32[1024,256] {
  %p = f32[1024,256]{1,0} parameter(0)
  %ag = f32[1024,256]{1,0} all-reduce(%p), channel_id=1, replica_groups=[2,8]<=[16], to_apply=%x
  ROOT %r = f32[1024,256]{1,0} copy(%ag)
}
"""
    cm = CostModel(txt, n_devices=16)
    coll = cm.collective_bytes()
    size = 1024 * 256 * 4
    assert abs(coll["all-reduce"] - 2 * size * 7 / 8) < 1
    assert coll["counts"]["all-reduce"] == 1


def test_bytes_dynamic_update_slice_counts_update_only():
    """Decode-style cache update: bytes ~ update region, not whole cache."""
    cache = jnp.zeros((8, 4096, 64), jnp.float32)
    upd = jnp.ones((8, 1, 64), jnp.float32)

    def f(cache, upd):
        return jax.lax.dynamic_update_slice(cache, upd, (0, 5, 0))

    cm = CostModel(compile_text(f, cache, upd))
    cache_bytes = 8 * 4096 * 64 * 4
    # donation isn't used here so XLA copies the buffer once; what matters
    # is that the model does not charge the DUS itself the full cache.
    assert cm.bytes_accessed() < 2.5 * cache_bytes


def test_while_inside_while_multiplies():
    w = jnp.zeros((3, 4, 128, 128), jnp.float32)
    x = jnp.zeros((16, 128), jnp.float32)

    def f(w, x):
        def outer(x, wo):
            def inner(x, wi):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(inner, x, wo)[0], None
        return jax.lax.scan(outer, x, w)[0].sum()

    cm = CostModel(compile_text(f, w, x))
    expected = 3 * 4 * 2 * 16 * 128 * 128
    assert abs(cm.flops() - expected) / expected < 0.05
