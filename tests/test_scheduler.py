"""Executable spec of the continuous-batching scheduler (scheduler.py).

Everything here runs on an injectable FAKE clock with scripted arrivals —
zero real sleeps — extending the ``MicroBatcher(clock=)`` pattern: every
scheduling decision (admission order, weighted-fair pick, preemption,
window close, cancellation point, backpressure) is replayed
deterministically and asserted exactly.

Two harness layers:

* ``picks()`` / fake pieces — pure policy tests, no numerics: drive
  ``next_chunk``/``complete_chunk`` by hand and assert the decision
  sequence.
* ``SchedHarness`` — the REAL result path (``pack_scheduled`` +
  ``packed_predict`` + ``complete_chunk``), still single-threaded and
  fake-clocked: one ``step()`` per chunk, so any admission interleaving
  can be scripted and its per-request results compared against
  per-request ``predict_sbv`` — the 1e-12 parity contract under
  mid-stream admission, preemption, and cancellation.
"""
import os
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import packed_predict, predict_sbv
from repro.core.predict import build_train_index
from repro.data.gp_sim import paper_synthetic
from repro.serving import (
    AdmissionQueueFull, BatchingPolicy, ContinuousScheduler, PipelineConfig,
    SchedulerPolicy, ServeRequest, SpoolResultSink, pack_scheduled,
    request_chunk_bounds,
)
from repro.serving.telemetry import ServerStats

pytestmark = pytest.mark.scheduler


# -- harness -----------------------------------------------------------


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def mk_req(n: int = 4, slo: str = "interactive", d: int = 2) -> ServeRequest:
    return ServeRequest(x=np.zeros((n, d)), future=Future(), slo=slo)


def mk_sched(clock=None, stats=None, chunk_size=4, bs_pred=2, **policy_kw):
    """Scheduler with a zero batching window: admission happens at the
    first boundary after submit unless a test opts into a window."""
    window = policy_kw.pop("window", BatchingPolicy(max_wait_s=0.0))
    return ContinuousScheduler(
        policy=SchedulerPolicy(**policy_kw), window=window,
        chunk_size=chunk_size, bs_pred=bs_pred,
        clock=clock or FakeClock(), stats=stats,
    )


def fake_complete(sched, item):
    """Land one chunk without numerics: a minimal piece whose scatter
    writes recognizable values (the within-request row index)."""
    n = item.stop - item.start
    piece = SimpleNamespace(
        q_idx=np.arange(item.start, item.stop),
        q_mask=np.ones(n, dtype=bool),
    )
    vals = np.arange(item.start, item.stop, dtype=float)
    sched.complete_chunk(item, piece, vals, vals + 0.5)


def picks(sched, limit=100, complete=True):
    """Drain the scheduler single-threadedly, returning the pick sequence
    (the schedule itself — what the policy tests assert on)."""
    out = []
    for _ in range(limit):
        item = sched.next_chunk()
        if item is None:
            return out
        out.append(item)
        if complete:
            fake_complete(sched, item)
    raise AssertionError("scheduler did not drain")


# -- chunk protocol ----------------------------------------------------


def test_request_chunk_bounds_mirror_iter_query_chunks():
    """The scheduler's per-request chunking is EXACTLY the
    ``iter_query_chunks`` stepping (step = max(chunk_size, bs_pred)) —
    the precondition of the parity contract."""
    assert request_chunk_bounds(10, 4, 2) == [(0, 4), (4, 8), (8, 10)]
    assert request_chunk_bounds(10, None, 25) == [(0, 10)]
    assert request_chunk_bounds(3, 4, 8) == [(0, 3)]   # step = bs_pred floor
    assert request_chunk_bounds(8, 4, 2) == [(0, 4), (4, 8)]
    assert request_chunk_bounds(1, 4096, 25) == [(0, 1)]


# -- admission ordering / weighted fairness ----------------------------


def test_interactive_preempts_queued_bulk_in_admission_order():
    """A bulk sweep is running; an interactive arrival enters at the
    running batch's virtual time and its chunk is picked at the NEXT
    boundary, ahead of the bulk request's remaining chunks."""
    clock = FakeClock()
    stats = ServerStats()
    sched = mk_sched(clock=clock, stats=stats)
    bulk = mk_req(12, slo="bulk")        # 3 chunks of 4
    sched.submit(bulk)
    first = sched.next_chunk()
    assert first.request is bulk and first.ci == 0

    clock.advance(0.001)
    inter = mk_req(4, slo="interactive")
    sched.submit(inter)
    nxt = sched.next_chunk()
    assert nxt.request is inter          # preempts bulk chunks 1, 2
    assert stats.n_preempted >= 1        # jumped ahead of older bulk work
    fake_complete(sched, first)
    fake_complete(sched, nxt)
    rest = picks(sched)
    assert [it.request for it in rest] == [bulk, bulk]
    assert [it.ci for it in rest] == [1, 2]


def test_weighted_fair_keeps_bulk_starvation_free():
    """Both classes backlogged: interactive (weight 3) gets 3 of every 4
    boundaries, bulk (weight 1) gets the 4th — every 4-pick window
    contains BOTH classes, so neither starves."""
    sched = mk_sched()
    inter = mk_req(9 * 4, slo="interactive")   # 9 chunks
    bulk = mk_req(9 * 4, slo="bulk")           # 9 chunks
    sched.submit(bulk)     # bulk submitted FIRST — weights still hold
    sched.submit(inter)
    seq = [it.request.slo for it in picks(sched, limit=30, complete=False)]
    assert len(seq) == 18
    # 3:1 share while both are backlogged (interactive drains after its
    # 9 chunks; the tail is all bulk).
    both = seq[:12]
    assert both.count("interactive") == 9 and both.count("bulk") == 3
    for i in range(len(both) - 3):
        win = both[i:i + 4]
        assert "bulk" in win and "interactive" in win
    # Per-request chunk order is always in-order regardless of class.
    for slo in ("interactive", "bulk"):
        cis = [it for it, s in zip(range(len(seq)), seq) if s == slo]
        assert cis == sorted(cis)


def test_same_class_requests_run_fifo():
    sched = mk_sched()
    reqs = [mk_req(4, slo="interactive") for _ in range(3)]
    for r in reqs:
        sched.submit(r)
    got = [it.request for it in picks(sched, complete=False)]
    assert got == reqs


# -- cancellation ------------------------------------------------------


def test_cancellation_takes_effect_within_one_chunk():
    """Cancel between boundaries: the already-dispatched chunk completes
    (result discarded), every remaining chunk is dropped at the next
    boundary, and the future reports cancelled."""
    stats = ServerStats()
    sched = mk_sched(stats=stats)
    req = mk_req(12, slo="bulk")         # 3 chunks
    sched.submit(req)
    first = sched.next_chunk()
    assert first.ci == 0
    assert sched.cancel(req.future)
    # The in-flight chunk lands AFTER the cancel — discarded, no error.
    fake_complete(sched, first)
    assert sched.next_chunk() is None    # chunks 1, 2 never scheduled
    assert req.future.cancelled()
    with pytest.raises(Exception):
        req.future.result(timeout=0)
    assert stats.n_cancelled == 1


def test_cancel_queued_request_before_admission():
    stats = ServerStats()
    sched = mk_sched(stats=stats)
    req = mk_req(4)
    sched.submit(req)
    assert sched.queue_depth_points == 4
    # Plain future.cancel() (no scheduler handle needed) works too:
    # futures are never marked running before resolution.
    assert req.future.cancel()
    assert sched.next_chunk() is None
    assert sched.queue_depth_points == 0
    assert req.future.cancelled()
    assert stats.n_cancelled == 1


def test_cancel_unknown_future_is_refused():
    sched = mk_sched()
    assert not sched.cancel(Future())


def test_cancel_after_completion_is_a_noop():
    sched = mk_sched()
    req = mk_req(4)
    sched.submit(req)
    picks(sched)
    mean, var = req.future.result(timeout=0)
    assert not sched.cancel(req.future)   # already resolved: unknown now
    np.testing.assert_array_equal(mean, np.arange(4.0))
    np.testing.assert_array_equal(var, np.arange(4.0) + 0.5)


# -- backpressure ------------------------------------------------------


def test_bounded_admission_queue_raises_and_recovers():
    stats = ServerStats()
    sched = mk_sched(stats=stats, queue_bound=10)
    sched.submit(mk_req(8))
    with pytest.raises(AdmissionQueueFull):
        sched.submit(mk_req(4))          # 8 + 4 > 10
    assert stats.n_rejected == 1
    sched.submit(mk_req(2))              # 8 + 2 == 10: exactly at bound
    item = sched.next_chunk()            # boundary: queue drains into batch
    assert item is not None
    assert sched.queue_depth_points == 0
    sched.submit(mk_req(10))             # room again after admission
    assert stats.queue_depth_peak == 10


def test_max_active_requests_caps_running_batch():
    sched = mk_sched(max_active_requests=2)
    reqs = [mk_req(8) for _ in range(4)]   # 2 chunks each
    for r in reqs:
        sched.submit(r)
    first = sched.next_chunk()
    assert first.request is reqs[0]
    assert sched.queue_depth_points == 16  # reqs[2:] still queued
    # Completing the first two requests frees slots for the rest.
    fake_complete(sched, first)
    for it in picks(sched):
        pass
    assert all(r.future.done() for r in reqs)


# -- adaptive window interaction ---------------------------------------


def test_idle_window_defers_admission_until_close_or_trip():
    """Device idle: the (adaptive) batching window applies exactly as in
    drain mode — admission waits for coalescing partners until the
    window elapses on the fake clock, max_points trips, or flush()."""
    clock = FakeClock()
    window = BatchingPolicy(max_points=100, max_wait_s=0.010)
    sched = mk_sched(clock=clock, window=window)
    sched.submit(mk_req(4))
    assert sched.next_chunk() is None          # window open, device idle
    clock.advance(0.005)
    assert sched.next_chunk() is None          # still open
    clock.advance(0.006)                       # past t_arrival + 10ms
    assert sched.next_chunk() is not None

    # flush() forces admission with the window still open.
    sched2 = mk_sched(clock=FakeClock(), window=window)
    sched2.submit(mk_req(4))
    assert sched2.next_chunk() is None
    sched2.flush()
    assert sched2.next_chunk() is not None

    # max_points trips the window immediately.
    sched3 = mk_sched(clock=FakeClock(),
                      window=BatchingPolicy(max_points=8, max_wait_s=30.0))
    sched3.submit(mk_req(8))
    assert sched3.next_chunk() is not None


def test_busy_device_admits_immediately_despite_window():
    """The window is an IDLE-only tax: while the running batch is
    non-empty, a boundary admits new arrivals at once (that is the whole
    point of continuous batching)."""
    clock = FakeClock()
    sched = mk_sched(clock=clock,
                     window=BatchingPolicy(max_points=100, max_wait_s=30.0))
    bulk = mk_req(8, slo="bulk")
    sched.submit(bulk)
    sched.flush()                              # start the running batch
    assert sched.next_chunk().request is bulk
    inter = mk_req(4, slo="interactive")
    sched.submit(inter)                        # 30s window — but busy
    assert sched.next_chunk().request is inter


def test_adaptive_window_shrinks_with_dense_arrivals():
    """Adaptive EMA machinery (shared ArrivalWindow) drives the idle
    gate: dense scripted arrivals shrink the wait to window_factor*EMA,
    so admission happens earlier than max_wait_s."""
    clock = FakeClock()
    window = BatchingPolicy(max_points=10_000, max_wait_s=0.010,
                            adaptive=True, window_factor=2.0, ema_alpha=1.0)
    sched = mk_sched(clock=clock, window=window)
    for _ in range(4):                         # 1ms gaps -> EMA = 1ms
        sched.submit(mk_req(1))
        clock.advance(0.001)
    # Window is now 2ms; the LAST arrival is 1ms old, 1ms to go.
    assert sched.next_chunk() is None
    clock.advance(0.0015)
    assert sched.next_chunk() is not None


# -- the parity contract (real compute, scripted schedules) ------------


@pytest.fixture(scope="module")
def problem():
    x, y, params = paper_synthetic(seed=0, n=80, d=3)
    cfg = PipelineConfig(bs_pred=4, m_pred=16, chunk_size=8)
    index = build_train_index(x, y, np.asarray(params.beta), cfg.m_pred,
                              seed=11)
    return params, x, y, index, cfg


class SchedHarness:
    """Scripted-arrival executor over the REAL result path: one chunk of
    real pack+predict per step(), single-threaded, fake-clocked."""

    def __init__(self, problem, seed=11, **policy_kw):
        self.params, self.x, self.y, self.index, self.cfg = problem
        self.seed = seed
        self.clock = FakeClock()
        self.stats = ServerStats()
        window = policy_kw.pop("window", BatchingPolicy(max_wait_s=0.0))
        self.sched = ContinuousScheduler(
            policy=SchedulerPolicy(**policy_kw), window=window,
            chunk_size=self.cfg.chunk_size, bs_pred=self.cfg.bs_pred,
            clock=self.clock, stats=self.stats,
        )

    def submit(self, xq, slo="interactive"):
        req = ServeRequest(x=np.asarray(xq, dtype=np.float64),
                           future=Future(), slo=slo)
        self.sched.submit(req)
        return req.future

    def step(self) -> bool:
        item = self.sched.next_chunk()
        if item is None:
            return False
        packed = pack_scheduled(self.index, self.cfg, item, seed=self.seed)
        mu, var = packed_predict(self.params, packed, nu=self.cfg.nu,
                                 backend=self.cfg.backend)
        self.sched.complete_chunk(item, packed, mu, var)
        return True

    def drain(self):
        self.sched.close()
        while self.step():
            pass

    def reference(self, xq):
        ref = predict_sbv(self.params, self.x, self.y, np.asarray(xq),
                          bs_pred=self.cfg.bs_pred, m_pred=self.cfg.m_pred,
                          seed=self.seed, chunk_size=self.cfg.chunk_size,
                          n_sims=2)
        return np.asarray(ref.mean), np.asarray(ref.var)

    def assert_matches_reference(self, fut, xq):
        result = fut.result(timeout=0)
        if isinstance(result, SpoolResultSink):
            mean, var = result.materialize()
        else:                       # bare scheduler: plain (mean, var) tuple
            mean, var = result
        ref_mean, ref_var = self.reference(xq)
        np.testing.assert_allclose(mean, ref_mean, rtol=0, atol=1e-12)
        np.testing.assert_allclose(var, ref_var, rtol=0, atol=1e-12)


def test_mid_stream_admission_preserves_per_request_parity(problem):
    """THE contract: requests admitted mid-stream — interleaved with
    running bulk chunks, preempting each other — still match their own
    per-request predict_sbv call to 1e-12, because the scheduler only
    reorders which chunk runs when."""
    rng = np.random.default_rng(42)
    h = SchedHarness(problem)
    xs, futs = [], []

    def add(n, slo):
        xq = rng.uniform(size=(n, 3))
        xs.append(xq)
        futs.append(h.submit(xq, slo=slo))

    add(20, "bulk")          # 3 chunks
    assert h.step()          # bulk chunk 0 running
    add(3, "interactive")    # arrives mid-stream, preempts
    assert h.step()
    add(17, "bulk")          # second sweep joins the running batch
    add(1, "interactive")
    h.drain()
    for fut, xq in zip(futs, xs):
        h.assert_matches_reference(fut, xq)
    by_class = h.stats.summary()["by_class"]
    assert by_class["interactive"]["n"] == 2
    assert by_class["bulk"]["n"] == 2


def test_cancellation_mid_stream_leaves_others_exact(problem):
    rng = np.random.default_rng(43)
    h = SchedHarness(problem)
    x_keep = rng.uniform(size=(12, 3))
    x_dead = rng.uniform(size=(20, 3))
    fut_keep = h.submit(x_keep, slo="interactive")
    fut_dead = h.submit(x_dead, slo="bulk")
    assert h.step()                        # something is in flight
    h.sched.cancel(fut_dead)
    h.drain()
    assert fut_dead.cancelled()
    h.assert_matches_reference(fut_keep, x_keep)


def test_spool_sink_result_roundtrips_exactly(problem, tmp_path):
    """Bulk results routed through the disk spool reproduce the in-RAM
    result bit-exactly (float64 .npz round-trip), and cleanup removes
    every spooled file."""
    rng = np.random.default_rng(44)
    h = SchedHarness(problem, spool_threshold=16, spool_dir=str(tmp_path))
    x_small = rng.uniform(size=(6, 3))     # below threshold: RAM
    x_big = rng.uniform(size=(30, 3))      # above: spooled, 4 chunks
    fut_small = h.submit(x_small, slo="interactive")
    fut_big = h.submit(x_big, slo="bulk")
    h.drain()
    h.assert_matches_reference(fut_small, x_small)
    assert fut_small.result(timeout=0).sink is None \
        if hasattr(fut_small.result(timeout=0), "sink") else True

    sink = fut_big.result(timeout=0)
    assert isinstance(sink, SpoolResultSink)
    assert sink.n_chunks == 4
    assert sink.spooled_bytes > 0
    # Bounded-memory read path covers every row exactly once...
    seen = np.concatenate([idx for idx, _, _ in sink.iter_chunks()])
    np.testing.assert_array_equal(np.sort(seen), np.arange(30))
    # ... and materialize() equals the per-request reference to 1e-12.
    mean, var = sink.materialize()
    ref_mean, ref_var = h.reference(x_big)
    np.testing.assert_allclose(mean, ref_mean, rtol=0, atol=1e-12)
    np.testing.assert_allclose(var, ref_var, rtol=0, atol=1e-12)
    spooled = [f for f in os.listdir(str(tmp_path) + "/req_000001")]
    assert spooled
    sink.cleanup()
    assert not os.path.exists(str(tmp_path) + "/req_000001")


# -- property test: random interleavings (hypothesis) ------------------


from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=8, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=18),
                   min_size=1, max_size=4),
    slos=st.lists(st.sampled_from(["interactive", "bulk"]),
                  min_size=4, max_size=4),
    ops=st.lists(st.tuples(st.sampled_from(["step", "submit", "cancel",
                                            "flush"]),
                           st.integers(min_value=0, max_value=3)),
                 max_size=20),
    data_seed=st.integers(min_value=0, max_value=2**16),
)
def test_random_interleavings_match_reference(problem_cached, sizes, slos,
                                              ops, data_seed):
    """Property: for ANY interleaving of submit/cancel/flush/step, every
    non-cancelled request's result equals its own predict_sbv reference,
    and every future resolves exactly once (no stranded, no double-set —
    a double set_result would raise InvalidStateError inside the run)."""
    rng = np.random.default_rng(data_seed)
    h = SchedHarness(problem_cached)
    xs = [rng.uniform(size=(n, 3)) for n in sizes]
    futs = [None] * len(xs)
    next_submit = 0
    cancelled = set()

    def do_submit():
        nonlocal next_submit
        if next_submit < len(xs):
            i = next_submit
            futs[i] = h.submit(xs[i], slo=slos[i % len(slos)])
            next_submit += 1

    do_submit()
    for op, k in ops:
        if op == "submit":
            do_submit()
        elif op == "step":
            h.step()
        elif op == "flush":
            h.sched.flush()
        elif op == "cancel" and k < next_submit:
            if h.sched.cancel(futs[k]):
                cancelled.add(k)
        h.clock.advance(0.001)
    while next_submit < len(xs):
        do_submit()
    h.drain()

    for i, (fut, xq) in enumerate(zip(futs, xs)):
        assert fut.done()                          # resolved exactly once
        if fut.cancelled():
            assert i in cancelled
        else:
            h.assert_matches_reference(fut, xq)


@pytest.fixture(scope="module")
def problem_cached(problem):
    # Warm the jit cache once so hypothesis examples reuse the single
    # compiled (padded) shape instead of recompiling per example.
    params, x, y, index, cfg = problem
    item = SimpleNamespace(
        entry=SimpleNamespace(req=SimpleNamespace(x=np.zeros((8, 3)))),
        start=0, stop=8, ci=0)
    packed = pack_scheduled(index, cfg, item, seed=11)
    packed_predict(params, packed, nu=cfg.nu, backend=cfg.backend)
    return problem


# -- threaded end-to-end (GPServer in scheduler mode) ------------------


def test_server_continuous_mode_end_to_end(problem):
    """Real threads, real clock: GPServer(config.scheduler=...) serves a
    mixed SLO workload with a spooled bulk sweep and a cancellation, and
    every completed request matches its per-request reference."""
    from repro.serving import GPServer, GPServerConfig

    params, x, y, index, cfg = problem
    rng = np.random.default_rng(45)
    config = GPServerConfig(
        pipeline=cfg,
        policy=BatchingPolicy(max_points=4096, max_wait_s=0.002),
        scheduler=SchedulerPolicy(spool_threshold=64, queue_bound=100_000),
        seed=11,
    )
    server = GPServer(params, x, y, config)
    reqs = [(rng.uniform(size=(n, 3)), slo)
            for n, slo in [(5, "interactive"), (70, "bulk"),
                           (2, "interactive"), (12, "interactive")]]
    with server:
        futs = [server.submit(xq, slo=slo) for xq, slo in reqs]
        victim = server.submit(rng.uniform(size=(40, 3)), slo="bulk")
        assert server.cancel(victim)
        server.flush()
        results = [f.result(timeout=600) for f in futs]

    assert victim.cancelled()
    for (xq, _slo), res in zip(reqs, results):
        ref = predict_sbv(params, x, y, xq, bs_pred=cfg.bs_pred,
                          m_pred=cfg.m_pred, seed=11,
                          chunk_size=cfg.chunk_size, n_sims=2)
        if res.sink is not None:
            mean, var = res.sink.materialize()
            res.sink.cleanup()
        else:
            mean, var = res.mean, res.var
        np.testing.assert_allclose(mean, np.asarray(ref.mean),
                                   rtol=0, atol=1e-12)
        np.testing.assert_allclose(var, np.asarray(ref.var),
                                   rtol=0, atol=1e-12)
    summary = server.stats.summary()
    assert summary["n_cancelled"] == 1
    assert summary["by_class"]["interactive"]["n"] == 3
    assert summary["by_class"]["bulk"]["n"] == 1
    assert summary["by_class"]["interactive"]["latency_p99_s"] > 0
