"""Serving equivalence: the persistent server is a rearrangement of the
packed prediction pipeline, not a new numerical path.

Contracts (ISSUE satellite):
(a) micro-batched multi-request results == single-call ``predict_sbv`` on
    the concatenated queries (coalescing is concatenation);
(b) double-buffered pipeline == synchronous chunk loop, bitwise;
(c) tile-padded (8x128) kernel output == untiled ref to <= 1e-5;
(d) the max-points policy splits oversized windows into multiple batches
    and every request still gets exact-GP-quality answers;
(e) latency smoke: a batch is answered under a generous wall-clock bound
    (the CI serving gate).
"""
import threading

import numpy as np
import pytest

from repro.core import exact_predict, packed_predict, predict_sbv
from repro.core.packing import tile_predict_shapes
from repro.core.predict import build_train_index, pack_queries
from repro.data.gp_sim import paper_synthetic
from repro.serving import (
    BatchingPolicy, GPServer, GPServerConfig, PipelineConfig,
    predict_pipelined, predict_synchronous,
)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def problem():
    x, y, params = paper_synthetic(seed=0, n=400, d=4)
    rng = np.random.default_rng(7)
    requests = [rng.uniform(size=(n, 4)) for n in (33, 5, 80, 1, 41)]
    return params, x, y, requests


def test_microbatched_requests_match_single_predict_sbv(problem):
    params, x, y, requests = problem
    concat = np.concatenate(requests, axis=0)
    cfg = GPServerConfig(
        pipeline=PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64),
        policy=BatchingPolicy(max_points=100_000, max_wait_s=30.0),
        seed=3,
    )
    server = GPServer(params, x, y, cfg)
    with server:
        futs = [server.submit(r) for r in requests]
        server.flush()  # everything queued -> ONE micro-batch
        results = [f.result(timeout=300) for f in futs]

    ref = predict_sbv(params, x, y, concat, bs_pred=8, m_pred=32, seed=3,
                      chunk_size=64, n_sims=2)
    got_mean = np.concatenate([r.mean for r in results])
    got_var = np.concatenate([r.var for r in results])
    np.testing.assert_allclose(got_mean, ref.mean, rtol=0, atol=1e-12)
    np.testing.assert_allclose(got_var, ref.var, rtol=0, atol=1e-12)

    stats = server.stats.summary()
    assert stats["n_batches"] == 1
    assert stats["n_requests"] == len(requests)
    assert stats["n_points"] == concat.shape[0]


def test_pipelined_equals_synchronous(problem):
    params, x, y, requests = problem
    xt = np.concatenate(requests, axis=0)
    index = build_train_index(x, y, np.asarray(params.beta), 32, seed=1)
    cfg = PipelineConfig(bs_pred=8, m_pred=32, chunk_size=48)
    m_sync, v_sync = predict_synchronous(params, index, xt, cfg, seed=1)
    m_pipe, v_pipe = predict_pipelined(params, index, xt, cfg, seed=1)
    np.testing.assert_array_equal(m_pipe, m_sync)
    np.testing.assert_array_equal(v_pipe, v_sync)


def test_tiled_kernel_matches_untiled_ref(problem):
    params, x, y, requests = problem
    xt = np.concatenate(requests, axis=0)
    index = build_train_index(x, y, np.asarray(params.beta), 24, seed=2)
    packed = pack_queries(index, xt, bs_pred=8, m_pred=24, seed=2)

    mu_r, var_r = packed_predict(params, packed, backend="ref")

    # In-jit tiling (the compiled TPU entry point, interpret mode here).
    mu_t, var_t = packed_predict(params, packed, backend="pallas_tiled")
    assert np.asarray(mu_t).shape == packed.q_mask.shape  # sliced back
    np.testing.assert_allclose(np.asarray(mu_t), np.asarray(mu_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_t), np.asarray(var_r),
                               rtol=1e-5, atol=1e-5)

    # Host-side tile padding: lane-aligned shapes, padded slots inert.
    tiled = packed.pad_to_tiles()
    bs_t, m_t = tile_predict_shapes(packed.bs_pred, packed.m_pred)
    assert (tiled.bs_pred, tiled.m_pred) == (bs_t, m_t)
    assert bs_t % 8 == 0 and m_t % 128 == 0
    assert tiled.n_queries == packed.n_queries
    mu_h, var_h = packed_predict(params, tiled, backend="pallas")
    msk = packed.q_mask
    np.testing.assert_allclose(
        np.asarray(mu_h)[:, : packed.bs_pred][msk], np.asarray(mu_r)[msk],
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(var_h)[:, : packed.bs_pred][msk], np.asarray(var_r)[msk],
        rtol=1e-5, atol=1e-5)


def test_max_points_policy_splits_batches_and_stays_exact():
    """Oversized windows split into several micro-batches; every request
    still matches the exact GP (m_pred >= n_train makes the block
    conditional THE exact conditional, so correctness is checkable
    per-request regardless of how the batcher grouped them)."""
    x, y, params = paper_synthetic(seed=4, n=60, d=3)
    rng = np.random.default_rng(5)
    requests = [rng.uniform(size=(n, 3)) for n in (20, 20, 20, 20)]
    cfg = GPServerConfig(
        pipeline=PipelineConfig(bs_pred=8, m_pred=80, chunk_size=None),
        policy=BatchingPolicy(max_points=40, max_wait_s=30.0),
        seed=4,
    )
    server = GPServer(params, x, y, cfg)
    with server:
        futs = [server.submit(r) for r in requests]
        server.flush()
        results = [f.result(timeout=300) for f in futs]
    assert server.stats.summary()["n_batches"] >= 2
    for req, res in zip(requests, results):
        em, ev = exact_predict(params, x, y, req)
        np.testing.assert_allclose(res.mean, np.asarray(em), atol=1e-4, rtol=0)
        np.testing.assert_allclose(res.var, np.asarray(ev), atol=1e-4, rtol=0)


def test_stop_timeout_fails_queued_futures():
    """Regression: stop() used to raise TimeoutError while still-queued
    requests kept their futures pending forever. Now every queued future
    is failed BEFORE the TimeoutError propagates, so no client blocks on
    a request the wedged dispatcher will never pick up."""
    x, y, params = paper_synthetic(seed=9, n=40, d=2)
    cfg = GPServerConfig(
        pipeline=PipelineConfig(bs_pred=4, m_pred=16, chunk_size=None),
        # max_points=1: every submit trips the window -> one request per
        # batch, so the second submit stays queued behind the wedged first.
        policy=BatchingPolicy(max_points=1, max_wait_s=30.0),
        seed=9,
    )
    server = GPServer(params, x, y, cfg)
    entered, release = threading.Event(), threading.Event()

    def wedged_process(batch):
        entered.set()
        release.wait(timeout=60.0)
        for req in batch:
            if req.future.set_running_or_notify_cancel():
                req.future.set_result("late")

    server._process = wedged_process
    server.start()
    rng = np.random.default_rng(0)
    fut1 = server.submit(rng.uniform(size=(2, 2)))
    assert entered.wait(timeout=30.0)          # dispatcher wedged on req 1
    fut2 = server.submit(rng.uniform(size=(2, 2)))

    with pytest.raises(TimeoutError):
        server.stop(timeout_s=0.2)
    # The queued future fails promptly instead of hanging forever.
    with pytest.raises(RuntimeError, match="timed out"):
        fut2.result(timeout=5.0)

    release.set()                              # un-wedge; clean shutdown
    server.stop(timeout_s=60.0)
    assert fut1.result(timeout=5.0) == "late"


def test_latency_smoke_and_telemetry(problem):
    """CI serving gate: a warmed server answers a batch well under a
    generous wall-clock bound and reports sane telemetry."""
    params, x, y, requests = problem
    cfg = GPServerConfig(
        pipeline=PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64),
        policy=BatchingPolicy(max_points=4096, max_wait_s=0.005),
        seed=6,
    )
    server = GPServer(params, x, y, cfg)
    with server:
        server.warmup()
        res = server.predict(requests[0], timeout_s=60.0)
    assert res.latency_s < 60.0
    assert res.queue_wait_s <= res.latency_s
    assert np.all(np.isfinite(res.mean)) and np.all(res.var > 0)
    stats = server.stats.summary()
    assert stats["n_requests"] == 2  # warmup + request
    assert stats["n_compiled_shapes"] >= 1
    assert stats["latency_p95_s"] > 0


def test_adaptive_window_scales_with_interarrival_ema():
    """Deterministic fake-clock check of the adaptive batching window:
    dense arrivals shrink the wait toward window_factor * EMA; sparse
    arrivals clamp it back at max_wait_s; adaptive=False is inert."""
    from concurrent.futures import Future

    from repro.serving.batching import MicroBatcher, PredictRequest

    t = [0.0]
    clock = lambda: t[0]
    mk = lambda: PredictRequest(x=np.zeros((1, 2)), future=Future())

    pol = BatchingPolicy(max_wait_s=0.010, adaptive=True,
                         window_factor=4.0, ema_alpha=0.5)
    b = MicroBatcher(pol, clock=clock)
    # no observations yet -> full window
    assert b.effective_wait_s() == pytest.approx(0.010)
    b.put(mk())  # first arrival: still no gap sample
    assert b.effective_wait_s() == pytest.approx(0.010)

    # dense traffic: 1ms gaps -> EMA=1ms -> window = 4ms < max_wait
    for _ in range(6):
        t[0] += 0.001
        b.put(mk())
    assert b.effective_wait_s() == pytest.approx(0.004, rel=1e-6)

    # one sparse gap (1s) with alpha=0.5 blows the EMA past the cap
    t[0] += 1.0
    b.put(mk())
    assert b.effective_wait_s() == pytest.approx(0.010)

    # exact EMA arithmetic: gaps 2ms then 4ms from a fresh batcher
    b2 = MicroBatcher(pol, clock=clock)
    b2.put(mk())
    t[0] += 0.002
    b2.put(mk())   # EMA = 2ms
    t[0] += 0.004
    b2.put(mk())   # EMA = 0.5*2 + 0.5*4 = 3ms -> window = min(10, 12) ms
    assert b2.effective_wait_s() == pytest.approx(0.010)
    assert b2._ema_gap_s == pytest.approx(0.003)

    # adaptive off: window pinned at max_wait_s regardless of traffic
    b3 = MicroBatcher(BatchingPolicy(max_wait_s=0.010, adaptive=False),
                      clock=clock)
    for _ in range(5):
        t[0] += 0.0001
        b3.put(mk())
    assert b3.effective_wait_s() == pytest.approx(0.010)


def test_adaptive_deadline_drives_next_batch():
    """next_batch's deadline runs on the batcher's (injectable) clock:
    once the fake clock passes t_arrival + effective_wait, the dispatcher
    returns the partial batch immediately instead of sleeping out
    max_wait_s in real time."""
    import time
    from concurrent.futures import Future

    from repro.serving.batching import MicroBatcher, PredictRequest

    t = [0.0]
    b = MicroBatcher(
        BatchingPolicy(max_points=10_000, max_wait_s=30.0, adaptive=True,
                       window_factor=2.0, ema_alpha=1.0),
        clock=lambda: t[0],
    )
    # Establish a 1ms-gap EMA -> window = 2ms (vs the 30s hard cap).
    for _ in range(3):
        b.put(PredictRequest(x=np.zeros((1, 2)), future=Future()))
        t[0] += 0.001
    assert b.effective_wait_s() == pytest.approx(0.002)
    # Clock is now past every arrival's deadline: next_batch must drain
    # the queue and return without waiting out the 30s cap in real time.
    t[0] += 1.0
    t0 = time.monotonic()
    batch = b.next_batch()
    assert len(batch) == 3
    assert time.monotonic() - t0 < 5.0  # returned immediately, not in 30s


def test_bucketed_serving_matches_uniform(problem):
    """PipelineConfig(n_buckets=K): bucketed micro-batches reproduce the
    uniform path to 1e-10 and report padding occupancy in (0, 1]."""
    params, x, y, requests = problem
    from repro.core.predict import build_train_index
    from repro.serving.telemetry import ServerStats

    index = build_train_index(x, y, np.asarray(params.beta), 32, seed=0)
    xt = np.concatenate(requests, axis=0)
    cfg_u = PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64)
    cfg_b = PipelineConfig(bs_pred=8, m_pred=32, chunk_size=64, n_buckets=4)
    stats = ServerStats()
    m_u, v_u = predict_synchronous(params, index, xt, cfg_u, seed=0)
    m_b, v_b = predict_synchronous(params, index, xt, cfg_b, seed=0,
                                   stats=stats)
    np.testing.assert_allclose(m_b, m_u, atol=1e-10, rtol=0)
    np.testing.assert_allclose(v_b, v_u, atol=1e-10, rtol=0)
    # double-buffered bucketed == sync bucketed, bitwise
    m_p, v_p = predict_pipelined(params, index, xt, cfg_b, seed=0)
    assert np.array_equal(m_p, m_b) and np.array_equal(v_p, v_b)
    occ = stats.summary()["padding_occupancy"]
    assert 0.0 < occ <= 1.0
