"""Flash path == XLA path through the full model forward (interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params, lm_loss, prefill_step


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen2-moe-a2.7b"])
def test_flash_forward_matches_xla(arch):
    cfg_x = get_config(arch).reduced(use_flash="never")
    cfg_f = get_config(arch).reduced(use_flash="always")
    params = init_params(jax.random.key(0), cfg_x)
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg_x.vocab, (2, 64)), jnp.int32)
    lab = jnp.asarray(rng.integers(0, cfg_x.vocab, (2, 64)), jnp.int32)

    lx = float(lm_loss(params, tok, lab, cfg_x))
    lf = float(lm_loss(params, tok, lab, cfg_f))
    np.testing.assert_allclose(lf, lx, rtol=5e-3)


def test_flash_prefill_matches_xla():
    cfg_x = get_config("internlm2-1.8b").reduced(use_flash="never")
    cfg_f = get_config("internlm2-1.8b").reduced(use_flash="always")
    params = init_params(jax.random.key(1), cfg_x)
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.integers(0, cfg_x.vocab, (2, 32)), jnp.int32)
    gx, cx = prefill_step(params, tok, cfg_x, cache_len=48)
    gf, cf = prefill_step(params, tok, cfg_f, cache_len=48)
    np.testing.assert_allclose(np.asarray(gf, np.float32),
                               np.asarray(gx, np.float32), rtol=3e-2, atol=3e-2)
    # bf16 drift amplifies through layers; 99.98% of elements match at 3e-2
    np.testing.assert_allclose(np.asarray(cf["k"], np.float32),
                               np.asarray(cx["k"], np.float32), rtol=8e-2, atol=8e-2)
