"""Train-step invariants: grad-accum equivalence, compression, mixed precision."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.training.train_step import make_train_step, train_state_init


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced(n_layers=2, vocab=128)
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)
    lab = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)
    return cfg, params, tok, lab


def _flat(t):
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in jax.tree.leaves(t)])


def test_loss_decreases(setup):
    cfg, params, tok, lab = setup
    step = jax.jit(make_train_step(cfg, lr=1e-2))
    state = train_state_init(params)
    losses = []
    for _ in range(8):
        state, m = step(state, tok, lab)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.xfail(
    strict=False,
    reason="backend-dependent bf16 rounding: CPU emulation of bf16 matmuls "
           "can push the accum-1 vs accum-4 parameter delta past one quantum",
)
def test_grad_accum_matches_full_batch(setup):
    """accum=4 microbatching must produce the same update as accum=1."""
    cfg, params, tok, lab = setup
    s1 = train_state_init(params)
    s4 = train_state_init(params)
    step1 = jax.jit(make_train_step(cfg, lr=1e-2, grad_accum=1))
    step4 = jax.jit(make_train_step(cfg, lr=1e-2, grad_accum=4))
    s1, m1 = step1(s1, tok, lab)
    s4, m4 = step4(s4, tok, lab)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=2e-2)
    p1, p4 = _flat(s1.params), _flat(s4.params)
    # bf16 params: one quantum of rounding noise allowed
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p4), atol=2e-2)


def test_compression_error_feedback(setup):
    """int8-compressed training still reduces loss; errors stay bounded."""
    cfg, params, tok, lab = setup
    step = jax.jit(make_train_step(cfg, lr=1e-2, compress=True))
    state = train_state_init(params)
    err = None
    losses = []
    for _ in range(8):
        state, m, err = step(state, tok, lab, err)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses
    enorm = float(jnp.sqrt(sum(jnp.sum(e * e) for e in jax.tree.leaves(err))))
    assert np.isfinite(enorm)


def test_opt_state_is_fp32(setup):
    cfg, params, tok, lab = setup
    state = train_state_init(params)
    for leaf in jax.tree.leaves(state.opt.mu):
        assert leaf.dtype == jnp.float32
