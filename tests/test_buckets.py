"""Bucketed variable-size block execution == the uniform-padded path.

Invariants (ISSUE 3 acceptance):
(a) bucketed loglik == single-bucket ``packed_loglik`` to 1e-10 (f64),
    across skewed block-size distributions and both extremes (all blocks
    in one bucket, one block per bucket);
(b) bucketed predict == ``predict_sbv`` to 1e-10;
(c) occupancy (true FLOPs / padded FLOPs) never decreases under
    bucketing and strictly improves on a skewed distribution;
(d) pack_blocks rejects sentinel-padded neighbor lists instead of
    silently gathering them as real masked-True rows (regression);
(e) per-bucket backend dispatch resolves 'auto' sanely.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    KernelParams, SBVConfig, bucket_blocks, bucket_prediction, packed_loglik,
    predict_sbv, preprocess,
)
from repro.core.blocks import build_blocks, scale_inputs
from repro.core.buckets import (
    BucketedBlocks, assign_buckets, bucket_ceilings, bucket_mults,
)
from repro.core.nns import filtered_nns
from repro.core.packing import pack_blocks

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

pytestmark = pytest.mark.buckets

PAR = KernelParams.create(sigma2=1.3, beta=[0.3, 0.5, 2.0], nugget=1e-2, d=3)


def skewed_data(seed=0, n_clusters=10, d=3):
    """Clustered inputs whose k-means/RAC blocks come out size-skewed."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(size=(n_clusters, d))
    sizes = rng.lognormal(3.0, 0.9, size=n_clusters).astype(int) + 5
    x = np.concatenate(
        [c + 0.04 * rng.normal(size=(s, d)) for c, s in zip(centers, sizes)]
    )
    y = rng.normal(size=x.shape[0])
    return x, y


@pytest.fixture(scope="module")
def skewed_packed():
    x, y = skewed_data()
    cfg = SBVConfig(n_blocks=20, m=25, clustering="kmeans")
    packed, blocks = preprocess(x, y, PAR.beta, cfg)
    return x, y, packed, blocks


# -- (a) likelihood equivalence ---------------------------------------

@pytest.mark.parametrize("n_buckets", [1, 2, 4, 10_000])
def test_bucketed_loglik_matches_uniform(skewed_packed, n_buckets):
    """K=1 (all blocks one bucket) through K>=bc (one block per realized
    size) all reproduce the uniform-padded likelihood."""
    _, _, packed, _ = skewed_packed
    ll_u = float(packed_loglik(PAR, packed))
    bucketed = bucket_blocks(packed, n_buckets=n_buckets)
    ll_b = float(packed_loglik(PAR, bucketed))
    np.testing.assert_allclose(ll_b, ll_u, rtol=1e-10)


def test_bucketed_loglik_tile_aligned(skewed_packed):
    """Tile-aligned ceilings (the pallas_tiled rules) stay exact."""
    _, _, packed, _ = skewed_packed
    bs_mult, m_mult = bucket_mults("pallas_tiled")
    bucketed = bucket_blocks(packed, n_buckets=4, bs_mult=bs_mult, m_mult=m_mult)
    np.testing.assert_allclose(
        float(packed_loglik(PAR, bucketed)), float(packed_loglik(PAR, packed)),
        rtol=1e-10,
    )


def test_single_bucket_is_identity(skewed_packed):
    """n_buckets=1 keeps every block in one batch at the global ceilings."""
    _, _, packed, _ = skewed_packed
    bucketed = bucket_blocks(packed, n_buckets=1)
    assert bucketed.n_buckets == 1
    assert bucketed.n_blocks == packed.n_blocks
    assert bucketed.n_points == packed.n_points
    pk = bucketed.buckets[0]
    # max true sizes, not the (possibly larger) source padding
    assert pk.bs_max == int(packed.blk_mask.sum(1).max())
    np.testing.assert_array_equal(np.sort(bucketed.ranks[0]),
                                  np.arange(packed.n_blocks))


def test_bucketed_preserves_blocks_and_points(skewed_packed):
    _, _, packed, _ = skewed_packed
    bucketed = bucket_blocks(packed, n_buckets=4)
    assert bucketed.n_blocks == packed.n_blocks
    assert bucketed.n_points == packed.n_points
    all_ranks = np.concatenate(bucketed.ranks)
    np.testing.assert_array_equal(np.sort(all_ranks), np.arange(packed.n_blocks))


# -- (b) prediction equivalence ---------------------------------------

@pytest.mark.parametrize("n_buckets", [2, 4, 10_000])
def test_bucketed_predict_matches_uniform(n_buckets):
    x, y = skewed_data(seed=3)
    rng = np.random.default_rng(4)
    xt = np.concatenate([
        rng.uniform(size=(150, 3)),
        x[:40] + 0.01 * rng.normal(size=(40, 3)),  # clustered queries: skew
    ])
    p_u = predict_sbv(PAR, x, y, xt, bs_pred=8, m_pred=40, seed=0, n_sims=2)
    p_b = predict_sbv(PAR, x, y, xt, bs_pred=8, m_pred=40, seed=0, n_sims=2,
                      n_buckets=n_buckets)
    np.testing.assert_allclose(p_b.mean, p_u.mean, atol=1e-10, rtol=0)
    np.testing.assert_allclose(p_b.var, p_u.var, atol=1e-10, rtol=0)


def test_bucketed_predict_chunked_matches_uniform():
    x, y = skewed_data(seed=5)
    xt = np.random.default_rng(6).uniform(size=(300, 3))
    p_u = predict_sbv(PAR, x, y, xt, bs_pred=8, m_pred=30, seed=1, n_sims=2,
                      chunk_size=128)
    p_b = predict_sbv(PAR, x, y, xt, bs_pred=8, m_pred=30, seed=1, n_sims=2,
                      chunk_size=128, n_buckets=4)
    np.testing.assert_allclose(p_b.mean, p_u.mean, atol=1e-10, rtol=0)
    np.testing.assert_allclose(p_b.var, p_u.var, atol=1e-10, rtol=0)


# -- (c) occupancy ----------------------------------------------------

def test_occupancy_improves_on_skew(skewed_packed):
    _, _, packed, _ = skewed_packed
    occ1 = bucket_blocks(packed, n_buckets=1).occupancy()
    occ4 = bucket_blocks(packed, n_buckets=4).occupancy()
    assert 0.0 < occ1 <= 1.0 and 0.0 < occ4 <= 1.0
    assert occ4 > occ1, (occ1, occ4)


def test_prediction_occupancy_improves():
    x, y = skewed_data(seed=7)
    from repro.core.predict import build_train_index, pack_queries

    index = build_train_index(x, y, np.asarray(PAR.beta), 30, seed=0)
    xt = np.random.default_rng(8).uniform(size=(250, 3))
    packed = pack_queries(index, xt, bs_pred=8, m_pred=30, seed=0)
    occ1 = bucket_prediction(packed, n_buckets=1).occupancy()
    occ4 = bucket_prediction(packed, n_buckets=4).occupancy()
    assert occ4 >= occ1
    assert 0.0 < occ4 <= 1.0


# -- bucket-boundary policy -------------------------------------------

def test_bucket_ceilings_cover_and_align():
    sizes = np.asarray([3, 7, 9, 20, 50, 200])
    for mult in (1, 8, 128):
        ceils = bucket_ceilings(sizes, 4, mult=mult)
        assert np.all(np.diff(ceils) > 0)
        assert ceils[-1] >= sizes.max()
        assert np.all(ceils % mult == 0)
        idx = assign_buckets(sizes, ceils)
        assert np.all(ceils[idx] >= sizes)
        # smallest admissible ceiling: the one below (if any) is too small
        prev = np.where(idx > 0, ceils[np.maximum(idx - 1, 0)], -1)
        assert np.all(prev < sizes)


def test_bucket_ceilings_uniform_sizes_collapse():
    ceils = bucket_ceilings(np.full(10, 17), 4, mult=1)
    assert ceils.tolist() == [17]


if HAVE_HYPOTHESIS:
    size_dists = st.lists(st.integers(min_value=1, max_value=60),
                          min_size=2, max_size=12)
else:  # stub strategies; tests below skip via @given
    size_dists = None


@given(sizes=size_dists, n_buckets=st.integers(min_value=1, max_value=6))
@settings(max_examples=15, deadline=None)
def test_property_bucketed_loglik_matches(sizes, n_buckets):
    """Random block-size distributions: bucketed == uniform likelihood."""
    rng = np.random.default_rng(sum(sizes) + n_buckets)
    d = 3
    x = np.concatenate([
        rng.uniform(size=(1, d)) + 0.05 * rng.normal(size=(s, d))
        for s in sizes
    ])
    y = rng.normal(size=x.shape[0])
    beta = np.asarray(PAR.beta)
    xs = scale_inputs(x, beta)
    blocks = build_blocks(xs, n_blocks=len(sizes), n_workers=1, beta=beta,
                          seed=0, method="kmeans")
    m = min(20, x.shape[0])
    neigh = filtered_nns(xs, blocks, m)
    packed = pack_blocks(x, y, blocks, neigh, m=m)
    ll_u = float(packed_loglik(PAR, packed))
    ll_b = float(packed_loglik(PAR, bucket_blocks(packed, n_buckets=n_buckets)))
    np.testing.assert_allclose(ll_b, ll_u, rtol=1e-10)


# -- (d) pack_blocks neighbor-validation regression -------------------

def test_pack_blocks_rejects_sentinel_padded_neighbors(skewed_packed):
    """A fixed-width neighbor array padded with -1 must raise, not wrap
    around to the last training point with nn_mask=True."""
    x, y, _, blocks = skewed_packed
    xs = scale_inputs(x, np.asarray(PAR.beta))
    neigh = filtered_nns(xs, blocks, 25)
    bad = list(neigh)
    short = next(i for i in range(len(bad)) if 0 < bad[i].size < 25)
    bad[short] = np.concatenate(
        [bad[short], np.full(25 - bad[short].size, -1, dtype=np.int64)]
    )
    with pytest.raises(ValueError, match="neighbor indices outside"):
        pack_blocks(x, y, blocks, bad, m=25)
    # repeat-of-last-index padding is in-range but just as corrupting:
    # duplicate conditioning rows -> near-singular covariance
    rep = list(neigh)
    rep[short] = np.concatenate(
        [rep[short], np.full(25 - rep[short].size, rep[short][-1])]
    )
    with pytest.raises(ValueError, match="duplicate neighbor indices"):
        pack_blocks(x, y, blocks, rep, m=25)


def test_pack_blocks_underfull_neighbors_masked(skewed_packed):
    """A block with fewer than m true neighbors packs a short masked row;
    the mask sum equals the true neighbor count, tail rows stay zero."""
    x, y, packed, blocks = skewed_packed
    xs = scale_inputs(x, np.asarray(PAR.beta))
    neigh = filtered_nns(xs, blocks, 25)
    for rank, b in enumerate(blocks.order):
        k = min(neigh[b].size, 25)
        assert packed.nn_mask[rank].sum() == k
        assert not packed.nn_mask[rank, k:].any()
        assert np.all(packed.nn_x[rank, k:] == 0.0)


# -- (e) backend dispatch ---------------------------------------------

def test_select_backend_policy():
    from repro.kernels.ops import select_backend

    # tile-aligned f32 predict shapes take the compiled tiled kernel
    assert select_backend(8, 128, "predict", np.float32) == "pallas_tiled"
    assert select_backend(16, 256, "predict", np.float32) == "pallas_tiled"
    # unaligned-but-big shapes use the fused kernel; small ones stay ref
    assert select_backend(25, 120, "predict", np.float64) == "pallas"
    assert select_backend(4, 16, "predict", np.float32) == "ref"
    # the loglik kernel has no tiled variant; big shapes go fused, small ref
    assert select_backend(16, 128, "loglik", np.float32) == "pallas"
    assert select_backend(2, 8, "loglik", np.float64) == "ref"
    # bf16-assembly buckets tile at the doubled (16, 128) sublane: 8-row
    # f32-aligned shapes are NOT tiled-eligible at bf16, 16-row ones are
    import jax.numpy as jnp
    assert select_backend(8, 256, "predict", jnp.bfloat16) == "pallas"
    assert select_backend(16, 128, "predict", jnp.bfloat16) == "pallas_tiled"
    assert select_backend(32, 256, "predict", jnp.bfloat16) == "pallas_tiled"
    # f64 never takes the compiled tiled kernel, whatever the alignment
    assert select_backend(8, 256, "predict", np.float64) == "pallas"
    assert select_backend(16, 128, "predict", np.float64) == "pallas"
    # bf16 loglik has no tiled variant either; sizes route as usual
    assert select_backend(16, 128, "loglik", jnp.bfloat16) == "pallas"
    assert select_backend(4, 8, "loglik", jnp.bfloat16) == "ref"


def test_packed_loglik_pallas_backend_per_bucket(skewed_packed):
    """Bucketed execution with the fused kernel matches ref per bucket."""
    _, _, packed, _ = skewed_packed
    bucketed = bucket_blocks(packed, n_buckets=3)
    ll_ref = float(packed_loglik(PAR, bucketed, backend="ref"))
    ll_pal = float(packed_loglik(PAR, bucketed, backend="pallas"))
    np.testing.assert_allclose(ll_pal, ll_ref, rtol=1e-6)


# -- distributed work-balanced sharding -------------------------------

def test_bucket_sharding_balances_true_work(skewed_packed):
    """Per-bucket equal-count splits give every shard an equal slice of
    every bucket, so per-shard TRUE work (Sigma bs*(bs+m)^2) is balanced
    to within a bucket's geometric width — unlike an equal-count split of
    the uniform layout, where one shard can end up holding the outliers."""
    from repro.core.buckets import block_flops
    from repro.core.distributed import shard_blocks_by_owner

    _, _, packed, _ = skewed_packed
    n_workers = 4

    def shard_loads(pieces):
        loads = np.zeros(n_workers)
        for pk in pieces:
            pk = shard_blocks_by_owner(pk, n_workers)
            per_shard = pk.n_blocks // n_workers
            w = block_flops(pk.blk_mask.sum(1), pk.nn_mask.sum(1))
            for p in range(n_workers):
                loads[p] += float(w[p * per_shard:(p + 1) * per_shard].sum())
        return loads

    # Sort blocks by size so the uniform contiguous split is maximally
    # skewed (the adversarial case bucket-by-bucket sharding defuses).
    order = np.argsort(packed.blk_mask.sum(1))
    sorted_packed = type(packed)(
        blk_x=packed.blk_x[order], blk_y=packed.blk_y[order],
        blk_mask=packed.blk_mask[order], nn_x=packed.nn_x[order],
        nn_y=packed.nn_y[order], nn_mask=packed.nn_mask[order],
        owners=packed.owners[order],
    )
    uniform_loads = shard_loads([sorted_packed])
    bucket_loads = shard_loads(bucket_blocks(sorted_packed, n_buckets=4).buckets)
    imbalance = lambda l: l.max() / max(l.mean(), 1.0)
    assert imbalance(bucket_loads) < imbalance(uniform_loads), (
        bucket_loads, uniform_loads)


@pytest.mark.slow
def test_distributed_bucketed_matches_serial():
    """Bucket-by-bucket sharded loglik == serial, in a subprocess with 8
    virtual devices (same pattern as test_distributed_gp)."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax
        from repro.core import KernelParams, SBVConfig, preprocess, bucket_blocks
        from repro.core.vecchia import packed_loglik
        from repro.core.distributed import (
            distributed_bucketed_loglik, distributed_neg_loglik_fn,
        )
        from repro.data.gp_sim import paper_synthetic

        assert jax.device_count() == 8, jax.device_count()
        mesh = jax.make_mesh((8,), ("workers",))
        x, y, params = paper_synthetic(seed=0, n=400, d=4)
        cfg = SBVConfig(n_blocks=48, m=20, n_workers=8, seed=0)
        packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
        bucketed = bucket_blocks(packed, n_buckets=4)

        ll_serial = float(packed_loglik(params, packed))
        ll_dist = float(distributed_bucketed_loglik(params, bucketed, mesh))
        np.testing.assert_allclose(ll_dist, ll_serial, rtol=1e-10)

        loss = distributed_neg_loglik_fn(bucketed, 3.5, mesh)
        np.testing.assert_allclose(
            float(loss(params)), -ll_serial / packed.n_points, rtol=1e-10)
        print("BUCKET_DIST_OK")
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BUCKET_DIST_OK" in out.stdout


# -- fit re-buckets per structure refresh -----------------------------

def test_fit_sbv_bucketed_smoke():
    x, y = skewed_data(seed=9, n_clusters=6)
    from repro.core.fit import fit_sbv

    res = fit_sbv(x, y, SBVConfig(n_blocks=8, m=15), inner_steps=4,
                  outer_rounds=2, n_buckets=3)
    losses = [h[2] for h in res.history]
    assert losses[-1] < losses[0]
    assert isinstance(res.packed, BucketedBlocks)  # re-bucketed each refresh


# -- mixed-precision ladder (docs/precision.md) -----------------------

tuning = pytest.mark.tuning


@tuning
def test_cast_packed_dtype_contract(skewed_packed):
    """Tier cast touches coordinates (storage) and observations (acc)
    only; boolean masks and integer owners pass through untouched."""
    import jax.numpy as jnp
    from repro.core.buckets import acc_dtype, cast_packed, storage_dtype

    _, _, packed, _ = skewed_packed
    for tier in ("bf16", "f32", "f64"):
        pk = cast_packed(packed, tier)
        assert pk.blk_x.dtype == storage_dtype(tier)
        assert pk.nn_x.dtype == storage_dtype(tier)
        assert pk.blk_y.dtype == acc_dtype(tier)
        assert pk.nn_y.dtype == acc_dtype(tier)
        np.testing.assert_array_equal(pk.blk_mask, packed.blk_mask)
        np.testing.assert_array_equal(pk.owners, packed.owners)
    assert storage_dtype("bf16") == jnp.bfloat16
    assert acc_dtype("bf16") == jnp.float32


@tuning
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_ladder_nll_within_tier_budget(skewed_packed, backend):
    """Per-bucket nll at each bucket's PROBED rung stays inside that
    rung's documented error budget relative to the f64 reference — the
    deployed-ladder contract ``assign_precision`` enforces by demotion
    (docs/precision.md). Checked independently of the probe here: the
    assigned tiers are re-evaluated bucket by bucket."""
    from repro.core.buckets import (
        PrecisionPolicy, assign_precision, cast_packed,
    )

    _, _, packed, _ = skewed_packed
    bucketed = bucket_blocks(packed, n_buckets=3)
    for want in ("bf16", "f32"):
        pol = PrecisionPolicy(tier=want)
        tiers = assign_precision(PAR, bucketed, pol, backend=backend)
        assert len(tiers) == len(bucketed.buckets)
        for pk, tier in zip(bucketed.buckets, tiers):
            ll_ref = float(packed_loglik(PAR, cast_packed(pk, "f64"),
                                         backend=backend))
            ll_t = float(packed_loglik(PAR, cast_packed(pk, tier),
                                       backend=backend))
            rel = abs(ll_t - ll_ref) / max(1.0, abs(ll_ref))
            assert np.isfinite(ll_t)
            assert rel <= pol.budget_for(tier), (want, tier, backend, rel)


@tuning
def test_assign_precision_demotes_over_budget(skewed_packed):
    """A vanishing budget forces every bucket down to f64; a loose one
    keeps the requested rung. Tiers align with the bucket list."""
    from repro.core.buckets import (
        PrecisionPolicy, apply_precision, assign_precision,
    )

    _, _, packed, _ = skewed_packed
    bucketed = bucket_blocks(packed, n_buckets=3)
    strict = assign_precision(
        PAR, bucketed, PrecisionPolicy(tier="bf16", error_budget=0.0))
    assert strict == ["f64"] * len(bucketed.buckets)
    loose = assign_precision(
        PAR, bucketed, PrecisionPolicy(tier="bf16", error_budget=1.0))
    assert loose == ["bf16"] * len(bucketed.buckets)
    mixed = apply_precision(bucketed, loose)
    ll = float(packed_loglik(PAR, mixed))
    assert np.isfinite(ll)


@tuning
def test_precision_fit_and_predict_mspe(skewed_packed):
    """bf16-assembly end to end: the fit converges with per-bucket
    probed tiers and prediction MSPE stays within the tier's budget of
    the f64 prediction."""
    from repro.core.fit import fit_sbv

    x, y, _, _ = skewed_packed
    cfg = SBVConfig(n_blocks=12, m=15)
    res = fit_sbv(x, y, cfg, inner_steps=4, outer_rounds=1, n_buckets=3,
                  precision="bf16")
    losses = [h[2] for h in res.history]
    assert losses[-1] < losses[0]
    assert res.precision_tiers is not None
    assert set(res.precision_tiers) <= {"bf16", "f32", "f64"}

    rng = np.random.default_rng(11)
    xt = rng.uniform(x.min(0), x.max(0), size=(120, x.shape[1]))
    p64 = predict_sbv(res.params, x, y, xt, bs_pred=10, m_pred=30, n_sims=2)
    p16 = predict_sbv(res.params, x, y, xt, bs_pred=10, m_pred=30, n_sims=2,
                      precision="bf16")
    assert np.all(np.isfinite(p16.mean)) and np.all(p16.var > 0)
    scale = float(np.sqrt(np.mean(p64.mean ** 2))) + 1e-12
    rel = float(np.sqrt(np.mean((p16.mean - p64.mean) ** 2))) / scale
    assert rel < 0.1, rel  # bf16 coords round at ~4e-3; keep headroom


@tuning
def test_autotune_record_reproduces_choices(tmp_path):
    """The autotuner's persisted record reloads to the same execution
    choices (ISSUE acceptance: TuningRecord reproduces choices on
    reload) and drives fit_sbv without re-measuring."""
    from repro.core.fit import fit_sbv
    from repro.tuning import TuningRecord, as_record, autotune_loglik

    x, y = skewed_data(seed=5, n_clusters=5)
    cfg = SBVConfig(n_blocks=10, m=12)
    rec = autotune_loglik(x, y, cfg, params=PAR, bucket_grid=(0, 2),
                          tiers=("bf16", "f64"), repeats=1,
                          save_dir=str(tmp_path))
    back = TuningRecord.load(str(tmp_path))
    assert back.to_dict() == rec.to_dict()
    assert (back.n_buckets, back.precision, back.bucket_tiers) == \
        (rec.n_buckets, rec.precision, rec.bucket_tiers)
    assert len(rec.candidates) == 4  # 2 bucket levels x 2 tiers measured
    assert as_record(str(tmp_path)).to_dict() == rec.to_dict()

    res = fit_sbv(x, y, cfg, inner_steps=3, outer_rounds=1,
                  tuning=str(tmp_path))
    losses = [h[2] for h in res.history]
    assert losses[-1] < losses[0]
