"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import KernelParams, SBVConfig, preprocess
from repro.core.vecchia import packed_loglik
from repro.kernels import ops
from repro.kernels.ref import matern_cov_ref
from repro.kernels.sbv_loglik import sbv_loglik_pallas


def _packed(n=60, d=3, bc=10, m=12, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, d))
    y = rng.normal(size=n)
    beta = np.linspace(0.3, 2.0, d)
    cfg = SBVConfig(n_blocks=bc, m=m, seed=seed, dtype=dtype)
    packed, _ = preprocess(x, y, beta, cfg)
    params = KernelParams.create(sigma2=1.4, beta=beta, nugget=1e-2)
    return params, packed


@pytest.mark.parametrize("n,d,bc,m", [
    (40, 2, 8, 6),
    (60, 3, 10, 12),
    (90, 5, 6, 24),
    (50, 10, 50, 8),   # CV-style: every block ~1 point
    (64, 4, 2, 40),    # few big blocks
])
def test_sbv_loglik_matches_ref_f64(n, d, bc, m):
    params, packed = _packed(n, d, bc, m)
    got = ops.sbv_loglik(
        params,
        jnp.asarray(packed.blk_x), jnp.asarray(packed.blk_y), jnp.asarray(packed.blk_mask),
        jnp.asarray(packed.nn_x), jnp.asarray(packed.nn_y), jnp.asarray(packed.nn_mask),
    )
    want = packed_loglik(params, packed, backend="ref")
    np.testing.assert_allclose(float(got), float(want), rtol=1e-9)


@pytest.mark.parametrize("nu", [0.5, 1.5, 2.5, 3.5])
def test_sbv_loglik_nu_sweep(nu):
    params, packed = _packed(50, 3, 8, 10)
    got = ops.sbv_loglik(
        params,
        jnp.asarray(packed.blk_x), jnp.asarray(packed.blk_y), jnp.asarray(packed.blk_mask),
        jnp.asarray(packed.nn_x), jnp.asarray(packed.nn_y), jnp.asarray(packed.nn_mask),
        nu,
    )
    want = packed_loglik(params, packed, nu=nu, backend="ref")
    np.testing.assert_allclose(float(got), float(want), rtol=1e-9)


def test_sbv_loglik_f32_close_to_f64():
    params, packed = _packed(60, 3, 10, 12)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    got = sbv_loglik_pallas(
        f32(params.beta), f32(params.sigma2), f32(params.nugget),
        f32(packed.blk_x), f32(packed.blk_y), f32(packed.blk_mask),
        f32(packed.nn_x), f32(packed.nn_y), f32(packed.nn_mask),
    )
    want = packed_loglik(params, packed, backend="ref")
    np.testing.assert_allclose(float(jnp.sum(got)), float(want), rtol=5e-4)


def test_sbv_loglik_gradient_matches_ref():
    params, packed = _packed(50, 3, 8, 10)
    args = (
        jnp.asarray(packed.blk_x), jnp.asarray(packed.blk_y), jnp.asarray(packed.blk_mask),
        jnp.asarray(packed.nn_x), jnp.asarray(packed.nn_y), jnp.asarray(packed.nn_mask),
    )
    g_pallas = jax.grad(lambda p: ops.sbv_loglik(p, *args))(params)
    g_ref = jax.grad(lambda p: packed_loglik(p, packed, backend="ref"))(params)
    for a, b in zip(jax.tree.leaves(g_pallas), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8)


@pytest.mark.parametrize("b,na,nb,d,tile", [
    (1, 16, 16, 2, 8),
    (3, 50, 70, 4, 32),   # non-divisible -> padding path
    (2, 128, 128, 8, 128),
    (1, 200, 33, 10, 64),
])
def test_matern_cov_matches_ref(b, na, nb, d, tile):
    rng = np.random.default_rng(1)
    xa = jnp.asarray(rng.uniform(size=(b, na, d)))
    xb = jnp.asarray(rng.uniform(size=(b, nb, d)))
    params = KernelParams.create(sigma2=0.7, beta=np.linspace(0.5, 1.5, d))
    got = ops.matern_cov(xa, xb, params, tile=tile)
    want = matern_cov_ref(xa, xb, params.beta, params.sigma2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-12)


def test_matern_cov_dtype_sweep():
    rng = np.random.default_rng(2)
    params = KernelParams.create(sigma2=1.0, beta=[0.5, 1.0])
    for dtype, tol in [(jnp.float32, 1e-5), (jnp.float64, 1e-12)]:
        xa = jnp.asarray(rng.uniform(size=(2, 20, 2)), dtype)
        got = ops.matern_cov(xa, xa, params, tile=16)
        want = matern_cov_ref(xa, xa, params.beta.astype(dtype), params.sigma2.astype(dtype))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)
