"""Regression tests for the MLE fit (paper Alg. 1 outer loop).

Pins three behaviors that the suite previously never checked:

* the gradient path actually optimizes: nll/n decreases
  monotonically-ish over inner steps (Adam may oscillate locally but the
  running best must keep improving and the final loss must land far
  below the start);
* the fitted beta recovers the ANISOTROPY ORDERING of the
  ``paper_synthetic`` generator (relevant dims 0-1 have true beta=0.05,
  the rest 5.0 — relevance estimation is the paper's Fig. 6/7 claim);
* the paper-faithful derivative-free path (``fit_neldermead``) reaches
  the same loss basin (smoke parity with the gradient path).
"""
import numpy as np
import pytest

from repro.core.fit import fit_neldermead, fit_sbv
from repro.core.pipeline import SBVConfig
from repro.data.gp_sim import paper_synthetic


@pytest.fixture(scope="module")
def fitted():
    x, y, params = paper_synthetic(seed=0, n=400, d=4)
    cfg = SBVConfig(n_blocks=24, m=24, seed=0)
    res = fit_sbv(x, y, cfg, inner_steps=40, outer_rounds=2, lr=0.1)
    return x, y, cfg, res


def test_fit_sbv_nll_decreases(fitted):
    _, _, _, res = fitted
    losses = [h[2] for h in res.history]
    assert np.all(np.isfinite(losses))
    # Strong overall decrease: the synthetic start is O(10^2), the optimum
    # is O(1) negative.
    assert losses[-1] < losses[0] - 10.0, (losses[0], losses[-1])
    # Monotonically-ish: the running best improves through the schedule
    # and local oscillations stay a minority of steps.
    running_best = np.minimum.accumulate(losses)
    assert running_best[len(losses) // 2] < losses[0] - 5.0
    n_increase = sum(1 for a, b in zip(losses, losses[1:]) if b > a + 1e-9)
    assert n_increase <= 0.4 * (len(losses) - 1), n_increase
    # Final loss is the best region visited (no late divergence).
    assert losses[-1] <= running_best[-1] + 1.0


def test_fit_sbv_recovers_anisotropy_ordering(fitted):
    _, _, _, res = fitted
    beta = np.exp(np.asarray(res.params.log_beta))
    relevant, irrelevant = beta[:2], beta[2:]
    # Every relevant dim must come out more relevant (smaller beta) than
    # every irrelevant dim, with a clear margin in the mean.
    assert relevant.max() < irrelevant.min(), beta
    assert relevant.mean() < 0.25 * irrelevant.mean(), beta


def test_fit_neldermead_smoke_parity(fitted):
    x, y, cfg, res = fitted
    nm = fit_neldermead(x, y, cfg, maxiter=150)
    nll_grad = res.history[-1][2]
    nll_nm = nm.history[-1][2]
    # Paper-faithful derivative-free path lands in the same basin: both
    # far below the ~O(10^2) start, within a couple nats/point of each
    # other (NM at 150 iters is expected to trail the analytic gradient).
    assert np.isfinite(nll_nm)
    assert nll_nm < 5.0, nll_nm
    assert abs(nll_nm - nll_grad) < 2.5, (nll_nm, nll_grad)
